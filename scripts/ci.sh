#!/usr/bin/env bash
# Per-PR gate: tier-1 test suite + a quick placement-scoring perf check so
# regressions in the batched scoring path show up in CI, not in Exp-2 runs.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

echo "== tier-1 tests =="
python -m pytest -x -q

echo "== kernel parity (forced Pallas interpreter) =="
# the interpret lowering executes the actual kernel bodies off-TPU; run the
# kernel parity suites under it explicitly so the lane is pinned even if the
# autouse fixtures ever change
REPRO_PALLAS_INTERPRET=1 python -m pytest -q tests/test_kernels.py tests/test_sweep_kernels.py

echo "== dispatch autotune (quick) =="
# the host-calibration path must work end to end on this container: a quick
# autotune under a wall-clock budget emits a profile that validates, and a
# second run must be a cached no-op (same host + valid profile => no probes)
DISPATCH_PROFILE_OUT="$(mktemp -d)/dispatch_profile.json"
python -m repro.serve.policy --quick --budget-s 120 --out "$DISPATCH_PROFILE_OUT"
python -m repro.serve.policy --validate "$DISPATCH_PROFILE_OUT"
python -m repro.serve.policy --quick --budget-s 120 --out "$DISPATCH_PROFILE_OUT" --expect-cached

# every perf gate below compares against baselines recorded under the
# built-in DispatchPolicy defaults; pin them so a tuned profile in this
# host's ~/.cache/repro/dispatch can never skew a gated ratio
export REPRO_DISPATCH_PROFILE=default

echo "== fused sweep kernel perf (quick) =="
# ONE stage-3 launch per fused forward (counter-asserted inside) and the
# fused sweep must amortize >= 1.2x over per-level launches on the interpret
# lowering; the kernel-routed merged engine must cost nothing on the jnp
# serving lowering (regression-gated vs the recorded baseline)
python benchmarks/kernel_bench.py --quick --min-fused-ratio 1.2 \
  --baseline benchmarks/baselines/kernel_bench_quick.json --max-regression 0.10

echo "== placement scoring perf (quick) =="
# the fast path must build each candidate graph exactly once (asserted inside),
# stay well ahead of the seed per-metric-rebuild path, and the fused/pallas
# scoring ratios must not regress >10% below the recorded baseline
python benchmarks/placement_bench.py --quick --min-speedup 3 \
  --baseline benchmarks/baselines/placement_bench_quick.json --max-regression 0.10

echo "== training step perf (quick) =="
# the unified engine's training step must stay >= 1.5x the seed per-member
# path at batch 256 and must not regress >10% below the recorded baseline;
# signature-exact banding must be no slower per step than the bucket-
# conservative plan (and strictly fewer stage-3 rows, asserted inside)
python benchmarks/training_bench.py --quick --min-speedup 1.5 \
  --min-exact-ratio 1.0 \
  --baseline benchmarks/baselines/training_bench_quick.json --max-regression 0.10

echo "== serving micro-batch perf (quick) =="
# PlacementService coalescing must stay >= 2x one-request-at-a-time
# submission and must not regress >10% below the recorded baseline
python benchmarks/serve_bench.py --quick --min-speedup 2 \
  --baseline benchmarks/baselines/serve_bench_quick.json --max-regression 0.10

echo "== mixed-stream cross-query perf (quick) =="
# the cross-query broadcast drain must answer a 16-distinct-structure stream
# >= 2x faster than the per-structure-group drain (one forward per drain vs
# one per structure) and must not regress >10% below the recorded baseline
python benchmarks/serve_bench.py --mode mixed --quick --min-speedup 2 \
  --baseline benchmarks/baselines/serve_bench_mixed_quick.json --max-regression 0.10

echo "== open-loop load harness (quick) =="
# sustained-load tail latency: the warmed double-buffered service must keep
# its open-loop p95 far below the pre-PR cold service at the same calibrated
# arrival rate (Poisson + bursty schedules, saturation-knee sweep inside),
# and the ratio must not regress >10% below the recorded baseline
python benchmarks/load_harness.py --quick --min-ratio 2 \
  --baseline benchmarks/baselines/load_harness_quick.json --max-regression 0.10

echo "== continuous placement controller (quick) =="
# seeded drift+failure scenario: the controller's end-of-run fleet cost must
# beat the do-nothing static baseline >= 2x (lane is deterministic -- the
# regression gate vs the recorded baseline trips on behavior changes, not
# noise), its largest move must respect the DispatchPolicy migration budget
# and its migration count the replan-every-tick oracle's (asserted inside),
# and the warm estimator lane's replan p95 is the SLO
python benchmarks/controller_bench.py --quick --min-ratio 2 \
  --max-replan-p95-ms 250 \
  --baseline benchmarks/baselines/controller_bench_quick.json --max-regression 0.10

echo "== chaos harness (quick) =="
# the fault-injection battery first (all injector seeds pinned inside —
# breaker/retry/deadline/swap/shadow/rollback semantics, incl. the
# end-to-end brownout->promote->reject->rollback lifecycle), then the bench:
python -m pytest -q tests/test_lifecycle.py
# seeded fault injection (transient raises, hangs, NaN outputs, slow host)
# against the live service under open-loop load: zero lost/failed futures
# under every profile (SystemExit inside on violation), the NaN profile must
# trip the circuit breaker into heuristic fallback, a corrupted on-disk
# bundle must be rejected by load(verify=True), and the worst-profile p95 of
# NON-faulted requests must stay within --p95-budget of the fault-free
# control run and within 10% of the recorded baseline
python benchmarks/chaos_bench.py --quick --p95-budget 6.0 \
  --baseline benchmarks/baselines/chaos_bench_quick.json --max-regression 0.10

echo "== examples smoke (API drift gate) =="
# the examples exercise the public train->bundle->serve surface end to end;
# tiny corpus/epoch settings via --smoke
python examples/quickstart.py --smoke
python examples/optimize_placement.py --smoke
python examples/controller_demo.py --smoke
