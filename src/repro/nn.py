"""Minimal functional NN substrate (no flax/optax in this environment).

Parameters are plain pytrees (nested dicts of jnp arrays); every layer is an
``init(key, ...) -> params`` / ``apply(params, x) -> y`` pair. Used by both the
COSTREAM GNN and the LM stack.
"""

from __future__ import annotations

import math
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, object]


# -- initializers -------------------------------------------------------------


def glorot(key: jax.Array, shape: Tuple[int, ...], dtype=jnp.float32) -> jax.Array:
    fan_in, fan_out = shape[-2], shape[-1]
    scale = math.sqrt(2.0 / (fan_in + fan_out))
    return scale * jax.random.normal(key, shape, dtype)


def he(key: jax.Array, shape: Tuple[int, ...], dtype=jnp.float32) -> jax.Array:
    fan_in = shape[-2]
    return math.sqrt(2.0 / fan_in) * jax.random.normal(key, shape, dtype)


def normal(key: jax.Array, shape: Tuple[int, ...], stddev: float = 0.02, dtype=jnp.float32):
    return stddev * jax.random.normal(key, shape, dtype)


# -- dense / MLP ---------------------------------------------------------------


def init_linear(key: jax.Array, d_in: int, d_out: int, dtype=jnp.float32) -> Params:
    kw, _ = jax.random.split(key)
    return {"w": glorot(kw, (d_in, d_out), dtype), "b": jnp.zeros((d_out,), dtype)}


def apply_linear(p: Params, x: jax.Array) -> jax.Array:
    return x @ p["w"] + p["b"]


def init_mlp(key: jax.Array, sizes: Sequence[int], dtype=jnp.float32) -> Params:
    keys = jax.random.split(key, len(sizes) - 1)
    return {
        "layers": [
            init_linear(k, sizes[i], sizes[i + 1], dtype) for i, k in enumerate(keys)
        ]
    }


def apply_mlp(
    p: Params, x: jax.Array, act: Callable[[jax.Array], jax.Array] = jax.nn.relu
) -> jax.Array:
    layers = p["layers"]
    for i, layer in enumerate(layers):
        x = apply_linear(layer, x)
        if i < len(layers) - 1:
            x = act(x)
    return x


# -- banked (per-node-type) MLPs ------------------------------------------------
# A bank stacks T type-specific MLPs as leading-axis weight stacks; application
# computes all types and selects with a one-hot mask. With T <= 7 this is a
# masked-matmul — the MXU-friendly formulation (see DESIGN.md SS4); the Pallas
# kernel in repro.kernels fuses it.


def init_mlp_bank(
    key: jax.Array, n_types: int, sizes: Sequence[int], dtype=jnp.float32
) -> Params:
    keys = jax.random.split(key, len(sizes) - 1)
    layers = []
    for i, k in enumerate(keys):
        sub = jax.random.split(k, n_types)
        w = jnp.stack([glorot(s, (sizes[i], sizes[i + 1]), dtype) for s in sub])
        b = jnp.zeros((n_types, sizes[i + 1]), dtype)
        layers.append({"w": w, "b": b})
    return {"layers": layers}


def apply_mlp_bank(
    p: Params,
    x: jax.Array,
    type_onehot: jax.Array,
    act: Callable[[jax.Array], jax.Array] = jax.nn.relu,
) -> jax.Array:
    """x: (..., N, F); type_onehot: (..., N, T) -> (..., N, H).

    Per layer, select each node's type-specific weights via the one-hot:
    y = x @ W[t(n)] + b[t(n)]. Formulated as T masked GEMMs (rows of the
    "wrong" type are zeroed before the matmul) — dense, static, MXU-friendly,
    and much faster than materializing the (N, T, H) bank product.
    """
    layers = p["layers"]
    n_types = layers[0]["w"].shape[0]
    for i, layer in enumerate(layers):
        y = type_onehot @ layer["b"]
        for t in range(n_types):
            y = y + (x * type_onehot[..., t : t + 1]) @ layer["w"][t]
        x = act(y) if i < len(layers) - 1 else y
    return x


def apply_mlp_bank_slotted(
    p: Params,
    x: jax.Array,
    slot_ranges: Sequence[Tuple[int, int, int]],
    act: Callable[[jax.Array], jax.Array] = jax.nn.relu,
) -> jax.Array:
    """Banked MLP over a *canonical slot layout*: nodes are pre-sorted so that
    all nodes of type t live in the static slot range [start, stop).

    ``slot_ranges``: sequence of (type_id, start, stop). Each layer then runs
    one narrow GEMM per type on its slice — no masking waste at all, and the
    slices are static (TPU/Pallas-friendly). x: (..., N, F) -> (..., N, H).
    """
    layers = p["layers"]
    for i, layer in enumerate(layers):
        pieces = []
        for t, start, stop in slot_ranges:
            pieces.append(x[..., start:stop, :] @ layer["w"][t] + layer["b"][t])
        y = jnp.concatenate(pieces, axis=-2)
        x = act(y) if i < len(layers) - 1 else y
    return x


# -- norms ------------------------------------------------------------------------


def init_layernorm(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def apply_layernorm(p: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return y * p["scale"] + p["bias"]


def init_rmsnorm(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


def apply_rmsnorm(p: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + p["scale"].astype(jnp.float32))).astype(dtype)


# -- misc ---------------------------------------------------------------------------


def count_params(params) -> int:
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(params))


def cast_floats(params, dtype):
    def _cast(x):
        if jnp.issubdtype(x.dtype, jnp.floating):
            return x.astype(dtype)
        return x

    return jax.tree_util.tree_map(_cast, params)
