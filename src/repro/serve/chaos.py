"""Seeded, deterministic fault injection for the serving stack.

Production estimators fail in a handful of characteristic ways — a forward
raises (driver hiccup, OOM), a forward hangs (device contention), the model
emits NaN/Inf (bad bundle, out-of-distribution input), the bundle on disk is
corrupt (truncated upload), or the host is simply slow.  This module turns
each of those into an *injector* that installs via the estimator hook seam
(``CostEstimator.add_hook``) and misbehaves on a seeded schedule, so a chaos
run is exactly reproducible: same seed + same request order = same faults at
the same calls.

Injector protocol (duck-typed, matches the estimator's hook seam):

* ``before(kind, n)`` — called when a forward for ``kind`` (``"score"``,
  ``"estimate"``, ...) covering ``n`` rows is dispatched.  Raising here
  fails the forward before any device work.
* ``after(kind, out) -> out | None`` — called when the forward's results
  materialize at drain-finalize.  Returning a value replaces the output
  (how ``NaNFault`` poisons results); returning ``None`` keeps it.

Every injector has an ``enabled`` flag (flip it to open/close the fault
window without touching the hook list), an ``n_injected`` counter, and draws
from its own ``numpy`` Generator.  ``benchmarks/chaos_bench.py`` drives the
open-loop load harness under each profile; ``docs/robustness.md`` catalogs
the profiles and the budgets they are gated against.
"""

from __future__ import annotations

import glob
import os
import time
from typing import Callable, Dict, Optional

import numpy as np


class ChaosError(RuntimeError):
    """An injected transient fault (retryable, unlike a typed verdict)."""


class _Injector:
    """Common machinery: seeded rng, enable window, injection counter."""

    def __init__(self, p: float = 1.0, seed: int = 0):
        assert 0.0 <= p <= 1.0, p
        self.p = p
        self.rng = np.random.default_rng(seed)
        self.enabled = True
        self.n_injected = 0

    def _fire(self) -> bool:
        # the rng is consumed even while disabled so the post-window draws
        # don't depend on how long the window was — the schedule stays a
        # pure function of (seed, call index)
        hit = self.rng.random() < self.p
        if not self.enabled:
            return False
        if hit:
            self.n_injected += 1
        return hit

    def before(self, kind: str, n: int) -> None:  # pragma: no cover - default
        pass

    def after(self, kind: str, out):  # pragma: no cover - default
        return None


class RaiseFault(_Injector):
    """Forward raises ``ChaosError`` at dispatch with probability ``p``."""

    def before(self, kind: str, n: int) -> None:
        if self._fire():
            raise ChaosError(f"injected raise on {kind} ({n} rows)")


class HangFault(_Injector):
    """Forward hangs for ``hang_s`` at dispatch with probability ``p``.

    The hang is a bounded sleep, not an unbounded block: the point is to
    push requests past their deadline / SLO budget deterministically, not to
    wedge the test process.
    """

    def __init__(self, hang_s: float = 0.2, p: float = 1.0, seed: int = 0):
        super().__init__(p=p, seed=seed)
        assert hang_s >= 0.0, hang_s
        self.hang_s = hang_s

    def before(self, kind: str, n: int) -> None:
        if self._fire():
            time.sleep(self.hang_s)


class NaNFault(_Injector):
    """Poison a forward's outputs with NaN with probability ``p``.

    Replaces the first value of every float metric in the result — the
    estimator's always-on finite guard then raises ``NonFiniteEstimate``,
    which is exactly the path a silently-garbage model exercises.  Outputs
    are copied, never mutated in place: the fault corrupts what this caller
    sees, not shared buffers.
    """

    def after(self, kind: str, out):
        if not self._fire():
            return None
        items = out if isinstance(out, (list, tuple)) else [out]
        poisoned = []
        for d in items:
            if d is None:
                poisoned.append(d)
                continue
            bad = {}
            for m, v in d.items():
                v = np.asarray(v)
                if v.dtype.kind == "f" and v.size:
                    v = v.copy()
                    v.flat[0] = np.nan
                bad[m] = v
            poisoned.append(bad)
        return poisoned if isinstance(out, (list, tuple)) else poisoned[0]


class SlowHost(_Injector):
    """Every forward pays an extra ``delay_s`` — a uniformly slow host.

    Unlike ``HangFault`` this is not probabilistic: slowness is a property
    of the host, not of individual calls, so ``p`` defaults to 1 and the
    delay applies to each dispatched forward while enabled.
    """

    def __init__(self, delay_s: float = 0.02, seed: int = 0):
        super().__init__(p=1.0, seed=seed)
        assert delay_s >= 0.0, delay_s
        self.delay_s = delay_s

    def before(self, kind: str, n: int) -> None:
        if self._fire():
            time.sleep(self.delay_s)


def corrupt_bundle(directory: str, seed: int = 0, n_bytes: int = 64) -> str:
    """Flip bytes inside the bundle's ``arrays.npz`` — a truncated/bit-rotted
    artifact on disk.  Returns the corrupted file's path.

    The corruption targets the newest step dir (the one ``load`` picks) and
    overwrites ``n_bytes`` seeded positions past the zip header, so
    ``CostModelBundle.load(verify=True)`` reliably rejects it while the
    file still *exists* and still looks like a bundle to a directory listing.
    """
    candidates = sorted(glob.glob(os.path.join(directory, "step_*", "arrays.npz")))
    if not candidates:
        raise FileNotFoundError(f"no step_*/arrays.npz under {directory}")
    path = candidates[-1]
    size = os.path.getsize(path)
    rng = np.random.default_rng(seed)
    # skip the first 512 bytes when the file allows: corrupting the member
    # payloads (not just the magic) exercises the per-metric verify path,
    # not only np.load's header check
    lo = min(512, max(0, size - n_bytes - 1))
    positions = rng.integers(lo, size, size=min(n_bytes, size))
    with open(path, "r+b") as f:
        for pos in positions:
            f.seek(int(pos))
            byte = f.read(1)
            f.seek(int(pos))
            f.write(bytes([byte[0] ^ 0xFF if byte else 0xFF]))
    return path


def profiles(seed: int = 0) -> Dict[str, Callable[[], Optional[_Injector]]]:
    """The chaos-profile catalog: name -> fresh-injector factory.

    Factories (not instances) so each benchmark phase gets an injector with
    a pristine rng — reusing one across phases would make the second phase's
    fault schedule depend on the first's call count.  ``corrupt_bundle`` is
    not listed: it is an on-disk fault, injected at load time, not a hook.
    """
    return {
        "raise": lambda: RaiseFault(p=0.3, seed=seed),
        "hang": lambda: HangFault(hang_s=0.08, p=0.3, seed=seed),
        "nan": lambda: NaNFault(p=0.4, seed=seed),
        "slow_host": lambda: SlowHost(delay_s=0.01, seed=seed),
    }
