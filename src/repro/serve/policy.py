"""``DispatchPolicy``: every serving dispatch tunable in ONE calibrated object.

COSTREAM's pitch is cheap, accurate cost estimates on *heterogeneous*
hardware — yet a serving stack that hardcodes its own performance crossovers
(merged-vs-per-structure row limit, chunk widths, cache capacities) is
implicitly calibrated to whatever container those constants were measured on.
This module makes the dispatch layer hold itself to the same standard as the
model it serves (the retrofitting playbook in PAPERS.md): all tunables live
on a frozen, JSON-serializable ``DispatchPolicy``, and ``autotune()``
measures the real crossovers on the running host with short seeded probes.

The policy is strictly a *performance* object: any valid policy yields
float-identical ``score``/``estimate`` results (test-pinned) — it decides how
work is batched, routed, chunked, and cached, never what is computed.

Resolution order (``resolve_policy``), applied by ``CostEstimator`` /
``PlacementService`` when constructed without an explicit ``policy=``:

1. ``REPRO_DISPATCH_PROFILE`` env var — ``"default"`` (or ``"none"``/``"0"``)
   pins the built-in defaults (CI and tests use this so routing assertions
   and perf baselines stay comparable across containers); any other value is
   a profile JSON path, loaded without a host check (an explicit pin);
2. the per-host profile cache ``~/.cache/repro/dispatch/<fingerprint>.json``
   written by ``autotune()`` — loaded only when its recorded host
   fingerprint matches this machine (a copied cache directory silently
   falling back to defaults instead of mis-tuning);
3. the built-in defaults.

Cache-capacity sizing rationale (the ONE place these numbers live): each
capacity scales with rebuild-cost over per-entry footprint.  Jit traces are
the most expensive entries to lose (a recompile costs seconds) and the
cheapest to keep (a host-side callable), so ``trace_cache_size`` anchors the
budget; banding plans are tiny pure-Python tuples (2x traces); featurized
skeletons hold device-resident arrays (trace/4); merged cross-query groups
hold a whole device skeleton *stack* per entry (trace/8).

CLI (used by ``scripts/ci.sh``)::

    python -m repro.serve.policy --quick [--out PATH] [--budget-s S]
        [--expect-cached]   # fail if a probe ran (the profile must be warm)
    python -m repro.serve.policy --validate PATH

Methodology and field reference: docs/dispatch.md.
"""

from __future__ import annotations

import contextlib
import hashlib
import json
import math
import os
import platform
import time
import warnings
from dataclasses import asdict, dataclass, fields, replace
from pathlib import Path
from typing import Dict, List, Optional, Tuple

#: Bump when the profile JSON layout changes; older profiles are ignored
#: (fall back to defaults), never misread.
PROFILE_SCHEMA_VERSION = 1

#: Env var: "default"/"none"/"0" pins built-in defaults; otherwise a path.
PROFILE_ENV = "REPRO_DISPATCH_PROFILE"

_DEFAULT_CACHE_DIR = Path("~/.cache/repro/dispatch")


class DispatchProfileWarning(UserWarning):
    """A dispatch profile exists on disk but was rejected (corrupt, stale,
    or recorded on another host) — the process runs built-in defaults."""


@dataclass(frozen=True)
class RetryPolicy:
    """Seeded exponential-backoff retry schedule for transient estimator
    failures (a view over the ``retry_*`` fields of ``DispatchPolicy``;
    consumed by ``PlacementService``).  ``max_attempts`` counts the first
    try: 1 disables retries."""

    max_attempts: int = 2
    backoff_s: float = 0.02
    jitter: float = 0.5

    def sleep_s(self, attempt: int, u: float) -> float:
        """Backoff before retry ``attempt`` (1-based) given ``u`` ~ U(0,1)."""
        return self.backoff_s * (2.0 ** (attempt - 1)) * (1.0 + u * self.jitter)


@dataclass(frozen=True)
class DispatchPolicy:
    """Every tunable the serving stack used to inline as a magic constant.

    Frozen and hashable: a policy can key caches and ride jit-trace keys.
    All fields are performance knobs — see the module docstring for the
    invariant (results never depend on the policy) and docs/dispatch.md for
    the per-field methodology.
    """

    # -- routing crossovers (host-measurable; autotune targets) -----------------
    #: Merged-vs-per-structure drain crossover: a score drain averaging at
    #: most this many candidate rows per structure is dispatch-bound and
    #: merges into one cross-query forward; above it, per-structure
    #: specialized forwards win their dispatch back.  None: always merge.
    cross_query_row_limit: Optional[int] = 16
    #: Candidate-panel width of the placed stacked forward
    #: (``gnn.apply_gnn_placed_stacked``): the scan chunk that keeps the
    #: per-stage activation working set cache-resident.  0 disables chunking.
    score_chunk: int = 256
    # -- batching ----------------------------------------------------------------
    #: Rows (score) / graphs (estimate) per fused forward; oversized drains
    #: are chunked to this width (``PlacementService.max_batch`` and the
    #: estimator's ``max_rows``).
    max_batch: int = 1024
    #: First-seen runtime structure mixes admitted to the merged path
    #: (compile-cache bound under open-loop arrivals).  None: unbounded.
    max_merged_mixes: Optional[int] = 32
    #: Drain pipelining: None = auto (on for accelerator backends, off on
    #: CPU where host and device share cores); True/False forces.
    double_buffer: Optional[bool] = None
    #: ``start()`` warmup breadth: candidate buckets pre-compiled per warmed
    #: structure (powers of two up to this).
    warmup_cands: int = 8
    # -- kernel tiling (Pallas/interpret lowerings only) -------------------------
    #: Batch-row tile cap of the fused stage-3 sweep kernel
    #: (``kernels/mp_sweep``): the largest divisor of the batch not above
    #: this bounds one program's VMEM working set.  Unused on the jnp-oracle
    #: lowering (XLA owns its own tiling there).
    sweep_tile_rows: int = 128
    #: Batch-row tile cap of the segment gather/scatter kernels
    #: (``kernels/seg_gather``), same contract as ``sweep_tile_rows``.
    seg_gather_tile: int = 128
    # -- placement search --------------------------------------------------------
    #: Default candidate-sample size of ``PlacementOptimizer.optimize``.
    search_k: int = 64
    #: Elites mutated per hill-climb refinement round (the refinement top-k).
    refine_top: int = 8
    # -- continuous placement controller (repro.control; docs/controller.md) -----
    #: Telemetry tick interval [simulated s].  30 s matches the Storm-style
    #: monitoring loop of the Exp-2b baseline and gives 8 ticks per paper
    #: 4-minute measurement window — coarse enough that one tick amortizes a
    #: fused re-scoring pass, fine enough to catch drift inside one window.
    controller_tick_s: float = 30.0
    #: Drift-detector window [ticks]: EWMA span and CUSUM minimum run length.
    #: 4 ticks = 2 minutes of telemetry — half a measurement window, the
    #: shortest span over which the simulator's log-normal measurement noise
    #: (sigma=0.12) averages well below real drift steps (>= log 2).
    detector_window: int = 4
    #: CUSUM alarm level on the log(observed/predicted) cost residual.  With
    #: per-tick noise sigma ~= 0.12 and the detector's slack k = 2*sigma, a
    #: sustained 2x cost drift (residual ~= 0.7) crosses 1.5 within ~3 ticks
    #: while pure noise needs a >12-sigma excursion — alarms inside one
    #: detector window without firing on measurement noise.
    drift_threshold: float = 1.5
    #: Max window-state bytes one re-placement may move [MB], modeled as
    #: migration downtime.  64 MB covers the full state of typical corpus
    #: windows (count windows of ~1e3-1e4 tuples at ~100 B/tuple with JVM
    #: overhead) while excluding bulk moves of several large stateful ops at
    #: once; 0 disables migrations entirely (detect-only mode).
    migration_budget_mb: float = 64.0
    #: Ticks a re-placed query is held before it may re-plan again.  3 ticks
    #: covers detector_window - 1 post-migration samples, so the detector
    #: re-arms on post-move telemetry instead of thrashing on the residual
    #: spike the migration itself caused.  0 disables the cooldown.
    replan_cooldown_ticks: int = 3
    #: Re-placement search breadth: candidate sub-assignments scored per
    #: affected query.  Half of ``search_k``: the frozen prefix shrinks the
    #: space (only affected ops move), and re-plan latency is an SLO — 32
    #: rows ride one fused forward well under the p95 gate in
    #: benchmarks/controller_bench.py.
    replan_k: int = 32
    # -- serving robustness (serve.lifecycle + serve.service; docs/robustness.md)
    #: Fraction of drained score requests mirrored through a shadow candidate
    #: estimator during a ``BundleSwapper`` shadow phase.  0.5 halves the
    #: shadow-side device load while still covering every structure in a
    #: mixed stream within ~2x ``shadow_min_requests`` drains.
    shadow_fraction: float = 0.5
    #: Minimum mirrored requests before a shadow verdict may accept; below
    #: this the divergence statistics are noise and ``promote`` rejects with
    #: an "insufficient shadow traffic" verdict rather than guessing.
    shadow_min_requests: int = 8
    #: Minimum Spearman rank correlation between candidate and live placement
    #: orderings.  Placement search consumes *orderings*, not absolute costs
    #: (argmin over candidates), so rank agreement is the acceptance signal
    #: that predicts identical placement decisions; 0.8 tolerates local
    #: re-ranking among near-ties while rejecting models that invert rankings.
    shadow_rank_corr_min: float = 0.8
    #: Maximum mean relative cost error of the candidate vs the live answers.
    #: Guards the cost *magnitudes* the controller's drift detector consumes
    #: (a rank-preserving 3x inflation would trip every CUSUM alarm); 0.25
    #: stays under the detector's sustained-drift alarm level (log 2 ~= 0.7).
    shadow_rel_err_max: float = 0.25
    #: Bound on the shadow mirror queue (requests awaiting candidate scoring
    #: off the critical path).  When full, new mirror samples are dropped —
    #: shadow evaluation sheds load, it never backpressures live traffic.
    shadow_queue_depth: int = 64
    #: Drained requests observed after a promotion before the health verdict.
    #: One breaker window (x2) of post-swap traffic: long enough to see a
    #: systematic regression, short enough to roll back within seconds.
    health_window_requests: int = 32
    #: Max (degraded + non-finite + failed + timed-out) / drained over the
    #: post-promotion health window before auto-rollback.  0.1 sits well
    #: above the healthy-path error rate (~0 on a good bundle) and below the
    #: breaker's open threshold — rollback fires before the breaker trips.
    health_error_rate_max: float = 0.1
    #: Total attempts per estimator call (1 = no retry).  2 covers the
    #: transient single-shot failures chaos testing injects without letting
    #: a deterministic failure triple drain latency.
    retry_max_attempts: int = 2
    #: Base of the seeded exponential backoff between retries [s]: attempt k
    #: sleeps ``retry_backoff_s * 2**k * (1 + U(0,1) * retry_jitter)``.  20 ms
    #: is one drain's worth of budget — enough for a GC pause or allocator
    #: hiccup to clear, small enough to stay inside a request deadline.
    retry_backoff_s: float = 0.02
    #: Uniform jitter fraction on the backoff (decorrelates retry storms
    #: across workers; 0 disables).
    retry_jitter: float = 0.5
    #: Sliding window [request outcomes] the circuit breaker evaluates.
    #: One max-size drain (16 cross-query rows) of history: the breaker
    #: reacts to the current failure mode, not to stale incidents.
    breaker_window: int = 16
    #: Failure fraction over the window that opens the breaker.  0.5 means a
    #: majority of recent forwards failed — the estimator is effectively
    #: down, and heuristic answers beat a coin-flip estimator.
    breaker_failure_rate: float = 0.5
    #: Outcomes required in-window before the rate is trusted (a single
    #: failure after idle must not open the breaker).
    breaker_min_samples: int = 4
    #: Seconds the breaker stays open before half-open probes the estimator
    #: with one real request.  0.5 s covers a device reset or cache refill
    #: without serving minutes of heuristic answers after recovery.
    breaker_cooldown_s: float = 0.5
    # -- cache capacities (sizing rationale: module docstring) -------------------
    #: Jitted-forward trace entries (all module-level trace caches in
    #: ``serve.estimator`` share this budget anchor).
    trace_cache_size: int = 256
    #: Stage-3 banding plans (``core.bucketing``): tiny tuples, 2x traces.
    banding_cache_size: int = 512
    #: Device-resident (query, cluster) skeleton entries: trace/4.
    skeleton_cache_size: int = 64
    #: Merged cross-query groups (device skeleton stacks): trace/8.
    merged_group_cache_size: int = 32

    # -- validation / serialization ---------------------------------------------

    def validate(self) -> "DispatchPolicy":
        """Raise ``ValueError`` on an out-of-range field; return self."""

        def _positive(name: str, allow_none: bool = False, allow_zero: bool = False):
            v = getattr(self, name)
            if v is None:
                if not allow_none:
                    raise ValueError(f"DispatchPolicy.{name} must not be None")
                return
            if not isinstance(v, int) or isinstance(v, bool):
                raise ValueError(f"DispatchPolicy.{name} must be an int, got {v!r}")
            if v < 0 or (v == 0 and not allow_zero):
                raise ValueError(f"DispatchPolicy.{name} must be positive, got {v}")

        def _positive_f(name: str, allow_zero: bool = False):
            v = getattr(self, name)
            if isinstance(v, bool) or not isinstance(v, (int, float)):
                raise ValueError(f"DispatchPolicy.{name} must be a number, got {v!r}")
            if not math.isfinite(v) or v < 0 or (v == 0 and not allow_zero):
                raise ValueError(f"DispatchPolicy.{name} must be positive, got {v}")

        def _fraction(name: str, lo: float = 0.0, hi: float = 1.0, allow_lo: bool = True):
            v = getattr(self, name)
            if isinstance(v, bool) or not isinstance(v, (int, float)):
                raise ValueError(f"DispatchPolicy.{name} must be a number, got {v!r}")
            if not math.isfinite(v) or v > hi or v < lo or (v == lo and not allow_lo):
                raise ValueError(
                    f"DispatchPolicy.{name} must be in [{lo}, {hi}], got {v}"
                )

        _positive("cross_query_row_limit", allow_none=True, allow_zero=True)
        _positive("score_chunk", allow_zero=True)
        _positive("max_batch")
        _positive("max_merged_mixes", allow_none=True, allow_zero=True)
        _positive("sweep_tile_rows")
        _positive("seg_gather_tile")
        _positive("warmup_cands")
        _positive("search_k")
        _positive("refine_top")
        _positive_f("controller_tick_s")
        _positive("detector_window")
        _positive_f("drift_threshold")
        _positive_f("migration_budget_mb", allow_zero=True)
        _positive("replan_cooldown_ticks", allow_zero=True)
        _positive("replan_k")
        _fraction("shadow_fraction")
        _positive("shadow_min_requests")
        _fraction("shadow_rank_corr_min", lo=-1.0)
        _positive_f("shadow_rel_err_max")
        _positive("shadow_queue_depth")
        _positive("health_window_requests")
        _fraction("health_error_rate_max", allow_lo=False)
        _positive("retry_max_attempts")
        _positive_f("retry_backoff_s", allow_zero=True)
        _fraction("retry_jitter")
        _positive("breaker_window")
        _fraction("breaker_failure_rate", allow_lo=False)
        _positive("breaker_min_samples")
        _positive_f("breaker_cooldown_s", allow_zero=True)
        if self.breaker_min_samples > self.breaker_window:
            raise ValueError(
                "DispatchPolicy.breaker_min_samples must not exceed "
                f"breaker_window ({self.breaker_min_samples} > {self.breaker_window})"
            )
        _positive("trace_cache_size")
        _positive("banding_cache_size")
        _positive("skeleton_cache_size")
        _positive("merged_group_cache_size")
        if self.double_buffer not in (None, True, False):
            raise ValueError(
                f"DispatchPolicy.double_buffer must be None/True/False, "
                f"got {self.double_buffer!r}"
            )
        return self

    def to_dict(self) -> Dict:
        return asdict(self)

    @classmethod
    def from_dict(cls, d: Dict) -> "DispatchPolicy":
        """Strict inverse of ``to_dict``: unknown keys raise (schema guard)."""
        known = {f.name for f in fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(f"unknown DispatchPolicy fields: {sorted(unknown)}")
        return cls(**d).validate()

    def retry_policy(self) -> RetryPolicy:
        """The ``retry_*`` fields as one ``RetryPolicy`` view."""
        return RetryPolicy(
            max_attempts=self.retry_max_attempts,
            backoff_s=self.retry_backoff_s,
            jitter=self.retry_jitter,
        )

    def resolved_double_buffer(self) -> bool:
        """The backend-auto rule, applied: launch-ahead only pays where device
        compute runs beside the host; on CPU they share cores, so the split
        just fragments drains (measured in serve_bench)."""
        if self.double_buffer is not None:
            return bool(self.double_buffer)
        import jax

        return jax.default_backend() != "cpu"


# -- host identity ----------------------------------------------------------------


def host_descriptor() -> Dict[str, object]:
    """The hardware/runtime identity a tuned profile is valid for."""
    import jax

    return {
        "node": platform.node(),
        "machine": platform.machine(),
        "cpu_count": os.cpu_count(),
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
    }


def host_fingerprint(descriptor: Optional[Dict] = None) -> str:
    """Stable digest of ``host_descriptor()`` — the profile cache key."""
    d = descriptor if descriptor is not None else host_descriptor()
    blob = json.dumps(d, sort_keys=True).encode()
    return hashlib.sha256(blob).hexdigest()[:16]


def profile_path(fingerprint: Optional[str] = None) -> Path:
    """The per-host profile cache location (ignores the env override)."""
    fp = fingerprint if fingerprint is not None else host_fingerprint()
    return _DEFAULT_CACHE_DIR.expanduser() / f"{fp}.json"


# -- profile persistence ----------------------------------------------------------


def save_profile(
    path,
    policy: DispatchPolicy,
    measurements: Optional[Dict] = None,
    descriptor: Optional[Dict] = None,
) -> Path:
    """Write a host-stamped profile JSON (parents created, atomic rename)."""
    policy.validate()
    d = descriptor if descriptor is not None else host_descriptor()
    payload = {
        "schema_version": PROFILE_SCHEMA_VERSION,
        "host_fingerprint": host_fingerprint(d),
        "host": d,
        "policy": policy.to_dict(),
        "measurements": measurements or {},
    }
    path = Path(path).expanduser()
    path.parent.mkdir(parents=True, exist_ok=True)
    tmp = path.with_suffix(".tmp")
    tmp.write_text(json.dumps(payload, indent=2, sort_keys=True))
    tmp.replace(path)
    return path


def load_profile(path, require_host_match: bool = True) -> Optional[Dict]:
    """Parsed+validated profile dict, or None when it must not be used.

    ``None`` — never an exception — on: missing file, unparseable JSON,
    schema-version mismatch, invalid policy fields, or (when
    ``require_host_match``) a recorded fingerprint from another machine.  A
    missing file is the normal untuned-host case and stays silent; a file
    that *exists* but cannot be used emits one ``DispatchProfileWarning``
    naming the path and reason, so operators can tell a tuned host from one
    silently running defaults on top of a corrupt profile.
    """

    def _reject(reason: str) -> None:
        warnings.warn(
            f"ignoring dispatch profile {path}: {reason} "
            "(falling back to built-in defaults; see docs/dispatch.md)",
            DispatchProfileWarning,
            stacklevel=3,
        )

    path = Path(path).expanduser()
    if not path.exists():
        return None
    try:
        payload = json.loads(path.read_text())
    except (OSError, ValueError) as e:
        _reject(f"unreadable or unparseable ({e.__class__.__name__})")
        return None
    if not isinstance(payload, dict):
        _reject("payload is not a JSON object")
        return None
    if payload.get("schema_version") != PROFILE_SCHEMA_VERSION:
        _reject(
            f"schema version {payload.get('schema_version')!r} != "
            f"{PROFILE_SCHEMA_VERSION} (stale profile)"
        )
        return None
    try:
        policy = DispatchPolicy.from_dict(payload.get("policy", {}))
    except (TypeError, ValueError) as e:
        _reject(f"invalid policy payload ({e})")
        return None
    if require_host_match and payload.get("host_fingerprint") != host_fingerprint():
        _reject("recorded host fingerprint is from another machine")
        return None
    payload["policy_obj"] = policy
    return payload


def resolve_policy() -> DispatchPolicy:
    """Env override -> cached host profile -> built-in defaults."""
    env = os.environ.get(PROFILE_ENV)
    if env is not None:
        if env.strip().lower() in ("", "default", "none", "0"):
            return DispatchPolicy()
        prof = load_profile(env, require_host_match=False)  # explicit pin
        if prof is None:
            raise ValueError(
                f"{PROFILE_ENV}={env!r} does not point at a valid dispatch "
                "profile (see docs/dispatch.md)"
            )
        return prof["policy_obj"]
    prof = load_profile(profile_path(), require_host_match=True)
    if prof is not None:
        return prof["policy_obj"]
    return DispatchPolicy()


# -- the process-wide active policy ----------------------------------------------
#
# Module-level consumers that cannot carry an instance policy (the shared
# jitted-forward trace caches in serve.estimator, the banding cache in
# core.bucketing, and the chunk fallback in core.gnn) read capacities from
# here.  Resolved lazily on first use so the env override and host profile
# apply process-wide; tests scope overrides with ``use_policy``.

_ACTIVE: Optional[DispatchPolicy] = None


def active_policy() -> DispatchPolicy:
    global _ACTIVE
    if _ACTIVE is None:
        _ACTIVE = resolve_policy()
    return _ACTIVE


def set_active_policy(policy: Optional[DispatchPolicy]) -> None:
    """Set (or, with None, re-resolve on next use) the process-wide policy."""
    global _ACTIVE
    _ACTIVE = policy.validate() if policy is not None else None


@contextlib.contextmanager
def use_policy(policy: DispatchPolicy):
    """Scoped ``set_active_policy`` (tests, autotune probes)."""
    global _ACTIVE
    prev = _ACTIVE
    _ACTIVE = policy.validate()
    try:
        yield policy
    finally:
        _ACTIVE = prev


# -- autotune ---------------------------------------------------------------------


@dataclass
class AutotuneResult:
    policy: DispatchPolicy
    measurements: Dict[str, object]
    reused_cached: bool  # True: a valid profile existed, no probe ran
    path: Optional[Path] = None


def _probe_estimator(hidden: int = 24, n_ensemble: int = 2, seed: int = 0):
    """A tiny randomly-initialized estimator: dispatch crossovers depend on
    shapes and launch counts, never on trained weights."""
    import jax

    from repro.core.model import CostModelConfig, init_cost_model
    from repro.core.gnn import GNNConfig
    from repro.serve.estimator import CostEstimator

    models = {}
    for i, metric in enumerate(("latency_p", "success")):
        cfg = CostModelConfig(
            metric=metric, n_ensemble=n_ensemble, gnn=GNNConfig(hidden=hidden)
        )
        models[metric] = (init_cost_model(jax.random.PRNGKey(seed + i), cfg), cfg)
    return CostEstimator(models, policy=DispatchPolicy())


def _best_of(fn, repeats: int) -> float:
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _probe_structures(n: int, seed: int) -> List[Tuple]:
    from repro.dsps.generator import WorkloadGenerator

    gen = WorkloadGenerator(seed=seed)
    kinds = ("linear", "two_way", "three_way")
    return [
        (gen.query(kind=kinds[i % len(kinds)], name=f"tune{i}"), gen.cluster(3 + i % 4))
        for i in range(n)
    ]


def _measure_cross_query_crossover(
    est, probes: Tuple[int, ...], repeats: int, seed: int
) -> Tuple[Optional[int], Dict]:
    """Largest probed rows-per-structure where the merged drain still beats
    the per-structure drain.  Always within the probed band — autotune
    interpolates between measurements, it never extrapolates past them."""
    import numpy as np

    from repro.placement import sample_assignment_matrix

    structures = _probe_structures(4, seed)
    metrics = tuple(est.models)
    rng = np.random.default_rng(seed)
    band: Dict[str, Dict[str, float]] = {}
    crossover = None
    for rows in probes:
        items = [
            (q, c, sample_assignment_matrix(q, c, rows, rng, max_tries_factor=400))
            for q, c in structures
        ]
        items = [(q, c, a) for q, c, a in items if len(a)]
        if len(items) < 2:
            continue

        def merged():
            est.score_many(items, metrics)

        def per_structure():
            for q, c, a in items:
                est.score(q, c, a, metrics)

        merged(), per_structure()  # warm both paths' traces outside the clock
        t_merged = _best_of(merged, repeats)
        t_per = _best_of(per_structure, repeats)
        band[str(rows)] = {"merged_s": t_merged, "per_structure_s": t_per}
        if t_merged < t_per:
            crossover = rows
    return crossover, band


def _measure_chunk_width(
    est, probes: Tuple[int, ...], batch: int, repeats: int, seed: int
) -> Tuple[Optional[int], Dict]:
    """Fastest placed-path panel width for a ``batch``-candidate scoring call."""
    import numpy as np

    from repro.placement import sample_assignment_matrix

    (q, c), = _probe_structures(1, seed + 101)
    rng = np.random.default_rng(seed)
    pool = sample_assignment_matrix(q, c, batch, rng, max_tries_factor=400)
    if not len(pool):
        return None, {}
    a = pool[np.arange(batch) % len(pool)]
    metrics = tuple(est.models)
    timings: Dict[str, float] = {}
    best_chunk, best_t = None, float("inf")
    for chunk in probes:
        if chunk and batch % chunk:
            continue  # the panel scan requires an integral panel count
        probe_est = type(est)(
            est.models, policy=replace(est.policy, score_chunk=chunk)
        )

        def run():
            probe_est.score(q, c, a, metrics)

        run()  # warm this chunk's trace outside the clock
        t = _best_of(run, repeats)
        timings[str(chunk)] = t
        if t < best_t:
            best_chunk, best_t = chunk, t
    return best_chunk, timings


def _measure_kernel_tiles(
    probes: Tuple[int, ...], repeats: int, seed: int
) -> Tuple[Optional[int], Optional[int], Dict]:
    """Fastest batch-tile caps for the fused sweep and seg-gather kernels.

    Only meaningful where the kernels actually execute (Pallas on TPU, or the
    forced interpreter): on the jnp-oracle lowering the caps are dead knobs,
    so the probe records why it skipped instead of writing noise into the
    profile.  The probe times the ops directly — a banded batch for
    ``mp_sweep`` (its levels from the real bucketing policy) and the merged
    engine's parent-table shapes for ``gather_sum``."""
    from repro.kernels import active_lowering

    meta: Dict[str, object] = {}
    if active_lowering() == "ref":
        meta["skipped"] = "jnp-oracle lowering: kernel tile caps are unused"
        return None, None, meta
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core.bucketing import batch_banding, bucket_size, pad_batch
    from repro.core.gnn import GNNConfig, _banded_plan, init_gnn
    from repro.core.graph import build_graph_batch
    from repro.kernels.mp_sweep import ops as sweep_ops
    from repro.kernels.seg_gather import ops as seg_ops
    from repro.placement import sample_assignment_matrix

    ((q, c),) = _probe_structures(1, seed + 202)
    rng = np.random.default_rng(seed)
    batch = 256
    pool = sample_assignment_matrix(q, c, batch, rng, max_tries_factor=400)
    if not len(pool):
        meta["skipped"] = "probe structure yielded no valid placements"
        return None, None, meta
    g = pad_batch(build_graph_batch(q, c, pool[np.arange(batch) % len(pool)]), bucket_size(batch))
    levels = _banded_plan(batch_banding(g)).levels
    cfg = GNNConfig(hidden=32)
    params = init_gnn(jax.random.PRNGKey(seed), cfg)["op_upd"]
    h = jnp.asarray(
        np.random.default_rng(seed + 1).standard_normal((batch, g.op_x.shape[-2], cfg.hidden)),
        jnp.float32,
    )
    a_flow, depth = jnp.asarray(g.a_flow), jnp.asarray(g.op_depth)
    mask = jnp.asarray(g.op_mask, jnp.float32)
    pidx = jnp.argsort(-jnp.swapaxes(a_flow, -1, -2), axis=-1)[..., :2]
    pmask = jnp.take_along_axis(jnp.swapaxes(a_flow, -1, -2), pidx, axis=-1)

    sweep_times: Dict[str, float] = {}
    gather_times: Dict[str, float] = {}
    best_sweep = best_gather = None
    bs = bg = float("inf")
    for tile in probes:
        with use_policy(DispatchPolicy(sweep_tile_rows=tile, seg_gather_tile=tile)):
            def run_sweep():
                sweep_ops.mp_sweep(params, h, a_flow, depth, mask, levels).block_until_ready()

            def run_gather():
                seg_ops.gather_sum(h, pidx, pmask).block_until_ready()

            run_sweep(), run_gather()  # warm outside the clock
            t_s = _best_of(run_sweep, repeats)
            t_g = _best_of(run_gather, repeats)
        sweep_times[str(tile)] = t_s
        gather_times[str(tile)] = t_g
        if t_s < bs:
            best_sweep, bs = tile, t_s
        if t_g < bg:
            best_gather, bg = tile, t_g
    meta["sweep_tile_timings_s"] = sweep_times
    meta["seg_gather_timings_s"] = gather_times
    return best_sweep, best_gather, meta


def autotune(
    quick: bool = False,
    budget_s: Optional[float] = None,
    seed: int = 0,
    out: Optional[os.PathLike] = None,
    force: bool = False,
    base: Optional[DispatchPolicy] = None,
) -> AutotuneResult:
    """Measure this host's dispatch crossovers and persist a profile.

    Short seeded probes (deterministic request streams, best-of-repeats
    timing — the ``serve.load`` calibration methodology) measure

    * the merged-vs-per-structure drain crossover -> ``cross_query_row_limit``
      (selected within the probed band, never extrapolated);
    * the placed-path panel width -> ``score_chunk``;
    * the kernel batch-tile caps -> ``sweep_tile_rows`` / ``seg_gather_tile``
      (only where the Pallas/interpret lowerings execute; the jnp-oracle
      lowering records the skip instead of writing noise).

    Everything else keeps ``base`` (default: the built-in defaults) — those
    knobs are capacity bounds, not crossovers.  The profile is written to
    ``out`` (default: ``profile_path()``); a second call finding a valid
    same-host profile at that path is a NO-OP (``reused_cached=True``, no
    probe runs) unless ``force``.  ``budget_s`` is a wall-clock bound: when
    it expires mid-run, un-probed knobs keep their defaults and the profile
    records ``budget_exhausted``.
    """
    target = Path(out).expanduser() if out is not None else profile_path()
    if not force:
        cached = load_profile(target, require_host_match=True)
        if cached is not None:
            return AutotuneResult(
                policy=cached["policy_obj"],
                measurements=cached.get("measurements", {}),
                reused_cached=True,
                path=target,
            )
    base = (base or DispatchPolicy()).validate()
    t_start = time.perf_counter()

    def budget_left() -> bool:
        return budget_s is None or (time.perf_counter() - t_start) < budget_s

    repeats = 3 if quick else 5
    row_probes = (1, 4, 16) if quick else (1, 2, 4, 8, 16, 32)
    chunk_batch = 256 if quick else 512
    chunk_probes = (64, 256) if quick else (64, 128, 256, 512)
    tile_probes = (32, 128) if quick else (32, 64, 128, 256)

    measurements: Dict[str, object] = {
        "quick": quick,
        "seed": seed,
        "row_probes": list(row_probes),
        "chunk_probes": list(chunk_probes),
        "chunk_batch": chunk_batch,
        "tile_probes": list(tile_probes),
    }
    policy = base
    # probes run under the BASE policy so the estimator's own dispatch is the
    # documented default configuration while it is being measured
    with use_policy(base):
        est = _probe_estimator(seed=seed)
        if budget_left():
            crossover, band = _measure_cross_query_crossover(
                est, row_probes, repeats, seed
            )
            measurements["cross_query_band"] = band
            if crossover is not None:
                # merged never winning picks the smallest probe (merge only
                # trivially small drains); winning everywhere picks the
                # largest — the selection stays inside the measured band
                policy = replace(policy, cross_query_row_limit=crossover)
                measurements["cross_query_row_limit"] = crossover
        else:
            measurements["budget_exhausted"] = "before cross_query probe"
        if budget_left():
            chunk, timings = _measure_chunk_width(
                est, chunk_probes, chunk_batch, repeats, seed
            )
            measurements["chunk_timings_s"] = timings
            if chunk is not None:
                policy = replace(policy, score_chunk=chunk)
                measurements["score_chunk"] = chunk
        else:
            measurements.setdefault("budget_exhausted", "before chunk probe")
        if budget_left():
            sweep_tile, gather_tile, tile_meta = _measure_kernel_tiles(
                tile_probes, repeats, seed
            )
            measurements["kernel_tiles"] = tile_meta
            if sweep_tile is not None:
                policy = replace(policy, sweep_tile_rows=sweep_tile)
                measurements["sweep_tile_rows"] = sweep_tile
            if gather_tile is not None:
                policy = replace(policy, seg_gather_tile=gather_tile)
                measurements["seg_gather_tile"] = gather_tile
        else:
            measurements.setdefault("budget_exhausted", "before kernel tile probe")
    measurements["elapsed_s"] = round(time.perf_counter() - t_start, 3)
    path = save_profile(target, policy.validate(), measurements)
    return AutotuneResult(
        policy=policy, measurements=measurements, reused_cached=False, path=path
    )


# -- CLI (scripts/ci.sh) ----------------------------------------------------------


def main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        prog="python -m repro.serve.policy", description=__doc__
    )
    ap.add_argument("--quick", action="store_true", help="small probe set for CI")
    ap.add_argument("--budget-s", type=float, default=None, help="wall-clock bound")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", type=str, default=None, help="profile path (default: host cache)")
    ap.add_argument("--force", action="store_true", help="re-probe even if cached")
    ap.add_argument(
        "--expect-cached",
        action="store_true",
        help="fail unless a valid cached profile made this a no-op (CI gate)",
    )
    ap.add_argument(
        "--validate", type=str, default=None, metavar="PATH",
        help="validate a profile JSON against the schema and exit",
    )
    args = ap.parse_args(argv)

    if args.validate is not None:
        prof = load_profile(args.validate, require_host_match=False)
        if prof is None:
            print(f"INVALID dispatch profile: {args.validate}")
            return 1
        print(json.dumps({"valid": True, "policy": prof["policy"]}, indent=2))
        return 0

    res = autotune(
        quick=args.quick,
        budget_s=args.budget_s,
        seed=args.seed,
        out=args.out,
        force=args.force,
    )
    print(
        json.dumps(
            {
                "reused_cached": res.reused_cached,
                "path": str(res.path),
                "policy": res.policy.to_dict(),
                "measurements": res.measurements,
            },
            indent=2,
            default=str,
        )
    )
    if args.expect_cached and not res.reused_cached:
        print("expected a cached profile but a probe ran")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
