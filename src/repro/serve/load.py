"""Open-loop load generation for ``PlacementService``: the serving-path SLO
instrumentation.

Closed-loop benchmarks (``benchmarks/serve_bench.py``) measure *drain
throughput*: the next request waits for the previous answer, so the system is
never pressured beyond its own pace.  A production estimator serving many
concurrent users sees an **open-loop** arrival process — requests arrive on
the *clients'* schedule whether or not the service keeps up — and is judged
on tail latency (p95/p99) and SLO violations, not on drain rate.  This
module generates seeded, deterministic arrival schedules (Poisson and
bursty), replays them against a service, and reduces the per-request
latencies to the quantities that matter:

* per-request latency measured from the request's *scheduled* arrival to its
  answer (so driver lag and queueing both count, the open-loop convention);
* p50/p95/p99 latency and the SLO-violation rate at a given threshold;
* the saturation knee over a rate sweep: the highest offered rate whose p95
  stays within budget (``find_knee``).

Schedules are pure functions of (rate, horizon, seed): re-running a
configuration replays the identical request sequence, so harness runs are
comparable across service configurations and across commits.
``benchmarks/load_harness.py`` is the CLI; docs/load_harness.md the
methodology reference.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.serve.service import PlacementService, ServiceOverloadError, ServiceStats

#: Latency quantiles every report carries, in ascending order.
QUANTILES = (50.0, 95.0, 99.0)


# -- arrival schedules ------------------------------------------------------------


def poisson_arrivals(rate: float, n: int, seed: int = 0) -> np.ndarray:
    """``n`` arrival offsets (seconds) of a Poisson process at ``rate`` req/s.

    Exponential i.i.d. inter-arrival gaps from a seeded generator: the
    memoryless process every open-loop serving benchmark defaults to.
    Deterministic in (rate, n, seed).
    """
    assert rate > 0 and n > 0, (rate, n)
    gaps = np.random.default_rng(seed).exponential(1.0 / rate, size=n)
    return np.cumsum(gaps)


def bursty_arrivals(
    rate: float,
    n: int,
    seed: int = 0,
    burst_factor: float = 8.0,
    burst_fraction: float = 0.2,
    period_s: float = 1.0,
) -> np.ndarray:
    """Arrival offsets of a two-phase on/off process averaging ``rate`` req/s.

    Each ``period_s`` window splits into a burst phase (``burst_fraction`` of
    the period at ``burst_factor`` x the base intensity) and a quiet phase
    (the remaining time at the complementary intensity, so the long-run mean
    stays ``rate``).  Models synchronized client behavior — monitoring rounds
    firing together, retry storms — which stresses queueing far harder than
    Poisson at the same mean rate.  Deterministic in all arguments.
    """
    assert rate > 0 and n > 0, (rate, n)
    assert 0.0 < burst_fraction < 1.0, burst_fraction
    assert burst_factor >= 1.0, burst_factor
    burst_rate = rate * burst_factor
    quiet_weight = 1.0 - burst_factor * burst_fraction
    if quiet_weight <= 0:  # all mass in the burst: quiet phase silent
        burst_rate = rate / burst_fraction
        quiet_rate = 0.0
    else:
        quiet_rate = rate * quiet_weight / (1.0 - burst_fraction)
    rng = np.random.default_rng(seed)
    out: List[float] = []
    t = 0.0
    while len(out) < n:
        burst_end = t + burst_fraction * period_s
        period_end = t + period_s
        cursor = t
        while True:  # burst phase: dense exponential gaps
            cursor += rng.exponential(1.0 / burst_rate)
            if cursor >= burst_end or len(out) >= n:
                break
            out.append(cursor)
        cursor = burst_end
        if quiet_rate > 0:
            while True:
                cursor += rng.exponential(1.0 / quiet_rate)
                if cursor >= period_end or len(out) >= n:
                    break
                out.append(cursor)
        t = period_end
    return np.asarray(out[:n])


# -- running one open-loop experiment ---------------------------------------------


@dataclass
class LoadReport:
    """One open-loop run reduced to its serving-quality numbers.

    ``latencies_s`` holds one entry per *answered* request, aligned with the
    arrival schedule order with rejected/failed requests removed; latency is
    measured from the request's scheduled arrival time (not the possibly-late
    submit), so queueing delay, driver lag, and service time all count —
    the number a client would experience.
    """

    n_requests: int
    n_answered: int
    n_rejected: int
    n_failed: int
    duration_s: float
    offered_rate: float  # requests/s the schedule asked for
    achieved_rate: float  # answered requests/s actually delivered
    latencies_s: np.ndarray
    p50_s: float
    p95_s: float
    p99_s: float
    slo_s: Optional[float]
    n_slo_violations: int  # answered-but-late plus rejected/failed requests
    slo_violation_rate: float
    stats: ServiceStats = field(default_factory=ServiceStats)

    def summary(self) -> Dict[str, float]:
        """The scalar subset, JSON-ready (for benchmark baselines)."""
        return {
            "n_requests": self.n_requests,
            "n_answered": self.n_answered,
            "n_rejected": self.n_rejected,
            "n_failed": self.n_failed,
            "duration_s": round(self.duration_s, 4),
            "offered_rps": round(self.offered_rate, 2),
            "achieved_rps": round(self.achieved_rate, 2),
            "p50_ms": round(self.p50_s * 1e3, 3),
            "p95_ms": round(self.p95_s * 1e3, 3),
            "p99_ms": round(self.p99_s * 1e3, 3),
            "slo_violation_rate": round(self.slo_violation_rate, 4),
            "max_queue_depth": self.stats.max_queue_depth,
            "max_drain": self.stats.max_drain,
            "mean_queue_wait_ms": round(
                (self.stats.queue_wait_s / max(1, self.stats.n_drained)) * 1e3, 3
            ),
        }


def latency_quantiles(latencies_s: Sequence[float]) -> Tuple[float, float, float]:
    """(p50, p95, p99) of a latency sample; NaNs when the sample is empty."""
    lat = np.asarray(latencies_s, dtype=np.float64)
    if lat.size == 0:
        return (float("nan"),) * 3
    p50, p95, p99 = np.percentile(lat, QUANTILES)
    return float(p50), float(p95), float(p99)


def run_open_loop(
    service: PlacementService,
    submit_fns: Sequence[Callable[[], "object"]],
    arrivals_s: np.ndarray,
    slo_s: Optional[float] = None,
    timeout_s: float = 120.0,
) -> LoadReport:
    """Replay ``submit_fns[i]`` at ``arrivals_s[i]`` against a started service.

    The driver thread sleeps to each scheduled arrival and fires the submit
    WITHOUT waiting for the answer (open loop: a slow service does not slow
    the clients down); completion times are captured by future callbacks.  A
    submit that raises ``ServiceOverloadError`` counts as rejected (and as an
    SLO violation — the client got no answer); any other per-request failure
    counts as failed.  Latency for answered requests is
    ``completion - scheduled_arrival``.
    """
    n = len(arrivals_s)
    assert n == len(submit_fns), (n, len(submit_fns))
    done_at = np.full(n, np.nan)
    failed = np.zeros(n, dtype=bool)
    rejected = np.zeros(n, dtype=bool)
    outstanding = threading.Semaphore(0)

    def _on_done(i: int, t0: float):
        def cb(fut):
            done_at[i] = time.perf_counter() - t0
            if fut.exception() is not None:
                failed[i] = True
            outstanding.release()

        return cb

    t0 = time.perf_counter()
    for i, (at, fire) in enumerate(zip(arrivals_s, submit_fns)):
        lag = at - (time.perf_counter() - t0)
        if lag > 0:
            time.sleep(lag)
        try:
            fut = fire()
        except ServiceOverloadError:
            rejected[i] = True
            outstanding.release()
            continue
        fut.add_done_callback(_on_done(i, t0))
    deadline = time.perf_counter() + timeout_s
    for _ in range(n):
        if not outstanding.acquire(timeout=max(0.0, deadline - time.perf_counter())):
            raise TimeoutError(
                f"open-loop run did not resolve all {n} requests within {timeout_s}s"
            )
    duration = time.perf_counter() - t0

    answered = ~rejected & ~failed
    latencies = (done_at - np.asarray(arrivals_s))[answered]
    p50, p95, p99 = latency_quantiles(latencies)
    n_answered = int(answered.sum())
    if slo_s is not None:
        n_viol = int((latencies > slo_s).sum()) + int(rejected.sum()) + int(failed.sum())
    else:
        n_viol = 0
    return LoadReport(
        n_requests=n,
        n_answered=n_answered,
        n_rejected=int(rejected.sum()),
        n_failed=int(failed.sum()),
        duration_s=duration,
        offered_rate=n / float(arrivals_s[-1]) if n else 0.0,
        achieved_rate=n_answered / duration if duration > 0 else 0.0,
        latencies_s=latencies,
        p50_s=p50,
        p95_s=p95,
        p99_s=p99,
        slo_s=slo_s,
        n_slo_violations=n_viol,
        slo_violation_rate=n_viol / n if n else 0.0,
        stats=ServiceStats(**vars(service.stats)),  # snapshot: stats keep mutating
    )


def score_request_stream(
    structures: Sequence[Tuple],
    n_requests: int,
    cands_per_request: int,
    seed: int = 0,
    metrics: Optional[Sequence[str]] = None,
) -> Callable[[PlacementService], List[Callable]]:
    """Submit thunks for a mixed score stream round-robining ``structures``.

    Request i targets structure ``i % len(structures)`` with a seeded
    candidate matrix — the heterogeneous many-small-queries mix the
    cross-query serving path exists for.  Returns a factory so the same
    deterministic stream can be replayed against several services.
    """
    from repro.placement import sample_assignment_matrix

    rng = np.random.default_rng(seed)
    payloads = []
    for i in range(n_requests):
        q, c = structures[i % len(structures)]
        payloads.append((q, c, sample_assignment_matrix(q, c, cands_per_request, rng)))

    def bind(service: PlacementService) -> List[Callable]:
        return [
            (lambda q=q, c=c, a=a: service.submit_score(q, c, a, metrics))
            for q, c, a in payloads
        ]

    return bind


# -- saturation knee --------------------------------------------------------------


@dataclass
class KneePoint:
    rate: float
    p95_s: float
    slo_violation_rate: float


def find_knee(
    run_at_rate: Callable[[float], LoadReport],
    rates: Sequence[float],
    slo_s: float,
) -> Tuple[Optional[float], List[KneePoint]]:
    """Sweep offered rates ascending; return (knee, per-rate points).

    The knee is the highest offered rate whose p95 latency stays within
    ``slo_s`` AND whose SLO-violation rate stays under 1% — the last
    sustainable operating point before queueing takes over.  ``None`` when
    even the lowest rate violates (the service is saturated everywhere in
    the sweep).  The sweep early-exits two rates past the knee: beyond
    saturation, open-loop p95 grows with run length, not with the service,
    so further points cost time and prove nothing.
    """
    knee = None
    points: List[KneePoint] = []
    over = 0
    for rate in sorted(rates):
        rep = run_at_rate(rate)
        points.append(KneePoint(rate, rep.p95_s, rep.slo_violation_rate))
        if rep.p95_s <= slo_s and rep.slo_violation_rate < 0.01:
            knee = rate
            over = 0
        else:
            over += 1
            if over >= 2:
                break
    return knee, points
