"""Fault-tolerant serving lifecycle: circuit breaking, heuristic fallback,
and shadow-evaluated bundle hot-swap with rollback.

COSTREAM's deployment story (PAPER.md §6) assumes the served cost model stays
healthy forever; this module is the failure path and the model-lifecycle path
the ROADMAP's "shadow evaluation before swap" item calls for (the Microsoft
"Learning, Retrofitting" playbook in PAPERS.md: never promote a retrained
model without validating it against live traffic first):

* ``CircuitBreaker`` — the classic closed -> open -> half-open state machine
  over a sliding window of per-request estimator outcomes.  While open,
  ``PlacementService`` answers score requests from ``fallback_scores``
  (tagged ``degraded`` in ``ServiceStats``) instead of failing clients, so
  the ``PlacementController`` keeps running on approximate costs during an
  estimator brown-out.
* ``fallback_scores`` — a deterministic heuristic stand-in for estimator
  scores, built on the in-tree ``heuristic_placement`` baseline: candidates
  are ranked by assignment distance to the heuristic placement (closer is
  better), feasibility filters answer optimistically.  Finite, cheap, and
  model-free — it works precisely when the model does not.
* ``BundleSwapper`` — shadow-evaluates a candidate ``CostModelBundle``
  against live traffic (mirroring a policy-configured fraction of drained
  score requests through the candidate off the critical path, scoring rank
  correlation on placement orderings + relative cost error vs the live
  answers), then promotes via ``PlacementService.swap_bundle`` or rejects
  with a typed ``ShadowRejected`` verdict; an optional post-promotion health
  window auto-rolls back on error-rate regression.

All thresholds live on ``DispatchPolicy`` (``shadow_*``, ``breaker_*``,
``health_*``; sizing rationale beside each field in serve/policy.py).  State
machines, failure taxonomy, and operational guidance: docs/robustness.md.
"""

from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.model import CLASSIFICATION_METRICS
from repro.placement.enumerate import heuristic_placement
from repro.serve.policy import DispatchPolicy

__all__ = [
    "CircuitBreaker",
    "ShadowRejected",
    "ShadowVerdict",
    "BundleSwapper",
    "fallback_scores",
]


# -- circuit breaker --------------------------------------------------------------


class CircuitBreaker:
    """Failure-rate-windowed breaker over per-request estimator outcomes.

    States: **closed** (normal; every call allowed) -> **open** (the windowed
    failure rate crossed ``failure_rate`` with at least ``min_samples``
    outcomes; calls denied for ``cooldown_s``) -> **half-open** (cooldown
    expired; exactly ONE probe is allowed through) -> closed on probe success
    / re-open on probe failure.  Thread-safe; the service records outcomes
    from its worker thread and client threads may read ``state``.

    ``clock`` is injectable for deterministic tests (defaults to
    ``time.monotonic``).  Thresholds come from the ``breaker_*`` fields of a
    ``DispatchPolicy`` via ``from_policy`` (docs/robustness.md#breaker).
    """

    def __init__(
        self,
        window: int = 16,
        failure_rate: float = 0.5,
        min_samples: int = 4,
        cooldown_s: float = 0.5,
        clock: Callable[[], float] = time.monotonic,
    ):
        if min_samples > window:
            raise ValueError(f"min_samples {min_samples} > window {window}")
        self.window = int(window)
        self.failure_rate = float(failure_rate)
        self.min_samples = int(min_samples)
        self.cooldown_s = float(cooldown_s)
        self._clock = clock
        self._lock = threading.Lock()
        self._outcomes: "deque[bool]" = deque(maxlen=self.window)
        self._state = "closed"
        self._opened_at = 0.0
        self.n_opens = 0  # lifetime open transitions (observability)

    @classmethod
    def from_policy(
        cls, policy: DispatchPolicy, clock: Callable[[], float] = time.monotonic
    ) -> "CircuitBreaker":
        return cls(
            window=policy.breaker_window,
            failure_rate=policy.breaker_failure_rate,
            min_samples=policy.breaker_min_samples,
            cooldown_s=policy.breaker_cooldown_s,
            clock=clock,
        )

    @property
    def state(self) -> str:
        with self._lock:
            return self._state

    def allow(self) -> bool:
        """Whether a real estimator call may proceed right now.

        Open + expired cooldown transitions to half-open and admits exactly
        one probe; every other open/half-open call is denied (the caller
        serves degraded answers instead)."""
        with self._lock:
            if self._state == "closed":
                return True
            if self._state == "open":
                if self._clock() - self._opened_at >= self.cooldown_s:
                    self._state = "half_open"
                    return True  # the single probe
                return False
            return False  # half_open: a probe is already in flight

    def record_success(self) -> None:
        with self._lock:
            self._outcomes.append(True)
            if self._state == "half_open":  # probe succeeded: recover
                self._state = "closed"
                self._outcomes.clear()

    def record_failure(self) -> None:
        with self._lock:
            self._outcomes.append(False)
            if self._state == "half_open":  # probe failed: back to open
                self._state = "open"
                self._opened_at = self._clock()
                self.n_opens += 1
                return
            if self._state == "closed" and len(self._outcomes) >= self.min_samples:
                failures = sum(1 for ok in self._outcomes if not ok)
                if failures / len(self._outcomes) >= self.failure_rate:
                    self._state = "open"
                    self._opened_at = self._clock()
                    self.n_opens += 1


# -- heuristic fallback scorer ----------------------------------------------------


def fallback_scores(
    query, cluster, assignments: np.ndarray, metrics: Sequence[str]
) -> Dict[str, np.ndarray]:
    """Model-free stand-in for ``CostEstimator.score`` during a brown-out.

    Ranks candidates by normalized assignment distance ``d`` to the
    deterministic ``heuristic_placement`` baseline (the paper's Exp-2a
    comparison placement): minimized regression metrics answer ``1 + d``,
    ``throughput`` (maximized) answers ``1 / (1 + d)``, and classification
    feasibility filters answer optimistically (1 = success / no
    backpressure — a brown-out must widen the candidate set, not empty it).
    Deterministic, finite, and cheap: one heuristic placement plus one
    vectorized distance per call, no model state touched.

    The answers are *approximate by construction*: they preserve only
    "prefer placements near the known-good heuristic", which is exactly the
    paper's pre-model baseline behavior.  ``ServiceStats.degraded`` tells
    consumers (e.g. the controller's degraded mode) they are looking at
    fallback numbers.
    """
    a = np.asarray(assignments, dtype=np.int64)
    if a.ndim != 2 or len(a) == 0:
        raise ValueError("no candidates to score")
    ref = np.asarray(heuristic_placement(query, cluster).assignment, dtype=np.int64)
    d = (a != ref[None, :]).mean(axis=1)  # (N,) in [0, 1]
    out: Dict[str, np.ndarray] = {}
    for m in metrics:
        if m in CLASSIFICATION_METRICS:
            out[m] = np.ones(len(a), dtype=np.float64)
        elif m == "throughput":
            out[m] = 1.0 / (1.0 + d)
        else:
            out[m] = 1.0 + d
    return out


# -- shadow evaluation ------------------------------------------------------------


def _avg_ranks(x: np.ndarray) -> np.ndarray:
    """Tie-averaged ordinal ranks, so constant runs carry no fake ordering."""
    order = np.argsort(x, kind="stable")
    ranks = np.empty(x.size, dtype=np.float64)
    sx = x[order]
    i = 0
    while i < x.size:
        j = i
        while j + 1 < x.size and sx[j + 1] == sx[i]:
            j += 1
        ranks[order[i : j + 1]] = 0.5 * (i + j)
        i = j + 1
    return ranks


def _spearman(live: np.ndarray, shadow: np.ndarray) -> Optional[float]:
    """Spearman rank correlation of two score vectors (ordinal ranks).

    None when fewer than two candidates (no ordering to compare).  A
    constant vector has no ordering information: both constant -> 1.0
    (trivially agreeing), one constant -> 0.0.
    """
    live = np.asarray(live, dtype=np.float64)
    shadow = np.asarray(shadow, dtype=np.float64)
    if live.size < 2:
        return None
    ra = _avg_ranks(live)
    rb = _avg_ranks(shadow)
    sa, sb = ra - ra.mean(), rb - rb.mean()
    denom = float(np.sqrt((sa * sa).sum() * (sb * sb).sum()))
    if denom == 0.0:
        return 1.0 if bool(np.all(ra == rb)) else 0.0
    return float((sa * sb).sum() / denom)


@dataclass(frozen=True)
class ShadowVerdict:
    """The outcome of one shadow phase, with the evidence behind it.

    ``rank_corr`` is the mean Spearman correlation between live and candidate
    placement orderings over mirrored multi-candidate regression scores
    (None: no request carried an ordering); ``rel_err`` the mean relative
    cost error (classification metrics contribute their disagreement rate).
    ``thresholds`` records the policy values the verdict was judged against.
    """

    accepted: bool
    reason: str
    n_mirrored: int
    n_dropped: int
    n_candidate_errors: int
    rank_corr: Optional[float]
    rel_err: Optional[float]
    thresholds: Dict[str, float] = field(default_factory=dict)


class ShadowRejected(RuntimeError):
    """A candidate bundle failed shadow evaluation; ``.verdict`` has why."""

    def __init__(self, verdict: ShadowVerdict):
        super().__init__(f"candidate rejected by shadow evaluation: {verdict.reason}")
        self.verdict = verdict


class BundleSwapper:
    """Shadow-evaluate a candidate estimator against live traffic, then
    promote it into a running ``PlacementService`` — or reject it.

    Protocol (state machine in docs/robustness.md#swap)::

        swapper = BundleSwapper(service, seed=0)
        swapper.start_shadow(candidate)      # bundle or CostEstimator
        ... live traffic flows ...           # a fraction is mirrored
        swapper.drain_shadow()               # deterministic tests: flush
        verdict = swapper.promote()          # swap, or raise ShadowRejected

    The mirror is a service observer: after each drain finalizes, a seeded
    ``shadow_fraction`` sample of successfully-answered score requests is
    re-scored through the candidate on a dedicated shadow thread — off the
    critical path, bounded by ``shadow_queue_depth`` (when full, samples are
    dropped and counted: shadow evaluation sheds load, it never
    backpressures live traffic).  The shadow phase doubles as candidate
    trace warmup: every structure it scores is compiled before promotion.

    ``promote`` applies the swap at a drain boundary via
    ``service.swap_bundle`` and (by default) arms a post-promotion health
    window: after ``health_window_requests`` further drained requests, the
    incremental (degraded + non-finite + timed-out + failed) rate is
    compared against ``health_error_rate_max`` and the PREVIOUS estimator is
    swapped back in on regression (``rolled_back``/``rollback_reason``).
    """

    def __init__(self, service, seed: int = 0, policy: Optional[DispatchPolicy] = None):
        self.service = service
        self.policy = (policy if policy is not None else service.policy).validate()
        self._rng = np.random.default_rng(seed)
        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)
        self._queue: "deque[Tuple]" = deque()
        self._pairs: List[Tuple[Dict, Dict, Tuple[str, ...]]] = []
        self._n_mirrored = 0
        self._n_dropped = 0
        self._n_candidate_errors = 0
        self._inflight = False
        self._stop = False
        self._mirroring = False
        self._candidate = None
        self._thread: Optional[threading.Thread] = None
        self._previous = None
        self._health: Optional[Dict] = None
        self.rolled_back = False
        self.rollback_reason: Optional[str] = None

    # -- shadow phase -------------------------------------------------------------

    def start_shadow(self, candidate) -> None:
        """Install the mirror and begin shadow-scoring through ``candidate``
        (a ``CostModelBundle`` — wrapped with the service's policy — or a
        ready ``CostEstimator``).  Restartable: a second call after
        ``stop_shadow`` begins a fresh phase with fresh statistics."""
        from repro.serve.estimator import CostEstimator

        if not isinstance(candidate, CostEstimator):
            candidate = CostEstimator.from_bundle(candidate, policy=self.service.policy)
        with self._lock:
            if self._mirroring:
                raise RuntimeError("a shadow phase is already running")
            self._candidate = candidate
            self._queue.clear()
            self._pairs = []
            self._n_mirrored = self._n_dropped = self._n_candidate_errors = 0
            self._stop = False
            self._mirroring = True
        self._thread = threading.Thread(
            target=self._shadow_loop, name="bundle-shadow", daemon=True
        )
        self._thread.start()
        self.service.add_observer(self._mirror)

    def _mirror(self, reqs, answers) -> None:
        # runs on the service worker thread after each finalized drain group:
        # sample delivered score answers into the bounded shadow queue
        for r, ans in zip(reqs, answers):
            if (
                r.kind != "score"
                or isinstance(ans, BaseException)
                or getattr(ans, "degraded", False)
            ):
                continue  # only mirror requests the live model truly answered
            with self._cond:
                if not self._mirroring or self._stop:
                    return
                if self._rng.random() >= self.policy.shadow_fraction:
                    continue
                if len(self._queue) >= self.policy.shadow_queue_depth:
                    self._n_dropped += 1  # shed, never backpressure
                    continue
                query, cluster, a, metrics, _ = r.payload
                self._queue.append((query, cluster, a, metrics, dict(ans)))
                self._cond.notify_all()

    def _shadow_loop(self) -> None:
        while True:
            with self._cond:
                while not self._queue and not self._stop:
                    self._cond.wait()
                if self._stop and not self._queue:
                    return
                item = self._queue.popleft()
                self._inflight = True
            query, cluster, a, metrics, live = item
            shadow = None
            try:
                shadow = self._candidate.score(query, cluster, a, metrics)
            except Exception:
                pass  # counted below; a raising candidate is itself a verdict
            with self._cond:
                self._n_mirrored += 1
                if shadow is None:
                    self._n_candidate_errors += 1
                else:
                    self._pairs.append((live, dict(shadow), tuple(metrics)))
                self._inflight = False
                self._cond.notify_all()

    def drain_shadow(self, timeout: Optional[float] = None) -> bool:
        """Block until every queued mirror sample is scored (tests/benches
        use this to make verdicts deterministic).  False on timeout."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cond:
            while self._queue or self._inflight:
                left = None if deadline is None else deadline - time.monotonic()
                if left is not None and left <= 0:
                    return False
                self._cond.wait(timeout=left)
        return True

    def stop_shadow(self) -> None:
        """Uninstall the mirror and stop the shadow thread.  Mirrored
        statistics survive — ``verdict()`` stays valid after stopping."""
        try:
            self.service.remove_observer(self._mirror)
        except ValueError:
            pass
        with self._cond:
            self._stop = True
            self._mirroring = False
            self._cond.notify_all()
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # -- verdict + promotion ------------------------------------------------------

    def verdict(self) -> ShadowVerdict:
        """Judge the candidate on what the shadow phase observed so far."""
        with self._lock:
            pairs = list(self._pairs)
            n_m, n_d, n_err = self._n_mirrored, self._n_dropped, self._n_candidate_errors
        corrs: List[float] = []
        rels: List[float] = []
        for live, shadow, metrics in pairs:
            for m in metrics:
                l = np.asarray(live[m], dtype=np.float64)
                s = np.asarray(shadow[m], dtype=np.float64)
                if m in CLASSIFICATION_METRICS:
                    rels.extend(np.abs(s - l).tolist())  # disagreement rate
                    continue
                rels.extend((np.abs(s - l) / (np.abs(l) + 1e-6)).tolist())
                if l.size >= 2 and float(np.ptp(l)) > 0.0:
                    c = _spearman(l, s)
                    if c is not None:
                        corrs.append(c)
        rank_corr = float(np.mean(corrs)) if corrs else None
        rel_err = float(np.mean(rels)) if rels else None
        thresholds = {
            "shadow_min_requests": self.policy.shadow_min_requests,
            "shadow_rank_corr_min": self.policy.shadow_rank_corr_min,
            "shadow_rel_err_max": self.policy.shadow_rel_err_max,
        }

        def _v(accepted: bool, reason: str) -> ShadowVerdict:
            return ShadowVerdict(
                accepted=accepted,
                reason=reason,
                n_mirrored=n_m,
                n_dropped=n_d,
                n_candidate_errors=n_err,
                rank_corr=rank_corr,
                rel_err=rel_err,
                thresholds=thresholds,
            )

        if n_err:
            return _v(False, f"candidate estimator raised on {n_err} mirrored request(s)")
        if n_m < self.policy.shadow_min_requests:
            return _v(
                False,
                f"insufficient shadow traffic ({n_m} < "
                f"shadow_min_requests={self.policy.shadow_min_requests})",
            )
        if rel_err is not None and rel_err > self.policy.shadow_rel_err_max:
            return _v(
                False,
                f"relative cost error {rel_err:.3f} > "
                f"shadow_rel_err_max={self.policy.shadow_rel_err_max}",
            )
        if rank_corr is not None and rank_corr < self.policy.shadow_rank_corr_min:
            return _v(
                False,
                f"placement-ordering rank correlation {rank_corr:.3f} < "
                f"shadow_rank_corr_min={self.policy.shadow_rank_corr_min}",
            )
        return _v(True, f"accepted over {n_m} mirrored request(s)")

    def promote(self, health_window: bool = True) -> ShadowVerdict:
        """Judge the shadow phase; on acceptance, swap the candidate live.

        Rejection raises ``ShadowRejected`` (shadow stopped, nothing
        swapped).  Acceptance stops the mirror, applies the swap at a drain
        boundary (``service.swap_bundle``), keeps the previous estimator for
        rollback, and — with ``health_window`` — watches the next
        ``health_window_requests`` drained requests: an incremental error
        rate above ``health_error_rate_max`` swaps the previous estimator
        back in (``rolled_back``/``rollback_reason`` record it).
        """
        v = self.verdict()
        candidate = self._candidate
        self.stop_shadow()
        if not v.accepted:
            raise ShadowRejected(v)
        st = self.service.stats
        self._previous = self.service.swap_bundle(candidate, wait=True)
        if health_window:
            self.rolled_back = False
            self.rollback_reason = None
            self._health = {
                "seen": 0,
                "n_degraded": st.n_degraded,
                "n_nonfinite": st.n_nonfinite,
                "n_timeouts": st.n_timeouts,
                "n_failed": st.n_failed,
            }
            self.service.add_observer(self._health_obs)
        return v

    def _health_obs(self, reqs, answers) -> None:
        # worker-thread observer: one verdict after health_window_requests
        h = self._health
        if h is None:
            return
        h["seen"] += len(reqs)
        if h["seen"] < self.policy.health_window_requests:
            return
        st = self.service.stats
        errors = (
            (st.n_degraded - h["n_degraded"])
            + (st.n_nonfinite - h["n_nonfinite"])
            + (st.n_timeouts - h["n_timeouts"])
            + (st.n_failed - h["n_failed"])
        )
        rate = errors / max(h["seen"], 1)
        self._health = None
        try:
            self.service.remove_observer(self._health_obs)
        except ValueError:
            pass
        if rate > self.policy.health_error_rate_max:
            self.rolled_back = True
            self.rollback_reason = (
                f"post-promotion error rate {rate:.3f} > "
                f"health_error_rate_max={self.policy.health_error_rate_max} "
                f"over {h['seen']} request(s)"
            )
            # wait=False: this runs ON the worker thread — the swap applies
            # at the next drain boundary; blocking here would deadlock
            self.service.swap_bundle(self._previous, wait=False)

    def close(self) -> None:
        """Stop shadowing and disarm any pending health window."""
        self.stop_shadow()
        self._health = None
        try:
            self.service.remove_observer(self._health_obs)
        except ValueError:
            pass
