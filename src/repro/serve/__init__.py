"""Serving subsystem: train once offline, answer many queries online.

The stable online API (docs/api.md) is three objects:

* ``CostModelBundle`` — the versioned on-disk artifact holding every trained
  metric ensemble + configs + training metadata (one save/load round-trip);
* ``CostEstimator``   — the single inference facade (``estimate`` / ``score``
  / ``optimize``) constructed from a bundle, owning all serving caches;
* ``PlacementService`` — the micro-batching front-end that coalesces
  concurrent requests into fused bucket-padded forwards.
"""

from repro.serve.bundle import (
    BUNDLE_SCHEMA_VERSION,
    BundleVersionError,
    CostModelBundle,
    LazyModels,
    bundle_from_checkpoint,
    corpus_fingerprint,
    layout_descriptor,
    merge_bundles,
)
from repro.serve.estimator import CostEstimator
from repro.serve.service import PlacementService, ServiceStats

__all__ = [
    "BUNDLE_SCHEMA_VERSION",
    "BundleVersionError",
    "CostModelBundle",
    "CostEstimator",
    "LazyModels",
    "PlacementService",
    "ServiceStats",
    "bundle_from_checkpoint",
    "corpus_fingerprint",
    "layout_descriptor",
    "merge_bundles",
]
