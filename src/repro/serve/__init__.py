"""Serving subsystem: train once offline, answer many queries online.

The stable online API (docs/api.md) is three objects:

* ``CostModelBundle`` — the versioned on-disk artifact holding every trained
  metric ensemble + configs + training metadata (one save/load round-trip);
* ``CostEstimator``   — the single inference facade (``estimate`` / ``score``
  / ``optimize``) constructed from a bundle, owning all serving caches;
* ``PlacementService`` — the micro-batching front-end that coalesces
  concurrent requests into fused bucket-padded forwards.
"""

from repro.serve.bundle import (
    BUNDLE_SCHEMA_VERSION,
    BundleVersionError,
    CostModelBundle,
    LazyModels,
    bundle_from_checkpoint,
    corpus_fingerprint,
    layout_descriptor,
    merge_bundles,
)
from repro.serve.estimator import CostEstimator, DeferredResult
from repro.serve.load import (
    KneePoint,
    LoadReport,
    bursty_arrivals,
    find_knee,
    latency_quantiles,
    poisson_arrivals,
    run_open_loop,
    score_request_stream,
)
from repro.serve.service import PlacementService, ServiceOverloadError, ServiceStats

__all__ = [
    "BUNDLE_SCHEMA_VERSION",
    "BundleVersionError",
    "CostModelBundle",
    "CostEstimator",
    "DeferredResult",
    "KneePoint",
    "LazyModels",
    "LoadReport",
    "PlacementService",
    "ServiceOverloadError",
    "ServiceStats",
    "bundle_from_checkpoint",
    "bursty_arrivals",
    "corpus_fingerprint",
    "find_knee",
    "latency_quantiles",
    "layout_descriptor",
    "merge_bundles",
    "poisson_arrivals",
    "run_open_loop",
    "score_request_stream",
]
