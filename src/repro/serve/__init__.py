"""Serving subsystem: train once offline, answer many queries online.

The stable online API (docs/api.md) is three objects:

* ``CostModelBundle`` — the versioned on-disk artifact holding every trained
  metric ensemble + configs + training metadata (one save/load round-trip);
* ``CostEstimator``   — the single inference facade (``estimate`` / ``score``
  / ``optimize``) constructed from a bundle, owning all serving caches;
* ``PlacementService`` — the micro-batching front-end that coalesces
  concurrent requests into fused bucket-padded forwards.

Dispatch tunables (routing crossovers, chunk widths, cache capacities) live
on ``DispatchPolicy`` (serve/policy.py): ``autotune()`` calibrates them to
the running host, ``resolve_policy()`` applies the persisted profile / env
override, and ``stacking`` holds the fused multi-metric ensemble helpers
retired out of ``core/model.py`` in 0.7.
"""

from repro.serve.bundle import (
    BUNDLE_SCHEMA_VERSION,
    BundleVersionError,
    CostModelBundle,
    LazyModels,
    bundle_from_checkpoint,
    corpus_fingerprint,
    layout_descriptor,
    merge_bundles,
)
from repro.serve.estimator import CostEstimator, DeferredResult
from repro.serve.policy import (
    AutotuneResult,
    DispatchPolicy,
    active_policy,
    autotune,
    host_fingerprint,
    load_profile,
    profile_path,
    resolve_policy,
    save_profile,
    use_policy,
)
from repro.serve.stacking import StackedEnsembles, stack_metric_models
from repro.serve.load import (
    KneePoint,
    LoadReport,
    bursty_arrivals,
    find_knee,
    latency_quantiles,
    poisson_arrivals,
    run_open_loop,
    score_request_stream,
)
from repro.serve.service import PlacementService, ServiceOverloadError, ServiceStats

__all__ = [
    "AutotuneResult",
    "BUNDLE_SCHEMA_VERSION",
    "BundleVersionError",
    "CostModelBundle",
    "CostEstimator",
    "DeferredResult",
    "DispatchPolicy",
    "KneePoint",
    "LazyModels",
    "LoadReport",
    "PlacementService",
    "ServiceOverloadError",
    "ServiceStats",
    "StackedEnsembles",
    "active_policy",
    "autotune",
    "bundle_from_checkpoint",
    "bursty_arrivals",
    "corpus_fingerprint",
    "find_knee",
    "host_fingerprint",
    "latency_quantiles",
    "layout_descriptor",
    "load_profile",
    "merge_bundles",
    "poisson_arrivals",
    "profile_path",
    "resolve_policy",
    "save_profile",
    "score_request_stream",
    "stack_metric_models",
    "use_policy",
]
