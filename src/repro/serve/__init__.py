"""Serving subsystem: train once offline, answer many queries online.

The stable online API (docs/api.md) is three objects:

* ``CostModelBundle`` — the versioned on-disk artifact holding every trained
  metric ensemble + configs + training metadata (one save/load round-trip);
* ``CostEstimator``   — the single inference facade (``estimate`` / ``score``
  / ``optimize``) constructed from a bundle, owning all serving caches;
* ``PlacementService`` — the micro-batching front-end that coalesces
  concurrent requests into fused bucket-padded forwards.

Dispatch tunables (routing crossovers, chunk widths, cache capacities) live
on ``DispatchPolicy`` (serve/policy.py): ``autotune()`` calibrates them to
the running host, ``resolve_policy()`` applies the persisted profile / env
override, and ``stacking`` holds the fused multi-metric ensemble helpers
retired out of ``core/model.py`` in 0.7.

The fault path (docs/robustness.md) is ``lifecycle`` — shadow-evaluated
bundle hot-swap with rollback (``BundleSwapper``), circuit-breaker
degradation (``CircuitBreaker`` + ``fallback_scores``) — and ``chaos``, the
seeded fault injectors its guarantees are benchmarked under.
"""

from repro.serve.bundle import (
    BUNDLE_SCHEMA_VERSION,
    BundleIntegrityError,
    BundleVersionError,
    CostModelBundle,
    LazyModels,
    bundle_from_checkpoint,
    corpus_fingerprint,
    layout_descriptor,
    merge_bundles,
)
from repro.serve.estimator import CostEstimator, DeferredResult, NonFiniteEstimate
from repro.serve.lifecycle import (
    BundleSwapper,
    CircuitBreaker,
    ShadowRejected,
    ShadowVerdict,
    fallback_scores,
)
from repro.serve.policy import (
    AutotuneResult,
    DispatchPolicy,
    DispatchProfileWarning,
    RetryPolicy,
    active_policy,
    autotune,
    host_fingerprint,
    load_profile,
    profile_path,
    resolve_policy,
    save_profile,
    use_policy,
)
from repro.serve.stacking import StackedEnsembles, stack_metric_models
from repro.serve.load import (
    KneePoint,
    LoadReport,
    bursty_arrivals,
    find_knee,
    latency_quantiles,
    poisson_arrivals,
    run_open_loop,
    score_request_stream,
)
from repro.serve.service import (
    EstimateTimeoutError,
    PlacementService,
    ServiceOverloadError,
    ServiceStats,
)

__all__ = [
    "AutotuneResult",
    "BUNDLE_SCHEMA_VERSION",
    "BundleIntegrityError",
    "BundleSwapper",
    "BundleVersionError",
    "CircuitBreaker",
    "CostModelBundle",
    "CostEstimator",
    "DeferredResult",
    "DispatchPolicy",
    "DispatchProfileWarning",
    "EstimateTimeoutError",
    "KneePoint",
    "LazyModels",
    "LoadReport",
    "NonFiniteEstimate",
    "PlacementService",
    "RetryPolicy",
    "ServiceOverloadError",
    "ServiceStats",
    "ShadowRejected",
    "ShadowVerdict",
    "StackedEnsembles",
    "active_policy",
    "autotune",
    "bundle_from_checkpoint",
    "bursty_arrivals",
    "corpus_fingerprint",
    "fallback_scores",
    "find_knee",
    "host_fingerprint",
    "latency_quantiles",
    "layout_descriptor",
    "load_profile",
    "merge_bundles",
    "poisson_arrivals",
    "profile_path",
    "resolve_policy",
    "save_profile",
    "score_request_stream",
    "stack_metric_models",
    "use_policy",
]
