"""Fused multi-metric ensembles + inference voting (serving-side numerics).

The per-metric GNNs share one architecture (paper SIV-A: same GNNConfig,
different training targets), so their ensemble params are shape-identical
pytrees with a leading (E,) member axis.  Stacking them along that axis
turns "one forward per (metric, member)" into ONE vmapped forward whose
leading axis is sum(E_m) — a single kernel launch per GNN stage instead of
len(metrics) * E launches, which is where placement scoring spends its time
(dispatch overhead dominates these small graphs).

These helpers lived in ``core/model.py`` until repro 0.7; they are
serving-flavored (stacking and voting happen at inference, never in a
training step), so the core/model retirement moved them here next to their
only consumer, the ``CostEstimator`` facade.
"""

from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.model import CostModelConfig


def _ensemble_vote(raw: np.ndarray, cfg: CostModelConfig) -> np.ndarray:
    """(E, B) raw outputs -> cost-space prediction (paper SIV-A).

    regression: mean over members of expm1(raw); classification: majority vote
    over thresholded member probabilities -> {0,1}.
    """
    if cfg.task == "regression":
        return np.mean(np.expm1(raw), axis=0).clip(min=0.0)
    votes = (raw > 0.0).astype(np.int64)  # logit > 0 <=> p > 0.5
    return (votes.sum(axis=0) * 2 > votes.shape[0]).astype(np.int64)


class StackedEnsembles(NamedTuple):
    """Per-metric ensembles fused along the leading member axis.

    ``params`` leaves have shape ``(sum of member counts, ...)``; metric ``m``
    owns rows ``[offsets[i], offsets[i] + sizes[i])``.  Hashable-free (holds
    arrays), so it is passed positionally into jitted forwards that are cached
    on the shared ``GNNConfig`` instead.
    """

    params: object  # pytree, leaves stacked along axis 0
    metrics: Tuple[str, ...]
    cfgs: Tuple[CostModelConfig, ...]
    sizes: Tuple[int, ...]  # members per metric, in ``metrics`` order


def stack_metric_models(
    models: Dict[str, Tuple[object, CostModelConfig]],
    metrics: Optional[Sequence[str]] = None,
) -> StackedEnsembles:
    """Fuse several per-metric (params, cfg) ensembles into one stack.

    Requires every model to share the same ``GNNConfig`` and ``traditional_mp``
    flag (the forwards must be structurally identical to share a trace);
    raises ``ValueError`` otherwise so callers can fall back to the per-metric
    loop explicitly.  Member counts may differ — leaves are concatenated, not
    stacked, so metric i contributes ``sizes[i]`` rows.
    """
    names = tuple(metrics) if metrics is not None else tuple(models)
    assert names, "no metrics to stack"
    cfgs = tuple(models[m][1] for m in names)
    for c in cfgs[1:]:
        if c.gnn != cfgs[0].gnn or c.traditional_mp != cfgs[0].traditional_mp:
            raise ValueError(
                "cannot fuse metric ensembles with differing GNN configs: "
                f"{cfgs[0].metric}={cfgs[0].gnn} vs {c.metric}={c.gnn} "
                f"(traditional_mp {cfgs[0].traditional_mp} vs {c.traditional_mp})"
            )
    sizes = []
    for m in names:
        leaf = jax.tree_util.tree_leaves(models[m][0])[0]
        sizes.append(int(leaf.shape[0]))
    stacked = jax.tree_util.tree_map(
        lambda *leaves: jnp.concatenate([jnp.asarray(l) for l in leaves], axis=0),
        *[models[m][0] for m in names],
    )
    return StackedEnsembles(stacked, names, cfgs, tuple(sizes))


def _split_votes(raw: np.ndarray, stacked: StackedEnsembles) -> Dict[str, np.ndarray]:
    """(sum_E, B) fused raw outputs -> per-metric cost-space predictions."""
    out, off = {}, 0
    for m, cfg, sz in zip(stacked.metrics, stacked.cfgs, stacked.sizes):
        out[m] = _ensemble_vote(raw[off : off + sz], cfg)
        off += sz
    return out
