"""``CostEstimator``: the single inference facade over trained cost models.

One object answers every online query the paper's deployed model serves —
generic cost estimation for placed queries (``estimate``), candidate-placement
scoring (``score``), and full placement search (``optimize``) — constructed
from an in-memory model dict or a ``CostModelBundle``.  It owns all
serving-side state that used to be scattered across ``PlacementOptimizer``
and module-level dicts in ``core/model.py``:

* the per-(query, cluster) **skeleton LRU**: the featurized skeleton, its
  device transfer, and the trace-time ``QueryStatic``, shared by every
  ``score``/``optimize`` call on the same pair (the online-monitoring pattern
  re-scores one query every round);
* the per-metrics-tuple **stacked-ensemble cache**
  (``model.stack_metric_models``): all requested metrics ride ONE fused
  forward when their GNN configs are shape-identical;
* the **jitted-forward trace caches**.  These live at module level here
  (moved from ``core/model.py``): a trace is a pure function of (config,
  query structure, shapes, kernel lowering) — never of the estimator
  instance — so sharing them across estimators only deduplicates
  compilation.

Every dispatch tunable (chunk widths, cache capacities, routing crossovers)
comes from a ``serve.policy.DispatchPolicy`` — pass ``policy=`` or let the
constructor resolve the host profile / env override (``resolve_policy``).
The policy only moves performance knobs; predictions are policy-invariant
(test-pinned).

Scoring numerics are unchanged from the pre-facade path: docs/api.md is the
surface reference, docs/placement_search.md + docs/forward_engine.md the
engine internals.
"""

from __future__ import annotations

import threading
import warnings
from collections import OrderedDict
from collections.abc import Mapping
from functools import lru_cache, wraps  # lru_cache re-exported for tests/tools
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bucketing import BatchBanding, exact_banding_cached
from repro.core.gnn import (
    apply_gnn_merged,
    apply_gnn_placed,
    apply_gnn_placed_stacked,
    validate_merged_parents,
)
from repro.core.graph import (
    JointGraph,
    QueryStatic,
    batch_graphs,
    bucket_size,
    build_a_place_batch,
    build_graph,
    build_graph_batch,
    build_graph_skeleton,
    merge_graph_batches,
    pad_batch,
    query_static,
    skeleton_cache_key,
)
from repro.core.model import CostModelConfig, forward_ensemble
from repro.kernels import active_lowering
from repro.serve.policy import DispatchPolicy, active_policy, resolve_policy
from repro.serve.stacking import (
    StackedEnsembles,
    _ensemble_vote,
    _split_votes,
    stack_metric_models,
)

# -- jitted forward caches --------------------------------------------------------
#
# Every cached factory takes the kernels' active lowering as part of its key:
# the lowering is read at trace time, so without it a flipped
# REPRO_PALLAS_INTERPRET after the first call would silently reuse stale traces.

_MISS = object()


def _policy_lru(fn):
    """``lru_cache`` whose capacity tracks the active ``DispatchPolicy``.

    All four trace-factory caches share ONE capacity knob
    (``trace_cache_size``; sizing rationale in serve/policy.py) instead of
    the old scattered ``maxsize=64/128/256`` literals.  Capacity is read at
    insertion time, so installing a tuned profile resizes the caches without
    a process restart.  Matches the ``functools`` surface the tests touch:
    ``__wrapped__`` and ``cache_clear``.
    """
    cache: "OrderedDict[Tuple, object]" = OrderedDict()
    lock = threading.Lock()

    @wraps(fn)
    def wrapper(*args):
        with lock:
            hit = cache.get(args, _MISS)
            if hit is not _MISS:
                cache.move_to_end(args)
                return hit
        value = fn(*args)  # outside the lock: jax.jit wrapping is reentrant
        with lock:
            cache[args] = value
            cap = active_policy().trace_cache_size
            while len(cache) > cap:
                cache.popitem(last=False)
        return value

    wrapper.cache_clear = cache.clear
    return wrapper


def _can_donate() -> bool:
    """Whether input-buffer donation pays on this backend.

    XLA:CPU cannot alias donated inputs to outputs — donation there only
    produces "donated buffer was not usable" warnings — so the deferred
    dispatch path donates on accelerator backends and stays a no-op on CPU.
    The flag joins the trace-factory keys (a donating trace and a
    non-donating one are different executables).
    """
    return jax.default_backend() != "cpu"


@_policy_lru
def _jitted_forward(cfg: CostModelConfig, lowering: str = "ref"):
    return jax.jit(lambda p, g: forward_ensemble(p, g, cfg))


@_policy_lru
def _jitted_forward_stacked(
    gnn,
    traditional_mp: bool,
    banding: Optional[BatchBanding] = None,
    lowering: str = "ref",
    donate: bool = False,
):
    # metric only selects the loss/vote, never the forward; any metric works.
    # ``banding`` is the merged batch's static signature-exact stage-3 plan
    # (None: full-depth scan) — part of the trace key, like a shape.
    # ``donate`` releases the graph batch's device buffers to the launch —
    # only callers that built the batch themselves for this one call may pass
    # it (the merged drain path); ``estimate`` takes caller-owned batches.
    cfg = CostModelConfig(metric="latency_p", gnn=gnn, traditional_mp=traditional_mp)
    return jax.jit(
        lambda p, g: forward_ensemble(p, g, cfg, banding),
        donate_argnums=(1,) if donate else (),
    )


@_policy_lru
def _jitted_placed_forward(cfg: CostModelConfig, static: QueryStatic, lowering: str = "ref"):
    def f(p, skel, a_place):
        return jax.vmap(
            lambda pp: apply_gnn_placed(pp, skel, a_place, static, cfg.gnn)[..., 0]
        )(p)

    return jax.jit(f)


@_policy_lru
def _jitted_placed_forward_stacked(
    gnn,
    static: QueryStatic,
    n_hw: int,
    chunk: int = 0,
    lowering: str = "ref",
    donate: bool = False,
):
    # ``chunk`` (the policy's score_chunk) joins the key: the scan structure
    # it selects is part of the trace, exactly like a shape.  ``donate``
    # releases ``a_place`` (per-drain, caller-built) — never the skeleton,
    # which lives in the estimator's LRU across calls.
    def f(p, skel, a_place):
        return apply_gnn_placed_stacked(p, skel, a_place, static, gnn, n_hw, chunk)

    return jax.jit(f, donate_argnums=(2,) if donate else ())


@_policy_lru
def _jitted_merged_forward(
    gnn,
    banding: BatchBanding,
    max_parents: int,
    lowering: str = "ref",
    donate: bool = False,
):
    # the cross-query engine: S deduped skeletons + per-row (skel_id,
    # a_place); banding is the drain's signature-exact static plan.
    # ``donate`` releases the per-drain (skel_id, a_place) buffers — never
    # ``skels``, the cached device-resident skeleton stack of the mix.
    def f(p, skels, skel_id, a_place):
        return apply_gnn_merged(p, skels, skel_id, a_place, gnn, banding, max_parents)

    return jax.jit(f, donate_argnums=(2, 3) if donate else ())


class NonFiniteEstimate(RuntimeError):
    """An estimator output contained NaN/Inf.

    Raised by the always-on finiteness guard on every facade output
    (``estimate``/``score``/``estimate_many``/``score_many``) instead of
    returning garbage costs to the optimizer: a NaN cost compares false
    against everything, so an argmin over candidates would silently pick an
    arbitrary placement.  ``PlacementService`` counts these in
    ``ServiceStats.n_nonfinite`` and feeds them to the circuit breaker
    (docs/robustness.md).
    """


def _check_finite(kind: str, out):
    """Raise ``NonFiniteEstimate`` if any output array has NaN/Inf.

    ``out`` is a metric -> array dict or a sequence of them (the facade's
    two output shapes); one vectorized ``np.isfinite`` per array.
    """
    items = out if isinstance(out, (list, tuple)) else (out,)
    for d in items:
        if d is None:
            continue
        for m, v in d.items():
            v = np.asarray(v)
            if v.dtype.kind == "f" and not np.isfinite(v).all():
                bad = int(np.size(v) - np.count_nonzero(np.isfinite(v)))
                raise NonFiniteEstimate(
                    f"{kind} produced {bad} non-finite value(s) for metric "
                    f"{m!r} (shape {v.shape})"
                )
    return out


class DeferredResult:
    """Device work already dispatched; the host-side finalize is deferred.

    Every ``deferred=True`` facade call runs its host featurization and
    launches its jitted forwards eagerly (jax dispatch is asynchronous), then
    returns one of these instead of blocking on the device values.
    ``result()`` blocks and runs the remaining host work (convert, vote,
    split back per request).  ``PlacementService`` uses the split to
    featurize drain N+1 while drain N's device work is still running.
    """

    __slots__ = ("_finalize", "_value", "_done")

    def __init__(self, finalize):
        self._finalize = finalize
        self._done = False
        self._value = None

    def result(self):
        if not self._done:
            self._value = self._finalize()
            self._finalize = None  # drop captured device buffers
            self._done = True
        return self._value


def _maybe_defer(finalize, deferred: bool):
    return DeferredResult(finalize) if deferred else finalize()


# -- stateless scoring primitives -------------------------------------------------
#
# The numeric cores behind the facade methods.  Prefer the CostEstimator
# methods: these take raw params and do no skeleton/stack caching.


def ensemble_predict(params, g: JointGraph, cfg: CostModelConfig) -> np.ndarray:
    """Ensemble prediction in *cost space* for a batch of graphs."""
    raw = _jitted_forward(cfg, active_lowering())(params, g)
    return _ensemble_vote(np.asarray(raw), cfg)


def ensemble_proba(params, g: JointGraph, cfg: CostModelConfig) -> np.ndarray:
    """Mean over members of the per-member sigmoid probability."""
    assert cfg.task == "classification"
    raw = np.asarray(_jitted_forward(cfg, active_lowering())(params, g))
    return (1.0 / (1.0 + np.exp(-raw))).mean(axis=0)


def placed_predict(
    params, skel: JointGraph, a_place: jax.Array, static: QueryStatic, cfg: CostModelConfig
) -> np.ndarray:
    """Ensemble prediction over candidate placements of ONE query.

    ``skel`` is the shared unbatched skeleton, ``a_place`` the ``(B, O, W)``
    placement adjacencies.  Numerically equivalent to ``ensemble_predict`` on
    the broadcast batch, via the query-specialized forward (jit-cached per
    (config, query-structure) pair).  Not available for ``traditional_mp``
    ablation models — those don't have the 3-stage structure the
    specialization exploits; callers fall back to the generic path.
    """
    assert not cfg.traditional_mp, "use the generic path for traditional_mp models"
    fwd = _jitted_placed_forward(cfg, static, active_lowering())
    return _ensemble_vote(np.asarray(fwd(params, skel, a_place)), cfg)


def placed_predict_fused(
    stacked: StackedEnsembles,
    skel: JointGraph,
    a_place: jax.Array,
    static: QueryStatic,
    deferred: bool = False,
    chunk: Optional[int] = None,
    donate: bool = False,
) -> Dict[str, np.ndarray]:
    """All metrics' ensembles over one query's candidate placements, fused.

    One jitted ``apply_gnn_placed_stacked`` call evaluates every (metric,
    member) pair in a single launch per GNN stage, on the trimmed active-slot
    layout; the raw ``(sum_E, B)`` block is then split back per metric and
    voted exactly like ``placed_predict`` (the stacked-vs-loop equivalence
    test pins this to float tolerance).  ``deferred`` dispatches the forward
    and returns a ``DeferredResult`` whose ``result()`` blocks and splits.
    ``donate=True`` hands ``a_place``'s device buffer to the launch (freed
    for the output instead of held alive beside it) — pass it ONLY when the
    buffer was built for this call and never touched again, as the
    estimator's drain paths do; a no-op on CPU backends (``_can_donate``).
    """
    assert not stacked.cfgs[0].traditional_mp, (
        "use the generic path for traditional_mp models"
    )
    n_hw = int(np.asarray(skel.hw_mask).sum())
    if chunk is None:
        chunk = active_policy().score_chunk
    fwd = _jitted_placed_forward_stacked(
        stacked.cfgs[0].gnn, static, n_hw, chunk, active_lowering(),
        donate and _can_donate(),
    )
    raw = fwd(stacked.params, skel, a_place)
    return _maybe_defer(lambda: _split_votes(np.asarray(raw), stacked), deferred)


# -- the facade -------------------------------------------------------------------


class CostEstimator:
    """Serving facade over a set of trained per-metric ensembles.

    ``models``: dict metric -> (params, CostModelConfig), exactly the shape
    ``CostModelBundle.models`` carries (``from_bundle`` is the one-liner).
    ``policy``: a ``DispatchPolicy``; omitted, the host profile / env
    override resolves one (``serve.policy.resolve_policy``).
    Thread-safety: individual calls are safe to issue from one thread at a
    time; ``PlacementService`` adds the concurrent micro-batching front-end.
    """

    def __init__(
        self,
        models: Dict[str, Tuple[object, CostModelConfig]],
        meta=None,
        policy: Optional[DispatchPolicy] = None,
    ):
        # plain dicts are copied (callers may mutate theirs); other Mappings
        # (bundle.LazyModels) pass through so laziness survives the facade
        self.models = dict(models) if type(models) is dict else models
        assert isinstance(self.models, Mapping), type(models)
        self.meta = dict(meta or {})
        self.policy = (policy if policy is not None else resolve_policy()).validate()
        # (query, cluster) pairs kept device-resident
        self.skeleton_cache_size = self.policy.skeleton_cache_size
        self._skeletons: "OrderedDict[Tuple, Tuple[JointGraph, JointGraph, QueryStatic]]" = (
            OrderedDict()
        )
        self._stacked: Dict[Tuple[str, ...], Optional[StackedEnsembles]] = {}
        # cross-query drain mixes: structure-key tuple -> (device skeleton
        # stack, banding, max_parents).  A recurring mix (the steady state of
        # a monitoring loop) re-enters with zero stacking/banding/transfer.
        self._merged_groups: "OrderedDict[Tuple, Tuple]" = OrderedDict()
        self._optimizer = None
        # fault-injection / observation hooks (serve.chaos): objects with
        # optional ``before(kind, n)`` / ``after(kind, out) -> out | None``
        self._hooks: List[object] = []

    # -- hooks (the chaos-injection and observation seam; docs/robustness.md) -----

    def add_hook(self, hook) -> None:
        """Install a call hook.  ``before(kind, n)`` runs at dispatch time of
        every facade call (``kind`` in {"estimate", "score", "estimate_many",
        "score_many"}, ``n`` the row/graph count) and may raise or block —
        exactly what a real fault does.  ``after(kind, out)`` runs at
        finalize time (inside ``DeferredResult.result()`` for deferred
        calls) and may return a replacement output; the finiteness guard
        runs AFTER all hooks, so injected NaNs are caught like real ones."""
        self._hooks.append(hook)

    def remove_hook(self, hook) -> None:
        self._hooks.remove(hook)

    def _before(self, kind: str, n: int) -> None:
        for h in self._hooks:
            before = getattr(h, "before", None)
            if before is not None:
                before(kind, n)

    def _finish(self, kind: str, finalize, deferred: bool):
        """Wrap a finalize thunk with after-hooks + the finiteness guard."""

        def run():
            out = finalize()
            for h in self._hooks:
                after = getattr(h, "after", None)
                if after is not None:
                    repl = after(kind, out)
                    if repl is not None:
                        out = repl
            return _check_finite(kind, out)

        return _maybe_defer(run, deferred)

    @classmethod
    def from_bundle(
        cls,
        bundle,
        corpus_fingerprint: Optional[str] = None,
        policy: Optional[DispatchPolicy] = None,
        strict_provenance: bool = False,
    ) -> "CostEstimator":
        """Facade over a bundle's models (laziness preserved).

        ``corpus_fingerprint`` (see ``bundle.corpus_fingerprint``) is the
        caller's expectation of the corpus the models were trained on; when
        both it and the bundle's recorded ``meta["corpus_fingerprint"]``
        exist and disagree, a warning flags the provenance mismatch — the
        models still serve (retraining on refreshed labels is legitimate),
        but silently comparing them against the wrong corpus is not.
        ``strict_provenance=True`` upgrades the warning to a
        ``bundle.BundleVersionError`` — the lifecycle path (candidate
        bundles promoted into a live service) must never serve a model of
        unknown ancestry.
        """
        meta = bundle.meta or {}
        recorded = meta.get("corpus_fingerprint")
        if (
            corpus_fingerprint is not None
            and recorded is not None
            and recorded != corpus_fingerprint
        ):
            msg = (
                f"bundle was trained on corpus {recorded!r} but the caller "
                f"expects {corpus_fingerprint!r}; predictions are served "
                "against data the models never saw (provenance mismatch)"
            )
            if strict_provenance:
                from repro.serve.bundle import BundleVersionError

                raise BundleVersionError(msg)
            warnings.warn(msg, stacklevel=2)
        return cls(bundle.models, meta=meta, policy=policy)

    @property
    def metrics(self) -> Tuple[str, ...]:
        return tuple(self.models)

    def config(self, metric: str) -> CostModelConfig:
        return self.models[metric][1]

    # -- generic batch estimation -------------------------------------------------

    @staticmethod
    def _as_graphs(batch) -> JointGraph:
        """A batched ``JointGraph``, or a sequence of traces to featurize."""
        if not isinstance(batch, JointGraph):
            batch = batch_graphs(
                [build_graph(t.query, t.cluster, t.placement) for t in batch]
            )
        return jax.tree_util.tree_map(jnp.asarray, batch)

    def estimate(
        self, batch, metrics: Optional[Sequence[str]] = None, deferred: bool = False
    ) -> Dict[str, np.ndarray]:
        """Cost-space predictions for a batch of *placed* queries.

        ``batch`` is either a batched ``JointGraph`` or a sequence of traces
        (anything with ``.query``/``.cluster``/``.placement``), featurized
        here in one pass.  The batch is transferred to the device once and
        every requested ensemble (targets + success/backpressure filters)
        runs over the same resident batch; shape-identical per-metric configs
        (the COSTREAM default) are additionally fused into ONE stacked
        forward, heterogeneous configs fall back to a per-metric loop.
        Returns metric -> predictions aligned with the batch (``deferred``:
        a ``DeferredResult`` resolving to that dict once device work is done).
        """
        metrics = tuple(metrics) if metrics is not None else tuple(self.models)
        g = self._as_graphs(batch)
        self._before("estimate", int(g.op_x.shape[0]) if g.op_x.ndim == 3 else 1)
        stacked = self._stacked_for(metrics)
        if stacked is None:  # mixed architectures: per-metric forwards, shared batch
            lowering = active_lowering()
            raws = {
                m: _jitted_forward(self.models[m][1], lowering)(self.models[m][0], g)
                for m in metrics
            }
            return self._finish(
                "estimate",
                lambda: {
                    m: _ensemble_vote(np.asarray(raws[m]), self.models[m][1])
                    for m in metrics
                },
                deferred,
            )
        fwd = _jitted_forward_stacked(
            stacked.cfgs[0].gnn, stacked.cfgs[0].traditional_mp, None, active_lowering()
        )
        raw = fwd(stacked.params, g)
        return self._finish(
            "estimate", lambda: _split_votes(np.asarray(raw), stacked), deferred
        )

    def proba(self, batch, metric: str) -> np.ndarray:
        """Mean ensemble probability for one classification metric."""
        params, cfg = self.models[metric]
        return ensemble_proba(params, self._as_graphs(batch), cfg)

    # -- placement scoring --------------------------------------------------------

    def _skeleton_entry(
        self, query, cluster, key: Optional[Tuple] = None
    ) -> Tuple[JointGraph, JointGraph, QueryStatic]:
        """Cached (host skeleton, device skeleton, QueryStatic) for one pair.

        The host copy feeds the cross-query merge path (merging concatenates
        on the host before ONE device transfer); the device copy feeds the
        placed per-structure forwards.  Both ride the same LRU entry, so
        either path's hit warms the other.  ``key`` lets callers that already
        computed ``skeleton_cache_key`` (the service computes it at submit
        time) skip recomputing it — the key build is the most expensive host
        step on a warm cache."""
        if key is None:
            key = skeleton_cache_key(query, cluster)
        hit = self._skeletons.get(key)
        if hit is not None:
            self._skeletons.move_to_end(key)
            return hit
        host = build_graph_skeleton(query, cluster)
        entry = (host, jax.tree_util.tree_map(jnp.asarray, host), query_static(query))
        self._skeletons[key] = entry
        while len(self._skeletons) > self.skeleton_cache_size:
            self._skeletons.popitem(last=False)
        return entry

    def _skeleton_for(self, query, cluster) -> Tuple[JointGraph, QueryStatic]:
        """Cached (device-resident skeleton, QueryStatic) for one pair."""
        _, dev, static = self._skeleton_entry(query, cluster)
        return dev, static

    def _stacked_for(self, metrics: Tuple[str, ...]) -> Optional[StackedEnsembles]:
        """Fused ensemble stack for ``metrics``, or None if not fusable."""
        if metrics not in self._stacked:
            try:
                self._stacked[metrics] = stack_metric_models(self.models, metrics)
            except ValueError:  # heterogeneous per-metric configs
                self._stacked[metrics] = None
        return self._stacked[metrics]

    def scorer(self, query, cluster, metrics: Sequence[str], deferred: bool = False):
        """Scoring closure with the per-(query, cluster) work hoisted out.

        Refinement loops and repeated ``score``/``optimize`` calls re-score
        the same query; the skeleton, its device transfer, and the trace-time
        ``QueryStatic`` are identical throughout, so they come from the
        instance-level LRU (``_skeleton_for``) — at most ONE skeleton build
        per pair, and one fused stacked forward per scored batch.
        ``deferred`` makes the closure dispatch and return a
        ``DeferredResult`` instead of blocking on the device values.
        """
        metrics = tuple(metrics)
        if any(self.models[m][1].traditional_mp for m in metrics):
            # ablation models lack the 3-stage structure the specialized
            # forward exploits; build the full broadcast batch instead
            def score_generic(assignments: np.ndarray) -> Dict[str, np.ndarray]:
                n = len(assignments)
                if n == 0:  # not assert: callers (the service) rely on it under -O
                    raise ValueError("no candidates to score")
                graphs = pad_batch(
                    build_graph_batch(query, cluster, assignments), bucket_size(n)
                )
                # hooks + the finiteness guard fire inside the delegated
                # ``estimate`` (kind "estimate"), not a second time here
                pending = self.estimate(graphs, metrics, deferred=True)
                return _maybe_defer(
                    lambda: {m: v[:n] for m, v in pending.result().items()}, deferred
                )

            return score_generic

        skel, static = self._skeleton_for(query, cluster)
        stacked = self._stacked_for(metrics)

        def score(assignments: np.ndarray) -> Dict[str, np.ndarray]:
            n = len(assignments)
            if n == 0:  # not assert: callers (the service) rely on it under -O
                raise ValueError("no candidates to score")
            self._before("score", n)
            a_place = build_a_place_batch(query, cluster, assignments)
            pad = bucket_size(n) - n
            if pad:
                a_place = np.concatenate([a_place, np.repeat(a_place[-1:], pad, axis=0)])
            a_place = jnp.asarray(a_place)
            if stacked is not None:
                # a_place was built above for this one call: donate its buffer
                pending = placed_predict_fused(
                    stacked, skel, a_place, static, deferred=True,
                    chunk=self.policy.score_chunk, donate=True,
                )
                return self._finish(
                    "score",
                    lambda: {m: v[:n] for m, v in pending.result().items()},
                    deferred,
                )
            # heterogeneous (non-fusable) configs: per-metric loop, computed
            # eagerly — the rare path keeps no deferral, only the wrapper type
            out = {
                m: placed_predict(
                    self.models[m][0], skel, a_place, static, self.models[m][1]
                )[:n]
                for m in metrics
            }
            return self._finish("score", lambda: out, deferred)

        return score

    def score(
        self,
        query,
        cluster,
        assignments: np.ndarray,
        metrics: Optional[Sequence[str]] = None,
        deferred: bool = False,
    ) -> Dict[str, np.ndarray]:
        """Score an ``(N, n_ops)`` assignment matrix on every requested metric.

        One skeleton build per (query, cluster) pair (LRU-amortized), one
        bucket-padded stacked forward per call; padding rows are sliced off,
        so results are independent of the bucket and of batchmates.
        """
        metrics = tuple(metrics) if metrics is not None else tuple(self.models)
        return self.scorer(query, cluster, metrics, deferred=deferred)(
            np.asarray(assignments, dtype=np.int64)
        )

    # -- cross-query broadcast batches -------------------------------------------

    def supports_cross_query(self, metrics: Optional[Sequence[str]] = None) -> bool:
        """Whether ``metrics`` can ride one merged cross-query forward.

        Requires a fusable ensemble stack (shape-identical GNN configs) with
        the 3-stage structure (``traditional_mp`` ablation models aggregate
        over rounds, not stages, and keep their per-graph path).
        ``estimate_many``/``score_many`` fall back to per-request answers when
        this is False — the service uses it to route and count honestly.
        """
        metrics = tuple(metrics) if metrics is not None else tuple(self.models)
        stacked = self._stacked_for(metrics)
        return stacked is not None and not stacked.cfgs[0].traditional_mp

    def _merged_forward(
        self,
        merged: JointGraph,
        sizes: Sequence[int],
        metrics: Tuple[str, ...],
        max_rows: Optional[int],
        deferred: bool = False,
    ) -> List[Dict[str, np.ndarray]]:
        """One stacked forward per ``max_rows`` chunk of a merged host batch.

        Each chunk is bucket-padded (shape stability) and gets the
        signature-exact row-trimmed banding of the structures it actually
        contains (cached by signature hash — a recurring request mix reuses
        its plan AND its jit trace), so stage-3 work tracks real rows rather
        than the widest member.  Answers are split back per source batch.
        Every chunk is dispatched before any is blocked on; ``deferred``
        additionally defers the blocking itself to ``result()``.
        """
        stacked = self._stacked_for(metrics)
        total = int(merged.op_x.shape[0])
        step = max_rows if max_rows else total
        launched: List[Tuple[jax.Array, int]] = []
        fields = [np.asarray(x) for x in merged]
        for s in range(0, total, step):
            chunk = JointGraph(*[x[s : s + step] for x in fields])
            n = int(chunk.op_x.shape[0])
            chunk = pad_batch(chunk, bucket_size(n))
            banding = exact_banding_cached(chunk)
            # the chunk's device copy exists only for this launch: donate it
            fwd = _jitted_forward_stacked(
                stacked.cfgs[0].gnn, False, banding, active_lowering(), _can_donate()
            )
            raw = fwd(stacked.params, jax.tree_util.tree_map(jnp.asarray, chunk))
            launched.append((raw, n))

        def finalize() -> List[Dict[str, np.ndarray]]:
            parts = [
                {m: v[:n] for m, v in _split_votes(np.asarray(raw), stacked).items()}
                for raw, n in launched
            ]
            merged_out = {m: np.concatenate([p[m] for p in parts]) for m in metrics}
            out, off = [], 0
            for size in sizes:
                out.append({m: merged_out[m][off : off + size] for m in metrics})
                off += size
            return out

        return _maybe_defer(finalize, deferred)

    def estimate_many(
        self,
        batches: Sequence,
        metrics: Optional[Sequence[str]] = None,
        max_rows: Optional[int] = None,
        deferred: bool = False,
    ) -> List[Dict[str, np.ndarray]]:
        """``estimate`` for N independent batches through ONE fused forward.

        ``batches`` entries are batched ``JointGraph``s (single graphs are
        promoted) or trace sequences; structures may differ freely — every
        graph shares the canonical padded layout, so the batches concatenate
        along the batch axis (``graph.merge_graph_batches``) and one
        kernel-routed stacked forward per ``max_rows`` chunk answers
        everything.  Returns one metric -> predictions dict per input batch,
        order-aligned (``deferred``: a ``DeferredResult`` resolving to it).
        """
        metrics = tuple(metrics) if metrics is not None else tuple(self.models)
        batches = list(batches)
        if not batches:
            return _maybe_defer(lambda: [], deferred)
        host = []
        for b in batches:
            g = jax.tree_util.tree_map(np.asarray, self._as_graphs(b))
            if g.op_x.ndim == 2:  # single graph: promote to a batch of one
                g = jax.tree_util.tree_map(lambda x: x[None], g)
            host.append(g)
        total_graphs = sum(int(g.op_x.shape[0]) for g in host)
        if total_graphs == 0:
            raise ValueError("no graphs to estimate")
        if not self.supports_cross_query(metrics):
            # heterogeneous / ablation configs: per-batch fallback, chunked
            # and bucket-padded exactly like the merged path; every chunk is
            # dispatched before any is blocked on.  Hooks + the finiteness
            # guard fire inside the delegated ``estimate`` calls.
            pendings: List[Optional[List[Tuple]]] = []
            for g in host:
                total = int(g.op_x.shape[0])
                if total == 0:  # empty member: filled in below, like the
                    pendings.append(None)  # merged path's zero-width slice
                    continue
                step = max_rows if max_rows else total
                parts = []
                for s in range(0, total, step):
                    chunk = jax.tree_util.tree_map(lambda x: x[s : s + step], g)
                    n = int(chunk.op_x.shape[0])
                    parts.append(
                        (self.estimate(pad_batch(chunk, bucket_size(n)), metrics, deferred=True), n)
                    )
                pendings.append(parts)

            def finalize_fallback() -> List[Dict[str, np.ndarray]]:
                out: List[Optional[Dict[str, np.ndarray]]] = []
                for parts in pendings:
                    if parts is None:
                        out.append(None)
                        continue
                    done = [{m: v[:n] for m, v in p.result().items()} for p, n in parts]
                    out.append({m: np.concatenate([d[m] for d in done]) for m in metrics})
                template = next(o for o in out if o is not None)
                return [
                    o if o is not None else {m: template[m][:0] for m in metrics}
                    for o in out
                ]

            return _maybe_defer(finalize_fallback, deferred)
        self._before("estimate_many", total_graphs)
        merged, sizes = merge_graph_batches(host)
        pending = self._merged_forward(merged, sizes, metrics, max_rows, deferred=True)
        return self._finish("estimate_many", pending.result, deferred)

    def score_many(
        self,
        requests: Sequence[Tuple],
        metrics: Optional[Sequence[str]] = None,
        max_rows: Optional[int] = None,
        keys: Optional[Sequence[Tuple]] = None,
        deferred: bool = False,
    ) -> List[Dict[str, np.ndarray]]:
        """``score`` for N distinct (query, cluster, assignments) requests
        through ONE fused forward.

        The serving hot path for a heterogeneous request stream: requests
        are regrouped structure-major, each structure contributing its
        LRU-cached skeleton ONCE (zero featurization passes warm) plus all
        its candidate rows, and a single stacked ``apply_gnn_merged`` forward
        per ``max_rows`` chunk scores every (metric, member, candidate)
        triple — O(1) forwards per drain instead of O(#structures), with
        stage work proportional to real rows (the drain's signature-exact
        banding).  ``keys`` optionally carries precomputed
        ``skeleton_cache_key``s (the service computes them at submit).
        Returns one metric -> (N_i,) dict per request, order-aligned; answers
        equal per-request ``score`` to float tolerance (the merged engine and
        the placement-specialized engine are the same math in different
        association orders).  ``use_pallas`` models ride the same merged
        engine: its gathers/scatters are kernel-routed through
        ``kernels/seg_gather`` (see ``gnn.apply_gnn_merged``).
        """
        metrics = tuple(metrics) if metrics is not None else tuple(self.models)
        requests = list(requests)
        if not requests:
            return _maybe_defer(lambda: [], deferred)
        if not self.supports_cross_query(metrics):
            # hooks + the guard fire inside the delegated ``score`` calls
            per_req = [self.score(q, c, a, metrics, deferred=True) for q, c, a in requests]
            return _maybe_defer(lambda: [p.result() for p in per_req], deferred)
        stacked = self._stacked_for(metrics)
        if keys is None:
            keys = [skeleton_cache_key(q, c) for q, c, _ in requests]

        # regroup structure-major: one skeleton + one concatenated candidate
        # block per structure; remember each request's slice for the split
        groups: "OrderedDict[Tuple, List[int]]" = OrderedDict()
        mats = []
        for i, (q, c, a) in enumerate(requests):
            a = np.asarray(a, dtype=np.int64)
            if len(a) == 0:  # not assert: the service relies on it under -O
                raise ValueError("no candidates to score")
            mats.append(a)
            groups.setdefault(keys[i], []).append(i)
        self._before("score_many", sum(len(a) for a in mats))

        index_of, skels_dev, banding, max_parents = self._merged_group_for(
            requests, groups
        )
        blocks, ids = [], []
        for key, idxs in groups.items():
            q, c, _ = requests[idxs[0]]
            block = build_a_place_batch(q, c, np.concatenate([mats[i] for i in idxs]))
            blocks.append(block)
            ids.append(np.full(len(block), index_of[key], dtype=np.int32))
        skel_id = np.concatenate(ids) if len(ids) > 1 else ids[0]
        a_place = np.concatenate(blocks) if len(blocks) > 1 else blocks[0]
        pending = self._merged_placements_forward(
            skels_dev, banding, max_parents, skel_id, a_place,
            [len(b) for b in blocks], stacked, metrics, max_rows, deferred=True,
        )

        def finalize() -> List[Dict[str, np.ndarray]]:
            # split each structure's block back onto its requests, in order
            per_group = pending.result()
            out: List[Optional[Dict[str, np.ndarray]]] = [None] * len(requests)
            for g_out, idxs in zip(per_group, groups.values()):
                off = 0
                for i in idxs:
                    n = len(mats[i])
                    out[i] = {m: g_out[m][off : off + n] for m in metrics}
                    off += n
            return out

        return self._finish("score_many", finalize, deferred)

    def _merged_group_for(self, requests, groups) -> Tuple:
        """(key -> skeleton index, device skeleton stack, banding,
        max_parents) for one drain mix.

        Keyed on the *set* of structure keys — drains of one recurring mix
        arrive in whatever order client threads raced, so the index mapping
        is part of the entry and callers build ``skel_id`` through it; the
        mix then pays stacking, banding, and the skeleton device transfer
        exactly once (the steady state of an online monitoring loop)."""
        mix_key = frozenset(groups)
        hit = self._merged_groups.get(mix_key)
        if hit is not None:
            self._merged_groups.move_to_end(mix_key)
            return hit
        index_of = {key: i for i, key in enumerate(groups)}
        skels = batch_graphs(
            [self._skeleton_entry(*requests[idxs[0]][:2], key)[0] for key, idxs in groups.items()]
        )
        banding = exact_banding_cached(skels)
        max_parents = int(np.asarray(skels.a_flow).sum(axis=-2).max(initial=1))
        # the derived bound must actually cover every row's in-degree — a
        # violation would mean silently-dropped parents (wrong sums), so the
        # invariant is checked HERE, where the parent tables' width is fixed
        # for the lifetime of the cached group
        validate_merged_parents(skels.a_flow, max_parents, what="merged drain mix")
        entry = (index_of, jax.tree_util.tree_map(jnp.asarray, skels), banding, max_parents)
        self._merged_groups[mix_key] = entry
        while len(self._merged_groups) > self.policy.merged_group_cache_size:
            self._merged_groups.popitem(last=False)
        return entry

    def _merged_placements_forward(
        self,
        skels_dev: JointGraph,
        banding: BatchBanding,
        max_parents: int,
        skel_id: np.ndarray,
        a_place: np.ndarray,
        sizes: Sequence[int],
        stacked: StackedEnsembles,
        metrics: Tuple[str, ...],
        max_rows: Optional[int],
        deferred: bool = False,
    ) -> List[Dict[str, np.ndarray]]:
        """Chunked ``apply_gnn_merged`` over a structure-major placement batch.

        The trace is keyed on the participating structures' signature set
        (via the cached exact banding) and the bucket-padded row count — a
        recurring drain mix reuses its plan, its jit trace, AND its
        device-resident skeleton stack (``_merged_group_for``).
        """
        # per-chunk (ids, ap) device copies exist only for their launch:
        # donate them so a double-buffered drain holds one live batch, not two
        fwd = _jitted_merged_forward(
            stacked.cfgs[0].gnn, banding, max_parents, active_lowering(), _can_donate()
        )
        total = int(a_place.shape[0])
        step = max_rows if max_rows else total
        launched: List[Tuple[jax.Array, int]] = []
        for s in range(0, total, step):
            ids, ap = skel_id[s : s + step], a_place[s : s + step]
            n = len(ids)
            pad = bucket_size(n) - n
            if pad:
                ids = np.concatenate([ids, np.repeat(ids[-1:], pad)])
                ap = np.concatenate([ap, np.repeat(ap[-1:], pad, axis=0)])
            raw = fwd(stacked.params, skels_dev, jnp.asarray(ids), jnp.asarray(ap))
            launched.append((raw, n))

        def finalize() -> List[Dict[str, np.ndarray]]:
            parts = [
                {m: v[:n] for m, v in _split_votes(np.asarray(raw), stacked).items()}
                for raw, n in launched
            ]
            merged_out = {m: np.concatenate([p[m] for p in parts]) for m in metrics}
            out, off = [], 0
            for size in sizes:
                out.append({m: merged_out[m][off : off + size] for m in metrics})
                off += size
            return out

        return _maybe_defer(finalize, deferred)

    def optimize(self, query, cluster, target_metric: str = "latency_p", **kwargs):
        """Cost-based placement search (paper SV): sample -> score -> argopt.

        Delegates to a ``PlacementOptimizer`` sharing this estimator (and
        therefore its caches); see that class for the search knobs
        (``k``, ``refine_rounds``, ...).
        """
        if self._optimizer is None:
            from repro.placement.optimizer import PlacementOptimizer

            self._optimizer = PlacementOptimizer(self)
        return self._optimizer.optimize(query, cluster, target_metric, **kwargs)
