"""``CostEstimator``: the single inference facade over trained cost models.

One object answers every online query the paper's deployed model serves —
generic cost estimation for placed queries (``estimate``), candidate-placement
scoring (``score``), and full placement search (``optimize``) — constructed
from an in-memory model dict or a ``CostModelBundle``.  It owns all
serving-side state that used to be scattered across ``PlacementOptimizer``
and module-level dicts in ``core/model.py``:

* the per-(query, cluster) **skeleton LRU**: the featurized skeleton, its
  device transfer, and the trace-time ``QueryStatic``, shared by every
  ``score``/``optimize`` call on the same pair (the online-monitoring pattern
  re-scores one query every round);
* the per-metrics-tuple **stacked-ensemble cache**
  (``model.stack_metric_models``): all requested metrics ride ONE fused
  forward when their GNN configs are shape-identical;
* the **jitted-forward trace caches**.  These live at module level here
  (moved from ``core/model.py``): a trace is a pure function of (config,
  query structure, shapes, kernel lowering) — never of the estimator
  instance — so sharing them across estimators only deduplicates
  compilation, and the deprecation shims in ``core/model.py`` hit the same
  warm caches as the facade.

Scoring numerics are unchanged from the pre-facade path: docs/api.md is the
surface reference, docs/placement_search.md + docs/forward_engine.md the
engine internals.
"""

from __future__ import annotations

from collections import OrderedDict
from functools import lru_cache
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.gnn import apply_gnn_placed, apply_gnn_placed_stacked
from repro.core.graph import (
    JointGraph,
    QueryStatic,
    batch_graphs,
    bucket_size,
    build_a_place_batch,
    build_graph,
    build_graph_batch,
    build_graph_skeleton,
    pad_batch,
    query_static,
    skeleton_cache_key,
)
from repro.core.model import (
    CostModelConfig,
    StackedEnsembles,
    _ensemble_vote,
    _split_votes,
    forward_ensemble,
    stack_metric_models,
)
from repro.kernels import active_lowering

# -- jitted forward caches --------------------------------------------------------
#
# Every cached factory takes the kernels' active lowering as part of its key:
# the lowering is read at trace time, so without it a flipped
# REPRO_PALLAS_INTERPRET after the first call would silently reuse stale traces.


@lru_cache(maxsize=64)
def _jitted_forward(cfg: CostModelConfig, lowering: str = "ref"):
    return jax.jit(lambda p, g: forward_ensemble(p, g, cfg))


@lru_cache(maxsize=64)
def _jitted_forward_stacked(gnn, traditional_mp: bool, lowering: str = "ref"):
    # metric only selects the loss/vote, never the forward; any metric works
    cfg = CostModelConfig(metric="latency_p", gnn=gnn, traditional_mp=traditional_mp)
    return jax.jit(lambda p, g: forward_ensemble(p, g, cfg))


@lru_cache(maxsize=256)
def _jitted_placed_forward(cfg: CostModelConfig, static: QueryStatic, lowering: str = "ref"):
    def f(p, skel, a_place):
        return jax.vmap(
            lambda pp: apply_gnn_placed(pp, skel, a_place, static, cfg.gnn)[..., 0]
        )(p)

    return jax.jit(f)


@lru_cache(maxsize=256)
def _jitted_placed_forward_stacked(
    gnn, static: QueryStatic, n_hw: int, lowering: str = "ref"
):
    def f(p, skel, a_place):
        return apply_gnn_placed_stacked(p, skel, a_place, static, gnn, n_hw)

    return jax.jit(f)


# -- stateless scoring primitives -------------------------------------------------
#
# The numeric cores behind the facade methods AND the core.model deprecation
# shims.  Prefer the CostEstimator methods: these take raw params and do no
# skeleton/stack caching.


def ensemble_predict(params, g: JointGraph, cfg: CostModelConfig) -> np.ndarray:
    """Ensemble prediction in *cost space* for a batch of graphs."""
    raw = _jitted_forward(cfg, active_lowering())(params, g)
    return _ensemble_vote(np.asarray(raw), cfg)


def ensemble_proba(params, g: JointGraph, cfg: CostModelConfig) -> np.ndarray:
    """Mean over members of the per-member sigmoid probability."""
    assert cfg.task == "classification"
    raw = np.asarray(_jitted_forward(cfg, active_lowering())(params, g))
    return (1.0 / (1.0 + np.exp(-raw))).mean(axis=0)


def placed_predict(
    params, skel: JointGraph, a_place: jax.Array, static: QueryStatic, cfg: CostModelConfig
) -> np.ndarray:
    """Ensemble prediction over candidate placements of ONE query.

    ``skel`` is the shared unbatched skeleton, ``a_place`` the ``(B, O, W)``
    placement adjacencies.  Numerically equivalent to ``ensemble_predict`` on
    the broadcast batch, via the query-specialized forward (jit-cached per
    (config, query-structure) pair).  Not available for ``traditional_mp``
    ablation models — those don't have the 3-stage structure the
    specialization exploits; callers fall back to the generic path.
    """
    assert not cfg.traditional_mp, "use the generic path for traditional_mp models"
    fwd = _jitted_placed_forward(cfg, static, active_lowering())
    return _ensemble_vote(np.asarray(fwd(params, skel, a_place)), cfg)


def placed_predict_fused(
    stacked: StackedEnsembles, skel: JointGraph, a_place: jax.Array, static: QueryStatic
) -> Dict[str, np.ndarray]:
    """All metrics' ensembles over one query's candidate placements, fused.

    One jitted ``apply_gnn_placed_stacked`` call evaluates every (metric,
    member) pair in a single launch per GNN stage, on the trimmed active-slot
    layout; the raw ``(sum_E, B)`` block is then split back per metric and
    voted exactly like ``placed_predict`` (the stacked-vs-loop equivalence
    test pins this to float tolerance).
    """
    assert not stacked.cfgs[0].traditional_mp, (
        "use the generic path for traditional_mp models"
    )
    n_hw = int(np.asarray(skel.hw_mask).sum())
    fwd = _jitted_placed_forward_stacked(
        stacked.cfgs[0].gnn, static, n_hw, active_lowering()
    )
    return _split_votes(np.asarray(fwd(stacked.params, skel, a_place)), stacked)


# -- the facade -------------------------------------------------------------------


class CostEstimator:
    """Serving facade over a set of trained per-metric ensembles.

    ``models``: dict metric -> (params, CostModelConfig), exactly the shape
    ``CostModelBundle.models`` carries (``from_bundle`` is the one-liner).
    Thread-safety: individual calls are safe to issue from one thread at a
    time; ``PlacementService`` adds the concurrent micro-batching front-end.
    """

    skeleton_cache_size = 64  # (query, cluster) pairs kept device-resident

    def __init__(self, models: Dict[str, Tuple[object, CostModelConfig]], meta=None):
        self.models = dict(models)
        self.meta = dict(meta or {})
        self._skeletons: "OrderedDict[Tuple, Tuple[JointGraph, QueryStatic]]" = OrderedDict()
        self._stacked: Dict[Tuple[str, ...], Optional[StackedEnsembles]] = {}
        self._optimizer = None

    @classmethod
    def from_bundle(cls, bundle) -> "CostEstimator":
        return cls(bundle.models, meta=bundle.meta)

    @property
    def metrics(self) -> Tuple[str, ...]:
        return tuple(self.models)

    def config(self, metric: str) -> CostModelConfig:
        return self.models[metric][1]

    # -- generic batch estimation -------------------------------------------------

    @staticmethod
    def _as_graphs(batch) -> JointGraph:
        """A batched ``JointGraph``, or a sequence of traces to featurize."""
        if not isinstance(batch, JointGraph):
            batch = batch_graphs(
                [build_graph(t.query, t.cluster, t.placement) for t in batch]
            )
        return jax.tree_util.tree_map(jnp.asarray, batch)

    def estimate(self, batch, metrics: Optional[Sequence[str]] = None) -> Dict[str, np.ndarray]:
        """Cost-space predictions for a batch of *placed* queries.

        ``batch`` is either a batched ``JointGraph`` or a sequence of traces
        (anything with ``.query``/``.cluster``/``.placement``), featurized
        here in one pass.  The batch is transferred to the device once and
        every requested ensemble (targets + success/backpressure filters)
        runs over the same resident batch; shape-identical per-metric configs
        (the COSTREAM default) are additionally fused into ONE stacked
        forward, heterogeneous configs fall back to a per-metric loop.
        Returns metric -> predictions aligned with the batch.
        """
        metrics = tuple(metrics) if metrics is not None else tuple(self.models)
        g = self._as_graphs(batch)
        stacked = self._stacked_for(metrics)
        if stacked is None:  # mixed architectures: per-metric forwards, shared batch
            return {
                m: ensemble_predict(self.models[m][0], g, self.models[m][1])
                for m in metrics
            }
        fwd = _jitted_forward_stacked(
            stacked.cfgs[0].gnn, stacked.cfgs[0].traditional_mp, active_lowering()
        )
        return _split_votes(np.asarray(fwd(stacked.params, g)), stacked)

    def proba(self, batch, metric: str) -> np.ndarray:
        """Mean ensemble probability for one classification metric."""
        params, cfg = self.models[metric]
        return ensemble_proba(params, self._as_graphs(batch), cfg)

    # -- placement scoring --------------------------------------------------------

    def _skeleton_for(self, query, cluster) -> Tuple[JointGraph, QueryStatic]:
        """Cached (device-resident skeleton, QueryStatic) for one pair."""
        key = skeleton_cache_key(query, cluster)
        hit = self._skeletons.get(key)
        if hit is not None:
            self._skeletons.move_to_end(key)
            return hit
        skel = jax.tree_util.tree_map(jnp.asarray, build_graph_skeleton(query, cluster))
        entry = (skel, query_static(query))
        self._skeletons[key] = entry
        while len(self._skeletons) > self.skeleton_cache_size:
            self._skeletons.popitem(last=False)
        return entry

    def _stacked_for(self, metrics: Tuple[str, ...]) -> Optional[StackedEnsembles]:
        """Fused ensemble stack for ``metrics``, or None if not fusable."""
        if metrics not in self._stacked:
            try:
                self._stacked[metrics] = stack_metric_models(self.models, metrics)
            except ValueError:  # heterogeneous per-metric configs
                self._stacked[metrics] = None
        return self._stacked[metrics]

    def scorer(self, query, cluster, metrics: Sequence[str]):
        """Scoring closure with the per-(query, cluster) work hoisted out.

        Refinement loops and repeated ``score``/``optimize`` calls re-score
        the same query; the skeleton, its device transfer, and the trace-time
        ``QueryStatic`` are identical throughout, so they come from the
        instance-level LRU (``_skeleton_for``) — at most ONE skeleton build
        per pair, and one fused stacked forward per scored batch.
        """
        metrics = tuple(metrics)
        if any(self.models[m][1].traditional_mp for m in metrics):
            # ablation models lack the 3-stage structure the specialized
            # forward exploits; build the full broadcast batch instead
            def score_generic(assignments: np.ndarray) -> Dict[str, np.ndarray]:
                n = len(assignments)
                if n == 0:  # not assert: callers (the service) rely on it under -O
                    raise ValueError("no candidates to score")
                graphs = pad_batch(
                    build_graph_batch(query, cluster, assignments), bucket_size(n)
                )
                scored = self.estimate(graphs, metrics)
                return {m: v[:n] for m, v in scored.items()}

            return score_generic

        skel, static = self._skeleton_for(query, cluster)
        stacked = self._stacked_for(metrics)

        def score(assignments: np.ndarray) -> Dict[str, np.ndarray]:
            n = len(assignments)
            if n == 0:  # not assert: callers (the service) rely on it under -O
                raise ValueError("no candidates to score")
            a_place = build_a_place_batch(query, cluster, assignments)
            pad = bucket_size(n) - n
            if pad:
                a_place = np.concatenate([a_place, np.repeat(a_place[-1:], pad, axis=0)])
            a_place = jnp.asarray(a_place)
            if stacked is not None:
                scored = placed_predict_fused(stacked, skel, a_place, static)
                return {m: v[:n] for m, v in scored.items()}
            return {
                m: placed_predict(
                    self.models[m][0], skel, a_place, static, self.models[m][1]
                )[:n]
                for m in metrics
            }

        return score

    def score(
        self,
        query,
        cluster,
        assignments: np.ndarray,
        metrics: Optional[Sequence[str]] = None,
    ) -> Dict[str, np.ndarray]:
        """Score an ``(N, n_ops)`` assignment matrix on every requested metric.

        One skeleton build per (query, cluster) pair (LRU-amortized), one
        bucket-padded stacked forward per call; padding rows are sliced off,
        so results are independent of the bucket and of batchmates.
        """
        metrics = tuple(metrics) if metrics is not None else tuple(self.models)
        return self.scorer(query, cluster, metrics)(
            np.asarray(assignments, dtype=np.int64)
        )

    def optimize(self, query, cluster, target_metric: str = "latency_p", **kwargs):
        """Cost-based placement search (paper SV): sample -> score -> argopt.

        Delegates to a ``PlacementOptimizer`` sharing this estimator (and
        therefore its caches); see that class for the search knobs
        (``k``, ``refine_rounds``, ...).
        """
        if self._optimizer is None:
            from repro.placement.optimizer import PlacementOptimizer

            self._optimizer = PlacementOptimizer(self)
        return self._optimizer.optimize(query, cluster, target_metric, **kwargs)
