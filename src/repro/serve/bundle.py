"""Versioned on-disk bundle of trained COSTREAM cost models.

A ``CostModelBundle`` is the ONE serving artifact: every trained metric
ensemble of a deployment — the regression targets (latency/throughput) plus
the success/backpressure feasibility filters — together with their
``CostModelConfig``s and training metadata, in a single directory:

    <dir>/step_0000000000/arrays.npz     every metric's stacked ensemble params
    <dir>/step_0000000000/manifest.json  schema + layout versions, configs, meta
    <dir>/latest                         pointer (atomic-write protocol)

Bundles are written with the atomic checkpoint writer
(``training/checkpoint.py``), so a crash mid-save never corrupts a served
bundle.  One ``save``/``load`` round-trip replaces the five loose per-metric
checkpoint directories the training driver used to emit.

The manifest pins two compatibility contracts, checked on ``load``:

* ``schema_version`` — the bundle format itself (``BUNDLE_SCHEMA_VERSION``);
* ``layout`` — the depth-major canonical slot layout the params were trained
  against (``graph.SLOT_RANGES`` + pad sizes, the PR-3 engine contract).
  Ensemble weights are row-position-dependent, so serving them under a
  different layout would silently mis-predict; ``load`` refuses with a
  ``BundleVersionError`` instead.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
from collections.abc import Mapping
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

import jax
import numpy as np

from repro.core.gnn import GNNConfig
from repro.core.graph import MAX_DEPTH, MAX_HW, MAX_OPS, SLOT_RANGES
from repro.core.model import CostModelConfig, init_cost_model
from repro.training.checkpoint import (
    SEP,
    _path_str,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)

BUNDLE_SCHEMA_VERSION = 1


def layout_descriptor() -> Dict:
    """The slot-layout contract bundles are pinned to (JSON-normalized)."""
    return {
        "slot_ranges": [list(r) for r in SLOT_RANGES],
        "max_ops": MAX_OPS,
        "max_hw": MAX_HW,
        "max_depth": MAX_DEPTH,
    }


class BundleVersionError(RuntimeError):
    """The bundle's schema or slot layout is incompatible with this build."""


class BundleIntegrityError(RuntimeError):
    """The bundle's on-disk arrays are unreadable (truncated/corrupt npz,
    missing or mis-shaped params leaves).  Raised by
    ``CostModelBundle.load(verify=True)`` at load time — the lifecycle path
    verifies candidates up front so a lazy bundle can never defer corruption
    discovery to its first forward mid-drain."""


def _config_to_manifest(cfg: CostModelConfig) -> Dict:
    return {
        "metric": cfg.metric,
        "n_ensemble": cfg.n_ensemble,
        "traditional_mp": cfg.traditional_mp,
        "gnn": dataclasses.asdict(cfg.gnn),
    }


def _config_from_manifest(spec: Dict) -> CostModelConfig:
    return CostModelConfig(
        metric=spec["metric"],
        n_ensemble=spec["n_ensemble"],
        traditional_mp=spec.get("traditional_mp", False),
        gnn=GNNConfig(**spec["gnn"]),
    )


@dataclass
class CostModelBundle:
    """All trained metric ensembles of one deployment + their configs + meta.

    ``models``: metric name -> (ensemble params pytree, CostModelConfig) —
    the exact dict shape ``CostEstimator`` and ``PlacementOptimizer`` consume.
    ``meta``: free-form training provenance (corpus seeds, epochs, val
    losses); persisted verbatim in the manifest.
    """

    models: Dict[str, Tuple[object, CostModelConfig]]
    meta: Dict = field(default_factory=dict)

    @property
    def metrics(self) -> Tuple[str, ...]:
        return tuple(self.models)

    def config(self, metric: str) -> CostModelConfig:
        return self.models[metric][1]

    def params(self, metric: str):
        return self.models[metric][0]

    def save(self, directory: str) -> str:
        """Atomically persist the bundle; returns the written step directory."""
        assert self.models, "refusing to save an empty bundle"
        state = {m: params for m, (params, _) in self.models.items()}
        manifest = {
            "schema_version": BUNDLE_SCHEMA_VERSION,
            "layout": layout_descriptor(),
            "configs": {m: _config_to_manifest(cfg) for m, (_, cfg) in self.models.items()},
            "meta": self.meta,
        }
        return save_checkpoint(directory, 0, state, extra=manifest, keep=1)

    @classmethod
    def load(
        cls, directory: str, lazy: bool = True, verify: bool = False
    ) -> "CostModelBundle":
        """Load a bundle, refusing incompatible schema/layout versions.

        The manifest (configs, meta, compatibility contracts) is always read
        eagerly; with ``lazy=True`` (the default) each metric's ensemble
        params are deserialized from ``arrays.npz`` on first access instead —
        a many-metric bundle serving a latency-only workload never pays for
        the filters' weights.  ``CostEstimator`` preserves the laziness;
        anything that walks ``models.items()`` (``save``, ``merge_bundles``)
        simply forces the load.

        ``verify=True`` deserializes every metric's params once up front and
        raises ``BundleIntegrityError`` on any unreadable/mis-shaped leaf —
        a lazy bundle otherwise defers corruption discovery to the first
        forward that touches the bad metric, mid-drain.  The verification
        pass discards the arrays, so a verified lazy bundle still holds no
        params in memory until first use.
        """
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no bundle under {directory}")
        step_dir = os.path.join(directory, f"step_{step:010d}")
        with open(os.path.join(step_dir, "manifest.json")) as f:
            manifest = json.load(f)["extra"]
        _check_compatible(manifest, directory)
        cfgs = {m: _config_from_manifest(spec) for m, spec in manifest["configs"].items()}
        if verify:
            npz_path = os.path.join(step_dir, "arrays.npz")
            for m, cfg in cfgs.items():
                try:
                    _params_from_npz(npz_path, m, cfg, f"bundle arrays at {npz_path}")
                except Exception as e:
                    raise BundleIntegrityError(
                        f"bundle at {directory} failed verification for metric "
                        f"{m!r}: {e.__class__.__name__}: {e}"
                    ) from e
        if lazy:
            return cls(models=LazyModels(step_dir, cfgs), meta=manifest.get("meta", {}))
        like = {m: init_cost_model(jax.random.PRNGKey(0), cfg) for m, cfg in cfgs.items()}
        state, _, _ = restore_checkpoint(directory, like, step=step)
        assert state is not None, f"bundle manifest without arrays under {directory}"
        return cls(
            models={m: (state[m], cfgs[m]) for m in cfgs},
            meta=manifest.get("meta", {}),
        )


def _check_compatible(manifest: Dict, directory: str) -> None:
    got = manifest.get("schema_version")
    if got != BUNDLE_SCHEMA_VERSION:
        raise BundleVersionError(
            f"bundle at {directory} has schema_version={got!r}, but this build "
            f"reads v{BUNDLE_SCHEMA_VERSION}; re-export the bundle with a "
            "matching repro version (see docs/api.md#bundle-format)"
        )
    layout = manifest.get("layout")
    if layout != layout_descriptor():
        raise BundleVersionError(
            f"bundle at {directory} was trained against a different canonical "
            f"slot layout ({layout!r} vs {layout_descriptor()!r}); ensemble "
            "weights are row-position-dependent, so serving them under this "
            "build's depth-major layout would silently mis-predict — retrain "
            "or convert the bundle (docs/api.md#bundle-format)"
        )


def _params_from_npz(npz_path: str, prefix: str, cfg: CostModelConfig, origin: str):
    """Deserialize one ensemble's params from the ``prefix``-keyed npz leaves.

    ``np.load`` only decompresses the members actually read, so pulling one
    metric out of a many-metric ``arrays.npz`` costs that metric's bytes —
    the mechanism behind both lazy bundle loading (prefix = metric name) and
    checkpoint export (prefix = ``"0"``, the params element of the training
    step state).
    """
    like = init_cost_model(jax.random.PRNGKey(0), cfg)
    leaves_with_paths = jax.tree_util.tree_flatten_with_path(like)[0]
    treedef = jax.tree_util.tree_structure(like)
    new_leaves = []
    with np.load(npz_path) as data:
        files = set(data.files)
        for pth, leaf in leaves_with_paths:
            key = prefix + SEP + SEP.join(_path_str(p) for p in pth)
            if key not in files:
                raise KeyError(f"{origin} lacks params leaf {key}")
            arr = data[key]
            want = np.asarray(leaf)
            if tuple(arr.shape) != tuple(want.shape):
                raise ValueError(
                    f"params shape mismatch for {key}: stored {arr.shape} vs "
                    f"config {want.shape} — wrong CostModelConfig for {origin}"
                )
            new_leaves.append(arr.astype(want.dtype))
    return jax.tree_util.tree_unflatten(treedef, new_leaves)


class LazyModels(Mapping):
    """Read-only metric -> (params, cfg) mapping that defers array loading.

    Keys (and therefore ``bundle.metrics`` / ``estimator.metrics``) come from
    the eagerly-read manifest; a metric's params hit disk on its first
    ``[]``.  Loaded entries are kept, so repeated access is a dict lookup.
    """

    def __init__(self, step_dir: str, cfgs: Dict[str, CostModelConfig]):
        self._npz_path = os.path.join(step_dir, "arrays.npz")
        self._cfgs = dict(cfgs)
        self._loaded: Dict[str, Tuple[object, CostModelConfig]] = {}

    def __getitem__(self, metric: str) -> Tuple[object, CostModelConfig]:
        hit = self._loaded.get(metric)
        if hit is None:
            cfg = self._cfgs[metric]  # raises KeyError for unknown metrics
            params = _params_from_npz(
                self._npz_path, metric, cfg, f"bundle arrays at {self._npz_path}"
            )
            hit = self._loaded[metric] = (params, cfg)
        return hit

    def __iter__(self):
        return iter(self._cfgs)

    def __len__(self) -> int:
        return len(self._cfgs)


def corpus_fingerprint(traces) -> str:
    """Stable digest of a training corpus (size + every trace's labels).

    Recorded in bundle meta by the training driver and checked (with a
    warning, not an error — retraining on refreshed labels is legitimate) by
    ``CostEstimator.from_bundle`` so a bundle served against the wrong
    corpus' evaluation data is caught at load time, not in a q-error plot.
    """
    h = hashlib.sha256(str(len(traces)).encode())
    for t in traces:
        for k, v in sorted(t.labels.as_dict().items()):
            h.update(k.encode())
            h.update(np.float64(v).tobytes())
    return h.hexdigest()[:16]


def bundle_from_checkpoint(
    ckpt_dir: str, cfg: CostModelConfig, meta: Optional[Dict] = None
) -> CostModelBundle:
    """Export a ``train_cost_model`` checkpoint as a single-metric bundle.

    Training checkpoints persist the full step state ``(params, opt_state,
    ef)``; only the params (tuple element 0) belong in a serving bundle, so
    this reads the ``0/``-prefixed leaves of the newest step directly instead
    of reconstructing the optimizer/error-feedback trees just to discard
    them.  Combine the returned bundles of several metrics via
    ``merge_bundles`` before serving.
    """
    step = latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no training checkpoint under {ckpt_dir}")
    step_dir = os.path.join(ckpt_dir, f"step_{step:010d}")
    try:
        params = _params_from_npz(
            os.path.join(step_dir, "arrays.npz"),
            "0",
            cfg,
            f"checkpoint at {ckpt_dir}",
        )
    except KeyError as e:
        raise KeyError(
            f"{e.args[0]}; was it written by train_cost_model "
            "(state = (params, opt_state, ef))?"
        ) from None
    return CostModelBundle(
        models={cfg.metric: (params, cfg)},
        meta={"exported_from": os.path.abspath(ckpt_dir), "step": int(step), **(meta or {})},
    )


def merge_bundles(*bundles: CostModelBundle) -> CostModelBundle:
    """Union of several bundles' models (later bundles win on metric clash).

    Meta keys agreeing across bundles merge flat; keys carrying *different*
    values (e.g. every ``bundle_from_checkpoint`` export has its own
    ``exported_from``/``step``) are namespaced per source bundle as
    ``"<metrics>/<key>"``, so no metric's provenance is silently overwritten
    by another's.
    """
    models: Dict[str, Tuple[object, CostModelConfig]] = {}
    for b in bundles:
        models.update(b.models)
    first: Dict = {}
    conflicts = set()
    for b in bundles:
        for k, v in b.meta.items():
            if k in first and first[k] != v:
                conflicts.add(k)
            first.setdefault(k, v)
    meta = {k: v for k, v in first.items() if k not in conflicts}
    for b in bundles:
        ns = ",".join(b.metrics)
        for k, v in b.meta.items():
            if k in conflicts:
                meta[f"{ns}/{k}"] = v
    return CostModelBundle(models=models, meta=meta)
