"""``PlacementService``: a micro-batching front-end over a ``CostEstimator``.

The paper deploys COSTREAM by running "parallel instances" to score candidate
placements concurrently (§V); the TPU-native analogue is not N processes but
ONE fused forward whose batch axis carries every concurrent request.  This
service is that serving layer: requests are submitted from any thread and
answered with futures, while a single worker drains everything queued at each
wake-up — adaptive micro-batching, so while one fused forward runs, new
requests pile up and form the next batch — and answers each compatible group
with one bucket-padded stacked forward through the shared estimator:

* ``score`` requests coalesce per metrics tuple — including requests for
  *different* (query, cluster) structures: their placement batches merge
  structure-major into ONE shared batch (``CostEstimator.score_many``)
  answered by a single signature-banded merged forward per ``max_batch``
  chunk.  Merging trades S-1 dispatches for span-conservative stage work, so
  the drain routes adaptively: dispatch-bound drains (at most
  ``cross_query_row_limit`` candidate rows per structure on average) merge,
  compute-bound drains — and single-structure groups — take the
  placement-specialized per-structure path, which wins its dispatch back in
  exact per-query stage-3 work.  ``cross_query=False`` pins the pre-merge
  behavior of one forward per structure (the benchmark baseline).  Scores
  are batchmate-independent (the padding-invariance tests pin this), so
  coalescing is invisible to callers;
* ``estimate`` requests coalesce per metrics tuple: every ``JointGraph``
  shares the same padded layout, so batches concatenate along the batch axis
  (``CostEstimator.estimate_many``).

Throughput economics: each forward pays a fixed dispatch cost that dominates
these small graphs, so B coalesced requests cost ~1 dispatch instead of B —
and a heterogeneous stream of S structures costs ~1 dispatch instead of S.
``benchmarks/serve_bench.py`` gates both wins in CI.

Latency engineering (docs/load_harness.md measures all three):

* **double-buffered drains** (``double_buffer``, default on for accelerator
  backends): every drain
  is split into a *launch* half (host-side grouping + featurization + device
  dispatch, via the estimator's ``deferred=True`` calls) and a *finalize*
  half (block on device values, vote, resolve futures).  The worker launches
  drain N+1 before finalizing drain N, so host featurization overlaps device
  compute and the steady-state drain cycle tracks ``max(host, device)``
  instead of their sum;
* **bounded-queue admission control** (``max_queue_depth``): past the bound,
  ``submit_*`` raises ``ServiceOverloadError`` (``overflow="reject"``) or
  blocks the producer (``overflow="block"``) instead of queueing unbounded
  work — under sustained overload, latency is shed at the door rather than
  grown in the queue;
* **warmed compile caches** (``warmup=[(query, cluster), ...]``): ``start()``
  pre-runs every bucket-padded forward shape the structure set can hit, so
  first-request jit compilation never lands in a caller's latency.  Merged
  cross-query traces are keyed on the drain's *structure mix*, an unbounded
  space under open-loop arrivals — so the service only merges mixes that are
  warmed or within ``max_merged_mixes`` first-seen runtime admissions, and
  routes every other drain down the (warm) per-structure path.
"""

from __future__ import annotations

import threading
import time
from collections import OrderedDict, deque
from concurrent.futures import Future
from dataclasses import dataclass
from typing import Callable, Dict, List, NamedTuple, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.core.bucketing import bucket_size
from repro.core.graph import JointGraph, skeleton_cache_key
from repro.serve.estimator import CostEstimator
from repro.serve.policy import DispatchPolicy

# distinguishes "argument not passed" (fall back to the policy) from an
# explicit None, which several knobs accept with meaning (e.g.
# cross_query_row_limit=None -> always merge)
_UNSET = object()


class ServiceOverloadError(RuntimeError):
    """A submit hit the bounded queue (``max_queue_depth``) with
    ``overflow="reject"``: the request was *not* enqueued.  Callers shed load
    (drop, retry with backoff, or degrade) instead of growing tail latency."""


@dataclass
class ServiceStats:
    """Worker-side counters (mutated under the service lock).

    ``n_drained`` is the sum of all drain sizes, so ``n_drained ==
    n_requests`` exactly when every submitted request has been popped by the
    worker (the service-parity property tests pin this).  ``queue_wait_s`` /
    ``max_queue_wait_s`` measure time between submit and drain pop —
    time-in-queue, the component of request latency that backpressure and
    double-buffering exist to bound.
    """

    n_requests: int = 0
    n_batches: int = 0  # worker wake-ups that executed work
    n_forwards: int = 0  # estimator calls issued (one per group chunk)
    n_coalesced: int = 0  # requests that shared a forward with another
    n_cross_query: int = 0  # score requests answered via a merged cross-query batch
    n_drained: int = 0  # requests popped into drains (== sum of drain sizes)
    n_rejected: int = 0  # submits refused by admission control (never enqueued)
    max_queue_depth: int = 0  # peak queued requests observed at submit
    max_drain: int = 0  # largest single drain
    queue_wait_s: float = 0.0  # total submit -> drain-pop time across requests
    max_queue_wait_s: float = 0.0  # worst single request's time in queue

    def reset(self) -> None:
        self.n_requests = self.n_batches = 0
        self.n_forwards = self.n_coalesced = self.n_cross_query = 0
        self.n_drained = self.n_rejected = 0
        self.max_queue_depth = self.max_drain = 0
        self.queue_wait_s = self.max_queue_wait_s = 0.0


class _Request(NamedTuple):
    kind: str  # "score" | "estimate"
    key: Tuple  # coalescing key: equal keys share one forward
    payload: Tuple
    future: Future
    t_submit: float  # monotonic enqueue time (time-in-queue tracking)


class _LaunchedGroup(NamedTuple):
    """One coalescing group whose device work is dispatched but not resolved.

    ``finalize`` blocks on the device values and returns ``(answers,
    n_forwards, n_cross)`` — the per-request answers (values or exceptions)
    plus the work counters recorded at launch."""

    reqs: List[_Request]
    finalize: Callable[[], Tuple[List[object], int, int]]


class PlacementService:
    """Coalesces concurrent estimate/score requests into fused forwards.

    ``max_batch`` bounds the candidate rows (score) / graphs (estimate) per
    fused forward — a group beyond it is scored in chunks.  ``cross_query``
    (default True) lets score requests for *different* query structures share
    one merged forward (``CostEstimator.score_many``); False restores the
    one-forward-per-structure drain.  Merging trades one dispatch for span-
    conservative stage work, so it pays exactly when drains are
    dispatch-bound: a drain averaging more than ``cross_query_row_limit``
    candidate rows per structure has enough work per structure to amortize
    its own specialized forward and takes the per-structure path instead
    (None: always merge).  Merged traces are additionally keyed on the
    drain's structure *mix*, so the service merges only mixes registered by
    ``warm()`` plus at most ``max_merged_mixes`` first-seen runtime mixes
    (None: unbounded) — everything else takes the per-structure path, keeping
    the compile cache bounded under open-loop arrivals.

    ``max_queue_depth`` bounds the submit queue: past it, ``submit_*``
    raises ``ServiceOverloadError`` (``overflow="reject"``, the default) or
    blocks the producer until the worker drains (``overflow="block"``).
    ``double_buffer`` overlaps drain N+1's host featurization with drain N's
    device compute; the default (``None``) enables it only on accelerator
    backends — on CPU host and "device" share cores, so the launch/finalize
    split buys no overlap and only fragments bursts into smaller drains.  ``warmup`` is an optional sequence of
    ``(query, cluster)`` structures pre-compiled by ``start()`` (see
    ``warm()``), so p99 never pays first-request jit compilation.

    ``auto_start`` False leaves the worker stopped so tests (and one-shot
    batch jobs) can enqueue everything first and then ``start()`` for one
    deterministic drain.  Use as a context manager or call ``close()`` to
    stop the worker; close drains (or fails — never silently drops) every
    accepted request.

    Every dispatch default (``max_batch``, ``cross_query_row_limit``,
    ``double_buffer``, ``warmup_cands``, ``max_merged_mixes``) comes from the
    service's ``DispatchPolicy`` — ``policy=`` if given, else the estimator's
    resolved policy (host profile / ``REPRO_DISPATCH_PROFILE`` / defaults;
    see serve/policy.py).  An explicit constructor argument always wins over
    the policy, including explicit ``None`` where that is meaningful
    (``cross_query_row_limit=None`` means *always merge*).
    """

    def __init__(
        self,
        estimator: CostEstimator,
        max_batch: Optional[int] = None,
        auto_start: bool = True,
        cross_query: bool = True,
        cross_query_row_limit=_UNSET,
        max_queue_depth: Optional[int] = None,
        overflow: str = "reject",
        double_buffer=_UNSET,
        warmup: Optional[Sequence[Tuple]] = None,
        warmup_cands: Optional[int] = None,
        max_merged_mixes=_UNSET,
        policy: Optional[DispatchPolicy] = None,
    ):
        if overflow not in ("reject", "block"):
            raise ValueError(f"overflow must be 'reject' or 'block', got {overflow!r}")
        self.estimator = estimator
        self.policy = (policy if policy is not None else estimator.policy).validate()
        self.max_batch = int(max_batch if max_batch is not None else self.policy.max_batch)
        self.cross_query = bool(cross_query)
        self.cross_query_row_limit = (
            self.policy.cross_query_row_limit
            if cross_query_row_limit is _UNSET
            else cross_query_row_limit
        )
        self.max_queue_depth = max_queue_depth
        self.overflow = overflow
        if double_buffer is _UNSET or double_buffer is None:
            # launch-ahead only pays where device compute runs beside the
            # host; on CPU they share cores, so the split just fragments
            # drains (an extra dispatch per burst, measured in serve_bench);
            # the policy's tri-state None applies the same backend-auto rule
            double_buffer = self.policy.resolved_double_buffer()
        self.double_buffer = bool(double_buffer)
        self.warmup_cands = int(
            warmup_cands if warmup_cands is not None else self.policy.warmup_cands
        )
        self.max_merged_mixes = (
            self.policy.max_merged_mixes if max_merged_mixes is _UNSET else max_merged_mixes
        )
        self.stats = ServiceStats()
        self._warmup = list(warmup) if warmup else []
        self._warmed = False
        # structure mixes allowed on the merged path: warmed mixes plus up to
        # max_merged_mixes first-seen runtime mixes (insertion-ordered set)
        self._known_mixes: "OrderedDict[frozenset, bool]" = OrderedDict()
        self._n_runtime_mixes = 0
        self._queue: "deque[_Request]" = deque()
        self._cond = threading.Condition()
        self._stopped = False
        self._thread: Optional[threading.Thread] = None
        if auto_start:
            self.start()

    # -- lifecycle ----------------------------------------------------------------

    def start(self) -> "PlacementService":
        with self._cond:
            if self._stopped:  # not assert: a submit after close() must fail
                raise RuntimeError("PlacementService is closed")
            starting = self._thread is None
        if starting and self._warmup and not self._warmed:
            # outside the lock: warmup compiles for seconds, submits must not
            # block on it (they queue; the worker starts only after warm)
            self.warm(self._warmup, max_cands=self.warmup_cands)
        with self._cond:
            if self._stopped:
                raise RuntimeError("PlacementService is closed")
            if self._thread is None:
                self._thread = threading.Thread(
                    target=self._run, name="placement-service", daemon=True
                )
                self._thread.start()
        return self

    def close(self) -> None:
        """Stop the worker after draining everything already queued.

        Every accepted request resolves: queued futures on a never-started
        service fail with ``RuntimeError`` instead of leaving their waiters
        hanging, and if the worker thread died, requests it left behind are
        failed here rather than silently dropped."""
        with self._cond:
            self._stopped = True
            orphans = list(self._queue) if self._thread is None else []
            if orphans:
                self._queue.clear()
            self._cond.notify_all()
        for r in orphans:
            r.future.set_exception(RuntimeError("PlacementService closed before start"))
        if self._thread is not None:
            self._thread.join()
            self._thread = None
            # a healthy worker exits only once the queue is empty; anything
            # left means it died mid-run — fail, never strand, the waiters
            with self._cond:
                leftovers = list(self._queue)
                self._queue.clear()
            for r in leftovers:
                if not r.future.done():
                    r.future.set_exception(
                        RuntimeError("PlacementService worker died before serving this request")
                    )

    def __enter__(self) -> "PlacementService":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close()

    # -- warmup -------------------------------------------------------------------

    def warm(
        self,
        structures: Sequence[Tuple],
        max_cands: Optional[int] = None,
        metrics: Optional[Sequence[str]] = None,
    ) -> int:
        """Pre-compile the bounded set of serving traces for ``structures``.

        For each ``(query, cluster)`` pair, runs the placement-specialized
        scorer at every power-of-two candidate bucket up to
        ``bucket_size(max_cands)`` — the full set of jit shapes the
        per-structure drain path can hit.  When cross-query merging applies,
        additionally registers the full structure mix in the merged-mix set
        and runs the merged drain at every row bucket up to
        ``bucket_size(len(structures) * max_cands)`` (capped by
        ``max_batch``).  Dummy all-zero assignments are used — compilation is
        keyed on shapes and structure, never on values.  Returns the number
        of warm forwards issued; the count is bounded by ``O(len(structures)
        * log(max_cands))``, never by traffic.
        """
        structures = list(structures)
        metrics = tuple(metrics) if metrics is not None else tuple(self.estimator.models)
        max_cands = self.warmup_cands if max_cands is None else int(max_cands)
        n_forwards = 0
        for q, c in structures:
            a1 = np.zeros((1, q.n_ops()), dtype=np.int64)
            b = 1
            while True:
                self.estimator.score(q, c, np.repeat(a1, b, axis=0), metrics)
                n_forwards += 1
                if b >= min(bucket_size(max_cands), self.max_batch):
                    break
                b *= 2
        if (
            self.cross_query
            and len(structures) > 1
            and self.estimator.supports_cross_query(metrics)
        ):
            mix = frozenset(skeleton_cache_key(q, c) for q, c in structures)
            with self._cond:
                self._known_mixes[mix] = True
            n_structures = len(structures)
            top = min(bucket_size(n_structures * max_cands), self.max_batch)
            b = bucket_size(n_structures)
            while True:
                # exactly b total rows distributed over every structure, so
                # the merged chunk pads to exactly this power-of-two bucket
                base, extra = divmod(b, n_structures)
                items = [
                    (q, c, np.zeros((base + (1 if j < extra else 0), q.n_ops()), dtype=np.int64))
                    for j, (q, c) in enumerate(structures)
                ]
                self.estimator.score_many(items, metrics, max_rows=self.max_batch)
                n_forwards += 1
                if b >= top:
                    break
                b *= 2
        self._warmed = True
        return n_forwards

    def _admit_mix(self, mix: frozenset) -> bool:
        """Whether this drain's structure mix may use the merged path.

        Warmed mixes always pass; unseen runtime mixes are admitted
        first-come up to ``max_merged_mixes`` (each admission buys a new jit
        trace per row bucket, so the bound is what keeps the compile cache —
        and p99 — finite under arbitrary arrival interleavings)."""
        if self.max_merged_mixes is None:
            return True
        with self._cond:
            if mix in self._known_mixes:
                return True
            if self._n_runtime_mixes >= self.max_merged_mixes:
                return False
            self._n_runtime_mixes += 1
            self._known_mixes[mix] = True
            return True

    # -- submission ---------------------------------------------------------------

    def _submit(self, req: _Request) -> Future:
        with self._cond:
            if self._stopped:  # not assert: under -O the future would hang forever
                raise RuntimeError("PlacementService is closed")
            if self.max_queue_depth is not None and len(self._queue) >= self.max_queue_depth:
                if self.overflow == "reject":
                    self.stats.n_rejected += 1
                    raise ServiceOverloadError(
                        f"queue depth {len(self._queue)} at max_queue_depth="
                        f"{self.max_queue_depth}; request rejected"
                    )
                while len(self._queue) >= self.max_queue_depth and not self._stopped:
                    self._cond.wait()
                if self._stopped:
                    raise RuntimeError("PlacementService is closed")
            self._queue.append(req)
            self.stats.n_requests += 1
            if len(self._queue) > self.stats.max_queue_depth:
                self.stats.max_queue_depth = len(self._queue)
            self._cond.notify_all()
        return req.future

    def _resolve_metrics(self, metrics: Optional[Sequence[str]]) -> Tuple[str, ...]:
        return tuple(metrics) if metrics is not None else tuple(self.estimator.models)

    def submit_score(
        self,
        query,
        cluster,
        assignments: np.ndarray,
        metrics: Optional[Sequence[str]] = None,
    ) -> Future:
        """Async ``CostEstimator.score``; resolves to metric -> (N,) scores.

        Raises ``ServiceOverloadError`` (or blocks, per ``overflow``) when
        the bounded queue is full."""
        metrics = self._resolve_metrics(metrics)
        a = np.asarray(assignments, dtype=np.int64)
        skel_key = skeleton_cache_key(query, cluster)
        # cross-query services group on metrics alone — distinct structures
        # merge at drain time; the structure key rides along for sub-routing
        key = ("score", metrics) if self.cross_query else ("score", skel_key, metrics)
        return self._submit(
            _Request(
                "score", key, (query, cluster, a, metrics, skel_key), Future(),
                time.monotonic(),
            )
        )

    def submit_estimate(
        self, graphs: JointGraph, metrics: Optional[Sequence[str]] = None
    ) -> Future:
        """Async ``CostEstimator.estimate`` over a batched ``JointGraph``.

        Raises ``ServiceOverloadError`` (or blocks, per ``overflow``) when
        the bounded queue is full."""
        metrics = self._resolve_metrics(metrics)
        if not isinstance(graphs, JointGraph):
            graphs = self.estimator._as_graphs(graphs)
        if graphs.op_x.ndim == 2:  # single graph: promote to a batch of one
            graphs = jax.tree_util.tree_map(lambda x: np.asarray(x)[None], graphs)
        key = ("estimate", metrics)
        return self._submit(
            _Request("estimate", key, (graphs, metrics), Future(), time.monotonic())
        )

    def score(self, query, cluster, assignments, metrics=None) -> Dict[str, np.ndarray]:
        """Synchronous convenience: submit one score request and wait."""
        return self.submit_score(query, cluster, assignments, metrics).result()

    def estimate(self, graphs, metrics=None) -> Dict[str, np.ndarray]:
        """Synchronous convenience: submit one estimate request and wait."""
        return self.submit_estimate(graphs, metrics).result()

    # -- worker -------------------------------------------------------------------

    def _run(self) -> None:
        # The drain pipeline.  Each iteration pops everything queued, LAUNCHES
        # it (host grouping + featurization + async device dispatch), then
        # finalizes the PREVIOUS drain (block on device values, resolve
        # futures).  While drain N's device work runs, drain N+1's host work
        # proceeds — and when the queue is empty, the pending drain finalizes
        # immediately (the wait guard skips sleeping while work is in flight),
        # so idle-period latency never waits for a successor drain.
        pending: List[_LaunchedGroup] = []
        batch: List[_Request] = []
        launched: List[_LaunchedGroup] = []
        try:
            while True:
                with self._cond:
                    while not self._queue and not self._stopped and not pending:
                        self._cond.wait()
                    batch = list(self._queue)
                    self._queue.clear()
                    stopped = self._stopped
                    if batch:
                        now = time.monotonic()
                        self.stats.n_batches += 1
                        self.stats.n_drained += len(batch)
                        if len(batch) > self.stats.max_drain:
                            self.stats.max_drain = len(batch)
                        for r in batch:
                            wait = now - r.t_submit
                            self.stats.queue_wait_s += wait
                            if wait > self.stats.max_queue_wait_s:
                                self.stats.max_queue_wait_s = wait
                        self._cond.notify_all()  # blocked submitters: depth dropped
                launched = []
                if batch:
                    groups: Dict[Tuple, List[_Request]] = {}  # dicts keep insertion order
                    for req in batch:
                        groups.setdefault(req.key, []).append(req)
                    for reqs in groups.values():
                        launched.append(self._launch_group(reqs))
                for lg in pending:
                    self._finalize_group(lg)
                if self.double_buffer:
                    pending = launched
                else:
                    for lg in launched:
                        self._finalize_group(lg)
                    pending = []
                batch, launched = [], []
                if stopped and not pending:
                    with self._cond:
                        if not self._queue:  # stopped and drained
                            return
        except BaseException as e:  # pragma: no cover - worker skeleton bug
            # group-level failures are delivered per future and never reach
            # here; this is the backstop for a bug in the loop itself: fail
            # everything this worker owes so no accepted request is dropped
            for lg in list(pending) + list(launched):
                for r in lg.reqs:
                    if not r.future.done():
                        r.future.set_exception(e)
            for r in batch:
                if not r.future.done():
                    r.future.set_exception(e)
            with self._cond:
                leftovers = list(self._queue)
                self._queue.clear()
                self._cond.notify_all()
            for r in leftovers:
                if not r.future.done():
                    r.future.set_exception(e)
            raise

    def _launch_group(self, reqs: List[_Request]) -> _LaunchedGroup:
        """Host-side half of one group: featurize + dispatch, don't block."""
        try:
            if reqs[0].kind == "score":
                finalize = self._launch_scores(reqs)
            else:
                finalize = self._launch_estimates(reqs)
        except BaseException as e:  # launch failed: the whole group shares the error
            finalize = (lambda err: lambda: ([err] * len(reqs), 0, 0))(e)
        return _LaunchedGroup(reqs, finalize)

    def _finalize_group(self, lg: _LaunchedGroup) -> None:
        """Device-side half: block on results, record work, resolve futures."""
        try:
            answers, n_forwards, n_cross = lg.finalize()
        except BaseException as e:  # deliver, don't kill the worker
            answers, n_forwards, n_cross = [e] * len(lg.reqs), 0, 0
        # count the work before resolving futures, so a caller woken by
        # result() never observes counters lagging its own answer
        with self._cond:
            self.stats.n_forwards += n_forwards
            self.stats.n_cross_query += n_cross
            if len(lg.reqs) > 1:
                self.stats.n_coalesced += len(lg.reqs)
        # a per-request answer may be an exception (bad request, failed
        # subgroup): metrics-tuple groups span unrelated callers, so one
        # request's failure must never fail its batchmates
        for r, answer in zip(lg.reqs, answers):
            if isinstance(answer, BaseException):
                r.future.set_exception(answer)
            else:
                r.future.set_result(answer)

    def _launch_scores(self, reqs: List[_Request]) -> Callable:
        metrics = reqs[0].payload[3]
        answers: List[object] = [None] * len(reqs)
        # bad requests fail individually, they never poison the drain
        live = []
        for i, r in enumerate(reqs):
            if len(r.payload[2]) == 0:
                answers[i] = ValueError("no candidates to score")
            else:
                live.append(i)
        distinct = {reqs[i].payload[4] for i in live}
        rows_per_structure = (
            sum(len(reqs[i].payload[2]) for i in live) / len(distinct) if live else 0.0
        )
        if (
            self.cross_query
            and len(distinct) > 1
            and (
                self.cross_query_row_limit is None
                or rows_per_structure <= self.cross_query_row_limit
            )
            and self.estimator.supports_cross_query(metrics)
            and self._admit_mix(frozenset(distinct))
        ):
            # the cross-query hot path: merge every structure's placement
            # batch and answer the whole drain with one signature-banded
            # merged forward per max_batch rows
            items = [(reqs[i].payload[0], reqs[i].payload[1], reqs[i].payload[2]) for i in live]
            pending = self.estimator.score_many(
                items,
                metrics,
                max_rows=self.max_batch,
                keys=[reqs[i].payload[4] for i in live],  # computed once at submit
                deferred=True,
            )
            total = sum(len(a) for _, _, a in items)
            n_forwards = -(-total // self.max_batch)
            n_cross = len(live)

            def finalize():
                for i, ans in zip(live, pending.result()):
                    answers[i] = ans
                return answers, n_forwards, n_cross

            return finalize

        # one structure (or merging unsupported / compute-bound / mix not
        # admitted): the placement-specialized per-structure path, candidate
        # matrices concatenated per skeleton; a failing subgroup fails only
        # its own requests
        subgroups: Dict[Tuple, List[int]] = {}
        for i in live:
            subgroups.setdefault(reqs[i].payload[4], []).append(i)
        n_forwards = 0
        launched_subs: List[Tuple[List[int], List[int], Optional[List], Optional[BaseException]]] = []
        for idxs in subgroups.values():
            query, cluster, _, _, _ = reqs[idxs[0]].payload
            mats = [reqs[i].payload[2] for i in idxs]
            sizes = [len(m) for m in mats]
            merged_mat = np.concatenate(mats, axis=0)
            try:
                parts = []
                for s in range(0, len(merged_mat), self.max_batch):
                    parts.append(
                        self.estimator.score(
                            query, cluster, merged_mat[s : s + self.max_batch],
                            metrics, deferred=True,
                        )
                    )
                    n_forwards += 1
                launched_subs.append((idxs, sizes, parts, None))
            except BaseException as e:
                launched_subs.append((idxs, sizes, None, e))

        def finalize():
            for idxs, sizes, parts, err in launched_subs:
                if err is None:
                    try:
                        done = [p.result() for p in parts]
                        joined = {m: np.concatenate([d[m] for d in done]) for m in metrics}
                    except BaseException as e:
                        err = e
                if err is not None:
                    for i in idxs:
                        answers[i] = err
                    continue
                off = 0
                for i, size in zip(idxs, sizes):
                    answers[i] = {m: joined[m][off : off + size] for m in metrics}
                    off += size
            return answers, n_forwards, 0

        return finalize

    def _launch_estimates(self, reqs: List[_Request]) -> Callable:
        metrics = reqs[0].payload[1]
        graphs = [r.payload[0] for r in reqs]
        sizes = [int(np.asarray(g.op_x).shape[0]) for g in graphs]
        total = sum(sizes)
        if total == 0:
            raise ValueError("no graphs to estimate")
        # estimate_many merges along the batch axis, max_batch-chunks, and
        # bucket-pads each chunk: coalescing produces arbitrary merged sizes,
        # which would otherwise each pay a fresh jit trace.  Unmergeable
        # metrics (heterogeneous / ablation configs) chunk per batch instead,
        # so count what was actually issued
        pending = self.estimator.estimate_many(
            graphs, metrics, max_rows=self.max_batch, deferred=True
        )
        if self.estimator.supports_cross_query(metrics):
            n_forwards = -(-total // self.max_batch)
        else:
            n_forwards = sum(-(-n // self.max_batch) for n in sizes if n)
        return lambda: (pending.result(), n_forwards, 0)
