"""``PlacementService``: a micro-batching front-end over a ``CostEstimator``.

The paper deploys COSTREAM by running "parallel instances" to score candidate
placements concurrently (§V); the TPU-native analogue is not N processes but
ONE fused forward whose batch axis carries every concurrent request.  This
service is that serving layer: requests are submitted from any thread and
answered with futures, while a single worker drains everything queued at each
wake-up — adaptive micro-batching, so while one fused forward runs, new
requests pile up and form the next batch — and answers each compatible group
with one bucket-padded stacked forward through the shared estimator:

* ``score`` requests coalesce per metrics tuple — including requests for
  *different* (query, cluster) structures: their placement batches merge
  structure-major into ONE shared batch (``CostEstimator.score_many``)
  answered by a single signature-banded merged forward per ``max_batch``
  chunk.  Merging trades S-1 dispatches for span-conservative stage work, so
  the drain routes adaptively: dispatch-bound drains (at most
  ``cross_query_row_limit`` candidate rows per structure on average) merge,
  compute-bound drains — and single-structure groups — take the
  placement-specialized per-structure path, which wins its dispatch back in
  exact per-query stage-3 work.  ``cross_query=False`` pins the pre-merge
  behavior of one forward per structure (the benchmark baseline).  Scores
  are batchmate-independent (the padding-invariance tests pin this), so
  coalescing is invisible to callers;
* ``estimate`` requests coalesce per metrics tuple: every ``JointGraph``
  shares the same padded layout, so batches concatenate along the batch axis
  (``CostEstimator.estimate_many``).

Throughput economics: each forward pays a fixed dispatch cost that dominates
these small graphs, so B coalesced requests cost ~1 dispatch instead of B —
and a heterogeneous stream of S structures costs ~1 dispatch instead of S.
``benchmarks/serve_bench.py`` gates both wins in CI.
"""

from __future__ import annotations

import threading
from collections import deque
from concurrent.futures import Future
from dataclasses import dataclass
from typing import Dict, List, NamedTuple, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.core.graph import JointGraph, skeleton_cache_key
from repro.serve.estimator import CostEstimator


@dataclass
class ServiceStats:
    """Worker-side counters (mutated under the service lock)."""

    n_requests: int = 0
    n_batches: int = 0  # worker wake-ups that executed work
    n_forwards: int = 0  # estimator calls issued (one per group chunk)
    n_coalesced: int = 0  # requests that shared a forward with another
    n_cross_query: int = 0  # score requests answered via a merged cross-query batch

    def reset(self) -> None:
        self.n_requests = self.n_batches = 0
        self.n_forwards = self.n_coalesced = self.n_cross_query = 0


class _Request(NamedTuple):
    kind: str  # "score" | "estimate"
    key: Tuple  # coalescing key: equal keys share one forward
    payload: Tuple
    future: Future


class PlacementService:
    """Coalesces concurrent estimate/score requests into fused forwards.

    ``max_batch`` bounds the candidate rows (score) / graphs (estimate) per
    fused forward — a group beyond it is scored in chunks.  ``cross_query``
    (default True) lets score requests for *different* query structures share
    one merged forward (``CostEstimator.score_many``); False restores the
    one-forward-per-structure drain.  Merging trades one dispatch for span-
    conservative stage work, so it pays exactly when drains are
    dispatch-bound: a drain averaging more than ``cross_query_row_limit``
    candidate rows per structure has enough work per structure to amortize
    its own specialized forward and takes the per-structure path instead
    (None: always merge).  ``auto_start`` False leaves the worker stopped so
    tests (and one-shot batch jobs) can enqueue everything first and then
    ``start()`` for one deterministic drain.  Use as a context manager or
    call ``close()`` to stop the worker.
    """

    def __init__(
        self,
        estimator: CostEstimator,
        max_batch: int = 1024,
        auto_start: bool = True,
        cross_query: bool = True,
        cross_query_row_limit: Optional[int] = 16,
    ):
        self.estimator = estimator
        self.max_batch = int(max_batch)
        self.cross_query = bool(cross_query)
        self.cross_query_row_limit = cross_query_row_limit
        self.stats = ServiceStats()
        self._queue: "deque[_Request]" = deque()
        self._cond = threading.Condition()
        self._stopped = False
        self._thread: Optional[threading.Thread] = None
        if auto_start:
            self.start()

    # -- lifecycle ----------------------------------------------------------------

    def start(self) -> "PlacementService":
        with self._cond:
            if self._stopped:  # not assert: a submit after close() must fail
                raise RuntimeError("PlacementService is closed")
            if self._thread is None:
                self._thread = threading.Thread(
                    target=self._run, name="placement-service", daemon=True
                )
                self._thread.start()
        return self

    def close(self) -> None:
        """Stop the worker after draining everything already queued.

        Closing a service that was never started fails any queued futures
        instead of leaving their waiters hanging forever."""
        with self._cond:
            self._stopped = True
            orphans = list(self._queue) if self._thread is None else []
            if orphans:
                self._queue.clear()
            self._cond.notify_all()
        for r in orphans:
            r.future.set_exception(RuntimeError("PlacementService closed before start"))
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def __enter__(self) -> "PlacementService":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close()

    # -- submission ---------------------------------------------------------------

    def _submit(self, req: _Request) -> Future:
        with self._cond:
            if self._stopped:  # not assert: under -O the future would hang forever
                raise RuntimeError("PlacementService is closed")
            self._queue.append(req)
            self.stats.n_requests += 1
            self._cond.notify()
        return req.future

    def _resolve_metrics(self, metrics: Optional[Sequence[str]]) -> Tuple[str, ...]:
        return tuple(metrics) if metrics is not None else tuple(self.estimator.models)

    def submit_score(
        self,
        query,
        cluster,
        assignments: np.ndarray,
        metrics: Optional[Sequence[str]] = None,
    ) -> Future:
        """Async ``CostEstimator.score``; resolves to metric -> (N,) scores."""
        metrics = self._resolve_metrics(metrics)
        a = np.asarray(assignments, dtype=np.int64)
        skel_key = skeleton_cache_key(query, cluster)
        # cross-query services group on metrics alone — distinct structures
        # merge at drain time; the structure key rides along for sub-routing
        key = ("score", metrics) if self.cross_query else ("score", skel_key, metrics)
        return self._submit(
            _Request("score", key, (query, cluster, a, metrics, skel_key), Future())
        )

    def submit_estimate(
        self, graphs: JointGraph, metrics: Optional[Sequence[str]] = None
    ) -> Future:
        """Async ``CostEstimator.estimate`` over a batched ``JointGraph``."""
        metrics = self._resolve_metrics(metrics)
        if not isinstance(graphs, JointGraph):
            graphs = self.estimator._as_graphs(graphs)
        if graphs.op_x.ndim == 2:  # single graph: promote to a batch of one
            graphs = jax.tree_util.tree_map(lambda x: np.asarray(x)[None], graphs)
        key = ("estimate", metrics)
        return self._submit(_Request("estimate", key, (graphs, metrics), Future()))

    def score(self, query, cluster, assignments, metrics=None) -> Dict[str, np.ndarray]:
        """Synchronous convenience: submit one score request and wait."""
        return self.submit_score(query, cluster, assignments, metrics).result()

    def estimate(self, graphs, metrics=None) -> Dict[str, np.ndarray]:
        """Synchronous convenience: submit one estimate request and wait."""
        return self.submit_estimate(graphs, metrics).result()

    # -- worker -------------------------------------------------------------------

    def _run(self) -> None:
        while True:
            with self._cond:
                while not self._queue and not self._stopped:
                    self._cond.wait()
                if not self._queue:  # stopped and drained
                    return
                batch = list(self._queue)
                self._queue.clear()
                self.stats.n_batches += 1
            groups: Dict[Tuple, List[_Request]] = {}  # dicts preserve insertion order
            for req in batch:
                groups.setdefault(req.key, []).append(req)
            for reqs in groups.values():
                try:
                    self._execute_group(reqs)
                except BaseException as e:  # deliver, don't kill the worker
                    for r in reqs:
                        if not r.future.done():
                            r.future.set_exception(e)

    def _execute_group(self, reqs: List[_Request]) -> None:
        if reqs[0].kind == "score":
            per_request, n_forwards, n_cross = self._execute_scores(reqs)
        else:
            per_request, n_forwards, n_cross = self._execute_estimates(reqs)
        # count the work before resolving futures, so a caller woken by
        # result() never observes counters lagging its own answer
        with self._cond:
            self.stats.n_forwards += n_forwards
            self.stats.n_cross_query += n_cross
            if len(reqs) > 1:
                self.stats.n_coalesced += len(reqs)
        # a per-request answer may be an exception (bad request, failed
        # subgroup): metrics-tuple groups span unrelated callers, so one
        # request's failure must never fail its batchmates
        for r, answer in zip(reqs, per_request):
            if isinstance(answer, BaseException):
                r.future.set_exception(answer)
            else:
                r.future.set_result(answer)

    def _execute_scores(self, reqs: List[_Request]):
        metrics = reqs[0].payload[3]
        answers: List[object] = [None] * len(reqs)
        # bad requests fail individually, they never poison the drain
        live = []
        for i, r in enumerate(reqs):
            if len(r.payload[2]) == 0:
                answers[i] = ValueError("no candidates to score")
            else:
                live.append(i)
        distinct = {reqs[i].payload[4] for i in live}
        rows_per_structure = (
            sum(len(reqs[i].payload[2]) for i in live) / len(distinct) if live else 0.0
        )
        n_forwards = n_cross = 0
        if (
            self.cross_query
            and len(distinct) > 1
            and (
                self.cross_query_row_limit is None
                or rows_per_structure <= self.cross_query_row_limit
            )
            and self.estimator.supports_cross_query(metrics)
        ):
            # the cross-query hot path: merge every structure's placement
            # batch and answer the whole drain with one signature-banded
            # merged forward per max_batch rows
            items = [(reqs[i].payload[0], reqs[i].payload[1], reqs[i].payload[2]) for i in live]
            merged = self.estimator.score_many(
                items,
                metrics,
                max_rows=self.max_batch,
                keys=[reqs[i].payload[4] for i in live],  # computed once at submit
            )
            for i, ans in zip(live, merged):
                answers[i] = ans
            total = sum(len(a) for _, _, a in items)
            n_forwards = -(-total // self.max_batch)
            n_cross = len(live)
        else:
            # one structure (or merging unsupported / compute-bound): the
            # placement-specialized per-structure path, candidate matrices
            # concatenated per skeleton; a failing subgroup fails only its
            # own requests
            subgroups: Dict[Tuple, List[int]] = {}
            for i in live:
                subgroups.setdefault(reqs[i].payload[4], []).append(i)
            for idxs in subgroups.values():
                query, cluster, _, _, _ = reqs[idxs[0]].payload
                mats = [reqs[i].payload[2] for i in idxs]
                sizes = [len(m) for m in mats]
                merged_mat = np.concatenate(mats, axis=0)
                try:
                    parts = []
                    for s in range(0, len(merged_mat), self.max_batch):
                        parts.append(
                            self.estimator.score(
                                query, cluster, merged_mat[s : s + self.max_batch], metrics
                            )
                        )
                        n_forwards += 1
                    joined = {m: np.concatenate([p[m] for p in parts]) for m in metrics}
                except BaseException as e:
                    for i in idxs:
                        answers[i] = e
                    continue
                off = 0
                for i, size in zip(idxs, sizes):
                    answers[i] = {m: joined[m][off : off + size] for m in metrics}
                    off += size
        return answers, n_forwards, n_cross

    def _execute_estimates(self, reqs: List[_Request]):
        metrics = reqs[0].payload[1]
        graphs = [r.payload[0] for r in reqs]
        sizes = [int(np.asarray(g.op_x).shape[0]) for g in graphs]
        total = sum(sizes)
        if total == 0:
            raise ValueError("no graphs to estimate")
        # estimate_many merges along the batch axis, max_batch-chunks, and
        # bucket-pads each chunk: coalescing produces arbitrary merged sizes,
        # which would otherwise each pay a fresh jit trace.  Unmergeable
        # metrics (heterogeneous / ablation configs) chunk per batch instead,
        # so count what was actually issued
        answers = self.estimator.estimate_many(graphs, metrics, max_rows=self.max_batch)
        if self.estimator.supports_cross_query(metrics):
            n_forwards = -(-total // self.max_batch)
        else:
            n_forwards = sum(-(-n // self.max_batch) for n in sizes if n)
        return answers, n_forwards, 0
