"""``PlacementService``: a micro-batching front-end over a ``CostEstimator``.

The paper deploys COSTREAM by running "parallel instances" to score candidate
placements concurrently (§V); the TPU-native analogue is not N processes but
ONE fused forward whose batch axis carries every concurrent request.  This
service is that serving layer: requests are submitted from any thread and
answered with futures, while a single worker drains everything queued at each
wake-up — adaptive micro-batching, so while one fused forward runs, new
requests pile up and form the next batch — and answers each compatible group
with one bucket-padded stacked forward through the shared estimator:

* ``score`` requests coalesce when they target the same (query structure,
  cluster, metrics): their assignment matrices are concatenated along the
  candidate axis, scored once, and split back per request.  Scores are
  batchmate-independent (the padding-invariance tests pin this), so
  coalescing is invisible to callers;
* ``estimate`` requests coalesce per metrics tuple: every ``JointGraph``
  shares the same padded layout, so batches concatenate along the batch axis.

Throughput economics: each forward pays a fixed dispatch cost that dominates
these small graphs, so B coalesced requests cost ~1 dispatch instead of B —
``benchmarks/serve_bench.py`` gates the resulting requests/s win in CI.
"""

from __future__ import annotations

import threading
from collections import deque
from concurrent.futures import Future
from dataclasses import dataclass
from typing import Dict, List, NamedTuple, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.core.bucketing import bucket_size, pad_batch
from repro.core.graph import JointGraph, skeleton_cache_key
from repro.serve.estimator import CostEstimator


@dataclass
class ServiceStats:
    """Worker-side counters (mutated under the service lock)."""

    n_requests: int = 0
    n_batches: int = 0  # worker wake-ups that executed work
    n_forwards: int = 0  # estimator calls issued (one per group chunk)
    n_coalesced: int = 0  # requests that shared a forward with another

    def reset(self) -> None:
        self.n_requests = self.n_batches = self.n_forwards = self.n_coalesced = 0


class _Request(NamedTuple):
    kind: str  # "score" | "estimate"
    key: Tuple  # coalescing key: equal keys share one forward
    payload: Tuple
    future: Future


class PlacementService:
    """Coalesces concurrent estimate/score requests into fused forwards.

    ``max_batch`` bounds the candidate rows (score) / graphs (estimate) per
    fused forward — a group beyond it is scored in chunks.  ``auto_start``
    False leaves the worker stopped so tests (and one-shot batch jobs) can
    enqueue everything first and then ``start()`` for one deterministic
    drain.  Use as a context manager or call ``close()`` to stop the worker.
    """

    def __init__(
        self,
        estimator: CostEstimator,
        max_batch: int = 1024,
        auto_start: bool = True,
    ):
        self.estimator = estimator
        self.max_batch = int(max_batch)
        self.stats = ServiceStats()
        self._queue: "deque[_Request]" = deque()
        self._cond = threading.Condition()
        self._stopped = False
        self._thread: Optional[threading.Thread] = None
        if auto_start:
            self.start()

    # -- lifecycle ----------------------------------------------------------------

    def start(self) -> "PlacementService":
        with self._cond:
            if self._stopped:  # not assert: a submit after close() must fail
                raise RuntimeError("PlacementService is closed")
            if self._thread is None:
                self._thread = threading.Thread(
                    target=self._run, name="placement-service", daemon=True
                )
                self._thread.start()
        return self

    def close(self) -> None:
        """Stop the worker after draining everything already queued.

        Closing a service that was never started fails any queued futures
        instead of leaving their waiters hanging forever."""
        with self._cond:
            self._stopped = True
            orphans = list(self._queue) if self._thread is None else []
            if orphans:
                self._queue.clear()
            self._cond.notify_all()
        for r in orphans:
            r.future.set_exception(RuntimeError("PlacementService closed before start"))
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def __enter__(self) -> "PlacementService":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close()

    # -- submission ---------------------------------------------------------------

    def _submit(self, req: _Request) -> Future:
        with self._cond:
            if self._stopped:  # not assert: under -O the future would hang forever
                raise RuntimeError("PlacementService is closed")
            self._queue.append(req)
            self.stats.n_requests += 1
            self._cond.notify()
        return req.future

    def _resolve_metrics(self, metrics: Optional[Sequence[str]]) -> Tuple[str, ...]:
        return tuple(metrics) if metrics is not None else tuple(self.estimator.models)

    def submit_score(
        self,
        query,
        cluster,
        assignments: np.ndarray,
        metrics: Optional[Sequence[str]] = None,
    ) -> Future:
        """Async ``CostEstimator.score``; resolves to metric -> (N,) scores."""
        metrics = self._resolve_metrics(metrics)
        a = np.asarray(assignments, dtype=np.int64)
        key = ("score", skeleton_cache_key(query, cluster), metrics)
        return self._submit(_Request("score", key, (query, cluster, a, metrics), Future()))

    def submit_estimate(
        self, graphs: JointGraph, metrics: Optional[Sequence[str]] = None
    ) -> Future:
        """Async ``CostEstimator.estimate`` over a batched ``JointGraph``."""
        metrics = self._resolve_metrics(metrics)
        if not isinstance(graphs, JointGraph):
            graphs = self.estimator._as_graphs(graphs)
        if graphs.op_x.ndim == 2:  # single graph: promote to a batch of one
            graphs = jax.tree_util.tree_map(lambda x: np.asarray(x)[None], graphs)
        key = ("estimate", metrics)
        return self._submit(_Request("estimate", key, (graphs, metrics), Future()))

    def score(self, query, cluster, assignments, metrics=None) -> Dict[str, np.ndarray]:
        """Synchronous convenience: submit one score request and wait."""
        return self.submit_score(query, cluster, assignments, metrics).result()

    def estimate(self, graphs, metrics=None) -> Dict[str, np.ndarray]:
        """Synchronous convenience: submit one estimate request and wait."""
        return self.submit_estimate(graphs, metrics).result()

    # -- worker -------------------------------------------------------------------

    def _run(self) -> None:
        while True:
            with self._cond:
                while not self._queue and not self._stopped:
                    self._cond.wait()
                if not self._queue:  # stopped and drained
                    return
                batch = list(self._queue)
                self._queue.clear()
                self.stats.n_batches += 1
            groups: Dict[Tuple, List[_Request]] = {}  # dicts preserve insertion order
            for req in batch:
                groups.setdefault(req.key, []).append(req)
            for reqs in groups.values():
                try:
                    self._execute_group(reqs)
                except BaseException as e:  # deliver, don't kill the worker
                    for r in reqs:
                        if not r.future.done():
                            r.future.set_exception(e)

    def _execute_group(self, reqs: List[_Request]) -> None:
        n_forwards = 0
        if reqs[0].kind == "score":
            query, cluster, _, metrics = reqs[0].payload
            mats = [r.payload[2] for r in reqs]
            sizes = [len(m) for m in mats]
            merged = np.concatenate(mats, axis=0)
            parts = []
            # max(.., 1): an all-empty group still reaches the estimator so
            # callers get its meaningful "no candidates" error back
            for s in range(0, max(len(merged), 1), self.max_batch):
                parts.append(
                    self.estimator.score(query, cluster, merged[s : s + self.max_batch], metrics)
                )
                n_forwards += 1
            answers = {m: np.concatenate([p[m] for p in parts]) for m in metrics}
        else:
            metrics = reqs[0].payload[1]
            graphs = [r.payload[0] for r in reqs]
            sizes = [int(np.asarray(g.op_x).shape[0]) for g in graphs]
            merged = jax.tree_util.tree_map(
                lambda *xs: np.concatenate([np.asarray(x) for x in xs], axis=0), *graphs
            )
            total = sum(sizes)
            if total == 0:
                raise ValueError("no graphs to estimate")
            parts = []
            # max_batch-chunk like the score path, and bucket-pad each chunk:
            # coalescing produces arbitrary merged sizes, which would
            # otherwise each pay a fresh jit trace
            for s in range(0, total, self.max_batch):
                chunk = jax.tree_util.tree_map(lambda x: x[s : s + self.max_batch], merged)
                n = int(chunk.op_x.shape[0])
                out = self.estimator.estimate(pad_batch(chunk, bucket_size(n)), metrics)
                parts.append({m: v[:n] for m, v in out.items()})
                n_forwards += 1
            answers = {m: np.concatenate([p[m] for p in parts]) for m in metrics}
        # count the work before resolving futures, so a caller woken by
        # result() never observes counters lagging its own answer
        with self._cond:
            self.stats.n_forwards += n_forwards
            if len(reqs) > 1:
                self.stats.n_coalesced += len(reqs)
        off = 0
        for r, size in zip(reqs, sizes):
            r.future.set_result({m: answers[m][off : off + size] for m in metrics})
            off += size
