"""``PlacementService``: a micro-batching front-end over a ``CostEstimator``.

The paper deploys COSTREAM by running "parallel instances" to score candidate
placements concurrently (§V); the TPU-native analogue is not N processes but
ONE fused forward whose batch axis carries every concurrent request.  This
service is that serving layer: requests are submitted from any thread and
answered with futures, while a single worker drains everything queued at each
wake-up — adaptive micro-batching, so while one fused forward runs, new
requests pile up and form the next batch — and answers each compatible group
with one bucket-padded stacked forward through the shared estimator:

* ``score`` requests coalesce per metrics tuple — including requests for
  *different* (query, cluster) structures: their placement batches merge
  structure-major into ONE shared batch (``CostEstimator.score_many``)
  answered by a single signature-banded merged forward per ``max_batch``
  chunk.  Merging trades S-1 dispatches for span-conservative stage work, so
  the drain routes adaptively: dispatch-bound drains (at most
  ``cross_query_row_limit`` candidate rows per structure on average) merge,
  compute-bound drains — and single-structure groups — take the
  placement-specialized per-structure path, which wins its dispatch back in
  exact per-query stage-3 work.  ``cross_query=False`` pins the pre-merge
  behavior of one forward per structure (the benchmark baseline).  Scores
  are batchmate-independent (the padding-invariance tests pin this), so
  coalescing is invisible to callers;
* ``estimate`` requests coalesce per metrics tuple: every ``JointGraph``
  shares the same padded layout, so batches concatenate along the batch axis
  (``CostEstimator.estimate_many``).

Throughput economics: each forward pays a fixed dispatch cost that dominates
these small graphs, so B coalesced requests cost ~1 dispatch instead of B —
and a heterogeneous stream of S structures costs ~1 dispatch instead of S.
``benchmarks/serve_bench.py`` gates both wins in CI.

Latency engineering (docs/load_harness.md measures all three):

* **double-buffered drains** (``double_buffer``, default on for accelerator
  backends): every drain
  is split into a *launch* half (host-side grouping + featurization + device
  dispatch, via the estimator's ``deferred=True`` calls) and a *finalize*
  half (block on device values, vote, resolve futures).  The worker launches
  drain N+1 before finalizing drain N, so host featurization overlaps device
  compute and the steady-state drain cycle tracks ``max(host, device)``
  instead of their sum;
* **bounded-queue admission control** (``max_queue_depth``): past the bound,
  ``submit_*`` raises ``ServiceOverloadError`` (``overflow="reject"``) or
  blocks the producer (``overflow="block"``) instead of queueing unbounded
  work — under sustained overload, latency is shed at the door rather than
  grown in the queue;
* **warmed compile caches** (``warmup=[(query, cluster), ...]``): ``start()``
  pre-runs every bucket-padded forward shape the structure set can hit, so
  first-request jit compilation never lands in a caller's latency.  Merged
  cross-query traces are keyed on the drain's *structure mix*, an unbounded
  space under open-loop arrivals — so the service only merges mixes that are
  warmed or within ``max_merged_mixes`` first-seen runtime admissions, and
  routes every other drain down the (warm) per-structure path.
"""

from __future__ import annotations

import threading
import time
from collections import OrderedDict, deque
from concurrent.futures import Future
from dataclasses import dataclass
from typing import Callable, Dict, List, NamedTuple, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.core.bucketing import bucket_size
from repro.core.graph import JointGraph, skeleton_cache_key
from repro.serve.estimator import CostEstimator, NonFiniteEstimate
from repro.serve.lifecycle import CircuitBreaker, fallback_scores
from repro.serve.policy import DispatchPolicy

# distinguishes "argument not passed" (fall back to the policy) from an
# explicit None, which several knobs accept with meaning (e.g.
# cross_query_row_limit=None -> always merge)
_UNSET = object()


class ServiceOverloadError(RuntimeError):
    """A submit hit the bounded queue (``max_queue_depth``) with
    ``overflow="reject"``: the request was *not* enqueued.  Callers shed load
    (drop, retry with backoff, or degrade) instead of growing tail latency."""


class EstimateTimeoutError(TimeoutError):
    """A request's ``deadline_s`` expired before its drain finalized.

    Enforced at drain-finalize: the answer (even a computed one) is replaced
    by this error, because a placement decision made on a stale cost estimate
    is worse than an honest timeout the caller can fall back from.  Counted
    in ``ServiceStats.n_timeouts`` and fed to the circuit breaker (a
    browning-out estimator times out before it fails)."""


class _Degraded(dict):
    """A score answer computed by the heuristic fallback scorer, not the
    model.  A plain mapping to callers (same metric -> array shape), plus a
    ``degraded`` marker and the estimator failure that caused it (None when
    the breaker was already open and the estimator was never tried)."""

    degraded = True

    def __init__(self, values: Dict, cause: Optional[BaseException] = None):
        super().__init__(values)
        self.cause = cause


@dataclass
class ServiceStats:
    """Worker-side counters (mutated under the service lock).

    ``n_drained`` is the sum of all drain sizes, so ``n_drained ==
    n_requests`` exactly when every submitted request has been popped by the
    worker (the service-parity property tests pin this).  ``queue_wait_s`` /
    ``max_queue_wait_s`` measure time between submit and drain pop —
    time-in-queue, the component of request latency that backpressure and
    double-buffering exist to bound.
    """

    n_requests: int = 0
    n_batches: int = 0  # worker wake-ups that executed work
    n_forwards: int = 0  # estimator calls issued (one per group chunk)
    n_coalesced: int = 0  # requests that shared a forward with another
    n_cross_query: int = 0  # score requests answered via a merged cross-query batch
    n_drained: int = 0  # requests popped into drains (== sum of drain sizes)
    n_rejected: int = 0  # submits refused by admission control (never enqueued)
    max_queue_depth: int = 0  # peak queued requests observed at submit
    max_drain: int = 0  # largest single drain
    queue_wait_s: float = 0.0  # total submit -> drain-pop time across requests
    max_queue_wait_s: float = 0.0  # worst single request's time in queue
    # -- robustness counters (docs/robustness.md) --------------------------------
    n_degraded: int = 0  # score answers served by the heuristic fallback scorer
    n_nonfinite: int = 0  # estimator outputs rejected by the NaN/Inf guard
    n_timeouts: int = 0  # answers replaced by EstimateTimeoutError at finalize
    n_retries: int = 0  # estimator re-attempts after a transient failure
    n_failed: int = 0  # requests delivered an exception (excl. bad requests)
    n_swaps: int = 0  # bundle swaps applied (incl. rollbacks)
    degraded: bool = False  # breaker not closed: answers may be fallback-based

    def reset(self) -> None:
        self.n_requests = self.n_batches = 0
        self.n_forwards = self.n_coalesced = self.n_cross_query = 0
        self.n_drained = self.n_rejected = 0
        self.max_queue_depth = self.max_drain = 0
        self.queue_wait_s = self.max_queue_wait_s = 0.0
        self.n_degraded = self.n_nonfinite = self.n_timeouts = 0
        self.n_retries = self.n_failed = self.n_swaps = 0
        self.degraded = False


class _Request(NamedTuple):
    kind: str  # "score" | "estimate"
    key: Tuple  # coalescing key: equal keys share one forward
    payload: Tuple
    future: Future
    t_submit: float  # monotonic enqueue time (time-in-queue tracking)
    deadline_s: Optional[float] = None  # answer-by budget from submit time


class _LaunchedGroup(NamedTuple):
    """One coalescing group whose device work is dispatched but not resolved.

    ``finalize`` blocks on the device values and returns ``(answers,
    n_forwards, n_cross)`` — the per-request answers (values or exceptions)
    plus the work counters recorded at launch."""

    reqs: List[_Request]
    finalize: Callable[[], Tuple[List[object], int, int]]


class PlacementService:
    """Coalesces concurrent estimate/score requests into fused forwards.

    ``max_batch`` bounds the candidate rows (score) / graphs (estimate) per
    fused forward — a group beyond it is scored in chunks.  ``cross_query``
    (default True) lets score requests for *different* query structures share
    one merged forward (``CostEstimator.score_many``); False restores the
    one-forward-per-structure drain.  Merging trades one dispatch for span-
    conservative stage work, so it pays exactly when drains are
    dispatch-bound: a drain averaging more than ``cross_query_row_limit``
    candidate rows per structure has enough work per structure to amortize
    its own specialized forward and takes the per-structure path instead
    (None: always merge).  Merged traces are additionally keyed on the
    drain's structure *mix*, so the service merges only mixes registered by
    ``warm()`` plus at most ``max_merged_mixes`` first-seen runtime mixes
    (None: unbounded) — everything else takes the per-structure path, keeping
    the compile cache bounded under open-loop arrivals.

    ``max_queue_depth`` bounds the submit queue: past it, ``submit_*``
    raises ``ServiceOverloadError`` (``overflow="reject"``, the default) or
    blocks the producer until the worker drains (``overflow="block"``).
    ``double_buffer`` overlaps drain N+1's host featurization with drain N's
    device compute; the default (``None``) enables it only on accelerator
    backends — on CPU host and "device" share cores, so the launch/finalize
    split buys no overlap and only fragments bursts into smaller drains.  ``warmup`` is an optional sequence of
    ``(query, cluster)`` structures pre-compiled by ``start()`` (see
    ``warm()``), so p99 never pays first-request jit compilation.

    ``auto_start`` False leaves the worker stopped so tests (and one-shot
    batch jobs) can enqueue everything first and then ``start()`` for one
    deterministic drain.  Use as a context manager or call ``close()`` to
    stop the worker; close drains (or fails — never silently drops) every
    accepted request.

    Every dispatch default (``max_batch``, ``cross_query_row_limit``,
    ``double_buffer``, ``warmup_cands``, ``max_merged_mixes``) comes from the
    service's ``DispatchPolicy`` — ``policy=`` if given, else the estimator's
    resolved policy (host profile / ``REPRO_DISPATCH_PROFILE`` / defaults;
    see serve/policy.py).  An explicit constructor argument always wins over
    the policy, including explicit ``None`` where that is meaningful
    (``cross_query_row_limit=None`` means *always merge*).
    """

    def __init__(
        self,
        estimator: CostEstimator,
        max_batch: Optional[int] = None,
        auto_start: bool = True,
        cross_query: bool = True,
        cross_query_row_limit=_UNSET,
        max_queue_depth: Optional[int] = None,
        overflow: str = "reject",
        double_buffer=_UNSET,
        warmup: Optional[Sequence[Tuple]] = None,
        warmup_cands: Optional[int] = None,
        max_merged_mixes=_UNSET,
        policy: Optional[DispatchPolicy] = None,
        seed: int = 0,
    ):
        if overflow not in ("reject", "block"):
            raise ValueError(f"overflow must be 'reject' or 'block', got {overflow!r}")
        self.estimator = estimator
        self.policy = (policy if policy is not None else estimator.policy).validate()
        self.max_batch = int(max_batch if max_batch is not None else self.policy.max_batch)
        self.cross_query = bool(cross_query)
        self.cross_query_row_limit = (
            self.policy.cross_query_row_limit
            if cross_query_row_limit is _UNSET
            else cross_query_row_limit
        )
        self.max_queue_depth = max_queue_depth
        self.overflow = overflow
        if double_buffer is _UNSET or double_buffer is None:
            # launch-ahead only pays where device compute runs beside the
            # host; on CPU they share cores, so the split just fragments
            # drains (an extra dispatch per burst, measured in serve_bench);
            # the policy's tri-state None applies the same backend-auto rule
            double_buffer = self.policy.resolved_double_buffer()
        self.double_buffer = bool(double_buffer)
        self.warmup_cands = int(
            warmup_cands if warmup_cands is not None else self.policy.warmup_cands
        )
        self.max_merged_mixes = (
            self.policy.max_merged_mixes if max_merged_mixes is _UNSET else max_merged_mixes
        )
        self.stats = ServiceStats()
        self._warmup = list(warmup) if warmup else []
        self._warmed = False
        # structure mixes allowed on the merged path: warmed mixes plus up to
        # max_merged_mixes first-seen runtime mixes (insertion-ordered set)
        self._known_mixes: "OrderedDict[frozenset, bool]" = OrderedDict()
        self._n_runtime_mixes = 0
        self._queue: "deque[_Request]" = deque()
        self._cond = threading.Condition()
        self._stopped = False
        self._thread: Optional[threading.Thread] = None
        # -- robustness plumbing (docs/robustness.md) ----------------------------
        # seeded rng for retry backoff jitter; touched only by the worker
        self._rng = np.random.default_rng(seed)
        self._retry = self.policy.retry_policy()
        self._breaker = CircuitBreaker.from_policy(self.policy)
        # a requested estimator swap awaiting the next drain boundary:
        # (new estimator, future resolving to the replaced estimator)
        self._pending_swap: Optional[Tuple[CostEstimator, Future]] = None
        # observers fire on the worker thread after each finalized group
        # (the BundleSwapper mirror and health window ride this seam)
        self._observers: List[Callable] = []
        if auto_start:
            self.start()

    # -- lifecycle ----------------------------------------------------------------

    def start(self) -> "PlacementService":
        with self._cond:
            if self._stopped:  # not assert: a submit after close() must fail
                raise RuntimeError("PlacementService is closed")
            starting = self._thread is None
        if starting and self._warmup and not self._warmed:
            # outside the lock: warmup compiles for seconds, submits must not
            # block on it (they queue; the worker starts only after warm)
            self.warm(self._warmup, max_cands=self.warmup_cands)
        with self._cond:
            if self._stopped:
                raise RuntimeError("PlacementService is closed")
            if self._thread is None:
                self._thread = threading.Thread(
                    target=self._run, name="placement-service", daemon=True
                )
                self._thread.start()
        return self

    def close(self) -> None:
        """Stop the worker after draining everything already queued.

        Every accepted request resolves: queued futures on a never-started
        service fail with ``RuntimeError`` instead of leaving their waiters
        hanging, and if the worker thread died, requests it left behind are
        failed here rather than silently dropped."""
        with self._cond:
            self._stopped = True
            orphans = list(self._queue) if self._thread is None else []
            if orphans:
                self._queue.clear()
            self._cond.notify_all()
        for r in orphans:
            r.future.set_exception(RuntimeError("PlacementService closed before start"))
        if self._thread is not None:
            self._thread.join()
            self._thread = None
            # a healthy worker exits only once the queue is empty; anything
            # left means it died mid-run — fail, never strand, the waiters
            with self._cond:
                leftovers = list(self._queue)
                self._queue.clear()
            for r in leftovers:
                if not r.future.done():
                    r.future.set_exception(
                        RuntimeError("PlacementService worker died before serving this request")
                    )
        # a swap the worker never applied resolves with an error — the
        # requester must not hang on a future nobody will fulfill
        with self._cond:
            swap, self._pending_swap = self._pending_swap, None
        if swap is not None and not swap[1].done():
            swap[1].set_exception(RuntimeError("PlacementService closed before the swap applied"))

    def __enter__(self) -> "PlacementService":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close()

    # -- bundle hot-swap + observation (docs/robustness.md) -----------------------

    @property
    def breaker(self) -> CircuitBreaker:
        """The service's circuit breaker (read ``.state`` for health checks)."""
        return self._breaker

    def swap_bundle(self, candidate, wait: bool = True, timeout: Optional[float] = None):
        """Atomically replace the serving estimator at the next drain boundary.

        ``candidate`` is a ``CostEstimator`` or a ``CostModelBundle`` (wrapped
        with this service's policy).  The swap quiesces between drains: groups
        already launched hold the old estimator in their finalize closures and
        finish on it; everything popped after the boundary routes to the new
        one; the old estimator's instance caches are released when its last
        in-flight group resolves.  Warm merged-mix admissions survive the swap
        (they key on structures, not weights), and same-architecture swaps
        reuse the module-level jit trace caches — a hot-swap costs zero
        recompiles.

        ``wait=True`` blocks until the boundary and returns the *replaced*
        estimator (rollback keeps it alive); ``wait=False`` returns a
        ``Future`` resolving to it — required when calling from a worker-side
        observer (the rollback path), where blocking would deadlock the very
        thread that applies swaps.  On a service whose worker is not running,
        the swap applies immediately.  Raises ``RuntimeError`` on a closed
        service or when another swap is still pending.
        """
        est = (
            candidate
            if isinstance(candidate, CostEstimator)
            else CostEstimator.from_bundle(candidate, policy=self.policy)
        )
        fut: Future = Future()
        with self._cond:
            if self._stopped:
                raise RuntimeError("PlacementService is closed")
            if self._pending_swap is not None:
                raise RuntimeError("a bundle swap is already pending")
            if self._thread is None:
                # no worker: there is no in-flight work to quiesce around
                old, self.estimator = self.estimator, est
                self.stats.n_swaps += 1
                fut.set_result(old)
                return fut.result() if wait else fut
            self._pending_swap = (est, fut)
            self._cond.notify_all()
        return fut.result(timeout) if wait else fut

    def add_observer(self, fn: Callable) -> None:
        """Register ``fn(requests, answers)``, called on the worker thread
        after each drain group's futures resolve (answers may be exceptions
        or ``degraded``-marked fallback dicts).  Observer errors are
        swallowed — observation must never fail a drain."""
        with self._cond:
            self._observers.append(fn)

    def remove_observer(self, fn: Callable) -> None:
        """Unregister an observer; raises ``ValueError`` if absent."""
        with self._cond:
            self._observers.remove(fn)

    # -- warmup -------------------------------------------------------------------

    def warm(
        self,
        structures: Sequence[Tuple],
        max_cands: Optional[int] = None,
        metrics: Optional[Sequence[str]] = None,
    ) -> int:
        """Pre-compile the bounded set of serving traces for ``structures``.

        For each ``(query, cluster)`` pair, runs the placement-specialized
        scorer at every power-of-two candidate bucket up to
        ``bucket_size(max_cands)`` — the full set of jit shapes the
        per-structure drain path can hit.  When cross-query merging applies,
        additionally registers the full structure mix in the merged-mix set
        and runs the merged drain at every row bucket up to
        ``bucket_size(len(structures) * max_cands)`` (capped by
        ``max_batch``).  Dummy all-zero assignments are used — compilation is
        keyed on shapes and structure, never on values.  Returns the number
        of warm forwards issued; the count is bounded by ``O(len(structures)
        * log(max_cands))``, never by traffic.
        """
        structures = list(structures)
        metrics = tuple(metrics) if metrics is not None else tuple(self.estimator.models)
        max_cands = self.warmup_cands if max_cands is None else int(max_cands)
        n_forwards = 0
        for q, c in structures:
            a1 = np.zeros((1, q.n_ops()), dtype=np.int64)
            b = 1
            while True:
                self.estimator.score(q, c, np.repeat(a1, b, axis=0), metrics)
                n_forwards += 1
                if b >= min(bucket_size(max_cands), self.max_batch):
                    break
                b *= 2
        if (
            self.cross_query
            and len(structures) > 1
            and self.estimator.supports_cross_query(metrics)
        ):
            mix = frozenset(skeleton_cache_key(q, c) for q, c in structures)
            with self._cond:
                self._known_mixes[mix] = True
            n_structures = len(structures)
            top = min(bucket_size(n_structures * max_cands), self.max_batch)
            b = bucket_size(n_structures)
            while True:
                # exactly b total rows distributed over every structure, so
                # the merged chunk pads to exactly this power-of-two bucket
                base, extra = divmod(b, n_structures)
                items = [
                    (q, c, np.zeros((base + (1 if j < extra else 0), q.n_ops()), dtype=np.int64))
                    for j, (q, c) in enumerate(structures)
                ]
                self.estimator.score_many(items, metrics, max_rows=self.max_batch)
                n_forwards += 1
                if b >= top:
                    break
                b *= 2
        self._warmed = True
        return n_forwards

    def _admit_mix(self, mix: frozenset) -> bool:
        """Whether this drain's structure mix may use the merged path.

        Warmed mixes always pass; unseen runtime mixes are admitted
        first-come up to ``max_merged_mixes`` (each admission buys a new jit
        trace per row bucket, so the bound is what keeps the compile cache —
        and p99 — finite under arbitrary arrival interleavings)."""
        if self.max_merged_mixes is None:
            return True
        with self._cond:
            if mix in self._known_mixes:
                return True
            if self._n_runtime_mixes >= self.max_merged_mixes:
                return False
            self._n_runtime_mixes += 1
            self._known_mixes[mix] = True
            return True

    # -- submission ---------------------------------------------------------------

    def _submit(self, req: _Request) -> Future:
        with self._cond:
            if self._stopped:  # not assert: under -O the future would hang forever
                raise RuntimeError("PlacementService is closed")
            if self.max_queue_depth is not None and len(self._queue) >= self.max_queue_depth:
                if self.overflow == "reject":
                    self.stats.n_rejected += 1
                    raise ServiceOverloadError(
                        f"queue depth {len(self._queue)} at max_queue_depth="
                        f"{self.max_queue_depth}; request rejected"
                    )
                while len(self._queue) >= self.max_queue_depth and not self._stopped:
                    self._cond.wait()
                if self._stopped:
                    raise RuntimeError("PlacementService is closed")
            self._queue.append(req)
            self.stats.n_requests += 1
            if len(self._queue) > self.stats.max_queue_depth:
                self.stats.max_queue_depth = len(self._queue)
            self._cond.notify_all()
        return req.future

    def _resolve_metrics(self, metrics: Optional[Sequence[str]]) -> Tuple[str, ...]:
        return tuple(metrics) if metrics is not None else tuple(self.estimator.models)

    @staticmethod
    def _check_deadline(deadline_s: Optional[float]) -> Optional[float]:
        if deadline_s is None:
            return None
        deadline_s = float(deadline_s)
        if not deadline_s > 0:
            raise ValueError(f"deadline_s must be positive, got {deadline_s}")
        return deadline_s

    def submit_score(
        self,
        query,
        cluster,
        assignments: np.ndarray,
        metrics: Optional[Sequence[str]] = None,
        deadline_s: Optional[float] = None,
    ) -> Future:
        """Async ``CostEstimator.score``; resolves to metric -> (N,) scores.

        Raises ``ServiceOverloadError`` (or blocks, per ``overflow``) when
        the bounded queue is full.  ``deadline_s`` is an answer-by budget
        from submit time, enforced at drain-finalize: a late answer is
        replaced by ``EstimateTimeoutError`` (docs/robustness.md#deadlines)."""
        metrics = self._resolve_metrics(metrics)
        a = np.asarray(assignments, dtype=np.int64)
        skel_key = skeleton_cache_key(query, cluster)
        # cross-query services group on metrics alone — distinct structures
        # merge at drain time; the structure key rides along for sub-routing
        key = ("score", metrics) if self.cross_query else ("score", skel_key, metrics)
        return self._submit(
            _Request(
                "score", key, (query, cluster, a, metrics, skel_key), Future(),
                time.monotonic(), self._check_deadline(deadline_s),
            )
        )

    def submit_estimate(
        self,
        graphs: JointGraph,
        metrics: Optional[Sequence[str]] = None,
        deadline_s: Optional[float] = None,
    ) -> Future:
        """Async ``CostEstimator.estimate`` over a batched ``JointGraph``.

        Raises ``ServiceOverloadError`` (or blocks, per ``overflow``) when
        the bounded queue is full.  ``deadline_s`` as in ``submit_score``."""
        metrics = self._resolve_metrics(metrics)
        if not isinstance(graphs, JointGraph):
            graphs = self.estimator._as_graphs(graphs)
        if graphs.op_x.ndim == 2:  # single graph: promote to a batch of one
            graphs = jax.tree_util.tree_map(lambda x: np.asarray(x)[None], graphs)
        key = ("estimate", metrics)
        return self._submit(
            _Request(
                "estimate", key, (graphs, metrics), Future(), time.monotonic(),
                self._check_deadline(deadline_s),
            )
        )

    def score(self, query, cluster, assignments, metrics=None) -> Dict[str, np.ndarray]:
        """Synchronous convenience: submit one score request and wait."""
        return self.submit_score(query, cluster, assignments, metrics).result()

    def estimate(self, graphs, metrics=None) -> Dict[str, np.ndarray]:
        """Synchronous convenience: submit one estimate request and wait."""
        return self.submit_estimate(graphs, metrics).result()

    # -- worker -------------------------------------------------------------------

    def _run(self) -> None:
        # The drain pipeline.  Each iteration pops everything queued, LAUNCHES
        # it (host grouping + featurization + async device dispatch), then
        # finalizes the PREVIOUS drain (block on device values, resolve
        # futures).  While drain N's device work runs, drain N+1's host work
        # proceeds — and when the queue is empty, the pending drain finalizes
        # immediately (the wait guard skips sleeping while work is in flight),
        # so idle-period latency never waits for a successor drain.
        pending: List[_LaunchedGroup] = []
        batch: List[_Request] = []
        launched: List[_LaunchedGroup] = []
        try:
            while True:
                with self._cond:
                    while (
                        not self._queue
                        and not self._stopped
                        and not pending
                        and self._pending_swap is None
                    ):
                        self._cond.wait()
                    # the drain boundary: an estimator swap applies here —
                    # groups in `pending` hold the OLD estimator in their
                    # finalize closures and finish on it; everything popped
                    # from now on routes to the new one
                    swap, self._pending_swap = self._pending_swap, None
                    old_est = None
                    if swap is not None:
                        old_est, self.estimator = self.estimator, swap[0]
                        self.stats.n_swaps += 1
                    batch = list(self._queue)
                    self._queue.clear()
                    stopped = self._stopped
                    if batch:
                        now = time.monotonic()
                        self.stats.n_batches += 1
                        self.stats.n_drained += len(batch)
                        if len(batch) > self.stats.max_drain:
                            self.stats.max_drain = len(batch)
                        for r in batch:
                            wait = now - r.t_submit
                            self.stats.queue_wait_s += wait
                            if wait > self.stats.max_queue_wait_s:
                                self.stats.max_queue_wait_s = wait
                        self._cond.notify_all()  # blocked submitters: depth dropped
                if swap is not None:
                    # resolve outside the lock: done-callbacks run inline
                    swap[1].set_result(old_est)
                launched = []
                if batch:
                    groups: Dict[Tuple, List[_Request]] = {}  # dicts keep insertion order
                    for req in batch:
                        groups.setdefault(req.key, []).append(req)
                    for reqs in groups.values():
                        launched.append(self._launch_group(reqs))
                for lg in pending:
                    self._finalize_group(lg)
                if self.double_buffer:
                    pending = launched
                else:
                    for lg in launched:
                        self._finalize_group(lg)
                    pending = []
                batch, launched = [], []
                if stopped and not pending:
                    with self._cond:
                        if not self._queue and self._pending_swap is None:
                            return  # stopped and drained
        except BaseException as e:  # pragma: no cover - worker skeleton bug
            # group-level failures are delivered per future and never reach
            # here; this is the backstop for a bug in the loop itself: fail
            # everything this worker owes so no accepted request is dropped
            for lg in list(pending) + list(launched):
                for r in lg.reqs:
                    if not r.future.done():
                        r.future.set_exception(e)
            for r in batch:
                if not r.future.done():
                    r.future.set_exception(e)
            with self._cond:
                leftovers = list(self._queue)
                self._queue.clear()
                swap, self._pending_swap = self._pending_swap, None
                self._cond.notify_all()
            for r in leftovers:
                if not r.future.done():
                    r.future.set_exception(e)
            if swap is not None and not swap[1].done():
                swap[1].set_exception(e)
            raise

    def _launch_group(self, reqs: List[_Request]) -> _LaunchedGroup:
        """Host-side half of one group: featurize + dispatch, don't block."""
        try:
            if reqs[0].kind == "score":
                finalize = self._launch_scores(reqs)
            else:
                finalize = self._launch_estimates(reqs)
        except BaseException as e:  # launch failed: the whole group shares the error
            finalize = (lambda err: lambda: ([err] * len(reqs), 0, 0))(e)
        return _LaunchedGroup(reqs, finalize)

    def _finalize_group(self, lg: _LaunchedGroup) -> None:
        """Device-side half: block on results, record work, resolve futures."""
        try:
            answers, n_forwards, n_cross = lg.finalize()
        except BaseException as e:  # deliver, don't kill the worker
            answers, n_forwards, n_cross = [e] * len(lg.reqs), 0, 0
        answers = list(answers)
        # deadlines are judged where the answer materializes: an estimate
        # that finished after the caller's budget is replaced, not delivered
        now = time.monotonic()
        for j, r in enumerate(lg.reqs):
            if r.deadline_s is not None and (now - r.t_submit) > r.deadline_s:
                answers[j] = EstimateTimeoutError(
                    f"{r.kind} answered in {now - r.t_submit:.3f}s, "
                    f"over its {r.deadline_s:.3f}s deadline"
                )
        # count the work before resolving futures, so a caller woken by
        # result() never observes counters lagging its own answer
        with self._cond:
            self.stats.n_forwards += n_forwards
            self.stats.n_cross_query += n_cross
            if len(lg.reqs) > 1:
                self.stats.n_coalesced += len(lg.reqs)
            for answer in answers:
                if isinstance(answer, _Degraded):
                    self.stats.n_degraded += 1
                    if isinstance(answer.cause, NonFiniteEstimate):
                        self.stats.n_nonfinite += 1
                    if answer.cause is not None:
                        # a real estimator failure behind the fallback; a
                        # causeless _Degraded is the breaker's own
                        # short-circuit and must not re-feed it
                        self._breaker.record_failure()
                elif isinstance(answer, EstimateTimeoutError):
                    self.stats.n_timeouts += 1
                    self._breaker.record_failure()
                elif isinstance(answer, NonFiniteEstimate):
                    self.stats.n_nonfinite += 1
                    self.stats.n_failed += 1
                    self._breaker.record_failure()
                elif isinstance(answer, ValueError):
                    pass  # caller error, says nothing about estimator health
                elif isinstance(answer, BaseException):
                    self.stats.n_failed += 1
                    self._breaker.record_failure()
                else:
                    self._breaker.record_success()
            self.stats.degraded = self._breaker.state != "closed"
        # a per-request answer may be an exception (bad request, failed
        # subgroup): metrics-tuple groups span unrelated callers, so one
        # request's failure must never fail its batchmates
        for r, answer in zip(lg.reqs, answers):
            if isinstance(answer, BaseException):
                r.future.set_exception(answer)
            else:
                r.future.set_result(answer)
        for obs in list(self._observers):
            try:
                obs(lg.reqs, answers)
            except Exception:
                pass  # observers are best-effort, never worker-fatal

    def _launch_scores(self, reqs: List[_Request]) -> Callable:
        metrics = reqs[0].payload[3]
        answers: List[object] = [None] * len(reqs)
        # bad requests fail individually, they never poison the drain
        live = []
        for i, r in enumerate(reqs):
            if len(r.payload[2]) == 0:
                answers[i] = ValueError("no candidates to score")
            else:
                live.append(i)
        if live and not self._breaker.allow():
            # circuit open: serve heuristic-placement fallback scores without
            # touching the estimator at all; answers are tagged degraded so
            # callers (and ServiceStats) can tell

            def finalize():
                for i in live:
                    q, c, a, ms, _ = reqs[i].payload
                    answers[i] = self._degraded_answer(q, c, a, ms, cause=None)
                return answers, 0, 0

            return finalize

        distinct = {reqs[i].payload[4] for i in live}
        rows_per_structure = (
            sum(len(reqs[i].payload[2]) for i in live) / len(distinct) if live else 0.0
        )
        if (
            self.cross_query
            and len(distinct) > 1
            and (
                self.cross_query_row_limit is None
                or rows_per_structure <= self.cross_query_row_limit
            )
            and self.estimator.supports_cross_query(metrics)
            and self._admit_mix(frozenset(distinct))
        ):
            # the cross-query hot path: merge every structure's placement
            # batch and answer the whole drain with one signature-banded
            # merged forward per max_batch rows
            items = [(reqs[i].payload[0], reqs[i].payload[1], reqs[i].payload[2]) for i in live]
            pending = self.estimator.score_many(
                items,
                metrics,
                max_rows=self.max_batch,
                keys=[reqs[i].payload[4] for i in live],  # computed once at submit
                deferred=True,
            )
            total = sum(len(a) for _, _, a in items)
            n_forwards = -(-total // self.max_batch)
            n_cross = len(live)

            est = self.estimator  # finalize must use the estimator that launched

            def finalize():
                try:
                    results = pending.result()
                except BaseException as e:
                    try:
                        results = self._retry_call(
                            lambda: est.score_many(
                                items,
                                metrics,
                                max_rows=self.max_batch,
                                keys=[reqs[i].payload[4] for i in live],
                            ),
                            e,
                        )
                    except BaseException as final:
                        for i in live:
                            q, c, a, ms, _ = reqs[i].payload
                            answers[i] = self._degraded_answer(q, c, a, ms, cause=final)
                        return answers, n_forwards, n_cross
                for i, ans in zip(live, results):
                    answers[i] = ans
                return answers, n_forwards, n_cross

            return finalize

        # one structure (or merging unsupported / compute-bound / mix not
        # admitted): the placement-specialized per-structure path, candidate
        # matrices concatenated per skeleton; a failing subgroup fails only
        # its own requests
        subgroups: Dict[Tuple, List[int]] = {}
        for i in live:
            subgroups.setdefault(reqs[i].payload[4], []).append(i)
        n_forwards = 0
        est = self.estimator  # finalize must use the estimator that launched
        launched_subs: List[Tuple] = []
        for idxs in subgroups.values():
            query, cluster, _, _, _ = reqs[idxs[0]].payload
            mats = [reqs[i].payload[2] for i in idxs]
            sizes = [len(m) for m in mats]
            merged_mat = np.concatenate(mats, axis=0)
            try:
                parts = []
                for s in range(0, len(merged_mat), self.max_batch):
                    parts.append(
                        self.estimator.score(
                            query, cluster, merged_mat[s : s + self.max_batch],
                            metrics, deferred=True,
                        )
                    )
                    n_forwards += 1
                launched_subs.append((idxs, sizes, parts, None, query, cluster, merged_mat))
            except BaseException as e:
                launched_subs.append((idxs, sizes, None, e, query, cluster, merged_mat))

        def retry_sub(query, cluster, merged_mat, first_err):
            def attempt():
                done = []
                for s in range(0, len(merged_mat), self.max_batch):
                    done.append(
                        est.score(query, cluster, merged_mat[s : s + self.max_batch], metrics)
                    )
                return {m: np.concatenate([d[m] for d in done]) for m in metrics}

            return self._retry_call(attempt, first_err)

        def finalize():
            for idxs, sizes, parts, err, query, cluster, merged_mat in launched_subs:
                joined = None
                if err is None:
                    try:
                        done = [p.result() for p in parts]
                        joined = {m: np.concatenate([d[m] for d in done]) for m in metrics}
                    except BaseException as e:
                        err = e
                if joined is None:
                    try:
                        joined = retry_sub(query, cluster, merged_mat, err)
                    except BaseException as final:
                        for i in idxs:
                            q, c, a, ms, _ = reqs[i].payload
                            answers[i] = self._degraded_answer(q, c, a, ms, cause=final)
                        continue
                off = 0
                for i, size in zip(idxs, sizes):
                    answers[i] = {m: joined[m][off : off + size] for m in metrics}
                    off += size
            return answers, n_forwards, 0

        return finalize

    def _launch_estimates(self, reqs: List[_Request]) -> Callable:
        metrics = reqs[0].payload[1]
        graphs = [r.payload[0] for r in reqs]
        sizes = [int(np.asarray(g.op_x).shape[0]) for g in graphs]
        total = sum(sizes)
        if total == 0:
            raise ValueError("no graphs to estimate")
        # estimate_many merges along the batch axis, max_batch-chunks, and
        # bucket-pads each chunk: coalescing produces arbitrary merged sizes,
        # which would otherwise each pay a fresh jit trace.  Unmergeable
        # metrics (heterogeneous / ablation configs) chunk per batch instead,
        # so count what was actually issued
        pending = self.estimator.estimate_many(
            graphs, metrics, max_rows=self.max_batch, deferred=True
        )
        if self.estimator.supports_cross_query(metrics):
            n_forwards = -(-total // self.max_batch)
        else:
            n_forwards = sum(-(-n // self.max_batch) for n in sizes if n)
        est = self.estimator  # finalize must use the estimator that launched

        def finalize():
            try:
                results = pending.result()
            except BaseException as e:
                # estimates have no heuristic fallback: retry transients, then
                # deliver the error to the callers
                results = self._retry_call(
                    lambda: est.estimate_many(graphs, metrics, max_rows=self.max_batch),
                    e,
                )
            return results, n_forwards, 0

        return finalize

    # -- failure handling -------------------------------------------------

    @staticmethod
    def _transient(e: BaseException) -> bool:
        # caller errors and typed verdicts won't change on a second try;
        # everything else (backend hiccups, injected faults) may
        return isinstance(e, Exception) and not isinstance(
            e, (ValueError, NonFiniteEstimate, EstimateTimeoutError, ServiceOverloadError)
        )

    def _retry_call(self, fn: Callable, first_err: BaseException):
        """Re-run ``fn`` under the policy's RetryPolicy after ``first_err``.

        Raises the last error if every attempt fails or the error is not
        transient.  Sleeps are seeded-jittered exponential backoff, so a
        given service seed replays the same schedule.
        """
        if not self._transient(first_err):
            raise first_err
        last = first_err
        for attempt in range(1, self._retry.max_attempts):
            with self._cond:
                self.stats.n_retries += 1
            time.sleep(self._retry.sleep_s(attempt, float(self._rng.random())))
            try:
                return fn()
            except BaseException as e:
                last = e
                if not self._transient(e):
                    raise
        raise last

    def _degraded_answer(self, query, cluster, assignments, metrics, cause):
        """Heuristic-placement fallback scores, tagged ``degraded=True``.

        Used when the breaker is open (``cause=None``) or when the estimator
        failed past its retry budget (``cause`` = the final error).  If even
        the model-free fallback fails, the original cause is delivered.
        """
        try:
            return _Degraded(
                fallback_scores(query, cluster, assignments, metrics), cause=cause
            )
        except Exception as e:
            return cause if cause is not None else e
