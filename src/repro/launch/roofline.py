"""Roofline analysis (deliverable (g)).

Derives the three roofline terms from a compiled dry-run artifact:

  compute term    = HLO_FLOPs            / (peak_FLOP/s per chip)
  memory term     = HLO_bytes_accessed   / (HBM bytes/s per chip)
  collective term = collective_bytes     / (ICI bytes/s per chip)

``compiled.cost_analysis()`` reports the *per-partition* module cost under
SPMD, so the terms above are per-chip step-time lower bounds already.
collective_bytes is parsed from the optimized HLO text: we sum the result
(shard) sizes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute op.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1,
    "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """bytes of one 'dtype[d0,d1,...]' shape string."""
    m = _SHAPE_RE.match(shape_str.strip())
    if not m:
        return 0
    dtype, dims = m.groups()
    nbytes = _DTYPE_BYTES.get(dtype)
    if nbytes is None:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * nbytes


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum result bytes per collective kind from optimized HLO text."""
    out = {k: 0 for k in COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"%?[\w.\-]+\s*=\s*(.+?)\s+([\w\-]+)\(", line)
        if not m:
            continue
        shapes_str, opname = m.groups()
        # strip async wrappers: 'all-gather-start'/'-done' count once at start
        base = opname.replace("-start", "")
        if base.endswith("-done") or base not in COLLECTIVES:
            continue
        # result may be a tuple '(f32[..], f32[..])'
        total = 0
        if shapes_str.startswith("("):
            for part in shapes_str.strip("()").split(","):
                part = part.strip()
                if "[" in part:
                    # recombine 'f32[8' + '128]' splits: fall back to regex scan
                    pass
            for sm in _SHAPE_RE.finditer(shapes_str):
                total += _shape_bytes(sm.group(0))
        else:
            total = _shape_bytes(shapes_str)
        out[base] += total
        out["count"] += 1
    return out


@dataclass
class RooflineTerms:
    flops: float  # per-chip HLO flops
    hbm_bytes: float  # per-chip bytes accessed
    coll_bytes: float  # per-chip collective bytes moved
    coll_breakdown: Dict[str, int] = field(default_factory=dict)
    model_flops: float = 0.0  # 6*N*D (train) or 2*N_active*D (inference), global

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS_BF16

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    def useful_flops_ratio(self, n_chips: int) -> float:
        """MODEL_FLOPS / (HLO flops summed over chips): remat/redundancy waste."""
        total = self.flops * n_chips
        return self.model_flops / total if total > 0 else 0.0

    def roofline_fraction(self, n_chips: int) -> float:
        """Useful-FLOPs MFU bound implied by the dominant term."""
        t_step = max(self.t_compute, self.t_memory, self.t_collective)
        if t_step <= 0:
            return 0.0
        return self.model_flops / (n_chips * PEAK_FLOPS_BF16 * t_step)

    def as_dict(self, n_chips: int) -> Dict:
        return {
            "flops_per_chip": self.flops,
            "hbm_bytes_per_chip": self.hbm_bytes,
            "collective_bytes_per_chip": self.coll_bytes,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "model_flops": self.model_flops,
            "useful_flops_ratio": self.useful_flops_ratio(n_chips),
            "roofline_fraction": self.roofline_fraction(n_chips),
            "collectives": self.coll_breakdown,
        }


def terms_from_compiled(
    compiled, hlo_text: str, model_flops: float
) -> RooflineTerms:
    ca = compiled.cost_analysis()
    if isinstance(ca, list):  # older jax returns [dict]
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    hbm = float(ca.get("bytes accessed", 0.0))
    coll = collective_bytes(hlo_text)
    total_coll = sum(v for k, v in coll.items() if k != "count")
    return RooflineTerms(
        flops=flops,
        hbm_bytes=hbm,
        coll_bytes=float(total_coll),
        coll_breakdown=coll,
        model_flops=model_flops,
    )


def model_flops_estimate(n_params_active: int, tokens: int, kind: str) -> float:
    """6*N*D for training, 2*N*D for a forward (prefill/decode)."""
    if kind == "train":
        return 6.0 * n_params_active * tokens
    return 2.0 * n_params_active * tokens
