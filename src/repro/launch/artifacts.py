"""Artifact store: trained model parameters + metadata under artifacts/.

Every benchmark harness reads models from here; the training driver writes
them. Params are saved with the atomic checkpoint writer; metadata (model
config, corpus seeds, training history) lives in the manifest.
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, Optional, Tuple

import jax
import numpy as np

from repro.core.gnn import GNNConfig
from repro.core.model import CostModelConfig, init_cost_model
from repro.core.flat_vector import FlatVectorConfig, init_flat_model
from repro.training.checkpoint import restore_checkpoint, save_checkpoint

ROOT = os.environ.get("REPRO_ARTIFACTS", os.path.join(os.path.dirname(__file__), "../../../artifacts"))


def path(*parts: str) -> str:
    p = os.path.abspath(os.path.join(ROOT, *parts))
    return p


def save_cost_model(name: str, params, cfg: CostModelConfig, extra: Optional[Dict] = None):
    d = path("costream", name)
    meta = {
        "metric": cfg.metric,
        "n_ensemble": cfg.n_ensemble,
        "traditional_mp": cfg.traditional_mp,
        "gnn": dataclasses.asdict(cfg.gnn),
        **(extra or {}),
    }
    save_checkpoint(d, 0, params, extra=meta, keep=1)


def load_cost_model(name: str) -> Tuple[object, CostModelConfig]:
    d = path("costream", name)
    # read manifest first to rebuild the config/like-tree
    step_dir = os.path.join(d, "step_0000000000")
    with open(os.path.join(step_dir, "manifest.json")) as f:
        meta = json.load(f)["extra"]
    gnn_kwargs = dict(meta["gnn"])
    cfg = CostModelConfig(
        metric=meta["metric"],
        n_ensemble=meta["n_ensemble"],
        traditional_mp=meta.get("traditional_mp", False),
        gnn=GNNConfig(**gnn_kwargs),
    )
    like = init_cost_model(jax.random.PRNGKey(0), cfg)
    params, _, _ = restore_checkpoint(d, like)
    assert params is not None, f"no checkpoint under {d}"
    return params, cfg


def save_flat_model(name: str, params, cfg: FlatVectorConfig, extra: Optional[Dict] = None):
    d = path("flat", name)
    meta = {"hidden": cfg.hidden, "n_layers": cfg.n_layers, "task": cfg.task, **(extra or {})}
    save_checkpoint(d, 0, params, extra=meta, keep=1)


def load_flat_model(name: str) -> Tuple[object, FlatVectorConfig]:
    d = path("flat", name)
    step_dir = os.path.join(d, "step_0000000000")
    with open(os.path.join(step_dir, "manifest.json")) as f:
        meta = json.load(f)["extra"]
    cfg = FlatVectorConfig(hidden=meta["hidden"], n_layers=meta["n_layers"], task=meta["task"])
    like = init_flat_model(jax.random.PRNGKey(0), cfg)
    params, _, _ = restore_checkpoint(d, like)
    assert params is not None, f"no checkpoint under {d}"
    return params, cfg


def exists(kind: str, name: str) -> bool:
    return os.path.exists(path(kind, name, "latest"))


# -- serving bundles (repro.serve.bundle) ------------------------------------------
#
# The serving path loads ONE versioned bundle holding every metric ensemble
# (docs/api.md#bundle-format) instead of five loose per-metric checkpoints;
# the per-metric save_cost_model/load_cost_model files above remain the
# resumable per-stage training artifacts the bundle is assembled from.


def save_bundle(name: str, bundle) -> str:
    return bundle.save(path("bundles", name))


def load_bundle(name: str):
    from repro.serve.bundle import CostModelBundle

    return CostModelBundle.load(path("bundles", name))


def bundle_exists(name: str) -> bool:
    return exists("bundles", name)
