"""Production mesh construction (deliverable (e)).

Target: TPU v5e pods. Single pod = 256 chips as a (data=16, model=16) mesh;
multi-pod = 2 pods = 512 chips as (pod=2, data=16, model=16), where the pod
axis extends data parallelism across the inter-pod DCN/ICI boundary.

Import of this module never touches jax device state: the mesh is built by a
FUNCTION so the dry-run (which forces 512 host devices) controls when jax
first initializes.

Real-TPU launch flags (inert on CPU; recorded here for cluster runs):
  --xla_tpu_enable_async_collective_fusion=true
  --xla_tpu_enable_async_collective_fusion_fuse_all_gather=true
  --xla_tpu_overlap_compute_collective_tc=true
  --xla_enable_async_all_gather=true
  --xla_enable_async_collective_permute=true
  --xla_tpu_spmd_threshold_for_allgather_cse=10000
"""

from __future__ import annotations

from typing import Tuple

import jax

TPU_PERF_FLAGS = (
    "--xla_tpu_enable_async_collective_fusion=true "
    "--xla_tpu_enable_async_collective_fusion_fuse_all_gather=true "
    "--xla_tpu_overlap_compute_collective_tc=true "
    "--xla_enable_async_all_gather=true "
    "--xla_enable_async_collective_permute=true"
)

# v5e hardware constants used by the roofline analysis
PEAK_FLOPS_BF16 = 197e12  # per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """Arbitrary mesh for tests / elastic reconfiguration."""
    return jax.make_mesh(shape, axes)


def data_axes(mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def n_chips(mesh) -> int:
    import numpy as np

    return int(np.prod(list(mesh.shape.values())))
