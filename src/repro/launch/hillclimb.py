from repro.launch import dryrun  # noqa: F401  (sets XLA_FLAGS=512 devices first)

"""SPerf hillclimbing driver: hypothesis -> change -> re-lower -> re-analyse.

Three cells (chosen per the assignment: worst roofline fraction, most
collective-bound, most representative of the paper's technique-at-scale):

  A qwen3-8b x train_4k        (dense train; memory-term dominated)
  B deepseek-v2-236b x decode_32k  (MoE+MLA decode; memory/args dominated;
                                    expert placement = the paper's operator-
                                    placement analogue at this layer)
  C recurrentgemma-2b x prefill_32k (most collective-bound cell)

Each variant re-runs the dry-run cell with a tagged artifact; the
EXPERIMENTS.md SPerf table is assembled from these JSONs.

Usage: PYTHONPATH=src python -m repro.launch.hillclimb [--cell A,B,C]
"""

import argparse
import dataclasses
import json

from repro.launch.dryrun import run_cell
from repro.models.params import ShardingRules


def fmt(cell):
    if cell["status"] != "ok":
        return cell.get("error", cell["status"])
    r = cell["roofline"]
    return (
        f"Tc={r['t_compute_s']:.3f}s Tm={r['t_memory_s']:.3f}s "
        f"Tcoll={r['t_collective_s']:.3f}s -> {r['bottleneck']} "
        f"(frac={r['roofline_fraction']:.3f}, temp={cell['memory']['temp_size_in_bytes']/1e9:.1f}GB)"
    )


def cell_a():
    """qwen3-8b train_4k: the memory term is dominated by the per-layer
    activation stream (saved residuals, norms, elementwise traffic).

    H1: Megatron-style sequence parallelism (activations sharded over the
        model axis between blocks) divides that traffic by 16.
    H2: remat='dots' (keep matmul outputs, recompute elementwise) trades
        +bytes for -flops; with SP the memory headroom allows it.
    """
    out = {}
    out["A1_seq_parallel"] = run_cell(
        "qwen3-8b", "train_4k", False, tag="_sp", seq_parallel=True
    )
    out["A2_sp_dots"] = run_cell(
        "qwen3-8b",
        "train_4k",
        False,
        tag="_sp_dots",
        seq_parallel=True,
        mutate_cfg=lambda c: dataclasses.replace(c, remat="dots"),
    )
    return out


def cell_b():
    """deepseek-v2-236b decode_32k: per-chip args are dominated by the MLA
    compressed cache replicated over the model axis (only batch-sharded).

    H1: shard the cache sequence dim over 'model' (flash-decode style): the
        16x replication disappears; attention reduces over the sharded dim
        with one small collective per layer.
    """
    rules = ShardingRules().replace("act_seq", ("model", None))
    out = {}
    out["B1_kv_seq_shard"] = run_cell(
        "deepseek-v2-236b", "decode_32k", False, rules=rules, tag="_kvshard"
    )
    return out


def cell_c():
    """recurrentgemma-2b prefill_32k: most collective-bound baseline.

    H1: the dense (r x r) RG-LRU gate matmuls contract over the model-sharded
        channel dim -> an all-reduce of (B, S, r) fp32 per gate per layer.
        Griffin's actual design uses block-diagonal gates (one block per
        head): with blocks aligned to the channel sharding the contraction
        is shard-local and those collectives vanish.
    H2: + sequence parallelism for the elementwise/norm traffic.
    """
    out = {}
    out["C1_blockdiag"] = run_cell(
        "recurrentgemma-2b",
        "prefill_32k",
        False,
        tag="_blockdiag",
        mutate_cfg=lambda c: dataclasses.replace(c, rg_blockdiag=True),
    )
    out["C2_blockdiag_sp"] = run_cell(
        "recurrentgemma-2b",
        "prefill_32k",
        False,
        tag="_blockdiag_sp",
        seq_parallel=True,
        mutate_cfg=lambda c: dataclasses.replace(c, rg_blockdiag=True),
    )
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", default="A,B,C")
    args = ap.parse_args()
    results = {}
    if "A" in args.cell:
        results.update(cell_a())
    if "B" in args.cell:
        results.update(cell_b())
    if "C" in args.cell:
        results.update(cell_c())
    print("\n=== hillclimb results ===")
    for name, cell in results.items():
        print(f"{name}: {fmt(cell)}")


if __name__ == "__main__":
    main()
