"""Fault-tolerance harness: heartbeat monitoring, failure/straggler detection,
evict -> elastic re-mesh -> checkpoint-restore (DESIGN.md SS7).

Hardware failures cannot be produced in this container, so the harness drives
a *virtual cluster*: each virtual host reports heartbeats and per-step
latencies; the monitor implements the production policy (missed-heartbeat
eviction, latency-outlier straggler demotion) and the recovery path is the
real one — rebuild the mesh at the surviving size, restore the latest atomic
checkpoint, resume. The same ``FaultPolicy`` would run against real hosts'
heartbeats on a cluster.
"""

from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np


def straggler_outliers(
    samples: Dict[int, float], zscore: float, min_population: int = 4
) -> List[Tuple[int, float]]:
    """Median/MAD robust z-score outliers of ``{key: latency}`` samples.

    Returns ``(key, z)`` for every sample whose modified z-score
    (``0.6745 * (v - median) / MAD``) exceeds ``zscore``.  The median/MAD
    pair stays meaningful when up to half the population misbehaves — a
    mean/stddev test would be dragged toward the stragglers it is hunting.
    Empty below ``min_population``: an outlier needs a population to stand
    out from.  Shared by ``ClusterMonitor`` (slow SPMD hosts) and the chaos
    harness (slow-host request tails, ``benchmarks/chaos_bench.py``).
    """
    if len(samples) < min_population:
        return []
    vals = np.array(list(samples.values()), dtype=np.float64)
    med = np.median(vals)
    mad = np.median(np.abs(vals - med)) + 1e-9
    out: List[Tuple[int, float]] = []
    for key, v in samples.items():
        z = 0.6745 * (float(v) - med) / mad
        if z > zscore:
            out.append((key, float(z)))
    return out


@dataclass
class FaultPolicy:
    heartbeat_timeout_s: float = 60.0
    straggler_zscore: float = 3.0  # step-latency outlier threshold
    straggler_min_steps: int = 8  # warm-up before straggler detection
    max_evictions_per_hour: int = 8


@dataclass
class VirtualHost:
    host_id: int
    alive: bool = True
    straggle_factor: float = 1.0  # >1 = slow host
    last_heartbeat: float = 0.0
    step_times: List[float] = field(default_factory=list)


class ClusterMonitor:
    """Tracks heartbeats + step latencies; decides evictions."""

    def __init__(self, n_hosts: int, policy: Optional[FaultPolicy] = None):
        # default must be constructed per-monitor: a dataclass instance in the
        # signature is evaluated once and shared, so one monitor mutating its
        # policy (e.g. relaxing the heartbeat timeout) would retune every
        # other monitor in the process
        self.policy = policy if policy is not None else FaultPolicy()
        self.hosts: Dict[int, VirtualHost] = {
            i: VirtualHost(host_id=i) for i in range(n_hosts)
        }
        self.evictions: List[Tuple[float, int, str]] = []

    # -- signals ---------------------------------------------------------------
    def heartbeat(self, host_id: int, now: float) -> None:
        self.hosts[host_id].last_heartbeat = now

    def report_step(self, host_id: int, seconds: float) -> None:
        self.hosts[host_id].step_times.append(seconds)

    def inject_failure(self, host_id: int) -> None:
        self.hosts[host_id].alive = False

    def inject_straggler(self, host_id: int, factor: float) -> None:
        self.hosts[host_id].straggle_factor = factor

    # -- detection ---------------------------------------------------------------
    def detect(self, now: float) -> List[Tuple[int, str]]:
        out: List[Tuple[int, str]] = []
        # heartbeat timeouts: check EVERY tracked host — a dead host is
        # precisely one that stopped heartbeating
        for h in self.hosts.values():
            if now - h.last_heartbeat > self.policy.heartbeat_timeout_s:
                out.append((h.host_id, "heartbeat-timeout"))
        live = [h for h in self.hosts.values() if h.alive]
        # stragglers: median/MAD outlier test across hosts' recent step times
        min_steps = min(self.policy.straggler_min_steps, 3)
        recent = {
            h.host_id: np.mean(h.step_times[-min_steps:])
            for h in live
            if len(h.step_times) >= min_steps
        }
        for hid, z in straggler_outliers(recent, self.policy.straggler_zscore):
            out.append((hid, f"straggler(z={z:.1f})"))
        return out

    def evict(self, host_id: int, reason: str, now: float) -> None:
        del self.hosts[host_id]
        self.evictions.append((now, host_id, reason))

    def n_alive(self) -> int:
        return len(self.hosts)


@dataclass
class RecoveryEvent:
    step: int
    reason: str
    old_hosts: int
    new_hosts: int
    resumed_from: Optional[int]


def run_with_faults(
    train_epoch: Callable[[int, int], float],  # (start_step, n_hosts) -> end_step
    save_fn: Callable[[int], None],
    restore_fn: Callable[[], Optional[int]],
    monitor: ClusterMonitor,
    schedule: Dict[int, Tuple[str, int]],  # step -> ("fail"|"straggle", host_id)
    total_steps: int,
    steps_per_round: int = 10,
    base_step_time: float = 0.1,
) -> Tuple[int, List[RecoveryEvent]]:
    """Drive a training loop against the virtual cluster.

    Each round simulates ``steps_per_round`` SPMD steps: every live host
    reports heartbeat + step latency (stragglers report inflated times); the
    monitor then decides evictions. An eviction triggers the real recovery
    path: save/restore via the atomic checkpointer and a smaller host count.
    """
    events: List[RecoveryEvent] = []
    step = restore_fn() or 0
    now = 0.0
    while step < total_steps:
        # inject scheduled faults
        for s, (kind, hid) in list(schedule.items()):
            if s <= step and hid in monitor.hosts:
                if kind == "fail":
                    monitor.inject_failure(hid)
                else:
                    monitor.inject_straggler(hid, 8.0)
                del schedule[s]
        # one round of synchronous steps; time advances past the heartbeat
        # window so hosts that stopped heartbeating (alive=False) stand out
        now += monitor.policy.heartbeat_timeout_s + 1
        for h in monitor.hosts.values():
            t = base_step_time * h.straggle_factor
            if h.alive:
                monitor.heartbeat(h.host_id, now)
                monitor.report_step(h.host_id, t)
        detected = monitor.detect(now)
        if detected:
            old = monitor.n_alive()
            save_fn(step)
            for hid, reason in detected:
                if hid in monitor.hosts:
                    monitor.evict(hid, reason, now)
            resumed = restore_fn()
            events.append(
                RecoveryEvent(
                    step=step,
                    reason=";".join(r for _, r in detected),
                    old_hosts=old,
                    new_hosts=monitor.n_alive(),
                    resumed_from=resumed,
                )
            )
            step = resumed or step
        step = train_epoch(step, monitor.n_alive())
        if step % (steps_per_round * 5) == 0:
            save_fn(step)
    save_fn(step)
    return step, events
