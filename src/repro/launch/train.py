"""COSTREAM training driver: builds the benchmark corpus and trains every
model artifact the experiment harnesses need.

Stages (resumable; each skips finished artifacts):

  main       5 per-metric GNN ensembles (paper SIV-A) on the full corpus
  flat       flat-vector baselines [16] for the same 5 metrics
  extrap     8 restricted-range retrains for Exp 4 (4 hw dims x stronger/weaker)
  ablations  Exp 7a featurization variants + Exp 7b traditional message passing
  finetune   Exp 5b few-shot fine-tuning on filter-chain queries

Run:  PYTHONPATH=src python -m repro.launch.train --stage all
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import pickle
import time
from typing import Dict, List, Optional

import jax
import numpy as np

from repro.core.flat_vector import FlatVectorConfig, featurize_flat_traces
from repro.core.graph import drop_hardware, drop_hw_features
from repro.core.model import (
    ALL_METRICS,
    CLASSIFICATION_METRICS,
    REGRESSION_METRICS,
    CostModelConfig,
)
from repro.dsps import ranges
from repro.dsps.generator import GeneratorConfig, WorkloadGenerator
from repro.launch import artifacts
from repro.training.batching import dataset_from_traces, split_dataset, split_indices
from repro.training.loop import TrainConfig, train_cost_model, train_flat_model

CORPUS_SEED = 42
SPLIT_SEED = 7
MAIN_CORPUS = 22_000
EXTRAP_CORPUS = 6_000
FINETUNE_N = 3_000


def corpus_cache(name: str, build) -> List:
    os.makedirs(artifacts.path("corpus"), exist_ok=True)
    p = artifacts.path("corpus", f"{name}.pkl")
    if os.path.exists(p):
        with open(p, "rb") as f:
            return pickle.load(f)
    traces = build()
    tmp = p + ".tmp"
    with open(tmp, "wb") as f:
        pickle.dump(traces, f)
    os.replace(tmp, p)
    return traces


def main_corpus() -> List:
    return corpus_cache(
        "main", lambda: WorkloadGenerator(seed=CORPUS_SEED).corpus(MAIN_CORPUS)
    )


def _train_one(
    traces,
    metric: str,
    name: str,
    n_ensemble: int,
    epochs: int,
    transform=None,
    traditional_mp: bool = False,
    extra: Optional[Dict] = None,
    seed: int = 0,
    verbose: bool = True,
):
    if artifacts.exists("costream", name):
        print(f"[skip] {name}")
        return
    t0 = time.time()
    ds = dataset_from_traces(traces, metric, transform=transform)
    tr, va, te = split_dataset(ds, seed=SPLIT_SEED)
    cfg = CostModelConfig(metric=metric, n_ensemble=n_ensemble, traditional_mp=traditional_mp)
    res = train_cost_model(
        tr,
        va,
        cfg,
        # signature-exact bands: these fixed corpora dwarf the batch size, so
        # the extra per-signature traces amortize and every step runs
        # row-trimmed stage-3 spans (benchmarks/training_bench.py)
        TrainConfig(
            epochs=epochs,
            batch_size=512,
            lr=1.5e-3,
            seed=seed,
            verbose=verbose,
            exact_banding=True,
        ),
    )
    artifacts.save_cost_model(
        name,
        res.params,
        cfg,
        extra={
            "best_val": res.best_val,
            "steps": res.steps,
            "history": res.history,
            "seconds": time.time() - t0,
            **(extra or {}),
        },
    )
    print(f"[done] {name} val={res.best_val:.4f} in {time.time() - t0:.0f}s")


def stage_main(epochs: int):
    traces = main_corpus()
    for metric in ALL_METRICS:
        _train_one(traces, metric, f"main_{metric}", n_ensemble=3, epochs=epochs)
    export_main_bundle(epochs)


def export_main_bundle(epochs: int):
    """Assemble the five per-metric ensembles into the ONE versioned serving
    artifact (repro.serve.CostModelBundle) the online path loads; the loose
    per-metric checkpoints stay as the resumable training artifacts."""
    from repro.serve.bundle import CostModelBundle, corpus_fingerprint

    if artifacts.bundle_exists("main"):
        print("[skip] bundle main")
        return
    missing = [m for m in ALL_METRICS if not artifacts.exists("costream", f"main_{m}")]
    if missing:
        print(f"[warn] bundle main not exported: metrics not trained yet {missing}")
        return
    bundle = CostModelBundle(
        models={m: artifacts.load_cost_model(f"main_{m}") for m in ALL_METRICS},
        meta={
            "stage": "main",
            "corpus_seed": CORPUS_SEED,
            "split_seed": SPLIT_SEED,
            "corpus_size": MAIN_CORPUS,
            # provenance: CostEstimator.from_bundle(corpus_fingerprint=...)
            # warns when served against data from a different corpus
            "corpus_fingerprint": corpus_fingerprint(main_corpus()),
            "epochs": epochs,
        },
    )
    artifacts.save_bundle("main", bundle)
    print(f"[done] bundle main ({', '.join(bundle.metrics)})")


def stage_flat(epochs: int):
    traces = main_corpus()
    x = featurize_flat_traces(traces)
    # the same partition split_dataset uses for the GNN models
    idx_tr, idx_va, _ = split_indices(len(traces), seed=SPLIT_SEED)
    from repro.core.model import label_array

    for metric in ALL_METRICS:
        name = f"flat_{metric}"
        if artifacts.exists("flat", name):
            print(f"[skip] {name}")
            continue
        y = label_array(traces, metric)
        task = "regression" if metric in REGRESSION_METRICS else "classification"
        cfg = FlatVectorConfig(task=task)
        params = train_flat_model(
            x[idx_tr],
            y[idx_tr],
            x[idx_va],
            y[idx_va],
            cfg,
            TrainConfig(epochs=epochs, batch_size=512, lr=1.5e-3),
        )
        artifacts.save_flat_model(name, params, cfg)
        print(f"[done] {name}")


def extrap_generator(direction: str, dim: str) -> GeneratorConfig:
    spec = ranges.extrapolation_ranges()[direction]["train"]
    kw = {}
    mapping = {
        "ram": ("ram_mb", "RAM_MB"),
        "cpu": ("cpu", "CPU"),
        "bandwidth": ("bandwidth_mbps", "BANDWIDTH_MBPS"),
        "latency": ("latency_ms", "LATENCY_MS"),
    }
    field, key = mapping[dim]
    kw[field] = tuple(spec[key])
    return GeneratorConfig().with_hardware(**kw)


def stage_extrap(epochs: int):
    for direction in ("stronger", "weaker"):
        for dim in ("ram", "cpu", "bandwidth", "latency"):
            cname = f"extrap_{direction}_{dim}"
            traces = corpus_cache(
                cname,
                lambda d=direction, m=dim: WorkloadGenerator(
                    extrap_generator(d, m), seed=CORPUS_SEED + hash((d, m)) % 1000
                ).corpus(EXTRAP_CORPUS),
            )
            for metric in ALL_METRICS:
                _train_one(
                    traces,
                    metric,
                    f"{cname}_{metric}",
                    n_ensemble=1,
                    epochs=epochs,
                    extra={"direction": direction, "dim": dim},
                    verbose=False,
                )


def stage_ablations(epochs: int):
    traces = main_corpus()
    # Exp 7a: featurization variants for L_e — plus an equal-budget "full"
    # model so the Fig-12 comparison is apples-to-apples at these epochs
    _train_one(traces, "latency_e", "ablate_full_latency_e", n_ensemble=3, epochs=epochs)
    _train_one(
        traces,
        "latency_e",
        "ablate_no_hw_nodes_latency_e",
        n_ensemble=3,
        epochs=epochs,
        transform=drop_hardware,
    )
    _train_one(
        traces,
        "latency_e",
        "ablate_no_hw_feats_latency_e",
        n_ensemble=3,
        epochs=epochs,
        transform=drop_hw_features,
    )
    # Exp 7b: traditional message passing for the regression metrics
    for metric in REGRESSION_METRICS:
        _train_one(
            traces,
            metric,
            f"ablate_traditional_{metric}",
            n_ensemble=3,
            epochs=epochs,
            traditional_mp=True,
        )


def chain_corpus(name: str, n: int, seed: int, chain_lengths=(2, 3, 4)) -> List:
    """Filter-chain queries unseen in training (Exp 5 / Exp 5b)."""
    from repro.dsps.generator import Trace
    from repro.dsps.simulator import simulate

    def build():
        gen = WorkloadGenerator(seed=seed)
        out = []
        for i in range(n):
            ln = chain_lengths[i % len(chain_lengths)]
            q = gen.linear_query(name=f"{name}{i}", n_filters=ln)
            c = gen.cluster()
            p = gen.placement(q, c)
            out.append(Trace(query=q, cluster=c, placement=p, labels=simulate(q, c, p, rng=gen.rng)))
        return out

    return corpus_cache(name, build)


def finetune_corpus() -> List:
    return chain_corpus("finetune_chains", FINETUNE_N, CORPUS_SEED + 5)


def stage_finetune(epochs: int):
    name = "finetune_throughput"
    if artifacts.exists("costream", name):
        print(f"[skip] {name}")
        return
    base_params, cfg = artifacts.load_cost_model("main_throughput")
    traces = finetune_corpus()
    ds = dataset_from_traces(traces, "throughput")
    tr, va, _ = split_dataset(ds, fractions=(0.9, 0.1, 0.0), seed=SPLIT_SEED)
    res = train_cost_model(
        tr,
        va,
        cfg,
        TrainConfig(epochs=epochs, batch_size=256, lr=3e-4, verbose=True),
        init_params=base_params,
    )
    artifacts.save_cost_model(name, res.params, cfg, extra={"finetuned_from": "main_throughput"})
    print(f"[done] {name}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--stage", default="all", choices=["all", "main", "flat", "extrap", "ablations", "finetune"])
    ap.add_argument("--epochs", type=int, default=26)
    ap.add_argument("--extrap-epochs", type=int, default=12)
    ap.add_argument("--ablation-epochs", type=int, default=16)
    ap.add_argument("--finetune-epochs", type=int, default=8)
    args = ap.parse_args()

    t0 = time.time()
    if args.stage in ("all", "main"):
        stage_main(args.epochs)
    if args.stage in ("all", "flat"):
        stage_flat(args.epochs)
    if args.stage in ("all", "extrap"):
        stage_extrap(args.extrap_epochs)
    if args.stage in ("all", "ablations"):
        stage_ablations(args.ablation_epochs)
    if args.stage in ("all", "finetune"):
        stage_finetune(args.finetune_epochs)
    print(f"total {time.time() - t0:.0f}s")


if __name__ == "__main__":
    main()
