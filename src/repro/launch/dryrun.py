import os

os.environ["XLA_FLAGS"] = (
    os.environ.get("REPRO_DRYRUN_XLA_FLAGS")
    or "--xla_force_host_platform_device_count="
    + os.environ.get("REPRO_DRYRUN_DEVICES", "512")
)

# ^ MUST precede any jax-importing module: jax locks the device count on first
# backend initialization. Everything below is a normal module.

"""Multi-pod dry-run (deliverable (e)).

For every (architecture x input shape) cell, lower + compile the appropriate
step (train_step / prefill_step / serve_step) against the production mesh —
16x16 single-pod and 2x16x16 multi-pod — with ShapeDtypeStruct inputs (no
allocation), then record:

  * memory_analysis()   — proves the partitioned program fits
  * cost_analysis()     — per-chip FLOPs / bytes for the roofline
  * collective bytes    — parsed from the optimized HLO
  * the three roofline terms + dominant bottleneck (SRoofline)

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all --mesh both
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k
"""

import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS, SHAPES, cell_supported, get_config, get_shape, input_specs
from repro.launch import artifacts
from repro.launch.mesh import data_axes, make_production_mesh, n_chips
from repro.launch.roofline import model_flops_estimate, terms_from_compiled
from repro.models.params import ShardingRules, abstract, count_params, shardings
from repro.models.steps import TrainStepConfig, make_prefill_step, make_serve_step, make_train_step
from repro.models.transformer import ModelConfig, model_cache_defs, model_defs
from repro.training.optim import AdamState


def active_params(cfg: ModelConfig) -> int:
    """Active parameters per token (MoE: routed experts count at top_k/E)."""
    total = count_params(model_defs(cfg))
    if cfg.moe is None:
        return total
    # expert weights: 3 matrices per expert per MoE layer
    n_moe_layers = sum(k in ("moe", "mla_moe") for k in cfg.prefix) + cfg.n_groups * sum(
        k in ("moe", "mla_moe") for k in cfg.pattern
    ) + sum(k in ("moe", "mla_moe") for k in cfg.suffix)
    per_expert = 3 * cfg.d_model * cfg.moe.expert_ff
    routed = n_moe_layers * cfg.moe.n_experts * per_expert
    active_routed = n_moe_layers * cfg.moe.top_k * per_expert
    return total - routed + active_routed


def batch_sharding(spec_tree, mesh):
    """Shardings for the abstract input batch: batch dim over (pod, data)."""
    daxes = data_axes(mesh)
    ax = daxes if len(daxes) > 1 else daxes[0]

    def per_leaf(s):
        if s.shape == ():
            return NamedSharding(mesh, P())
        parts = [None] * len(s.shape)
        if s.shape[0] % np.prod([mesh.shape[a] for a in (ax if isinstance(ax, tuple) else (ax,))]) == 0:
            parts[0] = ax
        return NamedSharding(mesh, P(*parts))

    return jax.tree_util.tree_map(per_leaf, spec_tree)


def build_cell(cfg: ModelConfig, shape_name: str, mesh, rules: ShardingRules,
               tcfg: TrainStepConfig):
    """Returns (fn, abstract_args, in_shardings) for the cell's step."""
    shape = get_shape(shape_name)
    specs_in = input_specs(cfg, shape)
    pdefs = model_defs(cfg)
    params_abs = abstract(pdefs)
    params_sh = shardings(pdefs, rules, mesh)

    if shape.kind == "train":
        train_step, opt = make_train_step(cfg, tcfg)
        opt_abs = jax.eval_shape(opt.init, params_abs)
        opt_sh = AdamState(
            step=NamedSharding(mesh, P()),
            mu=params_sh,
            nu=params_sh,
        )
        state_abs = {
            "params": params_abs,
            "opt": opt_abs,
            "step": jax.ShapeDtypeStruct((), jnp.int32),
        }
        state_sh = {"params": params_sh, "opt": opt_sh, "step": NamedSharding(mesh, P())}
        args = (state_abs, specs_in)
        in_sh = (state_sh, batch_sharding(specs_in, mesh))
        return train_step, args, in_sh

    if shape.kind == "prefill":
        prefill = make_prefill_step(cfg)
        args = (params_abs, specs_in)
        in_sh = (params_sh, batch_sharding(specs_in, mesh))
        return prefill, args, in_sh

    # decode
    serve = make_serve_step(cfg)
    cdefs = model_cache_defs(cfg, shape.global_batch, shape.seq_len)
    cache_abs = specs_in["cache"]
    cache_sh = shardings(cdefs, rules, mesh)
    tok_abs = specs_in["tokens"]
    args = (params_abs, cache_abs, tok_abs, specs_in["cache_len"])
    in_sh = (
        params_sh,
        cache_sh,
        batch_sharding(tok_abs, mesh),
        NamedSharding(mesh, P()),
    )
    return serve, args, in_sh


def _lower_terms(cfg, shape_name, mesh, rules, tcfg, model_flops, seq_parallel=False):
    """Lower+compile one config variant and return its raw roofline terms."""
    from repro.models import sharding_ctx

    fn, args, in_sh = build_cell(cfg, shape_name, mesh, rules, tcfg)
    with mesh, sharding_ctx.use_mesh(mesh, seq_parallel=seq_parallel):
        compiled = jax.jit(fn, in_shardings=in_sh).lower(*args).compile()
    return terms_from_compiled(compiled, compiled.as_text(), model_flops)


def _delta_correct(cfg, shape_name, mesh, rules, tcfg, terms, model_flops, seq_parallel=False):
    """Per-group linear extrapolation of flops/bytes/collective bytes."""
    from repro.models import blocks as B

    n = cfg.n_groups
    if n < 2 or (cfg.enc_pattern and cfg.enc_groups != n):
        return terms, {"delta": False, "reason": "n_groups<2 or enc mismatch"}
    try:
        B.set_attn_unroll_cap(64)
        kw: Dict[str, Any] = {"n_groups": 1, "scan_layers": False}
        kw2: Dict[str, Any] = {"n_groups": 2, "scan_layers": False}
        if cfg.enc_pattern:
            kw["enc_groups"] = 1
            kw2["enc_groups"] = 2
        t1 = _lower_terms(
            dataclasses.replace(cfg, **kw), shape_name, mesh, rules, tcfg, model_flops,
            seq_parallel=seq_parallel,
        )
        t2 = _lower_terms(
            dataclasses.replace(cfg, **kw2), shape_name, mesh, rules, tcfg, model_flops,
            seq_parallel=seq_parallel,
        )
    except Exception as e:  # keep the uncorrected terms rather than fail the cell
        return terms, {"delta": False, "reason": f"{type(e).__name__}: {e}"}
    finally:
        B.set_attn_unroll_cap(1)

    def extrap(a, b):
        return max(a + (b - a) * (n - 1), 0.0)

    corrected = dataclasses.replace(
        terms,
        flops=extrap(t1.flops, t2.flops),
        hbm_bytes=extrap(t1.hbm_bytes, t2.hbm_bytes),
        coll_bytes=extrap(t1.coll_bytes, t2.coll_bytes),
    )
    meta = {
        "delta": True,
        "g1": {"flops": t1.flops, "bytes": t1.hbm_bytes, "coll": t1.coll_bytes},
        "g2": {"flops": t2.flops, "bytes": t2.hbm_bytes, "coll": t2.coll_bytes},
        "scanned_raw": {
            "flops": terms.flops,
            "bytes": terms.hbm_bytes,
            "coll": terms.coll_bytes,
        },
    }
    return corrected, meta


def run_cell(
    arch: str,
    shape_name: str,
    multi_pod: bool,
    rules: Optional[ShardingRules] = None,
    save: bool = True,
    verbose: bool = True,
    tag: str = "",
    tcfg: Optional[TrainStepConfig] = None,
    mutate_cfg=None,  # ModelConfig -> ModelConfig (hillclimb variants)
    seq_parallel: bool = False,  # Megatron-SP activation sharding
) -> Dict[str, Any]:
    shape = get_shape(shape_name)
    ok, why = cell_supported(arch, shape)
    mesh_name = "multi" if multi_pod else "single"
    cell = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "status": "skipped" if not ok else "pending",
    }
    if not ok:
        cell["skip_reason"] = why
        if verbose:
            print(f"[skip] {arch} x {shape_name} ({mesh_name}): {why}")
        return cell

    cfg = get_config(arch)
    if mutate_cfg is not None:
        cfg = mutate_cfg(cfg)
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = rules or ShardingRules()
    # big-model dry-runs keep Adam moments in bf16 (no fp32 master; DESIGN SS7)
    tcfg = tcfg or TrainStepConfig(
        moment_dtype=jnp.bfloat16 if count_params(model_defs(cfg)) > 5e10 else jnp.float32
    )

    from repro.models import sharding_ctx

    t0 = time.time()
    try:
        fn, args, in_sh = build_cell(cfg, shape_name, mesh, rules, tcfg)
        with mesh, sharding_ctx.use_mesh(mesh, seq_parallel=seq_parallel):
            jitted = jax.jit(fn, in_shardings=in_sh)
            lowered = jitted.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        mem_d = {}
        for k in (
            "argument_size_in_bytes",
            "output_size_in_bytes",
            "temp_size_in_bytes",
            "generated_code_size_in_bytes",
        ):
            mem_d[k] = int(getattr(mem, k, 0) or 0)

        hlo = compiled.as_text()
        n_active = active_params(cfg)
        if shape.kind == "train":
            tokens = shape.global_batch * shape.seq_len
            mf = model_flops_estimate(n_active, tokens, "train")
        elif shape.kind == "prefill":
            tokens = shape.global_batch * shape.seq_len
            mf = model_flops_estimate(n_active, tokens, "fwd")
        else:
            tokens = shape.global_batch  # one new token per sequence
            mf = model_flops_estimate(n_active, tokens, "fwd")
        terms = terms_from_compiled(compiled, hlo, mf)
        # XLA cost_analysis counts a while-loop body ONCE, so the scanned
        # layer stack is undercounted by ~n_groups. Correct with the delta
        # method: lower 1-group and 2-group variants (attention chunk scans
        # unrolled) and extrapolate per-group cost linearly.
        terms, delta_meta = _delta_correct(
            cfg, shape_name, mesh, rules, tcfg, terms, mf, seq_parallel=seq_parallel
        )

        chips = n_chips(mesh)
        cell.update(
            {
                "status": "ok",
                "chips": chips,
                "n_params": count_params(model_defs(cfg)),
                "n_params_active": n_active,
                "lower_s": round(t_lower, 1),
                "compile_s": round(t_compile, 1),
                "memory": mem_d,
                "roofline": terms.as_dict(chips),
                "delta_correction": delta_meta,
            }
        )
        if verbose:
            r = cell["roofline"]
            print(
                f"[ok] {arch} x {shape_name} ({mesh_name}{tag}): "
                f"Tc={r['t_compute_s']:.3e}s Tm={r['t_memory_s']:.3e}s "
                f"Tcoll={r['t_collective_s']:.3e}s -> {r['bottleneck']}; "
                f"temp/chip={mem_d['temp_size_in_bytes']/1e9:.2f}GB "
                f"args/chip={mem_d['argument_size_in_bytes']/1e9:.2f}GB "
                f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)"
            )
    except Exception as e:
        cell["status"] = "error"
        cell["error"] = f"{type(e).__name__}: {e}"
        cell["traceback"] = traceback.format_exc()[-2000:]
        if verbose:
            print(f"[ERR] {arch} x {shape_name} ({mesh_name}): {cell['error']}")

    if save:
        outdir = artifacts.path("dryrun", mesh_name + tag)
        os.makedirs(outdir, exist_ok=True)
        with open(os.path.join(outdir, f"{arch}__{shape_name}.json"), "w") as f:
            json.dump(cell, f, indent=2, default=str)
    return cell


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    archs = list(ARCHS) if args.arch == "all" else args.arch.split(",")
    shapes = [s.name for s in SHAPES] if args.shape == "all" else args.shape.split(",")
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    results = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                mesh_name = "multi" if mp else "single"
                out = artifacts.path("dryrun", mesh_name, f"{arch}__{shape}.json")
                if args.skip_existing and os.path.exists(out):
                    with open(out) as f:
                        prev = json.load(f)
                    if prev.get("status") in ("ok", "skipped"):
                        print(f"[cached] {arch} x {shape} ({mesh_name})")
                        results.append(prev)
                        continue
                results.append(run_cell(arch, shape, mp))

    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    n_err = sum(r["status"] == "error" for r in results)
    print(f"\n=== dry-run complete: {n_ok} ok, {n_skip} skipped, {n_err} errors ===")
    return 1 if n_err else 0


if __name__ == "__main__":
    raise SystemExit(main())
