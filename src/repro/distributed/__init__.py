"""Distribution substrate: sharding rules, DP train step with explicit
compressed gradient reduction, pipeline parallelism."""

from repro.models.params import ShardingRules, shardings, specs, spec_for
from repro.distributed.dp import make_dp_train_step
from repro.distributed.pipeline import pipeline_forward

__all__ = [
    "ShardingRules",
    "shardings",
    "specs",
    "spec_for",
    "make_dp_train_step",
    "pipeline_forward",
]
