"""Pipeline parallelism: GPipe-style microbatch pipeline under shard_map.

Stages live on a ``pipe`` mesh axis; activations move stage-to-stage with
collective_permute. The schedule is the classic fill-run-drain loop: with M
microbatches and K stages the bubble fraction is (K-1)/(M+K-1). Used for the
very deep assigned archs (deepseek-67b: 95 layers) as an alternative to pure
FSDP+TP when cross-slice bandwidth is scarce — see EXPERIMENTS.md SPerf for
the measured trade-off on the dry-run.
"""

from __future__ import annotations

from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P


def pipeline_forward(
    stage_fn: Callable[[Any, jax.Array], jax.Array],  # (stage_params, x) -> y
    mesh,
    pipe_axis: str = "pipe",
):
    """Returns pipelined(params_stacked, x_microbatched).

    params_stacked: leaves with leading dim = n_stages (sharded over pipe).
    x_microbatched: (M, mb, ...) microbatches, replicated into every stage;
    stage k processes microbatch m at tick t = m + k.
    Output: (M, mb, ...) final-stage outputs.
    """
    n_stages = mesh.shape[pipe_axis]

    def run(params, xs):
        # params: stage-local slice (leading dim 1) after shard_map split
        params = jax.tree_util.tree_map(lambda p: p[0], params)
        k = jax.lax.axis_index(pipe_axis)
        M = xs.shape[0]
        ticks = M + n_stages - 1
        buf = jnp.zeros_like(xs[0])  # current activation held by this stage
        outs = jnp.zeros_like(xs)

        def tick(t, carry):
            buf, outs = carry
            # stage 0 ingests microbatch t (if any); others use permuted input
            x_in = jnp.where(k == 0, xs[jnp.minimum(t, M - 1)], buf)
            active = (t - k >= 0) & (t - k < M)
            y = stage_fn(params, x_in)
            y = jnp.where(active, y, buf)
            # last stage writes its finished microbatch
            outs = jax.lax.cond(
                active & (k == n_stages - 1),
                lambda o: o.at[jnp.clip(t - k, 0, M - 1)].set(y),
                lambda o: o,
                outs,
            )
            # shift activations downstream: stage k -> k+1
            nxt = jax.lax.ppermute(
                y, pipe_axis, [(i, (i + 1) % n_stages) for i in range(n_stages)]
            )
            return nxt, outs

        buf, outs = jax.lax.fori_loop(0, ticks, tick, (buf, outs))
        # only the last stage holds real outputs; broadcast them to all stages
        outs = jax.lax.ppermute(
            outs,
            pipe_axis,
            [((n_stages - 1 + i) % n_stages, i) for i in range(n_stages)],
        ) if n_stages > 1 else outs
        return outs

    return shard_map(
        run,
        mesh=mesh,
        in_specs=(P(pipe_axis), P()),
        out_specs=P(),
        check_rep=False,
    )
