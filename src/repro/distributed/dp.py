"""Data-parallel train step with an *explicit* gradient-reduction path.

With plain pjit the gradient all-reduce is implicit in XLA; to apply gradient
compression (top-k error feedback / int8) on the wire we make the reduction
explicit with shard_map over the data axes:

  per-shard grads -> compress -> psum -> decompress -> optimizer update

The compression happens *before* the psum, so the bytes crossing ICI/DCN are
the compressed representation (on real hardware int8 moves 4x fewer bytes;
top-k moves k values + indices). The optimizer update runs replicated-per-
shard on identical reduced grads — the standard ZeRO-0 layout.
"""

from __future__ import annotations

from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.training import optim
from repro.training.compression import int8_dequantize, int8_quantize


def make_dp_train_step(
    loss_fn: Callable,  # (params, batch) -> scalar loss
    opt: optim.Optimizer,
    mesh,
    data_axis: str = "data",
    compression: Optional[str] = None,  # None | "int8"
    batch_spec: Optional[Any] = None,
):
    """Returns train_step(state, batch, key) for a mesh with a data axis.

    Params/opt state are replicated across ``data_axis`` (pure DP); the batch
    is sharded on its leading dim. Compression is applied pre-psum.
    """
    axis = data_axis
    bspec = batch_spec if batch_spec is not None else P(axis)

    def step_shard(params, opt_state, batch, key):
        # per-shard loss/grads on the local micro-batch
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        nshards = jax.lax.psum(jnp.ones(()), axis)
        if compression == "int8":
            leaves, treedef = jax.tree_util.tree_flatten(grads)
            keys = jax.random.split(jax.random.fold_in(key, jax.lax.axis_index(axis)), len(leaves))
            reduced = []
            for g, k in zip(leaves, keys):
                q, scale = int8_quantize(g, k, stochastic=True)
                # the wire format is (q:int8, scale:f32); psum the dequantized
                # value (XLA moves the int8 operand; scale is O(1))
                reduced.append(jax.lax.psum(int8_dequantize(q, scale), axis) / nshards)
            grads = treedef.unflatten(reduced)
        else:
            grads = jax.tree_util.tree_map(lambda g: jax.lax.psum(g, axis) / nshards, grads)
        loss = jax.lax.psum(loss, axis) / nshards
        updates, opt_state = opt.update(grads, opt_state, params)
        params = optim.apply_updates(params, updates)
        return params, opt_state, loss

    sharded = shard_map(
        step_shard,
        mesh=mesh,
        in_specs=(P(), P(), bspec, P()),
        out_specs=(P(), P(), P()),
        check_rep=False,
    )

    @jax.jit
    def train_step(state: Dict[str, Any], batch, key):
        params, opt_state, loss = sharded(state["params"], state["opt"], batch, key)
        return {"params": params, "opt": opt_state, "step": state["step"] + 1}, {"loss": loss}

    return train_step
