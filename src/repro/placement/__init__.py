"""Placement selection with COSTREAM (paper SV) + baselines."""

from repro.placement.enumerate import (
    enumerate_candidates,
    heuristic_placement,
    valid_candidate,
)
from repro.placement.optimizer import PlacementOptimizer, OptimizerResult
from repro.placement.baselines import online_monitoring_run, MonitoringResult

__all__ = [k for k in dir() if not k.startswith("_")]
