"""Placement selection with COSTREAM (paper SV) + baselines."""

from repro.placement.enumerate import (
    batch_validity_mask,
    dedup_assignments,
    heuristic_placement,
    mutate_assignments,
    sample_assignment_matrix,
    sample_assignments,
    valid_candidate,
)
from repro.placement.optimizer import PlacementOptimizer, OptimizerResult
from repro.placement.baselines import online_monitoring_run, MonitoringResult

__all__ = [k for k in dir() if not k.startswith("_")]
