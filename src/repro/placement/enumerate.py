"""Placement candidate enumeration (paper SV, Fig. 5; after Governor [32]).

Candidates respect three IoT-scenario rules:
  (1) operator co-location is allowed,
  (2) data flows from same-or-weaker to stronger hardware bins,
  (3) placements are acyclic (data never returns to a previously left host).

The sampler is fully vectorized: it draws an ``(N, n_ops)`` assignment matrix
in one topological sweep (NumPy ops across the whole candidate axis) and
validates all rows with batched checks.  All consumers — the optimizer, the
flat-vector ranker, the Exp-2 benchmarks — operate on the raw matrix via
``sample_assignment_matrix``; convert a row with ``Placement.of(row)`` only
at the simulator/reporting boundary.
"""

from __future__ import annotations

from typing import List, Optional, Tuple

import numpy as np

from repro.dsps.hardware import Cluster, hardware_bin
from repro.dsps.placement import (
    Placement,
    is_acyclic_placement,
    respects_increasing_capability,
)
from repro.dsps.query import OpType, Query


def valid_candidate(query: Query, cluster: Cluster, placement: Placement) -> bool:
    return respects_increasing_capability(query, cluster, placement) and is_acyclic_placement(
        query, placement
    )


def heuristic_placement(query: Query, cluster: Cluster) -> Placement:
    """The deterministic initial placement baseline (after [32]).

    Sources go to the weakest bin (edge), each subsequent depth level moves to
    the next-stronger available node, round-robin within a level. This is the
    placement the paper compares its optimized placements against (Exp 2a) and
    the starting point of the monitoring baseline (Exp 2b).
    """
    by_strength = sorted(
        cluster.nodes, key=lambda n: (hardware_bin(n), n.cpu, n.ram_mb, n.bandwidth_mbps)
    )
    depths = query.depths()
    max_d = max(depths.values())
    assign = [0] * query.n_ops()
    n = len(by_strength)
    rr = {}
    for op in query.operators:
        d = depths[op.op_id]
        # map depth range onto node-strength range
        idx = int(round(d / max(max_d, 1) * (n - 1)))
        # round-robin among equal-depth operators across neighboring nodes
        bump = rr.get(d, 0)
        rr[d] = bump + 1
        idx = min(n - 1, idx + (bump % 2))
        assign[op.op_id] = by_strength[idx].node_id
    p = Placement.of(assign)
    if not valid_candidate(query, cluster, p):
        # fall back: everything on the strongest node is always valid
        p = Placement.of([by_strength[-1].node_id] * query.n_ops())
    return p


# -- vectorized sampling --------------------------------------------------------


def batch_validity_mask(
    query: Query,
    cluster: Cluster,
    assignments: np.ndarray,
    paths: Optional[List[List[int]]] = None,
) -> np.ndarray:
    """Vectorized Fig.-5 rule check over an ``(N, n_ops)`` assignment matrix.

    Row i is True iff ``Placement.of(assignments[i])`` passes
    ``valid_candidate`` — the batched twin of the scalar predicates in
    ``repro.dsps.placement`` (kept: they are the readable spec).  ``paths``
    (placement-invariant) can be precomputed via ``query.root_to_sink_paths``
    by callers that check many batches of the same query.
    """
    assignments = np.asarray(assignments)
    n = assignments.shape[0]
    ok = np.ones(n, dtype=bool)
    if n == 0 or not query.edges:
        return ok
    bins = np.asarray(cluster.bins())

    # rule (2): along every logical edge, bins must be non-decreasing
    e_u = np.asarray([u for u, _ in query.edges])
    e_v = np.asarray([v for _, v in query.edges])
    ok &= (bins[assignments[:, e_u]] <= bins[assignments[:, e_v]]).all(axis=1)

    # rule (3): per root->sink path, no host revisited after being left.
    # With consecutive duplicates treated as staying put: hosts[i] == hosts[j]
    # (i < j) is a violation iff some hop between them changed host.
    for path in paths if paths is not None else query.root_to_sink_paths():
        hosts = assignments[:, path]  # (N, L)
        L = hosts.shape[1]
        if L < 3:
            continue  # a revisit needs at least leave + return
        changed = hosts[:, 1:] != hosts[:, :-1]  # (N, L-1)
        pref = np.concatenate(
            [np.zeros((n, 1), dtype=np.int64), np.cumsum(changed, axis=1)], axis=1
        )  # (N, L): #host-changes before position j
        same = hosts[:, :, None] == hosts[:, None, :]  # (N, L, L)
        moved_between = pref[:, None, :] > pref[:, :, None]  # (N, L, L): i -> j changed host
        upper = np.triu(np.ones((L, L), dtype=bool), k=2)  # pairs i < j-1
        ok &= ~(same & moved_between & upper).any(axis=(1, 2))
    return ok


def dedup_assignments(assignments: np.ndarray) -> np.ndarray:
    """Drop duplicate rows, preserving first-seen order."""
    if len(assignments) == 0:
        return assignments
    _, first = np.unique(assignments, axis=0, return_index=True)
    return assignments[np.sort(first)]


def sample_assignments(
    query: Query,
    cluster: Cluster,
    n: int,
    rng: np.random.Generator,
    colocation_bias: float = 0.4,
) -> np.ndarray:
    """Draw ``n`` placement candidates at once as an ``(n, n_ops)`` matrix.

    One vectorized pass per operator in topological order: every candidate
    picks uniformly among hosts whose bin is >= the max bin over its parents'
    hosts (rule 2 by construction along tree edges), with a ``colocation_bias``
    chance of reusing a random parent's host instead.  Co-location can still
    break rule 2 under multi-parent joins and rule 3 is not enforced during
    the sweep, so rows must be filtered with ``batch_validity_mask``.
    """
    bins = np.asarray(cluster.bins())
    # hosts sorted strongest-bin first: the hosts eligible for a minimum bin b
    # are exactly a prefix of this order, of length count_ge[b]
    order_desc = np.argsort(-bins, kind="stable")
    count_ge = np.asarray([(bins >= b).sum() for b in range(int(bins.max()) + 2)])

    assign = np.zeros((n, query.n_ops()), dtype=np.int64)
    for u in query.topological_order():
        parents = query.parents(u)
        if parents:
            min_bin = bins[assign[:, parents]].max(axis=1)  # (n,)
        else:
            min_bin = np.zeros(n, dtype=np.int64)
        n_opts = count_ge[min_bin]  # (n,) >= 1: the parent's own host qualifies
        pick = order_desc[(rng.random(n) * n_opts).astype(np.int64)]
        if parents:
            coloc = rng.random(n) < colocation_bias
            via = np.asarray(parents)[rng.integers(0, len(parents), size=n)]
            pick = np.where(coloc, assign[np.arange(n), via], pick)
        assign[:, u] = pick
    return assign


def sample_assignment_matrix(
    query: Query,
    cluster: Cluster,
    k: int,
    rng: np.random.Generator,
    max_tries_factor: int = 30,
    colocation_bias: float = 0.4,
) -> np.ndarray:
    """Up to ``k`` distinct valid assignments, shape ``(<=k, n_ops)``.

    Oversamples in vectorized rounds (draw -> validity mask -> dedup) until
    ``k`` candidates are collected or the tries budget — the same
    ``k * max_tries_factor`` total draws the old rejection loop allowed — is
    spent.
    """
    budget = k * max_tries_factor
    paths = query.root_to_sink_paths()
    pool = np.zeros((0, query.n_ops()), dtype=np.int64)
    while len(pool) < k and budget > 0:
        draw = min(max(2 * (k - len(pool)), 32), budget)
        budget -= draw
        batch = sample_assignments(query, cluster, draw, rng, colocation_bias)
        batch = batch[batch_validity_mask(query, cluster, batch, paths)]
        pool = dedup_assignments(np.concatenate([pool, batch], axis=0))
    return pool[:k]


def mutate_assignments(
    query: Query,
    cluster: Cluster,
    parents: np.ndarray,
    n_children_per: int,
    rng: np.random.Generator,
) -> np.ndarray:
    """One-op host mutations of parent assignments, validity-filtered.

    Each parent row spawns ``n_children_per`` children with a single random
    operator moved to a random host; children violating the Fig.-5 rules are
    dropped and survivors deduplicated.  The refinement loop's move operator:
    cheap to generate in bulk, and every survivor re-enters the same batched
    scoring path as the initial candidates.
    """
    parents = np.asarray(parents, dtype=np.int64)
    if parents.size == 0 or n_children_per <= 0:
        return parents[:0]
    children = np.repeat(parents, n_children_per, axis=0)
    n = len(children)
    ops = rng.integers(0, query.n_ops(), size=n)
    children[np.arange(n), ops] = rng.integers(0, cluster.n_nodes(), size=n)
    children = children[batch_validity_mask(query, cluster, children)]
    return dedup_assignments(children)


