"""Heuristic placement enumeration (paper SV, Fig. 5; after Governor [32]).

Candidates respect three IoT-scenario rules:
  (1) operator co-location is allowed,
  (2) data flows from same-or-weaker to stronger hardware bins,
  (3) placements are acyclic (data never returns to a previously left host).
"""

from __future__ import annotations

from typing import List, Optional, Set, Tuple

import numpy as np

from repro.dsps.hardware import Cluster, hardware_bin
from repro.dsps.placement import (
    Placement,
    is_acyclic_placement,
    respects_increasing_capability,
)
from repro.dsps.query import OpType, Query


def valid_candidate(query: Query, cluster: Cluster, placement: Placement) -> bool:
    return respects_increasing_capability(query, cluster, placement) and is_acyclic_placement(
        query, placement
    )


def heuristic_placement(query: Query, cluster: Cluster) -> Placement:
    """The deterministic initial placement baseline (after [32]).

    Sources go to the weakest bin (edge), each subsequent depth level moves to
    the next-stronger available node, round-robin within a level. This is the
    placement the paper compares its optimized placements against (Exp 2a) and
    the starting point of the monitoring baseline (Exp 2b).
    """
    order = np.argsort([(hardware_bin(n), -n.cpu * 0 + n.cpu) for n in cluster.nodes], axis=0)
    by_strength = sorted(
        cluster.nodes, key=lambda n: (hardware_bin(n), n.cpu, n.ram_mb, n.bandwidth_mbps)
    )
    depths = query.depths()
    max_d = max(depths.values())
    assign = [0] * query.n_ops()
    n = len(by_strength)
    rr = {}
    for op in query.operators:
        d = depths[op.op_id]
        # map depth range onto node-strength range
        idx = int(round(d / max(max_d, 1) * (n - 1)))
        # round-robin among equal-depth operators across neighboring nodes
        bump = rr.get(d, 0)
        rr[d] = bump + 1
        idx = min(n - 1, idx + (bump % 2))
        assign[op.op_id] = by_strength[idx].node_id
    p = Placement.of(assign)
    if not valid_candidate(query, cluster, p):
        # fall back: everything on the strongest node is always valid
        p = Placement.of([by_strength[-1].node_id] * query.n_ops())
    return p


def enumerate_candidates(
    query: Query,
    cluster: Cluster,
    k: int,
    rng: np.random.Generator,
    max_tries_factor: int = 30,
) -> List[Placement]:
    """Sample up to ``k`` distinct rule-respecting placement candidates."""
    bins = cluster.bins()
    nodes_by_bin: List[List[int]] = [[], [], []]
    for i, b in enumerate(bins):
        nodes_by_bin[b].append(i)

    depths = query.depths()
    topo = query.topological_order()
    out: List[Placement] = []
    seen: Set[Tuple[int, ...]] = set()
    tries = 0
    while len(out) < k and tries < k * max_tries_factor:
        tries += 1
        assign = [-1] * query.n_ops()
        ok = True
        for u in topo:
            parents = query.parents(u)
            min_bin = max((bins[assign[p]] for p in parents), default=0)
            # choose a host with bin >= min_bin, biased towards staying close
            options = [i for i in range(cluster.n_nodes()) if bins[i] >= min_bin]
            if not options:
                ok = False
                break
            # co-location bias: reuse a parent's host 40% of the time
            if parents and rng.random() < 0.4:
                assign[u] = assign[parents[int(rng.integers(0, len(parents)))]]
            else:
                assign[u] = int(options[int(rng.integers(0, len(options)))])
        if not ok:
            continue
        p = Placement.of(assign)
        if p.assignment in seen:
            continue
        if not valid_candidate(query, cluster, p):
            continue
        seen.add(p.assignment)
        out.append(p)
    return out
