"""Cost-based placement optimizer (paper SV, Fig. 4).

Enumerate candidates -> score all of them with the COSTREAM ensembles in ONE
batched jit call per metric (candidates along the batch axis — the TPU-native
analogue of the paper's "parallel COSTREAM instances") -> filter out
candidates predicted unsuccessful or backpressured via majority vote -> pick
the argopt of the target metric.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import JointGraph, batch_graphs, build_graph
from repro.core.model import CostModelConfig, predict
from repro.dsps.hardware import Cluster
from repro.dsps.placement import Placement
from repro.dsps.query import Query
from repro.placement.enumerate import enumerate_candidates


@dataclass
class OptimizerResult:
    placement: Placement
    predicted: Dict[str, float]
    n_candidates: int
    n_feasible: int
    candidates: List[Placement]
    scores: np.ndarray  # predicted target metric per candidate


class PlacementOptimizer:
    """Holds trained per-metric ensembles and selects initial placements.

    ``models``: dict metric -> (params, CostModelConfig). Requires the target
    metric plus (when available) "success" and "backpressure" for the sanity
    filter; missing filters degrade gracefully (paper's procedure needs them,
    our ablations can disable them).
    """

    def __init__(self, models: Dict[str, Tuple[object, CostModelConfig]]):
        self.models = models

    def score_candidates(
        self, query: Query, cluster: Cluster, candidates: List[Placement], metric: str
    ) -> np.ndarray:
        params, cfg = self.models[metric]
        singles = [build_graph(query, cluster, p) for p in candidates]
        # pad to a shape bucket so the jitted scorer doesn't retrace per count
        n = len(singles)
        bucket = 1 << max(0, (n - 1)).bit_length()
        singles = singles + [singles[-1]] * (bucket - n)
        graphs = batch_graphs(singles)
        graphs = jax.tree_util.tree_map(jnp.asarray, graphs)
        return predict(params, graphs, cfg)[:n]

    def optimize(
        self,
        query: Query,
        cluster: Cluster,
        target_metric: str = "latency_p",
        k: int = 64,
        rng: Optional[np.random.Generator] = None,
        minimize: Optional[bool] = None,
        require_feasible: bool = True,
    ) -> OptimizerResult:
        rng = rng or np.random.default_rng(0)
        candidates = enumerate_candidates(query, cluster, k, rng)
        assert candidates, "no valid placement candidates found"
        if minimize is None:
            minimize = target_metric != "throughput"

        feasible = np.ones(len(candidates), dtype=bool)
        if require_feasible:
            if "success" in self.models:
                s = self.score_candidates(query, cluster, candidates, "success")
                feasible &= s.astype(bool)
            if "backpressure" in self.models:
                b = self.score_candidates(query, cluster, candidates, "backpressure")
                feasible &= b.astype(bool)  # R_O = 1 means no backpressure
            if not feasible.any():
                feasible = np.ones(len(candidates), dtype=bool)  # nothing passes: rank all

        scores = self.score_candidates(query, cluster, candidates, target_metric)
        masked = np.where(feasible, scores, np.inf if minimize else -np.inf)
        best = int(np.argmin(masked) if minimize else np.argmax(masked))
        preds = {target_metric: float(scores[best])}
        return OptimizerResult(
            placement=candidates[best],
            predicted=preds,
            n_candidates=len(candidates),
            n_feasible=int(feasible.sum()),
            candidates=candidates,
            scores=scores,
        )
