"""Cost-based placement optimizer (paper SV, Fig. 4).

Vectorized single-materialization search pipeline:

  sample -> build once -> score all metrics -> refine -> argopt

1. ``sample_assignment_matrix`` draws the candidate set as an ``(N, n_ops)``
   matrix with batched rule checks (no per-candidate Python loop).
2. Scoring goes through the shared ``CostEstimator`` facade
   (``repro.serve.estimator``): skeleton built once per (query, cluster)
   pair (LRU-amortized across calls), ALL requested metric ensembles fused
   into one bucket-padded stacked forward per batch — the TPU-native
   analogue of the paper's "parallel COSTREAM instances".
3. An optional hill-climb refinement loop mutates the top-k candidates and
   re-scores the children through the same batched path, so search quality
   scales with compute instead of with the initial sample's luck.

Since the serving redesign (docs/api.md) this class is a thin *search
strategy* layer: all model state, caches, and forwards live on the
estimator; the optimizer contributes candidate sampling, the feasibility
filter, and the refinement loop.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.graph import batch_graphs, bucket_size, build_graph
from repro.core.model import CostModelConfig
from repro.dsps.hardware import Cluster
from repro.dsps.placement import Placement
from repro.dsps.query import Query
from repro.placement.enumerate import (
    dedup_assignments,
    mutate_assignments,
    sample_assignment_matrix,
)
from repro.serve.estimator import CostEstimator


@dataclass
class OptimizerResult:
    placement: Placement
    predicted: Dict[str, float]
    n_candidates: int
    n_feasible: int
    candidates: List[Placement]
    scores: np.ndarray  # predicted target metric per candidate


class PlacementOptimizer:
    """Selects initial placements by scoring candidates with a CostEstimator.

    Construct from a metric -> (params, CostModelConfig) dict (the legacy
    shape), an existing ``CostEstimator`` (shares its caches), or a saved
    bundle via ``from_bundle``.  Requires the target metric plus (when
    available) "success" and "backpressure" for the sanity filter; missing
    filters degrade gracefully (the paper's procedure needs them, our
    ablations can disable them).
    """

    def __init__(self, models):
        self.estimator = (
            models if isinstance(models, CostEstimator) else CostEstimator(models)
        )

    @classmethod
    def from_bundle(cls, bundle) -> "PlacementOptimizer":
        return cls(CostEstimator.from_bundle(bundle))

    @property
    def models(self) -> Dict[str, Tuple[object, CostModelConfig]]:
        return self.estimator.models

    def score_candidates(
        self, query: Query, cluster: Cluster, candidates: List[Placement], metric: str
    ) -> np.ndarray:
        """Legacy per-metric path: rebuilds the graph batch on every call.

        Kept as the reference implementation (and the benchmark baseline);
        prefer ``score_assignments`` / ``CostEstimator.score`` which build
        once for all metrics.
        """
        singles = [build_graph(query, cluster, p) for p in candidates]
        # pad to a shape bucket so the jitted scorer doesn't retrace per count
        n = len(singles)
        singles = singles + [singles[-1]] * (bucket_size(n) - n)
        return self.estimator.estimate(batch_graphs(singles), [metric])[metric][:n]

    def score_assignments(
        self,
        query: Query,
        cluster: Cluster,
        assignments: np.ndarray,
        metrics: Sequence[str],
    ) -> Dict[str, np.ndarray]:
        """Fast path: build the candidate batch ONCE, score every metric on it.

        Delegates to ``CostEstimator.score`` (docs/api.md); returns metric ->
        ``(N,)`` predictions, bucket- and batchmate-independent.
        """
        return self.estimator.score(query, cluster, assignments, metrics)

    @staticmethod
    def _feasible_mask(
        scores: Dict[str, np.ndarray], n: int, filter_metrics: Sequence[str]
    ) -> np.ndarray:
        feasible = np.ones(n, dtype=bool)
        for m in filter_metrics:
            feasible &= scores[m].astype(bool)  # 1 = success / no backpressure
        if not feasible.any():
            feasible = np.ones(n, dtype=bool)  # nothing passes: rank all
        return feasible

    def optimize(
        self,
        query: Query,
        cluster: Cluster,
        target_metric: str = "latency_p",
        k: Optional[int] = None,
        rng: Optional[np.random.Generator] = None,
        minimize: Optional[bool] = None,
        require_feasible: bool = True,
        refine_rounds: int = 0,
        refine_top: Optional[int] = None,
        refine_mutations: int = 4,
    ) -> OptimizerResult:
        """``refine_rounds`` is opt-in: hill-climbing maximizes the *predicted*
        objective, which with a weak model can chase model error instead of
        real cost. Enable it (2-3 rounds) for well-trained ensembles or
        oracle scorers; the default matches the paper's sample-and-argopt.

        ``k`` (candidate pool) and ``refine_top`` (elites per round) default
        from the estimator's ``DispatchPolicy`` (``search_k``/``refine_top``):
        search breadth is a cost/accuracy dial the host profile owns."""
        policy = self.estimator.policy
        k = policy.search_k if k is None else k
        refine_top = policy.refine_top if refine_top is None else refine_top
        rng = rng or np.random.default_rng(0)
        pool = sample_assignment_matrix(query, cluster, k, rng)
        assert len(pool), "no valid placement candidates found"
        if minimize is None:
            minimize = target_metric != "throughput"

        filter_metrics = (
            [m for m in ("success", "backpressure") if m in self.models]
            if require_feasible
            else []
        )
        metrics = [target_metric] + [m for m in filter_metrics if m != target_metric]
        if type(self).score_assignments is PlacementOptimizer.score_assignments:
            score = self.estimator.scorer(query, cluster, metrics)
        else:
            # subclass supplies its own scoring (e.g. a simulator oracle in
            # tests); honor the override instead of the hoisted fast path
            score = lambda a: self.score_assignments(query, cluster, a, metrics)
        scores = score(pool)

        worst = np.inf if minimize else -np.inf

        def masked_target() -> np.ndarray:
            feasible = self._feasible_mask(scores, len(pool), filter_metrics)
            return np.where(feasible, scores[target_metric], worst)

        for _ in range(refine_rounds):
            ranked = np.argsort(masked_target())
            if not minimize:
                ranked = ranked[::-1]
            elites = pool[ranked[:refine_top]]
            children = mutate_assignments(query, cluster, elites, refine_mutations, rng)
            # drop children already in the pool (dedup keeps first occurrence)
            children = dedup_assignments(np.concatenate([pool, children]))[len(pool) :]
            if not len(children):
                break
            child_scores = score(children)
            pool = np.concatenate([pool, children])
            scores = {m: np.concatenate([scores[m], child_scores[m]]) for m in metrics}

        feasible = self._feasible_mask(scores, len(pool), filter_metrics)
        masked = masked_target()
        best = int(np.argmin(masked) if minimize else np.argmax(masked))
        preds = {m: float(scores[m][best]) for m in metrics}
        return OptimizerResult(
            placement=Placement.of(pool[best]),
            predicted=preds,
            n_candidates=len(pool),
            n_feasible=int(feasible.sum()),
            candidates=[Placement.of(row) for row in pool],
            scores=scores[target_metric],
        )
