"""Cost-based placement optimizer (paper SV, Fig. 4).

Vectorized single-materialization search pipeline:

  sample -> build once -> score all metrics -> refine -> argopt

1. ``sample_assignment_matrix`` draws the candidate set as an ``(N, n_ops)``
   matrix with batched rule checks (no per-candidate Python loop).
2. ``build_graph_batch`` materializes the padded ``JointGraph`` batch in one
   pass — query/cluster features are placement-invariant, only ``a_place``
   varies per candidate.
3. ``predict_metrics`` runs ALL requested metric ensembles (target +
   success/backpressure feasibility filters) over the same device-resident
   batch, padded to power-of-two buckets so the jitted forwards never retrace
   per candidate count (the TPU-native analogue of the paper's "parallel
   COSTREAM instances").
4. An optional hill-climb refinement loop mutates the top-k candidates and
   re-scores the children through the same batched path, so search quality
   scales with compute instead of with the initial sample's luck.
"""

from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import (
    JointGraph,
    batch_graphs,
    bucket_size,
    build_a_place_batch,
    build_graph,
    build_graph_batch,
    build_graph_skeleton,
    pad_batch,
    query_static,
    skeleton_cache_key,
)
from repro.core.model import (
    CostModelConfig,
    predict,
    predict_metrics,
    predict_placements,
    predict_placements_fused,
    stack_metric_models,
)
from repro.dsps.hardware import Cluster
from repro.dsps.placement import Placement
from repro.dsps.query import Query
from repro.placement.enumerate import (
    dedup_assignments,
    mutate_assignments,
    sample_assignment_matrix,
)


@dataclass
class OptimizerResult:
    placement: Placement
    predicted: Dict[str, float]
    n_candidates: int
    n_feasible: int
    candidates: List[Placement]
    scores: np.ndarray  # predicted target metric per candidate


class PlacementOptimizer:
    """Holds trained per-metric ensembles and selects initial placements.

    ``models``: dict metric -> (params, CostModelConfig). Requires the target
    metric plus (when available) "success" and "backpressure" for the sanity
    filter; missing filters degrade gracefully (paper's procedure needs them,
    our ablations can disable them).

    Per-(query, cluster) state — the featurized skeleton, its device
    transfer, and the trace-time ``QueryStatic`` — is cached across
    ``optimize``/``score_assignments`` calls (keyed structurally via
    ``skeleton_cache_key``, LRU-bounded by ``skeleton_cache_size``): the
    online-monitoring pattern re-scores the same query every round, and
    rebuilding the skeleton per call was pure waste.  The per-metric
    ensembles are fused into one stacked forward per scoring call when their
    configs are shape-identical (``stack_metric_models``); heterogeneous
    configs fall back to the per-metric loop.
    """

    skeleton_cache_size = 64  # (query, cluster) pairs kept device-resident

    def __init__(self, models: Dict[str, Tuple[object, CostModelConfig]]):
        self.models = models
        self._skeletons: "OrderedDict[Tuple, Tuple[JointGraph, object]]" = OrderedDict()
        self._stacked: Dict[Tuple[str, ...], object] = {}

    def _skeleton_for(self, query: Query, cluster: Cluster):
        """Cached (device-resident skeleton, QueryStatic) for one pair."""
        key = skeleton_cache_key(query, cluster)
        hit = self._skeletons.get(key)
        if hit is not None:
            self._skeletons.move_to_end(key)
            return hit
        skel = jax.tree_util.tree_map(jnp.asarray, build_graph_skeleton(query, cluster))
        entry = (skel, query_static(query))
        self._skeletons[key] = entry
        while len(self._skeletons) > self.skeleton_cache_size:
            self._skeletons.popitem(last=False)
        return entry

    def _stacked_for(self, metrics: Tuple[str, ...]):
        """Fused ensemble stack for ``metrics``, or None if not fusable."""
        if metrics not in self._stacked:
            try:
                self._stacked[metrics] = stack_metric_models(self.models, metrics)
            except ValueError:  # heterogeneous per-metric configs
                self._stacked[metrics] = None
        return self._stacked[metrics]

    def score_candidates(
        self, query: Query, cluster: Cluster, candidates: List[Placement], metric: str
    ) -> np.ndarray:
        """Legacy per-metric path: rebuilds the graph batch on every call.

        Kept as the reference implementation (and the benchmark baseline);
        prefer ``score_assignments`` which builds once for all metrics.
        """
        params, cfg = self.models[metric]
        singles = [build_graph(query, cluster, p) for p in candidates]
        # pad to a shape bucket so the jitted scorer doesn't retrace per count
        n = len(singles)
        singles = singles + [singles[-1]] * (bucket_size(n) - n)
        graphs = batch_graphs(singles)
        graphs = jax.tree_util.tree_map(jnp.asarray, graphs)
        return predict(params, graphs, cfg)[:n]

    def score_assignments(
        self,
        query: Query,
        cluster: Cluster,
        assignments: np.ndarray,
        metrics: Sequence[str],
    ) -> Dict[str, np.ndarray]:
        """Fast path: build the candidate batch ONCE, score every metric on it.

        Returns metric -> ``(N,)`` predictions.  The batch is padded to the
        enclosing power-of-two bucket (see docs/placement_search.md) and the
        padding rows sliced off, so results are independent of the bucket.
        """
        return self._make_scorer(query, cluster, list(metrics))(
            np.asarray(assignments, dtype=np.int64)
        )

    def _make_scorer(self, query: Query, cluster: Cluster, metrics: Sequence[str]):
        """Scoring closure with the per-(query, cluster) work hoisted out.

        The refinement loop re-scores new candidates every round, and repeated
        ``optimize`` calls re-score the same query; the skeleton, its device
        transfer, and the trace-time ``QueryStatic`` are identical throughout,
        so they come from the instance-level cache (``_skeleton_for``).
        """
        metrics = tuple(metrics)
        if any(self.models[m][1].traditional_mp for m in metrics):
            # ablation models lack the 3-stage structure the specialized
            # forward exploits; build the full broadcast batch instead
            def score_generic(assignments: np.ndarray) -> Dict[str, np.ndarray]:
                n = len(assignments)
                assert n > 0, "no candidates to score"
                graphs = pad_batch(
                    build_graph_batch(query, cluster, assignments), bucket_size(n)
                )
                scored = predict_metrics({m: self.models[m] for m in metrics}, graphs)
                return {m: v[:n] for m, v in scored.items()}

            return score_generic

        skel, static = self._skeleton_for(query, cluster)
        stacked = self._stacked_for(metrics)

        def score(assignments: np.ndarray) -> Dict[str, np.ndarray]:
            n = len(assignments)
            assert n > 0, "no candidates to score"
            a_place = build_a_place_batch(query, cluster, assignments)
            pad = bucket_size(n) - n
            if pad:
                a_place = np.concatenate([a_place, np.repeat(a_place[-1:], pad, axis=0)])
            a_place = jnp.asarray(a_place)
            if stacked is not None:
                scored = predict_placements_fused(stacked, skel, a_place, static)
                return {m: v[:n] for m, v in scored.items()}
            return {
                m: predict_placements(
                    self.models[m][0], skel, a_place, static, self.models[m][1]
                )[:n]
                for m in metrics
            }

        return score

    @staticmethod
    def _feasible_mask(
        scores: Dict[str, np.ndarray], n: int, filter_metrics: Sequence[str]
    ) -> np.ndarray:
        feasible = np.ones(n, dtype=bool)
        for m in filter_metrics:
            feasible &= scores[m].astype(bool)  # 1 = success / no backpressure
        if not feasible.any():
            feasible = np.ones(n, dtype=bool)  # nothing passes: rank all
        return feasible

    def optimize(
        self,
        query: Query,
        cluster: Cluster,
        target_metric: str = "latency_p",
        k: int = 64,
        rng: Optional[np.random.Generator] = None,
        minimize: Optional[bool] = None,
        require_feasible: bool = True,
        refine_rounds: int = 0,
        refine_top: int = 8,
        refine_mutations: int = 4,
    ) -> OptimizerResult:
        """``refine_rounds`` is opt-in: hill-climbing maximizes the *predicted*
        objective, which with a weak model can chase model error instead of
        real cost. Enable it (2-3 rounds) for well-trained ensembles or
        oracle scorers; the default matches the paper's sample-and-argopt."""
        rng = rng or np.random.default_rng(0)
        pool = sample_assignment_matrix(query, cluster, k, rng)
        assert len(pool), "no valid placement candidates found"
        if minimize is None:
            minimize = target_metric != "throughput"

        filter_metrics = (
            [m for m in ("success", "backpressure") if m in self.models]
            if require_feasible
            else []
        )
        metrics = [target_metric] + [m for m in filter_metrics if m != target_metric]
        if type(self).score_assignments is PlacementOptimizer.score_assignments:
            score = self._make_scorer(query, cluster, metrics)
        else:
            # subclass supplies its own scoring (e.g. a simulator oracle in
            # tests); honor the override instead of the hoisted fast path
            score = lambda a: self.score_assignments(query, cluster, a, metrics)
        scores = score(pool)

        worst = np.inf if minimize else -np.inf

        def masked_target() -> np.ndarray:
            feasible = self._feasible_mask(scores, len(pool), filter_metrics)
            return np.where(feasible, scores[target_metric], worst)

        for _ in range(refine_rounds):
            ranked = np.argsort(masked_target())
            if not minimize:
                ranked = ranked[::-1]
            elites = pool[ranked[:refine_top]]
            children = mutate_assignments(query, cluster, elites, refine_mutations, rng)
            # drop children already in the pool (dedup keeps first occurrence)
            children = dedup_assignments(np.concatenate([pool, children]))[len(pool) :]
            if not len(children):
                break
            child_scores = score(children)
            pool = np.concatenate([pool, children])
            scores = {m: np.concatenate([scores[m], child_scores[m]]) for m in metrics}

        feasible = self._feasible_mask(scores, len(pool), filter_metrics)
        masked = masked_target()
        best = int(np.argmin(masked) if minimize else np.argmax(masked))
        preds = {m: float(scores[m][best]) for m in metrics}
        return OptimizerResult(
            placement=Placement.of(pool[best]),
            predicted=preds,
            n_candidates=len(pool),
            n_feasible=int(feasible.sum()),
            candidates=[Placement.of(row) for row in pool],
            scores=scores[target_metric],
        )
