"""Online-monitoring rescheduling baseline (paper Exp 2b, after [1, 11]).

Storm-style adaptive scheduling: start from the heuristic placement, monitor
runtime statistics (here: the simulator's host utilizations), and migrate the
most loaded operator to a stronger/less-utilized host every monitoring
interval, paying a migration overhead. We report (a) the initial slow-down
vs. the COSTREAM-chosen placement and (b) the *monitoring overhead*: the time
until the rescheduler reaches a placement competitive with COSTREAM's initial
one.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Tuple

import numpy as np

from repro.dsps.hardware import Cluster, hardware_bin
from repro.dsps.placement import Placement
from repro.dsps.query import OpType, Query
from repro.dsps.simulator import SimulatorConfig, analyze_operators, simulate, _dtype_mix


@dataclass
class MonitoringResult:
    initial_latency: float  # L_p of the heuristic initial placement
    final_latency: float
    target_latency: float  # L_p of the COSTREAM placement to beat
    steps: List[float]  # L_p after each monitoring round
    overhead_seconds: float  # time until competitive (inf if never)
    migrations: int


def _host_utilizations(query: Query, cluster: Cluster, placement: Placement) -> np.ndarray:
    """Monitoring signal: per-host CPU utilization (what Storm exposes)."""
    rt = analyze_operators(query, _dtype_mix(query))
    load = np.zeros(cluster.n_nodes())
    for op in query.operators:
        n = placement.node_of(op.op_id)
        load[n] += rt[op.op_id].rate_in * rt[op.op_id].service_ms / 1e3
    caps = np.array([node.cores() for node in cluster.nodes])
    return load / np.maximum(caps, 1e-9)


def online_monitoring_run(
    query: Query,
    cluster: Cluster,
    initial: Placement,
    target_latency: float,
    monitor_interval_s: float = 30.0,
    migration_cost_s: float = 12.0,
    max_rounds: int = 12,
    sim: SimulatorConfig = SimulatorConfig(),
    rng: Optional[np.random.Generator] = None,
) -> MonitoringResult:
    rng = rng or np.random.default_rng(0)
    placement = initial
    labels = simulate(query, cluster, placement, sim, rng=rng)
    initial_latency = labels.latency_p
    lat = initial_latency
    steps = [lat]
    elapsed = monitor_interval_s  # first stats need one interval to stabilize
    migrations = 0
    overhead = np.inf if lat > target_latency else 0.0

    for _ in range(max_rounds):
        if lat <= target_latency:
            overhead = min(overhead, elapsed)
            break
        util = _host_utilizations(query, cluster, placement)
        hot = int(np.argmax(util))
        ops_on_hot = [i for i in range(query.n_ops()) if placement.node_of(i) == hot]
        movable = [i for i in ops_on_hot if query.op(i).op_type != OpType.SOURCE]
        if not movable:
            elapsed += monitor_interval_s
            continue
        # move the heaviest movable operator to the least-utilized stronger host
        bins = cluster.bins()
        order = np.argsort(util)
        dest = None
        for cand in order:
            if cand != hot and bins[int(cand)] >= bins[hot]:
                dest = int(cand)
                break
        if dest is None:
            dest = int(order[0])
        victim = movable[-1]
        assign = list(placement.assignment)
        assign[victim] = dest
        placement = Placement.of(assign)
        migrations += 1
        elapsed += monitor_interval_s + migration_cost_s
        labels = simulate(query, cluster, placement, sim, rng=rng)
        lat = labels.latency_p
        steps.append(lat)
        if lat <= target_latency:
            overhead = min(overhead, elapsed)
            break

    return MonitoringResult(
        initial_latency=initial_latency,
        final_latency=lat,
        target_latency=target_latency,
        steps=steps,
        overhead_seconds=float(overhead),
        migrations=migrations,
    )
