"""COSTREAM reproduction: learned cost models for operator placement in
edge-cloud environments (arXiv 2403.08444), grown into a JAX/Pallas serving
system.

``import repro`` exposes the stable surface — train a model, bundle it, serve
it (docs/api.md):

    WorkloadGenerator   corpus of (query, cluster, placement, labels) traces
    CostModelConfig     per-metric GNN ensemble configuration
    CostModelBundle     versioned on-disk artifact of all trained ensembles
    CostEstimator       the single inference facade (estimate/score/optimize)
    PlacementService    micro-batching front-end for concurrent requests
    PlacementOptimizer  search strategy layer (sample -> score -> refine)
    PlacementController closed-loop drift-aware re-placement (docs/controller.md)
    DispatchPolicy      host-calibrated dispatch tunables (docs/dispatch.md)

Deeper layers (``repro.core`` engine, ``repro.dsps`` substrate,
``repro.training`` loops, ``repro.kernels`` Pallas kernels) remain importable
directly but are not version-stable.

0.7 removed the deprecated ``core.model.predict_*`` shims; the facade is the
one inference surface (docs/api.md).
"""

__version__ = "0.7.0"

from repro.control import PlacementController
from repro.core.model import CostModelConfig
from repro.dsps.generator import WorkloadGenerator
from repro.serve import (
    BundleSwapper,
    CircuitBreaker,
    CostEstimator,
    CostModelBundle,
    DispatchPolicy,
    PlacementService,
    ShadowRejected,
)
from repro.placement.optimizer import PlacementOptimizer

__all__ = [
    "BundleSwapper",
    "CircuitBreaker",
    "CostEstimator",
    "CostModelBundle",
    "CostModelConfig",
    "DispatchPolicy",
    "PlacementController",
    "PlacementOptimizer",
    "PlacementService",
    "ShadowRejected",
    "WorkloadGenerator",
    "__version__",
]
