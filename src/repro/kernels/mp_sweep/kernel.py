"""Pallas kernel: the ENTIRE stage-3 SOURCES->OPS sweep in one launch.

Where ``mp_update`` fuses one depth step (and the banded engine launches it
once per level, round-tripping the (B, N, H) state through HBM each time),
this kernel walks the whole static banding table inside a single
``pl.pallas_call``:

  for (d, [s, e), slot_ranges, p) in levels:        # compile-time constants
      msg = a_flow[:p, s:e]^T @ h[:p]               # parent aggregation
      upd = MLP'_{T(v)}([h[s:e], msg])              # banked 2-layer update
      h[s:e] = where(depth == d & mask, upd, h[s:e])

The row tile of ``h`` is read from HBM once, carried through all L levels as
a VMEM-resident value (Pallas grid pipelining double-buffers the next tile's
loads behind the current tile's compute), and written once — 1 launch and
one read+write of the state per forward instead of L of each.  The banked
``op_upd`` weights are loaded per launch and stay resident for the whole
sweep; the banding table itself occupies no memory at all — spans, slot
ranges, and parent bounds are Python constants baked into the unrolled loop.

VMEM budget (v5e, fp32, TB=128, N=12, H=64): h 384 KiB, a_flow 576 KiB,
weights (T=5) ~1.2 MiB, per-level intermediates < 1 MiB — the sweep reuses
one level's working set, so residency matches ``mp_update``'s.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(h_ref, a_ref, depth_ref, mask_ref, w1_ref, b1_ref, w2_ref, b2_ref, out_ref, *, levels):
    h = h_ref[...]  # (TB, N, H): loaded ONCE, updated across all levels
    n = h.shape[1]
    for d, (s, e), slot_ranges, p in levels:
        # 1. parent aggregation for the level's rows against possible parents:
        #    msg[b, v] = sum_{u < p} a[b, u, v] * h[b, u]  for v in [s, e)
        a = a_ref[:, :p, s:e]  # static slice
        msg = jax.lax.dot_general(
            a, h[:, :p], (((1,), (1,)), ((0,), (0,))), preferred_element_type=jnp.float32
        )  # contract over u -> (TB, e-s, H)
        # 2. concat + 3. banked MLP over the level's static slot ranges
        z = jnp.concatenate([h[:, s:e, :], msg], axis=-1)  # (TB, e-s, 2H)
        outs = []
        for t, start, stop in slot_ranges:
            zs = z[:, start - s : stop - s, :]
            hid = jnp.maximum(
                jax.lax.dot_general(
                    zs, w1_ref[t], (((2,), (0,)), ((), ())), preferred_element_type=jnp.float32
                )
                + b1_ref[t],
                0.0,
            )
            outs.append(
                jax.lax.dot_general(
                    hid, w2_ref[t], (((2,), (0,)), ((), ())), preferred_element_type=jnp.float32
                )
                + b2_ref[t]
            )
        upd = jnp.concatenate(outs, axis=1)
        # 4. depth select inside the span; the state value (not HBM) carries
        #    the update into the next level's aggregation
        sel = (depth_ref[:, s:e] == d) & (mask_ref[:, s:e] > 0)
        new = jnp.where(sel[..., None], upd, h[:, s:e]).astype(h.dtype)
        pieces = ([h[:, :s]] if s else []) + [new] + ([h[:, e:]] if e < n else [])
        h = pieces[0] if len(pieces) == 1 else jnp.concatenate(pieces, axis=1)
    out_ref[...] = h.astype(out_ref.dtype)


def mp_sweep_pallas(
    params,
    h: jax.Array,  # (B, N, H)
    a_flow: jax.Array,  # (B, N, N)
    depth: jax.Array,  # (B, N) int32
    mask: jax.Array,  # (B, N) float32
    levels,  # ((d, (s, e), slot_ranges, parent_rows | None), ...) static
    tile_b: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """One ``pallas_call`` for the whole banded sweep; ``levels`` are the
    banding's per-level constants (``gnn.StagePlan("sweep").levels``): depth
    value, contiguous ``row_span`` the level updates, slot ranges tiling the
    span in absolute row indices, and the ``parent_rows`` contraction bound
    (``None`` = full row axis)."""
    l1, l2 = params["layers"]
    w1, b1, w2, b2 = l1["w"], l1["b"], l2["w"], l2["b"]
    B, N, H = h.shape
    tb = min(tile_b, B)
    assert B % tb == 0
    norm_levels = []
    for d, span, slot_ranges, parent_rows in levels:
        s, e = (0, N) if span is None else (int(span[0]), int(span[1]))
        assert 0 <= s < e <= N, (span, N)
        edge = s  # the per-range outputs are concatenated back over the span
        for t, start, stop in slot_ranges:
            assert start == edge and start < stop <= e, (
                f"slot ranges must tile row span {(s, e)} contiguously, got {slot_ranges}"
            )
            edge = stop
        assert edge == e, (slot_ranges, (s, e))
        p = N if parent_rows is None else int(parent_rows)
        assert 0 < p <= N, (p, N)
        norm_levels.append((int(d), (s, e), tuple(slot_ranges), p))
    return pl.pallas_call(
        functools.partial(_kernel, levels=tuple(norm_levels)),
        grid=(B // tb,),
        in_specs=[
            pl.BlockSpec((tb, N, H), lambda i: (i, 0, 0)),
            pl.BlockSpec((tb, N, N), lambda i: (i, 0, 0)),
            pl.BlockSpec((tb, N), lambda i: (i, 0)),
            pl.BlockSpec((tb, N), lambda i: (i, 0)),
            pl.BlockSpec(w1.shape, lambda i: (0, 0, 0)),
            pl.BlockSpec(b1.shape, lambda i: (0, 0)),
            pl.BlockSpec(w2.shape, lambda i: (0, 0, 0)),
            pl.BlockSpec(b2.shape, lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tb, N, H), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, N, H), h.dtype),
        interpret=interpret,
    )(h, a_flow, depth, mask, w1, b1, w2, b2)
