"""jit'd wrapper for the fused depth-sweep kernel (custom_vjp via oracle).

Per-backend lowering as in ``kernels/mp_update/ops.py``: Pallas kernel on
TPU, jnp oracle off-TPU (``REPRO_PALLAS_INTERPRET=1`` forces the interpreter
for parity testing), oracle VJP for the backward everywhere.  The row-tile
cap comes from the active ``DispatchPolicy.sweep_tile_rows`` (an autotune
target, not a fresh constant).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import active_lowering as _lowering
from repro.kernels.common import largest_tile as _largest_tile
from repro.kernels.mp_sweep.kernel import mp_sweep_pallas
from repro.kernels.mp_sweep.ref import mp_sweep_ref


def _tile_cap() -> int:
    from repro.serve.policy import active_policy  # lazy: kernels never pull serve at import

    return active_policy().sweep_tile_rows


@partial(jax.custom_vjp, nondiff_argnums=(5,))
def _mp_sweep(params, h, a_flow, depth, mask, levels):
    mode = _lowering()
    if mode == "ref":
        # the oracle broadcasts shared (N,N)/(N,) fields itself — keeping
        # a_flow unbatched lets XLA lower each aggregation as one GEMM
        return mp_sweep_ref(params, h, a_flow, depth, mask, levels)
    squeeze = h.ndim == 2
    if squeeze:
        h, a_flow, depth, mask = h[None], a_flow[None], depth[None], mask[None]
    elif h.ndim == 3:  # the Pallas kernel needs every operand batched
        b = h.shape[0]
        if a_flow.ndim == 2:
            a_flow = jnp.broadcast_to(a_flow, (b,) + a_flow.shape)
        if depth.ndim == 1:
            depth = jnp.broadcast_to(depth, (b,) + depth.shape)
        if mask.ndim == 1:
            mask = jnp.broadcast_to(mask, (b,) + mask.shape)
    out = mp_sweep_pallas(
        params,
        h,
        a_flow,
        depth,
        mask,
        levels,
        tile_b=_largest_tile(h.shape[0], _tile_cap()),
        interpret=mode == "interpret",
    )
    return out[0] if squeeze else out


def _fwd(params, h, a_flow, depth, mask, levels):
    return _mp_sweep(params, h, a_flow, depth, mask, levels), (params, h, a_flow, depth, mask)


def _bwd(levels, res, g):
    params, h, a_flow, depth, mask = res
    _, vjp = jax.vjp(
        lambda p, hh, aa: mp_sweep_ref(p, hh, aa, depth, mask, levels),
        params,
        h,
        a_flow,
    )
    dp, dh, da = vjp(g)
    return dp, dh, da, None, None


_mp_sweep.defvjp(_fwd, _bwd)


def mp_sweep(params, h, a_flow, depth, mask, levels):
    """Fused stage-3 sweep: every banding level in ONE kernel launch.

    ``levels`` is the static banding table — per level ``(d, row_span,
    slot_ranges, parent_rows)`` exactly as ``gnn.StagePlan`` carries it; it
    is baked into the kernel as compile-time constants (and into the jit
    trace key via ``nondiff_argnums``).  ``a_flow``/``depth``/``mask`` may be
    unbatched while ``h`` is batched, as in ``mp_update`` — the Pallas and
    interpret lowerings broadcast the shared fields inside the custom_vjp
    primal so gradients transpose back correctly.
    """
    if len(params["layers"]) != 2:  # loud even under python -O (no silent fallback)
        raise NotImplementedError(
            f"Pallas mp-sweep kernel fuses exactly two layers, got {len(params['layers'])}"
        )
    norm = tuple(
        (
            int(d),
            None if span is None else (int(span[0]), int(span[1])),
            tuple(slot_ranges),
            None if parent_rows is None else int(parent_rows),
        )
        for d, span, slot_ranges, parent_rows in levels
    )
    if not norm:  # a depth-0-only batch has no sweep work at all
        return h
    return _mp_sweep(params, h, a_flow, depth, mask, norm)
