"""Fused stage-3 depth sweep: the whole banded data-flow pass in ONE launch.

``mp_update`` runs one depth level per ``pl.pallas_call`` — L launches and L
full-state HBM round-trips per forward.  ``mp_sweep`` bakes the static
banding table (per-level depth, ``row_span``, slot ranges, ``parent_rows``)
into the kernel as compile-time constants and walks every level inside one
call: the hidden-state row tile is read once, updated in registers/VMEM
across all levels, and written once.
"""

from repro.kernels.mp_sweep.ops import mp_sweep  # noqa: F401
