"""Pure-jnp oracle for the fused stage-3 depth sweep.

The sweep is, by definition, the sequential composition of one
``mp_update_ref`` step per banding level — this oracle IS that loop, so the
fused kernel's parity target and the pre-fusion banded engine are the same
function.  ``apply_fn`` is injected like ``mp_update_ref``'s: the jnp banded
path passes ``nn.apply_mlp_bank_slotted`` so >2-layer (unfusable) banks keep
working through the same code.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.banked_mlp.ref import banked_mlp_slotted_ref
from repro.kernels.mp_update.ref import mp_update_ref


def mp_sweep_ref(
    params,
    h: jax.Array,  # (..., N, H)
    a_flow: jax.Array,  # (..., N, N)  a_flow[u, v] = 1 iff u -> v
    depth: jax.Array,  # (..., N) int32
    mask: jax.Array,  # (..., N) float {0,1}
    levels,  # ((d, row_span, slot_ranges, parent_rows), ...) static
    apply_fn=banked_mlp_slotted_ref,
) -> jax.Array:
    """Run every banding level's depth step in topological order."""
    for d, span, slot_ranges, parent_hi in levels:
        h = mp_update_ref(
            params,
            h,
            a_flow,
            depth,
            mask,
            jnp.asarray(d, depth.dtype),
            slot_ranges,
            row_span=span,
            parent_rows=parent_hi,
            apply_fn=apply_fn,
        )
    return h
