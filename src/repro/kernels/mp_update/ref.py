"""Pure-jnp oracle for the fused message-passing depth step."""

from __future__ import annotations

from typing import Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.banked_mlp.ref import banked_mlp_slotted_ref


def mp_update_ref(
    params,
    h: jax.Array,  # (..., N, H)
    a_flow: jax.Array,  # (..., N, N)  a_flow[u, v] = 1 iff u -> v
    depth: jax.Array,  # (..., N) int32
    mask: jax.Array,  # (..., N) float {0,1}
    d: jax.Array,  # scalar int32: the depth level being updated
    slot_ranges: Sequence[Tuple[int, int, int]],
    row_span=None,  # static (s, e): restrict the update to rows [s, e)
    parent_rows=None,  # static p: a_flow[u, v] == 0 for u >= p, v in the span
    apply_fn=banked_mlp_slotted_ref,  # (params, x, slot_ranges) -> y
) -> jax.Array:
    """One SOURCES->OPS depth step: aggregate parents, update, select.

    With ``row_span=(s, e)`` only rows [s, e) are aggregated/updated (the
    ``slot_ranges`` are absolute row indices inside the span); rows outside
    pass through — mirrors the kernel's static-span fast path.
    ``parent_rows`` bounds the aggregation's contraction like the kernel's.
    This function owns the span geometry for every jnp consumer: the banded
    training sweep passes its own banked-MLP ``apply_fn`` (supporting >2
    layers) instead of re-implementing the slicing.
    """
    if row_span is None:
        msg = jnp.swapaxes(a_flow, -1, -2) @ h  # msg[v] = sum_{u: u->v} h[u]
        upd = apply_fn(params, jnp.concatenate([h, msg], axis=-1), slot_ranges)
        sel = ((depth == d) & (mask > 0))[..., None]
        return jnp.where(sel, upd, h)
    s, e = row_span
    p = a_flow.shape[-2] if parent_rows is None else parent_rows
    msg = jnp.swapaxes(a_flow[..., :p, s:e], -1, -2) @ h[..., :p, :]  # (..., e-s, H)
    z = jnp.concatenate([h[..., s:e, :], msg], axis=-1)
    shifted = tuple((t, start - s, stop - s) for t, start, stop in slot_ranges)
    upd = apply_fn(params, z, shifted)
    sel = ((depth[..., s:e] == d) & (mask[..., s:e] > 0))[..., None]
    return jnp.concatenate(
        [h[..., :s, :], jnp.where(sel, upd, h[..., s:e, :]), h[..., e:, :]], axis=-2
    )
