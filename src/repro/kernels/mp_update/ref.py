"""Pure-jnp oracle for the fused message-passing depth step."""

from __future__ import annotations

from typing import Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.banked_mlp.ref import banked_mlp_slotted_ref


def mp_update_ref(
    params,
    h: jax.Array,  # (..., N, H)
    a_flow: jax.Array,  # (..., N, N)  a_flow[u, v] = 1 iff u -> v
    depth: jax.Array,  # (..., N) int32
    mask: jax.Array,  # (..., N) float {0,1}
    d: jax.Array,  # scalar int32: the depth level being updated
    slot_ranges: Sequence[Tuple[int, int, int]],
) -> jax.Array:
    """One SOURCES->OPS depth step: aggregate parents, update, select."""
    msg = jnp.swapaxes(a_flow, -1, -2) @ h  # msg[v] = sum_{u: u->v} h[u]
    upd = banked_mlp_slotted_ref(params, jnp.concatenate([h, msg], axis=-1), slot_ranges)
    sel = ((depth == d) & (mask > 0))[..., None]
    return jnp.where(sel, upd, h)
