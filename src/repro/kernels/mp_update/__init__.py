from repro.kernels.mp_update import kernel, ops, ref

__all__ = ["kernel", "ops", "ref"]
