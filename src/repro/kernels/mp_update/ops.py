"""jit'd wrapper for the fused MP depth-step kernel (custom_vjp via oracle).

Per-backend lowering as in ``kernels/banked_mlp/ops.py``: Pallas kernel on
TPU, jnp oracle off-TPU (``REPRO_PALLAS_INTERPRET=1`` forces the interpreter
for parity testing), oracle VJP for the backward everywhere.
"""

from __future__ import annotations

from functools import partial
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import active_lowering as _lowering
from repro.kernels.common import largest_tile as _largest_tile
from repro.kernels.mp_update.kernel import mp_update_pallas
from repro.kernels.mp_update.ref import mp_update_ref


@partial(jax.custom_vjp, nondiff_argnums=(6, 7, 8))
def _mp_update(params, h, a_flow, depth, mask, d, slot_ranges, row_span, parent_rows):
    mode = _lowering()
    if mode == "ref":
        # the oracle broadcasts shared (N,N)/(N,) fields itself — keeping
        # a_flow unbatched here lets XLA lower the aggregation as one GEMM
        # instead of a per-candidate batched matmul
        return mp_update_ref(
            params, h, a_flow, depth, mask, d, slot_ranges, row_span, parent_rows
        )
    squeeze = h.ndim == 2
    if squeeze:
        h, a_flow, depth, mask = h[None], a_flow[None], depth[None], mask[None]
    elif h.ndim == 3:  # the Pallas kernel needs every operand batched
        b = h.shape[0]
        if a_flow.ndim == 2:
            a_flow = jnp.broadcast_to(a_flow, (b,) + a_flow.shape)
        if depth.ndim == 1:
            depth = jnp.broadcast_to(depth, (b,) + depth.shape)
        if mask.ndim == 1:
            mask = jnp.broadcast_to(mask, (b,) + mask.shape)
    out = mp_update_pallas(
        params,
        h,
        a_flow,
        depth,
        mask,
        d,
        slot_ranges,
        tile_b=_largest_tile(h.shape[0]),
        interpret=mode == "interpret",
        row_span=row_span,
        parent_rows=parent_rows,
    )
    return out[0] if squeeze else out


def _fwd(params, h, a_flow, depth, mask, d, slot_ranges, row_span, parent_rows):
    return _mp_update(
        params, h, a_flow, depth, mask, d, slot_ranges, row_span, parent_rows
    ), (
        params,
        h,
        a_flow,
        depth,
        mask,
        d,
    )


def _bwd(slot_ranges, row_span, parent_rows, res, g):
    params, h, a_flow, depth, mask, d = res
    _, vjp = jax.vjp(
        lambda p, hh, aa: mp_update_ref(
            p, hh, aa, depth, mask, d, slot_ranges, row_span, parent_rows
        ),
        params,
        h,
        a_flow,
    )
    dp, dh, da = vjp(g)
    return dp, dh, da, None, None, None


_mp_update.defvjp(_fwd, _bwd)


def mp_update(
    params,
    h,
    a_flow,
    depth,
    mask,
    d,
    slot_ranges: Sequence[Tuple[int, int, int]],
    row_span: Tuple[int, int] = None,
    parent_rows: int = None,
):
    """Fused stage-3 depth step: aggregate -> concat -> banked MLP -> select.

    ``a_flow``/``depth``/``mask`` may be unbatched ``(N, N)`` / ``(N,)`` while
    ``h`` is batched ``(B, N, H)`` — the placement-specialized forward shares
    one graph skeleton across all candidates.  The Pallas/interpret lowerings
    broadcast the shared fields to the batch (inside the custom_vjp primal, so
    gradients transpose back correctly); the jnp-oracle lowering keeps them
    unbatched and lets XLA lower the aggregation as one GEMM.

    ``row_span=(s, e)`` statically restricts aggregation/update/select to
    rows [s, e) (``slot_ranges`` must tile the span); rows outside pass
    through untouched.  The placed path sorts slots by depth so each depth
    level is one such span — the dense work of provably-unselected rows
    vanishes while the step stays a single fused launch.  ``parent_rows=p``
    additionally bounds the aggregation's contraction to rows [0, p) (valid
    when ``a_flow[u >= p, span] == 0``, as in the depth-major layout).
    """
    if len(params["layers"]) != 2:  # loud even under python -O (no silent fallback)
        raise NotImplementedError(
            f"Pallas mp-update kernel fuses exactly two layers, got {len(params['layers'])}"
        )
    span = None if row_span is None else (int(row_span[0]), int(row_span[1]))
    p = None if parent_rows is None else int(parent_rows)
    return _mp_update(params, h, a_flow, depth, mask, d, tuple(slot_ranges), span, p)
