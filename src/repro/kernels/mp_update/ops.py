"""jit'd wrapper for the fused MP depth-step kernel (custom_vjp via oracle)."""

from __future__ import annotations

from functools import partial
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.mp_update.kernel import mp_update_pallas
from repro.kernels.mp_update.ref import mp_update_ref


def _use_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _largest_tile(b: int, cap: int = 128) -> int:
    for t in range(min(cap, b), 0, -1):
        if b % t == 0:
            return t
    return 1


@partial(jax.custom_vjp, nondiff_argnums=(6,))
def _mp_update(params, h, a_flow, depth, mask, d, slot_ranges):
    squeeze = h.ndim == 2
    if squeeze:
        h, a_flow, depth, mask = h[None], a_flow[None], depth[None], mask[None]
    out = mp_update_pallas(
        params,
        h,
        a_flow,
        depth,
        mask,
        d,
        slot_ranges,
        tile_b=_largest_tile(h.shape[0]),
        interpret=_use_interpret(),
    )
    return out[0] if squeeze else out


def _fwd(params, h, a_flow, depth, mask, d, slot_ranges):
    return _mp_update(params, h, a_flow, depth, mask, d, slot_ranges), (
        params,
        h,
        a_flow,
        depth,
        mask,
        d,
    )


def _bwd(slot_ranges, res, g):
    params, h, a_flow, depth, mask, d = res
    _, vjp = jax.vjp(
        lambda p, hh, aa: mp_update_ref(p, hh, aa, depth, mask, d, slot_ranges),
        params,
        h,
        a_flow,
    )
    dp, dh, da = vjp(g)
    return dp, dh, da, None, None, None


_mp_update.defvjp(_fwd, _bwd)


def mp_update(params, h, a_flow, depth, mask, d, slot_ranges: Sequence[Tuple[int, int, int]]):
    """Fused stage-3 depth step: aggregate -> concat -> banked MLP -> select."""
    assert len(params["layers"]) == 2
    return _mp_update(params, h, a_flow, depth, mask, d, tuple(slot_ranges))
