"""Pallas kernel: one fused SOURCES->OPS message-passing depth step.

Fuses, for a tile of TB graphs held in VMEM:
  1. parent aggregation      msg = a_flow^T @ h           (per-graph matmul)
  2. feature concat          z = [h, msg]                 (register-level)
  3. banked 2-layer MLP      upd = MLP'_{T(v)}(z)         (slot-ranged GEMMs)
  4. depth select            h'  = where(depth == d, upd, h)

Unfused, steps 1-4 are five HBM round-trips of the (B, N, H) state per scan
iteration; fused they are one read + one write — this is the hot inner loop
of COSTREAM training (max_depth iterations per forward).

VMEM budget (v5e, fp32, TB=128, N=12, H=64): h 384 KiB, a_flow 576 KiB,
weights (T=5) ~ 1.2 MiB, intermediates < 1 MiB -> comfortably resident.
"""

from __future__ import annotations

import functools
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(h_ref, a_ref, depth_ref, mask_ref, d_ref, w1_ref, b1_ref, w2_ref, b2_ref, out_ref, *, slot_ranges):
    h = h_ref[...]  # (TB, N, H)
    a = a_ref[...]  # (TB, N, N)
    # 1. parent aggregation: msg[b, v] = sum_u a[b, u, v] * h[b, u]
    msg = jax.lax.dot_general(
        a, h, (((1,), (1,)), ((0,), (0,))), preferred_element_type=jnp.float32
    )  # contract over u -> (TB, N, H)
    # 2. concat
    z = jnp.concatenate([h, msg], axis=-1)  # (TB, N, 2H)
    # 3. banked MLP over static slot ranges
    upd = jnp.zeros_like(h)
    outs = []
    for t, start, stop in slot_ranges:
        zs = z[:, start:stop, :]
        hid = jnp.maximum(
            jax.lax.dot_general(
                zs, w1_ref[t], (((2,), (0,)), ((), ())), preferred_element_type=jnp.float32
            )
            + b1_ref[t],
            0.0,
        )
        outs.append(
            jax.lax.dot_general(
                hid, w2_ref[t], (((2,), (0,)), ((), ())), preferred_element_type=jnp.float32
            )
            + b2_ref[t]
        )
    upd = jnp.concatenate(outs, axis=1)
    # 4. depth select
    d = d_ref[0]
    sel = (depth_ref[...] == d) & (mask_ref[...] > 0)
    out_ref[...] = jnp.where(sel[..., None], upd, h).astype(out_ref.dtype)


def mp_update_pallas(
    params,
    h: jax.Array,  # (B, N, H)
    a_flow: jax.Array,  # (B, N, N)
    depth: jax.Array,  # (B, N) int32
    mask: jax.Array,  # (B, N) float32
    d: jax.Array,  # () int32
    slot_ranges: Sequence[Tuple[int, int, int]],
    tile_b: int = 128,
    interpret: bool = True,
) -> jax.Array:
    l1, l2 = params["layers"]
    w1, b1, w2, b2 = l1["w"], l1["b"], l2["w"], l2["b"]
    B, N, H = h.shape
    tb = min(tile_b, B)
    assert B % tb == 0
    d_arr = jnp.asarray(d, jnp.int32).reshape((1,))
    return pl.pallas_call(
        functools.partial(_kernel, slot_ranges=tuple(slot_ranges)),
        grid=(B // tb,),
        in_specs=[
            pl.BlockSpec((tb, N, H), lambda i: (i, 0, 0)),
            pl.BlockSpec((tb, N, N), lambda i: (i, 0, 0)),
            pl.BlockSpec((tb, N), lambda i: (i, 0)),
            pl.BlockSpec((tb, N), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec(w1.shape, lambda i: (0, 0, 0)),
            pl.BlockSpec(b1.shape, lambda i: (0, 0)),
            pl.BlockSpec(w2.shape, lambda i: (0, 0, 0)),
            pl.BlockSpec(b2.shape, lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tb, N, H), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, N, H), h.dtype),
        interpret=interpret,
    )(h, a_flow, depth, mask, d_arr, w1, b1, w2, b2)
