"""Pallas kernel: one fused SOURCES->OPS message-passing depth step.

Fuses, for a tile of TB graphs held in VMEM:
  1. parent aggregation      msg = a_flow^T @ h           (per-graph matmul)
  2. feature concat          z = [h, msg]                 (register-level)
  3. banked 2-layer MLP      upd = MLP'_{T(v)}(z)         (slot-ranged GEMMs)
  4. depth select            h'  = where(depth == d, upd, h)

Unfused, steps 1-4 are five HBM round-trips of the (B, N, H) state per scan
iteration; fused they are one read + one write — this is the hot inner loop
of COSTREAM training (max_depth iterations per forward).

VMEM budget (v5e, fp32, TB=128, N=12, H=64): h 384 KiB, a_flow 576 KiB,
weights (T=5) ~ 1.2 MiB, intermediates < 1 MiB -> comfortably resident.
"""

from __future__ import annotations

import functools
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(h_ref, a_ref, depth_ref, mask_ref, d_ref, w1_ref, b1_ref, w2_ref, b2_ref, out_ref, *, slot_ranges, row_span, parent_rows):
    h = h_ref[...]  # (TB, N, H)
    s, e = row_span  # static rows eligible for this depth step
    p = parent_rows  # static bound: a_flow[u, v] == 0 for u >= p, v in [s, e)
    # 1. parent aggregation, only for eligible rows against possible parents:
    #    msg[b, v] = sum_{u < p} a[b, u, v] * h[b, u]  for v in [s, e)
    a = a_ref[:, :p, s:e]  # (TB, p, e-s) static slice
    msg = jax.lax.dot_general(
        a, h[:, :p], (((1,), (1,)), ((0,), (0,))), preferred_element_type=jnp.float32
    )  # contract over u -> (TB, e-s, H)
    # 2. concat
    z = jnp.concatenate([h[:, s:e, :], msg], axis=-1)  # (TB, e-s, 2H)
    # 3. banked MLP over static slot ranges (absolute rows inside [s, e))
    outs = []
    for t, start, stop in slot_ranges:
        zs = z[:, start - s : stop - s, :]
        hid = jnp.maximum(
            jax.lax.dot_general(
                zs, w1_ref[t], (((2,), (0,)), ((), ())), preferred_element_type=jnp.float32
            )
            + b1_ref[t],
            0.0,
        )
        outs.append(
            jax.lax.dot_general(
                hid, w2_ref[t], (((2,), (0,)), ((), ())), preferred_element_type=jnp.float32
            )
            + b2_ref[t]
        )
    upd = jnp.concatenate(outs, axis=1)
    # 4. depth select inside the span; rows outside pass through untouched
    d = d_ref[0]
    sel = (depth_ref[:, s:e] == d) & (mask_ref[:, s:e] > 0)
    out_ref[...] = h.astype(out_ref.dtype)
    out_ref[:, s:e, :] = jnp.where(sel[..., None], upd, h[:, s:e]).astype(out_ref.dtype)


def mp_update_pallas(
    params,
    h: jax.Array,  # (B, N, H)
    a_flow: jax.Array,  # (B, N, N)
    depth: jax.Array,  # (B, N) int32
    mask: jax.Array,  # (B, N) float32
    d: jax.Array,  # () int32
    slot_ranges: Sequence[Tuple[int, int, int]],
    tile_b: int = 128,
    interpret: bool = True,
    row_span: Tuple[int, int] = None,
    parent_rows: int = None,
) -> jax.Array:
    """``row_span=(s, e)`` statically restricts the update to rows [s, e):
    aggregation, MLP, and select all run at span width and rows outside pass
    through — the query-specialized placed path sorts slots by depth so each
    depth level is one contiguous span, skipping the provably-unselected rows'
    dense work.  ``None`` means the full row axis (the generic scan path,
    where the updated depth is dynamic).  ``parent_rows=p`` additionally
    promises ``a_flow[u, v] == 0`` for ``u >= p, v`` in the span (depth-major
    layouts: parents precede the level), shrinking the aggregation GEMM's
    contraction axis."""
    l1, l2 = params["layers"]
    w1, b1, w2, b2 = l1["w"], l1["b"], l2["w"], l2["b"]
    B, N, H = h.shape
    tb = min(tile_b, B)
    assert B % tb == 0
    span = (0, N) if row_span is None else (int(row_span[0]), int(row_span[1]))
    assert 0 <= span[0] < span[1] <= N, (span, N)
    # the per-range outputs are concatenated back over the span, so the ranges
    # must tile [s, e) exactly, in order
    edge = span[0]
    for t, start, stop in slot_ranges:
        assert start == edge and start < stop <= span[1], (
            f"slot ranges must tile row span {span} contiguously, got {slot_ranges}"
        )
        edge = stop
    assert edge == span[1], (slot_ranges, span)
    p = N if parent_rows is None else int(parent_rows)
    assert 0 < p <= N, (p, N)
    d_arr = jnp.asarray(d, jnp.int32).reshape((1,))
    return pl.pallas_call(
        functools.partial(
            _kernel, slot_ranges=tuple(slot_ranges), row_span=span, parent_rows=p
        ),
        grid=(B // tb,),
        in_specs=[
            pl.BlockSpec((tb, N, H), lambda i: (i, 0, 0)),
            pl.BlockSpec((tb, N, N), lambda i: (i, 0, 0)),
            pl.BlockSpec((tb, N), lambda i: (i, 0)),
            pl.BlockSpec((tb, N), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec(w1.shape, lambda i: (0, 0, 0)),
            pl.BlockSpec(b1.shape, lambda i: (0, 0)),
            pl.BlockSpec(w2.shape, lambda i: (0, 0, 0)),
            pl.BlockSpec(b2.shape, lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tb, N, H), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, N, H), h.dtype),
        interpret=interpret,
    )(h, a_flow, depth, mask, d_arr, w1, b1, w2, b2)
