"""Pure-jnp oracle for the gated linear recurrence  h_t = a_t * h_{t-1} + b_t.

This is the state update at the heart of the RG-LRU (RecurrentGemma /
Griffin) block once the gates have been applied; the oracle uses an
associative scan (what XLA would give you without a kernel).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def linear_scan_ref(a: jax.Array, b: jax.Array, h0: jax.Array) -> jax.Array:
    """a, b: (B, T, D); h0: (B, D) -> h: (B, T, D) with h_t = a_t h_{t-1} + b_t."""

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    aa, bb = jax.lax.associative_scan(combine, (a, b), axis=1)
    return aa * h0[:, None, :] + bb
