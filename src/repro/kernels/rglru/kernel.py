"""Pallas kernel: chunked gated linear recurrence (RG-LRU state update).

    h_t = a_t * h_{t-1} + b_t        a, b: (B, T, D)

TPU adaptation of the GPU "parallel scan over warps" formulation: the TPU has
no shuffle-based scan, but its grid is executed *sequentially* per core, so we
tile T into chunks and carry the running state h in a VMEM scratch buffer
across grid steps (grid = (B/TB, T/TT), T innermost). Within a chunk the
recurrence is a short fori_loop over TT VMEM-resident (TB, D)-vector steps —
VPU work with zero HBM traffic until the chunk's outputs are flushed once.

For long-context decode (the 500k cells) this streams a/b exactly once from
HBM -> the kernel is purely bandwidth-bound, which is the roofline optimum
for this op (arithmetic intensity ~ 2 FLOP / 12 bytes).

VMEM sizing (v5e, 16 MiB, fp32): a/b/out tiles are (TB, TT, D); with TB=4,
TT=128, D=2560 that is 3 x 5 MiB + carry 40 KiB — in budget; callers shrink
tiles for wider D.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(a_ref, b_ref, h0_ref, out_ref, h_carry, *, tt: int):
    t_idx = pl.program_id(1)

    # initialize the carry at the first chunk of every batch tile
    @pl.when(t_idx == 0)
    def _():
        h_carry[...] = h0_ref[...].astype(h_carry.dtype)

    a = a_ref[...].astype(jnp.float32)  # (TB, TT, D)
    b = b_ref[...].astype(jnp.float32)
    h = h_carry[...]  # (TB, D) fp32

    def step(i, carry):
        h, out = carry
        h = a[:, i, :] * h + b[:, i, :]
        out = jax.lax.dynamic_update_index_in_dim(out, h, i, axis=1)
        return h, out

    out0 = jnp.zeros(a.shape, jnp.float32)
    h, out = jax.lax.fori_loop(0, tt, step, (h, out0))
    out_ref[...] = out.astype(out_ref.dtype)
    h_carry[...] = h


def linear_scan_pallas(
    a: jax.Array,
    b: jax.Array,
    h0: jax.Array,
    tile_b: int = 4,
    tile_t: int = 128,
    interpret: bool = True,
) -> jax.Array:
    B, T, D = a.shape
    tb, tt = min(tile_b, B), min(tile_t, T)
    assert B % tb == 0 and T % tt == 0, (B, T, tb, tt)
    grid = (B // tb, T // tt)  # T innermost: chunks run in carry order
    return pl.pallas_call(
        functools.partial(_kernel, tt=tt),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tb, tt, D), lambda i, j: (i, j, 0)),
            pl.BlockSpec((tb, tt, D), lambda i, j: (i, j, 0)),
            pl.BlockSpec((tb, D), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((tb, tt, D), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((B, T, D), a.dtype),
        scratch_shapes=[pltpu.VMEM((tb, D), jnp.float32)],
        interpret=interpret,
    )(a, b, h0)
