from repro.kernels.rglru import kernel, ops, ref

__all__ = ["kernel", "ops", "ref"]
