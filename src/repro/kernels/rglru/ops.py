"""jit'd wrapper for the RG-LRU linear-scan kernel (custom_vjp via oracle).

The backward pass of h_t = a_t h_{t-1} + b_t is itself a reversed linear
scan; we express it through the oracle's VJP (associative scan), keeping the
op trainable while the forward uses the chunked kernel.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.common import largest_tile as _largest_tile
from repro.kernels.rglru.kernel import linear_scan_pallas
from repro.kernels.rglru.ref import linear_scan_ref


def _use_interpret() -> bool:
    return jax.default_backend() != "tpu"


@jax.custom_vjp
def linear_scan(a, b, h0):
    return linear_scan_pallas(
        a,
        b,
        h0,
        tile_b=_largest_tile(a.shape[0], 4),
        tile_t=_largest_tile(a.shape[1], 128),
        interpret=_use_interpret(),
    )


def _fwd(a, b, h0):
    return linear_scan(a, b, h0), (a, b, h0)


def _bwd(res, g):
    a, b, h0 = res
    _, vjp = jax.vjp(linear_scan_ref, a, b, h0)
    return vjp(g)


linear_scan.defvjp(_fwd, _bwd)
