"""jit'd wrappers for the segment gather/scatter kernels (custom_vjp).

Per-backend lowering as in the other kernel packages: Pallas on TPU, jnp
oracle off-TPU, ``REPRO_PALLAS_INTERPRET=1`` forces the interpreter.  The
backward delegates to the oracle's VJP; the integer index operands get
symbolic-zero (``float0``) cotangents, so the ops are trainable wherever the
merged engine is differentiated.  Batch-tile caps come from the active
``DispatchPolicy.seg_gather_tile``.
"""

from __future__ import annotations

from functools import partial

import jax
import numpy as np
from jax import dtypes

from repro.kernels import active_lowering as _lowering
from repro.kernels.common import largest_tile as _largest_tile
from repro.kernels.seg_gather.kernel import gather_sum_pallas, segment_sum_pallas
from repro.kernels.seg_gather.ref import gather_sum_ref, segment_sum_ref


def _tile_cap() -> int:
    from repro.serve.policy import active_policy  # lazy: kernels never pull serve at import

    return active_policy().seg_gather_tile


def _int_zero(idx):
    return np.zeros(np.shape(idx), dtypes.float0)


@jax.custom_vjp
def _gather_sum(h, idx, w):
    mode = _lowering()
    if mode == "ref":
        return gather_sum_ref(h, idx, w)
    return gather_sum_pallas(
        h, idx, w, tile_b=_largest_tile(h.shape[0], _tile_cap()), interpret=mode == "interpret"
    )


def _gather_fwd(h, idx, w):
    return _gather_sum(h, idx, w), (h, idx, w)


def _gather_bwd(res, g):
    h, idx, w = res
    _, vjp = jax.vjp(lambda hh, ww: gather_sum_ref(hh, idx, ww), h, w)
    dh, dw = vjp(g)
    return dh, _int_zero(idx), dw


_gather_sum.defvjp(_gather_fwd, _gather_bwd)


@partial(jax.custom_vjp, nondiff_argnums=(2,))
def _segment_sum(x, seg, n_seg):
    mode = _lowering()
    if mode == "ref":
        return segment_sum_ref(x, seg, n_seg)
    return segment_sum_pallas(
        x, seg, n_seg, tile_b=_largest_tile(x.shape[0], _tile_cap()), interpret=mode == "interpret"
    )


def _segment_fwd(x, seg, n_seg):
    return _segment_sum(x, seg, n_seg), (x, seg)


def _segment_bwd(n_seg, res, g):
    x, seg = res
    _, vjp = jax.vjp(lambda xx: segment_sum_ref(xx, seg, n_seg), x)
    (dx,) = vjp(g)
    return dx, _int_zero(seg)


_segment_sum.defvjp(_segment_fwd, _segment_bwd)


def gather_sum(h: jax.Array, idx: jax.Array, w: jax.Array) -> jax.Array:
    """Weighted row gather: ``out[b, r] = sum_p w[b, r, p] * h[b, idx[b, r, p]]``.

    The merged engine's parent-table aggregation (stage 3, ``P = max_parents``
    with the parent mask as ``w``) and single-host gather (stage 2, ``P = 1``
    with the placed flag as ``w``).  ``h``: (B, N, H); ``idx``/``w``: (B, R, P).
    """
    return _gather_sum(h, idx, w)


def segment_sum(x: jax.Array, seg: jax.Array, n_seg: int) -> jax.Array:
    """Segment scatter-add: ``out[b, s] = sum_{r: seg[b, r] == s} x[b, r]``.

    The merged engine's stage-1 OPS->HW aggregation (``seg`` = each
    operator's host index; rows must be pre-masked so padded operators
    contribute zero).  ``x``: (B, N, H); ``seg``: (B, N); out: (B, n_seg, H).
    """
    return _segment_sum(x, seg, int(n_seg))
