"""Pallas kernels: segment gather-sum and scatter-add as one-hot matmuls.

Gather/scatter have no native TPU lowering inside a kernel — but both are
SpMM-shaped, and the sparse operand is tiny (N = MAX_OPS rows): a one-hot
selection matrix built from a ``broadcasted_iota`` compare turns each into a
single batched ``dot_general`` that the MXU executes directly.

* ``gather_sum``:  out[b, r] = sum_p w[b,r,p] * h[b, idx[b,r,p]]
  The (idx, w) parent table collapses to a dense (R, N) weight matrix
  W[r, u] = sum_p [idx[r,p] == u] * w[r,p] — summing the one-hots over the
  P axis is exact because a row's parents are distinct — then out = W @ h.
* ``segment_sum``: out[b, s] = sum_{r: seg[b,r] == s} x[b, r]
  The one-hot transpose: out = onehot(seg)^T @ x.

Both tile the batch axis (``DispatchPolicy.seg_gather_tile`` caps the tile);
the gather's row axis is padded to a power of two by the wrapper so the
selection matmul hits MXU-friendly shapes, and the pad rows (zero weights)
are sliced back off.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pow2_at_least(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


def _gather_kernel(h_ref, idx_ref, w_ref, out_ref):
    h = h_ref[...]  # (TB, N, H)
    idx = idx_ref[...]  # (TB, R, P) int32
    w = w_ref[...]  # (TB, R, P)
    n = h.shape[1]
    # one-hot selection: sel[b, r, p, u] = w[b, r, p] where idx[b, r, p] == u
    u = jax.lax.broadcasted_iota(jnp.int32, idx.shape + (n,), dimension=3)
    sel = jnp.where(idx[..., None] == u, w[..., None], 0.0)  # (TB, R, P, N)
    weights = sel.sum(axis=2)  # (TB, R, N): distinct parents -> exact
    out = jax.lax.dot_general(
        weights, h, (((2,), (1,)), ((0,), (0,))), preferred_element_type=jnp.float32
    )  # (TB, R, H)
    out_ref[...] = out.astype(out_ref.dtype)


def _segment_kernel(x_ref, seg_ref, out_ref, *, n_seg):
    x = x_ref[...]  # (TB, N, H)
    seg = seg_ref[...]  # (TB, N) int32
    s = jax.lax.broadcasted_iota(jnp.int32, seg.shape + (n_seg,), dimension=2)
    onehot = (seg[..., None] == s).astype(x.dtype)  # (TB, N, S)
    out = jax.lax.dot_general(
        onehot, x, (((1,), (1,)), ((0,), (0,))), preferred_element_type=jnp.float32
    )  # contract over rows -> (TB, S, H)
    out_ref[...] = out.astype(out_ref.dtype)


def gather_sum_pallas(
    h: jax.Array,  # (B, N, H)
    idx: jax.Array,  # (B, R, P) int
    w: jax.Array,  # (B, R, P)
    tile_b: int = 128,
    interpret: bool = True,
) -> jax.Array:
    B, N, H = h.shape
    _, R, P = idx.shape
    tb = min(tile_b, B)
    assert B % tb == 0
    r_pad = _pow2_at_least(R)
    if r_pad != R:  # pad rows carry zero weight: they gather h[:, 0] * 0
        pad = ((0, 0), (0, r_pad - R), (0, 0))
        idx = jnp.pad(idx, pad)
        w = jnp.pad(w, pad)
    out = pl.pallas_call(
        _gather_kernel,
        grid=(B // tb,),
        in_specs=[
            pl.BlockSpec((tb, N, H), lambda i: (i, 0, 0)),
            pl.BlockSpec((tb, r_pad, P), lambda i: (i, 0, 0)),
            pl.BlockSpec((tb, r_pad, P), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((tb, r_pad, H), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, r_pad, H), h.dtype),
        interpret=interpret,
    )(h, idx.astype(jnp.int32), w)
    return out[:, :R] if r_pad != R else out


def segment_sum_pallas(
    x: jax.Array,  # (B, N, H)
    seg: jax.Array,  # (B, N) int
    n_seg: int,
    tile_b: int = 128,
    interpret: bool = True,
) -> jax.Array:
    B, N, H = x.shape
    tb = min(tile_b, B)
    assert B % tb == 0
    return pl.pallas_call(
        functools.partial(_segment_kernel, n_seg=int(n_seg)),
        grid=(B // tb,),
        in_specs=[
            pl.BlockSpec((tb, N, H), lambda i: (i, 0, 0)),
            pl.BlockSpec((tb, N), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((tb, n_seg, H), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, int(n_seg), H), x.dtype),
        interpret=interpret,
    )(x, seg.astype(jnp.int32))
