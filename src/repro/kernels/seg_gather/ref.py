"""Pure-jnp oracles for the segment gather/scatter ops.

These are EXACTLY the index formulations the merged engine inlined before
the kernels existed (take_along_axis gather; vmapped ``.at[].add`` scatter),
so routing ``apply_gnn_merged`` through the ops is bitwise-neutral on the
ref lowering.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def gather_sum_ref(h: jax.Array, idx: jax.Array, w: jax.Array) -> jax.Array:
    """``out[b, r] = sum_p w[b, r, p] * h[b, idx[b, r, p]]``.

    ``h``: (B, N, H) source states; ``idx``: (B, R, P) int row tables;
    ``w``: (B, R, P) per-entry weights (the parent masks / placed flags).
    """
    b = idx.shape[0]
    gat = jnp.take_along_axis(h, idx.reshape(b, -1, 1), axis=-2).reshape(
        *idx.shape, h.shape[-1]
    )  # (B, R, P, H)
    return (gat * w[..., None]).sum(axis=-2)


def segment_sum_ref(x: jax.Array, seg: jax.Array, n_seg: int) -> jax.Array:
    """``out[b, s] = sum_{r: seg[b, r] == s} x[b, r]`` for ``s < n_seg``.

    ``x``: (B, N, H) row states (pre-masked: padded rows contribute zero);
    ``seg``: (B, N) int segment ids in [0, n_seg).
    """

    def one(xr, sr):
        return jnp.zeros((n_seg, xr.shape[-1]), xr.dtype).at[sr].add(xr)

    return jax.vmap(one)(x, seg)
