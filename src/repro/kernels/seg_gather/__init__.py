"""Segment gather/scatter kernels for the cross-query merged engine.

``apply_gnn_merged`` expresses the graph aggregations as index ops instead
of dense adjacency matmuls: the stage-3 parent-table gather + masked sum
(``gather_sum``, which also covers the stage-2 single-host gather) and the
stage-1 OPS->HW scatter-add (``segment_sum``).  Both are SpMM-shaped — on
TPU the kernels lower them as one-hot contractions (iota compare feeding the
MXU), tiled over the candidate axis with power-of-2 row padding.
"""

from repro.kernels.seg_gather.ops import gather_sum, segment_sum  # noqa: F401
