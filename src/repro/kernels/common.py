"""Shared helpers for the kernel packages' ops wrappers.

One home for the tile-size arithmetic every ``ops.py`` needs (previously
three drifting copies in banked_mlp / mp_update / rglru): Pallas grids
require the tiled axis to divide evenly, so the usable tile is the largest
divisor of the axis length not exceeding the cap.  Caps come from the active
``DispatchPolicy`` (``sweep_tile_rows`` / ``seg_gather_tile`` for the new
kernels) or the package's documented VMEM budget — never fresh inline
constants.
"""

from __future__ import annotations


def largest_tile(n: int, cap: int = 128) -> int:
    """Largest divisor of ``n`` that is <= ``cap`` (1 when ``n == 0``).

    The Pallas callers tile a batch axis of length ``n`` with a grid of
    ``n // tile`` programs, so the tile must divide ``n`` exactly; ``cap``
    bounds the per-program VMEM working set.
    """
    for t in range(min(cap, n), 0, -1):
        if n % t == 0:
            return t
    return 1
