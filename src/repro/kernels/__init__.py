"""Pallas TPU kernels for the perf-critical compute hot-spots.

Three kernels (each: kernel.py = pl.pallas_call + BlockSpec, ops.py = jit'd
wrapper with custom_vjp, ref.py = pure-jnp oracle):

* ``banked_mlp``  — fused 2-layer node-type-specific MLP over the canonical
  slot layout (COSTREAM encoder / update networks).
* ``mp_update``   — one stage-3 message-passing depth step fused end-to-end:
  adjacency matmul + concat + banked MLP + depth-select.
* ``rglru``       — chunked RG-LRU linear recurrence (RecurrentGemma blocks),
  VMEM-tiled over (batch, channel) with sequential in-kernel time loop.

On CPU all kernels run under ``interpret=True`` (the container has no TPU);
the BlockSpecs are written for TPU v5e VMEM (16 MiB/core) and MXU alignment.
"""
