"""Pallas TPU kernels for the perf-critical compute hot-spots.

Five kernel packages (each: kernel.py = pl.pallas_call + BlockSpec, ops.py =
jit'd wrapper with custom_vjp, ref.py = pure-jnp oracle):

* ``banked_mlp``  — fused 2-layer node-type-specific MLP over the canonical
  slot layout (COSTREAM encoder / update networks).
* ``mp_update``   — one stage-3 message-passing depth step fused end-to-end:
  adjacency matmul + concat + banked MLP + depth-select.
* ``mp_sweep``    — the ENTIRE banded stage-3 depth sweep in one launch: the
  static banding table as compile-time constants, the hidden-state row tile
  read once and carried through all levels in VMEM.
* ``seg_gather``  — segment gather-sum / scatter-add as one-hot SpMM matmuls
  (the cross-query merged engine's parent-table and host aggregations).
* ``rglru``       — chunked RG-LRU linear recurrence (RecurrentGemma blocks),
  VMEM-tiled over (batch, channel) with sequential in-kernel time loop.

Shared ops-level helpers (tile arithmetic) live in ``kernels.common``.

Per-backend lowering (``active_lowering``): on TPU the ops run the Pallas
kernels; on other backends they lower to the jnp oracles (compiled XLA, no
interpreter emulation tax) unless ``REPRO_PALLAS_INTERPRET=1`` forces the
Pallas interpreter — slow, used by the parity tests to execute the actual
kernel bodies.  The BlockSpecs are written for TPU v5e VMEM (16 MiB/core)
and MXU alignment.
"""

from __future__ import annotations

import os

import jax


def active_lowering() -> str:
    """'pallas' (TPU) | 'interpret' (forced via env) | 'ref' (other backends).

    Read at TRACE time: jitted callers that cache traces must include this
    value in their cache key, or a later env-var flip silently keeps the old
    lowering (see ``core.model``'s jitted forwards).
    """
    if jax.default_backend() == "tpu":
        return "pallas"
    if os.environ.get("REPRO_PALLAS_INTERPRET", "0") == "1":
        return "interpret"
    return "ref"
