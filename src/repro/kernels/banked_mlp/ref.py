"""Pure-jnp oracle for the fused slotted banked 2-layer MLP."""

from __future__ import annotations

from typing import Sequence, Tuple

import jax
import jax.numpy as jnp


def banked_mlp_slotted_ref(
    params,
    x: jax.Array,
    slot_ranges: Sequence[Tuple[int, int, int]],
) -> jax.Array:
    """x: (..., N, F) -> (..., N, H2). Two layers, ReLU between.

    params follows nn.init_mlp_bank: {"layers": [{"w": (T,F,H1), "b": (T,H1)},
    {"w": (T,H1,H2), "b": (T,H2)}]}.
    """
    l1, l2 = params["layers"]
    pieces = []
    for t, start, stop in slot_ranges:
        h = jax.nn.relu(x[..., start:stop, :] @ l1["w"][t] + l1["b"][t])
        pieces.append(h @ l2["w"][t] + l2["b"][t])
    return jnp.concatenate(pieces, axis=-2)
