from repro.kernels.banked_mlp import kernel, ops, ref

__all__ = ["kernel", "ops", "ref"]
