"""jit'd public wrapper for the banked-MLP kernel.

Forward runs the Pallas kernel (interpret=True on CPU); backward delegates to
the VJP of the jnp oracle via custom_vjp, so the op is trainable everywhere.
Accepts (N, F) single graphs (auto-batched) or (B, N, F) batches; arbitrary
leading dims via vmap are supported by the Pallas batching rule.
"""

from __future__ import annotations

from functools import partial
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.banked_mlp.kernel import banked_mlp_slotted_pallas
from repro.kernels.banked_mlp.ref import banked_mlp_slotted_ref


def _use_interpret() -> bool:
    return jax.default_backend() != "tpu"


@partial(jax.custom_vjp, nondiff_argnums=(2,))
def _banked_mlp(params, x, slot_ranges):
    if x.ndim == 2:
        return banked_mlp_slotted_pallas(
            params, x[None], slot_ranges, tile_b=1, interpret=_use_interpret()
        )[0]
    B = x.shape[0]
    tile = 128 if B % 128 == 0 else (B if B <= 128 else _largest_tile(B))
    return banked_mlp_slotted_pallas(
        params, x, slot_ranges, tile_b=tile, interpret=_use_interpret()
    )


def _largest_tile(b: int, cap: int = 128) -> int:
    for t in range(min(cap, b), 0, -1):
        if b % t == 0:
            return t
    return 1


def _fwd(params, x, slot_ranges):
    return _banked_mlp(params, x, slot_ranges), (params, x)


def _bwd(slot_ranges, res, g):
    params, x = res
    _, vjp = jax.vjp(lambda p, xx: banked_mlp_slotted_ref(p, xx, slot_ranges), params, x)
    return vjp(g)


_banked_mlp.defvjp(_fwd, _bwd)


def banked_mlp_slotted(params, x: jax.Array, slot_ranges: Sequence[Tuple[int, int, int]]):
    """Fused type-specific 2-layer MLP on the canonical slot layout."""
    assert len(params["layers"]) == 2, "kernel fuses exactly two layers"
    return _banked_mlp(params, x, tuple(slot_ranges))
