"""jit'd public wrapper for the banked-MLP kernel.

Per-backend lowering (``_lowering``): on TPU the forward runs the Pallas
kernel; off-TPU it lowers to the jnp oracle — the SAME function that provides
the backward pass everywhere — so CPU runs stay fast-compiled instead of
paying the Pallas interpreter's emulation tax.  Set
``REPRO_PALLAS_INTERPRET=1`` to force the interpreter off-TPU (slow; the
kernel parity tests use it to execute the actual kernel body).  Backward
always delegates to the VJP of the jnp oracle via custom_vjp, so the op is
trainable everywhere.  Accepts (N, F) single graphs (auto-batched) or
(B, N, F) batches; arbitrary leading dims via vmap are supported by the
Pallas batching rule.
"""

from __future__ import annotations

from functools import partial
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import active_lowering as _lowering
from repro.kernels.banked_mlp.kernel import banked_mlp_slotted_pallas
from repro.kernels.banked_mlp.ref import banked_mlp_slotted_ref
from repro.kernels.common import largest_tile as _largest_tile


@partial(jax.custom_vjp, nondiff_argnums=(2,))
def _banked_mlp(params, x, slot_ranges):
    mode = _lowering()
    if mode == "ref":
        return banked_mlp_slotted_ref(params, x, slot_ranges)
    if x.ndim == 2:
        return banked_mlp_slotted_pallas(
            params, x[None], slot_ranges, tile_b=1, interpret=mode == "interpret"
        )[0]
    B = x.shape[0]
    tile = 128 if B % 128 == 0 else (B if B <= 128 else _largest_tile(B))
    return banked_mlp_slotted_pallas(
        params, x, slot_ranges, tile_b=tile, interpret=mode == "interpret"
    )


def _fwd(params, x, slot_ranges):
    return _banked_mlp(params, x, slot_ranges), (params, x)


def _bwd(slot_ranges, res, g):
    params, x = res
    _, vjp = jax.vjp(lambda p, xx: banked_mlp_slotted_ref(p, xx, slot_ranges), params, x)
    return vjp(g)


_banked_mlp.defvjp(_fwd, _bwd)


def banked_mlp_slotted(params, x: jax.Array, slot_ranges: Sequence[Tuple[int, int, int]]):
    """Fused type-specific 2-layer MLP on the canonical slot layout."""
    if len(params["layers"]) != 2:  # loud even under python -O (no silent fallback)
        raise NotImplementedError(
            f"Pallas banked-MLP kernel fuses exactly two layers, got {len(params['layers'])}"
        )
    return _banked_mlp(params, x, tuple(slot_ranges))
