"""Pallas kernel: fused slotted banked 2-layer MLP.

One program processes a tile of TB graphs: the whole (TB, N, F) node block
lives in VMEM together with all type-specific weight banks (they are tiny:
T <= 5, F <= 2*H, H <= 128 -> < 1 MiB), so both GEMM layers and the ReLU fuse
into a single VMEM-resident pass — the memory-bound alternative on small
graphs would round-trip HBM three times.

TPU sizing notes (v5e): VMEM 16 MiB. With TB = 128, N = 12, F = 128, fp32:
x tile 768 KiB, intermediate 384 KiB, out 384 KiB, weights < 1 MiB — well
under budget. The N x F panels are zero-padded to the (8, 128) fp32 tile by
Mosaic; matmul dims H1/H2 should be multiples of 128 for full MXU utilization
(the COSTREAM configs use H = 64: half-lane utilization, traded consciously —
the model is small and latency-bound, see DESIGN.md SS4).
"""

from __future__ import annotations

import functools
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, out_ref, *, slot_ranges):
    x = x_ref[...]  # (TB, N, F)
    for t, start, stop in slot_ranges:
        xs = x[:, start:stop, :]  # (TB, S, F) static slice
        h = jnp.maximum(
            jax.lax.dot_general(
                xs,
                w1_ref[t],
                (((2,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            )
            + b1_ref[t],
            0.0,
        )
        y = (
            jax.lax.dot_general(
                h,
                w2_ref[t],
                (((2,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            )
            + b2_ref[t]
        )
        out_ref[:, start:stop, :] = y.astype(out_ref.dtype)


def banked_mlp_slotted_pallas(
    params,
    x: jax.Array,
    slot_ranges: Sequence[Tuple[int, int, int]],
    tile_b: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """x: (B, N, F) -> (B, N, H2)."""
    l1, l2 = params["layers"]
    w1, b1 = l1["w"], l1["b"]  # (T,F,H1), (T,H1)
    w2, b2 = l2["w"], l2["b"]  # (T,H1,H2), (T,H2)
    B, N, F = x.shape
    H2 = w2.shape[-1]
    tb = min(tile_b, B)
    assert B % tb == 0, f"batch {B} not divisible by tile {tb}"

    grid = (B // tb,)
    return pl.pallas_call(
        functools.partial(_kernel, slot_ranges=tuple(slot_ranges)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tb, N, F), lambda i: (i, 0, 0)),
            pl.BlockSpec(w1.shape, lambda i: (0, 0, 0)),
            pl.BlockSpec(b1.shape, lambda i: (0, 0)),
            pl.BlockSpec(w2.shape, lambda i: (0, 0, 0)),
            pl.BlockSpec(b2.shape, lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tb, N, H2), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, N, H2), x.dtype),
        interpret=interpret,
    )(x, w1, b1, w2, b2)
