"""Transformer building blocks for the assigned architectures.

Every block ships a ``*_defs(cfg)`` (ParamDef tree, carries sharding axes) and
an ``apply_*`` function. Covered:

* attention: GQA/MQA, qk-norm (qwen3), attention/final logit softcap (gemma2),
  sliding-window local attention (gemma2, recurrentgemma), MLA with compressed
  KV (deepseek-v2), bidirectional encoder + cross attention (whisper);
  KV-cache decode for all of them.
* FFN: SwiGLU / GeGLU / GELU.
* MoE: top-k router with capacity-based one-hot dispatch (GShard-style einsum
  formulation — GSPMD-friendly), optional shared experts (deepseek-v2) and a
  dense residual branch (arctic).
* RG-LRU recurrent block (recurrentgemma) over the Pallas linear-scan kernel's
  oracle formulation (kernel used on TPU).
* xLSTM: mLSTM (matrix memory, chunkwise-recurrent) and sLSTM (scalar memory)
  blocks.
"""

from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.params import ParamDef, pdef

Params = Dict[str, Any]

# ---------------------------------------------------------------------------
# small pieces
# ---------------------------------------------------------------------------


def rmsnorm_defs(d: int) -> Params:
    return {"scale": pdef((d,), (None,), init="zeros", dtype=jnp.float32)}


def apply_rmsnorm(p: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + p["scale"])).astype(dt)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, D) with D even; positions: (B, S) or (S,)."""
    d = x.shape[-1]
    half = d // 2
    freq = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    if positions.ndim == 1:
        positions = positions[None, :]
    angle = positions[..., None].astype(jnp.float32) * freq  # (B,S,half)
    cos = jnp.cos(angle)[:, :, None, :]
    sin = jnp.sin(angle)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def softcap(x: jax.Array, cap: Optional[float]) -> jax.Array:
    if cap is None:
        return x
    return jnp.tanh(x / cap) * cap


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class AttnConfig:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    qk_norm: bool = False
    attn_softcap: Optional[float] = None
    window: Optional[int] = None  # sliding-window size; None = global
    causal: bool = True
    rope_theta: float = 10_000.0
    cross: bool = False  # cross-attention (kv from encoder output)


def attn_defs(c: AttnConfig) -> Params:
    d, h, kv, hd = c.d_model, c.n_heads, c.n_kv_heads, c.head_dim
    # granularity = head_dim: tensor parallelism may split heads apart but
    # never inside one head (element-sharded heads cross-contaminate the
    # attention einsums and blow up collectives)
    p = {
        "wq": pdef((d, h * hd), ("embed", "heads"), granularity=(1, hd)),
        "wk": pdef((d, kv * hd), ("embed", "kv"), granularity=(1, hd)),
        "wv": pdef((d, kv * hd), ("embed", "kv"), granularity=(1, hd)),
        "wo": pdef((h * hd, d), ("heads", "embed"), granularity=(hd, 1)),
    }
    if c.qk_norm:
        p["q_norm"] = rmsnorm_defs(hd)
        p["k_norm"] = rmsnorm_defs(hd)
    return p


# k-sequence chunk length for blocked attention; naive path below this size.
ATTN_BLOCK = 1024

# Roofline-lowering mode: unroll the chunk scan (trip counts <= this cap) so
# HLO cost_analysis counts every chunk instead of one while-loop body. The
# dry-run sets this; normal execution keeps the rolled loop (smaller HLO).
_ATTN_UNROLL_CAP = 1


def set_attn_unroll_cap(cap: int) -> None:
    global _ATTN_UNROLL_CAP
    _ATTN_UNROLL_CAP = cap


def _attend_naive(
    q: jax.Array,  # (B, Sq, H, D)
    k: jax.Array,  # (B, Sk, KV, D)
    v: jax.Array,  # (B, Sk, KV, D)
    *,
    q_pos: jax.Array,  # (Sq,) or (B, Sq)
    k_pos: jax.Array,  # (Sk,)
    causal: bool,
    window: Optional[int],
    cap: Optional[float],
    k_len: Optional[jax.Array] = None,  # valid cache length for decode
) -> jax.Array:
    B, Sq, H, D = q.shape
    KV = k.shape[2]
    rep = H // KV
    qh = q.reshape(B, Sq, KV, rep, D)
    logits = jnp.einsum("bqkrd,bskd->bkrqs", qh.astype(jnp.float32), k.astype(jnp.float32))
    logits = logits / math.sqrt(D)
    logits = softcap(logits, cap)
    qp = q_pos if q_pos.ndim == 2 else q_pos[None, :]
    mask = jnp.ones((B, Sq, k.shape[1]), dtype=bool)
    if causal:
        mask &= qp[:, :, None] >= k_pos[None, None, :]
    if window is not None:
        mask &= (qp[:, :, None] - k_pos[None, None, :]) < window
    if k_len is not None:
        mask &= k_pos[None, None, :] < jnp.asarray(k_len).reshape(-1, 1, 1)
    logits = jnp.where(mask[:, None, None, :, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkrqs,bskd->bqkrd", probs, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, D)


def _attend_blocked(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    q_pos: jax.Array,
    k_pos: jax.Array,
    causal: bool,
    window: Optional[int],
    cap: Optional[float],
    k_len: Optional[jax.Array] = None,
    block: int = ATTN_BLOCK,
) -> jax.Array:
    """Flash-style online-softmax attention over k-chunks.

    Never materializes the (Sq, Sk) logits — memory is O(Sq x block). This is
    the default for Sk > ATTN_BLOCK (the naive path at 32k sequence would
    materialize multi-TB logit tensors; see EXPERIMENTS.md SPerf iteration 1).
    """
    B, Sq, H, D = q.shape
    Sk = k.shape[1]
    KV = k.shape[2]
    rep = H // KV
    nblk = (Sk + block - 1) // block
    pad = nblk * block - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, (0, pad), constant_values=2**30)
    qh = (q.astype(jnp.float32) / math.sqrt(D)).reshape(B, Sq, KV, rep, D)
    qp = q_pos if q_pos.ndim == 2 else q_pos[None, :]

    kb = k.reshape(B, nblk, block, KV, D)
    vb = v.reshape(B, nblk, block, KV, D)
    pb = k_pos.reshape(nblk, block)

    def chunk(carry, blk):
        m, l, acc = carry  # (B,KV,rep,Sq), (B,KV,rep,Sq), (B,KV,rep,Sq,D)
        kc, vc, pc = blk
        logits = jnp.einsum("bqkrd,bskd->bkrqs", qh, kc.astype(jnp.float32))
        logits = softcap(logits, cap)
        mask = jnp.ones((B, Sq, block), dtype=bool)
        if causal:
            mask &= qp[:, :, None] >= pc[None, None, :]
        if window is not None:
            mask &= (qp[:, :, None] - pc[None, None, :]) < window
        if k_len is not None:
            mask &= pc[None, None, :] < jnp.asarray(k_len).reshape(-1, 1, 1)
        logits = jnp.where(mask[:, None, None, :, :], logits, -1e30)
        m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
        p = jnp.exp(logits - m_new[..., None])
        scale = jnp.exp(m - m_new)
        l_new = l * scale + jnp.sum(p, axis=-1)
        acc_new = acc * scale[..., None] + jnp.einsum(
            "bkrqs,bskd->bkrqd", p, vc.astype(jnp.float32)
        )
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, KV, rep, Sq), -1e30, jnp.float32)
    l0 = jnp.zeros((B, KV, rep, Sq), jnp.float32)
    a0 = jnp.zeros((B, KV, rep, Sq, D), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        # checkpoint per chunk: the scan VJP then saves only the (m, l, acc)
        # carries instead of stacking per-chunk fp32 probabilities (which
        # would re-materialize the full S^2 tensor across iterations)
        jax.checkpoint(chunk),
        (m0, l0, a0),
        (jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0), pb),
        unroll=nblk if nblk <= _ATTN_UNROLL_CAP else 1,
    )
    out = acc / jnp.maximum(l, 1e-30)[..., None]  # (B,KV,rep,Sq,D)
    return jnp.moveaxis(out, 3, 1).reshape(B, Sq, H, D)


def _attend(q, k, v, **kw):
    if k.shape[1] > ATTN_BLOCK:
        # checkpoint: the chunk scan must RECOMPUTE its probabilities in the
        # backward pass (flash-attention's trick); without this the scan
        # stacks per-chunk fp32 probs = the full S^2 tensor again
        return jax.checkpoint(lambda q, k, v: _attend_blocked(q, k, v, **kw))(q, k, v)
    return _attend_naive(q, k, v, **kw)


def apply_attn(
    p: Params,
    x: jax.Array,  # (B, S, d)
    c: AttnConfig,
    *,
    positions: jax.Array,  # (S,) int32 absolute positions of x
    kv_source: Optional[jax.Array] = None,  # cross-attention source
    cache: Optional[Dict[str, jax.Array]] = None,  # {"k","v"} (B, S_max, KV, D)
    cache_len: Optional[jax.Array] = None,  # () int32 tokens already cached
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    B, S, _ = x.shape
    h, kv, hd = c.n_heads, c.n_kv_heads, c.head_dim
    q = (x @ p["wq"]).reshape(B, S, h, hd)
    k = v = None
    if not (c.cross and cache is not None):  # cross-decode reads cached enc KV
        src = kv_source if c.cross else x
        k = (src @ p["wk"]).reshape(B, src.shape[1], kv, hd)
        v = (src @ p["wv"]).reshape(B, src.shape[1], kv, hd)
    if c.qk_norm:
        q = apply_rmsnorm(p["q_norm"], q)
        if k is not None:
            k = apply_rmsnorm(p["k_norm"], k)
    if not c.cross:
        q = rope(q, positions, c.rope_theta)
        k = rope(k, positions, c.rope_theta)

    new_cache = None
    if cache is not None and not c.cross:
        # decode: append to cache, attend over the valid prefix
        k_all = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), cache_len, axis=1)
        v_all = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), cache_len, axis=1)
        new_cache = {"k": k_all, "v": v_all}
        k_pos = jnp.arange(cache["k"].shape[1], dtype=jnp.int32)
        out = _attend(
            q,
            k_all,
            v_all,
            q_pos=positions,
            k_pos=k_pos,
            causal=c.causal,
            window=c.window,
            cap=c.attn_softcap,
            k_len=cache_len + S,
        )
    elif cache is not None and c.cross:
        # cross-attention cache holds the projected encoder kv, computed once
        out = _attend(
            q,
            cache["k"],
            cache["v"],
            q_pos=positions,
            k_pos=jnp.arange(cache["k"].shape[1], dtype=jnp.int32),
            causal=False,
            window=None,
            cap=c.attn_softcap,
        )
        new_cache = cache
    else:
        k_pos = positions if positions.ndim == 1 else positions[0]
        out = _attend(
            q,
            k,
            v,
            q_pos=positions,
            k_pos=jnp.arange(src.shape[1], dtype=jnp.int32) if c.cross else k_pos,
            causal=c.causal and not c.cross,
            window=c.window,
            cap=c.attn_softcap,
        )
    y = out.reshape(B, S, h * hd).astype(x.dtype) @ p["wo"]
    return y, new_cache


def cross_kv(p: Params, enc_out: jax.Array, c: AttnConfig) -> Dict[str, jax.Array]:
    """Precompute the cross-attention KV from encoder output (cached once)."""
    B, S, _ = enc_out.shape
    k = (enc_out @ p["wk"]).reshape(B, S, c.n_kv_heads, c.head_dim)
    v = (enc_out @ p["wv"]).reshape(B, S, c.n_kv_heads, c.head_dim)
    return {"k": k, "v": v}


# ---------------------------------------------------------------------------
# MLA (deepseek-v2 multi-head latent attention)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MLAConfig:
    d_model: int
    n_heads: int
    q_lora: int = 1536
    kv_lora: int = 512
    d_nope: int = 128
    d_rope: int = 64
    d_v: int = 128
    rope_theta: float = 10_000.0


def mla_defs(c: MLAConfig) -> Params:
    h = c.n_heads
    return {
        "wq_a": pdef((c.d_model, c.q_lora), ("embed", None)),
        "q_norm": rmsnorm_defs(c.q_lora),
        "wq_b": pdef(
            (c.q_lora, h * (c.d_nope + c.d_rope)), (None, "heads"),
            granularity=(1, c.d_nope + c.d_rope),
        ),
        "wkv_a": pdef((c.d_model, c.kv_lora + c.d_rope), ("embed", None)),
        "kv_norm": rmsnorm_defs(c.kv_lora),
        "wk_b": pdef((c.kv_lora, h * c.d_nope), (None, "heads"), granularity=(1, c.d_nope)),
        "wv_b": pdef((c.kv_lora, h * c.d_v), (None, "heads"), granularity=(1, c.d_v)),
        "wo": pdef((h * c.d_v, c.d_model), ("heads", "embed"), granularity=(c.d_v, 1)),
    }


def apply_mla(
    p: Params,
    x: jax.Array,
    c: MLAConfig,
    *,
    positions: jax.Array,
    cache: Optional[Dict[str, jax.Array]] = None,  # {"ckv": (B, S_max, kv_lora + d_rope)}
    cache_len: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    B, S, _ = x.shape
    h = c.n_heads
    # queries
    q = apply_rmsnorm(p["q_norm"], x @ p["wq_a"]) @ p["wq_b"]
    q = q.reshape(B, S, h, c.d_nope + c.d_rope)
    q_nope, q_rope = q[..., : c.d_nope], q[..., c.d_nope :]
    q_rope = rope(q_rope, positions, c.rope_theta)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)

    # compressed kv: the ONLY thing cached (MLA's memory saving)
    ckv_full = x @ p["wkv_a"]  # (B, S, kv_lora + d_rope)
    ckv, k_rope = ckv_full[..., : c.kv_lora], ckv_full[..., c.kv_lora :]
    ckv = apply_rmsnorm(p["kv_norm"], ckv)
    k_rope = rope(k_rope[:, :, None, :], positions, c.rope_theta)[:, :, 0, :]
    packed = jnp.concatenate([ckv, k_rope], axis=-1)

    new_cache = None
    if cache is not None:
        packed = jax.lax.dynamic_update_slice_in_dim(
            cache["ckv"], packed.astype(cache["ckv"].dtype), cache_len, axis=1
        )
        new_cache = {"ckv": packed}
        k_len = cache_len + S
    else:
        k_len = None

    ckv_all = packed[..., : c.kv_lora]
    k_rope_all = packed[..., c.kv_lora :]
    Sk = packed.shape[1]
    # expand compressed kv (absorbed-matmul variant is a perf iteration)
    k_nope = (ckv_all @ p["wk_b"]).reshape(B, Sk, h, c.d_nope)
    v = (ckv_all @ p["wv_b"]).reshape(B, Sk, h, c.d_v)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope_all[:, :, None, :], (B, Sk, h, c.d_rope))], axis=-1
    )
    out = _attend(
        q,
        k,
        v if c.d_v == c.d_nope + c.d_rope else jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, c.d_nope + c.d_rope - c.d_v))),
        q_pos=positions,
        k_pos=jnp.arange(Sk, dtype=jnp.int32),
        causal=True,
        window=None,
        cap=None,
        k_len=k_len,
    )[..., : c.d_v]
    y = out.reshape(B, S, h * c.d_v).astype(x.dtype) @ p["wo"]
    return y, new_cache


# ---------------------------------------------------------------------------
# FFN
# ---------------------------------------------------------------------------


def ffn_defs(d: int, f: int, kind: str) -> Params:
    if kind in ("swiglu", "geglu"):
        return {
            "w_gate": pdef((d, f), ("embed", "ff")),
            "w_up": pdef((d, f), ("embed", "ff")),
            "w_down": pdef((f, d), ("ff", "embed")),
        }
    return {
        "w_in": pdef((d, f), ("embed", "ff")),
        "b_in": pdef((f,), ("ff",), init="zeros"),
        "w_out": pdef((f, d), ("ff", "embed")),
        "b_out": pdef((d,), (None,), init="zeros"),
    }


def apply_ffn(p: Params, x: jax.Array, kind: str) -> jax.Array:
    if kind == "swiglu":
        return (jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]
    if kind == "geglu":
        return (jax.nn.gelu(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]
    return (jax.nn.gelu(x @ p["w_in"] + p["b_in"])) @ p["w_out"] + p["b_out"]


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    expert_ff: int
    n_shared: int = 0  # shared experts (deepseek-v2)
    shared_ff: int = 0
    dense_residual: bool = False  # parallel dense FFN branch (arctic)
    dense_ff: int = 0
    capacity_factor: float = 1.25


def moe_defs(d: int, c: MoEConfig, ffn_kind: str = "swiglu") -> Params:
    p: Params = {
        "router": pdef((d, c.n_experts), ("embed", None), scale=0.1),
        "w_gate": pdef((c.n_experts, d, c.expert_ff), ("experts", "embed", "ff")),
        "w_up": pdef((c.n_experts, d, c.expert_ff), ("experts", "embed", "ff")),
        "w_down": pdef((c.n_experts, c.expert_ff, d), ("experts", "ff", "embed")),
    }
    if c.n_shared > 0:
        p["shared"] = ffn_defs(d, c.shared_ff or c.expert_ff * c.n_shared, ffn_kind)
    if c.dense_residual:
        p["dense"] = ffn_defs(d, c.dense_ff or c.expert_ff, ffn_kind)
    return p


def apply_moe(p: Params, x: jax.Array, c: MoEConfig, ffn_kind: str = "swiglu") -> jax.Array:
    """GShard-style GROUPED capacity dispatch: einsum one-hots, static shapes.

    x: (B, S, d). Each sequence is a dispatch group (GShard's 'groups'):
    capacity = ceil(cf * k * S / E) **per group**. Ungrouped dispatch over the
    global token batch makes capacity O(total tokens) and the dispatch
    einsums quadratic in it — the dry-run roofline measured 100x the model
    FLOPs on deepseek-v2 before this grouping (EXPERIMENTS.md SPerf).
    Groups stay sharded over (pod, data); experts over the model axis, so
    dispatch lowers to an all-to-all-like collective under GSPMD.
    """
    B, S, d = x.shape
    cap = max(c.top_k, int(c.capacity_factor * c.top_k * S / c.n_experts))

    logits = (x @ p["router"]).astype(jnp.float32)  # (B, S, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, c.top_k)  # (B, S, k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

    onehot = jax.nn.one_hot(top_e, c.n_experts, dtype=jnp.float32)  # (B, S, k, E)
    # position of each (token, slot) within its expert's per-group buffer
    flat = onehot.reshape(B, S * c.top_k, c.n_experts)
    pos = (jnp.cumsum(flat, axis=1) - 1.0).reshape(B, S, c.top_k, c.n_experts)
    keep = (pos < cap) & (onehot > 0)
    pos_oh = jax.nn.one_hot(pos, cap, dtype=jnp.float32) * keep[..., None]
    # dispatch: (B, S, k, E) x (B, S, k, E, cap) -> (B, S, E, cap)
    dispatch = jnp.einsum("bske,bskec->bsec", onehot, pos_oh)
    combine = jnp.einsum("bsk,bske,bskec->bsec", top_p, onehot, pos_oh)

    xe = jnp.einsum("bsec,bsd->becd", dispatch, x.astype(jnp.float32)).astype(x.dtype)
    h = jax.nn.silu(jnp.einsum("becd,edf->becf", xe, p["w_gate"])) * jnp.einsum(
        "becd,edf->becf", xe, p["w_up"]
    )
    ye = jnp.einsum("becf,efd->becd", h, p["w_down"])
    y = jnp.einsum("bsec,becd->bsd", combine, ye.astype(jnp.float32)).astype(x.dtype)
    if c.n_shared > 0:
        y = y + apply_ffn(p["shared"], x, ffn_kind)
    if c.dense_residual:
        y = y + apply_ffn(p["dense"], x, ffn_kind)
    return y


# ---------------------------------------------------------------------------
# RG-LRU recurrent block (recurrentgemma / Griffin)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class RGLRUConfig:
    d_model: int
    width: int  # recurrence width (channels)
    conv_width: int = 4
    c_const: float = 8.0
    use_kernel: bool = True
    # Griffin uses BLOCK-DIAGONAL gate matrices (one block per head); the
    # dense variant is our conservative baseline — block-diagonal gates
    # remove the cross-shard contraction entirely (SPerf iteration).
    block_diag_gates: bool = False
    n_gate_blocks: int = 1


def rglru_defs(c: RGLRUConfig) -> Params:
    d, r = c.d_model, c.width
    p = {
        "w_x": pdef((d, r), ("embed", "ff")),
        "w_gate": pdef((d, r), ("embed", "ff")),
        "conv_k": pdef((c.conv_width, r), (None, "ff"), scale=0.5),
        "conv_b": pdef((r,), ("ff",), init="zeros"),
        "b_rg": pdef((r,), ("ff",), init="zeros"),
        "b_ig": pdef((r,), ("ff",), init="zeros"),
        "lam": pdef((r,), ("ff",), init="normal", scale=1.0, dtype=jnp.float32),
        "w_out": pdef((r, d), ("ff", "embed")),
    }
    if c.block_diag_gates:
        nb = c.n_gate_blocks
        rb = r // nb
        # gate blocks sharded at block granularity on dim 0 (contraction
        # stays shard-local when the channel sharding aligns to blocks)
        p["w_rg"] = pdef((nb, rb, rb), ("ff", None, None), scale=0.5)
        p["w_ig"] = pdef((nb, rb, rb), ("ff", None, None), scale=0.5)
    else:
        p["w_rg"] = pdef((r, r), ("ff", None), scale=0.5)  # recurrence gate
        p["w_ig"] = pdef((r, r), ("ff", None), scale=0.5)  # input gate
    return p


def _gate_matmul(u: jax.Array, w: jax.Array, c: RGLRUConfig) -> jax.Array:
    if not c.block_diag_gates:
        return u @ w
    nb = c.n_gate_blocks
    B, S, r = u.shape
    ub = u.reshape(B, S, nb, r // nb)
    return jnp.einsum("bsnr,nre->bsne", ub, w).reshape(B, S, r)


def _causal_conv1d(x: jax.Array, k: jax.Array, b: jax.Array, state: Optional[jax.Array] = None):
    """x: (B, S, r); k: (W, r) depthwise. state: (B, W-1, r) trailing inputs."""
    W = k.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)  # (B, S+W-1, r)
    out = sum(xp[:, i : i + x.shape[1], :] * k[i] for i in range(W)) + b
    new_state = xp[:, -(W - 1) :, :]
    return out.astype(x.dtype), new_state


def apply_rglru(
    p: Params,
    x: jax.Array,  # (B, S, d)
    c: RGLRUConfig,
    *,
    cache: Optional[Dict[str, jax.Array]] = None,  # {"h": (B, r), "conv": (B, W-1, r)}
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    gate = jax.nn.gelu(x @ p["w_gate"])  # (B, S, r)
    u = x @ p["w_x"]
    u, conv_state = _causal_conv1d(u, p["conv_k"], p["conv_b"], cache["conv"] if cache else None)

    r_gate = jax.nn.sigmoid(_gate_matmul(u, p["w_rg"], c) + p["b_rg"]).astype(jnp.float32)
    i_gate = jax.nn.sigmoid(_gate_matmul(u, p["w_ig"], c) + p["b_ig"]).astype(jnp.float32)
    log_a = -c.c_const * jax.nn.softplus(p["lam"]) * r_gate  # (B, S, r) in fp32
    a = jnp.exp(log_a)
    gated_in = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (
        i_gate * u.astype(jnp.float32)
    )

    h0 = cache["h"].astype(jnp.float32) if cache else jnp.zeros(
        (x.shape[0], c.width), jnp.float32
    )
    if c.use_kernel:
        from repro.kernels.rglru.ops import linear_scan

        h = linear_scan(a, gated_in, h0)
    else:
        from repro.kernels.rglru.ref import linear_scan_ref

        h = linear_scan_ref(a, gated_in, h0)

    y = (h.astype(x.dtype) * gate) @ p["w_out"]
    new_cache = None
    if cache is not None:
        new_cache = {"h": h[:, -1, :].astype(cache["h"].dtype), "conv": conv_state}
    return y, new_cache


# ---------------------------------------------------------------------------
# xLSTM blocks
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class XLSTMConfig:
    d_model: int
    n_heads: int
    expansion: int = 2  # mLSTM up-projection factor
    chunk: int = 64  # chunkwise-recurrent block length (mLSTM)


def mlstm_defs(c: XLSTMConfig) -> Params:
    d = c.d_model
    di = c.expansion * d
    return {
        "w_up": pdef((d, 2 * di), ("embed", "ff")),
        "wq": pdef((di, di), ("ff", None)),
        "wk": pdef((di, di), ("ff", None)),
        "wv": pdef((di, di), ("ff", None)),
        "w_if": pdef((di, 2 * c.n_heads), ("ff", None), scale=0.1),  # i/f gate logits
        "b_if": pdef((2 * c.n_heads,), (None,), init="zeros"),
        "norm": rmsnorm_defs(di),
        "w_down": pdef((di, d), ("ff", "embed")),
    }


def apply_mlstm(
    p: Params,
    x: jax.Array,  # (B, S, d)
    c: XLSTMConfig,
    *,
    cache: Optional[Dict[str, jax.Array]] = None,
    # cache: {"C": (B, H, dh, dh), "n": (B, H, dh), "m": (B, H)}
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    B, S, d = x.shape
    di = c.expansion * d
    H = c.n_heads
    dh = di // H
    up = x @ p["w_up"]
    u, z = up[..., :di], up[..., di:]
    q = (u @ p["wq"]).reshape(B, S, H, dh)
    k = (u @ p["wk"]).reshape(B, S, H, dh) / math.sqrt(dh)
    v = (u @ p["wv"]).reshape(B, S, H, dh)
    gates = (u @ p["w_if"] + p["b_if"]).astype(jnp.float32)  # (B, S, 2H)
    log_i = gates[..., :H]  # exponential input gate (log space)
    log_f = jax.nn.log_sigmoid(gates[..., H:])  # forget gate

    def step(carry, t):
        C, n, m = carry  # (B,H,dh,dh), (B,H,dh), (B,H)
        li, lf = log_i[:, t], log_f[:, t]
        m_new = jnp.maximum(lf + m, li)
        fg = jnp.exp(lf + m - m_new)
        ig = jnp.exp(li - m_new)
        kt, vt, qt = k[:, t], v[:, t], q[:, t]
        C = fg[..., None, None] * C + ig[..., None, None] * jnp.einsum("bhd,bhe->bhde", vt, kt)
        n = fg[..., None] * n + ig[..., None] * kt
        num = jnp.einsum("bhde,bhe->bhd", C, qt.astype(jnp.float32))
        den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n, qt.astype(jnp.float32))), 1.0)
        h = (num / den[..., None]).astype(x.dtype)
        return (C, n, m_new), h

    if cache is not None:
        carry0 = (
            cache["C"].astype(jnp.float32),
            cache["n"].astype(jnp.float32),
            cache["m"].astype(jnp.float32),
        )
    else:
        carry0 = (
            jnp.zeros((B, H, dh, dh), jnp.float32),
            jnp.zeros((B, H, dh), jnp.float32),
            jnp.full((B, H), -1e30, jnp.float32),
        )
    (C, n, m), hs = jax.lax.scan(step, carry0, jnp.arange(S))
    h = jnp.moveaxis(hs, 0, 1).reshape(B, S, di)  # (B, S, H, dh) -> flat
    h = apply_rmsnorm(p["norm"], h) * jax.nn.silu(z)
    y = h @ p["w_down"]
    new_cache = None
    if cache is not None:
        new_cache = {
            "C": C.astype(cache["C"].dtype),
            "n": n.astype(cache["n"].dtype),
            "m": m.astype(cache["m"].dtype),
        }
    return y, new_cache


def slstm_defs(c: XLSTMConfig) -> Params:
    d = c.d_model
    H = c.n_heads
    dh = d // H
    p = {
        "w_gates": pdef((d, 4 * d), ("embed", "ff")),  # i, f, z, o pre-activations
        "b_gates": pdef((4 * d,), (None,), init="zeros"),
        "r_gates": pdef((H, dh, 4 * dh), (None, None, None), scale=0.5),  # block-diag recurrent
        "norm": rmsnorm_defs(d),
        "w_out": pdef((d, d), ("embed", None)),
    }
    return p


def apply_slstm(
    p: Params,
    x: jax.Array,
    c: XLSTMConfig,
    *,
    cache: Optional[Dict[str, jax.Array]] = None,
    # cache: {"c": (B, d), "n": (B, d), "m": (B, d), "h": (B, d)}
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    B, S, d = x.shape
    H = c.n_heads
    dh = d // H
    pre = x @ p["w_gates"] + p["b_gates"]  # (B, S, 4d)

    def step(carry, t):
        cst, nst, mst, hst = carry  # (B,d) each, fp32
        rec = jnp.einsum(
            "bhd,hde->bhe", hst.reshape(B, H, dh).astype(x.dtype), p["r_gates"]
        ).reshape(B, 4 * d)
        g = (pre[:, t] + rec).astype(jnp.float32)
        gi, gf, gz, go = jnp.split(g, 4, axis=-1)
        m_new = jnp.maximum(jax.nn.log_sigmoid(gf) + mst, gi)
        ig = jnp.exp(gi - m_new)
        fg = jnp.exp(jax.nn.log_sigmoid(gf) + mst - m_new)
        z = jnp.tanh(gz)
        o = jax.nn.sigmoid(go)
        c_new = fg * cst + ig * z
        n_new = fg * nst + ig
        h_new = o * c_new / jnp.maximum(n_new, 1.0)
        return (c_new, n_new, m_new, h_new), h_new.astype(x.dtype)

    if cache is not None:
        carry0 = tuple(cache[k].astype(jnp.float32) for k in ("c", "n", "m", "h"))
    else:
        zero = jnp.zeros((B, d), jnp.float32)
        carry0 = (zero, zero, jnp.full((B, d), -1e30, jnp.float32), zero)
    carry, hs = jax.lax.scan(step, carry0, jnp.arange(S))
    h = jnp.moveaxis(hs, 0, 1)  # (B, S, d)
    y = apply_rmsnorm(p["norm"], h) @ p["w_out"]
    new_cache = None
    if cache is not None:
        cst, nst, mst, hst = carry
        new_cache = {
            "c": cst.astype(cache["c"].dtype),
            "n": nst.astype(cache["n"].dtype),
            "m": mst.astype(cache["m"].dtype),
            "h": hst.astype(cache["h"].dtype),
        }
    return y, new_cache
