"""Assigned LM-family architectures (dense / MoE / hybrid / SSM / enc-dec).

One generic transformer substrate with per-layer block kinds covers all ten
assigned architectures; parameters are declared as ``ParamDef`` trees that
carry logical sharding axes, so the same definition drives smoke tests
(materialized), the multi-pod dry-run (abstract), and sharding rules.
"""
