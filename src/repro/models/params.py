"""Parameter declaration system: shapes + logical sharding axes in one tree.

A model definition builds a pytree of ``ParamDef`` leaves. From that single
tree we derive:

* ``materialize(key, tree)``   — real initialized arrays (smoke tests, examples)
* ``abstract(tree)``           — ShapeDtypeStructs (dry-run lowering, no memory)
* ``specs(tree, rules, mesh)`` — PartitionSpecs per leaf from the logical axes

Logical axis names used by the LM stack:
  "embed"   model width dim          -> FSDP-sharded over the data axis
  "ff"      feed-forward hidden      -> tensor-parallel over the model axis
  "heads"   flattened head*head_dim  -> tensor-parallel over the model axis
  "kv"      flattened kv*head_dim    -> tensor-parallel over the model axis
  "vocab"   vocabulary               -> tensor-parallel over the model axis
  "experts" MoE expert count         -> expert-parallel over the model axis
  "layers"  stacked layer dim        -> never sharded (scan axis)
  None      replicated
"""

from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P


@dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]  # logical axis per dim, len == len(shape)
    init: str = "normal"  # normal | zeros | ones
    scale: float = 1.0  # stddev multiplier for "normal" (fan-in scaled)
    dtype: Any = jnp.bfloat16
    # sharding granularity per dim: a mesh axis may shard dim d only if
    # (shape[d] / granularity[d]) % axis_size == 0. Head dims set this to
    # head_dim so sharding never crosses a head boundary (element-sharded
    # heads produce pathological attention collectives).
    granularity: Optional[Tuple[int, ...]] = None

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)
        if self.granularity is not None:
            assert len(self.granularity) == len(self.shape)

    def gran(self, i: int) -> int:
        return 1 if self.granularity is None else self.granularity[i]


def pdef(shape, axes, init="normal", scale=1.0, dtype=jnp.bfloat16, granularity=None) -> ParamDef:
    return ParamDef(
        tuple(int(s) for s in shape), tuple(axes), init, scale, dtype,
        tuple(granularity) if granularity is not None else None,
    )


def is_def(x) -> bool:
    return isinstance(x, ParamDef)


def _tree_map_defs(fn, tree):
    return jax.tree_util.tree_map(fn, tree, is_leaf=is_def)


def abstract(tree):
    return _tree_map_defs(lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype), tree)


def materialize(key: jax.Array, tree, dtype_override=None):
    """Initialize real arrays. Deterministic per-leaf folding of the key."""
    leaves, treedef = jax.tree_util.tree_flatten(tree, is_leaf=is_def)
    out = []
    for i, d in enumerate(leaves):
        dt = dtype_override or d.dtype
        k = jax.random.fold_in(key, i)
        if d.init == "zeros":
            out.append(jnp.zeros(d.shape, dt))
        elif d.init == "ones":
            out.append(jnp.ones(d.shape, dt))
        else:
            fan_in = d.shape[-2] if len(d.shape) >= 2 else d.shape[-1]
            std = d.scale / math.sqrt(max(fan_in, 1))
            out.append((std * jax.random.normal(k, d.shape, jnp.float32)).astype(dt))
    return jax.tree_util.tree_unflatten(treedef, out)


# -- sharding rules --------------------------------------------------------------


@dataclass(frozen=True)
class ShardingRules:
    """logical axis -> candidate mesh axes; the first candidate whose axes all
    exist in the mesh AND evenly divide the dim wins."""

    rules: Tuple[Tuple[str, Tuple[Any, ...]], ...] = (
        ("embed", ("data", None)),  # FSDP / ZeRO-3 analogue
        ("ff", ("model", None)),  # tensor parallel
        ("heads", ("model", None)),
        ("kv", ("model", None)),
        ("vocab", ("model", "data", None)),
        ("experts", ("model", None)),  # expert parallel
        ("batch", (("pod", "data"), "data", None)),  # data parallel (+pod)
        ("act_seq", (None,)),  # cache sequence dim; 'model' = flash-decode shard
        ("layers", (None,)),
    )

    def lookup(self, logical: Optional[str]) -> Tuple[Any, ...]:
        if logical is None:
            return (None,)
        for name, cands in self.rules:
            if name == logical:
                return cands
        return (None,)

    def replace(self, logical: str, cands: Tuple[Any, ...]) -> "ShardingRules":
        new = tuple(
            (n, cands if n == logical else c) for (n, c) in self.rules
        )
        if logical not in [n for n, _ in self.rules]:
            new = new + ((logical, cands),)
        return ShardingRules(rules=new)


def _axes_in_mesh(mesh, axis) -> bool:
    flat = axis if isinstance(axis, tuple) else (axis,)
    return all(a in mesh.shape for a in flat)


def _axis_size(mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return int(mesh.shape[axis])


def spec_for(d: ParamDef, rules: ShardingRules, mesh) -> P:
    parts = []
    used = set()
    for i, (dim, logical) in enumerate(zip(d.shape, d.axes)):
        chosen = None
        units = dim // d.gran(i)  # shardable units (e.g. heads, not elements)
        for cand in rules.lookup(logical):
            if cand is None:
                chosen = None
                break
            flat = cand if isinstance(cand, tuple) else (cand,)
            if not _axes_in_mesh(mesh, cand):
                continue
            if any(a in used for a in flat):
                continue
            if units % _axis_size(mesh, cand) == 0:
                chosen = cand
                used.update(flat)
                break
        parts.append(chosen)
    return P(*parts)


def specs(tree, rules: ShardingRules, mesh):
    return _tree_map_defs(lambda d: spec_for(d, rules, mesh), tree)


def shardings(tree, rules: ShardingRules, mesh):
    from jax.sharding import NamedSharding

    return _tree_map_defs(lambda d: NamedSharding(mesh, spec_for(d, rules, mesh)), tree)


def count_params(tree) -> int:
    leaves = jax.tree_util.tree_leaves(tree, is_leaf=is_def)
    return int(sum(np.prod(d.shape) for d in leaves))


def bytes_params(tree) -> int:
    leaves = jax.tree_util.tree_leaves(tree, is_leaf=is_def)
    return int(sum(np.prod(d.shape) * np.dtype(d.dtype).itemsize for d in leaves))
