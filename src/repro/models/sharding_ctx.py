"""Activation-sharding context for the LM stack.

GSPMD propagates parameter shardings into activations; with FSDP-style
(data-axis) parameter sharding the propagation is ambiguous — the partitioner
may put the data axis on a *feature* dim of activations instead of the batch
dim, triggering involuntary full rematerialization (observed: 437 GB/chip
temp on whisper train_4k; see EXPERIMENTS.md SPerf iteration 1).

The drivers install the mesh here; ``forward`` then pins activations to
batch-over-(pod, data) at block boundaries. When no mesh is installed (smoke
tests, single device) every constraint is a no-op.
"""

from __future__ import annotations

from contextlib import contextmanager
from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

_MESH = None
_SEQ_PARALLEL = False  # shard dim 1 (sequence) of 3D activations over 'model'


def set_mesh(mesh) -> None:
    global _MESH
    _MESH = mesh


def get_mesh():
    return _MESH


def set_seq_parallel(on: bool) -> None:
    global _SEQ_PARALLEL
    _SEQ_PARALLEL = on


@contextmanager
def use_mesh(mesh, seq_parallel: bool = False):
    global _MESH, _SEQ_PARALLEL
    prev, prev_sp = _MESH, _SEQ_PARALLEL
    _MESH, _SEQ_PARALLEL = mesh, seq_parallel
    try:
        yield
    finally:
        _MESH, _SEQ_PARALLEL = prev, prev_sp


def _batch_axes(mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def constrain_batch(x: jax.Array) -> jax.Array:
    """Pin dim 0 to the data-parallel axes (divisibility-checked). With
    sequence parallelism on, dim 1 of 3D activations is additionally pinned
    to the model axis (Megatron-SP: the per-layer saved residual stream and
    all elementwise/norm work shard 16x). No-op without an installed mesh."""
    if _MESH is None:
        return x
    axes = _batch_axes(_MESH)
    if not axes:
        return x
    size = int(np.prod([_MESH.shape[a] for a in axes]))
    if x.shape[0] % size != 0:
        return x
    rest = [None] * (x.ndim - 1)
    if (
        _SEQ_PARALLEL
        and x.ndim == 3
        and "model" in _MESH.shape
        and x.shape[1] % _MESH.shape["model"] == 0
    ):
        rest[0] = "model"
    spec = P(axes if len(axes) > 1 else axes[0], *rest)
    return jax.lax.with_sharding_constraint(x, NamedSharding(_MESH, spec))


def constrain(x: jax.Array, *spec_parts) -> jax.Array:
    if _MESH is None:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(_MESH, P(*spec_parts)))
