"""Generic transformer assembly covering all ten assigned architectures.

A model is a prefix + repeated group pattern + suffix of *blocks*; the group
pattern is stacked and scanned (``lax.scan``) with optional remat, which keeps
the lowered HLO small even for 95-layer stacks. Block kinds:

  "attn"     global attention + FFN           (internlm2, qwen3, deepseek-67b,
                                               internvl2 backbone)
  "local"    sliding-window attention + FFN   (gemma2, recurrentgemma)
  "global"   global attention + FFN w/ gemma2 sandwich norms + softcaps
  "moe"      global attention + MoE           (arctic: + dense residual)
  "mla"      MLA attention + dense FFN        (deepseek-v2 first layer)
  "mla_moe"  MLA attention + MoE              (deepseek-v2)
  "rec"      RG-LRU recurrent block + FFN     (recurrentgemma)
  "mlstm"/"slstm"  xLSTM blocks (no separate FFN; d_ff = 0)
  "enc"      bidirectional attention + FFN    (whisper encoder)
  "dec"      causal self-attn + cross-attn + FFN (whisper decoder)
"""

from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from functools import partial
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import blocks as B
from repro.models.params import ParamDef, pdef
from repro.models.sharding_ctx import constrain_batch

Params = Dict[str, Any]


@dataclass(frozen=True)
class ModelConfig:
    name: str
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    # layer structure
    prefix: Tuple[str, ...] = ()
    pattern: Tuple[str, ...] = ("attn",)
    n_groups: int = 1
    suffix: Tuple[str, ...] = ()
    # attention details
    qk_norm: bool = False
    attn_softcap: Optional[float] = None
    final_softcap: Optional[float] = None
    window: Optional[int] = None
    rope_theta: float = 10_000.0
    # families
    mla: Optional[B.MLAConfig] = None
    moe: Optional[B.MoEConfig] = None
    rnn_width: Optional[int] = None
    conv_width: int = 4
    xlstm: Optional[B.XLSTMConfig] = None
    # ffn / embeddings
    ffn_kind: str = "swiglu"
    tie_embeddings: bool = False
    emb_scale: bool = False
    norm_eps: float = 1e-6
    # enc-dec (whisper): encoder stack runs first; None = decoder-only
    enc_pattern: Optional[Tuple[str, ...]] = None
    enc_groups: int = 0
    enc_positions: str = "rope"  # rope | sinusoidal
    # modality frontend stub
    frontend: str = "none"  # none | vision | audio
    vis_len: int = 0  # visual prefix length (vlm)
    # remat policy for the group scan: none | full | dots
    remat: str = "full"
    # use the Pallas linear-scan kernel inside RG-LRU blocks
    use_rglru_kernel: bool = False
    # Griffin-style block-diagonal RG-LRU gate matrices (SPerf iteration)
    rg_blockdiag: bool = False
    # lax.scan over layer groups (False = python loop, fully inlined HLO;
    # used by the dry-run's delta-corrected roofline lowering)
    scan_layers: bool = True

    def n_layers(self) -> int:
        return (
            len(self.prefix)
            + self.n_groups * len(self.pattern)
            + len(self.suffix)
            + self.enc_groups * len(self.enc_pattern or ())
        )

    def attn_cfg(self, kind: str) -> B.AttnConfig:
        return B.AttnConfig(
            d_model=self.d_model,
            n_heads=self.n_heads,
            n_kv_heads=self.n_kv_heads,
            head_dim=self.head_dim,
            qk_norm=self.qk_norm,
            attn_softcap=self.attn_softcap,
            window=self.window if kind == "local" else None,
            causal=kind != "enc",
            rope_theta=self.rope_theta,
            cross=False,
        )

    def cross_cfg(self) -> B.AttnConfig:
        return dataclasses.replace(self.attn_cfg("dec"), cross=True, causal=False)

    def rglru_cfg(self) -> B.RGLRUConfig:
        return B.RGLRUConfig(
            d_model=self.d_model,
            width=self.rnn_width or self.d_model,
            conv_width=self.conv_width,
            use_kernel=self.use_rglru_kernel,
            block_diag_gates=self.rg_blockdiag,
            n_gate_blocks=self.n_heads if self.rg_blockdiag else 1,
        )


# ---------------------------------------------------------------------------
# block definitions
# ---------------------------------------------------------------------------

_SANDWICH = ("global", "local")  # gemma2-style pre+post norms


def block_defs(cfg: ModelConfig, kind: str) -> Params:
    d = cfg.d_model
    p: Params = {"norm1": B.rmsnorm_defs(d)}
    if kind in ("attn", "local", "global", "moe", "enc", "dec"):
        p["attn"] = B.attn_defs(cfg.attn_cfg(kind))
    elif kind in ("mla", "mla_moe"):
        p["attn"] = B.mla_defs(cfg.mla)
    elif kind == "rec":
        p["rec"] = B.rglru_defs(cfg.rglru_cfg())
    elif kind == "mlstm":
        p["mix"] = B.mlstm_defs(cfg.xlstm)
        return p  # xLSTM blocks: mixer only
    elif kind == "slstm":
        p["mix"] = B.slstm_defs(cfg.xlstm)
        return p
    else:
        raise ValueError(kind)

    if kind == "dec":
        p["norm_c"] = B.rmsnorm_defs(d)
        p["cross"] = B.attn_defs(cfg.cross_cfg())

    p["norm2"] = B.rmsnorm_defs(d)
    if kind in ("moe", "mla_moe"):
        p["moe"] = B.moe_defs(d, cfg.moe, cfg.ffn_kind)
    else:
        p["ffn"] = B.ffn_defs(d, cfg.d_ff, cfg.ffn_kind)
    if kind in _SANDWICH and cfg.name.startswith("gemma2"):
        p["post_norm1"] = B.rmsnorm_defs(d)
        p["post_norm2"] = B.rmsnorm_defs(d)
    return p


def cache_defs(cfg: ModelConfig, kind: str, batch: int, max_seq: int) -> Params:
    """Decode-cache ParamDefs for one block (shapes + sharding axes)."""
    kvd = cfg.n_kv_heads * 0 + cfg.head_dim
    if kind in ("attn", "global", "moe", "enc"):
        shp = (batch, max_seq, cfg.n_kv_heads, cfg.head_dim)
        ax = ("batch", "act_seq", "kv", None)
        return {"k": pdef(shp, ax, init="zeros"), "v": pdef(shp, ax, init="zeros")}
    if kind == "local":
        s = min(max_seq, (cfg.window or max_seq))
        # window cache is allocated at full window size (ring indexing is a
        # perf iteration; baseline keeps the simple full buffer when short)
        shp = (batch, max_seq, cfg.n_kv_heads, cfg.head_dim)
        ax = ("batch", "act_seq", "kv", None)
        return {"k": pdef(shp, ax, init="zeros"), "v": pdef(shp, ax, init="zeros")}
    if kind in ("mla", "mla_moe"):
        m = cfg.mla
        return {
            "ckv": pdef(
                (batch, max_seq, m.kv_lora + m.d_rope),
                ("batch", "act_seq", None),
                init="zeros",
            )
        }
    if kind == "rec":
        r = cfg.rnn_width or cfg.d_model
        return {
            "h": pdef((batch, r), ("batch", "ff"), init="zeros", dtype=jnp.float32),
            "conv": pdef((batch, cfg.conv_width - 1, r), ("batch", None, "ff"), init="zeros"),
        }
    if kind == "mlstm":
        x = cfg.xlstm
        di = x.expansion * cfg.d_model
        dh = di // x.n_heads
        return {
            "C": pdef((batch, x.n_heads, dh, dh), ("batch", "heads", None, None), init="zeros", dtype=jnp.float32),
            "n": pdef((batch, x.n_heads, dh), ("batch", "heads", None), init="zeros", dtype=jnp.float32),
            "m": pdef((batch, x.n_heads), ("batch", None), init="zeros", dtype=jnp.float32),
        }
    if kind == "slstm":
        d = cfg.d_model
        z = {"c": None, "n": None, "m": None, "h": None}
        return {
            k: pdef((batch, d), ("batch", "ff"), init="zeros", dtype=jnp.float32) for k in z
        }
    if kind == "dec":
        shp = (batch, max_seq, cfg.n_kv_heads, cfg.head_dim)
        ax = ("batch", "act_seq", "kv", None)
        enc_len = 1500  # whisper native encoder frames
        xshp = (batch, enc_len, cfg.n_kv_heads, cfg.head_dim)
        return {
            "k": pdef(shp, ax, init="zeros"),
            "v": pdef(shp, ax, init="zeros"),
            "xk": pdef(xshp, ax, init="zeros"),
            "xv": pdef(xshp, ax, init="zeros"),
        }
    raise ValueError(kind)


def model_defs(cfg: ModelConfig) -> Params:
    """Full parameter tree (ParamDefs) for a model config."""
    d, v = cfg.d_model, cfg.vocab
    p: Params = {
        "embed": pdef((v, d), ("vocab", "embed"), scale=1.0),
        "final_norm": B.rmsnorm_defs(d),
    }
    if not cfg.tie_embeddings:
        p["head"] = pdef((d, v), ("embed", "vocab"))
    if cfg.enc_pattern:
        p["enc_groups"] = _stack_defs(
            {f"b{i}": block_defs(cfg, k) for i, k in enumerate(cfg.enc_pattern)}, cfg.enc_groups
        )
        p["enc_norm"] = B.rmsnorm_defs(d)
    if cfg.prefix:
        p["prefix"] = [block_defs(cfg, k) for k in cfg.prefix]
    if cfg.n_groups > 0:
        p["groups"] = _stack_defs(
            {f"b{i}": block_defs(cfg, k) for i, k in enumerate(cfg.pattern)}, cfg.n_groups
        )
    if cfg.suffix:
        p["suffix"] = [block_defs(cfg, k) for k in cfg.suffix]
    return p


def _stack_defs(tree: Params, n: int) -> Params:
    def stack(dfn: ParamDef) -> ParamDef:
        return pdef((n,) + dfn.shape, ("layers",) + dfn.axes, dfn.init, dfn.scale, dfn.dtype)

    return jax.tree_util.tree_map(stack, tree, is_leaf=lambda x: isinstance(x, ParamDef))


def model_cache_defs(cfg: ModelConfig, batch: int, max_seq: int) -> Params:
    c: Params = {}
    if cfg.prefix:
        c["prefix"] = [cache_defs(cfg, k, batch, max_seq) for k in cfg.prefix]
    if cfg.n_groups > 0:
        c["groups"] = _stack_defs(
            {f"b{i}": cache_defs(cfg, k, batch, max_seq) for i, k in enumerate(cfg.pattern)},
            cfg.n_groups,
        )
    if cfg.suffix:
        c["suffix"] = [cache_defs(cfg, k, batch, max_seq) for k in cfg.suffix]
    return c


# ---------------------------------------------------------------------------
# block application
# ---------------------------------------------------------------------------


def apply_block(
    p: Params,
    x: jax.Array,
    kind: str,
    cfg: ModelConfig,
    *,
    positions: jax.Array,
    cache: Optional[Params] = None,
    cache_len: Optional[jax.Array] = None,
    enc_out: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Optional[Params]]:
    eps = cfg.norm_eps
    new_cache: Optional[Params] = None
    h = B.apply_rmsnorm(p["norm1"], x, eps)

    if kind in ("attn", "local", "global", "moe", "enc", "dec"):
        sub = {"k": cache["k"], "v": cache["v"]} if cache is not None else None
        y, nc = B.apply_attn(
            p["attn"], h, cfg.attn_cfg(kind), positions=positions, cache=sub, cache_len=cache_len
        )
        if "post_norm1" in p:
            y = B.apply_rmsnorm(p["post_norm1"], y, eps)
        x = x + y
        if kind == "dec":
            hc = B.apply_rmsnorm(p["norm_c"], x, eps)
            if cache is not None:
                xsub = {"k": cache["xk"], "v": cache["xv"]}
                yc, _ = B.apply_attn(
                    p["cross"], hc, cfg.cross_cfg(), positions=positions, cache=xsub
                )
            else:
                yc, _ = B.apply_attn(
                    p["cross"], hc, cfg.cross_cfg(), positions=positions, kv_source=enc_out
                )
            x = x + yc
        if cache is not None:
            new_cache = dict(nc)
            if kind == "dec":
                new_cache["xk"], new_cache["xv"] = cache["xk"], cache["xv"]
    elif kind in ("mla", "mla_moe"):
        y, nc = B.apply_mla(p["attn"], h, cfg.mla, positions=positions, cache=cache, cache_len=cache_len)
        x = x + y
        new_cache = nc
    elif kind == "rec":
        y, nc = B.apply_rglru(p["rec"], h, cfg.rglru_cfg(), cache=cache)
        x = x + y
        new_cache = nc
    elif kind == "mlstm":
        y, nc = B.apply_mlstm(p["mix"], h, cfg.xlstm, cache=cache)
        return x + y, nc
    elif kind == "slstm":
        y, nc = B.apply_slstm(p["mix"], h, cfg.xlstm, cache=cache)
        return x + y, nc
    else:
        raise ValueError(kind)

    # FFN / MoE half
    h2 = B.apply_rmsnorm(p["norm2"], x, eps)
    if kind in ("moe", "mla_moe"):
        y2 = B.apply_moe(p["moe"], h2, cfg.moe, cfg.ffn_kind)
    else:
        y2 = B.apply_ffn(p["ffn"], h2, cfg.ffn_kind)
    if "post_norm2" in p:
        y2 = B.apply_rmsnorm(p["post_norm2"], y2, eps)
    return x + y2, new_cache


def _tree_slice(tree, i: int):
    return jax.tree_util.tree_map(lambda x: x[i], tree)


def _remat(fn, policy: str):
    if policy == "none":
        return fn
    if policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        )
    return jax.checkpoint(fn)


# ---------------------------------------------------------------------------
# full forward
# ---------------------------------------------------------------------------


def _sinusoidal(positions: jax.Array, d: int) -> jax.Array:
    pos = positions.astype(jnp.float32)[:, None]
    half = d // 2
    freq = jnp.exp(-math.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / max(half - 1, 1))
    ang = pos * freq
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def embed_tokens(params: Params, cfg: ModelConfig, tokens: jax.Array) -> jax.Array:
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.emb_scale:
        x = x * math.sqrt(cfg.d_model)
    return x


def unembed(params: Params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    from repro.models.sharding_ctx import get_mesh, constrain

    x = B.apply_rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = x @ params["embed"].T
    else:
        logits = x @ params["head"]
    logits = B.softcap(logits.astype(jnp.float32), cfg.final_softcap)
    mesh = get_mesh()
    if mesh is not None and "model" in mesh.shape and cfg.vocab % mesh.shape["model"] == 0:
        # vocab-parallel logits: the fp32 (B, S, V) tensor stays sharded over
        # the model axis; the CE logsumexp reduces it with one small all-reduce
        daxes = tuple(a for a in ("pod", "data") if a in mesh.shape)
        bax = daxes if len(daxes) > 1 else (daxes[0] if daxes else None)
        if bax is not None and logits.shape[0] % _mesh_size(mesh, bax) == 0:
            logits = constrain(logits, bax, *([None] * (logits.ndim - 2)), "model")
        else:
            logits = constrain(logits, *([None] * (logits.ndim - 1)), "model")
    return logits


def _mesh_size(mesh, axes) -> int:
    flat = axes if isinstance(axes, tuple) else (axes,)
    out = 1
    for a in flat:
        out *= mesh.shape[a]
    return out


def run_encoder(params: Params, cfg: ModelConfig, frames: jax.Array) -> jax.Array:
    """Whisper-style encoder over pre-embedded frames (conv frontend stub)."""
    S = frames.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)
    x = constrain_batch(frames)
    if cfg.enc_positions == "sinusoidal":
        x = x + _sinusoidal(positions, cfg.d_model)[None].astype(x.dtype)

    def group_fn(x, gp):
        for i, kind in enumerate(cfg.enc_pattern):
            x, _ = apply_block(gp[f"b{i}"], x, kind, cfg, positions=positions)
        return constrain_batch(x), None

    if cfg.scan_layers:
        x, _ = jax.lax.scan(_remat(group_fn, cfg.remat), x, params["enc_groups"])
    else:
        for gi in range(cfg.enc_groups):
            x, _ = group_fn(x, _tree_slice(params["enc_groups"], gi))
    return B.apply_rmsnorm(params["enc_norm"], x, cfg.norm_eps)


def forward(
    params: Params,
    cfg: ModelConfig,
    tokens: jax.Array,  # (B, S)
    *,
    vis_embeds: Optional[jax.Array] = None,  # (B, V, d) vlm prefix
    frames: Optional[jax.Array] = None,  # (B, T_enc, d) whisper encoder input
    cache: Optional[Params] = None,
    cache_len: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Optional[Params]]:
    """Returns (logits, new_cache). Training/prefill: cache=None."""
    x = embed_tokens(params, cfg, tokens)
    if vis_embeds is not None:
        x = jnp.concatenate([vis_embeds.astype(x.dtype), x], axis=1)
    x = constrain_batch(x)
    S = x.shape[1]
    if cache_len is None:
        positions = jnp.arange(S, dtype=jnp.int32)
    else:
        positions = cache_len + jnp.arange(S, dtype=jnp.int32)
    if cfg.enc_positions == "sinusoidal":
        x = x + _sinusoidal(positions, cfg.d_model)[None].astype(x.dtype)

    enc_out = None
    if cfg.enc_pattern and frames is not None:
        enc_out = run_encoder(params, cfg, frames)

    new_cache: Params = {}

    def run_plain(x):
        # no cache: prefix -> scanned groups -> suffix
        for i, kind in enumerate(cfg.prefix):
            x, _ = apply_block(params["prefix"][i], x, kind, cfg, positions=positions, enc_out=enc_out)

        def group_fn(x, gp):
            for i, kind in enumerate(cfg.pattern):
                x, _ = apply_block(gp[f"b{i}"], x, kind, cfg, positions=positions, enc_out=enc_out)
            return constrain_batch(x), None

        if cfg.n_groups > 0:
            if cfg.scan_layers:
                x, _ = jax.lax.scan(_remat(group_fn, cfg.remat), x, params["groups"])
            else:
                for gi in range(cfg.n_groups):
                    x, _ = group_fn(x, _tree_slice(params["groups"], gi))
        for i, kind in enumerate(cfg.suffix):
            x, _ = apply_block(params["suffix"][i], x, kind, cfg, positions=positions, enc_out=enc_out)
        return x

    if cache is None:
        x = run_plain(x)
        return unembed(params, cfg, x), None

    # cached decode / prefill-into-cache
    for i, kind in enumerate(cfg.prefix):
        x, nc = apply_block(
            params["prefix"][i], x, kind, cfg,
            positions=positions, cache=cache["prefix"][i], cache_len=cache_len, enc_out=enc_out,
        )
        new_cache.setdefault("prefix", []).append(nc)

    if cfg.n_groups > 0:

        def group_fn(x, scanned):
            gp, gc = scanned
            ncs = {}
            for i, kind in enumerate(cfg.pattern):
                x, nc = apply_block(
                    gp[f"b{i}"], x, kind, cfg,
                    positions=positions, cache=gc[f"b{i}"], cache_len=cache_len, enc_out=enc_out,
                )
                ncs[f"b{i}"] = nc
            return constrain_batch(x), ncs

        if cfg.scan_layers:
            x, group_caches = jax.lax.scan(group_fn, x, (params["groups"], cache["groups"]))
        else:
            caches = []
            for gi in range(cfg.n_groups):
                x, nc = group_fn(x, (_tree_slice(params["groups"], gi), _tree_slice(cache["groups"], gi)))
                caches.append(nc)
            group_caches = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *caches)
        new_cache["groups"] = group_caches

    for i, kind in enumerate(cfg.suffix):
        x, nc = apply_block(
            params["suffix"][i], x, kind, cfg,
            positions=positions, cache=cache["suffix"][i], cache_len=cache_len, enc_out=enc_out,
        )
        new_cache.setdefault("suffix", []).append(nc)

    return unembed(params, cfg, x), new_cache
