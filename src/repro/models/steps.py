"""train_step / serve_step factories for the LM stack.

``make_train_step`` builds the jit-able SPMD step (forward, CE loss, grads,
Adam update) used by the dry-run and the example drivers. ``make_serve_step``
builds the KV-cached single-token decode step. Both are pure functions over
pytrees so they lower with abstract inputs.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.transformer import ModelConfig, forward
from repro.training import optim

Params = Dict[str, Any]


def cross_entropy(logits: jax.Array, targets: jax.Array) -> jax.Array:
    """logits (B, S, V) fp32; targets (B, S) int32 -> scalar mean NLL.

    The gold logit is extracted with a one-hot reduction instead of
    take_along_axis: a vocab gather over model-sharded logits would force
    GSPMD to all-gather the full fp32 (B, S, V) tensor (observed +35 GB/chip
    on qwen3 train_4k); the masked reduction keeps it sharded end-to-end.
    """
    logz = jax.nn.logsumexp(logits, axis=-1)
    v = logits.shape[-1]
    onehot = jax.nn.one_hot(targets, v, dtype=logits.dtype)
    gold = jnp.sum(logits * onehot, axis=-1)
    return jnp.mean(logz - gold)


def lm_loss(params: Params, cfg: ModelConfig, batch: Dict[str, jax.Array]) -> jax.Array:
    tokens = batch["tokens"]
    vis = batch.get("vis_embeds")
    frames = batch.get("frames")
    logits, _ = forward(params, cfg, tokens, vis_embeds=vis, frames=frames)
    if vis is not None:
        v = vis.shape[1]
        logits = logits[:, v:, :]
    # next-token prediction within the token region
    return cross_entropy(logits[:, :-1, :], tokens[:, 1:])


@dataclass(frozen=True)
class TrainStepConfig:
    lr: float = 3e-4
    weight_decay: float = 0.01
    max_grad_norm: float = 1.0
    moment_dtype: Any = jnp.float32


def make_optimizer(tcfg: TrainStepConfig):
    return optim.adam(
        lr=tcfg.lr,
        weight_decay=tcfg.weight_decay,
        max_grad_norm=tcfg.max_grad_norm,
        moment_dtype=tcfg.moment_dtype,
    )


def make_train_step(cfg: ModelConfig, tcfg: TrainStepConfig = TrainStepConfig()):
    opt = make_optimizer(tcfg)

    def train_step(state: Dict[str, Any], batch: Dict[str, jax.Array]):
        params = state["params"]
        loss, grads = jax.value_and_grad(lambda p: lm_loss(p, cfg, batch))(params)
        updates, opt_state = opt.update(grads, state["opt"], params)
        params = optim.apply_updates(params, updates)
        new_state = {"params": params, "opt": opt_state, "step": state["step"] + 1}
        metrics = {"loss": loss, "grad_norm": optim.global_norm(grads)}
        return new_state, metrics

    return train_step, opt


def make_serve_step(cfg: ModelConfig):
    """One decode step: (params, cache, tokens (B,1), cache_len) ->
    (logits (B,1,V), new_cache, next_token (B,1))."""

    def serve_step(params: Params, cache: Params, tokens: jax.Array, cache_len: jax.Array):
        logits, new_cache = forward(params, cfg, tokens, cache=cache, cache_len=cache_len)
        next_tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[:, None]
        return logits, new_cache, next_tok

    return serve_step


def make_prefill_step(cfg: ModelConfig):
    """Prefill: run the full prompt once (no cache write needed for the
    prefill dry-run cells; decode cells own the cache)."""

    def prefill_step(params: Params, batch: Dict[str, jax.Array]):
        logits, _ = forward(
            params,
            cfg,
            batch["tokens"],
            vis_embeds=batch.get("vis_embeds"),
            frames=batch.get("frames"),
        )
        return logits[:, -1:, :]

    return prefill_step
