"""DeepSeek-V2 (236B MoE): MLA attention with compressed KV (kv_lora 512),
2 shared + 160 routed experts top-6, dense first layer [arXiv:2405.04434]."""

from repro.models.blocks import MLAConfig, MoEConfig
from repro.models.transformer import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-236b",
        d_model=5120,
        n_heads=128,
        n_kv_heads=128,
        head_dim=128,
        d_ff=12288,  # dense first layer FFN
        vocab=102400,
        prefix=("mla",),
        pattern=("mla_moe",),
        n_groups=59,  # + 1 dense prefix = 60 layers
        mla=MLAConfig(
            d_model=5120,
            n_heads=128,
            q_lora=1536,
            kv_lora=512,
            d_nope=128,
            d_rope=64,
            d_v=128,
        ),
        moe=MoEConfig(
            n_experts=160,
            top_k=6,
            expert_ff=1536,
            n_shared=2,
            shared_ff=3072,
        ),
        ffn_kind="swiglu",
    )
