"""Architecture configs: one module per assigned architecture + registry."""

from repro.configs.base import (
    ARCHS,
    SHAPES,
    ShapeSpec,
    get_config,
    get_shape,
    input_specs,
    reduced,
    cell_supported,
)

__all__ = [
    "ARCHS",
    "SHAPES",
    "ShapeSpec",
    "get_config",
    "get_shape",
    "input_specs",
    "reduced",
    "cell_supported",
]
