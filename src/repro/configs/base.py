"""Config registry: assigned architectures x input-shape grid.

Shapes (identical for every LM arch, per the assignment):
  train_4k     seq 4,096   global_batch 256   lowers train_step
  prefill_32k  seq 32,768  global_batch 32    lowers prefill_step
  decode_32k   seq 32,768  global_batch 128   lowers serve_step (1 new token)
  long_500k    seq 524,288 global_batch 1     lowers serve_step; SSM/hybrid only

``cell_supported`` encodes the assignment's skip rules (full-attention archs
skip long_500k; see DESIGN.md SS5).
"""

from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import blocks as B
from repro.models.transformer import ModelConfig, model_cache_defs
from repro.models.params import abstract

ARCHS = (
    "internlm2-1.8b",
    "qwen3-8b",
    "deepseek-67b",
    "gemma2-2b",
    "recurrentgemma-2b",
    "arctic-480b",
    "deepseek-v2-236b",
    "internvl2-1b",
    "xlstm-125m",
    "whisper-base",
)

_MODULES = {
    "internlm2-1.8b": "internlm2_1_8b",
    "qwen3-8b": "qwen3_8b",
    "deepseek-67b": "deepseek_67b",
    "gemma2-2b": "gemma2_2b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "arctic-480b": "arctic_480b",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "internvl2-1b": "internvl2_1b",
    "xlstm-125m": "xlstm_125m",
    "whisper-base": "whisper_base",
}

# archs whose decode state is sub-quadratic in context (run long_500k)
SUBQUADRATIC = ("recurrentgemma-2b", "xlstm-125m")


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = (
    ShapeSpec("train_4k", 4_096, 256, "train"),
    ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    ShapeSpec("decode_32k", 32_768, 128, "decode"),
    ShapeSpec("long_500k", 524_288, 1, "decode"),
)


def get_shape(name: str) -> ShapeSpec:
    for s in SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)


def get_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.config()


def cell_supported(arch: str, shape: ShapeSpec) -> Tuple[bool, str]:
    """Whether this (arch, shape) cell runs, and why not if skipped."""
    if shape.name == "long_500k" and arch not in SUBQUADRATIC:
        return False, "full-attention arch: 500k decode is not sub-quadratic (DESIGN.md SS5)"
    return True, ""


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Smoke-test variant: same family/topology, tiny sizes."""
    kw: Dict[str, Any] = dict(
        d_model=64,
        n_heads=4,
        n_kv_heads=max(1, min(cfg.n_kv_heads, 2)),
        head_dim=16,
        d_ff=0 if cfg.d_ff == 0 else 128,
        vocab=512,
        n_groups=min(cfg.n_groups, 2),
        enc_groups=min(cfg.enc_groups, 2),
        window=8 if cfg.window else None,
        vis_len=8 if cfg.vis_len else 0,
        rnn_width=64 if cfg.rnn_width else None,
        remat="none",
    )
    if cfg.mla is not None:
        kw["mla"] = B.MLAConfig(
            d_model=64, n_heads=4, q_lora=32, kv_lora=16, d_nope=16, d_rope=8, d_v=16
        )
    if cfg.moe is not None:
        kw["moe"] = dataclasses.replace(
            cfg.moe,
            n_experts=4,
            top_k=min(cfg.moe.top_k, 2),
            expert_ff=32,
            shared_ff=32 if cfg.moe.n_shared else 0,
            dense_ff=32 if cfg.moe.dense_residual else 0,
        )
    if cfg.xlstm is not None:
        kw["xlstm"] = B.XLSTMConfig(d_model=64, n_heads=4, expansion=2)
    return dataclasses.replace(cfg, **kw)


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins; no allocation)
# ---------------------------------------------------------------------------


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """Abstract inputs for the step lowered by this cell.

    train/prefill: {"tokens": (B, S)} (+ modality stubs).
    decode: {"tokens": (B, 1), "cache": <arch cache at S>, "cache_len": ()}.
    """
    Bsz, S = shape.global_batch, shape.seq_len
    tok = lambda b, s: jax.ShapeDtypeStruct((b, s), jnp.int32)
    out: Dict[str, Any] = {}
    if shape.kind in ("train", "prefill"):
        if cfg.frontend == "vision":
            v = min(cfg.vis_len, S // 2)
            out["tokens"] = tok(Bsz, S - v)
            out["vis_embeds"] = jax.ShapeDtypeStruct((Bsz, v, cfg.d_model), jnp.bfloat16)
        elif cfg.frontend == "audio":
            out["tokens"] = tok(Bsz, S)
            out["frames"] = jax.ShapeDtypeStruct((Bsz, S, cfg.d_model), jnp.bfloat16)
        else:
            out["tokens"] = tok(Bsz, S)
        return out
    # decode: one new token against a cache of S
    out["tokens"] = tok(Bsz, 1)
    out["cache"] = abstract(model_cache_defs(cfg, Bsz, S))
    out["cache_len"] = jax.ShapeDtypeStruct((), jnp.int32)
    return out
