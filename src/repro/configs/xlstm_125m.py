"""xLSTM-125M: alternating mLSTM (matrix memory) and sLSTM (scalar memory)
blocks, no separate FFN (d_ff = 0) [arXiv:2405.04517]."""

from repro.models.blocks import XLSTMConfig
from repro.models.transformer import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-125m",
        d_model=768,
        n_heads=4,
        n_kv_heads=4,
        head_dim=192,
        d_ff=0,
        vocab=50304,
        pattern=("mlstm", "slstm"),
        n_groups=6,  # 12 layers
        xlstm=XLSTMConfig(d_model=768, n_heads=4, expansion=2),
        tie_embeddings=True,
    )
