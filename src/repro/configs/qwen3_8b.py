"""Qwen3-8B: dense GQA decoder with per-head qk-norm [hf:Qwen/Qwen3-8B]."""

from repro.models.transformer import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-8b",
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=12288,
        vocab=151936,
        pattern=("attn",),
        n_groups=36,
        qk_norm=True,
        rope_theta=1_000_000.0,
        ffn_kind="swiglu",
    )
