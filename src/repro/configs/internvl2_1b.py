"""InternVL2-1B backbone: InternLM2-style decoder with a visual-prefix stub
(InternViT frontend provides precomputed patch embeddings) [arXiv:2404.16821]."""

from repro.models.transformer import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-1b",
        d_model=896,
        n_heads=14,
        n_kv_heads=2,
        head_dim=64,
        d_ff=4864,
        vocab=151655,
        pattern=("attn",),
        n_groups=24,
        rope_theta=1_000_000.0,
        ffn_kind="swiglu",
        frontend="vision",
        vis_len=1024,
    )
