"""InternLM2-1.8B: dense llama-style GQA decoder [arXiv:2403.17297; hf]."""

from repro.models.transformer import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="internlm2-1.8b",
        d_model=2048,
        n_heads=16,
        n_kv_heads=8,
        head_dim=128,
        d_ff=8192,
        vocab=92544,
        pattern=("attn",),
        n_groups=24,
        rope_theta=1_000_000.0,
        ffn_kind="swiglu",
    )
