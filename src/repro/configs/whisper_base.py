"""Whisper-base backbone: 6L encoder + 6L decoder with cross-attention,
GELU FFN, sinusoidal positions; conv audio frontend is a stub that feeds
precomputed frame embeddings [arXiv:2212.04356]."""

from repro.models.transformer import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-base",
        d_model=512,
        n_heads=8,
        n_kv_heads=8,
        head_dim=64,
        d_ff=2048,
        vocab=51865,
        enc_pattern=("enc",),
        enc_groups=6,
        pattern=("dec",),
        n_groups=6,
        enc_positions="sinusoidal",
        ffn_kind="gelu",
        frontend="audio",
        tie_embeddings=True,
    )
