"""Gemma2-2B: alternating local(4096)/global attention, logit softcaps,
GeGLU, sandwich norms, tied embeddings [arXiv:2408.00118]."""

from repro.models.transformer import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma2-2b",
        d_model=2304,
        n_heads=8,
        n_kv_heads=4,
        head_dim=256,
        d_ff=9216,
        vocab=256000,
        pattern=("local", "global"),
        n_groups=13,  # 26 layers
        window=4096,
        attn_softcap=50.0,
        final_softcap=30.0,
        ffn_kind="geglu",
        tie_embeddings=True,
        emb_scale=True,
    )
