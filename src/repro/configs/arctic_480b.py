"""Snowflake Arctic (480B MoE): dense-MoE hybrid — every layer has a dense
FFN residual in parallel with a 128-expert top-2 MoE
[hf:Snowflake/snowflake-arctic-base]."""

from repro.models.blocks import MoEConfig
from repro.models.transformer import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="arctic-480b",
        d_model=7168,
        n_heads=56,
        n_kv_heads=8,
        head_dim=128,
        d_ff=4864,
        vocab=32000,
        pattern=("moe",),
        n_groups=35,
        moe=MoEConfig(
            n_experts=128,
            top_k=2,
            expert_ff=4864,
            dense_residual=True,
            dense_ff=4864,
        ),
        ffn_kind="swiglu",
    )
