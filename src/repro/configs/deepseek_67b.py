"""DeepSeek-67B: 95-layer dense llama-arch GQA decoder [arXiv:2401.02954]."""

from repro.models.transformer import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-67b",
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        head_dim=128,
        d_ff=22016,
        vocab=102400,
        pattern=("attn",),
        n_groups=95,
        rope_theta=10_000.0,
        ffn_kind="swiglu",
    )
