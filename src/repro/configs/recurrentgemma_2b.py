"""RecurrentGemma-2B (Griffin): RG-LRU recurrent blocks + local attention in
a 2:1 pattern, MQA, tied embeddings [arXiv:2402.19427]."""

from repro.models.transformer import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-2b",
        d_model=2560,
        n_heads=10,
        n_kv_heads=1,
        head_dim=256,
        d_ff=7680,
        vocab=256000,
        pattern=("rec", "rec", "local"),
        n_groups=8,  # 24 layers ...
        suffix=("rec", "rec"),  # ... + 2 = 26
        window=2048,
        rnn_width=2560,
        conv_width=4,
        ffn_kind="geglu",
        tie_embeddings=True,
        emb_scale=True,
        use_rglru_kernel=False,  # flipped on for TPU builds
    )
