"""Operator placement: the mapping omega_i -> n_j (paper SIII-A)."""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Sequence, Tuple

from repro.dsps.hardware import Cluster, hardware_bin
from repro.dsps.query import Query


@dataclass(frozen=True)
class Placement:
    """assignment[op_id] = node_id for every operator of a query."""

    assignment: Tuple[int, ...]

    @staticmethod
    def of(mapping: Sequence[int]) -> "Placement":
        return Placement(assignment=tuple(int(x) for x in mapping))

    def node_of(self, op_id: int) -> int:
        return self.assignment[op_id]

    def colocated(self, op_a: int, op_b: int) -> bool:
        return self.assignment[op_a] == self.assignment[op_b]

    def used_nodes(self) -> List[int]:
        return sorted(set(self.assignment))

    def ops_on(self, node_id: int) -> List[int]:
        return [i for i, n in enumerate(self.assignment) if n == node_id]

    def validate(self, query: Query, cluster: Cluster) -> None:
        assert len(self.assignment) == query.n_ops(), (
            f"placement covers {len(self.assignment)} ops, query has {query.n_ops()}"
        )
        for node in self.assignment:
            assert 0 <= node < cluster.n_nodes(), node


def physical_hops(query: Query, placement: Placement) -> List[Tuple[int, int]]:
    """Data-flow edges that cross host boundaries (physical data flow)."""
    hops = []
    for u, v in query.edges:
        nu, nv = placement.node_of(u), placement.node_of(v)
        if nu != nv:
            hops.append((nu, nv))
    return hops


def respects_increasing_capability(
    query: Query, cluster: Cluster, placement: Placement
) -> bool:
    """Fig. 5 (2): data flows only from same-or-weaker to stronger bins."""
    bins = cluster.bins()
    for u, v in query.edges:
        if bins[placement.node_of(u)] > bins[placement.node_of(v)]:
            return False
    return True


def is_acyclic_placement(query: Query, placement: Placement) -> bool:
    """Fig. 5 (3): once data leaves a host it must never return to it.

    Checked per root-to-sink path over the sequence of visited hosts.
    """
    for path in query.root_to_sink_paths():
        hosts = [placement.node_of(op) for op in path]
        seen: list[int] = []
        for h in hosts:
            if seen and h == seen[-1]:
                continue
            if h in seen:
                return False
            seen.append(h)
    return True
