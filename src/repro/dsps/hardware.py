"""Heterogeneous hardware + network model (paper Table I hardware features).

Each compute node carries the four transferable hardware features the paper
uses: relative CPU capacity (% of a reference core), RAM, outgoing network
bandwidth and outgoing network latency (the paper configures these with
cgroups + tc-netem; here they are first-class attributes).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Sequence, Tuple

from repro.dsps import ranges


@dataclass(frozen=True)
class HardwareNode:
    node_id: int
    cpu: float  # % of a reference core (100 == one reference core)
    ram_mb: float
    bandwidth_mbps: float  # outgoing link bandwidth
    latency_ms: float  # outgoing link latency

    def cores(self) -> float:
        return self.cpu / 100.0


def hardware_bin(node: HardwareNode) -> int:
    """Classify hardware into three capability bins (paper Fig. 5 (2)).

    The paper intersects bins on their feature ranges to emulate realistic
    edge -> workstation -> cloud transitions; we score capability on log-scaled
    cpu+ram+bandwidth and cut the score range into three bins.
    """
    import math

    lo = (
        math.log(ranges.CPU[0]) + math.log(ranges.RAM_MB[0]) + math.log(ranges.BANDWIDTH_MBPS[0])
    )
    hi = (
        math.log(ranges.CPU[-1])
        + math.log(ranges.RAM_MB[-1])
        + math.log(ranges.BANDWIDTH_MBPS[-1])
    )
    score = math.log(max(node.cpu, 1e-9)) + math.log(max(node.ram_mb, 1e-9)) + math.log(
        max(node.bandwidth_mbps, 1e-9)
    )
    t = (score - lo) / max(hi - lo, 1e-9)
    if t < 1.0 / 3.0:
        return 0  # edge-class
    if t < 2.0 / 3.0:
        return 1  # workstation-class
    return 2  # cloud-class


@dataclass
class Cluster:
    """A set of heterogeneous nodes available for one query placement."""

    nodes: List[HardwareNode]

    def __post_init__(self):
        ids = [n.node_id for n in self.nodes]
        assert ids == sorted(ids) == list(range(len(ids))), "node_ids must be 0..n-1"

    def node(self, node_id: int) -> HardwareNode:
        return self.nodes[node_id]

    def n_nodes(self) -> int:
        return len(self.nodes)

    def bins(self) -> List[int]:
        return [hardware_bin(n) for n in self.nodes]

    def link(self, src: int, dst: int) -> Tuple[float, float]:
        """(bandwidth_mbps, latency_ms) of the src->dst link.

        The paper models per-host *outgoing* bandwidth/latency (netem on the
        sender); a transfer is additionally capped by the receiver's ingress.
        """
        if src == dst:
            return (float("inf"), 0.0)
        s, d = self.node(src), self.node(dst)
        return (min(s.bandwidth_mbps, d.bandwidth_mbps), s.latency_ms)

    def mean_features(self) -> Dict[str, float]:
        n = max(len(self.nodes), 1)
        return {
            "cpu": sum(x.cpu for x in self.nodes) / n,
            "ram_mb": sum(x.ram_mb for x in self.nodes) / n,
            "bandwidth_mbps": sum(x.bandwidth_mbps for x in self.nodes) / n,
            "latency_ms": sum(x.latency_ms for x in self.nodes) / n,
        }
