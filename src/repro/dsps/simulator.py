"""Analytic DSPS cost simulator: the label oracle of the benchmark corpus.

Given (query, cluster, placement) this computes the paper's five cost metrics

    C = (T, L_p, L_e, R_O, S)

via a queueing-network model of a JVM streaming engine:

* per-tuple service demands per operator derived from operator type, tuple
  width, attribute data types, and window state (paper Table I features);
* host capacity from the relative ``cpu`` feature; co-located operators share
  the host (paper Fig. 5 (1));
* windowed state sized from window length x tuple width x dtype byte widths;
  RAM exhaustion models GC pressure -> slowdown -> crash (paper Def. 5 (1));
* per-link flows from tuple rate x tuple byte width; saturation of a host's
  outgoing bandwidth causes backpressure just like CPU saturation;
* M/M/1-style waiting times + window residence + per-hop network latency
  accumulate into L_p along the critical source->sink path; L_e adds broker
  queueing which explodes under backpressure (paper Def. 3/4);
* logical failure when no tuple reaches the sink within the measurement
  interval (paper Def. 5 (2));
* log-normal measurement noise on the regression metrics.

All computations are plain Python/numpy (the corpus generator is host-side);
the learned model in ``repro.core`` never sees any of these internals — only
the transferable features and the resulting labels.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.dsps.hardware import Cluster, HardwareNode
from repro.dsps.placement import Placement
from repro.dsps.query import DType, Operator, OpType, Query

# ---------------------------------------------------------------------------
# Cost constants (reference-core milliseconds / bytes). These play the role of
# the physical machine behaviour the paper measures; they are fixed across the
# whole corpus so the learning problem is about *structure*, not constants.
# ---------------------------------------------------------------------------
BYTES_PER_ATTR = {DType.INT: 8.0, DType.DOUBLE: 8.0, DType.STRING: 64.0, DType.NONE: 0.0}
CPU_COST_DTYPE = {DType.INT: 1.0, DType.DOUBLE: 1.15, DType.STRING: 2.6, DType.NONE: 0.0}

MS_SOURCE_BASE = 0.012  # deserialization + emit
MS_SOURCE_PER_ATTR = 0.0015
MS_FILTER_BASE = 0.004
MS_FILTER_CMP = 0.0025  # x dtype factor
MS_AGG_UPDATE = 0.006  # per-tuple state update, x dtype factor
MS_AGG_GROUP_HASH = 0.004  # extra per-tuple if group-by, x dtype factor
MS_AGG_EMIT = 0.008  # per emitted row
MS_JOIN_INSERT = 0.007  # per-tuple window insert, x key dtype factor
MS_JOIN_PROBE = 0.004  # per-tuple hash probe, x key dtype factor
MS_JOIN_EMIT = 0.0045  # per emitted match (pair materialization)
MS_SINK_BASE = 0.010
MS_SINK_PER_ATTR = 0.0012
MS_NET_PER_TUPLE = 0.002  # serialization overhead for remote sends

JVM_BASE_MB = 384.0  # engine worker footprint per host
STATE_OVERHEAD = 1.6  # JVM object header / boxing overhead on window state
GC_SOFT = 0.60  # state/heap ratio where GC pressure starts to bite
GC_HARD = 1.00  # state/heap ratio beyond which the worker crashes
MEASUREMENT_S = 240.0  # paper: 4-minute measured executions

EPS = 1e-9


@dataclass(frozen=True)
class CostLabels:
    """The five cost metrics of the paper (SIV-A)."""

    throughput: float  # T      [tuples/s at the sink]
    latency_p: float  # L_p    [ms]
    latency_e: float  # L_e    [ms]
    backpressure: int  # R_O    1 = no backpressure, 0 = backpressured (paper Def. 4)
    success: int  # S      1 = tuples reached the sink, 0 = failed

    def as_dict(self) -> Dict[str, float]:
        return {
            "throughput": self.throughput,
            "latency_p": self.latency_p,
            "latency_e": self.latency_e,
            "backpressure": float(self.backpressure),
            "success": float(self.success),
        }


@dataclass(frozen=True)
class SimulatorConfig:
    noise_sigma: float = 0.12  # log-normal noise on regression metrics
    broker_base_ms: float = 8.0  # Kafka hand-off under no backpressure
    crash_under_hard_gc: bool = True
    seed_salt: int = 0x5EED


# ---------------------------------------------------------------------------
# Per-operator analytic quantities
# ---------------------------------------------------------------------------


def tuple_bytes(width: float, mix: Tuple[float, float, float]) -> float:
    """Average serialized bytes of a tuple of ``width`` attributes.

    ``mix`` = fraction of (int, double, string) attributes.
    """
    fi, fd, fs = mix
    per = fi * BYTES_PER_ATTR[DType.INT] + fd * BYTES_PER_ATTR[DType.DOUBLE] + fs * BYTES_PER_ATTR[
        DType.STRING
    ]
    return 24.0 + width * per  # 24B envelope (timestamps, ids)


def _dtype_factor(dt: Optional[DType]) -> float:
    return CPU_COST_DTYPE.get(dt if dt is not None else DType.INT, 1.0)


@dataclass
class OpRuntime:
    """Derived steady-state quantities for one operator."""

    rate_in: float = 0.0  # tuples/s arriving (sum over inputs)
    rate_out: float = 0.0  # tuples/s emitted
    service_ms: float = 0.0  # reference-core ms per input tuple (incl. emission)
    state_mb: float = 0.0  # window state resident bytes
    window_wait_ms: float = 0.0  # residence time until a tuple can be emitted
    bytes_out_per_s: float = 0.0


def analyze_operators(query: Query, dtype_mix: Tuple[float, float, float]) -> Dict[int, OpRuntime]:
    """Propagate rates/widths/state through the logical data flow."""
    rt: Dict[int, OpRuntime] = {i: OpRuntime() for i in range(query.n_ops())}
    order = query.topological_order()
    for u in order:
        op = query.op(u)
        r = rt[u]
        parents = query.parents(u)
        in_rates = [rt[p].rate_out for p in parents]
        r.rate_in = float(sum(in_rates))
        if op.op_type == OpType.SOURCE:
            r.rate_in = op.event_rate
            r.rate_out = op.event_rate
            r.service_ms = MS_SOURCE_BASE + MS_SOURCE_PER_ATTR * op.tuple_width_in
        elif op.op_type == OpType.FILTER:
            r.rate_out = r.rate_in * op.selectivity
            r.service_ms = MS_FILTER_BASE + MS_FILTER_CMP * _dtype_factor(op.literal_dtype)
        elif op.op_type == OpType.AGGREGATE:
            w = op.window
            assert w is not None
            win_len = w.length_tuples(r.rate_in)
            period = w.period_seconds(r.rate_in)
            groups = max(1.0, op.selectivity * win_len)
            emits_per_s = groups / max(period, EPS)
            r.rate_out = emits_per_s
            grouped = op.group_by_dtype not in (None, DType.NONE)
            per_tuple = MS_AGG_UPDATE * _dtype_factor(op.agg_dtype)
            if grouped:
                per_tuple += MS_AGG_GROUP_HASH * _dtype_factor(op.group_by_dtype)
            emit_ms = MS_AGG_EMIT * (emits_per_s / max(r.rate_in, EPS))
            r.service_ms = per_tuple + emit_ms
            r.state_mb = (
                win_len
                * tuple_bytes(op.tuple_width_in, dtype_mix)
                * STATE_OVERHEAD
                * (2.0 if w.wtype == "sliding" else 1.0)
            ) / 1e6
            # expected residence of a tuple before its window fires
            r.window_wait_ms = 0.5 * period * 1e3 if w.wtype == "tumbling" else 0.5 * w.slide() * (
                1e3 if w.policy == "time" else 1e3 / max(r.rate_in, EPS)
            )
        elif op.op_type == OpType.JOIN:
            w = op.window
            assert w is not None
            assert len(parents) == 2, "join expects two inputs"
            r1, r2 = in_rates
            w1 = w.length_tuples(max(r1, EPS))
            w2 = w.length_tuples(max(r2, EPS))
            # each arrival probes the opposite window; matches = sel x |W_opp|
            matches_per_s = op.selectivity * (r1 * w2 + r2 * w1)
            r.rate_out = matches_per_s
            kf = _dtype_factor(op.join_key_dtype)
            emit_ms = MS_JOIN_EMIT * (matches_per_s / max(r.rate_in, EPS))
            r.service_ms = (MS_JOIN_INSERT + MS_JOIN_PROBE) * kf + emit_ms
            width_avg = op.tuple_width_in / 2.0
            r.state_mb = (
                (w1 + w2)
                * tuple_bytes(width_avg, dtype_mix)
                * STATE_OVERHEAD
                * (2.0 if w.wtype == "sliding" else 1.0)
            ) / 1e6
            mean_rate = 0.5 * (max(r1, EPS) + max(r2, EPS))
            r.window_wait_ms = 0.5 * w.period_seconds(mean_rate) * 1e3
        elif op.op_type == OpType.SINK:
            r.rate_out = r.rate_in
            r.service_ms = MS_SINK_BASE + MS_SINK_PER_ATTR * op.tuple_width_in
        rt[u] = r
    return rt


# ---------------------------------------------------------------------------
# The simulator proper
# ---------------------------------------------------------------------------


def _dtype_mix(query: Query) -> Tuple[float, float, float]:
    ni = nd = ns = 0
    for op in query.operators:
        if op.op_type == OpType.SOURCE:
            ni += op.n_int
            nd += op.n_double
            ns += op.n_string
    tot = max(ni + nd + ns, 1)
    return (ni / tot, nd / tot, ns / tot)


def simulate(
    query: Query,
    cluster: Cluster,
    placement: Placement,
    config: SimulatorConfig = SimulatorConfig(),
    rng: Optional[np.random.Generator] = None,
) -> CostLabels:
    """Compute C = (T, L_p, L_e, R_O, S) for a placed query."""
    placement.validate(query, cluster)
    mix = _dtype_mix(query)
    rt = analyze_operators(query, mix)

    # --- host CPU utilization (co-location shares the host) -----------------
    host_load: Dict[int, float] = {}  # ref-core-seconds of work per second
    host_state: Dict[int, float] = {}
    for op in query.operators:
        n = placement.node_of(op.op_id)
        work = rt[op.op_id].rate_in * rt[op.op_id].service_ms / 1e3
        host_load[n] = host_load.get(n, 0.0) + work
        host_state[n] = host_state.get(n, 0.0) + rt[op.op_id].state_mb

    # GC pressure per host: state vs. heap (RAM minus worker footprint).
    gc_slow: Dict[int, float] = {}
    crashed = False
    for n, state_mb in host_state.items():
        heap = max(cluster.node(n).ram_mb - JVM_BASE_MB, 64.0)
        ratio = state_mb / heap
        if ratio >= GC_HARD and config.crash_under_hard_gc:
            crashed = True
        # GC slowdown factor >= 1, ramping up once past the soft threshold
        gc_slow[n] = 1.0 + max(0.0, (ratio - GC_SOFT) / max(1.0 - GC_SOFT, EPS)) ** 2 * 6.0

    host_util: Dict[int, float] = {}
    for n, load in host_load.items():
        cap = cluster.node(n).cores()
        host_util[n] = load * gc_slow.get(n, 1.0) / max(cap, EPS)

    # --- network flows (remote data-flow edges) ------------------------------
    # bytes/s leaving each host + per logical edge utilization of its link
    out_bytes: Dict[int, float] = {}
    edge_link_util: Dict[Tuple[int, int], float] = {}
    for u, v in query.edges:
        nu, nv = placement.node_of(u), placement.node_of(v)
        if nu == nv:
            continue
        width = query.op(u).tuple_width_out
        flow = rt[u].rate_out * tuple_bytes(width, mix)  # bytes/s
        out_bytes[nu] = out_bytes.get(nu, 0.0) + flow
        # remote sends also cost CPU on the sender
        host_load[nu] = host_load.get(nu, 0.0) + rt[u].rate_out * MS_NET_PER_TUPLE / 1e3
    for n, flow in out_bytes.items():
        cap_bytes = cluster.node(n).bandwidth_mbps * 1e6 / 8.0
        util = flow / max(cap_bytes, EPS)
        host_util[n] = max(host_util.get(n, 0.0), util)  # whichever saturates first
        edge_link_util[(n, -1)] = util

    # refresh utilization after adding network CPU cost
    for n, load in host_load.items():
        cap = cluster.node(n).cores()
        host_util[n] = max(
            host_util.get(n, 0.0), load * gc_slow.get(n, 1.0) / max(cap, EPS)
        )

    # --- backpressure & sustainable throughput -------------------------------
    rho_max = max(host_util.values()) if host_util else 0.0
    backpressured = rho_max >= 1.0
    throttle = min(1.0, 1.0 / max(rho_max, EPS)) if rho_max > 0 else 1.0

    sink_rate = rt[query.sink()].rate_in  # tuples/s arriving at the sink
    throughput = sink_rate * throttle

    # --- success -------------------------------------------------------------
    expected_out = throughput * MEASUREMENT_S
    success = 1
    if crashed:
        success = 0
    if expected_out < 1.0:
        success = 0
    if rho_max > 4.0:  # catastrophic overload: workers die before stabilizing
        success = 0

    # --- latencies along the critical path -----------------------------------
    # queueing wait at each op: M/M/1 with effective utilization of its host
    def op_wait_ms(op_id: int) -> float:
        n = placement.node_of(op_id)
        rho = min(host_util.get(n, 0.0), 0.995)
        svc = rt[op_id].service_ms * gc_slow.get(n, 1.0)
        return svc / max(1.0 - rho, 0.005) + rt[op_id].window_wait_ms

    def hop_ms(u: int, v: int) -> float:
        nu, nv = placement.node_of(u), placement.node_of(v)
        if nu == nv:
            return 0.05  # intra-host queue hand-off
        bw_mbps, lat_ms = cluster.link(nu, nv)
        width = query.op(u).tuple_width_out
        per_tuple_ms = tuple_bytes(width, mix) * 8.0 / max(bw_mbps * 1e6, EPS) * 1e3
        # link queueing inflation when close to saturation
        util = min(out_bytes.get(nu, 0.0) / max(bw_mbps * 1e6 / 8.0, EPS), 0.995)
        return lat_ms + per_tuple_ms / max(1.0 - util, 0.005)

    sink = query.sink()
    memo: Dict[int, float] = {}

    def path_ms(u: int) -> float:
        if u in memo:
            return memo[u]
        best = 0.0
        for v in query.children(u):
            best = max(best, hop_ms(u, v) + path_ms(v))
        memo[u] = op_wait_ms(u) + best
        return memo[u]

    latency_p = max(path_ms(s) for s in query.sources())

    # --- end-to-end latency: broker wait --------------------------------------
    if backpressured:
        # queues build for the whole measured interval; average waiting time of
        # an admitted tuple grows with the unprocessed fraction
        backlog_frac = max(0.0, 1.0 - throttle)
        broker_ms = config.broker_base_ms + 0.5 * MEASUREMENT_S * 1e3 * backlog_frac
    else:
        # near-saturation brokers already add queueing
        broker_ms = config.broker_base_ms / max(1.0 - min(rho_max, 0.99), 0.05)
    latency_e = latency_p + broker_ms

    # --- measurement noise -----------------------------------------------------
    if rng is None:
        rng = np.random.default_rng(
            abs(hash((query.name, placement.assignment, config.seed_salt))) % (2**32)
        )
    noise = lambda: float(np.exp(rng.normal(0.0, config.noise_sigma)))
    throughput = throughput * noise()
    latency_p = latency_p * noise()
    # broker wait gets its own noise; L_e >= L_p holds by construction
    latency_e = latency_p + broker_ms * noise()

    if success == 0:
        throughput = 0.0

    return CostLabels(
        throughput=float(max(throughput, 0.0)),
        latency_p=float(max(latency_p, 0.05)),
        latency_e=float(max(latency_e, 0.05)),
        backpressure=0 if backpressured else 1,
        success=int(success),
    )
