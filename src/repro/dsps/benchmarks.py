"""Unseen real-world benchmark queries (paper SVII-F / Table VI (B)).

Re-creations of the DSPBench-derived workloads the paper evaluates on:
advertisement (click/impression join), spike detection (sensor filter over a
windowed mean), and the DEBS'14 smart-grid global/local energy queries. Data
distributions differ from the synthetic corpus: widths, dtype mixes, and
selectivities are fixed by the scenario, and the smart-grid queries use a
window length unseen in training (the paper notes COSTREAM extrapolates to it).
"""

from __future__ import annotations

from typing import List, Optional

import numpy as np

from repro.dsps.query import (
    AggFn,
    DType,
    FilterFn,
    Operator,
    OpType,
    Query,
    WindowSpec,
)


def advertisement(rate_clicks: float, rate_impressions: float) -> Query:
    """Clicks JOIN impressions within a window, then filtered (sub-query of [36])."""
    ops = [
        Operator(op_id=0, op_type=OpType.SOURCE, event_rate=rate_clicks, n_int=2, n_string=2),
        Operator(op_id=1, op_type=OpType.SOURCE, event_rate=rate_impressions, n_int=3, n_string=3),
        Operator(
            op_id=2,
            op_type=OpType.FILTER,
            filter_fn=FilterFn.NE,
            literal_dtype=DType.STRING,
            selectivity=0.82,
        ),
        Operator(
            op_id=3,
            op_type=OpType.JOIN,
            join_key_dtype=DType.STRING,
            window=WindowSpec(wtype="sliding", policy="time", size=4.0, slide_ratio=0.5),
            selectivity=0.004,
        ),
        Operator(op_id=4, op_type=OpType.SINK),
    ]
    edges = [(0, 3), (1, 2), (2, 3), (3, 4)]
    return Query(operators=ops, edges=edges, name="advertisement").infer_widths()


def spike_detection(rate: float) -> Query:
    """Moving average over sensor values, spikes filtered out (IoT use case)."""
    ops = [
        Operator(op_id=0, op_type=OpType.SOURCE, event_rate=rate, n_int=1, n_double=3),
        Operator(
            op_id=1,
            op_type=OpType.AGGREGATE,
            agg_fn=AggFn.MEAN,
            group_by_dtype=DType.INT,  # per-sensor moving average
            agg_dtype=DType.DOUBLE,
            window=WindowSpec(wtype="sliding", policy="count", size=90.0, slide_ratio=0.34),
            selectivity=0.06,
        ),
        Operator(
            op_id=2,
            op_type=OpType.FILTER,
            filter_fn=FilterFn.GT,
            literal_dtype=DType.DOUBLE,
            selectivity=0.03,  # spikes are rare
        ),
        Operator(op_id=3, op_type=OpType.SINK),
    ]
    edges = [(0, 1), (1, 2), (2, 3)]
    return Query(operators=ops, edges=edges, name="spike_detection").infer_widths()


def smart_grid_global(rate: float) -> Query:
    """DEBS'14: sliding-window global energy consumption (unseen window size)."""
    ops = [
        Operator(op_id=0, op_type=OpType.SOURCE, event_rate=rate, n_int=4, n_double=2),
        Operator(
            op_id=1,
            op_type=OpType.AGGREGATE,
            agg_fn=AggFn.SUM,
            group_by_dtype=DType.NONE,
            agg_dtype=DType.DOUBLE,
            # 30s sliding window: outside the Table-II time range [0.25..16]
            window=WindowSpec(wtype="sliding", policy="time", size=30.0, slide_ratio=0.4),
            selectivity=1.0,
        ),
        Operator(op_id=2, op_type=OpType.SINK),
    ]
    edges = [(0, 1), (1, 2)]
    return Query(operators=ops, edges=edges, name="smart_grid_global").infer_widths()


def smart_grid_local(rate: float) -> Query:
    """DEBS'14: per-household energy consumption (group-by over unseen window)."""
    ops = [
        Operator(op_id=0, op_type=OpType.SOURCE, event_rate=rate, n_int=4, n_double=2),
        Operator(
            op_id=1,
            op_type=OpType.AGGREGATE,
            agg_fn=AggFn.SUM,
            group_by_dtype=DType.INT,  # household id
            agg_dtype=DType.DOUBLE,
            window=WindowSpec(wtype="sliding", policy="time", size=30.0, slide_ratio=0.4),
            selectivity=0.12,
        ),
        Operator(
            op_id=2,
            op_type=OpType.AGGREGATE,
            agg_fn=AggFn.MEAN,
            group_by_dtype=DType.INT,
            agg_dtype=DType.DOUBLE,
            window=WindowSpec(wtype="tumbling", policy="time", size=8.0, slide_ratio=1.0),
            selectivity=0.2,
        ),
        Operator(op_id=3, op_type=OpType.SINK),
    ]
    edges = [(0, 1), (1, 2), (2, 3)]
    return Query(operators=ops, edges=edges, name="smart_grid_local").infer_widths()


BENCHMARKS = {
    "advertisement": lambda rng: advertisement(
        rate_clicks=float(rng.choice([100, 200, 400, 800, 1600])),
        rate_impressions=float(rng.choice([200, 400, 800, 1600, 3200])),
    ),
    "spike_detection": lambda rng: spike_detection(
        rate=float(rng.choice([400, 800, 1600, 3200, 6400, 12800]))
    ),
    "smart_grid_global": lambda rng: smart_grid_global(
        rate=float(rng.choice([400, 800, 1600, 3200, 6400]))
    ),
    "smart_grid_local": lambda rng: smart_grid_local(
        rate=float(rng.choice([400, 800, 1600, 3200, 6400]))
    ),
}


def sample_benchmark_query(name: str, rng: np.random.Generator) -> Query:
    return BENCHMARKS[name](rng)
