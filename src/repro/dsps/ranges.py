"""Feature ranges of the synthetic training corpus (paper Table II).

These ranges drive both the workload generator (sampling) and the feature
normalization of the cost model (log-scale min/max). Evaluation-time
interpolation/extrapolation experiments (Exp 3/4) construct shifted copies.
"""

from __future__ import annotations

# --- hardware-related (Table II) -------------------------------------------
CPU = [50, 100, 200, 300, 400, 500, 600, 700, 800]  # % of a reference core
RAM_MB = [1000, 2000, 4000, 8000, 16000, 24000, 32000]
BANDWIDTH_MBPS = [25, 50, 100, 200, 400, 800, 1600, 3200, 6400, 10000]
LATENCY_MS = [1, 2, 5, 10, 20, 40, 80, 160]

# --- data-related ------------------------------------------------------------
EVENT_RATE_LINEAR = [100, 200, 400, 800, 1600, 3200, 6400, 12800, 25600]
EVENT_RATE_TWO_WAY = [50, 100, 250, 500, 750, 1000, 1250, 1500, 1750, 2000]
EVENT_RATE_THREE_WAY = [20, 50, 100, 200, 300, 400, 500, 600, 700, 800, 900, 1000]
TUPLE_WIDTHS = list(range(3, 11))  # [3..10] attributes per tuple
DTYPES = ["int", "double", "string"]

# --- operator-related --------------------------------------------------------
FILTER_FNS = ["<", ">", "<=", ">=", "!=", "startswith", "endswith"]
LITERAL_DTYPES = ["int", "string", "double"]
WINDOW_TYPES = ["sliding", "tumbling"]
WINDOW_POLICIES = ["count", "time"]
WINDOW_SIZE_COUNT = [5, 10, 20, 40, 80, 160, 320, 640]  # tuples
WINDOW_SIZE_TIME = [0.25, 0.5, 1, 2, 4, 8, 16]  # seconds
SLIDE_RATIO = (0.3, 0.7)  # x window length
JOIN_KEY_DTYPES = ["int", "string", "double"]
AGG_FNS = ["min", "max", "mean", "sum"]
GROUP_BY_DTYPES = ["int", "string", "double", "none"]

# Selectivity sampling (not in Table II; paper Definitions 6-8 bound them [0,1]).
FILTER_SEL_LOG10 = (-2.0, 0.0)  # 0.01 .. 1.0
JOIN_SEL_LOG10 = (-3.0, -0.5)  # 0.001 .. ~0.316 of the cartesian product
AGG_SEL_LOG10 = (-2.0, 0.0)  # distinct groups / window length

# Query mix of the benchmark corpus (paper SVI): linear / 2-way / 3-way joins.
QUERY_MIX = {"linear": 0.35, "two_way": 0.34, "three_way": 0.31}
# #filters distribution: 35% 1, 34% 2, 24% 3, 6% 4 (paper SVI); renormalized.
FILTER_COUNT_P = {1: 0.35, 2: 0.34, 3: 0.24, 4: 0.06}
AGG_PROBABILITY = 0.5

# Log-normalization bounds used by the transferable featurization. Chosen to
# cover the training ranges with generous head-room so that *extrapolation*
# (Exp 4) stays inside finite normalized values rather than clipping.
LOG_BOUNDS = {
    "cpu": (10.0, 3200.0),
    "ram_mb": (250.0, 128000.0),
    "bandwidth_mbps": (5.0, 40000.0),
    "latency_ms": (0.25, 640.0),
    "event_rate": (5.0, 102400.0),
    "tuple_width": (1.0, 40.0),
    "selectivity": (1e-4, 1.0),
    "window_count": (1.0, 2560.0),
    "window_time_s": (0.05, 64.0),
}


def interpolation_ranges() -> dict:
    """Unseen-but-in-range hardware values (paper Table IV (A))."""
    return {
        "CPU": [75, 150, 250, 350, 450, 550, 650, 750],
        "RAM_MB": [1500, 3000, 6000, 12000, 20000, 28000],
        "BANDWIDTH_MBPS": [35, 75, 150, 250, 550, 1200, 1900, 4800, 8000],
        "LATENCY_MS": [3, 7, 15, 30, 60, 120],
    }


def extrapolation_ranges() -> dict:
    """Reduced training ranges + out-of-range eval values (paper Table V)."""
    return {
        "stronger": {
            "train": {
                "RAM_MB": [1000, 2000, 4000, 8000, 16000],
                "CPU": [50, 100, 200, 300, 400, 500, 600],
                "BANDWIDTH_MBPS": [25, 50, 100, 200, 400, 800, 1600, 3200],
                "LATENCY_MS": [5, 10, 20, 40, 80, 160],
            },
            "eval": {
                "RAM_MB": [24000, 32000],
                "CPU": [700, 800],
                "BANDWIDTH_MBPS": [6400, 10000],
                "LATENCY_MS": [1, 2],
            },
        },
        "weaker": {
            "train": {
                "RAM_MB": [4000, 8000, 16000, 24000, 32000],
                "CPU": [200, 300, 400, 500, 600, 700, 800],
                "BANDWIDTH_MBPS": [100, 200, 400, 800, 1600, 3200, 6400, 10000],
                "LATENCY_MS": [1, 2, 5, 10, 20, 40],
            },
            "eval": {
                "RAM_MB": [1000, 2000],
                "CPU": [50, 100],
                "BANDWIDTH_MBPS": [25, 50],
                "LATENCY_MS": [80, 160],
            },
        },
    }
