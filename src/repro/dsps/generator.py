"""Benchmark workload generator (paper SVI + Table II).

Generates the cost-estimation corpus: random streaming queries (linear filter
chains, 2-way and 3-way joins at approximately 35/34/31 %), random
heterogeneous clusters, and placements, then labels them with the simulator.
Everything is reproducible from integer seeds.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.dsps import ranges
from repro.dsps.hardware import Cluster, HardwareNode
from repro.dsps.placement import Placement
from repro.dsps.query import (
    AggFn,
    DType,
    FilterFn,
    Operator,
    OpType,
    Query,
    WindowSpec,
)
from repro.dsps.simulator import CostLabels, SimulatorConfig, simulate


@dataclass(frozen=True)
class Trace:
    """One corpus entry: a placed query with its measured cost labels."""

    query: Query
    cluster: Cluster
    placement: Placement
    labels: CostLabels


@dataclass(frozen=True)
class GeneratorConfig:
    """Sampling ranges; defaults mirror Table II exactly."""

    cpu: Sequence[float] = tuple(ranges.CPU)
    ram_mb: Sequence[float] = tuple(ranges.RAM_MB)
    bandwidth_mbps: Sequence[float] = tuple(ranges.BANDWIDTH_MBPS)
    latency_ms: Sequence[float] = tuple(ranges.LATENCY_MS)
    event_rate_linear: Sequence[float] = tuple(ranges.EVENT_RATE_LINEAR)
    event_rate_two_way: Sequence[float] = tuple(ranges.EVENT_RATE_TWO_WAY)
    event_rate_three_way: Sequence[float] = tuple(ranges.EVENT_RATE_THREE_WAY)
    tuple_widths: Sequence[int] = tuple(ranges.TUPLE_WIDTHS)
    window_size_count: Sequence[float] = tuple(ranges.WINDOW_SIZE_COUNT)
    window_size_time: Sequence[float] = tuple(ranges.WINDOW_SIZE_TIME)
    filter_count_p: Tuple[Tuple[int, float], ...] = tuple(ranges.FILTER_COUNT_P.items())
    agg_probability: float = ranges.AGG_PROBABILITY
    query_mix: Tuple[Tuple[str, float], ...] = tuple(ranges.QUERY_MIX.items())
    n_hosts: Tuple[int, int] = (3, 8)
    max_filters_per_chain: int = 4  # training corpus uses 1 (Exp 5 uses 2..4)
    filters_per_chain: int = 1
    sim: SimulatorConfig = SimulatorConfig()

    def with_hardware(self, **kw) -> "GeneratorConfig":
        return replace(self, **kw)


class WorkloadGenerator:
    def __init__(self, config: GeneratorConfig = GeneratorConfig(), seed: int = 0):
        self.config = config
        self.rng = np.random.default_rng(seed)

    # -- sampling helpers ------------------------------------------------------
    def _choice(self, seq: Sequence) -> object:
        return seq[int(self.rng.integers(0, len(seq)))]

    def _dtype(self, allow_none: bool = False) -> DType:
        opts = [DType.INT, DType.DOUBLE, DType.STRING] + ([DType.NONE] if allow_none else [])
        return opts[int(self.rng.integers(0, len(opts)))]

    def _window(self) -> WindowSpec:
        policy = str(self._choice(ranges.WINDOW_POLICIES))
        wtype = str(self._choice(ranges.WINDOW_TYPES))
        if policy == "count":
            size = float(self._choice(self.config.window_size_count))
        else:
            size = float(self._choice(self.config.window_size_time))
        lo, hi = ranges.SLIDE_RATIO
        slide = float(self.rng.uniform(lo, hi))
        return WindowSpec(wtype=wtype, policy=policy, size=size, slide_ratio=slide)

    def _loguniform(self, lo10: float, hi10: float) -> float:
        return float(10.0 ** self.rng.uniform(lo10, hi10))

    def _source(self, op_id: int, rate_pool: Sequence[float]) -> Operator:
        width = int(self._choice(self.config.tuple_widths))
        # random attribute type mix
        kinds = self.rng.multinomial(width, [1 / 3] * 3)
        return Operator(
            op_id=op_id,
            op_type=OpType.SOURCE,
            event_rate=float(self._choice(rate_pool)),
            n_int=int(kinds[0]),
            n_double=int(kinds[1]),
            n_string=int(kinds[2]),
        )

    def _filter(self, op_id: int) -> Operator:
        fn = FilterFn(str(self._choice(ranges.FILTER_FNS)))
        if fn in (FilterFn.STARTSWITH, FilterFn.ENDSWITH):
            lit = DType.STRING
        else:
            lit = DType(str(self._choice(["int", "double"])))
        return Operator(
            op_id=op_id,
            op_type=OpType.FILTER,
            filter_fn=fn,
            literal_dtype=lit,
            selectivity=self._loguniform(*ranges.FILTER_SEL_LOG10),
        )

    def _agg(self, op_id: int) -> Operator:
        gb = self._dtype(allow_none=True)
        return Operator(
            op_id=op_id,
            op_type=OpType.AGGREGATE,
            agg_fn=AggFn(str(self._choice(ranges.AGG_FNS))),
            group_by_dtype=gb,
            agg_dtype=DType(str(self._choice(["int", "double"]))),
            window=self._window(),
            selectivity=(
                self._loguniform(*ranges.AGG_SEL_LOG10) if gb != DType.NONE else 1.0
            ),
        )

    def _join(self, op_id: int) -> Operator:
        return Operator(
            op_id=op_id,
            op_type=OpType.JOIN,
            join_key_dtype=self._dtype(),
            window=self._window(),
            selectivity=self._loguniform(*ranges.JOIN_SEL_LOG10),
        )

    def _sink(self, op_id: int) -> Operator:
        return Operator(op_id=op_id, op_type=OpType.SINK)

    def _n_filters(self) -> int:
        counts, probs = zip(*self.config.filter_count_p)
        probs = np.asarray(probs, dtype=np.float64)
        probs = probs / probs.sum()
        return int(self.rng.choice(counts, p=probs))

    # -- query templates ---------------------------------------------------------
    def query(self, kind: Optional[str] = None, name: str = "q") -> Query:
        if kind is None:
            kinds, probs = zip(*self.config.query_mix)
            probs = np.asarray(probs, dtype=np.float64)
            kind = str(self.rng.choice(kinds, p=probs / probs.sum()))
        if kind == "linear":
            return self.linear_query(name=name)
        if kind == "two_way":
            return self.join_query(n_streams=2, name=name)
        if kind == "three_way":
            return self.join_query(n_streams=3, name=name)
        raise ValueError(kind)

    def linear_query(self, name: str = "q", n_filters: Optional[int] = None) -> Query:
        """source -> filter+ -> [agg] -> sink (paper: linear filter queries).

        Training corpora use chains of length ``config.filters_per_chain``
        (default 1 — the paper's training data "has only seen 1 subsequent
        filter operator"); Exp 5 passes ``n_filters`` = 2..4 explicitly to
        build the *unseen* longer chains.
        """
        ops: List[Operator] = []
        edges: List[Tuple[int, int]] = []
        ops.append(self._source(0, self.config.event_rate_linear))
        prev = 0
        nf = self.config.filters_per_chain if n_filters is None else n_filters
        nf = max(1, min(nf, self.config.max_filters_per_chain))
        for _ in range(nf):
            ops.append(self._filter(len(ops)))
            edges.append((prev, len(ops) - 1))
            prev = len(ops) - 1
        if self.rng.random() < self.config.agg_probability:
            ops.append(self._agg(len(ops)))
            edges.append((prev, len(ops) - 1))
            prev = len(ops) - 1
        ops.append(self._sink(len(ops)))
        edges.append((prev, len(ops) - 1))
        return Query(operators=ops, edges=edges, name=name).infer_widths()

    def join_query(self, n_streams: int = 2, name: str = "q") -> Query:
        """n sources -> [filters] -> join tree -> [agg] -> sink (paper Fig. 6)."""
        assert n_streams in (2, 3)
        pool = (
            self.config.event_rate_two_way
            if n_streams == 2
            else self.config.event_rate_three_way
        )
        ops: List[Operator] = []
        edges: List[Tuple[int, int]] = []
        heads: List[int] = []
        budget = self._n_filters()
        for s in range(n_streams):
            ops.append(self._source(len(ops), pool))
            head = len(ops) - 1
            # optional filter on this stream
            if budget > 0 and self.rng.random() < 0.6:
                ops.append(self._filter(len(ops)))
                edges.append((head, len(ops) - 1))
                head = len(ops) - 1
                budget -= 1
            heads.append(head)
        # left-deep join tree
        left = heads[0]
        for s in range(1, n_streams):
            ops.append(self._join(len(ops)))
            j = len(ops) - 1
            edges.append((left, j))
            edges.append((heads[s], j))
            left = j
        # spend remaining filter budget after the join (never consecutively:
        # chains of >1 filter are reserved for the unseen-pattern experiment)
        if budget > 0 and self.rng.random() < 0.5:
            ops.append(self._filter(len(ops)))
            edges.append((left, len(ops) - 1))
            left = len(ops) - 1
        if self.rng.random() < self.config.agg_probability:
            ops.append(self._agg(len(ops)))
            edges.append((left, len(ops) - 1))
            left = len(ops) - 1
        ops.append(self._sink(len(ops)))
        edges.append((left, len(ops) - 1))
        return Query(operators=ops, edges=edges, name=name).infer_widths()

    # -- hardware ----------------------------------------------------------------
    def cluster(self, n_hosts: Optional[int] = None) -> Cluster:
        lo, hi = self.config.n_hosts
        n = int(self.rng.integers(lo, hi + 1)) if n_hosts is None else n_hosts
        nodes = [
            HardwareNode(
                node_id=i,
                cpu=float(self._choice(self.config.cpu)),
                ram_mb=float(self._choice(self.config.ram_mb)),
                bandwidth_mbps=float(self._choice(self.config.bandwidth_mbps)),
                latency_ms=float(self._choice(self.config.latency_ms)),
            )
            for i in range(n)
        ]
        return Cluster(nodes=nodes)

    # -- placement ----------------------------------------------------------------
    def placement(self, query: Query, cluster: Cluster) -> Placement:
        """Random placement with a mild co-location bias (training diversity).

        The corpus intentionally includes bad placements (overload, OOM,
        network-saturated) so the model learns backpressure/failure modes.
        """
        n = cluster.n_nodes()
        assign: List[int] = [0] * query.n_ops()
        for op in query.operators:
            if op.op_type == OpType.SOURCE or self.rng.random() < 0.35:
                assign[op.op_id] = int(self.rng.integers(0, n))
            else:
                # follow a parent's host (co-location) or pick fresh
                parents = query.parents(op.op_id)
                if parents and self.rng.random() < 0.5:
                    assign[op.op_id] = assign[parents[0]]
                else:
                    assign[op.op_id] = int(self.rng.integers(0, n))
        return Placement.of(assign)

    # -- corpus ---------------------------------------------------------------------
    def trace(self, kind: Optional[str] = None, name: str = "q") -> Trace:
        q = self.query(kind=kind, name=name)
        c = self.cluster()
        p = self.placement(q, c)
        labels = simulate(q, c, p, self.config.sim, rng=self.rng)
        return Trace(query=q, cluster=c, placement=p, labels=labels)

    def corpus(self, n: int, name_prefix: str = "q") -> List[Trace]:
        return [self.trace(name=f"{name_prefix}{i}") for i in range(n)]
