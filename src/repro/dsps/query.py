"""Streaming query IR: a DAG of algebraic streaming operators.

Mirrors the paper's operator model (SIII-A): source / filter / windowed
aggregation / windowed join / sink, with the transferable operator- and
data-related features of Table I attached to each operator node.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from enum import Enum
from typing import Dict, List, Optional, Sequence, Tuple


class OpType(str, Enum):
    SOURCE = "source"
    FILTER = "filter"
    AGGREGATE = "aggregate"
    JOIN = "join"
    SINK = "sink"


class DType(str, Enum):
    INT = "int"
    DOUBLE = "double"
    STRING = "string"
    NONE = "none"  # only valid for group-by


class FilterFn(str, Enum):
    LT = "<"
    GT = ">"
    LE = "<="
    GE = ">="
    NE = "!="
    STARTSWITH = "startswith"
    ENDSWITH = "endswith"


class AggFn(str, Enum):
    MIN = "min"
    MAX = "max"
    MEAN = "mean"
    SUM = "sum"


@dataclass(frozen=True)
class WindowSpec:
    """Window configuration for stateful operators (join / aggregation)."""

    wtype: str = "tumbling"  # sliding | tumbling
    policy: str = "count"  # count | time
    size: float = 10.0  # tuples (count) or seconds (time)
    slide_ratio: float = 0.5  # sliding interval as a fraction of the size

    def __post_init__(self):
        assert self.wtype in ("sliding", "tumbling"), self.wtype
        assert self.policy in ("count", "time"), self.policy
        assert self.size > 0, self.size
        assert 0.0 < self.slide_ratio <= 1.0, self.slide_ratio

    def slide(self) -> float:
        """Effective slide: tumbling windows slide by one full window."""
        return self.size if self.wtype == "tumbling" else self.size * self.slide_ratio

    def length_tuples(self, rate: float) -> float:
        """Window length in tuples given the incoming tuple rate [ev/s]."""
        if self.policy == "count":
            return float(self.size)
        return max(1.0, float(self.size) * max(rate, 1e-9))

    def period_seconds(self, rate: float) -> float:
        """Time between window emissions given the incoming rate [ev/s]."""
        slide = self.slide()
        if self.policy == "time":
            return float(slide)
        return float(slide) / max(rate, 1e-9)


@dataclass
class Operator:
    """One streaming operator with its Table-I transferable features.

    Only the fields relevant to ``op_type`` are meaningful; the featurizer
    masks the rest. ``tuple_width_in/out`` are derived by ``Query.infer_widths``.
    """

    op_id: int
    op_type: OpType
    # data-related (all nodes)
    tuple_width_in: float = 0.0
    tuple_width_out: float = 0.0
    # source
    event_rate: float = 0.0
    n_int: int = 0
    n_double: int = 0
    n_string: int = 0
    # filter
    filter_fn: Optional[FilterFn] = None
    literal_dtype: Optional[DType] = None
    # join
    join_key_dtype: Optional[DType] = None
    # aggregation
    agg_fn: Optional[AggFn] = None
    group_by_dtype: Optional[DType] = None
    agg_dtype: Optional[DType] = None
    # stateful ops
    window: Optional[WindowSpec] = None
    # filter/join/agg
    selectivity: float = 1.0

    def is_stateful(self) -> bool:
        return self.op_type in (OpType.AGGREGATE, OpType.JOIN)

    def replace(self, **kw) -> "Operator":
        return dataclasses.replace(self, **kw)


@dataclass
class Query:
    """A streaming query: operators + logical data-flow edges (a DAG).

    Convention: exactly one sink; sources have no parents; the DAG is a tree
    oriented towards the sink (paper SIII-A: "the logical data flow is not
    always linear but can take the form of a tree").
    """

    operators: List[Operator]
    edges: List[Tuple[int, int]]  # (upstream op_id, downstream op_id)
    name: str = "query"

    def __post_init__(self):
        ids = [op.op_id for op in self.operators]
        assert ids == sorted(ids) == list(range(len(ids))), "op_ids must be 0..n-1"
        for u, v in self.edges:
            assert 0 <= u < len(ids) and 0 <= v < len(ids), (u, v)
        assert len(self.sinks()) == 1, "exactly one sink expected"
        self._validate_acyclic()

    # -- structure ------------------------------------------------------------
    def op(self, op_id: int) -> Operator:
        return self.operators[op_id]

    def children(self, op_id: int) -> List[int]:
        return [v for (u, v) in self.edges if u == op_id]

    def parents(self, op_id: int) -> List[int]:
        return [u for (u, v) in self.edges if v == op_id]

    def sources(self) -> List[int]:
        return [op.op_id for op in self.operators if op.op_type == OpType.SOURCE]

    def sinks(self) -> List[int]:
        return [op.op_id for op in self.operators if op.op_type == OpType.SINK]

    def sink(self) -> int:
        return self.sinks()[0]

    def _validate_acyclic(self) -> None:
        order = self.topological_order()
        assert len(order) == len(self.operators), "data-flow graph has a cycle"

    def topological_order(self) -> List[int]:
        indeg: Dict[int, int] = {op.op_id: 0 for op in self.operators}
        for _, v in self.edges:
            indeg[v] += 1
        frontier = [i for i, d in sorted(indeg.items()) if d == 0]
        order: List[int] = []
        while frontier:
            u = frontier.pop(0)
            order.append(u)
            for v in self.children(u):
                indeg[v] -= 1
                if indeg[v] == 0:
                    frontier.append(v)
        return order

    def depths(self) -> Dict[int, int]:
        """Topological depth (longest distance from any source)."""
        depth: Dict[int, int] = {}
        for u in self.topological_order():
            ps = self.parents(u)
            depth[u] = 0 if not ps else 1 + max(depth[p] for p in ps)
        return depth

    def root_to_sink_paths(self) -> List[List[int]]:
        """All source->sink op-id paths (the units of the paper's Fig.-5
        acyclicity rule: once data leaves a host it must never return)."""
        sink = self.sink()

        def walk(u: int) -> List[List[int]]:
            if u == sink:
                return [[u]]
            out = []
            for v in self.children(u):
                for p in walk(v):
                    out.append([u] + p)
            return out

        paths: List[List[int]] = []
        for src in self.sources():
            paths.extend(walk(src))
        return paths

    def max_depth(self) -> int:
        return max(self.depths().values()) if self.operators else 0

    # -- feature derivation -----------------------------------------------------
    def infer_widths(self) -> "Query":
        """Derive tuple widths through the data flow (in topological order).

        source: width = #attributes; filter: pass-through; join: sum of both
        input widths; aggregation: (group key + aggregate value) or 1; sink:
        pass-through.
        """
        width: Dict[int, float] = {}
        for u in self.topological_order():
            op = self.op(u)
            pw = [width[p] for p in self.parents(u)]
            if op.op_type == OpType.SOURCE:
                w_in = float(op.n_int + op.n_double + op.n_string)
                w_out = w_in
            elif op.op_type == OpType.FILTER:
                w_in = pw[0]
                w_out = w_in
            elif op.op_type == OpType.JOIN:
                w_in = sum(pw)
                w_out = sum(pw)
            elif op.op_type == OpType.AGGREGATE:
                w_in = pw[0]
                w_out = 2.0 if (op.group_by_dtype not in (None, DType.NONE)) else 1.0
            else:  # SINK
                w_in = pw[0]
                w_out = pw[0]
            op.tuple_width_in = w_in
            op.tuple_width_out = w_out
            width[u] = w_out
        return self

    # -- stats -----------------------------------------------------------------
    def count(self, op_type: OpType) -> int:
        return sum(1 for op in self.operators if op.op_type == op_type)

    def n_ops(self) -> int:
        return len(self.operators)

    def describe(self) -> str:
        parts = []
        for op in self.operators:
            parts.append(f"{op.op_id}:{op.op_type.value}")
        edges = ",".join(f"{u}->{v}" for u, v in self.edges)
        return f"Query<{self.name}|{' '.join(parts)}|{edges}>"


def linear_chain(operators: Sequence[Operator], name: str = "query") -> Query:
    """Convenience builder: operators wired in a straight chain."""
    ops = list(operators)
    edges = [(i, i + 1) for i in range(len(ops) - 1)]
    return Query(operators=ops, edges=edges, name=name).infer_widths()
