"""DSPS substrate: query IR, hardware model, workload generator, cost simulator.

This package models the *system under study* of the COSTREAM paper: distributed
streaming queries (filter / windowed aggregation / windowed join) placed onto
heterogeneous edge-cloud hardware. The analytic simulator replaces the Apache
Storm + CloudLab measurement harness of the paper as the label oracle (see
DESIGN.md §2); everything learned on top of it is the paper's contribution.
"""

from repro.dsps.query import (
    Operator,
    OpType,
    Query,
    WindowSpec,
    AggFn,
    FilterFn,
    DType,
)
from repro.dsps.hardware import HardwareNode, Cluster, hardware_bin
from repro.dsps.placement import Placement
from repro.dsps.simulator import simulate, CostLabels, SimulatorConfig
from repro.dsps.generator import WorkloadGenerator, GeneratorConfig
from repro.dsps import ranges

__all__ = [
    "Operator",
    "OpType",
    "Query",
    "WindowSpec",
    "AggFn",
    "FilterFn",
    "DType",
    "HardwareNode",
    "Cluster",
    "hardware_bin",
    "Placement",
    "simulate",
    "CostLabels",
    "SimulatorConfig",
    "WorkloadGenerator",
    "GeneratorConfig",
    "ranges",
]
