"""Joint operator-resource graph (paper SIII-A) as padded dense arrays.

COSTREAM graphs are tiny (<= ~12 operators, <= 8 hosts) but ragged; on TPU we
represent them as fixed-shape padded blocks so batched message passing becomes
masked matmuls (see DESIGN.md SS4). One ``JointGraph`` holds a *batch* of
graphs when arrays carry a leading batch dim; ``batch_graphs`` stacks singles.
"""

from __future__ import annotations

import dataclasses
from typing import List, NamedTuple, Tuple

import numpy as np

from repro.core import features as F
from repro.dsps.hardware import Cluster
from repro.dsps.placement import Placement
from repro.dsps.query import Query

MAX_OPS = 12
MAX_HW = 8
# Longest source->sink chain in the corpus: source + 4 filters + agg + sink
# (depth 6) and the Exp-5 filter-chain variants; 8 leaves head-room while
# keeping the stage-3 scan short (it dominates step time).
MAX_DEPTH = 8

# Canonical DEPTH-MAJOR slot layout: operator i of type t occupies a slot
# inside t's static range, and the ranges themselves are ordered by where the
# type sits in the data flow (sources -> filters -> joins -> aggregations ->
# sink).  Two properties follow:
#   * type-specific MLPs run on static slices instead of masked full-width
#     banks (see nn.apply_mlp_bank_slotted) — a 5x FLOP cut that is also the
#     layout the Pallas kernel tiles on;
#   * topological depth is (for every corpus query shape: linear chains,
#     2-way and 3-way joins) non-decreasing along the slot axis, so each
#     stage-3 depth level occupies a narrow row band and ``batch_banding``
#     can hand the message-passing kernel tight static ``row_span`` /
#     ``parent_rows`` bounds.  Correctness never depends on the monotonicity
#     (banding is computed from the actual depths and only ever widens), only
#     the bands' tightness does.
#   type id: SOURCE=0, FILTER=1, AGGREGATE=2, JOIN=3, SINK=4 (features.OP_TYPE_IDS)
SLOT_RANGES = (
    (0, 0, 3),  # up to 3 sources (depth 0)
    (1, 3, 7),  # up to 4 filters (source chains, shallow)
    (3, 7, 9),  # up to 2 joins (after the filtered chains)
    (2, 9, 11),  # up to 2 aggregations (after joins in the corpus shapes)
    (4, 11, 12),  # 1 sink (always the deepest node)
)


class JointGraph(NamedTuple):
    """Padded joint graph; all fields are numpy/jnp arrays.

    Shapes below are for a single graph; batched graphs prepend a batch dim.
    """

    op_x: np.ndarray  # (MAX_OPS, OP_FEATURE_DIM) float32
    op_type: np.ndarray  # (MAX_OPS,) int32  in [0, N_OP_TYPES); padded rows are 0
    op_mask: np.ndarray  # (MAX_OPS,) float32 {0,1}
    op_depth: np.ndarray  # (MAX_OPS,) int32 topological depth; padded rows 0
    hw_x: np.ndarray  # (MAX_HW, HW_FEATURE_DIM) float32
    hw_mask: np.ndarray  # (MAX_HW,) float32 {0,1}
    a_flow: np.ndarray  # (MAX_OPS, MAX_OPS) float32; a_flow[u, v] = 1 iff u -> v
    a_place: np.ndarray  # (MAX_OPS, MAX_HW) float32; a_place[i, j] = 1 iff op i on host j

    @property
    def batched(self) -> bool:
        return self.op_x.ndim == 3


def _slot_assignment(query: Query) -> dict:
    """op_id -> canonical slot (inside its type's static range)."""
    base = {t: (start, stop) for (t, start, stop) in SLOT_RANGES}
    counts = {t: 0 for (t, _, _) in SLOT_RANGES}
    slots = {}
    for op in query.operators:
        t = F.op_type_id(op)
        start, stop = base[t]
        assert counts[t] < stop - start, (
            f"query exceeds slot capacity for type {t}: {query.describe()}"
        )
        slots[op.op_id] = start + counts[t]
        counts[t] += 1
    return slots


def build_graph_skeleton(
    query: Query,
    cluster: Cluster,
    max_ops: int = MAX_OPS,
    max_hw: int = MAX_HW,
) -> JointGraph:
    """The placement-invariant part of a joint graph (``a_place`` all zero).

    Query and cluster features do not depend on where operators run, so a
    skeleton can be materialized once and shared across every candidate
    placement of the same (query, cluster) pair — the single-materialization
    contract ``build_graph_batch`` relies on.
    """
    n_ops, n_hw = query.n_ops(), cluster.n_nodes()
    assert n_ops <= max_ops, f"query has {n_ops} ops > pad {max_ops}"
    assert n_hw <= max_hw, f"cluster has {n_hw} hosts > pad {max_hw}"

    op_x = np.zeros((max_ops, F.OP_FEATURE_DIM), dtype=np.float32)
    op_type = np.zeros((max_ops,), dtype=np.int32)
    op_mask = np.zeros((max_ops,), dtype=np.float32)
    op_depth = np.zeros((max_ops,), dtype=np.int32)
    hw_x = np.zeros((max_hw, F.HW_FEATURE_DIM), dtype=np.float32)
    hw_mask = np.zeros((max_hw,), dtype=np.float32)
    a_flow = np.zeros((max_ops, max_ops), dtype=np.float32)
    a_place = np.zeros((max_ops, max_hw), dtype=np.float32)

    # fill padded slots with their range's type id so slotted MLPs stay exact
    for t, start, stop in SLOT_RANGES:
        op_type[start:stop] = t

    slot = _slot_assignment(query)
    depths = query.depths()
    for op in query.operators:
        i = slot[op.op_id]
        op_x[i] = F.featurize_operator(op)
        op_type[i] = F.op_type_id(op)
        op_mask[i] = 1.0
        op_depth[i] = depths[op.op_id]
    for node in cluster.nodes:
        hw_x[node.node_id] = F.featurize_hardware(node)
        hw_mask[node.node_id] = 1.0
    for u, v in query.edges:
        a_flow[slot[u], slot[v]] = 1.0

    return JointGraph(
        op_x=op_x,
        op_type=op_type,
        op_mask=op_mask,
        op_depth=op_depth,
        hw_x=hw_x,
        hw_mask=hw_mask,
        a_flow=a_flow,
        a_place=a_place,
    )


def skeleton_cache_key(query: Query, cluster: Cluster) -> Tuple:
    """Hashable structural fingerprint of the skeleton-determining inputs.

    Two (query, cluster) pairs with equal keys featurize to identical
    ``build_graph_skeleton`` outputs and ``query_static`` summaries: the key
    covers every operator field (``dataclasses.astuple`` recurses into
    ``WindowSpec``), the logical edges, and the hardware nodes — but not
    ``query.name``, which never reaches the featurizer.  Computing it is
    O(n_ops + n_hw) tuple building, far cheaper than the skeleton
    featurization + device transfer it lets callers amortize (the
    online-monitoring pattern re-scores the same query every round).
    """
    return (
        tuple(dataclasses.astuple(op) for op in query.operators),
        tuple(query.edges),
        tuple(cluster.nodes),
    )


def slot_index(query: Query) -> np.ndarray:
    """``slot_index(q)[op_id]`` = the canonical padded row of that operator."""
    slot = _slot_assignment(query)
    return np.asarray([slot[i] for i in range(query.n_ops())], dtype=np.int64)


class QueryStatic(NamedTuple):
    """Hashable trace-time summary of one query's structure in slot space.

    Drives the placement-specialized GNN forward (``gnn.apply_gnn_placed``):
    the stage-3 data-flow sweep is unrolled over ``updates`` — per depth level
    ``d >= 1``, the tuple of ``(slot, type_id, parent_slots)`` to update — so
    only the handful of slots that actually carry an operator at each depth
    are recomputed, instead of all ``MAX_OPS`` slots for all ``MAX_DEPTH``
    levels.  Being a tuple-of-ints NamedTuple it is hashable and serves as a
    jit-cache key alongside the model config.
    """

    active: Tuple[int, ...]  # slots holding a real operator, ascending
    updates: Tuple[Tuple[Tuple[int, int, Tuple[int, ...]], ...], ...]


def query_static(query: Query) -> QueryStatic:
    slot = _slot_assignment(query)
    depths = query.depths()
    levels = []
    for d in range(1, query.max_depth() + 1):
        level = []
        for op in query.operators:
            if depths[op.op_id] != d:
                continue
            parents = tuple(sorted(slot[p] for p in query.parents(op.op_id)))
            level.append((slot[op.op_id], F.op_type_id(op), parents))
        levels.append(tuple(sorted(level)))
    return QueryStatic(
        active=tuple(sorted(slot[i] for i in range(query.n_ops()))),
        updates=tuple(levels),
    )


def build_a_place_batch(
    query: Query,
    cluster: Cluster,
    assignments: np.ndarray,
    max_ops: int = MAX_OPS,
    max_hw: int = MAX_HW,
) -> np.ndarray:
    """Just the ``(N, max_ops, max_hw)`` placement adjacency of a batch."""
    assignments = np.asarray(assignments, dtype=np.int64)
    assert assignments.ndim == 2 and assignments.shape[1] == query.n_ops(), assignments.shape
    assert cluster.n_nodes() <= max_hw, f"cluster has {cluster.n_nodes()} hosts > pad {max_hw}"
    n = assignments.shape[0]
    a_place = np.zeros((n, max_ops, max_hw), dtype=np.float32)
    rows = slot_index(query)
    a_place[np.arange(n)[:, None], rows[None, :], assignments] = 1.0
    return a_place


def build_graph(
    query: Query,
    cluster: Cluster,
    placement: Placement,
    max_ops: int = MAX_OPS,
    max_hw: int = MAX_HW,
) -> JointGraph:
    g = build_graph_skeleton(query, cluster, max_ops, max_hw)
    a_place = np.zeros((max_ops, max_hw), dtype=np.float32)
    slot = _slot_assignment(query)
    for i in range(query.n_ops()):
        a_place[slot[i], placement.node_of(i)] = 1.0
    return g._replace(a_place=a_place)


def broadcast_skeleton(skel: JointGraph, a_place: np.ndarray) -> JointGraph:
    """Broadcast one skeleton against an ``(N, max_ops, max_hw)`` placement batch.

    Every placement-invariant field becomes a zero-copy broadcast view along
    the new batch axis (read-only — copy before mutating); only ``a_place``
    carries per-candidate data.  This is the single-materialization contract
    behind ``build_graph_batch`` and the cross-query merge path, which reuses
    LRU-cached skeletons instead of re-featurizing.
    """
    a_place = np.asarray(a_place)
    n = a_place.shape[0]
    return JointGraph(
        *[np.broadcast_to(np.asarray(x), (n,) + np.asarray(x).shape) for x in skel[:-1]],
        a_place=a_place,
    )


def build_graph_batch(
    query: Query,
    cluster: Cluster,
    assignments: np.ndarray,
    max_ops: int = MAX_OPS,
    max_hw: int = MAX_HW,
) -> JointGraph:
    """Batch of ``N`` candidate placements of one query, built in one pass.

    ``assignments`` is an ``(N, n_ops)`` int matrix (``assignments[c, op_id]``
    = host of ``op_id`` in candidate ``c``).  The skeleton is materialized
    once and broadcast (``broadcast_skeleton``); only ``a_place`` is written
    per candidate.  Equivalent to
    ``batch_graphs([build_graph(q, c, Placement.of(row)) for row in a])`` but
    O(1) featurization passes instead of O(N).
    """
    assignments = np.asarray(assignments, dtype=np.int64)
    assert assignments.ndim == 2 and assignments.shape[1] == query.n_ops(), assignments.shape
    g = build_graph_skeleton(query, cluster, max_ops, max_hw)
    return broadcast_skeleton(g, build_a_place_batch(query, cluster, assignments, max_ops, max_hw))


def batch_graphs(graphs: List[JointGraph]) -> JointGraph:
    return JointGraph(*[np.stack([getattr(g, f) for g in graphs]) for f in JointGraph._fields])


class BroadcastBatch(NamedTuple):
    """Several per-query graph batches merged along the shared batch axis.

    ``graphs`` is one ordinary batched ``JointGraph`` — every member shares
    the canonical depth-major padded layout, so batches from *different*
    query structures concatenate directly — and ``sizes`` remembers each
    source batch's row count so fused answers can be split back per request.
    """

    graphs: JointGraph
    sizes: Tuple[int, ...]


def merge_graph_batches(batches: List[JointGraph]) -> BroadcastBatch:
    """Concatenate per-query batches (broadcast views included) into ONE batch.

    The cross-query serving primitive: N distinct requests' graphs become one
    shared padded batch whose single stacked forward replaces N per-structure
    forwards (``CostEstimator.estimate_many`` / ``score_many``).  Broadcast
    views from ``broadcast_skeleton`` are materialized here, once, at merge
    time.
    """
    assert batches, "no batches to merge"
    sizes = tuple(int(np.asarray(b.op_x).shape[0]) for b in batches)
    merged = JointGraph(
        *[
            np.concatenate([np.asarray(getattr(b, f)) for b in batches], axis=0)
            for f in JointGraph._fields
        ]
    )
    return BroadcastBatch(graphs=merged, sizes=sizes)


# Padding / shape-bucket / stage-3 banding policy shared with the training
# pipeline lives in core/bucketing.py; re-exported here because the graph
# layout and its padding + banding contracts are one interface.
from repro.core.bucketing import (  # noqa: E402,F401
    BatchBanding,
    batch_banding,
    batch_signature,
    bucket_size,
    exact_banding,
    exact_banding_cached,
    pad_batch,
)


# -- ablation transforms (Exp 7a) ----------------------------------------------


def drop_hardware(g: JointGraph) -> JointGraph:
    """Featurization ablation 1: operators only (no placement, no hardware)."""
    return g._replace(
        hw_mask=np.zeros_like(g.hw_mask),
        a_place=np.zeros_like(g.a_place),
        hw_x=np.zeros_like(g.hw_x),
    )


def drop_hw_features(g: JointGraph) -> JointGraph:
    """Featurization ablation 2: placement/co-location kept, hw features zeroed."""
    return g._replace(hw_x=np.zeros_like(g.hw_x))
