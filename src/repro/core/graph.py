"""Joint operator-resource graph (paper SIII-A) as padded dense arrays.

COSTREAM graphs are tiny (<= ~12 operators, <= 8 hosts) but ragged; on TPU we
represent them as fixed-shape padded blocks so batched message passing becomes
masked matmuls (see DESIGN.md SS4). One ``JointGraph`` holds a *batch* of
graphs when arrays carry a leading batch dim; ``batch_graphs`` stacks singles.
"""

from __future__ import annotations

from typing import List, NamedTuple, Optional, Tuple

import numpy as np

from repro.core import features as F
from repro.dsps.hardware import Cluster
from repro.dsps.placement import Placement
from repro.dsps.query import Query

MAX_OPS = 12
MAX_HW = 8
# Longest source->sink chain in the corpus: source + 4 filters + agg + sink
# (depth 6) and the Exp-5 filter-chain variants; 8 leaves head-room while
# keeping the stage-3 scan short (it dominates step time).
MAX_DEPTH = 8

# Canonical slot layout: operator i of type t occupies a slot inside t's
# static range. Type-specific MLPs then run on static slices instead of
# masked full-width banks (see nn.apply_mlp_bank_slotted) — a 5x FLOP cut
# that is also the layout the Pallas kernel tiles on.
#   type id: SOURCE=0, FILTER=1, AGGREGATE=2, JOIN=3, SINK=4 (features.OP_TYPE_IDS)
SLOT_RANGES = (
    (0, 0, 3),  # up to 3 sources
    (1, 3, 7),  # up to 4 filters
    (2, 7, 9),  # up to 2 aggregations
    (3, 9, 11),  # up to 2 joins
    (4, 11, 12),  # 1 sink
)


class JointGraph(NamedTuple):
    """Padded joint graph; all fields are numpy/jnp arrays.

    Shapes below are for a single graph; batched graphs prepend a batch dim.
    """

    op_x: np.ndarray  # (MAX_OPS, OP_FEATURE_DIM) float32
    op_type: np.ndarray  # (MAX_OPS,) int32  in [0, N_OP_TYPES); padded rows are 0
    op_mask: np.ndarray  # (MAX_OPS,) float32 {0,1}
    op_depth: np.ndarray  # (MAX_OPS,) int32 topological depth; padded rows 0
    hw_x: np.ndarray  # (MAX_HW, HW_FEATURE_DIM) float32
    hw_mask: np.ndarray  # (MAX_HW,) float32 {0,1}
    a_flow: np.ndarray  # (MAX_OPS, MAX_OPS) float32; a_flow[u, v] = 1 iff u -> v
    a_place: np.ndarray  # (MAX_OPS, MAX_HW) float32; a_place[i, j] = 1 iff op i on host j

    @property
    def batched(self) -> bool:
        return self.op_x.ndim == 3


def _slot_assignment(query: Query) -> dict:
    """op_id -> canonical slot (inside its type's static range)."""
    base = {t: (start, stop) for (t, start, stop) in SLOT_RANGES}
    counts = {t: 0 for (t, _, _) in SLOT_RANGES}
    slots = {}
    for op in query.operators:
        t = F.op_type_id(op)
        start, stop = base[t]
        assert counts[t] < stop - start, (
            f"query exceeds slot capacity for type {t}: {query.describe()}"
        )
        slots[op.op_id] = start + counts[t]
        counts[t] += 1
    return slots


def build_graph(
    query: Query,
    cluster: Cluster,
    placement: Placement,
    max_ops: int = MAX_OPS,
    max_hw: int = MAX_HW,
) -> JointGraph:
    n_ops, n_hw = query.n_ops(), cluster.n_nodes()
    assert n_ops <= max_ops, f"query has {n_ops} ops > pad {max_ops}"
    assert n_hw <= max_hw, f"cluster has {n_hw} hosts > pad {max_hw}"

    op_x = np.zeros((max_ops, F.OP_FEATURE_DIM), dtype=np.float32)
    op_type = np.zeros((max_ops,), dtype=np.int32)
    op_mask = np.zeros((max_ops,), dtype=np.float32)
    op_depth = np.zeros((max_ops,), dtype=np.int32)
    hw_x = np.zeros((max_hw, F.HW_FEATURE_DIM), dtype=np.float32)
    hw_mask = np.zeros((max_hw,), dtype=np.float32)
    a_flow = np.zeros((max_ops, max_ops), dtype=np.float32)
    a_place = np.zeros((max_ops, max_hw), dtype=np.float32)

    # fill padded slots with their range's type id so slotted MLPs stay exact
    for t, start, stop in SLOT_RANGES:
        op_type[start:stop] = t

    slot = _slot_assignment(query)
    depths = query.depths()
    for op in query.operators:
        i = slot[op.op_id]
        op_x[i] = F.featurize_operator(op)
        op_type[i] = F.op_type_id(op)
        op_mask[i] = 1.0
        op_depth[i] = depths[op.op_id]
    for node in cluster.nodes:
        hw_x[node.node_id] = F.featurize_hardware(node)
        hw_mask[node.node_id] = 1.0
    for u, v in query.edges:
        a_flow[slot[u], slot[v]] = 1.0
    for i in range(n_ops):
        a_place[slot[i], placement.node_of(i)] = 1.0

    return JointGraph(
        op_x=op_x,
        op_type=op_type,
        op_mask=op_mask,
        op_depth=op_depth,
        hw_x=hw_x,
        hw_mask=hw_mask,
        a_flow=a_flow,
        a_place=a_place,
    )


def batch_graphs(graphs: List[JointGraph]) -> JointGraph:
    return JointGraph(*[np.stack([getattr(g, f) for g in graphs]) for f in JointGraph._fields])


# -- ablation transforms (Exp 7a) ----------------------------------------------


def drop_hardware(g: JointGraph) -> JointGraph:
    """Featurization ablation 1: operators only (no placement, no hardware)."""
    return g._replace(
        hw_mask=np.zeros_like(g.hw_mask),
        a_place=np.zeros_like(g.a_place),
        hw_x=np.zeros_like(g.hw_x),
    )


def drop_hw_features(g: JointGraph) -> JointGraph:
    """Featurization ablation 2: placement/co-location kept, hw features zeroed."""
    return g._replace(hw_x=np.zeros_like(g.hw_x))
