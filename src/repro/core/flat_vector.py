"""Flat-vector baseline (paper SVII, after Ganapathi et al. [16]).

The baseline encodes a placed query as ONE fixed-width vector: aggregate query
statistics (operator counts, mean selectivities, window sizes, event rates)
plus aggregate hardware statistics (mean/min/max of the cluster features).
Crucially — and this is the point the paper makes — the *structural* coupling
between individual operators and the hosts they are placed on cannot be
represented, so placement-sensitive cost effects are invisible to it.

The paper trains LightGBM on this vector; lightgbm is not available offline,
so the baseline regressor/classifier is an MLP trained with the identical
losses (MSLE / BCE) — if anything a stronger baseline than boosted trees on a
39-dim dense vector.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import nn
from repro.core.features import lognorm
from repro.dsps.generator import Trace
from repro.dsps.hardware import Cluster
from repro.dsps.placement import Placement
from repro.dsps.query import OpType, Query

FLAT_DIM = 39


def featurize_flat(query: Query, cluster: Cluster, placement: Placement) -> np.ndarray:
    v = np.zeros((FLAT_DIM,), dtype=np.float32)
    ops = query.operators
    srcs = [o for o in ops if o.op_type == OpType.SOURCE]
    filts = [o for o in ops if o.op_type == OpType.FILTER]
    joins = [o for o in ops if o.op_type == OpType.JOIN]
    aggs = [o for o in ops if o.op_type == OpType.AGGREGATE]

    # query-structure aggregates
    v[0] = len(ops) / 12.0
    v[1] = len(srcs) / 3.0
    v[2] = len(filts) / 4.0
    v[3] = len(joins) / 2.0
    v[4] = len(aggs) / 2.0
    # data aggregates
    rates = [o.event_rate for o in srcs]
    v[5] = lognorm(float(np.sum(rates)), "event_rate")
    v[6] = lognorm(float(np.max(rates)), "event_rate")
    widths = [o.tuple_width_in for o in srcs]
    v[7] = lognorm(float(np.mean(widths)), "tuple_width")
    mix = np.array(
        [sum(o.n_int for o in srcs), sum(o.n_double for o in srcs), sum(o.n_string for o in srcs)],
        dtype=np.float32,
    )
    v[8:11] = mix / max(mix.sum(), 1.0)
    # selectivity aggregates
    if filts:
        v[11] = lognorm(float(np.prod([o.selectivity for o in filts])), "selectivity")
        v[12] = lognorm(float(np.min([o.selectivity for o in filts])), "selectivity")
    if joins:
        v[13] = lognorm(float(np.mean([o.selectivity for o in joins])), "selectivity")
    if aggs:
        v[14] = lognorm(float(np.mean([o.selectivity for o in aggs])), "selectivity")
    # window aggregates over all stateful ops
    stateful = joins + aggs
    if stateful:
        counts = [o.window.size for o in stateful if o.window.policy == "count"]
        times = [o.window.size for o in stateful if o.window.policy == "time"]
        v[15] = lognorm(float(np.mean(counts)), "window_count") if counts else 0.0
        v[16] = lognorm(float(np.mean(times)), "window_time_s") if times else 0.0
        v[17] = float(np.mean([o.window.slide_ratio for o in stateful]))
        v[18] = float(np.mean([1.0 if o.window.wtype == "sliding" else 0.0 for o in stateful]))
        v[19] = float(np.mean([1.0 if o.window.policy == "count" else 0.0 for o in stateful]))
    # hardware aggregates over the *used* hosts (the placement's only trace)
    used = [cluster.node(n) for n in placement.used_nodes()]
    feats = np.array(
        [[h.cpu, h.ram_mb, h.bandwidth_mbps, h.latency_ms] for h in used], dtype=np.float64
    )
    keys = ["cpu", "ram_mb", "bandwidth_mbps", "latency_ms"]
    for j, k in enumerate(keys):
        v[20 + 3 * j + 0] = lognorm(float(feats[:, j].mean()), k)
        v[20 + 3 * j + 1] = lognorm(float(feats[:, j].min()), k)
        v[20 + 3 * j + 2] = lognorm(float(feats[:, j].max()), k)
    # co-location coarse stats (count-only; no structure)
    v[32] = len(used) / 8.0
    v[33] = len(ops) / max(len(used), 1) / 12.0
    n_remote = sum(
        1 for (a, b) in query.edges if placement.node_of(a) != placement.node_of(b)
    )
    v[34] = n_remote / 12.0
    v[35] = query.max_depth() / 12.0
    return v


def featurize_flat_traces(traces: List[Trace]) -> np.ndarray:
    return np.stack([featurize_flat(t.query, t.cluster, t.placement) for t in traces])


# -- the baseline model (MLP on the flat vector) ---------------------------------


@dataclass(frozen=True)
class FlatVectorConfig:
    hidden: int = 128
    n_layers: int = 3
    task: str = "regression"  # regression | classification


def init_flat_model(key: jax.Array, cfg: FlatVectorConfig) -> nn.Params:
    sizes = [FLAT_DIM] + [cfg.hidden] * (cfg.n_layers - 1) + [1]
    return nn.init_mlp(key, sizes)


def forward_flat(params: nn.Params, x: jax.Array) -> jax.Array:
    return nn.apply_mlp(params, x)[..., 0]
