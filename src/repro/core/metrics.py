"""Evaluation metrics: q-error (paper SVII) and classification accuracy."""

from __future__ import annotations

from typing import Dict, Sequence

import numpy as np

EPS = 1e-6


def qerror(y_true: np.ndarray, y_pred: np.ndarray) -> np.ndarray:
    """q(c, c_hat) = max(c/c_hat, c_hat/c) >= 1; 1 is a perfect estimate."""
    c = np.maximum(np.asarray(y_true, dtype=np.float64), EPS)
    ch = np.maximum(np.asarray(y_pred, dtype=np.float64), EPS)
    return np.maximum(c / ch, ch / c)


def qerror_summary(y_true: np.ndarray, y_pred: np.ndarray) -> Dict[str, float]:
    q = qerror(y_true, y_pred)
    return {
        "q50": float(np.median(q)),
        "q95": float(np.percentile(q, 95)),
        "q99": float(np.percentile(q, 99)),
        "mean": float(np.mean(q)),
        "n": int(q.size),
    }


def accuracy(y_true: np.ndarray, y_pred: np.ndarray) -> float:
    y = np.asarray(y_true).astype(np.int64)
    p = np.asarray(y_pred).astype(np.int64)
    return float(np.mean(y == p))


def balanced_indices(labels: Sequence[int], rng: np.random.Generator) -> np.ndarray:
    """Subsample indices so both binary classes are equally represented
    (the paper balances classification test sets)."""
    labels = np.asarray(labels).astype(np.int64)
    idx0 = np.flatnonzero(labels == 0)
    idx1 = np.flatnonzero(labels == 1)
    n = min(idx0.size, idx1.size)
    if n == 0:
        return np.arange(labels.size)
    sel = np.concatenate([rng.permutation(idx0)[:n], rng.permutation(idx1)[:n]])
    return np.sort(sel)
