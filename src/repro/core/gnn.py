"""COSTREAM GNN: node-type encoders + the novel 3-stage message passing.

Implements Algorithm 1 of the paper on the padded dense ``JointGraph``:

  stage 0  h_v   = MLP_{T(v)}(x_v)                         (type-specific encoders)
  stage 1  OPS->HW   : hosts absorb the states of the operators placed on them
  stage 2  HW->OPS   : operators absorb the (updated) state of their host
  stage 3  SOURCES->OPS: states flow along the logical data flow in topological
                        order (depth-level steps with masked updates)
  readout  sum over all node states -> MLP_out -> prediction

Following the paper's text, every update is
``h'_v = MLP'_{T(v)}(concat(h_v, sum_{u in children(v)} h'_u))``.

ONE engine serves every consumer (see docs/forward_engine.md): the shared
stage-1/2/3 core ``_stages123`` takes a static ``StagePlan`` describing how
the stage-3 data-flow sweep runs —

* ``scan``   — a ``lax.scan`` over all ``max_depth`` levels with dynamic
  depth-select (the generic fallback for arbitrary batches);
* ``sweep``  — the banded plan FUSED: all non-empty depth levels of a
  bucket (``graph.BatchBanding``) run as ONE ``kernels/mp_sweep`` call with
  the banding table baked in as compile-time constants.  This is the
  training/serving path whenever a banding is present and the update bank
  is 2-layer (kernel-fusable);
* ``banded`` — the unfused fallback of ``sweep``: one statically-banded
  ``mp_update`` step per level (kept for >2-layer, jnp-only update banks);
* ``exact``  — the placement-specialized sweep unrolled over one query's
  ``QueryStatic.updates`` (only the slots that carry an operator at each
  level are recomputed).

``GNNConfig.use_pallas`` routes every plan kind through ``kernels/banked_mlp``
(stages 0-2) and ``kernels/mp_sweep`` / ``kernels/mp_update`` (stage 3), and
the cross-query merged engine through ``kernels/seg_gather``; configs the
kernels cannot fuse raise loudly instead of silently falling back.

``apply_gnn_traditional`` is the Exp-7b ablation: K rounds of symmetric
neighbor aggregation with shared (non-type-specific ordering) updates.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import nn
from repro.core.features import HW_FEATURE_DIM, N_OP_TYPES, OP_FEATURE_DIM
from repro.core.graph import (
    MAX_DEPTH,
    SLOT_RANGES,
    BatchBanding,
    JointGraph,
    QueryStatic,
)


@dataclass(frozen=True)
class GNNConfig:
    hidden: int = 64
    enc_layers: int = 2
    update_layers: int = 2
    readout_layers: int = 2
    max_depth: int = MAX_DEPTH
    n_outputs: int = 1
    use_pallas: bool = False  # route banked MLPs through the Pallas kernel


def init_gnn(key: jax.Array, cfg: GNNConfig) -> nn.Params:
    ks = jax.random.split(key, 6)
    h = cfg.hidden

    def sizes(d_in: int, n_layers: int, d_out: int):
        return [d_in] + [h] * (n_layers - 1) + [d_out]

    return {
        "op_enc": nn.init_mlp_bank(ks[0], N_OP_TYPES, sizes(OP_FEATURE_DIM, cfg.enc_layers, h)),
        "hw_enc": nn.init_mlp(ks[1], sizes(HW_FEATURE_DIM, cfg.enc_layers, h)),
        "op_upd": nn.init_mlp_bank(ks[2], N_OP_TYPES, sizes(2 * h, cfg.update_layers, h)),
        "hw_upd": nn.init_mlp(ks[3], sizes(2 * h, cfg.update_layers, h)),
        "out": nn.init_mlp(ks[4], sizes(h, cfg.readout_layers, cfg.n_outputs)),
    }


def _require_fusable(params: nn.Params, what: str) -> None:
    """``use_pallas`` must fail loudly, never silently fall back to jnp.

    The Pallas banked-MLP / mp-update kernels fuse exactly two layers; configs
    with a different depth cannot be routed through them, and pretending they
    were would make ``use_pallas`` a lie (the bug this guard exists to kill).
    """
    n = len(params["layers"])
    if n != 2:
        raise NotImplementedError(
            f"GNNConfig.use_pallas=True but '{what}' has {n} layers; the Pallas "
            "kernels fuse exactly two (enc_layers=update_layers=2). Use a "
            "2-layer config or set use_pallas=False."
        )


def _apply_bank(params, x, cfg: GNNConfig, ranges=SLOT_RANGES):
    """Type-specific MLP over a slot layout (default: graph.SLOT_RANGES)."""
    if cfg.use_pallas:
        from repro.kernels.banked_mlp import ops as bank_ops

        _require_fusable(params, "banked MLP (op_enc/op_upd)")
        return bank_ops.banked_mlp_slotted(params, x, ranges)
    return nn.apply_mlp_bank_slotted(params, x, ranges)


def _apply_shared(params, x, cfg: GNNConfig, what: str):
    """Shared (non-type-specific) MLP, e.g. hw_enc / hw_upd.

    Under ``use_pallas`` this routes through the banked-MLP kernel as a
    single-type bank covering the whole node axis — one slot range spanning
    all rows — so the hardware-side stages run in the same fused VMEM pass as
    the operator banks instead of silently staying on the jnp path.
    """
    if cfg.use_pallas:
        from repro.kernels.banked_mlp import ops as bank_ops

        _require_fusable(params, what)
        bank = {
            "layers": [{"w": l["w"][None], "b": l["b"][None]} for l in params["layers"]]
        }
        return bank_ops.banked_mlp_slotted(bank, x, ((0, 0, x.shape[-2]),))
    return nn.apply_mlp(params, x)


# ---------------------------------------------------------------------------
# The unified stage engine.
# ---------------------------------------------------------------------------


class StagePlan(NamedTuple):
    """Static description of the stage-3 data-flow sweep (jit-cache safe).

    ``kind``:
      * ``"scan"``   — ``lax.scan`` over depths ``1..depth_max``, full row
        width, dynamic depth-select (generic batches without banding);
      * ``"sweep"``  — ALL of ``levels`` in one fused ``kernels/mp_sweep``
        call (the banding table as compile-time constants; one stage-3
        launch per forward on the kernel path).  Chosen over ``banded``
        whenever the update bank is 2-layer;
      * ``"banded"`` — unrolled over ``levels``; each level runs at its static
        ``row_span`` with a static ``parent_rows`` contraction bound
        (bucketed training batches, ``graph.batch_banding``);
      * ``"exact"``  — the placement-specialized sweep: the jnp path unrolls
        ``updates`` (per level, the exact ``(row, type, parent_rows)``
        tuples), the Pallas path walks ``levels``.

    ``levels`` entries are ``(d, row_span | None, slot_ranges, parent_rows |
    None)`` with *absolute* row indices; ``slot_ranges`` must tile the span.
    """

    kind: str
    depth_max: int = 0
    levels: Tuple = ()
    updates: Tuple = ()


def _clip_ranges(ranges, start: int, stop: int):
    """Restrict slot ranges to [start, stop); result tiles the span exactly."""
    out = []
    for t, a, b in ranges:
        a2, b2 = max(a, start), min(b, stop)
        if a2 < b2:
            out.append((t, a2, b2))
    return tuple(out)


def _banded_plan(banding: BatchBanding, ranges=SLOT_RANGES, kind: str = "banded") -> StagePlan:
    return StagePlan(
        kind,
        levels=tuple(
            (d, span, _clip_ranges(ranges, *span), p) for d, span, p in banding.levels
        ),
    )


def _sweep_fusable(params: nn.Params) -> bool:
    """The fused sweep (and its oracle twin) handle exactly 2-layer banks."""
    return len(params["op_upd"]["layers"]) == 2


def _bank_member(p: nn.Params, t: int) -> nn.Params:
    """Extract one type's MLP from a stacked bank (leading type axis)."""
    return {"layers": [{"w": l["w"][t], "b": l["b"][t]} for l in p["layers"]]}


def _dataflow_sweep(
    params, h, a_flow, op_depth, op_mask, cfg: GNNConfig, ranges, plan: StagePlan
):
    """Stage 3: SOURCES->OPS along the data flow, per the static plan.

    ``h``/``a_flow``/``op_depth`` are rank-polymorphic (``(N, .)`` single,
    ``(B, N, .)`` batched); the ``exact`` jnp branch is the one exception —
    it indexes candidate batches explicitly (the placed path's layout).
    """
    if plan.kind == "sweep":
        mask_vec = (
            op_mask[..., 0] if op_mask is not None else jnp.ones(h.shape[:-1], jnp.float32)
        )
        if cfg.use_pallas:
            # the whole banding table in ONE kernel launch (vs one per level)
            from repro.kernels.mp_sweep import ops as sweep_ops

            _require_fusable(params["op_upd"], "op_upd (stage-3 mp_sweep)")
            return sweep_ops.mp_sweep(
                params["op_upd"], h, a_flow, op_depth, mask_vec, plan.levels
            )
        # jnp path: the sweep oracle IS the old per-level banded loop, with
        # the same injected banked apply — bitwise-identical numerics
        from repro.kernels.mp_sweep.ref import mp_sweep_ref

        return mp_sweep_ref(
            params["op_upd"],
            h,
            a_flow,
            op_depth,
            mask_vec,
            plan.levels,
            apply_fn=nn.apply_mlp_bank_slotted,
        )
    if cfg.use_pallas:
        from repro.kernels.mp_update import ops as mp_ops

        _require_fusable(params["op_upd"], "op_upd (stage-3 mp_update)")
        mask_vec = (
            op_mask[..., 0] if op_mask is not None else jnp.ones(h.shape[:-1], jnp.float32)
        )
        if plan.kind == "scan":

            def step(hh, d):
                return (
                    mp_ops.mp_update(
                        params["op_upd"], hh, a_flow, op_depth, mask_vec, d, ranges
                    ),
                    None,
                )

            h, _ = jax.lax.scan(
                step, h, jnp.arange(1, plan.depth_max + 1, dtype=op_depth.dtype)
            )
            return h
        for d, span, level_ranges, parent_hi in plan.levels:
            h = mp_ops.mp_update(
                params["op_upd"],
                h,
                a_flow,
                op_depth,
                mask_vec,
                jnp.asarray(d, op_depth.dtype),
                level_ranges,
                row_span=span,
                parent_rows=parent_hi,
            )
        return h

    sel_mask = None if op_mask is None else op_mask[..., 0] > 0

    def full_step(hh, d):
        msg = jnp.swapaxes(a_flow, -1, -2) @ hh  # msg[v] = sum over parents u
        upd = _apply_bank(params["op_upd"], jnp.concatenate([hh, msg], axis=-1), cfg, ranges)
        sel = op_depth == d
        if sel_mask is not None:
            sel = sel & sel_mask
        return jnp.where(sel[..., None], upd, hh)

    if plan.kind == "scan":
        h, _ = jax.lax.scan(
            lambda hh, d: (full_step(hh, d), None),
            h,
            jnp.arange(1, plan.depth_max + 1, dtype=op_depth.dtype),
        )
        return h
    if plan.kind == "banded":
        # the kernel oracle owns the span geometry; the banked apply is
        # injected so >2-layer (unfusable, jnp-only) banks work too
        from repro.kernels.mp_update.ref import mp_update_ref

        mask_vec = (
            op_mask[..., 0] if op_mask is not None else jnp.ones(h.shape[:-1], jnp.float32)
        )
        for d, span, level_ranges, parent_hi in plan.levels:
            h = mp_update_ref(
                params["op_upd"],
                h,
                a_flow,
                op_depth,
                mask_vec,
                jnp.asarray(d, op_depth.dtype),
                level_ranges,
                row_span=span,
                parent_rows=parent_hi,
                apply_fn=nn.apply_mlp_bank_slotted,
            )
        return h
    assert plan.kind == "exact", plan.kind
    for level in plan.updates:
        cols = [s for s, _, _ in level]
        news = []
        for s, t, parents in level:
            msg = sum(h[:, p] for p in parents[1:]) + h[:, parents[0]]
            x = jnp.concatenate([h[:, s], msg], axis=-1)  # (B, 2H)
            news.append(nn.apply_mlp(_bank_member(params["op_upd"], t), x))
        h = h.at[:, jnp.asarray(cols)].set(jnp.stack(news, axis=1))
    return h


def _stages123(
    params: nn.Params,
    h_ops0: jax.Array,  # (..., O', H) per-graph states, or (O', H) shared skeleton
    h_hw0: jax.Array,  # (..., W', H) / (W', H) matching h_ops0
    a_place: jax.Array,  # (..., O', W'); a leading candidate axis when shared
    a_flow: jax.Array,  # (..., O', O') or shared (O', O')
    op_depth: jax.Array,  # (..., O') int
    cfg: GNNConfig,
    *,
    ranges,  # slot ranges (type, start, stop) in THIS layout
    plan: StagePlan,
    op_mask: Optional[jax.Array] = None,  # (..., O', 1) or None when no padded rows
    hw_mask: Optional[jax.Array] = None,  # (..., W', 1) or None when no padded rows
) -> jax.Array:
    """Stages 1-3 + readout: the single core behind every forward.

    Two calling conventions, told apart by rank: the *generic* one (training,
    bulk scoring) passes per-graph stage-0 states with the same batch rank as
    ``a_place``; the *placed* one passes the unbatched shared-skeleton states
    against a ``(B, O', W')`` candidate batch — stage-0 work is then reused
    across all candidates and only broadcast where a stage needs it.
    """
    shared_skeleton = h_ops0.ndim < a_place.ndim

    # stage 1: OPS -> HW
    if shared_skeleton:
        b = a_place.shape[0]
        msg_hw = jnp.einsum("bow,oh->bwh", a_place, h_ops0)
        hw_in = jnp.concatenate(
            [jnp.broadcast_to(h_hw0, (b,) + h_hw0.shape), msg_hw], axis=-1
        )
    else:
        msg_hw = jnp.einsum("...ow,...oh->...wh", a_place, h_ops0)
        hw_in = jnp.concatenate([jnp.broadcast_to(h_hw0, msg_hw.shape), msg_hw], axis=-1)
    h_hw = _apply_shared(params["hw_upd"], hw_in, cfg, "hw_upd")
    if hw_mask is not None:
        h_hw = h_hw * hw_mask

    # stage 2: HW -> OPS
    msg_ops = jnp.einsum("...ow,...wh->...oh", a_place, h_hw)
    if shared_skeleton:
        ops_in = jnp.concatenate(
            [jnp.broadcast_to(h_ops0, msg_ops.shape), msg_ops], axis=-1
        )
    else:
        ops_in = jnp.concatenate([h_ops0, msg_ops], axis=-1)
    h = _apply_bank(params["op_upd"], ops_in, cfg, ranges)
    if op_mask is not None:
        h = h * op_mask

    # stage 3: data-flow sweep per the static plan
    h = _dataflow_sweep(params, h, a_flow, op_depth, op_mask, cfg, ranges, plan)

    # readout: rows are pre-masked, sum over the node axes
    pooled = jnp.sum(h, axis=-2) + jnp.sum(h_hw, axis=-2)
    return nn.apply_mlp(params["out"], pooled)


def _trim_rows(g: JointGraph, rows: Tuple[int, ...]) -> JointGraph:
    """Statically gather ``rows`` out of the padded operator axis.

    The dropped rows hold no operator in ANY graph of the batch (the
    ``exact_banding`` trim contract): their states are masked to exact zero
    before every reduction, so removing them changes no prediction or
    gradient — it only removes their dense work.  Hardware rows stay
    untouched (MAX_HW is small and ``a_place`` columns are per-host).
    """
    idx = jnp.asarray(rows)
    return g._replace(
        op_x=jnp.take(g.op_x, idx, axis=-2),
        op_type=jnp.take(g.op_type, idx, axis=-1),
        op_mask=jnp.take(g.op_mask, idx, axis=-1),
        op_depth=jnp.take(g.op_depth, idx, axis=-1),
        a_flow=jnp.take(jnp.take(g.a_flow, idx, axis=-2), idx, axis=-1),
        a_place=jnp.take(g.a_place, idx, axis=-2),
    )


def apply_gnn_batch(
    params: nn.Params,
    g: JointGraph,
    cfg: GNNConfig,
    banding: Optional[BatchBanding] = None,
) -> jax.Array:
    """Forward for a padded graph (batch) -> (..., n_outputs).

    Rank-polymorphic: a single ``(N, .)`` graph or a ``(B, N, .)`` batch run
    the same code — banked MLPs execute ONCE across the whole padded batch
    (one launch per stage), not per-graph under vmap.  ``banding`` (from
    ``bucketing.batch_banding`` / ``exact_banding``, static per bucket or
    per signature set) replaces the full ``max_depth`` stage-3 scan with the
    FUSED depth sweep over its non-empty levels (``StagePlan("sweep")``: one
    ``kernels/mp_sweep`` call for the whole table; >2-layer update banks fall
    back to the per-level ``banded`` loop); a banding carrying a row trim
    additionally gathers the batch onto its all-graphs-active row subset and
    runs EVERY stage there (``banding.ranges`` are that layout's type runs).
    Without a banding the sweep falls back to the seed-equivalent full scan.
    ``cfg.use_pallas`` routes stages 0-2 through ``kernels/banked_mlp`` and
    stage 3 through ``kernels/mp_sweep``/``kernels/mp_update`` (see module
    docstring).
    """
    ranges = SLOT_RANGES
    if banding is not None and banding.rows is not None:
        g = _trim_rows(g, banding.rows)
        ranges = banding.ranges
    op_mask = g.op_mask[..., None]
    hw_mask = g.hw_mask[..., None]
    h_ops0 = _apply_bank(params["op_enc"], g.op_x, cfg, ranges) * op_mask
    h_hw0 = _apply_shared(params["hw_enc"], g.hw_x, cfg, "hw_enc") * hw_mask
    plan = (
        StagePlan("scan", depth_max=cfg.max_depth)
        if banding is None
        else _banded_plan(
            banding, ranges, kind="sweep" if _sweep_fusable(params) else "banded"
        )
    )
    return _stages123(
        params,
        h_ops0,
        h_hw0,
        g.a_place,
        g.a_flow,
        g.op_depth,
        cfg,
        ranges=ranges,
        plan=plan,
        op_mask=op_mask,
        hw_mask=hw_mask,
    )


def apply_gnn(
    params: nn.Params,
    g: JointGraph,
    cfg: GNNConfig,
    banding: Optional[BatchBanding] = None,
) -> jax.Array:
    """Forward pass for ONE graph -> (n_outputs,); same engine as the batch."""
    return apply_gnn_batch(params, g, cfg, banding)


def apply_gnn_stacked(
    params: nn.Params,
    g: JointGraph,
    cfg: GNNConfig,
    banding: Optional[BatchBanding] = None,
) -> jax.Array:
    """ONE forward for member-stacked params over a shared graph batch.

    ``params`` leaves carry a leading member axis (an ensemble's members, or
    several metrics' ensembles concatenated by ``serve.stacking.stack_metric_models``);
    returns ``(members, B)`` raw outputs.  The batch — including its banding
    plan — is shared across members, so a training step issues one stacked
    forward instead of one per member.
    """
    return jax.vmap(lambda p: apply_gnn_batch(p, g, cfg, banding))(params)[..., 0]


def validate_merged_parents(a_flow, max_parents: int, what: str = "skeleton stack") -> None:
    """Raise when any row's data-flow in-degree exceeds ``max_parents``.

    The merged engine's parent tables keep only the top ``max_parents``
    entries of each ``a_flow`` column (``argsort(-flow_in)[..., :P]``): a row
    with more parents would have them silently dropped and the stage-3 sums
    would be WRONG, not slow.  Host-side (concrete arrays only) — the
    estimator calls it at merged-group build time, and ``apply_gnn_merged``
    re-checks eager concrete inputs for direct callers.
    """
    indeg = np.asarray(a_flow).sum(axis=-2)
    worst = int(indeg.max(initial=0))
    if worst > max_parents:
        loc = tuple(int(v) for v in np.argwhere(indeg > max_parents)[0])
        raise ValueError(
            f"merged cross-query engine: {what} row {loc} has data-flow "
            f"in-degree {worst} > max_parents={max_parents}; the parent-table "
            "gather would silently drop parents and return wrong sums. Pass "
            "max_parents >= the stack's true maximum in-degree "
            "(a_flow.sum(axis=-2).max(), as serve.estimator derives it)."
        )


def apply_gnn_merged(
    params: nn.Params,
    skels: JointGraph,  # (S, N, .) stacked skeletons (``a_place`` ignored)
    skel_id: jax.Array,  # (B,) int: row -> skeleton
    a_place: jax.Array,  # (B, N, W) one-hot placement adjacency per row
    cfg: GNNConfig,
    banding: BatchBanding,
    max_parents: int = 2,
) -> jax.Array:
    """ONE member-stacked forward over candidates of S DISTINCT structures.

    The cross-query serving engine: a merged drain's rows reference their
    structure through ``skel_id`` instead of materializing per-row skeleton
    copies, and the graph's sparsity is static — every operator has at most
    ``max_parents`` data-flow parents and exactly one host — so the
    aggregations that the generic batched engine expresses as per-graph
    adjacency matmuls (batched tiny GEMMs, dispatch-bound on CPU backends)
    become gathers and W-unrolled masked sums:

      * stage 0 runs on the S unique skeletons and is *gathered* per row —
        candidates of one structure never re-encode its operators;
      * stage 1 (OPS->HW) is a per-row segment scatter-add: each host state
        accumulates the operator states placed on it;
      * stage 2 (HW->OPS) gathers each operator's single host state;
      * stage 3 levels gather each in-span row's ``max_parents`` parent
        states (per-skeleton parent tables, built once from ``a_flow``) and
        run the banked update at the banding's static ``row_span``.

    Numerically equal to ``apply_gnn_stacked`` on the expanded broadcast
    batch to float tolerance (same sums, different association — the
    mixed-stream parity tests pin it).  The gathers/scatters route through
    ``kernels/seg_gather`` (one-hot SpMM kernels on TPU, the very same
    take_along_axis / scatter-add formulations on the jnp ref lowering), so
    ``use_pallas`` configs are served by this engine too — the banked MLPs
    then run through ``kernels/banked_mlp`` like every other path.
    ``banding`` must come from ``bucketing.exact_banding_cached`` over
    ``skels`` (signature sets are padding-invariant, so it also covers every
    chunk of the batch).  Returns ``(members, B)`` raw outputs.
    """
    from repro.kernels.seg_gather import ops as seg_ops

    try:
        flow_host = np.asarray(skels.a_flow)  # concrete (eager) inputs only
    except Exception:  # traced under jit: the estimator validated at group build
        flow_host = None
    if flow_host is not None:
        validate_merged_parents(flow_host, max_parents)
    ranges = SLOT_RANGES
    if banding.rows is not None:
        skels = _trim_rows(skels, banding.rows)
        a_place = jnp.take(a_place, jnp.asarray(banding.rows), axis=-2)
        ranges = banding.ranges
    plan = _banded_plan(banding, ranges)
    n_hw = skels.hw_x.shape[-2]

    # static sparsity, derived once per trace: parent tables per skeleton
    # (columns of a_flow hold each row's parents) and one host per row
    flow_in = jnp.swapaxes(skels.a_flow, -1, -2)  # (S, N, N): [v, u] = u -> v
    pidx = jnp.argsort(-flow_in, axis=-1)[..., :max_parents]  # (S, N, P)
    pmask = jnp.take_along_axis(flow_in, pidx, axis=-1)  # (S, N, P) in {0,1}
    row_pidx = pidx[skel_id]  # (B, N, P)
    row_pmask = pmask[skel_id]  # (B, N, P)
    host = jnp.argmax(a_place, axis=-1)  # (B, N)
    placed = jnp.max(a_place, axis=-1)[..., None]  # (B, N, 1): 0 for padded rows
    op_mask_s = skels.op_mask[..., None]  # (S, N, 1)
    hw_mask_b = skels.hw_mask[skel_id][..., None]  # (B, W, 1)
    op_mask_b = op_mask_s[skel_id]  # (B, N, 1)
    depth_b = skels.op_depth[skel_id]  # (B, N)

    def member_fwd(pp):
        # stage 0 on the S skeletons only, gathered out per candidate row
        h_ops_s = _apply_bank(pp["op_enc"], skels.op_x, cfg, ranges) * op_mask_s
        h_hw_s = _apply_shared(pp["hw_enc"], skels.hw_x, cfg, "hw_enc") * skels.hw_mask[..., None]
        h0 = h_ops_s[skel_id]  # (B, N, H)
        hw0 = h_hw_s[skel_id]  # (B, W, H)

        # stage 1: hosts absorb their operators (segment scatter-add per row)
        msg_hw = seg_ops.segment_sum(h0 * placed, host, n_hw)  # (B, W, H)
        h_hw = _apply_shared(pp["hw_upd"], jnp.concatenate([hw0, msg_hw], -1), cfg, "hw_upd")
        h_hw = h_hw * hw_mask_b

        # stage 2: operators absorb their single host's state (gather, P=1)
        msg_ops = seg_ops.gather_sum(h_hw, host[..., None], placed)
        h = _apply_bank(pp["op_upd"], jnp.concatenate([h0, msg_ops], -1), cfg, ranges)
        h = h * op_mask_b

        # stage 3: banded levels; parents gathered, never contracted
        for d, (s, e), level_ranges, _ in plan.levels:
            msg = seg_ops.gather_sum(h, row_pidx[:, s:e], row_pmask[:, s:e])
            z = jnp.concatenate([h[:, s:e], msg], axis=-1)
            shifted = tuple((t, a - s, b - s) for t, a, b in level_ranges)
            upd = _apply_bank(pp["op_upd"], z, cfg, shifted)
            sel = ((depth_b[:, s:e] == d) & (op_mask_b[:, s:e, 0] > 0))[..., None]
            h = h.at[:, s:e].set(jnp.where(sel, upd, h[:, s:e]))

        pooled = jnp.sum(h, axis=-2) + jnp.sum(h_hw, axis=-2)
        return nn.apply_mlp(pp["out"], pooled)[..., 0]

    return jax.vmap(member_fwd)(params)


def apply_gnn_placed(
    params: nn.Params,
    skel: JointGraph,
    a_place: jax.Array,
    static: QueryStatic,
    cfg: GNNConfig,
) -> jax.Array:
    """Placement-batch forward: one query, ``(B, O, W)`` candidate placements.

    Numerically identical to ``apply_gnn_batch`` on the broadcast batch (the
    parity tests in tests/test_placement.py pin this), but exploits that every
    candidate shares the skeleton:

      * stage 0 encoders run ONCE on the unbatched skeleton (placement-
        invariant) and are broadcast, not recomputed per candidate;
      * the stage-3 data-flow sweep only touches depth levels the query
        actually has (``static.updates``): on the jnp path each level updates
        just the slots holding an operator at that depth (narrow matmuls); on
        the Pallas path each level is one fused ``mp_update`` launch.

    ``cfg.use_pallas`` is honored on every stage: the stage-0 encoders and
    stage-1/2 updates route through ``kernels/banked_mlp`` (the shared
    hardware MLPs as single-type banks) and the stage-3 sweep through
    ``kernels/mp_update``.  The kernel ops pick a lowering per backend —
    Pallas on TPU, the jnp oracle elsewhere, ``REPRO_PALLAS_INTERPRET=1``
    forces the interpreter (see ``kernels.active_lowering``).  The readout
    MLP stays jnp by design — one tiny dense GEMM with no banked/slotted
    structure for the kernels to fuse. Configs the kernels cannot fuse raise
    loudly instead of silently falling back (see ``_require_fusable``).
    """
    op_mask = skel.op_mask[:, None]  # (O,1)
    hw_mask = skel.hw_mask[:, None]  # (W,1)

    # stage 0: shared across candidates
    h_ops0 = _apply_bank(params["op_enc"], skel.op_x, cfg) * op_mask
    h_hw0 = _apply_shared(params["hw_enc"], skel.hw_x, cfg, "hw_enc") * hw_mask

    # full padded layout: no contiguous spans available, full-width levels
    plan = StagePlan(
        "exact",
        levels=tuple(
            (d, None, SLOT_RANGES, None)
            for d, level in enumerate(static.updates, start=1)
            if level
        ),
        updates=static.updates,
    )
    return _stages123(
        params,
        h_ops0,
        h_hw0,
        a_place,
        skel.a_flow,
        skel.op_depth,
        cfg,
        ranges=SLOT_RANGES,
        plan=plan,
        op_mask=op_mask,
        hw_mask=hw_mask,
    )


def _slot_type(slot: int) -> int:
    for t, start, stop in SLOT_RANGES:
        if start <= slot < stop:
            return t
    raise ValueError(f"slot {slot} outside SLOT_RANGES")


def _type_runs(order, offset: int = 0):
    """Maximal runs of equal node type over ``order`` as (type, start, stop)."""
    runs = []
    for i, s in enumerate(order):
        t = _slot_type(s)
        if runs and runs[-1][0] == t:
            runs[-1][2] = offset + i + 1
        else:
            runs.append([t, offset + i, offset + i + 1])
    return tuple(tuple(r) for r in runs)


def _trimmed_layout(static: QueryStatic):
    """Trace-time remap of the padded slot layout to active slots only,
    ordered by (depth, slot).

    Depth-major order makes every stage-3 level one CONTIGUOUS row span, so
    the Pallas ``mp_update`` can statically restrict each depth step to the
    rows it actually updates (``row_span``); within a level, slot order keeps
    same-type operators adjacent, so banked MLPs still see few type runs.
    Returns (order: slot ids, ranges: type runs over the whole order,
    updates: stage-3 updates remapped to row positions, levels: per nonempty
    depth level (d, (start, stop) row span, type runs inside the span,
    parent-row bound)).
    """
    depth_of = {s: 0 for s in static.active}
    for d, level in enumerate(static.updates, start=1):
        for s, _, _ in level:
            depth_of[s] = d
    order = sorted(static.active, key=lambda s: (depth_of[s], s))
    pos = {s: i for i, s in enumerate(order)}
    updates = tuple(
        tuple((pos[s], t, tuple(pos[p] for p in parents)) for s, t, parents in level)
        for level in static.updates
    )
    levels = []
    for d, level in enumerate(static.updates, start=1):
        if not level:
            continue
        rows = sorted(pos[s] for s, _, _ in level)
        assert rows == list(range(rows[0], rows[-1] + 1)), "level not contiguous"
        span = (rows[0], rows[-1] + 1)
        # parents have strictly smaller depth, i.e. strictly earlier rows
        levels.append((d, span, _type_runs(order[span[0] : span[1]], offset=span[0]), span[0]))
    return tuple(order), _type_runs(order), updates, tuple(levels)


def apply_gnn_placed_stacked(
    params: nn.Params,
    skel: JointGraph,
    a_place: jax.Array,
    static: QueryStatic,
    cfg: GNNConfig,
    n_hw: int,
    chunk: Optional[int] = None,
) -> jax.Array:
    """ONE forward for a whole stack of ensembles: ``params`` leaves carry a
    leading member axis (ensemble members x metrics, see
    ``serve.stacking.stack_metric_models``); returns ``(members, B)`` raw outputs.

    Beyond fusing the per-(metric, member) launches of ``apply_gnn_placed``
    into one vmapped call per stage, the restructure buys two things the
    per-metric path cannot express:

      * **slot trimming** — every stage runs on the ``len(static.active)``
        slots that hold a real operator and the ``n_hw`` real hosts, not the
        MAX_OPS/MAX_HW padded layout: the padded rows are provably zero
        (masked before every reduction), so dropping them changes no
        prediction while cutting the wasted dense FLOPs;
      * **batch chunking** — with all members resident at once, the candidate
        axis is scanned in ``chunk``-sized panels so the per-stage activation
        working set stays cache-resident on CPU-class backends (a no-op for
        ``B <= chunk``; pass ``chunk=0`` to disable).  ``chunk=None`` (the
        default) reads the active ``DispatchPolicy``'s ``score_chunk`` —
        callers that thread an explicit policy (the serving facade) pass the
        width themselves.

    ``cfg.use_pallas`` routes through the same kernels as
    ``apply_gnn_placed``, with the trimmed type runs as the kernels' slot
    layout and each stage-3 depth level as a static ``row_span`` for
    ``mp_update`` (the depth-major trimmed order makes levels contiguous).
    """
    if chunk is None:
        from repro.serve.policy import active_policy  # lazy: core never pulls serve at import

        chunk = active_policy().score_chunk
    order, ranges, updates, levels = _trimmed_layout(static)
    idx = jnp.asarray(order)
    op_x = skel.op_x[idx]  # (n, F)
    hw_x = skel.hw_x[:n_hw]  # (n_hw, F_hw)
    a_flow = skel.a_flow[idx][:, idx]  # (n, n)
    op_depth = skel.op_depth[idx]  # (n,)
    a_place = a_place[:, idx, :n_hw]  # (B, n, n_hw)
    B = a_place.shape[0]
    plan = StagePlan("exact", levels=levels, updates=updates)

    # stage 0 is placement-invariant: once per member, outside the chunk scan
    def stage0(pp):
        return (
            _apply_bank(pp["op_enc"], op_x, cfg, ranges),
            _apply_shared(pp["hw_enc"], hw_x, cfg, "hw_enc"),
        )

    h0_ops, h0_hw = jax.vmap(stage0)(params)  # (E, n, H), (E, n_hw, H)

    def member_fwd(pp, h_ops0, h_hw0, ap):
        return _stages123(
            pp, h_ops0, h_hw0, ap, a_flow, op_depth, cfg, ranges=ranges, plan=plan
        )[..., 0]

    fwd = jax.vmap(member_fwd, in_axes=(0, 0, 0, None))
    if chunk and B > chunk and B % chunk == 0:
        panels = a_place.reshape(B // chunk, chunk, *a_place.shape[1:])
        _, outs = jax.lax.scan(
            lambda carry, ap: (carry, fwd(params, h0_ops, h0_hw, ap)), None, panels
        )  # (B/chunk, E, chunk)
        return outs.transpose(1, 0, 2).reshape(outs.shape[1], B)
    return fwd(params, h0_ops, h0_hw, a_place)


# ---------------------------------------------------------------------------
# Exp 7b ablation: "traditional" message passing — every node is updated from
# all of its neighbors each round, regardless of node type and stage ordering.
# ---------------------------------------------------------------------------


def apply_gnn_traditional(
    params: nn.Params, g: JointGraph, cfg: GNNConfig, n_rounds: int = 3
) -> jax.Array:
    op_mask = g.op_mask[:, None]
    hw_mask = g.hw_mask[:, None]

    h_ops = _apply_bank(params["op_enc"], g.op_x, cfg) * op_mask
    h_hw = _apply_shared(params["hw_enc"], g.hw_x, cfg, "hw_enc") * hw_mask

    # symmetric adjacency: data flow (both directions) + placement (both ways)
    a_sym = g.a_flow + g.a_flow.T  # (O,O)

    def round_step(carry, _):
        h_o, h_w = carry
        msg_o = a_sym @ h_o + g.a_place @ h_w
        msg_w = g.a_place.T @ h_o
        h_o2 = (
            _apply_bank(params["op_upd"], jnp.concatenate([h_o, msg_o], axis=-1), cfg)
            * op_mask
        )
        h_w2 = (
            _apply_shared(params["hw_upd"], jnp.concatenate([h_w, msg_w], axis=-1), cfg, "hw_upd")
            * hw_mask
        )
        return (h_o2, h_w2), None

    (h_ops, h_hw), _ = jax.lax.scan(round_step, (h_ops, h_hw), None, length=n_rounds)
    pooled = jnp.sum(h_ops * op_mask, axis=0) + jnp.sum(h_hw * hw_mask, axis=0)
    return nn.apply_mlp(params["out"], pooled)
