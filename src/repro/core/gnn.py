"""COSTREAM GNN: node-type encoders + the novel 3-stage message passing.

Implements Algorithm 1 of the paper on the padded dense ``JointGraph``:

  stage 0  h_v   = MLP_{T(v)}(x_v)                         (type-specific encoders)
  stage 1  OPS->HW   : hosts absorb the states of the operators placed on them
  stage 2  HW->OPS   : operators absorb the (updated) state of their host
  stage 3  SOURCES->OPS: states flow along the logical data flow in topological
                        order (a lax.scan over depth levels with masked updates)
  readout  sum over all node states -> MLP_out -> prediction

Following the paper's text, every update is
``h'_v = MLP'_{T(v)}(concat(h_v, sum_{u in children(v)} h'_u))``.

``apply_gnn_traditional`` is the Exp-7b ablation: K rounds of symmetric
neighbor aggregation with shared (non-type-specific ordering) updates.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import nn
from repro.core.features import HW_FEATURE_DIM, N_OP_TYPES, OP_FEATURE_DIM
from repro.core.graph import MAX_DEPTH, SLOT_RANGES, JointGraph, QueryStatic


@dataclass(frozen=True)
class GNNConfig:
    hidden: int = 64
    enc_layers: int = 2
    update_layers: int = 2
    readout_layers: int = 2
    max_depth: int = MAX_DEPTH
    n_outputs: int = 1
    use_pallas: bool = False  # route banked MLPs through the Pallas kernel


def init_gnn(key: jax.Array, cfg: GNNConfig) -> nn.Params:
    ks = jax.random.split(key, 6)
    h = cfg.hidden

    def sizes(d_in: int, n_layers: int, d_out: int):
        return [d_in] + [h] * (n_layers - 1) + [d_out]

    return {
        "op_enc": nn.init_mlp_bank(ks[0], N_OP_TYPES, sizes(OP_FEATURE_DIM, cfg.enc_layers, h)),
        "hw_enc": nn.init_mlp(ks[1], sizes(HW_FEATURE_DIM, cfg.enc_layers, h)),
        "op_upd": nn.init_mlp_bank(ks[2], N_OP_TYPES, sizes(2 * h, cfg.update_layers, h)),
        "hw_upd": nn.init_mlp(ks[3], sizes(2 * h, cfg.update_layers, h)),
        "out": nn.init_mlp(ks[4], sizes(h, cfg.readout_layers, cfg.n_outputs)),
    }


def _apply_bank(params, x, cfg: GNNConfig):
    """Type-specific MLP over the canonical slot layout (see graph.SLOT_RANGES)."""
    if cfg.use_pallas:
        from repro.kernels.banked_mlp import ops as bank_ops

        return bank_ops.banked_mlp_slotted(params, x, SLOT_RANGES)
    return nn.apply_mlp_bank_slotted(params, x, SLOT_RANGES)


def apply_gnn(params: nn.Params, g: JointGraph, cfg: GNNConfig) -> jax.Array:
    """Forward pass for ONE graph -> (n_outputs,). vmap for batches."""
    op_mask = g.op_mask[:, None]  # (O,1)
    hw_mask = g.hw_mask[:, None]  # (W,1)

    # stage 0: type-specific encoders
    h_ops = _apply_bank(params["op_enc"], g.op_x, cfg) * op_mask
    h_hw = nn.apply_mlp(params["hw_enc"], g.hw_x) * hw_mask

    # stage 1: OPS -> HW (co-located operators sum into their host)
    msg_hw = g.a_place.T @ h_ops  # (W,H)
    h_hw = (
        nn.apply_mlp(params["hw_upd"], jnp.concatenate([h_hw, msg_hw], axis=-1)) * hw_mask
    )

    # stage 2: HW -> OPS (each operator reads its host's updated state)
    msg_ops = g.a_place @ h_hw  # (O,H)
    h_ops = (
        _apply_bank(params["op_upd"], jnp.concatenate([h_ops, msg_ops], axis=-1), cfg)
        * op_mask
    )

    # stage 3: SOURCES -> OPS along the data flow, one depth level at a time
    if cfg.use_pallas:
        from repro.kernels.mp_update import ops as mp_ops

        def depth_step(h, d):
            return (
                mp_ops.mp_update(
                    params["op_upd"], h, g.a_flow, g.op_depth, g.op_mask, d, SLOT_RANGES
                ),
                None,
            )

    else:

        def depth_step(h, d):
            msg = g.a_flow.T @ h  # msg[v] = sum over parents u of h[u]
            upd = _apply_bank(params["op_upd"], jnp.concatenate([h, msg], axis=-1), cfg)
            sel = ((g.op_depth == d) & (g.op_mask > 0))[:, None]
            return jnp.where(sel, upd, h), None

    h_ops, _ = jax.lax.scan(
        depth_step, h_ops, jnp.arange(1, cfg.max_depth + 1, dtype=g.op_depth.dtype)
    )

    # readout: sum over all (masked) node states
    pooled = jnp.sum(h_ops * op_mask, axis=0) + jnp.sum(h_hw * hw_mask, axis=0)
    return nn.apply_mlp(params["out"], pooled)


def apply_gnn_batch(params: nn.Params, g: JointGraph, cfg: GNNConfig) -> jax.Array:
    """(B, ...) graphs -> (B, n_outputs)."""
    return jax.vmap(lambda gg: apply_gnn(params, gg, cfg))(g)


def _bank_member(p: nn.Params, t: int) -> nn.Params:
    """Extract one type's MLP from a stacked bank (leading type axis)."""
    return {"layers": [{"w": l["w"][t], "b": l["b"][t]} for l in p["layers"]]}


def apply_gnn_placed(
    params: nn.Params,
    skel: JointGraph,
    a_place: jax.Array,
    static: QueryStatic,
    cfg: GNNConfig,
) -> jax.Array:
    """Placement-batch forward: one query, ``(B, O, W)`` candidate placements.

    Numerically identical to ``apply_gnn_batch`` on the broadcast batch (the
    parity tests in tests/test_placement.py pin this), but exploits that every
    candidate shares the skeleton:

      * stage 0 encoders run ONCE on the unbatched skeleton (placement-
        invariant) and are broadcast, not recomputed per candidate;
      * the stage-3 data-flow sweep is unrolled over ``static.updates``,
        touching only the slots that hold an operator at each depth level —
        O(n_ops) narrow matmuls instead of O(MAX_DEPTH * MAX_OPS) masked ones,
        and depth levels past the query's true depth (provable no-ops) vanish.

    Always uses the jnp banked MLPs; ``cfg.use_pallas`` only routes the
    generic per-graph path through the kernels.
    """
    op_mask = skel.op_mask[:, None]  # (O,1)
    hw_mask = skel.hw_mask[:, None]  # (W,1)
    b = a_place.shape[0]

    # stage 0: shared across candidates
    h_ops0 = nn.apply_mlp_bank_slotted(params["op_enc"], skel.op_x, SLOT_RANGES) * op_mask
    h_hw0 = nn.apply_mlp(params["hw_enc"], skel.hw_x) * hw_mask

    # stage 1: OPS -> HW per candidate
    msg_hw = jnp.einsum("bow,oh->bwh", a_place, h_ops0)
    h_hw = (
        nn.apply_mlp(
            params["hw_upd"],
            jnp.concatenate([jnp.broadcast_to(h_hw0, (b,) + h_hw0.shape), msg_hw], axis=-1),
        )
        * hw_mask
    )

    # stage 2: HW -> OPS per candidate
    msg_ops = jnp.einsum("bow,bwh->boh", a_place, h_hw)
    h = (
        nn.apply_mlp_bank_slotted(
            params["op_upd"],
            jnp.concatenate([jnp.broadcast_to(h_ops0, (b,) + h_ops0.shape), msg_ops], axis=-1),
            SLOT_RANGES,
        )
        * op_mask
    )

    # stage 3: data-flow sweep, unrolled over the static structure
    for level in static.updates:
        cols = [s for s, _, _ in level]
        news = []
        for s, t, parents in level:
            msg = sum(h[:, p] for p in parents[1:]) + h[:, parents[0]]
            x = jnp.concatenate([h[:, s], msg], axis=-1)  # (B, 2H)
            news.append(nn.apply_mlp(_bank_member(params["op_upd"], t), x))
        h = h.at[:, jnp.asarray(cols)].set(jnp.stack(news, axis=1))

    pooled = jnp.sum(h, axis=1) + jnp.sum(h_hw, axis=1)  # rows are pre-masked
    return nn.apply_mlp(params["out"], pooled)


# ---------------------------------------------------------------------------
# Exp 7b ablation: "traditional" message passing — every node is updated from
# all of its neighbors each round, regardless of node type and stage ordering.
# ---------------------------------------------------------------------------


def apply_gnn_traditional(
    params: nn.Params, g: JointGraph, cfg: GNNConfig, n_rounds: int = 3
) -> jax.Array:
    op_mask = g.op_mask[:, None]
    hw_mask = g.hw_mask[:, None]

    h_ops = _apply_bank(params["op_enc"], g.op_x, cfg) * op_mask
    h_hw = nn.apply_mlp(params["hw_enc"], g.hw_x) * hw_mask

    # symmetric adjacency: data flow (both directions) + placement (both ways)
    a_sym = g.a_flow + g.a_flow.T  # (O,O)

    def round_step(carry, _):
        h_o, h_w = carry
        msg_o = a_sym @ h_o + g.a_place @ h_w
        msg_w = g.a_place.T @ h_o
        h_o2 = (
            _apply_bank(params["op_upd"], jnp.concatenate([h_o, msg_o], axis=-1), cfg)
            * op_mask
        )
        h_w2 = (
            nn.apply_mlp(params["hw_upd"], jnp.concatenate([h_w, msg_w], axis=-1)) * hw_mask
        )
        return (h_o2, h_w2), None

    (h_ops, h_hw), _ = jax.lax.scan(round_step, (h_ops, h_hw), None, length=n_rounds)
    pooled = jnp.sum(h_ops * op_mask, axis=0) + jnp.sum(h_hw * hw_mask, axis=0)
    return nn.apply_mlp(params["out"], pooled)
