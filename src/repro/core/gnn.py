"""COSTREAM GNN: node-type encoders + the novel 3-stage message passing.

Implements Algorithm 1 of the paper on the padded dense ``JointGraph``:

  stage 0  h_v   = MLP_{T(v)}(x_v)                         (type-specific encoders)
  stage 1  OPS->HW   : hosts absorb the states of the operators placed on them
  stage 2  HW->OPS   : operators absorb the (updated) state of their host
  stage 3  SOURCES->OPS: states flow along the logical data flow in topological
                        order (a lax.scan over depth levels with masked updates)
  readout  sum over all node states -> MLP_out -> prediction

Following the paper's text, every update is
``h'_v = MLP'_{T(v)}(concat(h_v, sum_{u in children(v)} h'_u))``.

``apply_gnn_traditional`` is the Exp-7b ablation: K rounds of symmetric
neighbor aggregation with shared (non-type-specific ordering) updates.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import nn
from repro.core.features import HW_FEATURE_DIM, N_OP_TYPES, OP_FEATURE_DIM
from repro.core.graph import MAX_DEPTH, SLOT_RANGES, JointGraph, QueryStatic


@dataclass(frozen=True)
class GNNConfig:
    hidden: int = 64
    enc_layers: int = 2
    update_layers: int = 2
    readout_layers: int = 2
    max_depth: int = MAX_DEPTH
    n_outputs: int = 1
    use_pallas: bool = False  # route banked MLPs through the Pallas kernel


def init_gnn(key: jax.Array, cfg: GNNConfig) -> nn.Params:
    ks = jax.random.split(key, 6)
    h = cfg.hidden

    def sizes(d_in: int, n_layers: int, d_out: int):
        return [d_in] + [h] * (n_layers - 1) + [d_out]

    return {
        "op_enc": nn.init_mlp_bank(ks[0], N_OP_TYPES, sizes(OP_FEATURE_DIM, cfg.enc_layers, h)),
        "hw_enc": nn.init_mlp(ks[1], sizes(HW_FEATURE_DIM, cfg.enc_layers, h)),
        "op_upd": nn.init_mlp_bank(ks[2], N_OP_TYPES, sizes(2 * h, cfg.update_layers, h)),
        "hw_upd": nn.init_mlp(ks[3], sizes(2 * h, cfg.update_layers, h)),
        "out": nn.init_mlp(ks[4], sizes(h, cfg.readout_layers, cfg.n_outputs)),
    }


def _require_fusable(params: nn.Params, what: str) -> None:
    """``use_pallas`` must fail loudly, never silently fall back to jnp.

    The Pallas banked-MLP / mp-update kernels fuse exactly two layers; configs
    with a different depth cannot be routed through them, and pretending they
    were would make ``use_pallas`` a lie (the bug this guard exists to kill).
    """
    n = len(params["layers"])
    if n != 2:
        raise NotImplementedError(
            f"GNNConfig.use_pallas=True but '{what}' has {n} layers; the Pallas "
            "kernels fuse exactly two (enc_layers=update_layers=2). Use a "
            "2-layer config or set use_pallas=False."
        )


def _apply_bank(params, x, cfg: GNNConfig, ranges=SLOT_RANGES):
    """Type-specific MLP over a slot layout (default: graph.SLOT_RANGES)."""
    if cfg.use_pallas:
        from repro.kernels.banked_mlp import ops as bank_ops

        _require_fusable(params, "banked MLP (op_enc/op_upd)")
        return bank_ops.banked_mlp_slotted(params, x, ranges)
    return nn.apply_mlp_bank_slotted(params, x, ranges)


def _apply_shared(params, x, cfg: GNNConfig, what: str):
    """Shared (non-type-specific) MLP, e.g. hw_enc / hw_upd.

    Under ``use_pallas`` this routes through the banked-MLP kernel as a
    single-type bank covering the whole node axis — one slot range spanning
    all rows — so the hardware-side stages run in the same fused VMEM pass as
    the operator banks instead of silently staying on the jnp path.
    """
    if cfg.use_pallas:
        from repro.kernels.banked_mlp import ops as bank_ops

        _require_fusable(params, what)
        bank = {
            "layers": [{"w": l["w"][None], "b": l["b"][None]} for l in params["layers"]]
        }
        return bank_ops.banked_mlp_slotted(bank, x, ((0, 0, x.shape[-2]),))
    return nn.apply_mlp(params, x)


def apply_gnn(params: nn.Params, g: JointGraph, cfg: GNNConfig) -> jax.Array:
    """Forward pass for ONE graph -> (n_outputs,). vmap for batches."""
    op_mask = g.op_mask[:, None]  # (O,1)
    hw_mask = g.hw_mask[:, None]  # (W,1)

    # stage 0: type-specific encoders
    h_ops = _apply_bank(params["op_enc"], g.op_x, cfg) * op_mask
    h_hw = _apply_shared(params["hw_enc"], g.hw_x, cfg, "hw_enc") * hw_mask

    # stage 1: OPS -> HW (co-located operators sum into their host)
    msg_hw = g.a_place.T @ h_ops  # (W,H)
    h_hw = (
        _apply_shared(params["hw_upd"], jnp.concatenate([h_hw, msg_hw], axis=-1), cfg, "hw_upd")
        * hw_mask
    )

    # stage 2: HW -> OPS (each operator reads its host's updated state)
    msg_ops = g.a_place @ h_hw  # (O,H)
    h_ops = (
        _apply_bank(params["op_upd"], jnp.concatenate([h_ops, msg_ops], axis=-1), cfg)
        * op_mask
    )

    # stage 3: SOURCES -> OPS along the data flow, one depth level at a time
    if cfg.use_pallas:
        from repro.kernels.mp_update import ops as mp_ops

        def depth_step(h, d):
            return (
                mp_ops.mp_update(
                    params["op_upd"], h, g.a_flow, g.op_depth, g.op_mask, d, SLOT_RANGES
                ),
                None,
            )

    else:

        def depth_step(h, d):
            msg = g.a_flow.T @ h  # msg[v] = sum over parents u of h[u]
            upd = _apply_bank(params["op_upd"], jnp.concatenate([h, msg], axis=-1), cfg)
            sel = ((g.op_depth == d) & (g.op_mask > 0))[:, None]
            return jnp.where(sel, upd, h), None

    h_ops, _ = jax.lax.scan(
        depth_step, h_ops, jnp.arange(1, cfg.max_depth + 1, dtype=g.op_depth.dtype)
    )

    # readout: sum over all (masked) node states
    pooled = jnp.sum(h_ops * op_mask, axis=0) + jnp.sum(h_hw * hw_mask, axis=0)
    return nn.apply_mlp(params["out"], pooled)


def apply_gnn_batch(params: nn.Params, g: JointGraph, cfg: GNNConfig) -> jax.Array:
    """(B, ...) graphs -> (B, n_outputs)."""
    return jax.vmap(lambda gg: apply_gnn(params, gg, cfg))(g)


def _bank_member(p: nn.Params, t: int) -> nn.Params:
    """Extract one type's MLP from a stacked bank (leading type axis)."""
    return {"layers": [{"w": l["w"][t], "b": l["b"][t]} for l in p["layers"]]}


def _placed_stages123(
    params: nn.Params,
    h_ops0: jax.Array,  # (O', H) stage-0 operator states (any slot layout)
    h_hw0: jax.Array,  # (W', H) stage-0 host states
    a_place: jax.Array,  # (B, O', W')
    a_flow: jax.Array,  # (O', O')
    op_depth: jax.Array,  # (O',) int
    updates,  # per-depth ((row, type, parent_rows), ...) in THIS layout
    ranges,  # slot ranges (type, start, stop) in THIS layout
    cfg: GNNConfig,
    op_mask: Optional[jax.Array] = None,  # (O',1) or None when no padded rows
    hw_mask: Optional[jax.Array] = None,  # (W',1) or None when no padded rows
    pallas_levels=None,  # per-depth (d, row_span, level_ranges) for mp_update
) -> jax.Array:
    """Stages 1-3 + readout of the placement-specialized forward.

    Layout-agnostic core shared by ``apply_gnn_placed`` (full padded slot
    layout) and ``apply_gnn_placed_stacked`` (trimmed active-slot layout,
    where the masks are provably all-ones and passed as None).  Under
    ``use_pallas``, stage 3 walks ``pallas_levels``: one fused ``mp_update``
    launch per depth level, statically restricted to ``row_span`` when the
    layout makes each level contiguous (the depth-sorted trimmed layout).
    """
    b = a_place.shape[0]

    # stage 1: OPS -> HW per candidate
    msg_hw = jnp.einsum("bow,oh->bwh", a_place, h_ops0)
    h_hw = _apply_shared(
        params["hw_upd"],
        jnp.concatenate([jnp.broadcast_to(h_hw0, (b,) + h_hw0.shape), msg_hw], axis=-1),
        cfg,
        "hw_upd",
    )
    if hw_mask is not None:
        h_hw = h_hw * hw_mask

    # stage 2: HW -> OPS per candidate
    msg_ops = jnp.einsum("bow,bwh->boh", a_place, h_hw)
    h = _apply_bank(
        params["op_upd"],
        jnp.concatenate([jnp.broadcast_to(h_ops0, (b,) + h_ops0.shape), msg_ops], axis=-1),
        cfg,
        ranges,
    )
    if op_mask is not None:
        h = h * op_mask

    # stage 3: data-flow sweep over only the depth levels the query has
    if cfg.use_pallas:
        from repro.kernels.mp_update import ops as mp_ops

        _require_fusable(params["op_upd"], "op_upd (stage-3 mp_update)")
        mask_vec = op_mask[:, 0] if op_mask is not None else jnp.ones_like(op_depth, jnp.float32)
        if pallas_levels is None:  # full layout: no contiguous spans available
            pallas_levels = tuple(
                (d, None, ranges, None) for d, level in enumerate(updates, start=1) if level
            )
        for d, span, level_ranges, parent_hi in pallas_levels:
            h = mp_ops.mp_update(
                params["op_upd"],
                h,
                a_flow,
                op_depth,
                mask_vec,
                jnp.asarray(d, op_depth.dtype),
                level_ranges,
                row_span=span,
                parent_rows=parent_hi,
            )
    else:
        for level in updates:
            cols = [s for s, _, _ in level]
            news = []
            for s, t, parents in level:
                msg = sum(h[:, p] for p in parents[1:]) + h[:, parents[0]]
                x = jnp.concatenate([h[:, s], msg], axis=-1)  # (B, 2H)
                news.append(nn.apply_mlp(_bank_member(params["op_upd"], t), x))
            h = h.at[:, jnp.asarray(cols)].set(jnp.stack(news, axis=1))

    pooled = jnp.sum(h, axis=1) + jnp.sum(h_hw, axis=1)  # rows are pre-masked
    return nn.apply_mlp(params["out"], pooled)


def apply_gnn_placed(
    params: nn.Params,
    skel: JointGraph,
    a_place: jax.Array,
    static: QueryStatic,
    cfg: GNNConfig,
) -> jax.Array:
    """Placement-batch forward: one query, ``(B, O, W)`` candidate placements.

    Numerically identical to ``apply_gnn_batch`` on the broadcast batch (the
    parity tests in tests/test_placement.py pin this), but exploits that every
    candidate shares the skeleton:

      * stage 0 encoders run ONCE on the unbatched skeleton (placement-
        invariant) and are broadcast, not recomputed per candidate;
      * the stage-3 data-flow sweep only touches depth levels the query
        actually has (``static.updates``): on the jnp path each level updates
        just the slots holding an operator at that depth (narrow matmuls); on
        the Pallas path each level is one fused ``mp_update`` launch.

    ``cfg.use_pallas`` is honored on every stage: the stage-0 encoders and
    stage-1/2 updates route through ``kernels/banked_mlp`` (the shared
    hardware MLPs as single-type banks) and the stage-3 sweep through
    ``kernels/mp_update``.  The kernel ops pick a lowering per backend —
    Pallas on TPU, the jnp oracle elsewhere, ``REPRO_PALLAS_INTERPRET=1``
    forces the interpreter (see ``kernels.active_lowering``).  The readout
    MLP stays jnp by design — one tiny dense GEMM with no banked/slotted
    structure for the kernels to fuse. Configs the kernels cannot fuse raise
    loudly instead of silently falling back (see ``_require_fusable``).
    """
    op_mask = skel.op_mask[:, None]  # (O,1)
    hw_mask = skel.hw_mask[:, None]  # (W,1)

    # stage 0: shared across candidates
    h_ops0 = _apply_bank(params["op_enc"], skel.op_x, cfg) * op_mask
    h_hw0 = _apply_shared(params["hw_enc"], skel.hw_x, cfg, "hw_enc") * hw_mask

    return _placed_stages123(
        params,
        h_ops0,
        h_hw0,
        a_place,
        skel.a_flow,
        skel.op_depth,
        static.updates,
        SLOT_RANGES,
        cfg,
        op_mask=op_mask,
        hw_mask=hw_mask,
    )


def _slot_type(slot: int) -> int:
    for t, start, stop in SLOT_RANGES:
        if start <= slot < stop:
            return t
    raise ValueError(f"slot {slot} outside SLOT_RANGES")


def _type_runs(order, offset: int = 0):
    """Maximal runs of equal node type over ``order`` as (type, start, stop)."""
    runs = []
    for i, s in enumerate(order):
        t = _slot_type(s)
        if runs and runs[-1][0] == t:
            runs[-1][2] = offset + i + 1
        else:
            runs.append([t, offset + i, offset + i + 1])
    return tuple(tuple(r) for r in runs)


def _trimmed_layout(static: QueryStatic):
    """Trace-time remap of the padded slot layout to active slots only,
    ordered by (depth, slot).

    Depth-major order makes every stage-3 level one CONTIGUOUS row span, so
    the Pallas ``mp_update`` can statically restrict each depth step to the
    rows it actually updates (``row_span``); within a level, slot order keeps
    same-type operators adjacent, so banked MLPs still see few type runs.
    Returns (order: slot ids, ranges: type runs over the whole order,
    updates: stage-3 updates remapped to row positions, levels: per nonempty
    depth level (d, (start, stop) row span, type runs inside the span)).
    """
    depth_of = {s: 0 for s in static.active}
    for d, level in enumerate(static.updates, start=1):
        for s, _, _ in level:
            depth_of[s] = d
    order = sorted(static.active, key=lambda s: (depth_of[s], s))
    pos = {s: i for i, s in enumerate(order)}
    updates = tuple(
        tuple((pos[s], t, tuple(pos[p] for p in parents)) for s, t, parents in level)
        for level in static.updates
    )
    levels = []
    for d, level in enumerate(static.updates, start=1):
        if not level:
            continue
        rows = sorted(pos[s] for s, _, _ in level)
        assert rows == list(range(rows[0], rows[-1] + 1)), "level not contiguous"
        span = (rows[0], rows[-1] + 1)
        # parents have strictly smaller depth, i.e. strictly earlier rows
        levels.append((d, span, _type_runs(order[span[0] : span[1]], offset=span[0]), span[0]))
    return tuple(order), _type_runs(order), updates, tuple(levels)


def apply_gnn_placed_stacked(
    params: nn.Params,
    skel: JointGraph,
    a_place: jax.Array,
    static: QueryStatic,
    cfg: GNNConfig,
    n_hw: int,
    chunk: int = 256,
) -> jax.Array:
    """ONE forward for a whole stack of ensembles: ``params`` leaves carry a
    leading member axis (ensemble members x metrics, see
    ``model.stack_metric_models``); returns ``(members, B)`` raw outputs.

    Beyond fusing the per-(metric, member) launches of ``apply_gnn_placed``
    into one vmapped call per stage, the restructure buys two things the
    per-metric path cannot express:

      * **slot trimming** — every stage runs on the ``len(static.active)``
        slots that hold a real operator and the ``n_hw`` real hosts, not the
        MAX_OPS/MAX_HW padded layout: the padded rows are provably zero
        (masked before every reduction), so dropping them changes no
        prediction while cutting the wasted dense FLOPs;
      * **batch chunking** — with all members resident at once, the candidate
        axis is scanned in ``chunk``-sized panels so the per-stage activation
        working set stays cache-resident on CPU-class backends (a no-op for
        ``B <= chunk``; pass ``chunk=0`` to disable).

    ``cfg.use_pallas`` routes through the same kernels as
    ``apply_gnn_placed``, with the trimmed type runs as the kernels' slot
    layout and each stage-3 depth level as a static ``row_span`` for
    ``mp_update`` (the depth-major trimmed order makes levels contiguous).
    """
    order, ranges, updates, levels = _trimmed_layout(static)
    idx = jnp.asarray(order)
    op_x = skel.op_x[idx]  # (n, F)
    hw_x = skel.hw_x[:n_hw]  # (n_hw, F_hw)
    a_flow = skel.a_flow[idx][:, idx]  # (n, n)
    op_depth = skel.op_depth[idx]  # (n,)
    a_place = a_place[:, idx, :n_hw]  # (B, n, n_hw)
    B = a_place.shape[0]

    # stage 0 is placement-invariant: once per member, outside the chunk scan
    def stage0(pp):
        return (
            _apply_bank(pp["op_enc"], op_x, cfg, ranges),
            _apply_shared(pp["hw_enc"], hw_x, cfg, "hw_enc"),
        )

    h0_ops, h0_hw = jax.vmap(stage0)(params)  # (E, n, H), (E, n_hw, H)

    def member_fwd(pp, h_ops0, h_hw0, ap):
        return _placed_stages123(
            pp, h_ops0, h_hw0, ap, a_flow, op_depth, updates, ranges, cfg,
            pallas_levels=levels,
        )[..., 0]

    fwd = jax.vmap(member_fwd, in_axes=(0, 0, 0, None))
    if chunk and B > chunk and B % chunk == 0:
        panels = a_place.reshape(B // chunk, chunk, *a_place.shape[1:])
        _, outs = jax.lax.scan(
            lambda carry, ap: (carry, fwd(params, h0_ops, h0_hw, ap)), None, panels
        )  # (B/chunk, E, chunk)
        return outs.transpose(1, 0, 2).reshape(outs.shape[1], B)
    return fwd(params, h0_ops, h0_hw, a_place)


# ---------------------------------------------------------------------------
# Exp 7b ablation: "traditional" message passing — every node is updated from
# all of its neighbors each round, regardless of node type and stage ordering.
# ---------------------------------------------------------------------------


def apply_gnn_traditional(
    params: nn.Params, g: JointGraph, cfg: GNNConfig, n_rounds: int = 3
) -> jax.Array:
    op_mask = g.op_mask[:, None]
    hw_mask = g.hw_mask[:, None]

    h_ops = _apply_bank(params["op_enc"], g.op_x, cfg) * op_mask
    h_hw = _apply_shared(params["hw_enc"], g.hw_x, cfg, "hw_enc") * hw_mask

    # symmetric adjacency: data flow (both directions) + placement (both ways)
    a_sym = g.a_flow + g.a_flow.T  # (O,O)

    def round_step(carry, _):
        h_o, h_w = carry
        msg_o = a_sym @ h_o + g.a_place @ h_w
        msg_w = g.a_place.T @ h_o
        h_o2 = (
            _apply_bank(params["op_upd"], jnp.concatenate([h_o, msg_o], axis=-1), cfg)
            * op_mask
        )
        h_w2 = (
            _apply_shared(params["hw_upd"], jnp.concatenate([h_w, msg_w], axis=-1), cfg, "hw_upd")
            * hw_mask
        )
        return (h_o2, h_w2), None

    (h_ops, h_hw), _ = jax.lax.scan(round_step, (h_ops, h_hw), None, length=n_rounds)
    pooled = jnp.sum(h_ops * op_mask, axis=0) + jnp.sum(h_hw * hw_mask, axis=0)
    return nn.apply_mlp(params["out"], pooled)
