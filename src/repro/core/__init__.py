"""COSTREAM core: the paper's primary contribution in JAX.

Joint operator-resource graphs, transferable featurization, the 3-stage
message-passing GNN, per-metric cost models with ensembles, evaluation
metrics, and the flat-vector baseline.
"""

from repro.core.features import (
    OP_FEATURE_DIM,
    HW_FEATURE_DIM,
    N_OP_TYPES,
    featurize_operator,
    featurize_hardware,
)
from repro.core.graph import (
    MAX_OPS,
    MAX_HW,
    BatchBanding,
    BroadcastBatch,
    JointGraph,
    QueryStatic,
    batch_banding,
    batch_signature,
    broadcast_skeleton,
    bucket_size,
    build_a_place_batch,
    build_graph,
    build_graph_batch,
    build_graph_skeleton,
    batch_graphs,
    drop_hardware,
    drop_hw_features,
    exact_banding,
    exact_banding_cached,
    merge_graph_batches,
    pad_batch,
    query_static,
)
from repro.core.gnn import (
    GNNConfig,
    init_gnn,
    apply_gnn,
    apply_gnn_batch,
    apply_gnn_placed,
    apply_gnn_stacked,
    apply_gnn_traditional,
)
from repro.core.model import (
    ALL_METRICS,
    REGRESSION_METRICS,
    CLASSIFICATION_METRICS,
    CostModelConfig,
    init_cost_model,
    forward_ensemble,
    ensemble_loss,
    loss_fn,
    msle_loss,
    bce_loss,
    label_array,
)
from repro.core.metrics import qerror, qerror_summary, accuracy, balanced_indices
from repro.core.flat_vector import (
    FLAT_DIM,
    FlatVectorConfig,
    featurize_flat,
    featurize_flat_traces,
    init_flat_model,
    forward_flat,
)

__all__ = [k for k in dir() if not k.startswith("_")]
