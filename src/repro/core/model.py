"""COSTREAM cost models: per-metric GNNs + losses + ensembles (paper SIV-A).

Five metrics, five separately trained models sharing the GNN architecture:
regression (throughput, processing latency, e2e latency) trained with MSLE in
log1p space, classification (backpressure occurrence, query success) trained
with BCE. Ensembles of E members (different init seeds) are vmap-stacked;
inference takes the mean (regression) / majority vote (classification) exactly
as SIV-A prescribes.
"""

from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import nn
from repro.core.gnn import (
    GNNConfig,
    apply_gnn_batch,
    apply_gnn_placed,
    apply_gnn_traditional,
    init_gnn,
)
from repro.core.graph import JointGraph, QueryStatic

REGRESSION_METRICS = ("throughput", "latency_p", "latency_e")
CLASSIFICATION_METRICS = ("backpressure", "success")
ALL_METRICS = REGRESSION_METRICS + CLASSIFICATION_METRICS


@dataclass(frozen=True)
class CostModelConfig:
    metric: str = "latency_p"
    gnn: GNNConfig = GNNConfig()
    n_ensemble: int = 3
    traditional_mp: bool = False  # Exp-7b ablation

    @property
    def task(self) -> str:
        if self.metric in REGRESSION_METRICS:
            return "regression"
        assert self.metric in CLASSIFICATION_METRICS, self.metric
        return "classification"


def init_cost_model(key: jax.Array, cfg: CostModelConfig) -> nn.Params:
    """Ensemble params: every leaf gets a leading (n_ensemble,) axis."""
    keys = jax.random.split(key, cfg.n_ensemble)
    return jax.vmap(lambda k: init_gnn(k, cfg.gnn))(keys)


def _forward_single(params, g: JointGraph, cfg: CostModelConfig) -> jax.Array:
    if cfg.traditional_mp:
        out = jax.vmap(lambda gg: apply_gnn_traditional(params, gg, cfg.gnn))(g)
    else:
        out = apply_gnn_batch(params, g, cfg.gnn)
    return out[..., 0]  # (B,)


def forward_ensemble(params, g: JointGraph, cfg: CostModelConfig) -> jax.Array:
    """(E-stacked params, batch of graphs) -> raw outputs (E, B).

    Raw output is log1p(cost) for regression, a logit for classification.
    """
    return jax.vmap(lambda p: _forward_single(p, g, cfg))(params)


# -- losses ---------------------------------------------------------------------


def msle_loss(raw: jax.Array, y: jax.Array) -> jax.Array:
    """Mean squared logarithmic error; ``raw`` already lives in log1p space."""
    return jnp.mean(jnp.square(raw - jnp.log1p(y)))


def bce_loss(raw: jax.Array, y: jax.Array) -> jax.Array:
    """Binary cross-entropy with logits."""
    return jnp.mean(
        jnp.maximum(raw, 0.0) - raw * y + jnp.log1p(jnp.exp(-jnp.abs(raw)))
    )


def loss_fn(cfg: CostModelConfig) -> Callable[[jax.Array, jax.Array], jax.Array]:
    return msle_loss if cfg.task == "regression" else bce_loss


def ensemble_loss(params, g: JointGraph, y: jax.Array, cfg: CostModelConfig) -> jax.Array:
    """Sum of member losses (members are independent; grads don't mix)."""
    raw = forward_ensemble(params, g, cfg)  # (E, B)
    per_member = jax.vmap(lambda r: loss_fn(cfg)(r, y))(raw)
    return jnp.sum(per_member)


# -- inference --------------------------------------------------------------------


from functools import lru_cache


@lru_cache(maxsize=64)
def _jitted_forward(cfg: CostModelConfig):
    return jax.jit(lambda p, g: forward_ensemble(p, g, cfg))


def _ensemble_vote(raw: np.ndarray, cfg: CostModelConfig) -> np.ndarray:
    """(E, B) raw outputs -> cost-space prediction (paper SIV-A).

    regression: mean over members of expm1(raw); classification: majority vote
    over thresholded member probabilities -> {0,1}.
    """
    if cfg.task == "regression":
        return np.mean(np.expm1(raw), axis=0).clip(min=0.0)
    votes = (raw > 0.0).astype(np.int64)  # logit > 0 <=> p > 0.5
    return (votes.sum(axis=0) * 2 > votes.shape[0]).astype(np.int64)


def predict(params, g: JointGraph, cfg: CostModelConfig) -> np.ndarray:
    """Ensemble prediction in *cost space* for a batch of graphs."""
    return _ensemble_vote(np.asarray(_jitted_forward(cfg)(params, g)), cfg)


@lru_cache(maxsize=256)
def _jitted_placed_forward(cfg: CostModelConfig, static: QueryStatic):
    def f(p, skel, a_place):
        return jax.vmap(lambda pp: apply_gnn_placed(pp, skel, a_place, static, cfg.gnn)[..., 0])(p)

    return jax.jit(f)


def predict_placements(
    params, skel: JointGraph, a_place: jax.Array, static: QueryStatic, cfg: CostModelConfig
) -> np.ndarray:
    """Ensemble prediction over candidate placements of ONE query.

    ``skel`` is the shared unbatched skeleton, ``a_place`` the ``(B, O, W)``
    placement adjacencies.  Numerically equivalent to ``predict`` on the
    broadcast batch, via the query-specialized forward (jit-cached per
    (config, query-structure) pair).  Not available for ``traditional_mp``
    ablation models — those don't have the 3-stage structure the
    specialization exploits; callers fall back to ``predict``.
    """
    assert not cfg.traditional_mp, "use predict() for traditional_mp models"
    raw = np.asarray(_jitted_placed_forward(cfg, static)(params, skel, a_place))
    return _ensemble_vote(raw, cfg)


def predict_metrics(
    models: Dict[str, Tuple[object, CostModelConfig]], g: JointGraph
) -> Dict[str, np.ndarray]:
    """Score ONE shared graph batch with several per-metric ensembles.

    The placement optimizer's fast path: ``g`` is transferred/donated to the
    device once and every requested ensemble (target + success/backpressure
    filters) runs over the same resident batch, instead of rebuilding and
    re-transferring the batch per metric.  Each metric keeps its own jitted
    forward (configs differ), but all of them share ``g``'s buffers.
    """
    g = jax.tree_util.tree_map(jnp.asarray, g)
    return {metric: predict(params, g, cfg) for metric, (params, cfg) in models.items()}


def predict_proba(params, g: JointGraph, cfg: CostModelConfig) -> np.ndarray:
    raw = np.asarray(_jitted_forward(cfg)(params, g))
    assert cfg.task == "classification"
    return 1.0 / (1.0 + np.exp(-raw)).mean(axis=0)


def label_array(traces, metric: str) -> np.ndarray:
    return np.asarray([t.labels.as_dict()[metric] for t in traces], dtype=np.float32)
