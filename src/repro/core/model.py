"""COSTREAM cost models: per-metric GNNs + losses + ensembles (paper SIV-A).

Five metrics, five separately trained models sharing the GNN architecture:
regression (throughput, processing latency, e2e latency) trained with MSLE in
log1p space, classification (backpressure occurrence, query success) trained
with BCE. Ensembles of E members (different init seeds) are vmap-stacked;
inference takes the mean (regression) / majority vote (classification) exactly
as SIV-A prescribes.

Since repro 0.7 this module is the NUMERIC CORE only: configs, init, the
ensemble forward, and the losses.  Everything serving-flavored moved out —
inference voting and multi-metric stacking live in ``repro.serve.stacking``,
and the one inference surface is ``repro.serve.CostEstimator`` (docs/api.md;
the interim ``predict_*`` deprecation shims were removed at the 0.7 horizon).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import nn
from repro.core.gnn import (
    GNNConfig,
    apply_gnn_batch,
    apply_gnn_stacked,
    apply_gnn_traditional,
    init_gnn,
)
from repro.core.graph import BatchBanding, JointGraph

REGRESSION_METRICS = ("throughput", "latency_p", "latency_e")
CLASSIFICATION_METRICS = ("backpressure", "success")
ALL_METRICS = REGRESSION_METRICS + CLASSIFICATION_METRICS


@dataclass(frozen=True)
class CostModelConfig:
    metric: str = "latency_p"
    gnn: GNNConfig = GNNConfig()
    n_ensemble: int = 3
    traditional_mp: bool = False  # Exp-7b ablation

    @property
    def task(self) -> str:
        if self.metric in REGRESSION_METRICS:
            return "regression"
        assert self.metric in CLASSIFICATION_METRICS, self.metric
        return "classification"


def init_cost_model(key: jax.Array, cfg: CostModelConfig) -> nn.Params:
    """Ensemble params: every leaf gets a leading (n_ensemble,) axis."""
    keys = jax.random.split(key, cfg.n_ensemble)
    return jax.vmap(lambda k: init_gnn(k, cfg.gnn))(keys)


def _forward_single(params, g: JointGraph, cfg: CostModelConfig) -> jax.Array:
    if cfg.traditional_mp:
        out = jax.vmap(lambda gg: apply_gnn_traditional(params, gg, cfg.gnn))(g)
    else:
        out = apply_gnn_batch(params, g, cfg.gnn)
    return out[..., 0]  # (B,)


def forward_ensemble(
    params,
    g: JointGraph,
    cfg: CostModelConfig,
    banding: Optional[BatchBanding] = None,
) -> jax.Array:
    """(E-stacked params, batch of graphs) -> raw outputs (E, B).

    Raw output is log1p(cost) for regression, a logit for classification.
    One stacked engine forward evaluates every member (``gnn.apply_gnn_stacked``
    — the member axis rides the same launch per stage, it is not one forward
    per member); ``banding`` is the bucket's static stage-3 plan from
    ``graph.batch_banding`` (None: full-depth scan, valid for any batch).
    The ``traditional_mp`` ablation lacks the 3-stage structure the engine
    exploits and keeps its per-graph path.
    """
    if cfg.traditional_mp:
        return jax.vmap(lambda p: _forward_single(p, g, cfg))(params)
    return apply_gnn_stacked(params, g, cfg.gnn, banding)


# -- losses ---------------------------------------------------------------------


def msle_loss(raw: jax.Array, y: jax.Array) -> jax.Array:
    """Mean squared logarithmic error; ``raw`` already lives in log1p space."""
    return jnp.mean(jnp.square(raw - jnp.log1p(y)))


def bce_loss(raw: jax.Array, y: jax.Array) -> jax.Array:
    """Binary cross-entropy with logits."""
    return jnp.mean(
        jnp.maximum(raw, 0.0) - raw * y + jnp.log1p(jnp.exp(-jnp.abs(raw)))
    )


def loss_fn(cfg: CostModelConfig) -> Callable[[jax.Array, jax.Array], jax.Array]:
    return msle_loss if cfg.task == "regression" else bce_loss


def ensemble_loss(
    params,
    g: JointGraph,
    y: jax.Array,
    cfg: CostModelConfig,
    banding: Optional[BatchBanding] = None,
) -> jax.Array:
    """Sum of member losses (members are independent; grads don't mix)."""
    raw = forward_ensemble(params, g, cfg, banding)  # (E, B)
    per_member = jax.vmap(lambda r: loss_fn(cfg)(r, y))(raw)
    return jnp.sum(per_member)


def label_array(traces, metric: str) -> np.ndarray:
    return np.asarray([t.labels.as_dict()[metric] for t in traces], dtype=np.float32)
