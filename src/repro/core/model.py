"""COSTREAM cost models: per-metric GNNs + losses + ensembles (paper SIV-A).

Five metrics, five separately trained models sharing the GNN architecture:
regression (throughput, processing latency, e2e latency) trained with MSLE in
log1p space, classification (backpressure occurrence, query success) trained
with BCE. Ensembles of E members (different init seeds) are vmap-stacked;
inference takes the mean (regression) / majority vote (classification) exactly
as SIV-A prescribes.
"""

from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Callable, Dict, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import nn
from repro.kernels import active_lowering
from repro.core.gnn import (
    GNNConfig,
    apply_gnn_batch,
    apply_gnn_placed,
    apply_gnn_placed_stacked,
    apply_gnn_stacked,
    apply_gnn_traditional,
    init_gnn,
)
from repro.core.graph import BatchBanding, JointGraph, QueryStatic

REGRESSION_METRICS = ("throughput", "latency_p", "latency_e")
CLASSIFICATION_METRICS = ("backpressure", "success")
ALL_METRICS = REGRESSION_METRICS + CLASSIFICATION_METRICS


@dataclass(frozen=True)
class CostModelConfig:
    metric: str = "latency_p"
    gnn: GNNConfig = GNNConfig()
    n_ensemble: int = 3
    traditional_mp: bool = False  # Exp-7b ablation

    @property
    def task(self) -> str:
        if self.metric in REGRESSION_METRICS:
            return "regression"
        assert self.metric in CLASSIFICATION_METRICS, self.metric
        return "classification"


def init_cost_model(key: jax.Array, cfg: CostModelConfig) -> nn.Params:
    """Ensemble params: every leaf gets a leading (n_ensemble,) axis."""
    keys = jax.random.split(key, cfg.n_ensemble)
    return jax.vmap(lambda k: init_gnn(k, cfg.gnn))(keys)


def _forward_single(params, g: JointGraph, cfg: CostModelConfig) -> jax.Array:
    if cfg.traditional_mp:
        out = jax.vmap(lambda gg: apply_gnn_traditional(params, gg, cfg.gnn))(g)
    else:
        out = apply_gnn_batch(params, g, cfg.gnn)
    return out[..., 0]  # (B,)


def forward_ensemble(
    params,
    g: JointGraph,
    cfg: CostModelConfig,
    banding: Optional[BatchBanding] = None,
) -> jax.Array:
    """(E-stacked params, batch of graphs) -> raw outputs (E, B).

    Raw output is log1p(cost) for regression, a logit for classification.
    One stacked engine forward evaluates every member (``gnn.apply_gnn_stacked``
    — the member axis rides the same launch per stage, it is not one forward
    per member); ``banding`` is the bucket's static stage-3 plan from
    ``graph.batch_banding`` (None: full-depth scan, valid for any batch).
    The ``traditional_mp`` ablation lacks the 3-stage structure the engine
    exploits and keeps its per-graph path.
    """
    if cfg.traditional_mp:
        return jax.vmap(lambda p: _forward_single(p, g, cfg))(params)
    return apply_gnn_stacked(params, g, cfg.gnn, banding)


# -- losses ---------------------------------------------------------------------


def msle_loss(raw: jax.Array, y: jax.Array) -> jax.Array:
    """Mean squared logarithmic error; ``raw`` already lives in log1p space."""
    return jnp.mean(jnp.square(raw - jnp.log1p(y)))


def bce_loss(raw: jax.Array, y: jax.Array) -> jax.Array:
    """Binary cross-entropy with logits."""
    return jnp.mean(
        jnp.maximum(raw, 0.0) - raw * y + jnp.log1p(jnp.exp(-jnp.abs(raw)))
    )


def loss_fn(cfg: CostModelConfig) -> Callable[[jax.Array, jax.Array], jax.Array]:
    return msle_loss if cfg.task == "regression" else bce_loss


def ensemble_loss(
    params,
    g: JointGraph,
    y: jax.Array,
    cfg: CostModelConfig,
    banding: Optional[BatchBanding] = None,
) -> jax.Array:
    """Sum of member losses (members are independent; grads don't mix)."""
    raw = forward_ensemble(params, g, cfg, banding)  # (E, B)
    per_member = jax.vmap(lambda r: loss_fn(cfg)(r, y))(raw)
    return jnp.sum(per_member)


# -- inference --------------------------------------------------------------------


from functools import lru_cache


# every cached factory below takes the kernels' active lowering as part of
# its key: the lowering is read at trace time, so without it a flipped
# REPRO_PALLAS_INTERPRET after the first call would silently reuse stale traces


@lru_cache(maxsize=64)
def _jitted_forward(cfg: CostModelConfig, lowering: str = "ref"):
    return jax.jit(lambda p, g: forward_ensemble(p, g, cfg))


def _ensemble_vote(raw: np.ndarray, cfg: CostModelConfig) -> np.ndarray:
    """(E, B) raw outputs -> cost-space prediction (paper SIV-A).

    regression: mean over members of expm1(raw); classification: majority vote
    over thresholded member probabilities -> {0,1}.
    """
    if cfg.task == "regression":
        return np.mean(np.expm1(raw), axis=0).clip(min=0.0)
    votes = (raw > 0.0).astype(np.int64)  # logit > 0 <=> p > 0.5
    return (votes.sum(axis=0) * 2 > votes.shape[0]).astype(np.int64)


def predict(params, g: JointGraph, cfg: CostModelConfig) -> np.ndarray:
    """Ensemble prediction in *cost space* for a batch of graphs."""
    raw = _jitted_forward(cfg, active_lowering())(params, g)
    return _ensemble_vote(np.asarray(raw), cfg)


# -- fused multi-metric ensembles -------------------------------------------------
#
# The per-metric GNNs share one architecture (paper SIV-A: same GNNConfig,
# different training targets), so their ensemble params are shape-identical
# pytrees with a leading (E,) member axis.  Stacking them along that axis
# turns "one forward per (metric, member)" into ONE vmapped forward whose
# leading axis is sum(E_m) — a single kernel launch per GNN stage instead of
# len(metrics) * E launches, which is where placement scoring spends its time
# (dispatch overhead dominates these small graphs).


class StackedEnsembles(NamedTuple):
    """Per-metric ensembles fused along the leading member axis.

    ``params`` leaves have shape ``(sum of member counts, ...)``; metric ``m``
    owns rows ``[offsets[i], offsets[i] + sizes[i])``.  Hashable-free (holds
    arrays), so it is passed positionally into jitted forwards that are cached
    on the shared ``GNNConfig`` instead.
    """

    params: object  # pytree, leaves stacked along axis 0
    metrics: Tuple[str, ...]
    cfgs: Tuple[CostModelConfig, ...]
    sizes: Tuple[int, ...]  # members per metric, in ``metrics`` order


def stack_metric_models(
    models: Dict[str, Tuple[object, CostModelConfig]],
    metrics: Optional[Sequence[str]] = None,
) -> StackedEnsembles:
    """Fuse several per-metric (params, cfg) ensembles into one stack.

    Requires every model to share the same ``GNNConfig`` and ``traditional_mp``
    flag (the forwards must be structurally identical to share a trace);
    raises ``ValueError`` otherwise so callers can fall back to the per-metric
    loop explicitly.  Member counts may differ — leaves are concatenated, not
    stacked, so metric i contributes ``sizes[i]`` rows.
    """
    names = tuple(metrics) if metrics is not None else tuple(models)
    assert names, "no metrics to stack"
    cfgs = tuple(models[m][1] for m in names)
    for c in cfgs[1:]:
        if c.gnn != cfgs[0].gnn or c.traditional_mp != cfgs[0].traditional_mp:
            raise ValueError(
                "cannot fuse metric ensembles with differing GNN configs: "
                f"{cfgs[0].metric}={cfgs[0].gnn} vs {c.metric}={c.gnn} "
                f"(traditional_mp {cfgs[0].traditional_mp} vs {c.traditional_mp})"
            )
    sizes = []
    for m in names:
        leaf = jax.tree_util.tree_leaves(models[m][0])[0]
        sizes.append(int(leaf.shape[0]))
    stacked = jax.tree_util.tree_map(
        lambda *leaves: jnp.concatenate([jnp.asarray(l) for l in leaves], axis=0),
        *[models[m][0] for m in names],
    )
    return StackedEnsembles(stacked, names, cfgs, tuple(sizes))


def _split_votes(raw: np.ndarray, stacked: StackedEnsembles) -> Dict[str, np.ndarray]:
    """(sum_E, B) fused raw outputs -> per-metric cost-space predictions."""
    out, off = {}, 0
    for m, cfg, sz in zip(stacked.metrics, stacked.cfgs, stacked.sizes):
        out[m] = _ensemble_vote(raw[off : off + sz], cfg)
        off += sz
    return out


@lru_cache(maxsize=64)
def _jitted_forward_stacked(gnn: GNNConfig, traditional_mp: bool, lowering: str = "ref"):
    # metric only selects the loss/vote, never the forward; any metric works
    cfg = CostModelConfig(metric="latency_p", gnn=gnn, traditional_mp=traditional_mp)
    return jax.jit(lambda p, g: forward_ensemble(p, g, cfg))


@lru_cache(maxsize=256)
def _jitted_placed_forward_stacked(
    gnn: GNNConfig, static: QueryStatic, n_hw: int, lowering: str = "ref"
):
    def f(p, skel, a_place):
        return apply_gnn_placed_stacked(p, skel, a_place, static, gnn, n_hw)

    return jax.jit(f)


def predict_placements_fused(
    stacked: StackedEnsembles, skel: JointGraph, a_place: jax.Array, static: QueryStatic
) -> Dict[str, np.ndarray]:
    """All metrics' ensembles over one query's candidate placements, fused.

    One jitted ``apply_gnn_placed_stacked`` call evaluates every (metric,
    member) pair in a single launch per GNN stage, on the trimmed active-slot
    layout; the raw ``(sum_E, B)`` block is then split back per metric and
    voted exactly like ``predict_placements`` (the stacked-vs-loop
    equivalence test pins this to float tolerance).
    """
    assert not stacked.cfgs[0].traditional_mp, "use predict() for traditional_mp models"
    n_hw = int(np.asarray(skel.hw_mask).sum())
    fwd = _jitted_placed_forward_stacked(
        stacked.cfgs[0].gnn, static, n_hw, active_lowering()
    )
    return _split_votes(np.asarray(fwd(stacked.params, skel, a_place)), stacked)


@lru_cache(maxsize=256)
def _jitted_placed_forward(cfg: CostModelConfig, static: QueryStatic, lowering: str = "ref"):
    def f(p, skel, a_place):
        return jax.vmap(lambda pp: apply_gnn_placed(pp, skel, a_place, static, cfg.gnn)[..., 0])(p)

    return jax.jit(f)


def predict_placements(
    params, skel: JointGraph, a_place: jax.Array, static: QueryStatic, cfg: CostModelConfig
) -> np.ndarray:
    """Ensemble prediction over candidate placements of ONE query.

    ``skel`` is the shared unbatched skeleton, ``a_place`` the ``(B, O, W)``
    placement adjacencies.  Numerically equivalent to ``predict`` on the
    broadcast batch, via the query-specialized forward (jit-cached per
    (config, query-structure) pair).  Not available for ``traditional_mp``
    ablation models — those don't have the 3-stage structure the
    specialization exploits; callers fall back to ``predict``.
    """
    assert not cfg.traditional_mp, "use predict() for traditional_mp models"
    fwd = _jitted_placed_forward(cfg, static, active_lowering())
    return _ensemble_vote(np.asarray(fwd(params, skel, a_place)), cfg)


def predict_metrics(
    models: Dict[str, Tuple[object, CostModelConfig]], g: JointGraph
) -> Dict[str, np.ndarray]:
    """Score ONE shared graph batch with several per-metric ensembles.

    The generic multi-metric path: ``g`` is transferred to the device once and
    every requested ensemble (target + success/backpressure filters) runs over
    the same resident batch.  When the per-metric GNN configs are
    shape-identical (the COSTREAM default — same architecture, different
    training targets) the ensembles are additionally fused into ONE stacked
    vmapped forward (see ``stack_metric_models``): a single launch per GNN
    stage instead of one forward per (metric, member).  Heterogeneous configs
    fall back to a per-metric loop over the shared batch.
    """
    g = jax.tree_util.tree_map(jnp.asarray, g)
    try:
        stacked = stack_metric_models(models)
    except ValueError:  # mixed architectures: per-metric forwards, shared batch
        return {m: predict(params, g, cfg) for m, (params, cfg) in models.items()}
    fwd = _jitted_forward_stacked(
        stacked.cfgs[0].gnn, stacked.cfgs[0].traditional_mp, active_lowering()
    )
    return _split_votes(np.asarray(fwd(stacked.params, g)), stacked)


def predict_proba(params, g: JointGraph, cfg: CostModelConfig) -> np.ndarray:
    raw = np.asarray(_jitted_forward(cfg)(params, g))
    assert cfg.task == "classification"
    return 1.0 / (1.0 + np.exp(-raw)).mean(axis=0)


def label_array(traces, metric: str) -> np.ndarray:
    return np.asarray([t.labels.as_dict()[metric] for t in traces], dtype=np.float32)
