"""COSTREAM cost models: per-metric GNNs + losses + ensembles (paper SIV-A).

Five metrics, five separately trained models sharing the GNN architecture:
regression (throughput, processing latency, e2e latency) trained with MSLE in
log1p space, classification (backpressure occurrence, query success) trained
with BCE. Ensembles of E members (different init seeds) are vmap-stacked;
inference takes the mean (regression) / majority vote (classification) exactly
as SIV-A prescribes.
"""

from __future__ import annotations

import warnings
from dataclasses import dataclass
from typing import Callable, Dict, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import nn
from repro.core.gnn import (
    GNNConfig,
    apply_gnn_batch,
    apply_gnn_stacked,
    apply_gnn_traditional,
    init_gnn,
)
from repro.core.graph import BatchBanding, JointGraph, QueryStatic

REGRESSION_METRICS = ("throughput", "latency_p", "latency_e")
CLASSIFICATION_METRICS = ("backpressure", "success")
ALL_METRICS = REGRESSION_METRICS + CLASSIFICATION_METRICS


@dataclass(frozen=True)
class CostModelConfig:
    metric: str = "latency_p"
    gnn: GNNConfig = GNNConfig()
    n_ensemble: int = 3
    traditional_mp: bool = False  # Exp-7b ablation

    @property
    def task(self) -> str:
        if self.metric in REGRESSION_METRICS:
            return "regression"
        assert self.metric in CLASSIFICATION_METRICS, self.metric
        return "classification"


def init_cost_model(key: jax.Array, cfg: CostModelConfig) -> nn.Params:
    """Ensemble params: every leaf gets a leading (n_ensemble,) axis."""
    keys = jax.random.split(key, cfg.n_ensemble)
    return jax.vmap(lambda k: init_gnn(k, cfg.gnn))(keys)


def _forward_single(params, g: JointGraph, cfg: CostModelConfig) -> jax.Array:
    if cfg.traditional_mp:
        out = jax.vmap(lambda gg: apply_gnn_traditional(params, gg, cfg.gnn))(g)
    else:
        out = apply_gnn_batch(params, g, cfg.gnn)
    return out[..., 0]  # (B,)


def forward_ensemble(
    params,
    g: JointGraph,
    cfg: CostModelConfig,
    banding: Optional[BatchBanding] = None,
) -> jax.Array:
    """(E-stacked params, batch of graphs) -> raw outputs (E, B).

    Raw output is log1p(cost) for regression, a logit for classification.
    One stacked engine forward evaluates every member (``gnn.apply_gnn_stacked``
    — the member axis rides the same launch per stage, it is not one forward
    per member); ``banding`` is the bucket's static stage-3 plan from
    ``graph.batch_banding`` (None: full-depth scan, valid for any batch).
    The ``traditional_mp`` ablation lacks the 3-stage structure the engine
    exploits and keeps its per-graph path.
    """
    if cfg.traditional_mp:
        return jax.vmap(lambda p: _forward_single(p, g, cfg))(params)
    return apply_gnn_stacked(params, g, cfg.gnn, banding)


# -- losses ---------------------------------------------------------------------


def msle_loss(raw: jax.Array, y: jax.Array) -> jax.Array:
    """Mean squared logarithmic error; ``raw`` already lives in log1p space."""
    return jnp.mean(jnp.square(raw - jnp.log1p(y)))


def bce_loss(raw: jax.Array, y: jax.Array) -> jax.Array:
    """Binary cross-entropy with logits."""
    return jnp.mean(
        jnp.maximum(raw, 0.0) - raw * y + jnp.log1p(jnp.exp(-jnp.abs(raw)))
    )


def loss_fn(cfg: CostModelConfig) -> Callable[[jax.Array, jax.Array], jax.Array]:
    return msle_loss if cfg.task == "regression" else bce_loss


def ensemble_loss(
    params,
    g: JointGraph,
    y: jax.Array,
    cfg: CostModelConfig,
    banding: Optional[BatchBanding] = None,
) -> jax.Array:
    """Sum of member losses (members are independent; grads don't mix)."""
    raw = forward_ensemble(params, g, cfg, banding)  # (E, B)
    per_member = jax.vmap(lambda r: loss_fn(cfg)(r, y))(raw)
    return jnp.sum(per_member)


# -- inference voting -------------------------------------------------------------


def _ensemble_vote(raw: np.ndarray, cfg: CostModelConfig) -> np.ndarray:
    """(E, B) raw outputs -> cost-space prediction (paper SIV-A).

    regression: mean over members of expm1(raw); classification: majority vote
    over thresholded member probabilities -> {0,1}.
    """
    if cfg.task == "regression":
        return np.mean(np.expm1(raw), axis=0).clip(min=0.0)
    votes = (raw > 0.0).astype(np.int64)  # logit > 0 <=> p > 0.5
    return (votes.sum(axis=0) * 2 > votes.shape[0]).astype(np.int64)


# -- fused multi-metric ensembles -------------------------------------------------
#
# The per-metric GNNs share one architecture (paper SIV-A: same GNNConfig,
# different training targets), so their ensemble params are shape-identical
# pytrees with a leading (E,) member axis.  Stacking them along that axis
# turns "one forward per (metric, member)" into ONE vmapped forward whose
# leading axis is sum(E_m) — a single kernel launch per GNN stage instead of
# len(metrics) * E launches, which is where placement scoring spends its time
# (dispatch overhead dominates these small graphs).


class StackedEnsembles(NamedTuple):
    """Per-metric ensembles fused along the leading member axis.

    ``params`` leaves have shape ``(sum of member counts, ...)``; metric ``m``
    owns rows ``[offsets[i], offsets[i] + sizes[i])``.  Hashable-free (holds
    arrays), so it is passed positionally into jitted forwards that are cached
    on the shared ``GNNConfig`` instead.
    """

    params: object  # pytree, leaves stacked along axis 0
    metrics: Tuple[str, ...]
    cfgs: Tuple[CostModelConfig, ...]
    sizes: Tuple[int, ...]  # members per metric, in ``metrics`` order


def stack_metric_models(
    models: Dict[str, Tuple[object, CostModelConfig]],
    metrics: Optional[Sequence[str]] = None,
) -> StackedEnsembles:
    """Fuse several per-metric (params, cfg) ensembles into one stack.

    Requires every model to share the same ``GNNConfig`` and ``traditional_mp``
    flag (the forwards must be structurally identical to share a trace);
    raises ``ValueError`` otherwise so callers can fall back to the per-metric
    loop explicitly.  Member counts may differ — leaves are concatenated, not
    stacked, so metric i contributes ``sizes[i]`` rows.
    """
    names = tuple(metrics) if metrics is not None else tuple(models)
    assert names, "no metrics to stack"
    cfgs = tuple(models[m][1] for m in names)
    for c in cfgs[1:]:
        if c.gnn != cfgs[0].gnn or c.traditional_mp != cfgs[0].traditional_mp:
            raise ValueError(
                "cannot fuse metric ensembles with differing GNN configs: "
                f"{cfgs[0].metric}={cfgs[0].gnn} vs {c.metric}={c.gnn} "
                f"(traditional_mp {cfgs[0].traditional_mp} vs {c.traditional_mp})"
            )
    sizes = []
    for m in names:
        leaf = jax.tree_util.tree_leaves(models[m][0])[0]
        sizes.append(int(leaf.shape[0]))
    stacked = jax.tree_util.tree_map(
        lambda *leaves: jnp.concatenate([jnp.asarray(l) for l in leaves], axis=0),
        *[models[m][0] for m in names],
    )
    return StackedEnsembles(stacked, names, cfgs, tuple(sizes))


def _split_votes(raw: np.ndarray, stacked: StackedEnsembles) -> Dict[str, np.ndarray]:
    """(sum_E, B) fused raw outputs -> per-metric cost-space predictions."""
    out, off = {}, 0
    for m, cfg, sz in zip(stacked.metrics, stacked.cfgs, stacked.sizes):
        out[m] = _ensemble_vote(raw[off : off + sz], cfg)
        off += sz
    return out


def label_array(traces, metric: str) -> np.ndarray:
    return np.asarray([t.labels.as_dict()[metric] for t in traces], dtype=np.float32)


# -- deprecated inference entry points --------------------------------------------
#
# The serving API moved behind ``repro.serve.CostEstimator`` (docs/api.md):
# the facade owns the skeleton/stack caches and the jitted-forward trace
# caches that used to live at this module's level.  The wrappers below keep
# the old call signatures alive for out-of-tree users: each delegates to the
# SAME serving machinery (shim output == facade output, test-pinned) and
# warns ONCE per process.  Removal horizon: docs/api.md#deprecations.

_DEPRECATION_WARNED: set = set()


def _warn_deprecated(name: str, replacement: str) -> None:
    if name in _DEPRECATION_WARNED:
        return
    _DEPRECATION_WARNED.add(name)
    warnings.warn(
        f"repro.core.model.{name} is deprecated; use {replacement} "
        "(docs/api.md#deprecations)",
        DeprecationWarning,
        stacklevel=3,
    )


def predict(params, g: JointGraph, cfg: CostModelConfig) -> np.ndarray:
    """Deprecated: use ``repro.serve.CostEstimator.estimate``."""
    _warn_deprecated("predict", "repro.serve.CostEstimator.estimate")
    from repro.serve import estimator as _serve

    return _serve.ensemble_predict(params, g, cfg)


def predict_proba(params, g: JointGraph, cfg: CostModelConfig) -> np.ndarray:
    """Deprecated: use ``repro.serve.CostEstimator.proba``."""
    _warn_deprecated("predict_proba", "repro.serve.CostEstimator.proba")
    from repro.serve import estimator as _serve

    return _serve.ensemble_proba(params, g, cfg)


def predict_metrics(
    models: Dict[str, Tuple[object, CostModelConfig]], g: JointGraph
) -> Dict[str, np.ndarray]:
    """Deprecated: use ``repro.serve.CostEstimator.estimate``."""
    _warn_deprecated("predict_metrics", "repro.serve.CostEstimator.estimate")
    from repro.serve import CostEstimator

    return CostEstimator(models).estimate(g)


def predict_placements(
    params, skel: JointGraph, a_place: jax.Array, static: QueryStatic, cfg: CostModelConfig
) -> np.ndarray:
    """Deprecated: use ``repro.serve.CostEstimator.score``."""
    _warn_deprecated("predict_placements", "repro.serve.CostEstimator.score")
    from repro.serve import estimator as _serve

    return _serve.placed_predict(params, skel, a_place, static, cfg)


def predict_placements_fused(
    stacked: StackedEnsembles, skel: JointGraph, a_place: jax.Array, static: QueryStatic
) -> Dict[str, np.ndarray]:
    """Deprecated: use ``repro.serve.CostEstimator.score``."""
    _warn_deprecated("predict_placements_fused", "repro.serve.CostEstimator.score")
    from repro.serve import estimator as _serve

    return _serve.placed_predict_fused(stacked, skel, a_place, static)
