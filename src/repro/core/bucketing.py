"""Shared padding / shape-bucket policy for jitted graph batches.

Training and placement scoring both feed ragged work (trace corpora,
candidate sets) through jitted forwards, and jitted forwards retrace per
input shape.  This module is the single place that decides how a ragged
count becomes a static shape:

* ``bucket_size``     — the enclosing power-of-two candidate-count bucket the
                        placement scorer pads to;
* ``pad_batch``       — pad a batched ``JointGraph``-like NamedTuple along
                        axis 0 by repeating the last row, so every padded row
                        stays a well-formed graph (masks and slot types
                        intact) and bucketed jit shapes never see garbage.

The training iterator (``training/batching.bucketed_batches``) applies the
same duplicate-samples-never-foreign-shapes policy at the index level: epoch
tails are padded by wrapping the banding group's own shuffled order.
Callers always slice predictions back to the true count; padded rows are
scored/trained but meaningless (placement) or benign duplicates (training).
"""

from __future__ import annotations

import numpy as np


def bucket_size(n: int) -> int:
    """Smallest power of two >= n: the jit shape buckets the scorer pads to."""
    assert n > 0, n
    return 1 << (n - 1).bit_length()


def pad_batch(g, target: int):
    """Pad a batched graph NamedTuple along axis 0 to ``target`` rows.

    Padding repeats the last graph, so every row stays a well-formed graph
    (masks and slot types intact) and bucketed jit shapes never see garbage;
    callers slice predictions back to the true count.  Works on any NamedTuple
    of batched arrays (``JointGraph`` in practice).
    """
    fields = [np.asarray(x) for x in g]
    n = fields[0].shape[0]
    assert all(x.shape[0] == n for x in fields), "fields disagree on batch size"
    assert n <= target, (n, target)
    if n == target:
        return g
    return type(g)(
        *[
            np.pad(x, [(0, target - n)] + [(0, 0)] * (x.ndim - 1), mode="edge")
            for x in fields
        ]
    )
