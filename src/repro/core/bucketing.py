"""Shared padding / shape-bucket / stage-3 banding policy for jitted batches.

Training and placement scoring both feed ragged work (trace corpora,
candidate sets, merged request streams) through jitted forwards, and jitted
forwards retrace per input shape.  This module is the single place that
decides how a ragged count becomes a static shape, and how a batch's depth
structure becomes a static stage-3 plan:

* ``bucket_size``     — the enclosing power-of-two candidate-count bucket the
                        placement scorer pads to;
* ``pad_batch``       — pad a batched ``JointGraph``-like NamedTuple along
                        axis 0 by repeating the last row, so every padded row
                        stays a well-formed graph (masks and slot types
                        intact) and bucketed jit shapes never see garbage;
* ``batch_banding``   — bucket-conservative per-depth ``row_span`` /
                        ``parent_rows`` bounds (valid for every sub-batch of
                        a bucket; the shared-plan training default);
* ``exact_banding``   — per-row (type, depth) **signature-exact** bands with
                        static row trimming: spans computed from exactly the
                        signatures present in the batch, and rows that carry
                        no operator in ANY member dropped from the layout
                        entirely.  Cached by signature hash
                        (``exact_banding_cached``) so zero-copy views and
                        merged request batches never recompute or retrace.

The training iterator (``training/batching.bucketed_batches``) applies the
same duplicate-samples-never-foreign-shapes policy at the index level: epoch
tails are padded by wrapping the banding group's own shuffled order.
Callers always slice predictions back to the true count; padded rows are
scored/trained but meaningless (placement) or benign duplicates (training).
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import numpy as np


def bucket_size(n: int) -> int:
    """Smallest power of two >= n: the jit shape buckets the scorer pads to."""
    assert n > 0, n
    return 1 << (n - 1).bit_length()


def pad_batch(g, target: int):
    """Pad a batched graph NamedTuple along axis 0 to ``target`` rows.

    Padding repeats the last graph, so every row stays a well-formed graph
    (masks and slot types intact) and bucketed jit shapes never see garbage;
    callers slice predictions back to the true count.  Works on any NamedTuple
    of batched arrays (``JointGraph`` in practice).
    """
    fields = [np.asarray(x) for x in g]
    n = fields[0].shape[0]
    assert all(x.shape[0] == n for x in fields), "fields disagree on batch size"
    assert n <= target, (n, target)
    if n == target:
        return g
    return type(g)(
        *[
            np.pad(x, [(0, target - n)] + [(0, 0)] * (x.ndim - 1), mode="edge")
            for x in fields
        ]
    )


# -- stage-3 banding --------------------------------------------------------------


class BatchBanding(NamedTuple):
    """Static stage-3 plan for a batch of graphs in the depth-major layout.

    ``levels`` holds, for every depth ``d >= 1`` at which ANY graph of the
    batch has an operator, the tuple ``(d, (start, stop), parent_rows)``:

    * ``(start, stop)`` — row span covering every batch graph's depth-``d``
      rows.  Rows outside the span are provably never selected at depth ``d``
      for any graph in the batch, so the message-passing step can statically
      skip their dense work (``kernels/mp_update``'s ``row_span``);
    * ``parent_rows`` — exclusive upper bound on the rows that feed messages
      into the span: ``a_flow[u, v] == 0`` for every ``u >= parent_rows`` and
      every selected ``v``, across the whole batch (the kernel's contraction
      bound).

    ``rows``/``ranges`` are the optional **row trim** (``exact_banding``):
    when set, the forward statically gathers just ``rows`` (ascending padded
    row indices — every row that holds a real operator in at least one batch
    member) and runs every stage on that trimmed layout, whose type runs are
    ``ranges``; ``levels`` then live in trimmed coordinates.  ``rows=None``
    (the conservative ``batch_banding`` output) means the full padded layout
    with the canonical ``graph.SLOT_RANGES``.

    Being a tuple-of-ints NamedTuple it is hashable and serves as the static
    jit-cache key for bucketed training steps and merged serving forwards:
    one trace per banding, and the scan runs ``len(levels)`` banded steps
    instead of MAX_DEPTH full-width ones.
    """

    levels: Tuple[Tuple[int, Tuple[int, int], int], ...]
    rows: Optional[Tuple[int, ...]] = None
    ranges: Optional[Tuple[Tuple[int, int, int], ...]] = None


def _batch_arrays(g):
    """(depth, mask, flow, types) as 2-D/3-D numpy, single graphs promoted."""
    depth = np.asarray(g.op_depth)
    mask = np.asarray(g.op_mask) > 0
    flow = np.asarray(g.a_flow)
    types = np.asarray(g.op_type)
    if depth.ndim == 1:  # single graph: treat as a one-element bucket
        depth, mask, flow, types = depth[None], mask[None], flow[None], types[None]
    return depth, mask, flow, types


def batch_banding(g) -> BatchBanding:
    """Host-side (numpy) conservative banding for a batched graph.

    Computed once per (n_ops, depth) bucket at dataset-bucketing time, NOT per
    batch: all batches of one bucket must share the static plan or the jitted
    step would retrace per batch.  The banding is *conservative*: valid for
    every sub-batch drawn from the bucket (padding included, since padded rows
    repeat bucket graphs).

    Like ``exact_banding``, the plan is a pure function of
    ``batch_signature(g)``: ``parent_rows`` bounds the contraction by the
    last row that is active at any depth ``< d`` — every edge into a
    depth-``d`` row comes from a strictly shallower active row, so the bound
    covers every possible ``a_flow`` over these signatures (what makes the
    signature-keyed banding caches sound).
    """
    depth, mask, _, _ = _batch_arrays(g)
    active = depth * mask
    levels = []
    for d in range(1, int(active.max(initial=0)) + 1):
        sel = (depth == d) & mask  # (B, N)
        if not sel.any():
            continue
        rows = np.flatnonzero(sel.any(axis=0))
        span = (int(rows[0]), int(rows[-1]) + 1)
        shallower = np.flatnonzero(((depth < d) & mask).any(axis=0))
        parent_rows = int(shallower[-1]) + 1 if shallower.size else 1
        levels.append((d, span, parent_rows))
    return BatchBanding(levels=tuple(levels))


def _type_runs(types) -> Tuple[Tuple[int, int, int], ...]:
    """Maximal runs of equal node type over ``types`` as (type, start, stop)."""
    runs = []
    for i, t in enumerate(int(x) for x in types):
        if runs and runs[-1][0] == t:
            runs[-1][2] = i + 1
        else:
            runs.append([t, i, i + 1])
    return tuple(tuple(r) for r in runs)


def batch_signature(g) -> Tuple[Tuple[int, ...], ...]:
    """Sorted unique per-graph row signatures of a batch — the banding key.

    A graph's row signature is the per-row topological depth with padded rows
    encoded as ``-1``; exact banding is a pure function of the *set* of
    signatures present (padding repeats members, so it never changes the
    key), which is what makes ``exact_banding_cached`` sound for every view,
    sub-batch, and merged request stream drawn from the same structures.
    """
    depth, mask, _, _ = _batch_arrays(g)
    sig = np.where(mask, depth, -1).astype(np.int64)
    return tuple(sorted(set(map(tuple, sig.tolist()))))


def exact_banding(g) -> BatchBanding:
    """Signature-exact bands + depth-clustered row trimming for a batch.

    Where ``batch_banding`` shares one conservative plan across a whole
    bucket, this plan is exact for the batch's per-row (type, depth)
    signatures: rows holding no operator in ANY member are statically dropped
    from the layout, and the kept rows are **reordered by mean active depth**
    (type, then slot, as tie-breaks).  Rows the stage-3 sweep selects at the
    same depth thereby cluster, so each level's span hull — and with it the
    level's aggregation + banked-MLP row work — shrinks toward the rows
    actually selected, instead of spanning whatever the canonical layout
    interleaves between them.  Correctness never depends on the order
    (selection inside a span stays dynamic); only the spans' tightness does.

    The plan is built from ``batch_signature(g)`` alone — ``parent_rows`` is
    the last kept row active at any depth ``< d`` (every data-flow edge comes
    from a strictly shallower row), not a function of ``a_flow`` — which
    makes it a pure function of the signature set: cacheable, multiplicity-
    independent, and valid for any padding that repeats members.  Costs one
    jit trace per distinct signature set; buys stage work proportional to
    real rows instead of the widest member.
    """
    sig = np.asarray(batch_signature(g), dtype=np.int64)  # (U, N), -1 = padded
    types = np.asarray(g.op_type)
    if types.ndim == 2:
        types = types[0]  # padded slots carry their range's type: rows agree
    keep = np.flatnonzero((sig >= 0).any(axis=0))
    if keep.size == 0:
        return BatchBanding(levels=())
    mean_depth = {
        int(r): float(np.mean(sig[:, r][sig[:, r] >= 0])) for r in keep
    }
    order = sorted(
        (int(r) for r in keep), key=lambda r: (mean_depth[r], int(types[r]), r)
    )
    sig_k = sig[:, order]  # (U, n) in the trimmed, depth-clustered layout
    levels = []
    for d in range(1, int(sig_k.max(initial=0)) + 1):
        rows = np.flatnonzero((sig_k == d).any(axis=0))
        if not rows.size:
            continue
        span = (int(rows[0]), int(rows[-1]) + 1)
        shallower = np.flatnonzero(((sig_k >= 0) & (sig_k < d)).any(axis=0))
        parent_rows = int(shallower[-1]) + 1 if shallower.size else 1
        levels.append((d, span, parent_rows))
    if keep.size == sig.shape[1] and order == list(range(sig.shape[1])):
        return BatchBanding(levels=tuple(levels))  # full width, canonical order
    return BatchBanding(
        levels=tuple(levels),
        rows=tuple(order),
        ranges=_type_runs(types[np.asarray(order)]),
    )


# (flavor, signature-set) -> BatchBanding.  Bands are pure functions of the
# signature set, so one cache serves every consumer (dataset buckets,
# zero-copy views, merged serving chunks) and bounds both recomputation and
# jit retraces.  Capacity comes from the active DispatchPolicy
# (``banding_cache_size``; sizing rationale in serve/policy.py).
_BANDING_CACHE: dict = {}


def _banding_cache_capacity() -> int:
    from repro.serve.policy import active_policy  # lazy: core never pulls serve at import

    return active_policy().banding_cache_size


def _banding_cached(g, flavor: str, compute) -> BatchBanding:
    key = (flavor, batch_signature(g))
    hit = _BANDING_CACHE.get(key)
    if hit is None:
        if len(_BANDING_CACHE) >= _banding_cache_capacity():
            _BANDING_CACHE.clear()  # tiny entries; full reset beats LRU churn
        hit = _BANDING_CACHE[key] = compute(g)
    return hit


def exact_banding_cached(g) -> BatchBanding:
    """``exact_banding`` memoized on ``batch_signature(g)``."""
    return _banding_cached(g, "exact", exact_banding)


def batch_banding_cached(g) -> BatchBanding:
    """``batch_banding`` memoized on ``batch_signature(g)``."""
    return _banding_cached(g, "conservative", batch_banding)
