"""Transferable featurization (paper SIV-B, Tables I & II).

Maps operators and hardware nodes to fixed-width numeric vectors. Only
*transferable* quantities appear (no hostnames, no literals): log-scaled
magnitudes normalized against generous bounds around the Table-II ranges so
that inter-/extrapolated values stay finite and ordered, plus one-hots for
categorical operator properties.
"""

from __future__ import annotations

import math
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.dsps import ranges
from repro.dsps.hardware import Cluster, HardwareNode
from repro.dsps.query import AggFn, DType, FilterFn, Operator, OpType, Query

# Node-type ids for operator nodes (banked encoders index on these).
OP_TYPE_IDS = {
    OpType.SOURCE: 0,
    OpType.FILTER: 1,
    OpType.AGGREGATE: 2,
    OpType.JOIN: 3,
    OpType.SINK: 4,
}
N_OP_TYPES = 5

_FILTER_FNS = [f.value for f in FilterFn]
_AGG_FNS = [f.value for f in AggFn]
_DTYPES3 = [DType.INT, DType.DOUBLE, DType.STRING]
_DTYPES4 = [DType.INT, DType.DOUBLE, DType.STRING, DType.NONE]

# ---------------------------------------------------------------------------
# Feature layout (operator nodes). Keep in sync with OP_FEATURE_DIM.
# ---------------------------------------------------------------------------
# 0  tuple_width_in   (log-norm)
# 1  tuple_width_out  (log-norm)
# 2  event_rate       (log-norm; sources only)
# 3  n_int / width    ; 4 n_double / width ; 5 n_string / width
# 6..12  filter_fn one-hot (7)
# 13..15 literal_dtype one-hot (3)
# 16 selectivity (log-norm)
# 17..19 join_key_dtype one-hot (3)
# 20..23 agg_fn one-hot (4)
# 24..27 group_by_dtype one-hot (4)
# 28..30 agg_dtype one-hot (3)
# 31..32 window type one-hot (sliding, tumbling)
# 33..34 window policy one-hot (count, time)
# 35 window size count (log-norm; 0 when time-based)
# 36 window size time  (log-norm; 0 when count-based)
# 37 slide ratio
# 38 is_stateful flag
OP_FEATURE_DIM = 39
HW_FEATURE_DIM = 4  # cpu, ram, bandwidth, latency (all log-norm)


def lognorm(x: float, key: str) -> float:
    lo, hi = ranges.LOG_BOUNDS[key]
    x = max(float(x), 1e-12)
    return (math.log(x) - math.log(lo)) / (math.log(hi) - math.log(lo))


def featurize_operator(op: Operator) -> np.ndarray:
    v = np.zeros((OP_FEATURE_DIM,), dtype=np.float32)
    v[0] = lognorm(max(op.tuple_width_in, 1.0), "tuple_width")
    v[1] = lognorm(max(op.tuple_width_out, 1.0), "tuple_width")
    if op.op_type == OpType.SOURCE:
        v[2] = lognorm(op.event_rate, "event_rate")
        width = max(op.n_int + op.n_double + op.n_string, 1)
        v[3] = op.n_int / width
        v[4] = op.n_double / width
        v[5] = op.n_string / width
    if op.op_type == OpType.FILTER:
        v[6 + _FILTER_FNS.index(op.filter_fn.value)] = 1.0
        v[13 + _DTYPES3.index(op.literal_dtype)] = 1.0
        v[16] = lognorm(op.selectivity, "selectivity")
    if op.op_type == OpType.JOIN:
        v[17 + _DTYPES3.index(op.join_key_dtype)] = 1.0
        v[16] = lognorm(op.selectivity, "selectivity")
    if op.op_type == OpType.AGGREGATE:
        v[20 + _AGG_FNS.index(op.agg_fn.value)] = 1.0
        v[24 + _DTYPES4.index(op.group_by_dtype)] = 1.0
        v[28 + _DTYPES3.index(op.agg_dtype)] = 1.0
        v[16] = lognorm(op.selectivity, "selectivity")
    if op.window is not None:
        v[31 + (0 if op.window.wtype == "sliding" else 1)] = 1.0
        v[33 + (0 if op.window.policy == "count" else 1)] = 1.0
        if op.window.policy == "count":
            v[35] = lognorm(op.window.size, "window_count")
        else:
            v[36] = lognorm(op.window.size, "window_time_s")
        v[37] = op.window.slide_ratio
    v[38] = 1.0 if op.is_stateful() else 0.0
    return v


def featurize_hardware(node: HardwareNode) -> np.ndarray:
    return np.array(
        [
            lognorm(node.cpu, "cpu"),
            lognorm(node.ram_mb, "ram_mb"),
            lognorm(node.bandwidth_mbps, "bandwidth_mbps"),
            lognorm(node.latency_ms, "latency_ms"),
        ],
        dtype=np.float32,
    )


def op_type_id(op: Operator) -> int:
    return OP_TYPE_IDS[op.op_type]
