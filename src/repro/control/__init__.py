"""Continuous placement control: drift-aware incremental re-placement for a
running query fleet (docs/controller.md).

The subsystem closes the loop the ROADMAP's online scenario asks for:
``FleetRuntime`` (telemetry oracle over the DSPS simulator) -> ``DriftDetector``
(EWMA/CUSUM + hard events) -> ``Replanner`` (budgeted sub-assignment search
through the fused scorer) -> ``PlacementController`` (the per-tick loop with
hysteresis, cooldown, and SLO-grade re-placement-latency reporting).
"""

from repro.control.controller import (
    ControllerReport,
    PlacementController,
    TickRecord,
    run_static,
)
from repro.control.detect import Alarm, DriftDetector
from repro.control.replan import MigrationDecision, ReplanItem, Replanner
from repro.control.scenario import build_scenario, fleet_queries, weak_cluster
from repro.control.telemetry import (
    FleetRuntime,
    FleetSnapshot,
    HostObs,
    QueryObs,
    ScenarioEvent,
    SimulatorScorer,
    plan_initial_fleet,
    seeded_events,
)

__all__ = [
    "Alarm",
    "ControllerReport",
    "DriftDetector",
    "FleetRuntime",
    "FleetSnapshot",
    "HostObs",
    "MigrationDecision",
    "PlacementController",
    "QueryObs",
    "ReplanItem",
    "Replanner",
    "ScenarioEvent",
    "SimulatorScorer",
    "TickRecord",
    "build_scenario",
    "fleet_queries",
    "plan_initial_fleet",
    "run_static",
    "seeded_events",
    "weak_cluster",
]
