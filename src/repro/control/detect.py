"""Drift and degradation detectors over fleet telemetry windows.

The controller's question every tick is not "is something wrong?" but
"*which queries* need re-placement, and which hosts should their operators
avoid?".  Two signal classes answer it:

* **Soft drift** — per-query sequential tests on the residual

      r_t = log(observed_cost_t / predicted_cost)

  where ``predicted_cost`` is the cost-model estimate *recorded when the
  current placement was chosen* (re-placement resets it).  Under no drift the
  residual is the simulator's log-normal measurement noise around the model's
  (constant) bias; under drift it acquires a sustained positive mean.  An
  EWMA (span = ``detector_window``) tracks the level for reporting, and a
  one-sided CUSUM ``s_t = max(0, s_{t-1} + r_t - k)`` with slack ``k``
  accumulates evidence; ``s_t > drift_threshold`` after at least
  ``detector_window`` samples raises a drift alarm.  CUSUM + window arm, not
  a single-sample threshold: one noisy tick cannot fire it, a modest but
  sustained shift cannot hide from it.

* **Hard events** — no statistics needed: orphaned operators (the query is
  running on a failover parking host), evictions, straggler flags from the
  ``ClusterMonitor``, and outright failed ticks (success = 0) alarm
  immediately, bypassing the window.

Alarms also *localize hosts*: hosts whose fleet utilization exceeds
``HOT_HOST_UTIL`` (plus freshly flagged stragglers) are reported as hosts the
re-planner should move work away from.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.control.telemetry import FleetSnapshot

#: Hosts above this fleet cpu utilization are reported "hot" in alarms: past
#: ~0.8 the simulator's M/M/1 waits grow super-linearly, so a replan should
#: treat the host as effectively full even before hard backpressure at 1.0.
HOT_HOST_UTIL = 0.8

#: CUSUM slack as a multiple of the simulator's measurement-noise sigma
#: (0.12): drifts smaller than ~2 sigma per tick are treated as noise floor.
CUSUM_SLACK = 0.25


@dataclass(frozen=True)
class Alarm:
    """One localized detection: which query, why, and which hosts to avoid."""

    tick: int
    query_id: int
    kind: str  # "drift" | "failed" | "orphaned" | "straggler" | "evicted"
    score: float  # CUSUM level (drift) or residual (hard events)
    hot_hosts: Tuple[int, ...] = ()  # current host indices to move away from

    def hard(self) -> bool:
        return self.kind != "drift"


@dataclass
class _QueryTrack:
    ewma: float = 0.0
    cusum: float = 0.0
    n: int = 0


class DriftDetector:
    """Per-query EWMA/CUSUM drift tracking + hard-event pass-through."""

    def __init__(self, window: int, threshold: float, slack: float = CUSUM_SLACK):
        assert window >= 1 and threshold > 0
        self.window = int(window)
        self.threshold = float(threshold)
        self.slack = float(slack)
        self._tracks: Dict[int, _QueryTrack] = {}

    def reset(self, query_id: int) -> None:
        """Re-arm after a re-placement: the residual baseline changed."""
        self._tracks[query_id] = _QueryTrack()

    def level(self, query_id: int) -> float:
        """Current EWMA residual — the recorded degradation of a query."""
        return self._tracks.get(query_id, _QueryTrack()).ewma

    def update(
        self, snapshot: FleetSnapshot, predicted_cost_ms: Dict[int, float]
    ) -> List[Alarm]:
        """Consume one tick of telemetry; return localized alarms.

        ``predicted_cost_ms`` maps query_id -> the cost predicted for the
        query's *current* placement when that placement was installed.
        """
        alarms: List[Alarm] = []
        alpha = 2.0 / (self.window + 1.0)
        flagged = {sid for sid, _ in snapshot.flagged}
        hot = tuple(
            h.index
            for h in snapshot.hosts
            if h.util >= HOT_HOST_UTIL or h.stable_id in flagged
        )
        for qid, obs in sorted(snapshot.queries.items()):
            tr = self._tracks.setdefault(qid, _QueryTrack())
            pred = max(float(predicted_cost_ms.get(qid, obs.cost_ms)), 1e-6)
            r = float(np.log(max(obs.cost_ms, 1e-6) / pred))
            tr.n += 1
            tr.ewma = r if tr.n == 1 else (1 - alpha) * tr.ewma + alpha * r
            tr.cusum = max(0.0, tr.cusum + r - self.slack)

            # hard events first: they bypass the window entirely
            if obs.orphaned:
                alarms.append(Alarm(snapshot.tick, qid, "orphaned", r, hot))
                continue
            if not obs.labels.success:
                alarms.append(Alarm(snapshot.tick, qid, "failed", r, hot))
                continue
            host_set = set(obs.assignment)
            if flagged and any(
                h.index in host_set for h in snapshot.hosts if h.stable_id in flagged
            ):
                alarms.append(Alarm(snapshot.tick, qid, "straggler", r, hot))
                continue
            if tr.n >= self.window and tr.cusum > self.threshold:
                alarms.append(Alarm(snapshot.tick, qid, "drift", tr.cusum, hot))
        return alarms
