"""``FleetRuntime``: simulated telemetry for a fleet of co-located queries.

The cost simulator (``dsps/simulator.py``) is the repo's ground-truth oracle
for ONE placed query on ONE cluster.  The continuous-placement scenario
(ROADMAP: drift, failure, elastic re-placement) needs the *fleet* view: N
queries sharing the same hosts, conditions changing over time.  This module
wraps the simulator as that oracle:

* **Ticks.**  Simulated time advances in ``controller_tick_s`` steps; each
  tick applies scheduled scenario events, drives the heartbeat/straggler
  monitor (``launch/faults.py``), and emits one ``FleetSnapshot`` of
  per-query observed costs and per-host utilization.

* **Contention.**  Co-located queries share hosts.  Each query is simulated
  against its *residual-capacity* view of the cluster: every host's cpu/ram
  reduced by the analytic load/state the OTHER queries place on it (the same
  ``analyze_operators`` quantities the simulator itself uses).  This is what
  a metrics backend would report as free capacity per host — so the
  controller may legitimately score candidates against the same view
  (``observed_cluster``).

* **Scenario events** (``ScenarioEvent``): tuple-rate drift and selectivity
  drift rebuild the affected query's operators (telemetry observes the new
  rates — the drifted query IS the current truth); ``fail`` stops a host's
  heartbeats so the ``ClusterMonitor`` evicts it on timeout (surviving hosts
  are renumbered, every placement remapped, operators stranded on the dead
  host parked as *orphans* on the lowest-numbered survivor); ``straggle``
  slows a host; ``join`` adds capacity.

* **Migrations** are applied through ``apply``: the new assignment takes
  effect next tick and the migration's downtime is charged to that tick's
  observed cost (throughput scaled down, latency_e inflated) — transition
  pain is real, not free.

Everything is seeded: the measurement-noise stream is derived from
``(seed, tick, query_id)``, so the same scenario replays bit-identically.
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace as dc_replace
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.dsps.hardware import Cluster, HardwareNode
from repro.dsps.placement import Placement
from repro.dsps.query import OpType, Query
from repro.dsps.simulator import (
    JVM_BASE_MB,
    MEASUREMENT_S,
    CostLabels,
    SimulatorConfig,
    _dtype_mix,
    analyze_operators,
    simulate,
)
from repro.launch.faults import ClusterMonitor, FaultPolicy, VirtualHost
from repro.serve.policy import DispatchPolicy, active_policy

#: Observed cost charged to a query whose tick failed (success = 0): the
#: worst-case broker backlog the simulator itself can produce — half the
#: 4-minute measurement interval of queued wait (paper Def. 4/5).
FAIL_COST_MS = 0.5 * MEASUREMENT_S * 1e3

#: Residual-capacity floors: a fully contended host still exposes a sliver of
#: capacity instead of a degenerate zero-cpu node.
MIN_RESIDUAL_CPU = 5.0
MIN_RESIDUAL_RAM_MB = JVM_BASE_MB + 64.0

#: Heartbeat timeout in ticks: one missed tick is noise (a long GC pause),
#: two is a dead host — the standard 1.5x monitoring-interval rule.
HEARTBEAT_TIMEOUT_TICKS = 1.5

#: Per-step wall time a healthy host reports to the straggler detector; only
#: ratios matter (the detector is a median/MAD outlier test).
BASE_STEP_S = 0.1


@dataclass(frozen=True)
class ScenarioEvent:
    """One scheduled condition change, applied when the runtime reaches
    ``tick``.  ``kind``:

    - ``"rate_drift"``: multiply ``query``'s source event rates by ``factor``
    - ``"selectivity_drift"``: multiply ``query``'s filter/join/agg
      selectivities by ``factor`` (clipped to (0.01, 1])
    - ``"fail"``: host ``host`` (stable id) stops heartbeating; evicted by
      the monitor one timeout later
    - ``"straggle"``: host ``host`` slows by ``factor`` (cpu / factor)
    - ``"join"``: a new host joins with ``node``'s features
    """

    tick: int
    kind: str
    query: Optional[int] = None
    host: Optional[int] = None
    factor: float = 1.0
    node: Optional[HardwareNode] = None

    def __post_init__(self):
        assert self.kind in (
            "rate_drift", "selectivity_drift", "fail", "straggle", "join",
        ), self.kind


@dataclass(frozen=True)
class QueryObs:
    """One query's observed telemetry for one tick."""

    query_id: int
    labels: CostLabels
    cost_ms: float  # scalar fleet-cost contribution (latency_e or FAIL_COST_MS)
    assignment: Tuple[int, ...]  # current host indices (post-remap)
    orphaned: Tuple[int, ...]  # ops parked on the failover host
    downtime_s: float  # migration downtime charged to this tick


@dataclass(frozen=True)
class HostObs:
    index: int  # current cluster index (contiguous)
    stable_id: int  # scenario-stable id (survives renumbering)
    util: float  # fleet cpu load / capacity
    state_mb: float  # fleet window state resident
    straggle: float  # >1 = slowed


@dataclass(frozen=True)
class FleetSnapshot:
    tick: int
    time_s: float
    queries: Dict[int, QueryObs]
    hosts: Tuple[HostObs, ...]
    evicted: Tuple[Tuple[int, str], ...]  # (stable_id, reason) this tick
    flagged: Tuple[Tuple[int, str], ...]  # straggler flags this tick
    joined: Tuple[int, ...]  # stable ids of hosts that joined this tick

    def fleet_cost_ms(self) -> float:
        """Mean per-query observed cost — the end-of-run gate metric."""
        if not self.queries:
            return 0.0
        return float(np.mean([q.cost_ms for q in self.queries.values()]))


class FleetRuntime:
    """Ground-truth oracle for N co-located queries under scenario events."""

    def __init__(
        self,
        queries: Sequence[Tuple[Query, Sequence[int]]],
        cluster: Cluster,
        events: Sequence[ScenarioEvent] = (),
        seed: int = 0,
        tick_s: Optional[float] = None,
        sim_config: Optional[SimulatorConfig] = None,
        policy: Optional[DispatchPolicy] = None,
    ):
        policy = policy if policy is not None else active_policy()
        self.tick_s = float(tick_s if tick_s is not None else policy.controller_tick_s)
        self.seed = int(seed)
        self.sim_config = sim_config if sim_config is not None else SimulatorConfig()
        self.events = sorted(events, key=lambda e: e.tick)
        self.cluster = Cluster(nodes=list(cluster.nodes))
        # own private operator instances: drift rebuilds operators and
        # infer_widths mutates them in place — never the caller's objects
        self._queries: Dict[int, Query] = {
            i: self._own(q) for i, (q, _) in enumerate(queries)
        }
        self._assign: Dict[int, np.ndarray] = {}
        for i, (q, a) in enumerate(queries):
            a = np.asarray(a, dtype=np.int64)
            Placement.of(a).validate(self._queries[i], cluster)
            self._assign[i] = a.copy()
        self._orphans: Dict[int, set] = {i: set() for i in self._queries}
        self._downtime: Dict[int, float] = {i: 0.0 for i in self._queries}
        # stable host ids: scenario events address hosts by the id they had
        # at fleet start; renumbering after an eviction preserves the mapping
        self._stable_ids: List[int] = [n.node_id for n in cluster.nodes]
        self._next_stable = len(cluster.nodes)
        self._dead: set = set()
        self._straggle: Dict[int, float] = {}
        self.monitor = ClusterMonitor(
            n_hosts=cluster.n_nodes(),
            policy=FaultPolicy(heartbeat_timeout_s=HEARTBEAT_TIMEOUT_TICKS * self.tick_s),
        )
        self.tick_idx = 0
        self.time_s = 0.0
        for sid in self._stable_ids:
            self.monitor.heartbeat(sid, 0.0)

    # -- views -------------------------------------------------------------------

    @property
    def query_ids(self) -> List[int]:
        return sorted(self._queries)

    def query(self, query_id: int) -> Query:
        """The query as telemetry currently observes it (drift included)."""
        return self._queries[query_id]

    def assignment(self, query_id: int) -> np.ndarray:
        return self._assign[query_id].copy()

    def orphans(self, query_id: int) -> Tuple[int, ...]:
        return tuple(sorted(self._orphans[query_id]))

    def state_mb(self, query_id: int) -> np.ndarray:
        """Per-op window-state footprint [MB] — the migration-cost unit."""
        q = self._queries[query_id]
        rt = analyze_operators(q, _dtype_mix(q))
        return np.array([rt[i].state_mb for i in range(q.n_ops())])

    def _own(self, q: Query) -> Query:
        return Query(
            operators=[op.replace() for op in q.operators],
            edges=list(q.edges),
            name=q.name,
        ).infer_widths()

    # -- contention --------------------------------------------------------------

    def _host_footprint(self, exclude: Optional[int] = None):
        """Fleet cpu load [ref-core-s/s] and state [MB] per host index."""
        n = self.cluster.n_nodes()
        load = np.zeros(n)
        state = np.zeros(n)
        for qid, q in self._queries.items():
            if qid == exclude:
                continue
            rt = analyze_operators(q, _dtype_mix(q))
            a = self._assign[qid]
            for op in q.operators:
                h = int(a[op.op_id])
                load[h] += rt[op.op_id].rate_in * rt[op.op_id].service_ms / 1e3
                state[h] += rt[op.op_id].state_mb
        return load, state

    def observed_cluster(self, query_id: Optional[int] = None) -> Cluster:
        """The cluster as host telemetry shows it to ``query_id``: each
        host's cpu/ram reduced by the other queries' resident load/state
        (and by any straggle slowdown).  This is both what the simulator
        runs the query against and what the controller may score against —
        contention enters through monitored residual capacity, not through
        simulator internals."""
        load, state = self._host_footprint(exclude=query_id)
        nodes = []
        for i, node in enumerate(self.cluster.nodes):
            slow = self._straggle.get(self._stable_ids[i], 1.0)
            cpu = max(node.cpu / slow - 100.0 * load[i], MIN_RESIDUAL_CPU)
            ram = max(node.ram_mb - state[i], MIN_RESIDUAL_RAM_MB)
            nodes.append(dc_replace(node, node_id=i, cpu=cpu, ram_mb=ram))
        return Cluster(nodes=nodes)

    # -- scenario events -----------------------------------------------------------

    def _apply_event(self, ev: ScenarioEvent) -> Optional[int]:
        if ev.kind in ("rate_drift", "selectivity_drift"):
            q = self._queries[ev.query]
            ops = []
            for op in q.operators:
                if ev.kind == "rate_drift" and op.op_type == OpType.SOURCE:
                    ops.append(op.replace(event_rate=op.event_rate * ev.factor))
                elif ev.kind == "selectivity_drift" and op.op_type in (
                    OpType.FILTER, OpType.JOIN, OpType.AGGREGATE,
                ):
                    sel = float(np.clip(op.selectivity * ev.factor, 0.01, 1.0))
                    ops.append(op.replace(selectivity=sel))
                else:
                    ops.append(op.replace())
            self._queries[ev.query] = Query(
                operators=ops, edges=list(q.edges), name=q.name
            ).infer_widths()
        elif ev.kind == "fail":
            if ev.host not in self._dead and ev.host in self._stable_ids:
                self._dead.add(ev.host)
                self.monitor.inject_failure(ev.host)
        elif ev.kind == "straggle":
            self._straggle[ev.host] = ev.factor
            if ev.host in self.monitor.hosts:
                self.monitor.inject_straggler(ev.host, ev.factor)
        elif ev.kind == "join":
            assert ev.node is not None, "join event needs a node spec"
            sid = self._next_stable
            self._next_stable += 1
            node = dc_replace(ev.node, node_id=self.cluster.n_nodes())
            self.cluster = Cluster(nodes=list(self.cluster.nodes) + [node])
            self._stable_ids.append(sid)
            self.monitor.hosts[sid] = VirtualHost(host_id=sid)
            self.monitor.heartbeat(sid, self.time_s)
            return sid
        return None

    def _evict(self, stable_id: int) -> None:
        """Remove a host: renumber survivors, remap every placement, park
        stranded operators as orphans on the lowest-numbered survivor."""
        idx = self._stable_ids.index(stable_id)
        survivors = [n for i, n in enumerate(self.cluster.nodes) if i != idx]
        assert survivors, "scenario evicted the last host"
        self.cluster = Cluster(
            nodes=[dc_replace(n, node_id=i) for i, n in enumerate(survivors)]
        )
        del self._stable_ids[idx]
        for qid, a in self._assign.items():
            stranded = np.where(a == idx)[0]
            a[a > idx] -= 1
            if len(stranded):
                # deterministic failover: the dead host's state is lost, its
                # operators restart on the parking host until the controller
                # re-places them
                a[stranded] = 0
                self._orphans[qid].update(int(s) for s in stranded)

    # -- migrations ----------------------------------------------------------------

    def apply(self, query_id: int, assignment: Sequence[int], downtime_s: float = 0.0) -> None:
        """Install a re-placement; ``downtime_s`` is charged to next tick."""
        a = np.asarray(assignment, dtype=np.int64)
        Placement.of(a).validate(self._queries[query_id], self.cluster)
        moved = np.where(a != self._assign[query_id])[0]
        self._assign[query_id] = a.copy()
        self._downtime[query_id] += float(downtime_s)
        self._orphans[query_id] -= {int(m) for m in moved}

    def adopt(self, query_id: int) -> None:
        """Accept the current (failover) placement as the query's new home:
        clears orphan status without a migration."""
        self._orphans[query_id].clear()

    # -- the tick ------------------------------------------------------------------

    def tick(self) -> FleetSnapshot:
        self.tick_idx += 1
        self.time_s += self.tick_s
        joined: List[int] = []
        for ev in self.events:
            if ev.tick == self.tick_idx:
                sid = self._apply_event(ev)
                if sid is not None:
                    joined.append(sid)

        # heartbeats + step reports from live hosts; dead hosts stay silent
        for sid in self._stable_ids:
            if sid in self._dead or sid not in self.monitor.hosts:
                continue
            self.monitor.heartbeat(sid, self.time_s)
            self.monitor.report_step(sid, BASE_STEP_S * self._straggle.get(sid, 1.0))

        evicted: List[Tuple[int, str]] = []
        flagged: List[Tuple[int, str]] = []
        for sid, reason in self.monitor.detect(self.time_s):
            if reason.startswith("heartbeat"):
                if sid in self._stable_ids:
                    self.monitor.evict(sid, reason, self.time_s)
                    self._evict(sid)
                    evicted.append((sid, reason))
            else:
                flagged.append((sid, reason))

        # per-query observed labels on the residual-capacity cluster
        obs: Dict[int, QueryObs] = {}
        for qid in self.query_ids:
            q = self._queries[qid]
            a = self._assign[qid]
            rng = np.random.default_rng((self.seed, self.tick_idx, qid, 0x7E1E))
            labels = simulate(
                q, self.observed_cluster(qid), Placement.of(a), self.sim_config, rng
            )
            down = self._downtime[qid]
            self._downtime[qid] = 0.0
            if down > 0.0:
                # migration downtime: the query is stopped for `down` seconds
                # of this tick — tuples queue at the broker and throughput
                # over the tick shrinks proportionally
                frac = min(down / self.tick_s, 1.0)
                labels = dc_replace(
                    labels,
                    throughput=labels.throughput * (1.0 - frac),
                    latency_e=labels.latency_e + down * 1e3,
                )
            cost = labels.latency_e if labels.success else FAIL_COST_MS
            obs[qid] = QueryObs(
                query_id=qid,
                labels=labels,
                cost_ms=float(cost),
                assignment=tuple(int(x) for x in a),
                orphaned=self.orphans(qid),
                downtime_s=down,
            )

        load, state = self._host_footprint()
        hosts = tuple(
            HostObs(
                index=i,
                stable_id=self._stable_ids[i],
                util=float(
                    load[i]
                    / max(self.cluster.node(i).cores()
                          / self._straggle.get(self._stable_ids[i], 1.0), 1e-9)
                ),
                state_mb=float(state[i]),
                straggle=self._straggle.get(self._stable_ids[i], 1.0),
            )
            for i in range(self.cluster.n_nodes())
        )
        return FleetSnapshot(
            tick=self.tick_idx,
            time_s=self.time_s,
            queries=obs,
            hosts=hosts,
            evicted=tuple(evicted),
            flagged=tuple(flagged),
            joined=tuple(joined),
        )


class SimulatorScorer:
    """Noise-free simulator oracle with the re-planner's scorer shape
    ``(query, cluster, assignments) -> {metric: (N,)}``.

    Stands in for a trained ``CostEstimator`` in tests, the demo, and the
    benchmark's decision-quality lanes, so controller behaviour is judged on
    placement decisions, not on a particular checkpoint's accuracy."""

    def __init__(self, config: Optional[SimulatorConfig] = None):
        self.config = (
            config if config is not None else SimulatorConfig(noise_sigma=0.0)
        )

    def __call__(self, query: Query, cluster: Cluster, assignments) -> Dict[str, np.ndarray]:
        rows = np.asarray(assignments, dtype=np.int64)
        out: Dict[str, List[float]] = {}
        rng = np.random.default_rng(0)  # unused at noise_sigma = 0
        for row in rows:
            labels = simulate(query, cluster, Placement.of(row), self.config, rng)
            for k, v in labels.as_dict().items():
                out.setdefault(k, []).append(v)
        return {k: np.asarray(v) for k, v in out.items()}


def plan_initial_fleet(
    queries: Sequence[Query],
    cluster: Cluster,
    k: int = 64,
    seed: int = 0,
    scorer=None,
    target_metric: str = "latency_e",
) -> List[Tuple[Query, Tuple[int, ...]]]:
    """Contention-aware greedy initial placement for a whole fleet.

    Queries are placed one at a time against the residual capacity left by
    the already-placed ones (the same footprint model ``FleetRuntime`` uses),
    each picking the best of ``k`` sampled candidates under ``scorer``
    (default: the noise-free simulator oracle) with failing/backpressured
    candidates heavily penalized.  This is "COSTREAM picks a good initial
    placement" — the starting state the drift scenario then invalidates.
    """
    from repro.placement.enumerate import heuristic_placement, sample_assignment_matrix

    scorer = scorer if scorer is not None else SimulatorScorer()
    n = cluster.n_nodes()
    load = np.zeros(n)
    state = np.zeros(n)
    out: List[Tuple[Query, Tuple[int, ...]]] = []
    rng = np.random.default_rng((seed, 0xF1EE7))
    for q in queries:
        nodes = [
            dc_replace(
                node,
                cpu=max(node.cpu - 100.0 * load[i], MIN_RESIDUAL_CPU),
                ram_mb=max(node.ram_mb - state[i], MIN_RESIDUAL_RAM_MB),
            )
            for i, node in enumerate(cluster.nodes)
        ]
        residual = Cluster(nodes=nodes)
        cand = sample_assignment_matrix(q, residual, k, rng)
        if len(cand) == 0:
            cand = np.asarray([heuristic_placement(q, residual).assignment])
        scores = scorer(q, residual, cand)
        cost = np.asarray(scores[target_metric], dtype=np.float64).copy()
        if "success" in scores:
            cost += 1e9 * (np.asarray(scores["success"]) < 0.5)
        if "backpressure" in scores:
            cost += 1e6 * (np.asarray(scores["backpressure"]) < 0.5)
        a = cand[int(np.argmin(cost))]
        rt = analyze_operators(q, _dtype_mix(q))
        for op in q.operators:
            h = int(a[op.op_id])
            load[h] += rt[op.op_id].rate_in * rt[op.op_id].service_ms / 1e3
            state[h] += rt[op.op_id].state_mb
        out.append((q, tuple(int(x) for x in a)))
    return out


def seeded_events(
    n_ticks: int,
    n_queries: int,
    host_ids: Sequence[int],
    seed: int = 0,
    drift_factor: float = 4.0,
    n_drifts: int = 2,
    fail: bool = True,
    join_node: Optional[HardwareNode] = None,
) -> List[ScenarioEvent]:
    """A seeded drift+failure scenario: ``n_drifts`` rate drifts in the first
    half of the run, one host failure at midpoint, optional capacity join at
    the three-quarter mark.  Deterministic in ``seed``."""
    rng = np.random.default_rng((seed, 0xC0577EA))
    events: List[ScenarioEvent] = []
    drift_qs = rng.choice(n_queries, size=min(n_drifts, n_queries), replace=False)
    for i, qid in enumerate(sorted(int(x) for x in drift_qs)):
        tick = 2 + int(rng.integers(0, max(n_ticks // 3, 1)))
        events.append(
            ScenarioEvent(tick=tick, kind="rate_drift", query=qid, factor=drift_factor)
        )
    if fail and len(host_ids) > 1:
        victim = int(host_ids[int(rng.integers(1, len(host_ids)))])
        events.append(ScenarioEvent(tick=max(n_ticks // 2, 2), kind="fail", host=victim))
    if join_node is not None:
        events.append(
            ScenarioEvent(tick=max(3 * n_ticks // 4, 3), kind="join", node=join_node)
        )
    return sorted(events, key=lambda e: e.tick)
