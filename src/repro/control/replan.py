"""Incremental re-placement under a migration-cost budget.

A full re-optimization answers "where would this query run best, from
scratch?" — the wrong question for a *running* query, where every moved
stateful operator drags its window state across the network and eats
downtime.  The re-planner answers the operational question instead: freeze
the operators the detector did NOT implicate, re-enumerate only the affected
sub-assignment, score every candidate through the fused scorer, and accept a
move only when

    predicted steady-state gain  >  hysteresis margin,  and
    state to move                <= migration budget.

Mechanics:

* **Candidates** (``sub_assignment_candidates``): the current assignment
  (always row 0 — the no-op reference), systematic block moves (all free ops
  onto each single host), and ``replan_k`` random redraws of the free
  positions; frozen positions are pinned to their current hosts in every
  row.  Rows are validity-filtered with the Fig.-5 rules as a *search
  prior* — if the filter starves the pool (the running placement may already
  violate bin monotonicity on the residual-capacity cluster), the unfiltered
  pool is used, since the simulator accepts any in-range assignment.

* **Scoring** rides ``CostEstimator.score`` / ``score_many`` — multiple
  affected queries in one tick share ONE merged cross-query forward and the
  estimator's skeleton/merged-group caches, which is what makes re-placement
  latency an SLO the serving stack can meet.  Any callable with the same
  ``(query, cluster, assignments) -> {metric: (N,)}`` shape can stand in
  (tests and the benchmark plug in a noise-free simulator oracle).

* **Migration cost**: moved operators pay their window-state bytes
  (``OpRuntime.state_mb`` — the simulator's own accounting), EXCEPT orphaned
  operators, whose state died with their host; re-homing an orphan is free.
  The chosen move's downtime = restart round-trip + state-bytes over the
  cluster's mean drain bandwidth, charged by the runtime to the next tick.

* **Budget**: candidates over ``migration_budget_mb`` are unselectable; with
  budget 0 only zero-state moves (orphan re-homes) remain and everything
  else degrades to a recorded no-op.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.dsps.hardware import Cluster
from repro.dsps.query import Query
from repro.placement.enumerate import batch_validity_mask, dedup_assignments

#: Operator redeploy round-trip charged once per accepted migration [s]:
#: stop-the-world rewire of the physical data flow (Storm/Flink rebalance
#: latencies are seconds-scale).
RESTART_S = 2.0

#: Penalty added to predicted cost of candidates the model deems failing /
#: backpressured — large enough to dominate any real latency, finite so a
#: hard item can still pick the least-bad candidate when all fail.
INFEASIBLE_PENALTY = (1e9, 1e6)  # (success < 0.5, backpressure < 0.5)


@dataclass(frozen=True)
class ReplanItem:
    """One affected query handed to the re-planner."""

    query_id: int
    query: Query
    cluster: Cluster  # residual-capacity view to score against
    current: Tuple[int, ...]
    free_ops: Tuple[int, ...]  # ops allowed to move; all others frozen
    state_mb: Tuple[float, ...]  # per-op window-state footprint
    orphaned: Tuple[int, ...] = ()  # ops whose state is already lost
    hard: bool = False  # failure/orphan: hysteresis margin waived


@dataclass(frozen=True)
class MigrationDecision:
    """The per-query outcome of one re-plan round (the decision-log unit)."""

    query_id: int
    action: str  # "migrate" | "accept" | "no-op"
    old: Tuple[int, ...]
    new: Tuple[int, ...]
    moved: Tuple[int, ...]
    migration_mb: float
    downtime_s: float
    predicted_cost: float  # chosen placement, model view
    current_cost: float  # current placement, model view
    gain: float  # relative predicted improvement
    reason: str
    n_candidates: int

    def to_dict(self) -> Dict:
        return {
            "query_id": self.query_id,
            "action": self.action,
            "old": list(self.old),
            "new": list(self.new),
            "moved": list(self.moved),
            "migration_mb": round(self.migration_mb, 6),
            "downtime_s": round(self.downtime_s, 6),
            "predicted_cost": round(self.predicted_cost, 6),
            "current_cost": round(self.current_cost, 6),
            "gain": round(self.gain, 6),
            "reason": self.reason,
            "n_candidates": self.n_candidates,
        }


def sub_assignment_candidates(
    item: ReplanItem, k: int, rng: np.random.Generator
) -> np.ndarray:
    """Candidate matrix with the current assignment at row 0 and only
    ``item.free_ops`` varying in the remaining rows."""
    cur = np.asarray(item.current, dtype=np.int64)
    free = np.asarray(sorted(item.free_ops), dtype=np.int64)
    n_hosts = item.cluster.n_nodes()
    if len(free) == 0 or n_hosts == 0:
        return cur[None, :]
    # systematic block moves: all free ops co-located on each host
    block = np.tile(cur, (n_hosts, 1))
    block[:, free] = np.arange(n_hosts, dtype=np.int64)[:, None]
    # random redraws of the free positions
    rand = np.tile(cur, (max(k, 1), 1))
    rand[:, free] = rng.integers(0, n_hosts, size=(max(k, 1), len(free)))
    pool = np.concatenate([block, rand], axis=0)
    mask = batch_validity_mask(item.query, item.cluster, pool)
    filtered = pool[mask]
    if len(filtered) < 2:
        filtered = pool  # Fig.-5 rules are a prior, not runtime feasibility
    cand = dedup_assignments(filtered)
    cand = cand[~(cand == cur).all(axis=1)][: max(k, 1)]
    return np.concatenate([cur[None, :], cand], axis=0)


class Replanner:
    """Budgeted sub-assignment search over one or many affected queries."""

    def __init__(
        self,
        estimator=None,
        scorer: Optional[Callable] = None,
        target_metric: str = "latency_e",
        metrics: Optional[Sequence[str]] = None,
        budget_mb: float = 64.0,
        replan_k: int = 32,
        min_gain: float = 0.05,
    ):
        assert (estimator is None) != (scorer is None), (
            "exactly one of estimator / scorer"
        )
        self.estimator = estimator
        self._scorer = scorer
        self.target_metric = target_metric
        if metrics is None:
            wanted = (target_metric, "success", "backpressure")
            if estimator is not None:
                metrics = tuple(m for m in wanted if m in estimator.models)
            else:
                metrics = wanted
        assert target_metric in metrics
        self.metrics = tuple(metrics)
        self.budget_mb = float(budget_mb)
        self.replan_k = int(replan_k)
        self.min_gain = float(min_gain)

    # -- scoring -----------------------------------------------------------------

    def _score_all(
        self, items: Sequence[ReplanItem], cands: Sequence[np.ndarray]
    ) -> List[Dict[str, np.ndarray]]:
        if self.estimator is not None:
            reqs = [(it.query, it.cluster, c) for it, c in zip(items, cands)]
            if len(reqs) > 1 and self.estimator.supports_cross_query(self.metrics):
                return self.estimator.score_many(reqs, self.metrics)
            return [self.estimator.score(q, c, a, self.metrics) for q, c, a in reqs]
        return [
            self._scorer(it.query, it.cluster, c) for it, c in zip(items, cands)
        ]

    # -- selection ---------------------------------------------------------------

    def _decide(
        self, item: ReplanItem, cand: np.ndarray, scores: Dict[str, np.ndarray]
    ) -> MigrationDecision:
        cur = np.asarray(item.current, dtype=np.int64)
        state = np.asarray(item.state_mb, dtype=np.float64)
        movable_state = state.copy()
        if item.orphaned:
            movable_state[list(item.orphaned)] = 0.0  # state already lost

        cost = np.asarray(scores[self.target_metric], dtype=np.float64).copy()
        p_fail, p_bp = INFEASIBLE_PENALTY
        if "success" in scores:
            cost = cost + p_fail * (np.asarray(scores["success"]) < 0.5)
        if "backpressure" in scores:
            cost = cost + p_bp * (np.asarray(scores["backpressure"]) < 0.5)

        moved_mask = cand != cur[None, :]
        mig_mb = (moved_mask * movable_state[None, :]).sum(axis=1)
        current_cost = float(cost[0])
        cur_t = tuple(int(x) for x in cur)

        sel_cost = np.where(mig_mb <= self.budget_mb + 1e-9, cost, np.inf)
        sel_cost[0] = current_cost  # the no-op is always selectable
        best = int(np.argmin(sel_cost))
        gain = (current_cost - float(sel_cost[best])) / max(abs(current_cost), 1e-9)

        margin = 0.0 if item.hard else self.min_gain
        if best == 0 or gain <= margin:
            if item.hard:
                # orphaned/failed query whose current (parking) placement
                # re-scored best: formally adopt it as the new home
                return MigrationDecision(
                    query_id=item.query_id, action="accept",
                    old=cur_t, new=cur_t, moved=(),
                    migration_mb=0.0, downtime_s=0.0,
                    predicted_cost=current_cost, current_cost=current_cost,
                    gain=0.0, reason="current placement re-scored best",
                    n_candidates=len(cand),
                )
            best_any = int(np.argmin(cost))
            reason = (
                "over migration budget"
                if best_any != 0 and mig_mb[best_any] > self.budget_mb + 1e-9
                else "gain below hysteresis margin"
            )
            return MigrationDecision(
                query_id=item.query_id, action="no-op",
                old=cur_t, new=cur_t, moved=(),
                migration_mb=0.0, downtime_s=0.0,
                predicted_cost=current_cost, current_cost=current_cost,
                gain=gain, reason=reason, n_candidates=len(cand),
            )

        row = cand[best]
        moved = tuple(int(i) for i in np.where(moved_mask[best])[0])
        mb = float(mig_mb[best])
        drain_mb_s = max(
            float(np.mean([n.bandwidth_mbps for n in item.cluster.nodes])) / 8.0, 1.0
        )
        return MigrationDecision(
            query_id=item.query_id, action="migrate",
            old=cur_t, new=tuple(int(x) for x in row), moved=moved,
            migration_mb=mb, downtime_s=RESTART_S + mb / drain_mb_s,
            predicted_cost=float(cost[best]), current_cost=current_cost,
            gain=gain, reason="predicted gain over budgeted move",
            n_candidates=len(cand),
        )

    def replan_many(
        self, items: Sequence[ReplanItem], seed_key: Tuple[int, ...] = (0,)
    ) -> List[MigrationDecision]:
        """Re-plan every affected query; one merged forward when possible."""
        items = list(items)
        if not items:
            return []
        cands = [
            sub_assignment_candidates(
                it, self.replan_k,
                np.random.default_rng(tuple(seed_key) + (it.query_id, 0xBEE5)),
            )
            for it in items
        ]
        scores = self._score_all(items, cands)
        return [self._decide(it, c, s) for it, c, s in zip(items, cands, scores)]
