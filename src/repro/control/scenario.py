"""The canonical drift+failure scenario (benchmark, demo, and doc example).

A deliberately bottom-heavy edge cluster and a fleet of placement-sensitive
queries, calibrated so the scripted events actually bite: an x8 rate drift
saturates whatever host the fleet leans on, and the failed host is the
strongest one — the host the contention-aware initial planner piles onto.
``benchmarks/controller_bench.py`` gates controller behavior on it;
``examples/controller_demo.py`` narrates it.
"""

from __future__ import annotations

from typing import List, Tuple

import numpy as np

from repro.control.telemetry import ScenarioEvent, SimulatorScorer, plan_initial_fleet
from repro.dsps.generator import WorkloadGenerator
from repro.dsps.hardware import Cluster, HardwareNode
from repro.dsps.query import Query


def weak_cluster() -> Cluster:
    """Six hosts spanning the corpus hardware range, deliberately bottom-heavy
    (cpu 100-400 of the corpus' 50-800) so an x8 rate drift saturates whatever
    host the fleet leans on.  Host 3 is the strongest — the oracle initial
    placement piles onto it, which is exactly what the scripted failure
    kills."""
    specs = [
        (300, 8000, 400, 5),
        (200, 4000, 200, 10),
        (150, 4000, 100, 10),
        (400, 16000, 800, 2),
        (100, 2000, 50, 20),
        (300, 8000, 400, 5),
    ]
    return Cluster([HardwareNode(i, *s) for i, s in enumerate(specs)])


def fleet_queries(cluster: Cluster, n: int, seed: int = 7) -> List[Query]:
    """``n`` placement-sensitive linear queries: high event rate (>= 1600/s,
    so drift has teeth) and an achievable sub-100ms e2e latency on this
    cluster (so fleet cost reflects placement, not window waits)."""
    from repro.placement.enumerate import sample_assignment_matrix

    gen = WorkloadGenerator(seed=seed)
    scorer = SimulatorScorer()
    out: List[Query] = []
    i = 0
    while len(out) < n and i < 40 * n:
        q = gen.query(kind="linear", name=f"fleet{i}")
        i += 1
        cand = sample_assignment_matrix(q, cluster, 32, np.random.default_rng(i))
        if not len(cand):
            continue
        s = scorer(q, cluster, cand)
        best = float(np.min(s["latency_e"] + 1e9 * (s["success"] < 0.5)))
        rate = max(op.event_rate for op in q.operators)
        if best < 100.0 and rate >= 1600:
            out.append(q)
    if len(out) < n:
        raise RuntimeError(f"only {len(out)}/{n} scenario queries found")
    return out


def build_scenario(
    n_queries: int, n_ticks: int, seed: int = 7
) -> Tuple[List[Tuple[Query, Tuple[int, ...]]], Cluster, List[ScenarioEvent]]:
    """The frozen drift+failure scenario; returns (fleet, cluster, events)."""
    cluster = weak_cluster()
    queries = fleet_queries(cluster, n_queries, seed=seed)
    fleet = plan_initial_fleet(queries, cluster, k=64, seed=3)
    drift_at = max(4, n_ticks // 5)
    fail_at = n_ticks // 2
    join_at = (3 * n_ticks) // 4
    events = [
        ScenarioEvent(tick=drift_at, kind="rate_drift", query=0, factor=8.0),
        ScenarioEvent(tick=drift_at + 1, kind="rate_drift", query=1, factor=8.0),
        ScenarioEvent(tick=fail_at, kind="fail", host=3),
        ScenarioEvent(
            tick=join_at, kind="join", node=HardwareNode(0, 500, 16000, 1600, 2)
        ),
    ]
    return fleet, cluster, events
