"""``PlacementController``: the closed drift -> detect -> re-place loop.

Per tick: pull one ``FleetSnapshot`` from the runtime, update the drift
detector against each query's *predicted-at-placement-time* cost baseline,
turn alarms into ``ReplanItem``s (freezing every operator the alarm did not
implicate), run the budgeted re-planner — one fused scoring pass for ALL
affected queries — and install accepted migrations.  Three mechanisms keep
the loop stable:

* **Hysteresis** — a migration must beat the current placement by
  ``min_gain`` (predicted, relative); hard events (orphans, failed ticks)
  waive it.
* **Cooldown** — a query that just got a decision is held for
  ``replan_cooldown_ticks`` before the detector may trigger it again, so the
  residual spike caused by the migration itself (downtime, new noise
  baseline) cannot re-trigger a move.  Hard events bypass cooldown: an
  orphaned query is never told to wait.
* **Baseline reset** — after a decision the detector re-arms and the
  predicted-cost baseline becomes the re-planner's score for the installed
  placement, so drift is always measured against what the model promised
  *for the placement that is actually running*.

A fourth, optional mechanism handles estimator brown-outs: when a
``degraded`` probe (typically ``lambda: service.stats.degraded``) reports
that scores are coming from the circuit-breaker's heuristic fallback, soft
drift alarms are deferred for that tick — approximate costs keep the fleet
observable but are not trusted to justify migrations — while hard events
(orphans, failed ticks) re-plan regardless.  Ticks taken in this state are
flagged ``TickRecord.degraded``.

Re-placement latency — alarm to chosen migrations, the wall-clock cost of
the scoring machinery — is recorded per re-plan round and reported as
p50/p95/p99 the same way ``serve.load.LoadReport`` reports service latency:
it is an SLO (gated in ``benchmarks/controller_bench.py``), not a debug
number.  Every knob comes from ``DispatchPolicy`` (docs/dispatch.md).

The whole loop is deterministic given (runtime seed, controller seed): the
decision log of ``run()`` replays bit-identically.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.control.detect import Alarm, DriftDetector
from repro.control.replan import MigrationDecision, ReplanItem, Replanner
from repro.control.telemetry import FleetRuntime, FleetSnapshot
from repro.serve.load import latency_quantiles
from repro.serve.policy import DispatchPolicy, active_policy


@dataclass(frozen=True)
class TickRecord:
    """Everything the controller saw and decided on one tick."""

    tick: int
    fleet_cost_ms: float
    alarms: Tuple[Alarm, ...]
    decisions: Tuple[MigrationDecision, ...]
    replan_latency_s: Optional[float]  # None: no re-plan ran this tick
    degraded: bool = False  # estimator brown-out: soft re-plans deferred

    def n_migrations(self) -> int:
        return sum(1 for d in self.decisions if d.action == "migrate")

    def migrated_mb(self) -> float:
        return float(sum(d.migration_mb for d in self.decisions))


@dataclass
class ControllerReport:
    """Run aggregate: the controller analog of ``serve.load.LoadReport``."""

    n_ticks: int
    records: List[TickRecord]
    final_cost_ms: float  # mean fleet cost over the closing window
    mean_cost_ms: float  # mean fleet cost over the whole run
    n_migrations: int
    n_noops: int
    migrated_mb: float
    max_migration_mb: float  # largest single decision (budget counter-check)
    replan_p50_ms: float
    replan_p95_ms: float
    replan_p99_ms: float
    n_replans: int

    def decision_log(self) -> List[Dict]:
        """Serializable replay log: deterministic for a fixed seed pair."""
        out = []
        for r in self.records:
            for d in r.decisions:
                out.append({"tick": r.tick, **d.to_dict()})
        return out

    def to_dict(self) -> Dict:
        return {
            "n_ticks": self.n_ticks,
            "final_cost_ms": self.final_cost_ms,
            "mean_cost_ms": self.mean_cost_ms,
            "n_migrations": self.n_migrations,
            "n_noops": self.n_noops,
            "migrated_mb": self.migrated_mb,
            "max_migration_mb": self.max_migration_mb,
            "replan_p50_ms": self.replan_p50_ms,
            "replan_p95_ms": self.replan_p95_ms,
            "replan_p99_ms": self.replan_p99_ms,
            "n_replans": self.n_replans,
        }


class PlacementController:
    """Drift-aware incremental re-placement over a ``FleetRuntime``.

    Exactly one of ``estimator`` (a ``CostEstimator`` — the production path,
    riding the fused/merged scorer and its caches) or ``scorer`` (any
    ``(query, cluster, assignments) -> {metric: (N,)}`` callable, e.g. a
    noise-free simulator oracle) provides predictions.  ``replan_every_tick``
    turns the controller into the clairvoyant upper-bound baseline: every
    query re-planned every tick, no cooldown, unbounded budget.
    """

    def __init__(
        self,
        runtime: FleetRuntime,
        estimator=None,
        scorer: Optional[Callable] = None,
        policy: Optional[DispatchPolicy] = None,
        target_metric: str = "latency_e",
        min_gain: float = 0.05,
        seed: int = 0,
        replan_every_tick: bool = False,
        degraded: Optional[Callable[[], bool]] = None,
    ):
        self.runtime = runtime
        #: brown-out probe, e.g. ``lambda: svc.stats.degraded`` — while it
        #: returns True the scorer is answering from the heuristic fallback,
        #: so soft drift alarms are deferred (re-planning on approximate
        #: costs would thrash); hard events (orphans, failures) still re-plan
        self._degraded_probe = degraded
        self.policy = (policy if policy is not None else active_policy()).validate()
        self.seed = int(seed)
        self.replan_every_tick = bool(replan_every_tick)
        budget = np.inf if replan_every_tick else self.policy.migration_budget_mb
        self.replanner = Replanner(
            estimator=estimator,
            scorer=scorer,
            target_metric=target_metric,
            budget_mb=budget,
            replan_k=self.policy.replan_k,
            min_gain=0.0 if replan_every_tick else min_gain,
        )
        self.detector = DriftDetector(
            window=self.policy.detector_window,
            threshold=self.policy.drift_threshold,
        )
        self._pred: Dict[int, float] = {}
        self._cooldown_until: Dict[int, int] = {}
        self.records: List[TickRecord] = []

    # -- scoring helpers ---------------------------------------------------------

    def _score_current(self, qid: int) -> float:
        """Model-predicted cost of the query's current placement — the
        detector baseline recorded at placement time."""
        it = self._item(qid, free_ops=())
        scores = self.replanner._score_all([it], [np.asarray([it.current])])[0]
        return float(scores[self.replanner.target_metric][0])

    def _item(self, qid: int, free_ops: Sequence[int], hard: bool = False) -> ReplanItem:
        rt = self.runtime
        return ReplanItem(
            query_id=qid,
            query=rt.query(qid),
            cluster=rt.observed_cluster(qid),
            current=tuple(int(x) for x in rt.assignment(qid)),
            free_ops=tuple(sorted(set(int(o) for o in free_ops))),
            state_mb=tuple(float(x) for x in rt.state_mb(qid)),
            orphaned=rt.orphans(qid),
            hard=hard,
        )

    # -- alarm -> replan item ----------------------------------------------------

    def _items_from_alarms(self, snap: FleetSnapshot, alarms: Sequence[Alarm]):
        by_query: Dict[int, Alarm] = {}
        for a in alarms:
            prev = by_query.get(a.query_id)
            if prev is None or (a.hard() and not prev.hard()):
                by_query[a.query_id] = a
        items: List[ReplanItem] = []
        for qid, a in sorted(by_query.items()):
            if not a.hard() and snap.tick < self._cooldown_until.get(qid, 0):
                continue  # cooling down; hard events never wait
            assign = self.runtime.assignment(qid)
            orphans = set(self.runtime.orphans(qid))
            on_hot = {i for i, h in enumerate(assign) if int(h) in set(a.hot_hosts)}
            free = orphans | on_hot
            if not free:
                free = set(range(len(assign)))  # whole query implicated
            items.append(self._item(qid, free, hard=a.hard()))
        return items

    # -- the loop ----------------------------------------------------------------

    def step(self) -> TickRecord:
        snap = self.runtime.tick()
        for qid in self.runtime.query_ids:
            if qid not in self._pred:
                self._pred[qid] = self._score_current(qid)
        alarms = self.detector.update(snap, self._pred)

        degraded = bool(self._degraded_probe()) if self._degraded_probe is not None else False
        if self.replan_every_tick:
            items = [
                self._item(qid, range(self.runtime.query(qid).n_ops()), hard=True)
                for qid in self.runtime.query_ids
            ]
        else:
            items = self._items_from_alarms(snap, alarms)
            if degraded:
                # the estimator is browned out: its scores are heuristic
                # fallbacks, good enough to keep serving but not to justify
                # migrations.  Defer drift-triggered moves until it recovers;
                # orphaned/failed queries cannot wait and re-plan anyway.
                items = [it for it in items if it.hard]

        decisions: Tuple[MigrationDecision, ...] = ()
        latency: Optional[float] = None
        if items:
            t0 = time.perf_counter()
            decisions = tuple(
                self.replanner.replan_many(items, seed_key=(self.seed, snap.tick))
            )
            latency = time.perf_counter() - t0
            for d in decisions:
                if d.action == "migrate":
                    self.runtime.apply(d.query_id, d.new, d.downtime_s)
                elif d.action == "accept":
                    self.runtime.adopt(d.query_id)
                # every decision re-arms the detector against the placement
                # the model just (re-)endorsed
                self._pred[d.query_id] = d.predicted_cost
                self.detector.reset(d.query_id)
                self._cooldown_until[d.query_id] = (
                    snap.tick + 1 + self.policy.replan_cooldown_ticks
                )

        rec = TickRecord(
            tick=snap.tick,
            fleet_cost_ms=snap.fleet_cost_ms(),
            alarms=tuple(alarms),
            decisions=decisions,
            replan_latency_s=latency,
            degraded=degraded,
        )
        self.records.append(rec)
        return rec

    def run(self, n_ticks: int, closing_window: Optional[int] = None) -> ControllerReport:
        for _ in range(n_ticks):
            self.step()
        return self.report(closing_window)

    def report(self, closing_window: Optional[int] = None) -> ControllerReport:
        recs = self.records
        costs = [r.fleet_cost_ms for r in recs]
        w = closing_window if closing_window is not None else max(1, len(recs) // 5)
        lat = [r.replan_latency_s for r in recs if r.replan_latency_s is not None]
        p50, p95, p99 = latency_quantiles(lat) if lat else (0.0, 0.0, 0.0)
        return ControllerReport(
            n_ticks=len(recs),
            records=list(recs),
            final_cost_ms=float(np.mean(costs[-w:])) if costs else 0.0,
            mean_cost_ms=float(np.mean(costs)) if costs else 0.0,
            n_migrations=sum(r.n_migrations() for r in recs),
            n_noops=sum(
                1 for r in recs for d in r.decisions if d.action == "no-op"
            ),
            migrated_mb=float(sum(r.migrated_mb() for r in recs)),
            max_migration_mb=float(
                max((d.migration_mb for r in recs for d in r.decisions), default=0.0)
            ),
            replan_p50_ms=p50 * 1e3,
            replan_p95_ms=p95 * 1e3,
            replan_p99_ms=p99 * 1e3,
            n_replans=len(lat),
        )


def run_static(runtime: FleetRuntime, n_ticks: int, closing_window: Optional[int] = None) -> ControllerReport:
    """The do-nothing baseline: tick the fleet, never re-place anything."""
    records = []
    for _ in range(n_ticks):
        snap = runtime.tick()
        records.append(
            TickRecord(
                tick=snap.tick,
                fleet_cost_ms=snap.fleet_cost_ms(),
                alarms=(),
                decisions=(),
                replan_latency_s=None,
            )
        )
    costs = [r.fleet_cost_ms for r in records]
    w = closing_window if closing_window is not None else max(1, len(records) // 5)
    return ControllerReport(
        n_ticks=len(records),
        records=records,
        final_cost_ms=float(np.mean(costs[-w:])) if costs else 0.0,
        mean_cost_ms=float(np.mean(costs)) if costs else 0.0,
        n_migrations=0,
        n_noops=0,
        migrated_mb=0.0,
        max_migration_mb=0.0,
        replan_p50_ms=0.0,
        replan_p95_ms=0.0,
        replan_p99_ms=0.0,
        n_replans=0,
    )
