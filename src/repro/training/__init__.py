"""Training substrate: optimizers, data pipeline, loops, checkpointing,
gradient compression, elastic resharding."""

from repro.training.optim import (
    adam,
    adamw,
    sgd,
    apply_updates,
    cosine_schedule,
    constant_schedule,
    clip_by_global_norm,
    global_norm,
)
from repro.training.batching import (
    BucketSpec,
    GraphDataset,
    batches,
    bucket_dataset,
    bucketed_batches,
    dataset_from_traces,
    n_batches,
    prefetch,
    split_dataset,
    split_indices,
)
from repro.training.checkpoint import save_checkpoint, restore_checkpoint, latest_step
from repro.training.compression import (
    EFState,
    ef_init,
    topk_with_error_feedback,
    int8_quantize,
    int8_dequantize,
    int8_roundtrip,
)
from repro.training.loop import (
    TrainConfig,
    TrainResult,
    train_cost_model,
    train_flat_model,
    predict_flat,
)

__all__ = [k for k in dir() if not k.startswith("_")]
