"""From-scratch optimizers + schedules (no optax in this environment).

Functional API mirroring optax: ``opt.init(params) -> state``;
``opt.update(grads, state, params) -> (updates, state)``; apply with
``apply_updates``. All states are pytrees -> shardable/checkpointable.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = object


class Optimizer(NamedTuple):
    init: Callable[[PyTree], PyTree]
    update: Callable[[PyTree, PyTree, Optional[PyTree]], Tuple[PyTree, PyTree]]


def apply_updates(params: PyTree, updates: PyTree) -> PyTree:
    return jax.tree_util.tree_map(lambda p, u: (p + u).astype(p.dtype), params, updates)


# -- schedules -------------------------------------------------------------------


def constant_schedule(lr: float) -> Callable[[jax.Array], jax.Array]:
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine_schedule(
    peak_lr: float, total_steps: int, warmup_steps: int = 0, final_frac: float = 0.1
) -> Callable[[jax.Array], jax.Array]:
    def sched(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / jnp.maximum(warmup_steps, 1)
        t = jnp.clip(
            (step - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0
        )
        cos = peak_lr * (final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(step < warmup_steps, warm, cos)

    return sched


# -- gradient transforms -----------------------------------------------------------


def global_norm(tree: PyTree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def clip_by_global_norm(tree: PyTree, max_norm: float) -> PyTree:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(lambda x: x * scale, tree)


# -- Adam / AdamW --------------------------------------------------------------------


class AdamState(NamedTuple):
    step: jax.Array
    mu: PyTree
    nu: PyTree


def adam(
    lr: float | Callable = 1e-3,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
    max_grad_norm: Optional[float] = None,
    moment_dtype=jnp.float32,
) -> Optimizer:
    """Adam(W). ``weight_decay`` > 0 gives decoupled AdamW decay."""
    sched = lr if callable(lr) else constant_schedule(lr)

    def init(params):
        zeros = lambda p: jnp.zeros_like(p, dtype=moment_dtype)
        return AdamState(
            step=jnp.zeros((), jnp.int32),
            mu=jax.tree_util.tree_map(zeros, params),
            nu=jax.tree_util.tree_map(zeros, params),
        )

    def update(grads, state, params=None):
        if max_grad_norm is not None:
            grads = clip_by_global_norm(grads, max_grad_norm)
        step = state.step + 1
        b1t = 1.0 - b1 ** step.astype(jnp.float32)
        b2t = 1.0 - b2 ** step.astype(jnp.float32)
        lr_t = sched(step)

        def upd(g, m, v, p):
            g32 = g.astype(jnp.float32)
            m2 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
            v2 = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g32)
            mhat = m2 / b1t
            vhat = v2 / b2t
            delta = -lr_t * mhat / (jnp.sqrt(vhat) + eps)
            if weight_decay > 0.0 and p is not None:
                delta = delta - lr_t * weight_decay * p.astype(jnp.float32)
            return delta, m2.astype(moment_dtype), v2.astype(moment_dtype)

        flat_g, treedef = jax.tree_util.tree_flatten(grads)
        flat_m = treedef.flatten_up_to(state.mu)
        flat_v = treedef.flatten_up_to(state.nu)
        flat_p = treedef.flatten_up_to(params) if params is not None else [None] * len(flat_g)
        out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
        deltas = treedef.unflatten([o[0] for o in out])
        mu = treedef.unflatten([o[1] for o in out])
        nu = treedef.unflatten([o[2] for o in out])
        return deltas, AdamState(step=step, mu=mu, nu=nu)

    return Optimizer(init=init, update=update)


def adamw(
    lr: float | Callable = 1e-3,
    weight_decay: float = 0.01,
    **kw,
) -> Optimizer:
    return adam(lr=lr, weight_decay=weight_decay, **kw)


# -- SGD (used by tests & the monitoring baseline) --------------------------------------


class SGDState(NamedTuple):
    step: jax.Array
    momentum: PyTree


def sgd(lr: float | Callable = 1e-2, momentum: float = 0.0) -> Optimizer:
    sched = lr if callable(lr) else constant_schedule(lr)

    def init(params):
        return SGDState(
            step=jnp.zeros((), jnp.int32),
            momentum=jax.tree_util.tree_map(lambda p: jnp.zeros_like(p, jnp.float32), params),
        )

    def update(grads, state, params=None):
        step = state.step + 1
        lr_t = sched(step)

        def upd(g, m):
            m2 = momentum * m + g.astype(jnp.float32)
            return -lr_t * m2, m2

        flat_g, treedef = jax.tree_util.tree_flatten(grads)
        flat_m = treedef.flatten_up_to(state.momentum)
        out = [upd(g, m) for g, m in zip(flat_g, flat_m)]
        deltas = treedef.unflatten([o[0] for o in out])
        mom = treedef.unflatten([o[1] for o in out])
        return deltas, SGDState(step=step, momentum=mom)

    return Optimizer(init=init, update=update)
