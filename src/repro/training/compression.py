"""Gradient compression for bandwidth-bound data parallelism.

Two schemes usable in the DP all-reduce path (DESIGN.md SS7):

* ``topk``: per-leaf magnitude top-k sparsification with **error feedback**
  (the residual is carried to the next step, guaranteeing convergence under
  standard assumptions). The compressed representation is (values, indices);
  in SPMD the all-reduce moves k values instead of the full leaf.
* ``int8``: symmetric per-leaf int8 quantization with stochastic rounding;
  4x fewer bytes on the wire, unbiased in expectation.

Both expose compress/decompress pairs usable inside shard_map (pre/post
psum), plus an ``EFState`` pytree that is checkpointed with the train state.
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

PyTree = object


class EFState(NamedTuple):
    residual: PyTree  # same structure as grads


def ef_init(params: PyTree) -> EFState:
    return EFState(
        residual=jax.tree_util.tree_map(lambda p: jnp.zeros_like(p, jnp.float32), params)
    )


# -- top-k sparsification ------------------------------------------------------------


def topk_compress(x: jax.Array, frac: float) -> Tuple[jax.Array, jax.Array]:
    """Keep the top ``frac`` fraction of entries by magnitude.

    Returns (values, flat_indices); k is static given the shape.
    """
    flat = x.reshape(-1).astype(jnp.float32)
    k = max(1, int(frac * flat.size))
    vals, idx = jax.lax.top_k(jnp.abs(flat), k)
    vals = flat[idx]
    return vals, idx


def topk_decompress(vals: jax.Array, idx: jax.Array, shape) -> jax.Array:
    flat = jnp.zeros((int(jnp.prod(jnp.asarray(shape))),), jnp.float32)
    flat = flat.at[idx].set(vals)
    return flat.reshape(shape)


def topk_with_error_feedback(
    grads: PyTree, ef: EFState, frac: float
) -> Tuple[PyTree, EFState, float]:
    """grads -> (sparse-reconstructed grads, new EF state, compression ratio)."""

    def per_leaf(g, r):
        acc = g.astype(jnp.float32) + r
        vals, idx = topk_compress(acc, frac)
        recon = topk_decompress(vals, idx, acc.shape)
        return recon, acc - recon

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_r = treedef.flatten_up_to(ef.residual)
    outs = [per_leaf(g, r) for g, r in zip(flat_g, flat_r)]
    recon = treedef.unflatten([o[0] for o in outs])
    resid = treedef.unflatten([o[1] for o in outs])
    return recon, EFState(residual=resid), frac


# -- int8 quantization ------------------------------------------------------------------


def int8_quantize(
    x: jax.Array, key: jax.Array, stochastic: bool = True
) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 with stochastic rounding. Returns (q, scale)."""
    x32 = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(x32)), 1e-12) / 127.0
    y = x32 / scale
    if stochastic:
        noise = jax.random.uniform(key, y.shape, minval=-0.5, maxval=0.5)
        q = jnp.clip(jnp.round(y + noise), -127, 127).astype(jnp.int8)
    else:
        q = jnp.clip(jnp.round(y), -127, 127).astype(jnp.int8)
    return q, scale


def int8_dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def int8_roundtrip(grads: PyTree, key: jax.Array, stochastic: bool = True) -> PyTree:
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    keys = jax.random.split(key, len(leaves))
    out = []
    for g, k in zip(leaves, keys):
        q, s = int8_quantize(g, k, stochastic)
        out.append(int8_dequantize(q, s))
    return treedef.unflatten(out)
