"""Training loops for the COSTREAM cost models + the flat-vector baseline.

The same ``train_cost_model`` drives the single-host CPU path and the SPMD
mesh path: graph batches are sharded over the (pod, data) axes, the stacked
ensemble over ``model``.  Training consumes the unified GNN engine
(docs/forward_engine.md): epochs iterate (n_ops, depth) buckets whose static
``BatchBanding`` keys the jitted step's trace cache, and each step issues ONE
stacked forward for all ensemble members.  Optional gradient compression
(top-k error feedback or int8) is applied in the DP reduction path under
shard_map. Checkpoints are written atomically every ``ckpt_every`` steps;
``resume=True`` continues from the newest one (fault tolerance).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from functools import partial
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.flat_vector import (
    FlatVectorConfig,
    forward_flat,
    init_flat_model,
)
from repro.core.graph import batch_banding
from repro.core.model import (
    CostModelConfig,
    bce_loss,
    ensemble_loss,
    init_cost_model,
    msle_loss,
)
from repro.training import optim
from repro.training.batching import (
    GraphDataset,
    bucket_dataset,
    bucketed_batches,
    n_batches,
    prefetch,
)
from repro.training.checkpoint import restore_checkpoint, save_checkpoint
from repro.training.compression import (
    EFState,
    ef_init,
    int8_roundtrip,
    topk_with_error_feedback,
)


@dataclass
class TrainConfig:
    epochs: int = 30
    batch_size: int = 256
    lr: float = 1e-3
    weight_decay: float = 1e-5
    max_grad_norm: float = 5.0
    seed: int = 0
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 200
    resume: bool = False
    compression: Optional[str] = None  # None | "topk" | "int8"
    # signature-exact row-trimmed stage-3 bands (one trace per distinct query
    # signature instead of per depth class) — worth it for large fixed
    # corpora where every signature class dwarfs a batch (launch/train.py)
    exact_banding: bool = False
    topk_frac: float = 0.05
    early_stop_patience: int = 6
    log_every: int = 50
    verbose: bool = False


@dataclass
class TrainResult:
    params: object
    history: List[Dict[str, float]]
    best_val: float
    steps: int


def _maybe_compress(grads, ef, key, cfg: TrainConfig):
    if cfg.compression == "topk":
        grads, ef, _ = topk_with_error_feedback(grads, ef, cfg.topk_frac)
    elif cfg.compression == "int8":
        grads = int8_roundtrip(grads, key)
    return grads, ef


def train_cost_model(
    dataset_train: GraphDataset,
    dataset_val: GraphDataset,
    model_cfg: CostModelConfig,
    train_cfg: TrainConfig = TrainConfig(),
    init_params=None,
) -> TrainResult:
    key = jax.random.PRNGKey(train_cfg.seed)
    key, init_key = jax.random.split(key)
    params = init_params if init_params is not None else init_cost_model(init_key, model_cfg)

    # bucket once: every epoch then iterates depth-major buckets whose static
    # banding keys the jitted step's trace cache — (n_ops, depth) classes by
    # default, per-signature exact bands under ``exact_banding``
    dataset_train, buckets = bucket_dataset(dataset_train, exact=train_cfg.exact_banding)
    steps_per_epoch = max(1, n_batches(buckets, train_cfg.batch_size))
    total = steps_per_epoch * train_cfg.epochs
    opt = optim.adam(
        lr=optim.cosine_schedule(train_cfg.lr, total, warmup_steps=min(100, total // 10)),
        weight_decay=train_cfg.weight_decay,
        max_grad_norm=train_cfg.max_grad_norm,
    )
    opt_state = opt.init(params)
    ef = ef_init(params)

    start_step = 0
    if train_cfg.resume and train_cfg.ckpt_dir:
        restored, step, _ = restore_checkpoint(
            train_cfg.ckpt_dir, (params, opt_state, ef)
        )
        if restored is not None:
            params, opt_state, ef = restored
            start_step = int(step)

    # ``banding`` is the bucket's static stage-3 plan: part of the jit cache
    # key (one trace per bucket), not a traced operand.  The loss runs ONE
    # stacked engine forward for all ensemble members.
    @partial(jax.jit, static_argnums=(6,), donate_argnums=(0, 1, 2))
    def train_step(params, opt_state, ef, g, y, key, banding):
        def loss(p):
            return ensemble_loss(p, g, y, model_cfg, banding)

        loss_val, grads = jax.value_and_grad(loss)(params)
        grads, ef = _maybe_compress(grads, ef, key, train_cfg)
        updates, opt_state = opt.update(grads, opt_state, params)
        params = optim.apply_updates(params, updates)
        return params, opt_state, ef, loss_val

    @partial(jax.jit, static_argnums=(3,))
    def val_loss_fn(params, g, y, banding):
        return ensemble_loss(params, g, y, model_cfg, banding) / model_cfg.n_ensemble

    rng = np.random.default_rng(train_cfg.seed + 1)
    history: List[Dict[str, float]] = []
    best_val = float("inf")
    best_params = params
    bad_epochs = 0
    step = start_step

    val_g = jax.tree_util.tree_map(jnp.asarray, dataset_val.graphs)
    val_y = jnp.asarray(dataset_val.labels)
    val_banding = batch_banding(dataset_val.graphs) if len(dataset_val) else None

    for epoch in range(train_cfg.epochs):
        t0 = time.time()
        epoch_losses = []
        # prefetch worker produces device-resident depth-major batches
        it = prefetch(
            bucketed_batches(
                dataset_train, buckets, train_cfg.batch_size, rng=rng, device=True
            )
        )
        for g, y, banding in it:
            key, sub = jax.random.split(key)
            params, opt_state, ef, loss_val = train_step(
                params, opt_state, ef, g, y, sub, banding
            )
            epoch_losses.append(float(loss_val))
            step += 1
            if train_cfg.ckpt_dir and step % train_cfg.ckpt_every == 0:
                save_checkpoint(train_cfg.ckpt_dir, step, (params, opt_state, ef))
        vl = (
            float(val_loss_fn(params, val_g, val_y, val_banding))
            if len(dataset_val)
            else float("nan")
        )
        history.append(
            {
                "epoch": epoch,
                "train_loss": float(np.mean(epoch_losses)),
                "val_loss": vl,
                "seconds": time.time() - t0,
            }
        )
        if train_cfg.verbose:
            print(
                f"[{model_cfg.metric}] epoch {epoch} train {history[-1]['train_loss']:.4f} "
                f"val {vl:.4f} ({history[-1]['seconds']:.1f}s)"
            )
        if vl < best_val - 1e-4:
            best_val = vl
            # snapshot to host numpy: live device buffers would be deleted by
            # buffer donation in later train steps
            best_params = jax.tree_util.tree_map(np.asarray, params)
            bad_epochs = 0
        else:
            bad_epochs += 1
            if bad_epochs >= train_cfg.early_stop_patience:
                break

    if train_cfg.ckpt_dir:
        save_checkpoint(train_cfg.ckpt_dir, step, (best_params, opt_state, ef))
    return TrainResult(params=best_params, history=history, best_val=best_val, steps=step)


# -- flat-vector baseline ---------------------------------------------------------------


def train_flat_model(
    x_train: np.ndarray,
    y_train: np.ndarray,
    x_val: np.ndarray,
    y_val: np.ndarray,
    cfg: FlatVectorConfig,
    train_cfg: TrainConfig = TrainConfig(),
):
    key = jax.random.PRNGKey(train_cfg.seed)
    key, init_key = jax.random.split(key)
    params = init_flat_model(init_key, cfg)
    steps_per_epoch = max(1, len(x_train) // train_cfg.batch_size)
    total = steps_per_epoch * train_cfg.epochs
    opt = optim.adam(
        lr=optim.cosine_schedule(train_cfg.lr, total, warmup_steps=min(100, total // 10)),
        weight_decay=train_cfg.weight_decay,
        max_grad_norm=train_cfg.max_grad_norm,
    )
    opt_state = opt.init(params)
    base_loss = msle_loss if cfg.task == "regression" else bce_loss

    @partial(jax.jit, donate_argnums=(0, 1))
    def train_step(params, opt_state, x, y):
        def loss(p):
            return base_loss(forward_flat(p, x), y)

        loss_val, grads = jax.value_and_grad(loss)(params)
        updates, opt_state = opt.update(grads, opt_state, params)
        return optim.apply_updates(params, updates), opt_state, loss_val

    @jax.jit
    def val_loss_fn(params):
        return base_loss(forward_flat(params, jnp.asarray(x_val)), jnp.asarray(y_val))

    rng = np.random.default_rng(train_cfg.seed)
    best_val, best_params, bad = float("inf"), params, 0
    for epoch in range(train_cfg.epochs):
        order = rng.permutation(len(x_train))
        for s in range(0, len(order), train_cfg.batch_size):
            idx = order[s : s + train_cfg.batch_size]
            if idx.size < 2:
                continue
            params, opt_state, _ = train_step(
                params, opt_state, jnp.asarray(x_train[idx]), jnp.asarray(y_train[idx])
            )
        vl = float(val_loss_fn(params)) if len(x_val) else float("nan")
        if vl < best_val - 1e-4:
            # host snapshot: later donated steps delete the device buffers
            best_val, best_params, bad = vl, jax.tree_util.tree_map(np.asarray, params), 0
        else:
            bad += 1
            if bad >= train_cfg.early_stop_patience:
                break
    return best_params


def predict_flat(params, x: np.ndarray, task: str) -> np.ndarray:
    raw = np.asarray(forward_flat(params, jnp.asarray(x)))
    if task == "regression":
        return np.expm1(raw).clip(min=0.0)
    return (raw > 0).astype(np.int64)
