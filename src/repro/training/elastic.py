"""Elastic scaling: re-shard a training state onto a different mesh.

On node failure the launcher rebuilds a smaller mesh from surviving hosts and
resumes from the latest checkpoint; on capacity recovery it grows back. Since
checkpoints are stored as full (unsharded) host arrays, resharding is a
device_put with the new mesh's NamedShardings — the sharding rules re-resolve
against the new mesh sizes automatically (divisibility-aware), so e.g. an
FSDP axis that shrank from 16 to 8 hosts still lays out correctly.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

from repro.models.params import ShardingRules, is_def, shardings


def reshard_state(state, target_shardings):
    """Place a host-side pytree onto devices with new shardings."""
    return jax.tree_util.tree_map(
        lambda x, s: jax.device_put(np.asarray(x), s), state, target_shardings
    )


def shrink_mesh_shape(shape: Tuple[int, ...], axes: Tuple[str, ...], axis: str, by: int):
    """Shrink one mesh axis (e.g. lose a data-parallel slice)."""
    out = []
    for a, s in zip(axes, shape):
        if a == axis:
            assert s % by == 0 and s // by >= 1, (a, s, by)
            out.append(s // by)
        else:
            out.append(s)
    return tuple(out)


def validate_global_batch(global_batch: int, mesh, data_axes=("pod", "data")) -> int:
    """Per-replica batch after an elastic change; raises if indivisible."""
    n = 1
    for a in data_axes:
        if a in mesh.shape:
            n *= mesh.shape[a]
    assert global_batch % n == 0, (
        f"global batch {global_batch} not divisible by data parallelism {n}"
    )
    return global_batch // n
