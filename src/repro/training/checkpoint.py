"""Fault-tolerant checkpointing: atomic pytree snapshots + resume.

Layout: ``<dir>/step_<n>/arrays.npz`` + ``manifest.json``. Writes go to a
temp directory first and are atomically renamed, so a crash mid-write never
corrupts the latest checkpoint (restart safety on preemption). A ``latest``
pointer file is updated last. Non-array state (step counters, RNG keys, mesh
shape) lives in the manifest for elastic-restart validation.
"""

from __future__ import annotations

import json
import os
import shutil
import tempfile
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

SEP = "/"


def _flatten_with_paths(tree) -> List[Tuple[str, np.ndarray]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = SEP.join(_path_str(p) for p in path)
        out.append((key, np.asarray(leaf)))
    return out


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def save_checkpoint(
    directory: str,
    step: int,
    state,
    extra: Optional[Dict[str, Any]] = None,
    keep: int = 3,
) -> str:
    """Atomically persist ``state`` (a pytree) at ``step``."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:010d}")
    tmp = tempfile.mkdtemp(dir=directory, prefix=".tmp_ckpt_")
    try:
        arrays = dict(_flatten_with_paths(state))
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        manifest = {
            "step": int(step),
            "time": time.time(),
            "keys": sorted(arrays.keys()),
            "extra": extra or {},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic on same filesystem
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    # update the 'latest' pointer last (atomic replace)
    ptr_tmp = os.path.join(directory, ".latest.tmp")
    with open(ptr_tmp, "w") as f:
        f.write(os.path.basename(final))
    os.replace(ptr_tmp, os.path.join(directory, "latest"))
    _gc_old(directory, keep)
    return final


def _gc_old(directory: str, keep: int) -> None:
    steps = sorted(d for d in os.listdir(directory) if d.startswith("step_"))
    for d in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(directory, d), ignore_errors=True)


def latest_step(directory: str) -> Optional[int]:
    ptr = os.path.join(directory, "latest")
    if not os.path.exists(ptr):
        return None
    with open(ptr) as f:
        name = f.read().strip()
    if not os.path.isdir(os.path.join(directory, name)):
        # pointer ahead of a crashed write: fall back to newest complete dir
        steps = sorted(d for d in os.listdir(directory) if d.startswith("step_"))
        if not steps:
            return None
        name = steps[-1]
    return int(name.split("_")[1])


def restore_checkpoint(directory: str, like, step: Optional[int] = None):
    """Restore a pytree of the same structure as ``like``.

    Returns (state, step, extra) or (None, None, None) when nothing exists.
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            return None, None, None
    path = os.path.join(directory, f"step_{step:010d}")
    with np.load(os.path.join(path, "arrays.npz")) as data:
        arrays = {k: data[k] for k in data.files}
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)

    leaves_with_paths = jax.tree_util.tree_flatten_with_path(like)[0]
    treedef = jax.tree_util.tree_structure(like)
    new_leaves = []
    for pth, leaf in leaves_with_paths:
        key = SEP.join(_path_str(p) for p in pth)
        if key not in arrays:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = arrays[key]
        want = np.asarray(leaf)
        if tuple(arr.shape) != tuple(want.shape):
            raise ValueError(f"shape mismatch for {key}: {arr.shape} vs {want.shape}")
        new_leaves.append(arr.astype(want.dtype))
    state = jax.tree_util.tree_unflatten(treedef, new_leaves)
    return state, manifest["step"], manifest.get("extra", {})
