"""Host-side data pipeline: trace corpus -> padded graph batches.

Features are materialized once (numpy), then an epoch iterator yields jnp
batches. ``pad_to_multiple`` keeps shapes static for jit; a background
prefetch thread overlaps host featurization with device compute.
"""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Iterator, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import JointGraph, batch_graphs, build_graph
from repro.core.model import label_array
from repro.dsps.generator import Trace


@dataclass
class GraphDataset:
    graphs: JointGraph  # batched numpy arrays, leading dim = N
    labels: np.ndarray  # (N,) for the selected metric

    def __len__(self) -> int:
        return int(self.graphs.op_x.shape[0])

    def select(self, idx: np.ndarray) -> "GraphDataset":
        g = JointGraph(*[getattr(self.graphs, f)[idx] for f in JointGraph._fields])
        return GraphDataset(graphs=g, labels=self.labels[idx])


def dataset_from_traces(
    traces: List[Trace], metric: str, transform=None
) -> GraphDataset:
    singles = [build_graph(t.query, t.cluster, t.placement) for t in traces]
    if transform is not None:
        singles = [transform(g) for g in singles]
    return GraphDataset(graphs=batch_graphs(singles), labels=label_array(traces, metric))


def split_dataset(
    ds: GraphDataset, fractions: Tuple[float, float, float] = (0.8, 0.1, 0.1), seed: int = 0
) -> Tuple[GraphDataset, GraphDataset, GraphDataset]:
    """train/val/test split (paper: 80/10/10)."""
    n = len(ds)
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n)
    n_tr = int(fractions[0] * n)
    n_va = int(fractions[1] * n)
    return (
        ds.select(perm[:n_tr]),
        ds.select(perm[n_tr : n_tr + n_va]),
        ds.select(perm[n_tr + n_va :]),
    )


def batches(
    ds: GraphDataset,
    batch_size: int,
    rng: Optional[np.random.Generator] = None,
    drop_remainder: bool = False,
) -> Iterator[Tuple[JointGraph, np.ndarray]]:
    n = len(ds)
    order = rng.permutation(n) if rng is not None else np.arange(n)
    for start in range(0, n, batch_size):
        idx = order[start : start + batch_size]
        if drop_remainder and idx.size < batch_size:
            return
        if idx.size < batch_size:
            # pad by repeating (mask via weights is unnecessary: eval uses
            # unpadded path; training tolerates duplicate samples in the tail)
            reps = np.concatenate([idx, order[: batch_size - idx.size]])
            idx = reps
        sub = ds.select(idx)
        yield sub.graphs, sub.labels


def prefetch(it: Iterator, size: int = 2) -> Iterator:
    """Background-thread prefetch (overlaps host prep with device compute)."""
    q: queue.Queue = queue.Queue(maxsize=size)
    stop = object()

    def worker():
        try:
            for item in it:
                q.put(item)
        finally:
            q.put(stop)

    t = threading.Thread(target=worker, daemon=True)
    t.start()
    while True:
        item = q.get()
        if item is stop:
            return
        yield item
