"""Host-side data pipeline: trace corpus -> bucketed depth-major graph batches.

Features are materialized once (numpy); an epoch iterator then yields
device-ready jnp batches.  Padding policy is shared with the placement
scorer via ``core/bucketing.py``; a background prefetch thread
(``prefetch``) overlaps host featurization + device transfer with compute.

The training iterator is **bucketed by (n_ops, depth)** (``bucket_dataset``
/ ``bucketed_batches``): graphs of one bucket share a static
``graph.BatchBanding`` stage-3 plan, so the jitted train step compiles once
per bucket and each step runs only the bucket's non-empty depth levels at
their banded row spans, instead of MAX_DEPTH full-width sweeps.
"""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from itertools import groupby
from typing import Iterator, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bucketing import batch_banding_cached, exact_banding_cached
from repro.core.graph import (
    BatchBanding,
    JointGraph,
    batch_graphs,
    build_graph,
)
from repro.core.model import label_array
from repro.dsps.generator import Trace


@dataclass
class GraphDataset:
    graphs: JointGraph  # batched numpy arrays, leading dim = N
    labels: np.ndarray  # (N,) for the selected metric

    def __len__(self) -> int:
        return int(self.graphs.op_x.shape[0])

    def select(self, idx: Union[np.ndarray, slice]) -> "GraphDataset":
        """Row subset.  A ``slice`` (or a contiguous, step-1 index vector) is
        applied as a numpy view — zero copies of the eight graph fields — the
        epoch-shuffling hot path re-slices buckets every epoch and fancy
        indexing re-materialized the whole ``JointGraph`` each time."""
        if not isinstance(idx, slice):
            idx = np.asarray(idx)
            # guards: a boolean mask can compare element-equal to an arange
            # (True == 1) but means something else, and a negative start
            # would turn into a slice crossing the end of the array
            if (
                idx.ndim == 1
                and idx.size
                and idx.dtype != np.bool_
                and int(idx[0]) >= 0
                and np.array_equal(idx, np.arange(int(idx[0]), int(idx[0]) + idx.size))
            ):
                idx = slice(int(idx[0]), int(idx[0]) + idx.size)
        g = JointGraph(*[getattr(self.graphs, f)[idx] for f in JointGraph._fields])
        return GraphDataset(graphs=g, labels=self.labels[idx])


def dataset_from_traces(
    traces: List[Trace], metric: str, transform=None
) -> GraphDataset:
    singles = [build_graph(t.query, t.cluster, t.placement) for t in traces]
    if transform is not None:
        singles = [transform(g) for g in singles]
    return GraphDataset(graphs=batch_graphs(singles), labels=label_array(traces, metric))


def split_indices(
    n: int, fractions: Tuple[float, float, float] = (0.8, 0.1, 0.1), seed: int = 0
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Deterministic train/val/test index split (paper: 80/10/10).

    The permutation is derived from the raw PCG64 bit stream
    (``np.random.PCG64(seed).random_raw``) — the one stream numpy's
    compatibility policy (NEP 19) pins across releases.  ``Generator``
    distribution methods like ``permutation`` are explicitly allowed to
    change between versions, which would silently re-partition the corpus on
    an upgrade; argsort of the raw draws carries the bit stream's guarantee
    (a regression test pins the exact indices).  The single source of truth
    for split membership: reuse it wherever a sibling pipeline (e.g. the
    flat-vector baseline) must see the same trace partition.
    """
    perm = np.argsort(np.random.PCG64(seed).random_raw(n), kind="stable")
    n_tr = int(fractions[0] * n)
    n_va = int(fractions[1] * n)
    return perm[:n_tr], perm[n_tr : n_tr + n_va], perm[n_tr + n_va :]


def split_dataset(
    ds: GraphDataset, fractions: Tuple[float, float, float] = (0.8, 0.1, 0.1), seed: int = 0
) -> Tuple[GraphDataset, GraphDataset, GraphDataset]:
    """train/val/test split (paper: 80/10/10); see ``split_indices``."""
    tr, va, te = split_indices(len(ds), fractions, seed)
    return ds.select(tr), ds.select(va), ds.select(te)


def batches(
    ds: GraphDataset,
    batch_size: int,
    rng: Optional[np.random.Generator] = None,
    drop_remainder: bool = False,
) -> Iterator[Tuple[JointGraph, np.ndarray]]:
    """Plain (un-bucketed) epoch iterator; kept for eval and simple callers."""
    n = len(ds)
    order = rng.permutation(n) if rng is not None else np.arange(n)
    for start in range(0, n, batch_size):
        idx = order[start : start + batch_size]
        if drop_remainder and idx.size < batch_size:
            return
        if idx.size < batch_size:
            # pad by repeating (mask via weights is unnecessary: eval uses
            # unpadded path; training tolerates duplicate samples in the tail)
            idx = np.concatenate([idx, order[: batch_size - idx.size]])
        sub = ds.select(idx)
        yield sub.graphs, sub.labels


# -- (n_ops, depth)-bucketed iteration (the training fast path) -----------------


@dataclass(frozen=True)
class BucketSpec:
    """One bucket: a contiguous row range of the resorted dataset plus its
    static stage-3 banding (shared by every batch drawn from the bucket — the
    jit cache key).  Conservative buckets group by (n_ops, depth); exact
    buckets group by the full per-row (type, depth) signature."""

    n_ops: int
    depth: int
    start: int
    stop: int
    banding: BatchBanding

    def __len__(self) -> int:
        return self.stop - self.start


def bucket_dataset(
    ds: GraphDataset, exact: bool = False
) -> Tuple[GraphDataset, Tuple[BucketSpec, ...]]:
    """Sort the dataset into banding buckets and describe them.

    Returns the resorted dataset (one fancy-index pass — per-epoch work then
    selects contiguous views) and one ``BucketSpec`` per bucket.

    ``exact=False`` (default): stable-sort by (depth, n_ops), one bucket per
    distinct (n_ops, depth) key.  Same-depth buckets share one conservative
    banding, computed over the whole contiguous depth class: measured on CPU,
    its wider spans cost nothing against the dominant win (scanning ``depth``
    levels instead of MAX_DEPTH), while the jitted step then compiles once
    per *depth class* (~4 traces per corpus) instead of once per
    (n_ops, depth) pair (~16).  Every sub-batch of the class — padding
    included — is covered by the shared plan.

    ``exact=True``: one bucket per distinct per-row (type, depth)
    *signature* (``bucketing.batch_signature``), each carrying its
    signature-exact row-trimmed banding — stage work proportional to real
    rows, at the cost of one trace per signature (more traces only where
    signatures actually differ) and per-signature epoch tails.  The right
    trade for large fixed corpora (``launch/train.py``) where every
    signature class is much larger than a batch.

    Either way the bandings come from the signature-keyed cache, so repeated
    bucketing of views over one corpus (train/val splits, re-bucketing per
    stage) never recomputes a plan.
    """
    if not len(ds):
        return ds, ()
    mask = np.asarray(ds.graphs.op_mask) > 0
    n_ops = mask.sum(axis=-1).astype(np.int64)
    depth = (np.asarray(ds.graphs.op_depth) * mask).max(axis=-1).astype(np.int64)
    if exact:
        sig = np.where(mask, np.asarray(ds.graphs.op_depth), -1).astype(np.int64)
        _, inverse = np.unique(sig, axis=0, return_inverse=True)
        # secondary keys keep signature classes inside depth-major order
        order = np.lexsort((inverse, n_ops, depth))
        class_of = inverse[order]
    else:
        # depth-primary so buckets sharing a banding (= a depth class) stay
        # contiguous: bucketed_batches draws batches per banding group
        order = np.lexsort((n_ops, depth))
        class_of = None
    ds = ds.select(order)
    n_ops, depth = n_ops[order], depth[order]
    if exact:
        bounds = np.flatnonzero(np.diff(class_of) != 0)
    else:
        bounds = np.flatnonzero((np.diff(n_ops) != 0) | (np.diff(depth) != 0))
        shared = {}
        for d in np.unique(depth):
            rows = np.flatnonzero(depth == d)  # contiguous after the sort
            shared[int(d)] = _class_banding(ds, int(rows[0]), int(rows[-1]) + 1, exact=False)
    starts = np.concatenate([[0], bounds + 1])
    stops = np.concatenate([bounds + 1, [len(ds)]])
    buckets = tuple(
        BucketSpec(
            n_ops=int(n_ops[a]),
            depth=int(depth[a]),
            start=int(a),
            stop=int(b),
            banding=(
                _class_banding(ds, int(a), int(b), exact=True)
                if exact
                else shared[int(depth[a])]
            ),
        )
        for a, b in zip(starts, stops)
    )
    return ds, buckets


def _class_banding(ds: GraphDataset, start: int, stop: int, exact: bool) -> BatchBanding:
    """Banding for one contiguous class, via the signature-keyed cache.

    Both flavors key on ``bucketing.batch_signature`` — a banding is a pure
    function of the signature set — so zero-copy views over the same corpus
    rows (train/val splits, repeated ``bucket_dataset`` calls, merged serving
    chunks) reuse one cached plan instead of recomputing per view.
    """
    g = ds.select(slice(start, stop)).graphs
    return exact_banding_cached(g) if exact else batch_banding_cached(g)


def _banding_groups(buckets: Sequence[BucketSpec]):
    """Consecutive buckets sharing a banding (one group per depth class)."""
    return [
        (banding, list(group))
        for banding, group in groupby(buckets, key=lambda b: b.banding)
    ]


def bucketed_batches(
    ds: GraphDataset,
    buckets: Sequence[BucketSpec],
    batch_size: int,
    rng: Optional[np.random.Generator] = None,
    device: bool = False,
) -> Iterator[Tuple[JointGraph, np.ndarray, BatchBanding]]:
    """Depth-major epoch iterator over a ``bucket_dataset`` result.

    Yields ``(graphs, labels, banding)`` with every batch drawn from a single
    *banding group* (the contiguous buckets of one depth class — they share
    the static plan AND the padded batch shape, so mixing them in a batch is
    free).  Only each group's single epoch tail is padded to ``batch_size``,
    by wrapping the group's own (shuffled) order — the seed iterator's
    policy, applied per group: at most ``batch_size - 1`` duplicate samples
    per group per epoch.  Padding per-bucket tails instead would over-weight
    rare (n_ops, depth) shapes by up to batch_size/len(bucket) in the summed
    loss.  ``rng`` shuffles within buckets and interleaves the batch order
    across groups.  ``device=True`` converts to device arrays inside the
    iterator — under ``prefetch`` the transfer then runs on the worker
    thread, overlapped with the previous step's compute.
    """
    plan = []
    for banding, group in _banding_groups(buckets):
        parts = []
        for b in group:
            part = np.arange(b.start, b.stop)
            parts.append(rng.permutation(part) if rng is not None else part)
        idx = np.concatenate(parts)
        for s in range(0, len(idx), batch_size):
            take = idx[s : s + batch_size]
            if take.size < batch_size:  # wrap the group's order, like the seed
                take = np.concatenate([take, np.resize(idx, batch_size - take.size)])
            plan.append((take, banding))
    if rng is not None:
        plan = [plan[i] for i in rng.permutation(len(plan))]
    for take, banding in plan:
        sub = ds.select(take)
        g, y = sub.graphs, sub.labels
        if device:
            g = jax.tree_util.tree_map(jnp.asarray, g)
            y = jnp.asarray(y)
        yield g, y, banding


def n_batches(buckets: Sequence[BucketSpec], batch_size: int) -> int:
    """Steps per epoch of ``bucketed_batches`` (for LR schedules)."""
    return sum(
        -(-sum(len(b) for b in group) // batch_size)
        for _, group in _banding_groups(buckets)
    )


def prefetch(it: Iterator, size: int = 2) -> Iterator:
    """Background-thread prefetch (overlaps host prep with device compute)."""
    q: queue.Queue = queue.Queue(maxsize=size)
    stop = object()

    def worker():
        try:
            for item in it:
                q.put(item)
        finally:
            q.put(stop)

    t = threading.Thread(target=worker, daemon=True)
    t.start()
    while True:
        item = q.get()
        if item is stop:
            return
        yield item
