"""Property-testing compat layer: real ``hypothesis`` when installed, else a
seeded-random fallback.

The suite only uses a small subset of hypothesis — ``@given`` over
``st.integers(lo, hi)`` / ``st.floats(lo, hi, allow_nan=False)`` plus
``@settings(max_examples=..., deadline=...)`` — so the fallback implements
exactly that: each ``@given`` test becomes a single pytest test that draws
``max_examples`` example tuples from a deterministic per-test RNG and runs the
body once per tuple.  Draws are reproducible across runs and machines (seeded
from the test name), so failures are repeatable; the failing example values
are attached to the assertion via ``pytest.fail`` chaining.

Usage (identical under both backends):

    from _propcheck import given, settings, strategies as st
"""

from __future__ import annotations

try:  # pragma: no cover - exercised only when hypothesis is installed
    from hypothesis import given, settings, strategies

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import functools
    import zlib

    import numpy as np

    DEFAULT_MAX_EXAMPLES = 20

    class _Strategy:
        """A draw rule: maps an ``np.random.Generator`` to one example."""

        def __init__(self, draw, label):
            self._draw = draw
            self.label = label

        def example(self, rng):
            return self._draw(rng)

        def __repr__(self):
            return self.label

    class strategies:  # noqa: N801 - mirrors the hypothesis module name
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)),
                f"integers({min_value}, {max_value})",
            )

        @staticmethod
        def floats(min_value, max_value, allow_nan=False, allow_infinity=False):
            # uniform over [lo, hi]; hypothesis shrinks/edge-biases, we don't —
            # determinism and bounds are what the suite relies on.
            return _Strategy(
                lambda rng: float(rng.uniform(min_value, max_value)),
                f"floats({min_value}, {max_value})",
            )

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.integers(0, 2)), "booleans()")

        @staticmethod
        def sampled_from(elements):
            pool = list(elements)
            return _Strategy(
                lambda rng: pool[int(rng.integers(0, len(pool)))],
                f"sampled_from({pool!r})",
            )

    def given(*strats, **kw_strats):
        def decorate(fn):
            @functools.wraps(fn)
            def runner(**fixtures):
                n = getattr(runner, "_propcheck_max_examples", DEFAULT_MAX_EXAMPLES)
                rng = np.random.default_rng(zlib.crc32(fn.__qualname__.encode()))
                for i in range(n):
                    args = tuple(s.example(rng) for s in strats)
                    kwargs = {k: s.example(rng) for k, s in kw_strats.items()}
                    try:
                        fn(*args, **kwargs, **fixtures)
                    except BaseException as e:
                        raise AssertionError(
                            f"falsifying example #{i + 1}/{n}: "
                            f"args={args!r} kwargs={kwargs!r}"
                        ) from e

            # hide the strategy-bound parameters from pytest's fixture
            # resolution: the wrapper only exposes genuinely free parameters.
            runner._propcheck_max_examples = DEFAULT_MAX_EXAMPLES
            runner.__signature__ = _free_signature(fn, len(strats), set(kw_strats))
            return runner

        return decorate

    def settings(max_examples=DEFAULT_MAX_EXAMPLES, deadline=None, **_ignored):
        def decorate(fn):
            fn._propcheck_max_examples = max_examples
            return fn

        return decorate

    def _free_signature(fn, n_positional, kw_names):
        import inspect

        sig = inspect.signature(fn)
        params = list(sig.parameters.values())[n_positional:]
        params = [p for p in params if p.name not in kw_names]
        return sig.replace(parameters=params)


st = strategies

__all__ = ["given", "settings", "strategies", "st", "HAVE_HYPOTHESIS"]
