"""Training substrate: optimizers, checkpointing, compression, batching."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _propcheck import given, settings, strategies as st

from repro.training import (
    adam,
    apply_updates,
    batches,
    bucket_dataset,
    bucketed_batches,
    clip_by_global_norm,
    cosine_schedule,
    dataset_from_traces,
    ef_init,
    global_norm,
    int8_dequantize,
    int8_quantize,
    int8_roundtrip,
    latest_step,
    n_batches,
    prefetch,
    restore_checkpoint,
    save_checkpoint,
    sgd,
    split_dataset,
    split_indices,
    topk_with_error_feedback,
)
from repro.training.elastic import shrink_mesh_shape, validate_global_batch
from repro.dsps import WorkloadGenerator


def test_adam_minimizes_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    opt = adam(lr=0.2)
    state = opt.init(params)
    for _ in range(150):
        grads = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        updates, state = opt.update(grads, state, params)
        params = apply_updates(params, updates)
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_sgd_momentum():
    params = {"w": jnp.asarray(4.0)}
    opt = sgd(lr=0.1, momentum=0.9)
    state = opt.init(params)
    for _ in range(100):
        grads = jax.grad(lambda p: p["w"] ** 2)(params)
        updates, state = opt.update(grads, state)
        params = apply_updates(params, updates)
    assert abs(float(params["w"])) < 0.1


def test_clip_by_global_norm():
    tree = {"a": jnp.ones((10,)) * 10.0}
    clipped = clip_by_global_norm(tree, 1.0)
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-5


def test_cosine_schedule_shape():
    s = cosine_schedule(1.0, 100, warmup_steps=10)
    assert float(s(0)) == 0.0
    assert abs(float(s(10)) - 1.0) < 1e-6
    assert float(s(100)) <= 0.2
    assert float(s(55)) < float(s(11))


def test_checkpoint_roundtrip(tmp_path):
    state = {"a": np.arange(6).reshape(2, 3).astype(np.float32), "b": {"c": np.ones(4)}}
    d = str(tmp_path / "ck")
    save_checkpoint(d, 5, state)
    save_checkpoint(d, 9, jax.tree_util.tree_map(lambda x: x * 2, state))
    assert latest_step(d) == 9
    restored, step, _ = restore_checkpoint(d, state)
    assert step == 9
    np.testing.assert_allclose(restored["a"], state["a"] * 2)


def test_checkpoint_gc(tmp_path):
    d = str(tmp_path / "ck")
    for s in range(6):
        save_checkpoint(d, s, {"x": np.ones(2)}, keep=2)
    dirs = [p for p in os.listdir(d) if p.startswith("step_")]
    assert len(dirs) == 2


def test_checkpoint_resume_after_crash(tmp_path):
    """A stale 'latest' pointer falls back to the newest complete dir."""
    d = str(tmp_path / "ck")
    save_checkpoint(d, 3, {"x": np.ones(2)})
    with open(os.path.join(d, "latest"), "w") as f:
        f.write("step_9999999999")  # simulates crash between write and rename
    assert latest_step(d) == 3


def test_topk_error_feedback_accumulates():
    grads = {"w": jnp.asarray([1.0, 0.1, 0.01, 0.001])}
    ef = ef_init(grads)
    recon, ef, _ = topk_with_error_feedback(grads, ef, frac=0.25)
    # only the largest entry survives; dropped mass lands in the residual
    assert float(recon["w"][0]) == pytest.approx(1.0)
    assert float(recon["w"][1]) == 0.0
    assert float(ef.residual["w"][1]) == pytest.approx(0.1, rel=1e-5)
    # residual accumulates every step and is eventually transmitted: after
    # enough steps, entry 1's accumulated value exceeds the fresh 1.0 grad
    sent_at = None
    for it in range(12):
        recon, ef, _frac = topk_with_error_feedback(grads, ef, frac=0.25)
        if float(recon["w"][1]) > 0:
            sent_at = it
            break
    assert sent_at is not None, "error feedback never transmitted the small coordinate"
    # nothing is lost: transmitted + residual == accumulated stream
    total = float(recon["w"][1]) + float(ef.residual["w"][1])
    assert total == pytest.approx(0.1 * (sent_at + 2), rel=1e-3)


def test_int8_quantization_bound():
    x = jnp.linspace(-3.0, 3.0, 100)
    q, scale = int8_quantize(x, jax.random.PRNGKey(0), stochastic=False)
    err = jnp.abs(int8_dequantize(q, scale) - x)
    assert float(err.max()) <= float(scale) * 0.51


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 1000))
def test_int8_stochastic_unbiased(seed):
    x = jnp.full((2048,), 0.3)
    out = int8_roundtrip({"x": x}, jax.random.PRNGKey(seed))["x"]
    assert abs(float(out.mean()) - 0.3) < 0.01


def test_batching_and_split():
    traces = WorkloadGenerator(seed=2).corpus(50)
    ds = dataset_from_traces(traces, "throughput")
    tr, va, te = split_dataset(ds, (0.8, 0.1, 0.1), seed=0)
    assert len(tr) == 40 and len(va) == 5 and len(te) == 5
    got = 0
    for g, y in batches(tr, 16, rng=np.random.default_rng(0)):
        assert g.op_x.shape[0] == 16  # padded tail
        got += 1
    assert got == 3


def test_split_indices_regression():
    """The 80/10/10 split must be identical on every numpy version: the split
    permutation is argsort of the raw PCG64 bit stream — the one stream
    NEP 19 pins across releases (Generator.permutation is NOT pinned) — and
    these literal indices freeze it."""
    tr, va, te = split_indices(10, seed=0)
    assert list(tr) == [3, 2, 1, 8, 6, 0, 7, 4]
    assert list(va) == [5]
    assert list(te) == [9]
    # disjoint cover for a second (n, seed) pair
    tr, va, te = split_indices(12, (0.8, 0.1, 0.1), seed=1)
    assert list(tr) == [9, 2, 4, 7, 5, 0, 11, 8, 10]
    assert sorted([*tr, *va, *te]) == list(range(12))


def test_select_contiguous_slice_is_view():
    """The epoch hot path selects contiguous runs; those must be numpy views
    of the parent arrays, not re-materialized copies."""
    ds = dataset_from_traces(WorkloadGenerator(seed=4).corpus(12), "latency_p")
    for idx in (slice(2, 9), np.arange(2, 9)):
        sub = ds.select(idx)
        assert len(sub) == 7
        assert np.shares_memory(sub.graphs.op_x, ds.graphs.op_x)
        assert np.shares_memory(sub.labels, ds.labels)
    # fancy selection still copies (and still works)
    fancy = ds.select(np.asarray([5, 2, 9]))
    assert not np.shares_memory(fancy.graphs.op_x, ds.graphs.op_x)
    np.testing.assert_array_equal(fancy.labels, ds.labels[[5, 2, 9]])


def test_bucketed_batches_cover_dataset():
    """Every sample appears, labels stay aligned with their graphs, every
    batch has the static shape of its bucket, and the banding covers every
    depth-d row of every graph in the batch."""
    traces = WorkloadGenerator(seed=6).corpus(70)
    ds = dataset_from_traces(traces, "throughput")
    ds, buckets = bucket_dataset(ds)
    assert sum(len(b) for b in buckets) == len(ds)
    def fingerprint(graphs, i):
        return b"".join(np.asarray(getattr(graphs, f)[i]).tobytes() for f in graphs._fields)

    label_of = {fingerprint(ds.graphs, i): ds.labels[i] for i in range(len(ds))}
    seen = set()
    got_batches = 0
    for g, y, banding in bucketed_batches(ds, buckets, 16, rng=np.random.default_rng(0)):
        got_batches += 1
        assert g.op_x.shape[0] == 16 and y.shape == (16,)
        depth = np.asarray(g.op_depth)
        mask = np.asarray(g.op_mask) > 0
        spans = {d: span for d, span, _ in banding.levels}
        for i in range(16):
            key = fingerprint(g, i)
            assert label_of[key] == y[i]
            seen.add(key)
            for d in range(1, int((depth[i] * mask[i]).max()) + 1):
                rows = np.flatnonzero((depth[i] == d) & mask[i])
                s, e = spans[d]
                assert s <= rows.min() and rows.max() < e
    assert got_batches == n_batches(buckets, 16)
    # padding duplicates rows, never drops them
    assert seen == set(label_of)


def test_exact_buckets_cover_dataset_with_trimmed_bands():
    """exact=True bucketing: every sample appears, every bucket holds ONE
    per-row signature, and its banding is the signature-exact row-trimmed
    plan (so each batch's stage-3 spans are exact, not depth-class-wide)."""
    from repro.core.bucketing import batch_signature

    ds = dataset_from_traces(WorkloadGenerator(seed=26).corpus(60), "throughput")
    ds, buckets = bucket_dataset(ds, exact=True)
    assert sum(len(b) for b in buckets) == len(ds)
    sigs = set()
    for b in buckets:
        sub = ds.select(slice(b.start, b.stop)).graphs
        sig = batch_signature(sub)
        assert len(sig) == 1, "an exact bucket mixes signatures"
        assert sig not in sigs, "signature split across buckets"
        sigs.add(sig)
        mask = np.asarray(sub.op_mask) > 0
        depth = np.asarray(sub.op_depth)
        keep = np.flatnonzero(mask.any(axis=0))
        rows = b.banding.rows if b.banding.rows is not None else tuple(range(depth.shape[1]))
        assert sorted(rows) in ([int(r) for r in keep], list(range(depth.shape[1])))
        spans = {d: span for d, span, _ in b.banding.levels}
        pos = {int(r): i for i, r in enumerate(rows)}
        for d in range(1, int((depth * mask).max(initial=0)) + 1):
            rows = [pos[r] for r in np.flatnonzero(((depth == d) & mask).any(axis=0))]
            s, e = spans[d]
            assert s <= min(rows) and max(rows) < e
    # the epoch iterator serves exact buckets unchanged (each its own group)
    seen = 0
    for g, y, banding in bucketed_batches(ds, buckets, 16):
        assert g.op_x.shape[0] == 16 and y.shape == (16,)
        assert banding in {b.banding for b in buckets}
        seen += 1
    assert seen == n_batches(buckets, 16)


def test_bucket_banding_cache_reused_across_views():
    """Re-bucketing views over the same corpus (train/val splits, repeated
    stages) must hit the signature-keyed banding caches instead of
    recomputing — for both the conservative and the exact flavor."""
    import repro.core.bucketing as bucketing_mod

    ds = dataset_from_traces(WorkloadGenerator(seed=28).corpus(40), "latency_p")
    tr, va, _ = split_dataset(ds, seed=0)
    bucketing_mod._BANDING_CACHE.clear()
    _, b1 = bucket_dataset(tr)
    _, b1x = bucket_dataset(tr, exact=True)
    n_entries = len(bucketing_mod._BANDING_CACHE)
    assert n_entries
    # same rows again (an identical view) -> zero new cache entries, and the
    # SAME banding objects (identity proves reuse, not recompute-and-equal)
    _, b2 = bucket_dataset(tr)
    _, b2x = bucket_dataset(tr, exact=True)
    assert len(bucketing_mod._BANDING_CACHE) == n_entries
    assert all(a.banding is b.banding for a, b in zip(b1, b2))
    assert all(a.banding is b.banding for a, b in zip(b1x, b2x))
    # a different split over the same corpus reuses every signature it shares
    _, bv = bucket_dataset(va, exact=True)
    shared = {b.banding for b in b1x} & {b.banding for b in bv}
    assert shared, "val split shares structures with train but reused none"


def test_bucketed_loss_matches_plain_forward():
    """The banded bucketed forward must equal the generic full-depth forward
    on the same batch (the depth-major layout is an optimization, not a
    different model)."""
    from repro.core import CostModelConfig, GNNConfig, forward_ensemble, init_cost_model

    ds = dataset_from_traces(WorkloadGenerator(seed=8).corpus(40), "latency_p")
    ds, buckets = bucket_dataset(ds)
    cfg = CostModelConfig(metric="latency_p", n_ensemble=2, gnn=GNNConfig(hidden=16))
    params = init_cost_model(jax.random.PRNGKey(0), cfg)
    for g, y, banding in bucketed_batches(ds, buckets, 8):
        gg = jax.tree_util.tree_map(jnp.asarray, g)
        banded = np.asarray(forward_ensemble(params, gg, cfg, banding))
        plain = np.asarray(forward_ensemble(params, gg, cfg))
        np.testing.assert_allclose(banded, plain, rtol=1e-5, atol=1e-6)


def test_train_step_issues_one_stacked_forward(monkeypatch):
    """A jitted training step must run the unified engine exactly once for
    the whole ensemble (one stacked forward), not once per member."""
    import repro.core.gnn as gnn_mod
    import repro.core.model as model_mod
    from repro.core import CostModelConfig, GNNConfig, init_cost_model
    from repro.core.model import ensemble_loss

    calls = {"stacked": 0, "batch": 0}
    orig_stacked, orig_batch = model_mod.apply_gnn_stacked, gnn_mod.apply_gnn_batch

    def counted_stacked(*a, **kw):
        calls["stacked"] += 1
        return orig_stacked(*a, **kw)

    def counted_batch(*a, **kw):
        calls["batch"] += 1
        return orig_batch(*a, **kw)

    monkeypatch.setattr(model_mod, "apply_gnn_stacked", counted_stacked)
    monkeypatch.setattr(gnn_mod, "apply_gnn_batch", counted_batch)
    ds = dataset_from_traces(WorkloadGenerator(seed=9).corpus(16), "latency_p")
    ds, buckets = bucket_dataset(ds)
    g, y, banding = next(iter(bucketed_batches(ds, buckets, 8)))
    g = jax.tree_util.tree_map(jnp.asarray, g)
    cfg = CostModelConfig(metric="latency_p", n_ensemble=3, gnn=GNNConfig(hidden=16))
    params = init_cost_model(jax.random.PRNGKey(0), cfg)

    def step(p):
        return jax.value_and_grad(
            lambda pp: ensemble_loss(pp, g, jnp.asarray(y), cfg, banding)
        )(p)

    jax.jit(step).lower(params)  # trace without executing
    assert calls["stacked"] == 1  # one stacked engine call for all members
    assert calls["batch"] == 1  # ... which enters the batch engine once (vmap)


def test_prefetch_order():
    assert list(prefetch(iter(range(10)), size=2)) == list(range(10))


def test_elastic_shapes():
    assert shrink_mesh_shape((2, 16, 16), ("pod", "data", "model"), "data", 2) == (2, 8, 16)
    with pytest.raises(AssertionError):
        shrink_mesh_shape((2, 16, 16), ("pod", "data", "model"), "data", 3)


def test_elastic_batch_validation():
    mesh = jax.make_mesh((1,), ("data",))
    assert validate_global_batch(64, mesh) == 64
