"""Training substrate: optimizers, checkpointing, compression, batching."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _propcheck import given, settings, strategies as st

from repro.training import (
    adam,
    apply_updates,
    batches,
    clip_by_global_norm,
    cosine_schedule,
    dataset_from_traces,
    ef_init,
    global_norm,
    int8_dequantize,
    int8_quantize,
    int8_roundtrip,
    latest_step,
    prefetch,
    restore_checkpoint,
    save_checkpoint,
    sgd,
    split_dataset,
    topk_with_error_feedback,
)
from repro.training.elastic import shrink_mesh_shape, validate_global_batch
from repro.dsps import WorkloadGenerator


def test_adam_minimizes_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    opt = adam(lr=0.2)
    state = opt.init(params)
    for _ in range(150):
        grads = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        updates, state = opt.update(grads, state, params)
        params = apply_updates(params, updates)
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_sgd_momentum():
    params = {"w": jnp.asarray(4.0)}
    opt = sgd(lr=0.1, momentum=0.9)
    state = opt.init(params)
    for _ in range(100):
        grads = jax.grad(lambda p: p["w"] ** 2)(params)
        updates, state = opt.update(grads, state)
        params = apply_updates(params, updates)
    assert abs(float(params["w"])) < 0.1


def test_clip_by_global_norm():
    tree = {"a": jnp.ones((10,)) * 10.0}
    clipped = clip_by_global_norm(tree, 1.0)
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-5


def test_cosine_schedule_shape():
    s = cosine_schedule(1.0, 100, warmup_steps=10)
    assert float(s(0)) == 0.0
    assert abs(float(s(10)) - 1.0) < 1e-6
    assert float(s(100)) <= 0.2
    assert float(s(55)) < float(s(11))


def test_checkpoint_roundtrip(tmp_path):
    state = {"a": np.arange(6).reshape(2, 3).astype(np.float32), "b": {"c": np.ones(4)}}
    d = str(tmp_path / "ck")
    save_checkpoint(d, 5, state)
    save_checkpoint(d, 9, jax.tree_util.tree_map(lambda x: x * 2, state))
    assert latest_step(d) == 9
    restored, step, _ = restore_checkpoint(d, state)
    assert step == 9
    np.testing.assert_allclose(restored["a"], state["a"] * 2)


def test_checkpoint_gc(tmp_path):
    d = str(tmp_path / "ck")
    for s in range(6):
        save_checkpoint(d, s, {"x": np.ones(2)}, keep=2)
    dirs = [p for p in os.listdir(d) if p.startswith("step_")]
    assert len(dirs) == 2


def test_checkpoint_resume_after_crash(tmp_path):
    """A stale 'latest' pointer falls back to the newest complete dir."""
    d = str(tmp_path / "ck")
    save_checkpoint(d, 3, {"x": np.ones(2)})
    with open(os.path.join(d, "latest"), "w") as f:
        f.write("step_9999999999")  # simulates crash between write and rename
    assert latest_step(d) == 3


def test_topk_error_feedback_accumulates():
    grads = {"w": jnp.asarray([1.0, 0.1, 0.01, 0.001])}
    ef = ef_init(grads)
    recon, ef, _ = topk_with_error_feedback(grads, ef, frac=0.25)
    # only the largest entry survives; dropped mass lands in the residual
    assert float(recon["w"][0]) == pytest.approx(1.0)
    assert float(recon["w"][1]) == 0.0
    assert float(ef.residual["w"][1]) == pytest.approx(0.1, rel=1e-5)
    # residual accumulates every step and is eventually transmitted: after
    # enough steps, entry 1's accumulated value exceeds the fresh 1.0 grad
    sent_at = None
    for it in range(12):
        recon, ef, _frac = topk_with_error_feedback(grads, ef, frac=0.25)
        if float(recon["w"][1]) > 0:
            sent_at = it
            break
    assert sent_at is not None, "error feedback never transmitted the small coordinate"
    # nothing is lost: transmitted + residual == accumulated stream
    total = float(recon["w"][1]) + float(ef.residual["w"][1])
    assert total == pytest.approx(0.1 * (sent_at + 2), rel=1e-3)


def test_int8_quantization_bound():
    x = jnp.linspace(-3.0, 3.0, 100)
    q, scale = int8_quantize(x, jax.random.PRNGKey(0), stochastic=False)
    err = jnp.abs(int8_dequantize(q, scale) - x)
    assert float(err.max()) <= float(scale) * 0.51


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 1000))
def test_int8_stochastic_unbiased(seed):
    x = jnp.full((2048,), 0.3)
    out = int8_roundtrip({"x": x}, jax.random.PRNGKey(seed))["x"]
    assert abs(float(out.mean()) - 0.3) < 0.01


def test_batching_and_split():
    traces = WorkloadGenerator(seed=2).corpus(50)
    ds = dataset_from_traces(traces, "throughput")
    tr, va, te = split_dataset(ds, (0.8, 0.1, 0.1), seed=0)
    assert len(tr) == 40 and len(va) == 5 and len(te) == 5
    got = 0
    for g, y in batches(tr, 16, rng=np.random.default_rng(0)):
        assert g.op_x.shape[0] == 16  # padded tail
        got += 1
    assert got == 3


def test_prefetch_order():
    assert list(prefetch(iter(range(10)), size=2)) == list(range(10))


def test_elastic_shapes():
    assert shrink_mesh_shape((2, 16, 16), ("pod", "data", "model"), "data", 2) == (2, 8, 16)
    with pytest.raises(AssertionError):
        shrink_mesh_shape((2, 16, 16), ("pod", "data", "model"), "data", 3)


def test_elastic_batch_validation():
    mesh = jax.make_mesh((1,), ("data",))
    assert validate_global_batch(64, mesh) == 64
