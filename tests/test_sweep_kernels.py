"""Fused stage-3 sweep + segment gather/scatter kernels: parity, grads, and
the one-launch contract of the sweep path.

Every parity case runs under BOTH off-TPU lowerings of the kernel ops: the
compiled jnp-oracle (``ref``) and the forced Pallas interpreter
(``interpret``), which executes the actual kernel bodies.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import nn
from repro.core.bucketing import batch_banding, bucket_size, exact_banding, pad_batch
from repro.core.gnn import (
    GNNConfig,
    _banded_plan,
    apply_gnn_batch,
    apply_gnn_merged,
    init_gnn,
    validate_merged_parents,
)
from repro.core.graph import (
    SLOT_RANGES,
    batch_graphs,
    build_a_place_batch,
    build_graph_skeleton,
)
from repro.dsps.generator import WorkloadGenerator
from repro.training.batching import dataset_from_traces
from repro.kernels.mp_sweep.ops import mp_sweep
from repro.kernels.mp_sweep.ref import mp_sweep_ref
from repro.kernels.mp_update.ref import mp_update_ref
from repro.kernels.seg_gather.ops import gather_sum, segment_sum
from repro.kernels.seg_gather.ref import gather_sum_ref, segment_sum_ref
from repro.placement import sample_assignment_matrix

LOWERINGS = ["ref", "interpret"]


def _set_lowering(monkeypatch, lowering):
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1" if lowering == "interpret" else "0")


def _banded_batch(seed=0, n=24, trim=False):
    """A mixed-structure bucketed batch + its banding (trimmed: exact)."""
    ds = dataset_from_traces(WorkloadGenerator(seed=seed).corpus(n), "latency_p")
    g = pad_batch(ds.graphs, bucket_size(ds.graphs.op_x.shape[0]))
    banding = exact_banding(g) if trim else batch_banding(g)
    return jax.tree_util.tree_map(jnp.asarray, g), banding


def _sweep_inputs(g, banding, hidden=16, seed=3):
    params = init_gnn(jax.random.PRNGKey(seed), GNNConfig(hidden=hidden))["op_upd"]
    rows = g.op_x.shape[-2] if banding.rows is None else len(banding.rows)
    h = jax.random.normal(jax.random.PRNGKey(seed + 1), (g.op_x.shape[0], rows, hidden))
    if banding.rows is None:
        a_flow, depth = g.a_flow, g.op_depth
        mask = g.op_mask.astype(jnp.float32)
    else:
        idx = jnp.asarray(banding.rows)
        a_flow = jnp.take(jnp.take(g.a_flow, idx, axis=-2), idx, axis=-1)
        depth = jnp.take(g.op_depth, idx, axis=-1)
        mask = jnp.take(g.op_mask, idx, axis=-1).astype(jnp.float32)
    ranges = SLOT_RANGES if banding.rows is None else banding.ranges
    levels = _banded_plan(banding, ranges).levels
    return params, h, a_flow, depth, mask, levels


@pytest.mark.parametrize("trim", [False, True], ids=["untrimmed", "trimmed"])
@pytest.mark.parametrize("lowering", LOWERINGS)
def test_mp_sweep_matches_per_level_loop(lowering, trim, monkeypatch):
    """ONE fused sweep call == the sequential per-level mp_update composition
    it replaces, on trimmed and untrimmed bandings, both lowerings."""
    _set_lowering(monkeypatch, lowering)
    g, banding = _banded_batch(seed=7, trim=trim)
    params, h, a_flow, depth, mask, levels = _sweep_inputs(g, banding)
    assert len(levels) > 1, "the fused-vs-per-level contrast needs >1 level"
    fused = mp_sweep(params, h, a_flow, depth, mask, levels)
    looped = h
    for d, span, slot_ranges, parent_hi in levels:
        looped = mp_update_ref(
            params, looped, a_flow, depth, mask, jnp.asarray(d, depth.dtype),
            slot_ranges, row_span=span, parent_rows=parent_hi,
        )
    np.testing.assert_allclose(
        np.asarray(fused), np.asarray(looped), rtol=1e-5, atol=1e-5
    )


@pytest.mark.parametrize("lowering", LOWERINGS)
def test_mp_sweep_grads_match_oracle(lowering, monkeypatch):
    """Values AND gradients (params, h, a_flow) vs the jnp sweep oracle."""
    _set_lowering(monkeypatch, lowering)
    g, banding = _banded_batch(seed=11)
    params, h, a_flow, depth, mask, levels = _sweep_inputs(g, banding)
    a_flow = a_flow.astype(jnp.float32)

    def loss_op(p, hh, aa):
        return jnp.sum(mp_sweep(p, hh, aa, depth, mask, levels) ** 2)

    def loss_ref(p, hh, aa):
        return jnp.sum(mp_sweep_ref(p, hh, aa, depth, mask, levels) ** 2)

    gk = jax.grad(loss_op, argnums=(0, 1, 2))(params, h, a_flow)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(params, h, a_flow)
    for a, b in zip(jax.tree_util.tree_leaves(gk), jax.tree_util.tree_leaves(gr)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-4)


def test_sweep_path_is_one_stage3_launch(monkeypatch):
    """The tentpole contract, counter-asserted: a banded ``use_pallas``
    forward issues exactly ONE stage-3 kernel launch (the fused sweep), and
    ZERO per-level mp_update launches."""
    from repro.kernels import mp_sweep as sweep_pkg
    from repro.kernels import mp_update as update_pkg

    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    calls = {"sweep": 0, "update": 0}
    orig_sweep = sweep_pkg.kernel.mp_sweep_pallas
    orig_update = update_pkg.kernel.mp_update_pallas

    def counting_sweep(*a, **k):
        calls["sweep"] += 1
        return orig_sweep(*a, **k)

    def counting_update(*a, **k):
        calls["update"] += 1
        return orig_update(*a, **k)

    monkeypatch.setattr(sweep_pkg.ops, "mp_sweep_pallas", counting_sweep)
    monkeypatch.setattr(update_pkg.ops, "mp_update_pallas", counting_update)
    g, banding = _banded_batch(seed=5)
    assert len(banding.levels) > 1
    params = init_gnn(jax.random.PRNGKey(0), GNNConfig(hidden=16))
    cfg = GNNConfig(hidden=16, use_pallas=True)
    out = apply_gnn_batch(params, g, cfg, banding)  # eager: ops dispatch per call
    assert out.shape[-1] == 1
    assert calls["sweep"] == 1, f"expected ONE fused sweep launch, got {calls['sweep']}"
    assert calls["update"] == 0, "per-level mp_update must not launch on the sweep path"


@pytest.mark.parametrize("lowering", LOWERINGS)
def test_gather_sum_parity_and_grads(lowering, monkeypatch):
    _set_lowering(monkeypatch, lowering)
    key = jax.random.PRNGKey(0)
    B, N, H, R, P = 6, 12, 16, 9, 2  # R non-power-of-2: exercises row padding
    h = jax.random.normal(key, (B, N, H))
    idx = jax.random.randint(jax.random.PRNGKey(1), (B, R, P), 0, N)
    w = (jax.random.uniform(jax.random.PRNGKey(2), (B, R, P)) > 0.4).astype(jnp.float32)
    np.testing.assert_allclose(
        np.asarray(gather_sum(h, idx, w)),
        np.asarray(gather_sum_ref(h, idx, w)),
        rtol=1e-5, atol=1e-6,
    )
    gk = jax.grad(lambda hh, ww: jnp.sum(gather_sum(hh, idx, ww) ** 2), argnums=(0, 1))(h, w)
    gr = jax.grad(lambda hh, ww: jnp.sum(gather_sum_ref(hh, idx, ww) ** 2), argnums=(0, 1))(h, w)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("lowering", LOWERINGS)
def test_segment_sum_parity_and_grads(lowering, monkeypatch):
    _set_lowering(monkeypatch, lowering)
    B, N, H, S = 6, 12, 16, 5
    x = jax.random.normal(jax.random.PRNGKey(0), (B, N, H))
    seg = jax.random.randint(jax.random.PRNGKey(1), (B, N), 0, S)
    np.testing.assert_allclose(
        np.asarray(segment_sum(x, seg, S)),
        np.asarray(segment_sum_ref(x, seg, S)),
        rtol=1e-5, atol=1e-6,
    )
    gk = jax.grad(lambda xx: jnp.sum(segment_sum(xx, seg, S) ** 2))(x)
    gr = jax.grad(lambda xx: jnp.sum(segment_sum_ref(xx, seg, S) ** 2))(x)
    np.testing.assert_allclose(np.asarray(gk), np.asarray(gr), rtol=1e-5, atol=1e-5)


def _merged_inputs(seed=0, n=8):
    gen = WorkloadGenerator(seed=seed)
    c = gen.cluster(4)
    qs = [gen.query(kind=k, name=f"m{i}") for i, k in enumerate(("linear", "two_way"))]
    rng = np.random.default_rng(seed)
    skels = batch_graphs([build_graph_skeleton(q, c) for q in qs])
    blocks, ids = [], []
    for i, q in enumerate(qs):
        a = sample_assignment_matrix(q, c, n, rng, max_tries_factor=400)
        blocks.append(build_a_place_batch(q, c, a))
        ids.append(np.full(len(a), i, dtype=np.int32))
    banding = exact_banding(skels)
    max_parents = int(np.asarray(skels.a_flow).sum(axis=-2).max(initial=1))
    return (
        jax.tree_util.tree_map(jnp.asarray, skels),
        jnp.asarray(np.concatenate(ids)),
        jnp.asarray(np.concatenate(blocks)),
        banding,
        max_parents,
    )


@pytest.mark.parametrize("lowering", LOWERINGS)
def test_merged_engine_use_pallas_matches_jnp(lowering, monkeypatch):
    """``apply_gnn_merged`` is no longer use_pallas-excluded: the kernel-routed
    engine (seg_gather + banked_mlp ops) matches the jnp path, values and
    grads, under both lowerings."""
    _set_lowering(monkeypatch, lowering)
    skels, skel_id, a_place, banding, max_parents = _merged_inputs(seed=13)
    cfg_j = GNNConfig(hidden=16)
    cfg_p = GNNConfig(hidden=16, use_pallas=True)
    params = jax.tree_util.tree_map(
        lambda p: p[None], init_gnn(jax.random.PRNGKey(2), cfg_j)
    )  # 1-member stack
    out_j = apply_gnn_merged(params, skels, skel_id, a_place, cfg_j, banding, max_parents)
    out_p = apply_gnn_merged(params, skels, skel_id, a_place, cfg_p, banding, max_parents)
    np.testing.assert_allclose(np.asarray(out_j), np.asarray(out_p), rtol=1e-4, atol=1e-4)

    def loss(p, cfg):
        return jnp.sum(
            apply_gnn_merged(p, skels, skel_id, a_place, cfg, banding, max_parents) ** 2
        )

    gj = jax.grad(lambda p: loss(p, cfg_j))(params)
    gp = jax.grad(lambda p: loss(p, cfg_p))(params)
    for a, b in zip(jax.tree_util.tree_leaves(gj), jax.tree_util.tree_leaves(gp)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-3, atol=1e-4)


def test_merged_in_degree_validation_raises():
    """A max_parents bound below the stack's true in-degree must raise a
    clear error instead of silently truncating parents (wrong sums)."""
    skels, skel_id, a_place, banding, max_parents = _merged_inputs(seed=17)
    assert max_parents >= 2, "a join query must have a >=2-parent row"
    cfg = GNNConfig(hidden=16)
    params = jax.tree_util.tree_map(
        lambda p: p[None], init_gnn(jax.random.PRNGKey(0), cfg)
    )
    with pytest.raises(ValueError, match="in-degree .* > max_parents"):
        apply_gnn_merged(
            params, skels, skel_id, a_place, cfg, banding, max_parents - 1
        )
    with pytest.raises(ValueError, match="wrong sums"):
        validate_merged_parents(skels.a_flow, 0)
    validate_merged_parents(skels.a_flow, max_parents)  # exact bound passes


def test_merged_group_build_validates_in_degree(monkeypatch):
    """The estimator derives max_parents at merged-group build time and pins
    the invariant there; an (artificially) understated bound raises."""
    from repro.serve import estimator as estimator_mod

    called = {}
    orig = estimator_mod.validate_merged_parents

    def spy(a_flow, max_parents, **kw):
        called["max_parents"] = max_parents
        return orig(a_flow, max_parents, **kw)

    monkeypatch.setattr(estimator_mod, "validate_merged_parents", spy)
    from repro.core.model import CostModelConfig, init_cost_model
    from repro.serve.estimator import CostEstimator

    models = {}
    for i, metric in enumerate(("latency_p", "success")):
        cfg = CostModelConfig(metric=metric, n_ensemble=2, gnn=GNNConfig(hidden=16))
        models[metric] = (init_cost_model(jax.random.PRNGKey(i), cfg), cfg)
    est = CostEstimator(models)
    gen = WorkloadGenerator(seed=3)
    rng = np.random.default_rng(0)
    reqs = []
    for i, k in enumerate(("linear", "two_way")):
        q, c = gen.query(kind=k, name=f"v{i}"), gen.cluster(3)
        reqs.append((q, c, sample_assignment_matrix(q, c, 4, rng, max_tries_factor=400)))
    out = est.score_many(reqs)
    assert len(out) == 2 and called["max_parents"] >= 1


def test_donation_is_backend_gated():
    """``_can_donate`` is False on CPU (XLA:CPU cannot reuse donated buffers)
    and the donating trace factories still produce correct results."""
    from repro.serve import estimator as estimator_mod

    assert estimator_mod._can_donate() == (jax.default_backend() != "cpu")
    # the donate flag is part of the trace key; both variants must agree
    skels, skel_id, a_place, banding, max_parents = _merged_inputs(seed=19)
    cfg = GNNConfig(hidden=16)
    params = jax.tree_util.tree_map(
        lambda p: p[None], init_gnn(jax.random.PRNGKey(1), cfg)
    )
    f_plain = estimator_mod._jitted_merged_forward(cfg, banding, max_parents, "ref", False)
    f_donate = estimator_mod._jitted_merged_forward(
        cfg, banding, max_parents, "ref", estimator_mod._can_donate()
    )
    out_a = f_plain(params, skels, skel_id, a_place)
    out_b = f_donate(params, skels, jnp.array(skel_id), jnp.array(a_place))
    np.testing.assert_allclose(np.asarray(out_a), np.asarray(out_b), rtol=1e-5, atol=1e-6)


def test_deep_update_bank_keeps_banded_plan():
    """>2-layer update banks cannot ride the fused sweep; the engine must
    fall back to the per-level banded loop (jnp) and still be correct."""
    from repro.core.gnn import _sweep_fusable

    g, banding = _banded_batch(seed=23)
    cfg = GNNConfig(hidden=16, update_layers=3)
    params = init_gnn(jax.random.PRNGKey(0), cfg)
    assert not _sweep_fusable(params)
    out_banded = apply_gnn_batch(params, g, cfg, banding)
    out_plain = apply_gnn_batch(params, g, cfg)
    np.testing.assert_allclose(
        np.asarray(out_banded), np.asarray(out_plain), rtol=1e-4, atol=1e-5
    )
