"""Service correctness battery for the load-hardened ``PlacementService``:
property-based parity under arbitrary request interleavings, multi-threaded
stress with injected mid-drain failures, worker-death delivery guarantees,
backpressure + straggler fault wiring, and a deterministic load-harness smoke
run (``slow`` marker)."""

import threading
import time

import jax
import numpy as np
import pytest

from _propcheck import given, settings, strategies as st
from repro.core import CostModelConfig, GNNConfig, init_cost_model
from repro.core.graph import batch_graphs, build_graph
from repro.dsps import WorkloadGenerator
from repro.launch.faults import ClusterMonitor, FaultPolicy
from repro.placement import sample_assignment_matrix
from repro.serve import (
    CostEstimator,
    PlacementService,
    ServiceOverloadError,
    bursty_arrivals,
    poisson_arrivals,
    run_open_loop,
    score_request_stream,
)

METRICS = ("latency_p", "success", "backpressure")
#: per-request metric selections the interleaving draws from; None = all
METRIC_MIXES = (None, ("latency_p",), ("latency_p", "success"))


def _models(hidden=16, n_ensemble=2):
    models = {}
    for i, m in enumerate(METRICS):
        cfg = CostModelConfig(metric=m, n_ensemble=n_ensemble, gnn=GNNConfig(hidden=hidden))
        models[m] = (init_cost_model(jax.random.PRNGKey(i), cfg), cfg)
    return models


# one estimator for the whole module: the jit caches are shared, so every
# test after the first runs on warm traces and the battery stays fast
_EST = CostEstimator(_models())

# hot-swap candidate with IDENTICAL weights (same PRNG keys): swapping it in
# mid-interleaving must not change any answer, which lets the parity property
# below quantify over swap timing too
_EST_TWIN = CostEstimator(_models())


def _structures(n=4, seed=71):
    gen = WorkloadGenerator(seed=seed)
    kinds = ("linear", "two_way", "three_way")
    return [
        (gen.query(kind=kinds[i % len(kinds)], name=f"batt{i}"), gen.cluster(3 + i % 4))
        for i in range(n)
    ]


_STRUCTURES = _structures()


def _graph_batch(n, seed):
    gen = WorkloadGenerator(seed=seed)
    traces = gen.corpus(n)
    return batch_graphs([build_graph(t.query, t.cluster, t.placement) for t in traces])


_GRAPHS = (_graph_batch(3, 73), _graph_batch(5, 79))


# -- satellite 1: property-based service parity -----------------------------------


@settings(max_examples=8, deadline=None)
@given(
    n_score=st.integers(0, 6),
    n_est=st.integers(0, 3),
    cross_query=st.booleans(),
    double_buffer=st.booleans(),
    shuffle_seed=st.integers(0, 10_000),
    cands=st.integers(1, 5),
    do_swap=st.booleans(),
)
def test_any_interleaving_matches_serial_estimator(
    n_score, n_est, cross_query, double_buffer, shuffle_seed, cands, do_swap
):
    """PROPERTY: any interleaving of submit_score / submit_estimate across
    mixed metric tuples and query structures resolves to the serial
    ``CostEstimator`` answer — bit-identical on the per-structure path
    (cross_query=False), float-identical on the merged paths — and the drain
    accounting stays consistent (n_drained == n_requests, no lost futures).
    When ``do_swap`` the interleaving also hot-swaps in a twin estimator with
    identical weights mid-stream: the swap applies at a drain boundary, hands
    back the old estimator, and perturbs no answer."""
    rng = np.random.default_rng(shuffle_seed)
    jobs = []  # ("score", q, c, a, metrics) | ("estimate", g, metrics)
    for i in range(n_score):
        q, c = _STRUCTURES[int(rng.integers(len(_STRUCTURES)))]
        a = sample_assignment_matrix(q, c, cands, rng)
        jobs.append(("score", q, c, a, METRIC_MIXES[int(rng.integers(len(METRIC_MIXES)))]))
    for i in range(n_est):
        g = _GRAPHS[int(rng.integers(len(_GRAPHS)))]
        jobs.append(("estimate", g, METRIC_MIXES[int(rng.integers(len(METRIC_MIXES)))]))
    rng.shuffle(jobs)
    if not jobs:
        return

    svc = PlacementService(
        _EST, auto_start=False, cross_query=cross_query, double_buffer=double_buffer
    )
    def _submit(job):
        if job[0] == "score":
            return svc.submit_score(job[1], job[2], job[3], job[4])
        return svc.submit_estimate(job[1], job[2])

    cut = len(jobs) // 2 if do_swap else len(jobs)
    futs = [_submit(job) for job in jobs[:cut]]
    svc.start()
    swap_fut = svc.swap_bundle(_EST_TWIN, wait=False) if do_swap else None
    futs += [_submit(job) for job in jobs[cut:]]
    got = [f.result(timeout=120) for f in futs]
    if swap_fut is not None:
        assert swap_fut.result(timeout=120) is _EST, "swap hands back the old estimator"
        assert svc.estimator is _EST_TWIN
    svc.close()

    # how many score requests share each per-structure coalescing group: a
    # solo request drains at exactly the serial batch shape (bit-identical);
    # coalesced same-structure requests concatenate into a bigger batch,
    # where XLA may pick a different dot kernel (1-ulp association diffs)
    group_count: dict = {}
    for job in jobs:
        if job[0] == "score":
            k = (id(job[1]), job[4])
            group_count[k] = group_count.get(k, 0) + 1

    for job, have in zip(jobs, got):
        if job[0] == "score":
            _, q, c, a, metrics = job
            want = _EST.score(q, c, a, metrics)
            assert set(have) == set(want)
            solo = group_count[(id(q), metrics)] == 1
            for m in want:
                if cross_query:
                    # merged cross-query answers run the signature-banded
                    # engine: same math, different sweep order
                    np.testing.assert_allclose(have[m], want[m], rtol=1e-4, atol=1e-5, err_msg=m)
                elif solo:
                    # per-structure drains take exactly the serial facade
                    # path at the serial batch shape: bit-identical
                    np.testing.assert_array_equal(have[m], want[m], err_msg=m)
                else:
                    np.testing.assert_allclose(have[m], want[m], rtol=1e-5, atol=1e-7, err_msg=m)
        else:
            _, g, metrics = job
            want = _EST.estimate(g, metrics)
            assert set(have) == set(want)
            for m in want:
                # coalesced estimates run at the merged batch shape
                np.testing.assert_allclose(have[m], want[m], rtol=1e-4, atol=1e-5, err_msg=m)

    assert all(f.done() for f in futs), "no lost futures"
    assert svc.stats.n_requests == len(jobs)
    assert svc.stats.n_drained == len(jobs), "every request popped into exactly one drain"
    assert svc.stats.n_rejected == 0
    assert svc.stats.max_drain <= len(jobs)
    assert svc.stats.n_batches >= 1
    assert svc.stats.n_swaps == (1 if do_swap else 0)


# -- satellite 2: concurrency stress + injected failures --------------------------


def test_threaded_submit_with_injected_drain_failure():
    """N producer threads submit while the worker drains; a transient
    mid-drain estimator exception must be retried at finalize (seeded
    backoff), every future must resolve with the right answer — zero
    client-visible failures — and the worker must keep serving afterwards."""
    est = CostEstimator(_models())
    n_threads, per_thread = 4, 8
    boom = RuntimeError("injected drain failure")
    calls = {"n": 0}
    orig = est.score

    def flaky(*a, **k):
        calls["n"] += 1
        if calls["n"] == 3:  # mid-drain: earlier groups already launched
            raise boom
        return orig(*a, **k)

    est.score = flaky
    try:
        # per-structure path (cross_query=False): the injected failure lands in
        # one structure's subgroup, whose requests alone must see it
        svc = PlacementService(est, auto_start=True, cross_query=False)
        futs = [[] for _ in range(n_threads)]
        meta = [[] for _ in range(n_threads)]

        def producer(t):
            rng = np.random.default_rng(100 + t)
            for i in range(per_thread):
                q, c = _STRUCTURES[(t + i) % len(_STRUCTURES)]
                a = sample_assignment_matrix(q, c, 3, rng)
                futs[t].append(svc.submit_score(q, c, a))
                meta[t].append((q, c, a))
                time.sleep(0.001)  # interleave with the worker's drains

        threads = [threading.Thread(target=producer, args=(t,)) for t in range(n_threads)]
        for th in threads:
            th.start()
        for th in threads:
            th.join()

        n_ok = 0
        for t in range(n_threads):
            for fut, (q, c, a) in zip(futs[t], meta[t]):
                # exception(timeout) blocks until resolution without raising
                assert fut.exception(timeout=120) is None, "transient failure leaked"
                have = fut.result()
                assert not getattr(have, "degraded", False), "retry should recover"
                want = _EST.score(q, c, a)  # same weights, un-patched facade
                for m in want:
                    # same-structure batchmates may coalesce into a bigger
                    # batch than the serial call: 1-ulp kernel diffs allowed
                    np.testing.assert_allclose(have[m], want[m], rtol=1e-5, atol=1e-7, err_msg=m)
                n_ok += 1
        assert n_ok == n_threads * per_thread, "every future resolved"
        assert svc.stats.n_retries >= 1, "the injected failure triggered a retry"
        assert svc.stats.n_failed == 0 and svc.stats.n_degraded == 0

        # the worker survived: it still answers
        q, c = _STRUCTURES[0]
        a = sample_assignment_matrix(q, c, 2, np.random.default_rng(0))
        ok = svc.score(q, c, a)
        np.testing.assert_allclose(
            ok["latency_p"], _EST.score(q, c, a)["latency_p"], rtol=1e-5, atol=1e-7
        )
        svc.close()
        assert svc.stats.n_requests == svc.stats.n_drained == n_threads * per_thread + 1
    finally:
        est.score = orig


@pytest.mark.filterwarnings("ignore::pytest.PytestUnhandledThreadExceptionWarning")
def test_worker_death_fails_futures_never_drops_them():
    """If the worker loop itself dies (a skeleton bug, here injected), every
    future it owed must fail with the error — and requests queued after the
    death must be failed by close(), not silently dropped."""
    est = CostEstimator(_models())
    svc = PlacementService(est, auto_start=False)
    crash = RuntimeError("worker skeleton crash")

    def exploding_launch(reqs):
        raise crash

    svc._launch_group = exploding_launch  # bypasses the per-group error capture
    q, c = _STRUCTURES[0]
    a = sample_assignment_matrix(q, c, 2, np.random.default_rng(1))
    f1 = svc.submit_score(q, c, a)
    svc.start()
    with pytest.raises(RuntimeError, match="worker skeleton crash"):
        f1.result(timeout=60)
    # the worker thread is dead now; a request that sneaks into the queue
    # afterwards has no one to serve it -- close() must fail it explicitly
    svc._thread.join(timeout=60)
    f2 = svc.submit_score(q, c, a)
    svc.close()
    with pytest.raises(RuntimeError, match="worker died before serving"):
        f2.result(timeout=60)


# -- satellite 3: fault-injection -- stalled drains, backpressure, recovery -------


def test_stalled_drain_triggers_straggler_and_backpressure_then_recovers():
    """A deliberately stalled drain (slow forward) must (a) engage the
    bounded-queue backpressure — rejections, not unbounded latency — and
    (b) stand out as a latency straggler to the ``launch.faults`` monitor
    when fed the measured drain latencies; removing the stall must restore
    steady-state latency and a clean monitor verdict.  All assertions run on
    harness measurements, never on sleeps."""
    est = CostEstimator(_models())
    structures = _STRUCTURES
    stall_s = 0.25
    stall = {"s": stall_s}
    orig = est.score

    def stalled(*a, **k):
        if stall["s"]:
            time.sleep(stall["s"])
        return orig(*a, **k)

    est.score = stalled
    try:
        svc = PlacementService(
            est,
            auto_start=True,
            cross_query=False,  # per-structure drains: the stall hits score()
            max_queue_depth=4,
            overflow="reject",
            warmup=structures,
            warmup_cands=4,
        )
        n, rate = 48, 40.0  # 10 arrivals per stalled drain >> depth 4
        sched = poisson_arrivals(rate, n, seed=3)
        stream = score_request_stream(structures, n, 2, seed=3, metrics=METRICS)

        stalled_rep = run_open_loop(svc, stream(svc), sched, slo_s=stall_s / 2)
        assert stalled_rep.n_rejected > 0, "backpressure must shed load at the door"
        assert stalled_rep.stats.n_rejected == stalled_rep.n_rejected
        assert stalled_rep.slo_violation_rate > 0.5, "a stalled service cannot meet the SLO"
        assert stalled_rep.stats.max_queue_depth <= 4 + 1, "the bound held"

        # recovery: remove the stall, same stream, same rate
        stall["s"] = 0.0
        svc.stats.reset()
        recovered = run_open_loop(svc, stream(svc), sched, slo_s=stall_s / 2)
        svc.close()
        assert recovered.n_rejected == 0, "steady state needs no shedding"
        assert recovered.n_answered == n
        assert recovered.p95_s < stalled_rep.p95_s, "recovery restored tail latency"
        assert stalled_rep.p50_s > 2 * recovered.p50_s, "the stall dominated latency"

        # the monitor sees the measured drain latencies: the stalled service
        # is a clear median/MAD outlier against healthy peers, the recovered
        # one is not (host 0 = this service, hosts 1-3 = healthy peers at the
        # recovered service's own latency scale)
        base = recovered.p50_s
        for phase_p50, expect_straggler in ((stalled_rep.p50_s, True), (base, False)):
            mon = ClusterMonitor(4, FaultPolicy(straggler_zscore=3.0, straggler_min_steps=3))
            for step in range(3):
                mon.report_step(0, phase_p50)
                for hid, f in ((1, 0.8), (2, 1.0), (3, 1.2)):
                    mon.report_step(hid, base * f)
                for hid in range(4):
                    mon.heartbeat(hid, float(step))
            verdicts = mon.detect(now=2.0)
            stragglers = [hid for hid, why in verdicts if why.startswith("straggler")]
            if expect_straggler:
                assert stragglers == [0], verdicts
            else:
                assert 0 not in stragglers, verdicts
    finally:
        est.score = orig


# -- satellite 4: deterministic load-harness smoke --------------------------------


@pytest.mark.slow
def test_load_harness_smoke_deterministic_low_rate():
    """Tiny seeded Poisson run on a warmed service: reproducible request
    count and schedule, zero SLO violations at a rate the service trivially
    sustains, monotone latency quantiles."""
    est = CostEstimator(_models())
    structures = _STRUCTURES
    # max_merged_mixes=0: only the warmed full mix may take the merged path,
    # so no arrival subset can buy a jit compile mid-run; warmup_cands=16
    # covers the per-structure row buckets any low-rate coalescing can hit
    svc = PlacementService(
        est, auto_start=True, warmup=structures, warmup_cands=16, max_merged_mixes=0
    )
    # calibrate "low rate" to this machine: arrivals 4x slower than the warm
    # synchronous latency can serve
    q, c = structures[0]
    a = sample_assignment_matrix(q, c, 2, np.random.default_rng(9))
    t0 = time.perf_counter()
    svc.score(q, c, a)
    t_warm = time.perf_counter() - t0
    rate = max(2.0, 0.25 / t_warm)
    slo_s = max(1.0, 50 * t_warm)
    svc.stats.reset()  # the calibration request is not part of the run

    n = 24
    sched = poisson_arrivals(rate, n, seed=5)
    np.testing.assert_array_equal(sched, poisson_arrivals(rate, n, seed=5))
    np.testing.assert_array_equal(
        bursty_arrivals(rate, n, seed=5), bursty_arrivals(rate, n, seed=5)
    )
    stream = score_request_stream(structures, n, 2, seed=5, metrics=METRICS)
    rep = run_open_loop(svc, stream(svc), sched, slo_s=slo_s)
    svc.close()
    assert rep.n_requests == n and rep.n_answered == n
    assert rep.n_rejected == 0 and rep.n_failed == 0
    assert rep.n_slo_violations == 0, f"low-rate run violated its SLO: {rep.summary()}"
    assert rep.p50_s <= rep.p95_s <= rep.p99_s
    assert np.isfinite(rep.latencies_s).all() and (rep.latencies_s > 0).all()
    assert rep.stats.n_drained == n
