import os

# Tests run on the single host CPU device; the dry-run (and only the dry-run)
# forces 512 host devices in its own subprocess.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

# Routing assertions (merged-vs-per-structure drains, chunk widths) pin the
# built-in DispatchPolicy defaults; a developer machine's autotuned profile
# in ~/.cache/repro/dispatch must not flip them (tests that exercise profile
# resolution set this themselves via monkeypatch).
os.environ.setdefault("REPRO_DISPATCH_PROFILE", "default")

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
