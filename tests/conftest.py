import os

# Tests run on the single host CPU device; the dry-run (and only the dry-run)
# forces 512 host devices in its own subprocess.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
