"""DSPS substrate tests: query IR, simulator physics, generator corpus."""

import numpy as np
import pytest
from _propcheck import given, settings, strategies as st

from repro.dsps import (
    Cluster,
    GeneratorConfig,
    HardwareNode,
    Placement,
    WorkloadGenerator,
    hardware_bin,
    simulate,
)
from repro.dsps.query import OpType
from repro.dsps.simulator import SimulatorConfig, analyze_operators, _dtype_mix
from repro.dsps.benchmarks import sample_benchmark_query

GEN = WorkloadGenerator(seed=123)


def test_query_structure():
    q = GEN.query(kind="three_way", name="t3")
    assert q.count(OpType.SOURCE) == 3
    assert q.count(OpType.JOIN) == 2
    assert len(q.sinks()) == 1
    order = q.topological_order()
    assert len(order) == q.n_ops()
    # every edge goes forward in topological order
    pos = {u: i for i, u in enumerate(order)}
    assert all(pos[u] < pos[v] for u, v in q.edges)


def test_widths_propagate():
    q = GEN.query(kind="two_way")
    for op in q.operators:
        if op.op_type != OpType.SOURCE:
            assert op.tuple_width_in > 0
    j = [o for o in q.operators if o.op_type == OpType.JOIN][0]
    parents = q.parents(j.op_id)
    assert j.tuple_width_in == sum(q.op(p).tuple_width_out for p in parents)


def test_simulator_deterministic():
    q = GEN.query(kind="linear", name="det")
    c = GEN.cluster(4)
    p = GEN.placement(q, c)
    a = simulate(q, c, p)
    b = simulate(q, c, p)
    assert a == b  # rng derived from (query, placement) hash


def test_le_geq_lp():
    for i in range(30):
        t = GEN.trace(name=f"le{i}")
        assert t.labels.latency_e >= t.labels.latency_p


def test_failed_queries_have_zero_throughput():
    for i in range(60):
        t = GEN.trace(name=f"s{i}")
        if t.labels.success == 0:
            assert t.labels.throughput == 0.0


def test_stronger_cpu_not_worse():
    """More CPU on every host must not increase latency (noise disabled)."""
    sim = SimulatorConfig(noise_sigma=0.0)
    worse = 0
    for i in range(20):
        q = GEN.query(name=f"cpu{i}")
        c = GEN.cluster(4)
        p = GEN.placement(q, c)
        weak = simulate(q, c, p, sim)
        strong_nodes = [
            HardwareNode(n.node_id, n.cpu * 4, n.ram_mb, n.bandwidth_mbps, n.latency_ms)
            for n in c.nodes
        ]
        strong = simulate(q, Cluster(strong_nodes), p, sim)
        if strong.latency_p > weak.latency_p * 1.001:
            worse += 1
    assert worse == 0


def test_backpressure_under_overload():
    """A tiny host fed a huge rate must backpressure."""
    gen = WorkloadGenerator(
        GeneratorConfig().with_hardware(cpu=(50,), event_rate_linear=(25600,)), seed=1
    )
    bp = 0
    for i in range(20):
        q = gen.query(kind="linear", name=f"bp{i}")
        c = gen.cluster(3)
        p = gen.placement(q, c)
        labels = simulate(q, c, p)
        bp += labels.backpressure == 0
    assert bp > 10  # most runs are backpressured


def test_corpus_mix():
    gen = WorkloadGenerator(seed=7)
    kinds = {"linear": 0, "two_way": 0, "three_way": 0}
    for i in range(300):
        q = gen.query(name=f"m{i}")
        joins = q.count(OpType.JOIN)
        kinds[["linear", "two_way", "three_way"][joins]] += 1
    # paper SVI: ~35/34/31
    assert 0.2 < kinds["linear"] / 300 < 0.5
    assert 0.2 < kinds["two_way"] / 300 < 0.5
    assert 0.15 < kinds["three_way"] / 300 < 0.45


def test_hardware_bins_ordered():
    lo = HardwareNode(0, 50, 1000, 25, 160)
    hi = HardwareNode(1, 800, 32000, 10000, 1)
    assert hardware_bin(lo) == 0
    assert hardware_bin(hi) == 2


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000))
def test_selectivities_bounded(seed):
    gen = WorkloadGenerator(seed=seed)
    q = gen.query(name="h")
    for op in q.operators:
        assert 0.0 <= op.selectivity <= 1.0
        if op.window is not None:
            assert op.window.size > 0


def test_benchmark_queries_simulate():
    rng = np.random.default_rng(3)
    for name in ("advertisement", "spike_detection", "smart_grid_global", "smart_grid_local"):
        q = sample_benchmark_query(name, rng)
        c = GEN.cluster(5)
        p = GEN.placement(q, c)
        labels = simulate(q, c, p)
        assert labels.latency_p > 0


def test_operator_rates_conserve():
    q = GEN.query(kind="linear", name="rates")
    rt = analyze_operators(q, _dtype_mix(q))
    for op in q.operators:
        if op.op_type == OpType.FILTER:
            parent = q.parents(op.op_id)[0]
            assert rt[op.op_id].rate_out <= rt[parent].rate_out + 1e-9
