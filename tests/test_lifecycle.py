"""Fault-tolerant serving lifecycle battery (docs/robustness.md): circuit
breaker state machine, heuristic fallback scoring, per-request deadlines,
NaN/Inf guarding, bundle hot-swap at drain boundaries, shadow-evaluated
promotion with rollback, and the deterministic end-to-end brown-out ->
recover -> promote -> reject -> rollback scenario."""

import threading
import time

import jax
import numpy as np
import pytest

from repro.core import CostModelConfig, GNNConfig, init_cost_model
from repro.dsps import WorkloadGenerator
from repro.placement import sample_assignment_matrix
from repro.placement.enumerate import heuristic_placement
from repro.serve import (
    BundleSwapper,
    CircuitBreaker,
    CostEstimator,
    DispatchPolicy,
    EstimateTimeoutError,
    NonFiniteEstimate,
    PlacementService,
    ShadowRejected,
    fallback_scores,
    poisson_arrivals,
    run_open_loop,
    score_request_stream,
)
from repro.serve.chaos import NaNFault, RaiseFault
from repro.serve.lifecycle import _spearman

METRICS = ("latency_p", "success", "backpressure")

#: fast deterministic lifecycle thresholds for the whole module: tiny breaker
#: window/cooldown, mirror everything, small shadow + health windows
_POLICY = DispatchPolicy(
    shadow_fraction=1.0,
    shadow_min_requests=3,
    health_window_requests=4,
    health_error_rate_max=0.25,
    breaker_window=8,
    breaker_failure_rate=0.5,
    breaker_min_samples=2,
    breaker_cooldown_s=0.05,
    retry_max_attempts=2,
    retry_backoff_s=0.001,
)


def _models(hidden=16, n_ensemble=2, key_base=0):
    models = {}
    for i, m in enumerate(METRICS):
        cfg = CostModelConfig(metric=m, n_ensemble=n_ensemble, gnn=GNNConfig(hidden=hidden))
        models[m] = (init_cost_model(jax.random.PRNGKey(key_base + i), cfg), cfg)
    return models


_EST = CostEstimator(_models())  # module-shared: jit caches stay warm


def _structures(n=2, seed=171):
    gen = WorkloadGenerator(seed=seed)
    kinds = ("linear", "two_way")
    return [
        (gen.query(kind=kinds[i % len(kinds)], name=f"life{i}"), gen.cluster(3 + i))
        for i in range(n)
    ]


_STRUCTURES = _structures()


def _service(est=None, **kw):
    kw.setdefault("policy", _POLICY)
    kw.setdefault("auto_start", True)
    return PlacementService(est if est is not None else _EST, **kw)


def _score_burst(svc, n, cands=3, seed=0, deadline_s=None):
    rng = np.random.default_rng(seed)
    futs = []
    for i in range(n):
        q, c = _STRUCTURES[i % len(_STRUCTURES)]
        a = sample_assignment_matrix(q, c, cands, rng)
        futs.append(svc.submit_score(q, c, a, METRICS, deadline_s=deadline_s))
    return futs


# -- circuit breaker --------------------------------------------------------------


def test_breaker_state_machine_deterministic_clock():
    now = {"t": 0.0}
    cb = CircuitBreaker(window=4, failure_rate=0.5, min_samples=2, cooldown_s=1.0,
                        clock=lambda: now["t"])
    assert cb.state == "closed" and cb.allow()
    cb.record_failure()
    assert cb.state == "closed", "below min_samples: one failure is not a verdict"
    cb.record_failure()
    assert cb.state == "open" and cb.n_opens == 1
    assert not cb.allow(), "open + cooldown not expired: denied"
    now["t"] = 1.5
    assert cb.allow(), "cooldown expired: exactly one half-open probe"
    assert cb.state == "half_open"
    assert not cb.allow(), "second call while the probe is in flight: denied"
    cb.record_failure()  # probe failed
    assert cb.state == "open" and cb.n_opens == 2
    now["t"] = 3.0
    assert cb.allow()
    cb.record_success()  # probe succeeded
    assert cb.state == "closed" and cb.allow()
    # the window slid clean on recovery: old failures don't linger
    cb.record_failure()
    assert cb.state == "closed"


def test_breaker_windowed_rate_and_policy_wiring():
    cb = CircuitBreaker.from_policy(_POLICY, clock=lambda: 0.0)
    assert (cb.window, cb.failure_rate, cb.min_samples, cb.cooldown_s) == (
        _POLICY.breaker_window,
        _POLICY.breaker_failure_rate,
        _POLICY.breaker_min_samples,
        _POLICY.breaker_cooldown_s,
    )
    # failure rate is windowed: enough successes keep an occasional failure
    # from tripping it
    for _ in range(6):
        cb.record_success()
    cb.record_failure()
    cb.record_failure()
    assert cb.state == "closed", "2/8 failures < 0.5"
    with pytest.raises(ValueError):
        CircuitBreaker(window=2, min_samples=4)


# -- heuristic fallback -----------------------------------------------------------


def test_fallback_scores_rank_by_heuristic_distance():
    q, c = _STRUCTURES[0]
    ref = np.asarray(heuristic_placement(q, c).assignment)
    far = (ref + 1) % 2  # flip every operator's node
    a = np.stack([ref, far])
    out = fallback_scores(q, c, a, ("latency_p", "throughput", "success", "backpressure"))
    assert set(out) == {"latency_p", "throughput", "success", "backpressure"}
    for v in out.values():
        assert np.isfinite(v).all() and v.shape == (2,)
    # minimized metric: the heuristic placement itself scores best (lowest)
    assert out["latency_p"][0] < out["latency_p"][1]
    # maximized metric: inverted
    assert out["throughput"][0] > out["throughput"][1]
    # feasibility filters answer optimistically (never empty the candidate set)
    assert np.all(out["success"] == 1.0) and np.all(out["backpressure"] == 1.0)
    with pytest.raises(ValueError):
        fallback_scores(q, c, np.empty((0, len(ref)), dtype=np.int64), ("latency_p",))


def test_spearman_rank_correlation():
    assert _spearman(np.array([1.0, 2.0, 3.0]), np.array([10.0, 20.0, 30.0])) == 1.0
    assert _spearman(np.array([1.0, 2.0, 3.0]), np.array([3.0, 2.0, 1.0])) == -1.0
    assert _spearman(np.array([1.0]), np.array([2.0])) is None
    assert _spearman(np.array([1.0, 1.0]), np.array([1.0, 1.0])) == 1.0
    assert _spearman(np.array([1.0, 1.0]), np.array([1.0, 2.0])) == 0.0


# -- NaN guard + deadlines --------------------------------------------------------


def test_nonfinite_guard_raises_on_direct_estimator_call():
    est = CostEstimator(_models())
    fault = NaNFault(p=1.0, seed=0)
    est.add_hook(fault)
    try:
        q, c = _STRUCTURES[0]
        a = sample_assignment_matrix(q, c, 3, np.random.default_rng(0))
        with pytest.raises(NonFiniteEstimate, match="non-finite"):
            est.score(q, c, a, METRICS)
    finally:
        est.remove_hook(fault)
    out = est.score(q, c, a, METRICS)  # hook removed: clean again
    assert all(np.isfinite(v).all() for v in out.values())


def test_deadline_enforced_at_finalize():
    est = CostEstimator(_models())
    orig = est.score

    def slow(*a, **k):
        time.sleep(0.15)
        return orig(*a, **k)

    est.score = slow
    try:
        svc = _service(est, cross_query=False)
        q, c = _STRUCTURES[0]
        a = sample_assignment_matrix(q, c, 2, np.random.default_rng(0))
        late = svc.submit_score(q, c, a, METRICS, deadline_s=0.01)
        with pytest.raises(EstimateTimeoutError, match="deadline"):
            late.result(timeout=60)
        ok = svc.submit_score(q, c, a, METRICS, deadline_s=30.0)
        assert ok.result(timeout=60) is not None
        svc.close()
        assert svc.stats.n_timeouts == 1
    finally:
        est.score = orig
    with pytest.raises(ValueError):
        _service(est).submit_score(q, c, a, METRICS, deadline_s=-1.0)


# -- breaker through the service --------------------------------------------------


def test_breaker_opens_serves_fallback_then_recovers():
    """NaN brown-out: the guard trips, the breaker opens, clients keep getting
    (degraded) answers — zero exceptions — and after the fault clears the
    half-open probe closes the breaker and real answers resume."""
    est = CostEstimator(_models())
    fault = NaNFault(p=1.0, seed=0)
    svc = _service(est)
    est.add_hook(fault)
    try:
        futs = _score_burst(svc, 8, seed=1)
        answers = [f.result(timeout=120) for f in futs]  # raises if any failed
        degraded = [a for a in answers if getattr(a, "degraded", False)]
        assert degraded, "the brown-out produced fallback answers"
        assert svc.stats.n_nonfinite >= 1, "the NaN guard saw the fault"
        assert svc.stats.n_failed == 0, "zero client-visible failures"
        assert svc.breaker.state != "closed" and svc.stats.degraded
        assert svc.stats.n_degraded == len(degraded)
    finally:
        est.remove_hook(fault)
    # fault cleared: wait out the cooldown, then the probe closes the breaker
    deadline = time.monotonic() + 30
    while time.monotonic() < deadline:
        time.sleep(_POLICY.breaker_cooldown_s)
        ans = [f.result(timeout=120) for f in _score_burst(svc, 2, seed=2)]
        if svc.breaker.state == "closed" and not any(
            getattr(a, "degraded", False) for a in ans
        ):
            break
    else:
        pytest.fail("breaker never closed after the fault cleared")
    q, c = _STRUCTURES[0]
    a = sample_assignment_matrix(q, c, 3, np.random.default_rng(7))
    have = svc.score(q, c, a, METRICS)
    want = _EST.score(q, c, a, METRICS)
    for m in METRICS:
        np.testing.assert_allclose(have[m], want[m], rtol=1e-4, atol=1e-5)
    svc.close()


def test_transient_raise_is_retried_not_delivered():
    est = CostEstimator(_models())
    fault = RaiseFault(p=1.0, seed=0)
    svc = _service(est, cross_query=False)
    est.add_hook(fault)
    try:
        # the fault hits launch AND the first retry; disable it from a
        # concurrent thread after the first backoff so the retry lands
        fut = _score_burst(svc, 1, seed=3)[0]
        threading.Timer(0.02, lambda: setattr(fault, "enabled", False)).start()
        ans = fut.result(timeout=120)
        assert ans is not None and svc.stats.n_failed == 0
        assert svc.stats.n_retries >= 1
    finally:
        est.remove_hook(fault)
        svc.close()


# -- hot swap ---------------------------------------------------------------------


def test_swap_bundle_applies_at_drain_boundary_and_returns_old():
    est_a = CostEstimator(_models(key_base=0))
    est_b = CostEstimator(_models(key_base=50))  # different weights
    svc = _service(est_a)
    q, c = _STRUCTURES[0]
    a = sample_assignment_matrix(q, c, 3, np.random.default_rng(0))
    before = svc.score(q, c, a, METRICS)
    old = svc.swap_bundle(est_b, wait=True)
    assert old is est_a and svc.estimator is est_b and svc.stats.n_swaps == 1
    after = svc.score(q, c, a, METRICS)
    assert not np.allclose(before["latency_p"], after["latency_p"]), (
        "different weights must answer differently"
    )
    want = est_b.score(q, c, a, METRICS)
    np.testing.assert_allclose(after["latency_p"], want["latency_p"], rtol=1e-5, atol=1e-7)
    svc.close()
    with pytest.raises(RuntimeError, match="closed"):
        svc.swap_bundle(est_a)


def test_swap_on_unstarted_service_applies_immediately():
    est_b = CostEstimator(_models())
    svc = _service(auto_start=False)
    old = svc.swap_bundle(est_b, wait=True)
    assert old is _EST and svc.estimator is est_b and svc.stats.n_swaps == 1
    svc.close()


def test_close_races_inflight_swap_no_lost_futures():
    """close() racing a wait=False swap: every request future resolves and
    the swap future is resolved either way — applied by the worker's final
    drains, or failed by close(); never silently dropped."""
    for attempt in range(4):  # several interleavings of close vs swap apply
        est_b = CostEstimator(_models())
        svc = _service(seed=attempt)
        futs = _score_burst(svc, 6, seed=attempt)
        swap_fut = svc.swap_bundle(est_b, wait=False)
        svc.close()
        for f in futs:
            assert f.exception(timeout=60) is None, "request future lost in the race"
        assert swap_fut.done(), "swap future must always resolve"
        if swap_fut.exception() is None:
            assert swap_fut.result() is _EST and svc.stats.n_swaps == 1
        else:
            assert "closed before the swap applied" in str(swap_fut.exception())


# -- shadow evaluation + promotion ------------------------------------------------


def test_shadow_accepts_equivalent_candidate_and_promotes():
    candidate = CostEstimator(_models())  # same weights, fresh instance
    svc = _service()
    swapper = BundleSwapper(svc, seed=0)
    swapper.start_shadow(candidate)
    futs = _score_burst(svc, 6, seed=5)
    for f in futs:
        assert f.exception(timeout=120) is None
    assert swapper.drain_shadow(timeout=60)
    v = swapper.verdict()
    assert v.accepted and v.n_mirrored >= _POLICY.shadow_min_requests
    assert v.rank_corr is not None and v.rank_corr > 0.99
    assert v.rel_err is not None and v.rel_err < 1e-4
    v2 = swapper.promote(health_window=False)
    assert v2.accepted and svc.estimator is candidate and svc.stats.n_swaps == 1
    swapper.close()
    svc.close()


def test_shadow_rejects_bad_candidate_nothing_swapped():
    candidate = CostEstimator(_models())
    orig = candidate.score

    def inverted(q, c, a, metrics=None, **kw):
        out = dict(orig(q, c, a, metrics))
        return {m: np.asarray(v)[::-1].copy() for m, v in out.items()}

    candidate.score = inverted  # reverses every placement ordering
    svc = _service()
    swapper = BundleSwapper(svc, seed=0)
    swapper.start_shadow(candidate)
    for f in _score_burst(svc, 6, seed=6):
        assert f.exception(timeout=120) is None
    assert swapper.drain_shadow(timeout=60)
    with pytest.raises(ShadowRejected) as exc:
        swapper.promote()
    assert not exc.value.verdict.accepted
    assert svc.estimator is _EST and svc.stats.n_swaps == 0, "nothing swapped"
    swapper.close()
    svc.close()


def test_shadow_rejects_on_insufficient_traffic_and_candidate_errors():
    svc = _service()
    swapper = BundleSwapper(svc, seed=0)
    swapper.start_shadow(CostEstimator(_models()))
    with pytest.raises(ShadowRejected, match="insufficient shadow traffic"):
        swapper.promote()  # no traffic mirrored at all
    # a raising candidate is itself a rejection, regardless of volume
    raising = CostEstimator(_models())
    fault = RaiseFault(p=1.0, seed=0)
    raising.add_hook(fault)
    swapper.start_shadow(raising)
    for f in _score_burst(svc, 6, seed=7):
        assert f.exception(timeout=120) is None
    assert swapper.drain_shadow(timeout=60)
    with pytest.raises(ShadowRejected, match="raised"):
        swapper.promote()
    swapper.close()
    svc.close()


def test_post_promotion_health_regression_rolls_back():
    candidate = CostEstimator(_models())
    svc = _service()
    swapper = BundleSwapper(svc, seed=0)
    swapper.start_shadow(candidate)
    for f in _score_burst(svc, 6, seed=8):
        assert f.exception(timeout=120) is None
    assert swapper.drain_shadow(timeout=60)
    v = swapper.promote(health_window=True)
    assert v.accepted and svc.estimator is candidate
    # the promoted candidate starts emitting NaN: the health window must
    # catch the regression and swap the previous estimator back in
    fault = NaNFault(p=1.0, seed=0)
    candidate.add_hook(fault)
    deadline = time.monotonic() + 60
    while not swapper.rolled_back and time.monotonic() < deadline:
        for f in _score_burst(svc, _POLICY.health_window_requests, seed=9):
            assert f.exception(timeout=120) is None, "zero client-visible failures"
    assert swapper.rolled_back and "health_error_rate_max" in swapper.rollback_reason
    # the rollback swap was queued wait=False from the worker thread: one
    # more drain applies it
    for f in _score_burst(svc, 2, seed=10):
        assert f.exception(timeout=120) is None
    assert svc.estimator is _EST, "previous estimator restored"
    assert svc.stats.n_swaps == 2  # promote + rollback
    swapper.close()
    svc.close()


def test_worker_death_mid_shadow_futures_fail_shadow_stops_clean():
    est = CostEstimator(_models())
    svc = _service(est, auto_start=False)
    swapper = BundleSwapper(svc, seed=0)
    swapper.start_shadow(CostEstimator(_models()))
    crash = RuntimeError("worker skeleton crash")

    def exploding_launch(reqs):
        raise crash

    svc._launch_group = exploding_launch
    q, c = _STRUCTURES[0]
    a = sample_assignment_matrix(q, c, 2, np.random.default_rng(1))
    fut = svc.submit_score(q, c, a, METRICS)
    svc.start()
    with pytest.raises(RuntimeError, match="worker skeleton crash"):
        fut.result(timeout=60)
    # the mirror never saw a delivered answer; stopping must not hang and the
    # verdict must reject (nothing was observed)
    assert swapper.drain_shadow(timeout=10)
    with pytest.raises(ShadowRejected, match="insufficient shadow traffic"):
        swapper.promote()
    swapper.close()
    svc.close()


# -- the end-to-end acceptance scenario -------------------------------------------


@pytest.mark.filterwarnings("ignore::pytest.PytestUnhandledThreadExceptionWarning")
def test_end_to_end_lifecycle_brownout_promote_reject_rollback():
    """ISSUE 10 acceptance: a live service under open-loop load survives a NaN
    brown-out on fallback answers (zero client-visible failures), recovers,
    shadow-promotes a good candidate, shadow-rejects a bad one, and
    auto-rolls back a post-promotion regression — deterministically seeded
    end to end."""
    est = CostEstimator(_models())
    svc = _service(est, seed=42)
    lost = []

    def drive(n, seed, rate=200.0):
        stream = score_request_stream(_STRUCTURES, n, 3, seed=seed, metrics=METRICS)(svc)
        rep = run_open_loop(svc, stream, poisson_arrivals(rate, n, seed=seed), timeout_s=300)
        lost.append(rep.n_requests - rep.n_answered - rep.n_rejected - rep.n_failed)
        return rep

    # phase 1: healthy traffic
    rep = drive(8, seed=1)
    assert rep.n_failed == 0 and svc.breaker.state == "closed"

    # phase 2: NaN brown-out -> breaker opens, fallback answers, zero failures
    fault = NaNFault(p=1.0, seed=0)
    est.add_hook(fault)
    rep = drive(10, seed=2)
    assert rep.n_failed == 0, "brown-out must degrade, never fail clients"
    assert svc.stats.n_nonfinite >= 1 and svc.stats.n_degraded >= 1
    assert svc.breaker.n_opens >= 1
    est.remove_hook(fault)

    # phase 3: fault cleared -> breaker closes via half-open probe
    deadline = time.monotonic() + 60
    while svc.breaker.state != "closed" and time.monotonic() < deadline:
        time.sleep(_POLICY.breaker_cooldown_s)
        drive(2, seed=3)
    assert svc.breaker.state == "closed", "breaker must recover after the fault"

    # phase 4: shadow-evaluate + promote a good candidate under live load
    good = CostEstimator(_models())
    swapper = BundleSwapper(svc, seed=7)
    swapper.start_shadow(good)
    drive(8, seed=4)
    assert swapper.drain_shadow(timeout=60)
    v = swapper.promote(health_window=False)
    assert v.accepted and svc.estimator is good and svc.breaker.state == "closed"

    # phase 5: a deliberately-bad candidate is rejected by shadow
    bad = CostEstimator(_models())
    orig = bad.score
    bad.score = lambda q, c, a, metrics=None, **kw: {
        m: np.asarray(val)[::-1].copy() for m, val in orig(q, c, a, metrics).items()
    }
    swapper.start_shadow(bad)
    drive(8, seed=5)
    assert swapper.drain_shadow(timeout=60)
    with pytest.raises(ShadowRejected):
        swapper.promote()
    assert svc.estimator is good, "rejected candidate never went live"

    # phase 6: a candidate that passes shadow but regresses after promotion
    # is auto-rolled back by the health window
    sleeper = CostEstimator(_models())
    swapper.start_shadow(sleeper)
    drive(8, seed=6)
    assert swapper.drain_shadow(timeout=60)
    swapper.promote(health_window=True)
    assert svc.estimator is sleeper
    regress = NaNFault(p=1.0, seed=1)
    sleeper.add_hook(regress)
    deadline = time.monotonic() + 60
    while not swapper.rolled_back and time.monotonic() < deadline:
        drive(_POLICY.health_window_requests, seed=7)
    assert swapper.rolled_back, "health window must catch the regression"
    drive(2, seed=8)  # applies the queued rollback swap at a drain boundary
    assert svc.estimator is good, "rolled back to the pre-regression estimator"

    assert sum(lost) == 0, "zero lost futures across the whole lifecycle"
    swapper.close()
    svc.close()
