"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps + grads."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _propcheck import given, settings, strategies as st

from repro import nn
from repro.core.graph import SLOT_RANGES
from repro.kernels.banked_mlp.ops import banked_mlp_slotted
from repro.kernels.banked_mlp.ref import banked_mlp_slotted_ref
from repro.kernels.mp_update.ops import mp_update
from repro.kernels.mp_update.ref import mp_update_ref
from repro.kernels.rglru.ops import linear_scan
from repro.kernels.rglru.ref import linear_scan_ref


@pytest.fixture(autouse=True)
def _force_pallas_interpreter(monkeypatch):
    """Off-TPU the ops lower to the jnp oracle by default; parity tests must
    execute the actual Pallas kernel body, so force the interpreter here."""
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")


@pytest.mark.parametrize("B", [1, 2, 8])
@pytest.mark.parametrize("F,H", [(39, 32), (64, 64), (128, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_banked_mlp_sweep(B, F, H, dtype):
    key = jax.random.PRNGKey(B * 1000 + F)
    p = nn.init_mlp_bank(key, 5, [F, H, H])
    if dtype == jnp.bfloat16:
        p = nn.cast_floats(p, dtype)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, 12, F), dtype)
    out_k = banked_mlp_slotted(p, x, SLOT_RANGES)
    out_r = banked_mlp_slotted_ref(p, x, SLOT_RANGES)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        np.asarray(out_k, np.float32), np.asarray(out_r, np.float32), atol=tol, rtol=tol
    )


def test_banked_mlp_grads_match():
    p = nn.init_mlp_bank(jax.random.PRNGKey(0), 5, [39, 32, 32])
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 12, 39))
    gk = jax.grad(lambda p, x: jnp.sum(banked_mlp_slotted(p, x, SLOT_RANGES) ** 2), argnums=(0, 1))(p, x)
    gr = jax.grad(lambda p, x: jnp.sum(banked_mlp_slotted_ref(p, x, SLOT_RANGES) ** 2), argnums=(0, 1))(p, x)
    for a, b in zip(jax.tree_util.tree_leaves(gk), jax.tree_util.tree_leaves(gr)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


@pytest.mark.parametrize("B", [1, 4])
@pytest.mark.parametrize("H", [32, 64])
def test_mp_update_sweep(B, H):
    key = jax.random.PRNGKey(H + B)
    p = nn.init_mlp_bank(key, 5, [2 * H, H, H])
    h = jax.random.normal(jax.random.PRNGKey(1), (B, 12, H))
    a = (jax.random.uniform(jax.random.PRNGKey(2), (B, 12, 12)) > 0.75).astype(jnp.float32)
    depth = jax.random.randint(jax.random.PRNGKey(3), (B, 12), 0, 6)
    mask = (jax.random.uniform(jax.random.PRNGKey(4), (B, 12)) > 0.2).astype(jnp.float32)
    for d in [0, 2, 5]:
        dd = jnp.asarray(d, jnp.int32)
        out_k = mp_update(p, h, a, depth, mask, dd, SLOT_RANGES)
        out_r = mp_update_ref(p, h, a, depth, mask, dd, SLOT_RANGES)
        np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r), atol=1e-5)


def test_mp_update_row_span_matches_full_width():
    """The banded kernel path (static row_span + parent_rows) must equal the
    full-width step wherever the banding promises hold: rows in the span are
    the depth-d rows and no parent lives at or past the span start.  Runs
    under the forced interpreter, so the actual kernel slicing executes."""
    H, B = 32, 4
    s, e, d = 3, 7, 2  # span rows = the filter slot range of SLOT_RANGES
    p = nn.init_mlp_bank(jax.random.PRNGKey(0), 5, [2 * H, H, H])
    h = jax.random.normal(jax.random.PRNGKey(1), (B, 12, H))
    a = (jax.random.uniform(jax.random.PRNGKey(2), (B, 12, 12)) > 0.6).astype(jnp.float32)
    a = a.at[:, s:, s:e].set(0.0)  # parents of span rows precede the span
    depth = jnp.full((B, 12), 1, jnp.int32).at[:, s:e].set(d)
    mask = jnp.ones((B, 12))
    dd = jnp.asarray(d, jnp.int32)
    banded = mp_update(
        p, h, a, depth, mask, dd, ((1, s, e),), row_span=(s, e), parent_rows=s
    )
    full = mp_update(p, h, a, depth, mask, dd, SLOT_RANGES)
    np.testing.assert_allclose(np.asarray(banded), np.asarray(full), atol=1e-5)
    # and against the banded jnp oracle explicitly
    ref = mp_update_ref(p, h, a, depth, mask, dd, ((1, s, e),), row_span=(s, e), parent_rows=s)
    np.testing.assert_allclose(np.asarray(banded), np.asarray(ref), atol=1e-5)


def test_mp_update_broadcasts_shared_skeleton_fields():
    """The placed path passes one shared (N,N)/(N,) skeleton for a (B,N,H)
    state; the wrapper must broadcast and match the fully-batched call."""
    H, B = 32, 4
    p = nn.init_mlp_bank(jax.random.PRNGKey(0), 5, [2 * H, H, H])
    h = jax.random.normal(jax.random.PRNGKey(1), (B, 12, H))
    a = (jax.random.uniform(jax.random.PRNGKey(2), (12, 12)) > 0.7).astype(jnp.float32)
    depth = jax.random.randint(jax.random.PRNGKey(3), (12,), 0, 6)
    mask = (jax.random.uniform(jax.random.PRNGKey(4), (12,)) > 0.2).astype(jnp.float32)
    d = jnp.asarray(2, jnp.int32)
    out = mp_update(p, h, a, depth, mask, d, SLOT_RANGES)
    ref = mp_update(
        p,
        h,
        jnp.broadcast_to(a, (B,) + a.shape),
        jnp.broadcast_to(depth, (B,) + depth.shape),
        jnp.broadcast_to(mask, (B,) + mask.shape),
        d,
        SLOT_RANGES,
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-6)


def test_mp_update_only_touches_selected_depth():
    H = 16
    p = nn.init_mlp_bank(jax.random.PRNGKey(0), 5, [2 * H, H, H])
    h = jax.random.normal(jax.random.PRNGKey(1), (1, 12, H))
    a = jnp.zeros((1, 12, 12))
    depth = jnp.zeros((1, 12), jnp.int32).at[0, 3].set(2)
    mask = jnp.ones((1, 12))
    out = mp_update(p, h, a, depth, mask, jnp.asarray(2, jnp.int32), SLOT_RANGES)
    # all rows except depth==2 rows must be unchanged
    unchanged = np.ones(12, bool)
    unchanged[3] = False
    np.testing.assert_allclose(np.asarray(out[0, unchanged]), np.asarray(h[0, unchanged]))
    assert not np.allclose(np.asarray(out[0, 3]), np.asarray(h[0, 3]))


@pytest.mark.parametrize("B,T,D", [(1, 16, 8), (2, 128, 32), (4, 256, 16)])
def test_rglru_scan_sweep(B, T, D):
    ks = jax.random.split(jax.random.PRNGKey(T), 3)
    a = jax.random.uniform(ks[0], (B, T, D), minval=0.5, maxval=0.999)
    b = jax.random.normal(ks[1], (B, T, D)) * 0.1
    h0 = jax.random.normal(ks[2], (B, D))
    np.testing.assert_allclose(
        np.asarray(linear_scan(a, b, h0)),
        np.asarray(linear_scan_ref(a, b, h0)),
        atol=1e-5,
    )


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 4), st.integers(1, 8), st.integers(1, 16))
def test_rglru_hypothesis_shapes(B, T, D):
    ks = jax.random.split(jax.random.PRNGKey(B * 100 + T * 10 + D), 3)
    a = jax.random.uniform(ks[0], (B, T, D), minval=0.0, maxval=1.0)
    b = jax.random.normal(ks[1], (B, T, D))
    h0 = jax.random.normal(ks[2], (B, D))
    out = linear_scan(a, b, h0)
    ref = linear_scan_ref(a, b, h0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)


def test_rglru_matches_sequential():
    """Oracle itself vs an explicit python loop."""
    B, T, D = 2, 7, 3
    rng = np.random.default_rng(0)
    a = rng.uniform(0.5, 1.0, (B, T, D)).astype(np.float32)
    b = rng.normal(size=(B, T, D)).astype(np.float32)
    h0 = rng.normal(size=(B, D)).astype(np.float32)
    h = h0.copy()
    expect = np.zeros_like(a)
    for t in range(T):
        h = a[:, t] * h + b[:, t]
        expect[:, t] = h
    np.testing.assert_allclose(np.asarray(linear_scan_ref(jnp.asarray(a), jnp.asarray(b), jnp.asarray(h0))), expect, atol=1e-5)


def test_pallas_gnn_path_matches_jnp():
    import repro.core as core
    from repro.dsps import WorkloadGenerator
    from repro.training import dataset_from_traces

    traces = WorkloadGenerator(seed=3).corpus(8)
    ds = dataset_from_traces(traces, "latency_p")
    g = jax.tree_util.tree_map(jnp.asarray, ds.graphs)
    cfg_ref = core.CostModelConfig(metric="latency_p", n_ensemble=2, gnn=core.GNNConfig(hidden=16))
    cfg_pal = core.CostModelConfig(
        metric="latency_p", n_ensemble=2, gnn=core.GNNConfig(hidden=16, use_pallas=True)
    )
    params = core.init_cost_model(jax.random.PRNGKey(0), cfg_ref)
    r1 = core.forward_ensemble(params, g, cfg_ref)
    r2 = core.forward_ensemble(params, g, cfg_pal)
    np.testing.assert_allclose(np.asarray(r1), np.asarray(r2), atol=1e-4)
