"""Distribution substrate: attention oracle equivalence, DP compression step,
pipeline parallelism, small-mesh dry-run (subprocess), fault harness."""

import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.blocks import _attend_blocked, _attend_naive

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def test_blocked_attention_matches_naive():
    key = jax.random.PRNGKey(0)
    B, Sq, Sk, H, KV, D = 2, 64, 2048, 4, 2, 16
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, Sq, H, D))
    k = jax.random.normal(ks[1], (B, Sk, KV, D))
    v = jax.random.normal(ks[2], (B, Sk, KV, D))
    kw = dict(
        q_pos=jnp.arange(Sk - Sq, Sk),
        k_pos=jnp.arange(Sk),
        causal=True,
        window=300,
        cap=30.0,
        k_len=None,
    )
    a = _attend_naive(q, k, v, **kw)
    b = _attend_blocked(q, k, v, block=256, **kw)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=3e-5)


def test_dp_train_step_single_device_mesh():
    """shard_map DP step with int8 compression on a 1-device mesh."""
    from repro.distributed import make_dp_train_step
    from repro.training import optim

    mesh = jax.make_mesh((1,), ("data",))

    def loss_fn(params, batch):
        pred = batch["x"] @ params["w"]
        return jnp.mean((pred - batch["y"]) ** 2)

    opt = optim.adam(lr=0.1)
    params = {"w": jnp.ones((4, 1))}
    state = {"params": params, "opt": opt.init(params), "step": jnp.zeros((), jnp.int32)}
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(16, 4)), jnp.float32)
    w_true = jnp.asarray([[1.0], [-2.0], [0.5], [3.0]])
    batch = {"x": x, "y": x @ w_true}

    step = make_dp_train_step(loss_fn, opt, mesh, compression="int8")
    losses = []
    for i in range(60):
        state, m = step(state, batch, jax.random.PRNGKey(i))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.1


def _run_subprocess(code: str) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env.pop("JAX_PLATFORMS", None)
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True,
        text=True,
        env=env,
        timeout=600,
    )
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    return out.stdout


@pytest.mark.slow
def test_pipeline_parallel_4_devices():
    out = _run_subprocess(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp, numpy as np
        from repro.distributed import pipeline_forward
        mesh = jax.make_mesh((4,), ("pipe",))
        W = jnp.stack([jnp.eye(8) * (i + 1) for i in range(4)])  # 4 stage mats
        def stage(w, x):
            return x @ w
        piped = pipeline_forward(stage, mesh)
        xs = jnp.asarray(np.random.default_rng(0).normal(size=(6, 2, 8)), jnp.float32)
        out = piped(W, xs)
        expect = xs
        for i in range(4):
            expect = expect @ (jnp.eye(8) * (i + 1))
        assert np.allclose(out, expect, atol=1e-4), (out[0,0,:3], expect[0,0,:3])
        print("PIPELINE-OK")
        """
    )
    assert "PIPELINE-OK" in out


@pytest.mark.slow
def test_dryrun_small_mesh_subprocess():
    """Lower + compile two reduced cells on an 8-device host mesh; roofline
    terms must be positive and the collective parser must find ops."""
    out = _run_subprocess(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax
        import repro.launch.mesh as M
        M.make_production_mesh = lambda *, multi_pod=False: jax.make_mesh(
            (2, 2, 2) if multi_pod else (4, 2),
            ("pod", "data", "model") if multi_pod else ("data", "model"))
        import repro.configs.base as CB
        CB.SHAPES = (CB.ShapeSpec("train_4k", 128, 8, "train"),
                     CB.ShapeSpec("decode_32k", 256, 8, "decode"))
        import repro.launch.dryrun as D
        from repro.configs import get_config, reduced
        _orig = get_config
        D.get_config = lambda a: reduced(_orig(a))
        import json
        for arch in ["internlm2-1.8b", "gemma2-2b"]:
            for mp in [False, True]:
                cell = D.run_cell(arch, "train_4k", mp, save=False, verbose=False)
                assert cell["status"] == "ok", cell.get("error")
                r = cell["roofline"]
                assert r["t_compute_s"] > 0 and r["t_memory_s"] > 0
                assert r["collectives"]["count"] > 0
        cell = D.run_cell("internlm2-1.8b", "decode_32k", False, save=False, verbose=False)
        assert cell["status"] == "ok", cell.get("error")
        print("DRYRUN-OK")
        """
    )
    assert "DRYRUN-OK" in out


def test_fault_harness_recovery(tmp_path):
    from repro.launch.faults import ClusterMonitor, FaultPolicy, run_with_faults
    from repro.training.checkpoint import latest_step, restore_checkpoint, save_checkpoint

    ckdir = str(tmp_path / "ck")
    state = {"step": np.zeros(1)}

    def save_fn(step):
        save_checkpoint(ckdir, step, {"step": np.asarray([step], np.float64)})

    def restore_fn():
        s = latest_step(ckdir)
        return int(s) if s is not None else None

    def train_epoch(start, n_hosts):
        assert n_hosts >= 1
        return start + 10

    monitor = ClusterMonitor(n_hosts=8, policy=FaultPolicy(heartbeat_timeout_s=5))
    schedule = {20: ("fail", 3), 40: ("straggle", 5)}
    final, events = run_with_faults(
        train_epoch, save_fn, restore_fn, monitor, schedule, total_steps=100
    )
    assert final >= 100
    assert len(events) >= 1  # at least the host failure triggered recovery
    reasons = ";".join(e.reason for e in events)
    assert "heartbeat-timeout" in reasons
    assert monitor.n_alive() <= 7
    # straggler demotion also fires
    assert any("straggler" in r for r in reasons.split(";")) or monitor.n_alive() <= 6
