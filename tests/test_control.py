"""Continuous placement controller: drift detection, incremental re-placement,
migration budget, cooldown, deterministic replay (docs/controller.md).

All decision-quality scenarios score through the noise-free ``SimulatorScorer``
oracle and seeded ``FleetRuntime`` noise, so every assertion here is
deterministic — these tests pin controller *behavior*, not statistics.
"""

from dataclasses import replace as dc_replace
from functools import lru_cache

import numpy as np
import pytest

from repro.control import (
    FleetRuntime,
    PlacementController,
    ReplanItem,
    Replanner,
    ScenarioEvent,
    SimulatorScorer,
    run_static,
)
from repro.dsps import WorkloadGenerator
from repro.dsps.hardware import Cluster, HardwareNode
from repro.launch.faults import ClusterMonitor
from repro.serve import active_policy


@lru_cache(maxsize=1)
def _corpus():
    """A pool of small linear queries with known analytic loads on the weak
    150-cpu hosts below: index 13 ~0.73 ref-cores (stateful agg), 14 ~0.32,
    2 ~0.42, 4 ~0.07, 6 ~0.03."""
    gen = WorkloadGenerator(seed=11)
    return [gen.query(kind="linear", name=f"t{i}") for i in range(16)]


def _host(i, cpu=150, ram=4000, bw=200, lat=10):
    return HardwareNode(i, cpu, ram, bw, lat)


def _pin(q, host):
    return (q, (host,) * q.n_ops())


def _controller(runtime, **kw):
    kw.setdefault("scorer", SimulatorScorer())
    kw.setdefault("seed", 0)
    return PlacementController(runtime, **kw)


# -- satellite regression: shared mutable default policy ---------------------------


def test_cluster_monitor_default_policy_not_shared():
    """Each monitor must own a fresh FaultPolicy: a dataclass default in the
    signature would be evaluated once, so relaxing one monitor's timeout
    would silently retune every other monitor in the process."""
    a, b = ClusterMonitor(n_hosts=2), ClusterMonitor(n_hosts=2)
    assert a.policy is not b.policy
    a.policy.heartbeat_timeout_s = 1.0
    assert b.policy.heartbeat_timeout_s != 1.0


def test_policy_controller_knobs_validate():
    pol = active_policy()
    pol.validate()  # defaults must pass
    for field, bad in [
        ("controller_tick_s", 0.0),
        ("detector_window", 0),
        ("drift_threshold", -1.0),
        ("migration_budget_mb", -0.5),
        ("replan_cooldown_ticks", -1),
        ("replan_k", 0),
    ]:
        with pytest.raises(ValueError, match=field):
            dc_replace(pol, **{field: bad}).validate()
    # zero is a meaningful setting for these two (no budget / no cooldown)
    dc_replace(pol, migration_budget_mb=0.0, replan_cooldown_ticks=0).validate()


# -- telemetry -----------------------------------------------------------------


def test_observed_cluster_is_residual_capacity():
    qs = _corpus()
    cluster = Cluster([_host(0), _host(1)])
    rt = FleetRuntime([_pin(qs[13], 0), _pin(qs[4], 0)], cluster, seed=0, tick_s=30.0)
    # query 4's view of host 0 is reduced by query 13's resident load; its
    # view of the empty host 1 is the raw node
    view = rt.observed_cluster(1)
    assert view.node(0).cpu < cluster.node(0).cpu
    assert view.node(1).cpu == cluster.node(1).cpu
    # the footprint excludes the query itself
    own_view = rt.observed_cluster(0)
    assert own_view.node(0).cpu > rt.observed_cluster(None).node(0).cpu


# -- drift: localized re-placement ---------------------------------------------


def _isolation_scenario():
    """Query 0 (heavy, stateful) alone on weak host 0 with a strong spare
    host 1; queries 1/2 isolated on their own hosts 2/3.  A x6 rate drift on
    query 0 saturates host 0 and implicates nothing else."""
    qs = _corpus()
    cluster = Cluster([_host(0), _host(1, cpu=600, ram=16000, bw=800, lat=2), _host(2), _host(3)])
    fleet = [_pin(qs[13], 0), _pin(qs[4], 2), _pin(qs[6], 3)]
    events = [ScenarioEvent(tick=3, kind="rate_drift", query=0, factor=6.0)]
    return fleet, cluster, events


def test_drift_replaces_only_affected_query():
    fleet, cluster, events = _isolation_scenario()
    ctl = _controller(FleetRuntime(fleet, cluster, events, seed=5, tick_s=30.0))
    init = {qid: ctl.runtime.assignment(qid) for qid in (1, 2)}
    for _ in range(12):
        ctl.step()
        # unaffected queries' assignments stay bit-identical on EVERY tick
        for qid in (1, 2):
            np.testing.assert_array_equal(ctl.runtime.assignment(qid), init[qid])
    rep = ctl.report()
    log = rep.decision_log()
    assert log, "drift must trigger at least one decision"
    assert {d["query_id"] for d in log} == {0}
    # detection within the window: drift lands at tick 3, the CUSUM needs
    # detector_window samples, so the alarm + migration land at tick 4
    drifts = [a for r in rep.records for a in r.alarms]
    assert {a.query_id for a in drifts} == {0}
    assert drifts[0].kind == "drift" and drifts[0].tick == 4
    first = log[0]
    assert first["action"] == "migrate" and first["tick"] == 4
    assert not np.array_equal(ctl.runtime.assignment(0), (0,) * len(first["old"]))
    # the move rescued the query: steady-state fleet cost is healthy again
    assert rep.final_cost_ms < 100.0
    # ... while doing nothing leaves the fleet saturated
    static = run_static(FleetRuntime(fleet, cluster, events, seed=5, tick_s=30.0), 12)
    assert static.final_cost_ms > 100.0 * rep.final_cost_ms


# -- failure: orphan re-placement ----------------------------------------------


def test_node_failure_always_triggers_orphan_replacement():
    qs = _corpus()
    cluster = Cluster([_host(0), _host(1), _host(2, cpu=300, ram=8000)])
    fleet = [_pin(qs[4], 2), _pin(qs[6], 1)]
    events = [ScenarioEvent(tick=4, kind="fail", host=2)]
    ctl = _controller(FleetRuntime(fleet, cluster, events, seed=5, tick_s=30.0))
    rep = ctl.run(10)
    # the monitor evicts one heartbeat-timeout after the failure; the stranded
    # query alarms "orphaned" that same tick and is re-placed immediately
    orphan_alarms = [a for r in rep.records for a in r.alarms if a.kind == "orphaned"]
    assert orphan_alarms and orphan_alarms[0].query_id == 0
    assert orphan_alarms[0].tick == 5
    tick5 = [d for d in rep.decision_log() if d["tick"] == 5 and d["query_id"] == 0]
    assert tick5 and tick5[0]["action"] in ("migrate", "accept")
    # orphan state died with the host: re-homing it is free
    assert tick5[0]["migration_mb"] == 0.0
    assert ctl.runtime.orphans(0) == ()
    assert ctl.runtime.cluster.n_nodes() == 2
    assert int(max(ctl.runtime.assignment(0))) < 2


def test_budget_zero_still_replaces_orphans():
    qs = _corpus()
    cluster = Cluster([_host(0), _host(1), _host(2, cpu=300, ram=8000)])
    fleet = [_pin(qs[4], 2), _pin(qs[6], 1)]
    events = [ScenarioEvent(tick=4, kind="fail", host=2)]
    pol = dc_replace(active_policy(), migration_budget_mb=0.0)
    ctl = _controller(FleetRuntime(fleet, cluster, events, seed=5, tick_s=30.0), policy=pol)
    rep = ctl.run(10)
    tick5 = [d for d in rep.decision_log() if d["tick"] == 5 and d["query_id"] == 0]
    assert tick5 and tick5[0]["action"] in ("migrate", "accept")
    assert ctl.runtime.orphans(0) == ()


# -- migration budget ----------------------------------------------------------


def test_budget_zero_forces_noop_and_records_degradation():
    fleet, cluster, events = _isolation_scenario()
    pol = dc_replace(active_policy(), migration_budget_mb=0.0)
    ctl = _controller(FleetRuntime(fleet, cluster, events, seed=5, tick_s=30.0), policy=pol)
    rep = ctl.run(12)
    log = rep.decision_log()
    assert log and all(d["action"] == "no-op" for d in log)
    # query 13 carries window state on its aggregate, so every useful move
    # costs >0 MB and the zero budget blocks it — recorded as such
    assert log[0]["reason"] == "over migration budget"
    np.testing.assert_array_equal(ctl.runtime.assignment(0), (0,) * len(log[0]["old"]))
    assert rep.n_migrations == 0 and rep.migrated_mb == 0.0
    # the degradation is recorded, not hidden: the blocked decision carries
    # the (bad) predicted cost of staying, and the fleet stays saturated
    assert log[0]["current_cost"] > 1000.0
    assert rep.final_cost_ms > 1000.0


def test_default_budget_admits_the_same_move():
    fleet, cluster, events = _isolation_scenario()
    ctl = _controller(FleetRuntime(fleet, cluster, events, seed=5, tick_s=30.0))
    rep = ctl.run(12)
    migs = [d for d in rep.decision_log() if d["action"] == "migrate"]
    assert migs and 0.0 < migs[0]["migration_mb"] <= active_policy().migration_budget_mb
    assert rep.max_migration_mb <= active_policy().migration_budget_mb


# -- cooldown ------------------------------------------------------------------


def test_cooldown_prevents_thrash():
    """Two co-located queries saturate their shared host after drift.  Both
    re-plan the same tick without seeing each other's move, so both hop to
    the same spare host — which saturates in turn.  With no cooldown this
    ping-pongs every tick; the cooldown holds each query after a decision
    and cuts the migration count by the cooldown factor."""
    qs = _corpus()
    cluster = Cluster([_host(0), _host(1)])
    fleet = [_pin(qs[13], 0), _pin(qs[2], 0)]
    events = [ScenarioEvent(tick=3, kind="rate_drift", query=0, factor=2.0)]

    def migrations(cooldown: int) -> int:
        pol = dc_replace(
            active_policy(), replan_cooldown_ticks=cooldown, detector_window=1
        )
        ctl = _controller(
            FleetRuntime(fleet, cluster, events, seed=5, tick_s=30.0), policy=pol
        )
        return ctl.run(18).n_migrations

    thrash, damped = migrations(0), migrations(4)
    assert thrash > 2 * damped
    assert damped > 0  # cooldown suppresses thrash, not re-placement itself


# -- deterministic replay ------------------------------------------------------


def test_same_seed_same_decision_log():
    fleet, cluster, events = _isolation_scenario()
    events = events + [ScenarioEvent(tick=7, kind="fail", host=3)]

    def run_once():
        ctl = _controller(FleetRuntime(fleet, cluster, events, seed=5, tick_s=30.0))
        rep = ctl.run(12)
        return rep.decision_log(), [r.fleet_cost_ms for r in rep.records]

    log_a, costs_a = run_once()
    log_b, costs_b = run_once()
    assert log_a == log_b
    assert costs_a == costs_b
    assert any(d["action"] in ("migrate", "accept") for d in log_a)


def test_different_controller_seed_may_differ_but_is_self_consistent():
    fleet, cluster, events = _isolation_scenario()
    ctl = _controller(FleetRuntime(fleet, cluster, events, seed=5, tick_s=30.0), seed=9)
    rep = ctl.run(12)
    # candidate redraws are seeded by (controller seed, tick, query): the run
    # completes and still rescues the fleet
    assert rep.final_cost_ms < 100.0


# -- estimator path ------------------------------------------------------------


@pytest.fixture(scope="module")
def tiny_estimator():
    import jax

    from repro.core import CostModelConfig, GNNConfig, init_cost_model
    from repro.serve import CostEstimator

    models = {}
    for i, metric in enumerate(("latency_e", "success", "backpressure")):
        cfg = CostModelConfig(metric=metric, n_ensemble=1, gnn=GNNConfig(hidden=8))
        models[metric] = (init_cost_model(jax.random.PRNGKey(i), cfg), cfg)
    return CostEstimator(models)


def test_replanner_rides_estimator_score_many(tiny_estimator):
    """Multiple affected queries in one round go through the estimator's
    merged cross-query forward; decisions are deterministic per seed key."""
    qs = _corpus()
    cluster = Cluster([_host(0), _host(1)])
    rt = FleetRuntime([_pin(qs[4], 0), _pin(qs[6], 1)], cluster, seed=0, tick_s=30.0)
    assert tiny_estimator.supports_cross_query(("latency_e", "success", "backpressure"))
    rp = Replanner(estimator=tiny_estimator, budget_mb=64.0, replan_k=8)
    items = [
        ReplanItem(
            query_id=qid,
            query=rt.query(qid),
            cluster=rt.observed_cluster(qid),
            current=tuple(int(x) for x in rt.assignment(qid)),
            free_ops=tuple(range(rt.query(qid).n_ops())),
            state_mb=tuple(float(x) for x in rt.state_mb(qid)),
        )
        for qid in (0, 1)
    ]
    d1 = rp.replan_many(items, seed_key=(0, 1))
    d2 = rp.replan_many(items, seed_key=(0, 1))
    assert [d.to_dict() for d in d1] == [d.to_dict() for d in d2]
    assert all(d.n_candidates > 1 for d in d1)
    assert all(d.action in ("migrate", "no-op") for d in d1)


def test_controller_estimator_smoke(tiny_estimator):
    qs = _corpus()
    cluster = Cluster([_host(0), _host(1)])
    fleet = [_pin(qs[4], 0), _pin(qs[6], 1)]
    events = [ScenarioEvent(tick=2, kind="rate_drift", query=0, factor=4.0)]

    def run_once():
        ctl = PlacementController(
            FleetRuntime(fleet, cluster, events, seed=3, tick_s=30.0),
            estimator=tiny_estimator,
            seed=0,
        )
        return ctl.run(6).decision_log()

    # warm/cold replay must match: the estimator's caches must not leak into
    # decisions
    assert run_once() == run_once()


# -- degraded mode: estimator brown-out defers soft re-plans -----------------------


def test_degraded_defers_drift_replan_until_recovery():
    """While the degraded probe reports a brown-out the controller still
    observes drift alarms but refuses to migrate on them (the scores behind
    them are heuristic fallbacks); when the probe clears, the standing drift
    triggers the deferred move on the next tick."""
    fleet, cluster, events = _isolation_scenario()
    flag = {"on": True}
    ctl = _controller(
        FleetRuntime(fleet, cluster, events, seed=5, tick_s=30.0),
        degraded=lambda: flag["on"],
    )
    for _ in range(8):
        rec = ctl.step()
        assert rec.degraded
        assert not rec.decisions, "soft drift must not re-plan while degraded"
    assert any(a.kind == "drift" for r in ctl.records for a in r.alarms), (
        "alarms stay visible during the brown-out; only the re-plan is deferred"
    )
    flag["on"] = False
    moved = False
    for _ in range(8):
        rec = ctl.step()
        assert not rec.degraded
        moved = moved or any(d.action == "migrate" for d in rec.decisions)
    assert moved, "recovery must release the deferred re-plan"


def test_degraded_still_replaces_orphans():
    """Hard events bypass the brown-out deferral: an orphaned query is
    re-homed immediately even while every tick is flagged degraded."""
    qs = _corpus()
    cluster = Cluster([_host(0), _host(1), _host(2, cpu=300, ram=8000)])
    fleet = [_pin(qs[4], 2), _pin(qs[6], 1)]
    events = [ScenarioEvent(tick=4, kind="fail", host=2)]
    ctl = _controller(
        FleetRuntime(fleet, cluster, events, seed=5, tick_s=30.0),
        degraded=lambda: True,
    )
    rep = ctl.run(10)
    assert all(r.degraded for r in rep.records)
    tick5 = [d for d in rep.decision_log() if d["tick"] == 5 and d["query_id"] == 0]
    assert tick5 and tick5[0]["action"] in ("migrate", "accept")
    assert ctl.runtime.orphans(0) == ()
