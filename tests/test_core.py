"""COSTREAM core tests: featurization, joint graph, GNN, losses, metrics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _propcheck import given, settings, strategies as st

from repro.core import (
    CostModelConfig,
    GNNConfig,
    JointGraph,
    MAX_HW,
    MAX_OPS,
    accuracy,
    apply_gnn,
    batch_graphs,
    bce_loss,
    build_graph,
    drop_hardware,
    ensemble_loss,
    forward_ensemble,
    init_cost_model,
    init_gnn,
    msle_loss,
    qerror,
    qerror_summary,
)
from repro.core.graph import SLOT_RANGES
from repro.serve.estimator import ensemble_predict
from repro.dsps import WorkloadGenerator

GEN = WorkloadGenerator(seed=5)


def _graph(seed=0):
    gen = WorkloadGenerator(seed=seed)
    q = gen.query(name="g")
    c = gen.cluster()
    p = gen.placement(q, c)
    return build_graph(q, c, p), (q, c, p)


def test_graph_slot_layout():
    g, (q, c, p) = _graph(1)
    # every active node sits inside its type's slot range
    for t, start, stop in SLOT_RANGES:
        seg = g.op_type[start:stop]
        assert (seg == t).all()
    assert g.op_mask.sum() == q.n_ops()
    assert g.hw_mask.sum() == c.n_nodes()
    # placement rows sum to 1 for active ops
    assert np.allclose(g.a_place.sum(axis=1) * g.op_mask, g.op_mask)
    # data-flow edge count preserved
    assert g.a_flow.sum() == len(q.edges)


def test_features_finite():
    g, _ = _graph(2)
    assert np.isfinite(g.op_x).all()
    assert np.isfinite(g.hw_x).all()


def test_gnn_padding_invariance():
    """Adding padded host slots must not change the prediction."""
    g, _ = _graph(3)
    cfg = GNNConfig(hidden=16)
    params = init_gnn(jax.random.PRNGKey(0), cfg)
    out1 = apply_gnn(params, jax.tree_util.tree_map(jnp.asarray, g), cfg)
    # zero out a padded host's features with garbage behind the mask
    g2 = g._replace(hw_x=g.hw_x + (1 - g.hw_mask[:, None]) * 999.0)
    out2 = apply_gnn(params, jax.tree_util.tree_map(jnp.asarray, g2), cfg)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), atol=1e-4)


def test_gnn_host_permutation_invariance():
    """Hosts are a set: permuting host slots permutes nothing observable."""
    g, _ = _graph(4)
    cfg = GNNConfig(hidden=16)
    params = init_gnn(jax.random.PRNGKey(1), cfg)
    perm = np.random.default_rng(0).permutation(MAX_HW)
    g2 = g._replace(
        hw_x=g.hw_x[perm], hw_mask=g.hw_mask[perm], a_place=g.a_place[:, perm]
    )
    out1 = apply_gnn(params, jax.tree_util.tree_map(jnp.asarray, g), cfg)
    out2 = apply_gnn(params, jax.tree_util.tree_map(jnp.asarray, g2), cfg)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), atol=1e-4)


def test_gnn_placement_sensitivity():
    """Moving an operator to a different host must change the prediction."""
    g, (q, c, p) = _graph(5)
    if c.n_nodes() < 2:
        pytest.skip("needs 2 hosts")
    cfg = GNNConfig(hidden=16)
    params = init_gnn(jax.random.PRNGKey(2), cfg)
    out1 = apply_gnn(params, jax.tree_util.tree_map(jnp.asarray, g), cfg)
    a2 = g.a_place.copy()
    row = int(np.argmax(g.op_mask))  # first active op
    new = np.zeros_like(a2[row])
    new[(np.argmax(a2[row]) + 1) % c.n_nodes()] = 1.0
    a2[row] = new
    g2 = g._replace(a_place=a2)
    out2 = apply_gnn(params, jax.tree_util.tree_map(jnp.asarray, g2), cfg)
    assert not np.allclose(np.asarray(out1), np.asarray(out2), atol=1e-6)


def test_drop_hardware_removes_info():
    g, _ = _graph(6)
    g2 = drop_hardware(g)
    assert g2.hw_mask.sum() == 0
    assert g2.a_place.sum() == 0


def test_losses():
    y = jnp.asarray([1.0, 10.0, 100.0])
    raw_perfect = jnp.log1p(y)
    assert float(msle_loss(raw_perfect, y)) < 1e-10
    assert float(msle_loss(raw_perfect + 1.0, y)) > 0.5
    logits = jnp.asarray([10.0, -10.0])
    labels = jnp.asarray([1.0, 0.0])
    assert float(bce_loss(logits, labels)) < 1e-3


def test_ensemble_members_differ():
    g, _ = _graph(7)
    gb = batch_graphs([g])
    gb = jax.tree_util.tree_map(jnp.asarray, gb)
    cfg = CostModelConfig(metric="latency_p", n_ensemble=3, gnn=GNNConfig(hidden=16))
    params = init_cost_model(jax.random.PRNGKey(3), cfg)
    raw = np.asarray(forward_ensemble(params, gb, cfg))
    assert raw.shape == (3, 1)
    assert len(set(np.round(raw[:, 0], 6))) > 1  # different seeds -> different preds


def test_classification_majority_vote():
    g, _ = _graph(8)
    gb = jax.tree_util.tree_map(jnp.asarray, batch_graphs([g, g, g]))
    cfg = CostModelConfig(metric="success", n_ensemble=3, gnn=GNNConfig(hidden=16))
    params = init_cost_model(jax.random.PRNGKey(4), cfg)
    out = ensemble_predict(params, gb, cfg)
    assert set(np.unique(out)).issubset({0, 1})


@settings(max_examples=50, deadline=None)
@given(
    st.floats(1e-3, 1e6, allow_nan=False),
    st.floats(1e-3, 1e6, allow_nan=False),
)
def test_qerror_properties(c, chat):
    q = qerror(np.asarray([c]), np.asarray([chat]))[0]
    assert q >= 1.0 - 1e-12
    # symmetry
    q2 = qerror(np.asarray([chat]), np.asarray([c]))[0]
    assert abs(q - q2) < 1e-9 * max(q, q2)


def test_qerror_perfect():
    s = qerror_summary(np.asarray([3.0, 5.0]), np.asarray([3.0, 5.0]))
    assert abs(s["q50"] - 1.0) < 1e-9


def test_accuracy():
    assert accuracy([1, 0, 1, 1], [1, 0, 0, 1]) == 0.75


def test_training_reduces_loss():
    """Three epochs on a tiny corpus must materially reduce training loss —
    and must actually move the parameters.  The old `last < first` check was
    a coin flip on batch-composition noise: a trainer that never applied its
    optimizer updates still passed it."""
    from repro.training import TrainConfig, dataset_from_traces, split_dataset, train_cost_model

    traces = WorkloadGenerator(seed=11).corpus(200)
    ds = dataset_from_traces(traces, "latency_p")
    tr, va, te = split_dataset(ds)
    cfg = CostModelConfig(metric="latency_p", n_ensemble=2, gnn=GNNConfig(hidden=16))
    init_params = init_cost_model(
        jax.random.split(jax.random.PRNGKey(0))[1], cfg
    )  # train_cost_model's own init for seed 0
    res = train_cost_model(tr, va, cfg, TrainConfig(epochs=3, batch_size=64, verbose=False))
    assert res.history[-1]["train_loss"] < 0.7 * res.history[0]["train_loss"]
    moved = max(
        float(jnp.abs(a - b).max())
        for a, b in zip(
            jax.tree_util.tree_leaves(res.params), jax.tree_util.tree_leaves(init_params)
        )
    )
    assert moved > 1e-4, "training returned the initial parameters unchanged"


# -- unified forward engine (docs/forward_engine.md) ----------------------------


def _bucketed_batch(seed=13, n=24, metric="latency_p"):
    from repro.training import bucket_dataset, dataset_from_traces

    ds = dataset_from_traces(WorkloadGenerator(seed=seed).corpus(n), metric)
    ds, buckets = bucket_dataset(ds)
    b = max(buckets, key=len)
    sub = ds.select(slice(b.start, b.stop))
    g = jax.tree_util.tree_map(jnp.asarray, sub.graphs)
    return g, sub.labels, b.banding


def test_engine_matches_per_graph_forwards():
    """Depth-major banded batch forward == one ``apply_gnn`` per graph with
    the full-depth scan, to float tolerance (same params, same math)."""
    from repro.core import apply_gnn_stacked

    g, _, banding = _bucketed_batch()
    cfg = CostModelConfig(metric="latency_p", n_ensemble=2, gnn=GNNConfig(hidden=16))
    params = init_cost_model(jax.random.PRNGKey(1), cfg)
    got = np.asarray(apply_gnn_stacked(params, g, cfg.gnn, banding))
    B = g.op_x.shape[0]
    for e in range(2):
        member = jax.tree_util.tree_map(lambda x: x[e], params)
        ref = np.stack(
            [
                np.asarray(
                    apply_gnn(member, jax.tree_util.tree_map(lambda x: x[i], g), cfg.gnn)
                )[0]
                for i in range(B)
            ]
        )
        np.testing.assert_allclose(got[e], ref, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("lowering", ["ref", "interpret"])
def test_training_forward_pallas_matches_jnp(lowering, monkeypatch):
    """The batched banded training forward with use_pallas=True must match
    the jnp path under BOTH off-TPU lowerings of the kernel ops (the
    interpret case executes the actual Pallas kernel bodies), for values AND
    gradients (training differentiates through the kernels)."""
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1" if lowering == "interpret" else "0")
    g, y, banding = _bucketed_batch(seed=14)
    cfg_j = CostModelConfig(metric="latency_p", n_ensemble=2, gnn=GNNConfig(hidden=16))
    cfg_p = CostModelConfig(
        metric="latency_p", n_ensemble=2, gnn=GNNConfig(hidden=16, use_pallas=True)
    )
    params = init_cost_model(jax.random.PRNGKey(2), cfg_j)
    out_j = np.asarray(forward_ensemble(params, g, cfg_j, banding))
    out_p = np.asarray(forward_ensemble(params, g, cfg_p, banding))
    np.testing.assert_allclose(out_j, out_p, atol=1e-4, rtol=1e-4)
    yy = jnp.asarray(y)
    g_j = jax.grad(lambda p: ensemble_loss(p, g, yy, cfg_j, banding))(params)
    g_p = jax.grad(lambda p: ensemble_loss(p, g, yy, cfg_p, banding))(params)
    for a, b in zip(jax.tree_util.tree_leaves(g_j), jax.tree_util.tree_leaves(g_p)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-3, rtol=5e-3)


@pytest.mark.parametrize("lowering", ["ref", "interpret"])
def test_trimmed_exact_banding_matches_untrimmed(lowering, monkeypatch):
    """Signature-exact banding with row trimming must equal BOTH the
    conservative untrimmed banding and the plain full-depth forward — values
    AND gradients — under both off-TPU kernel lowerings (training and the
    merged serving path differentiate/route through the kernels)."""
    from repro.core import batch_banding, exact_banding

    from repro.training import dataset_from_traces

    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1" if lowering == "interpret" else "0")
    # a deliberately mixed batch (several query structures) so the trim and
    # the exact spans differ from the conservative plan
    ds = dataset_from_traces(WorkloadGenerator(seed=21).corpus(24), "latency_p")
    cons = batch_banding(ds.graphs)
    exact = exact_banding(ds.graphs)
    assert exact.rows is not None, "mixed corpus must leave padded rows to trim"
    assert len(exact.rows) < MAX_OPS
    g = jax.tree_util.tree_map(jnp.asarray, ds.graphs)
    y = jnp.asarray(ds.labels)
    for pallas in (False, True):
        cfg = CostModelConfig(
            metric="latency_p", n_ensemble=2, gnn=GNNConfig(hidden=16, use_pallas=pallas)
        )
        params = init_cost_model(jax.random.PRNGKey(3), cfg)
        out_plain = np.asarray(forward_ensemble(params, g, cfg))
        out_cons = np.asarray(forward_ensemble(params, g, cfg, cons))
        out_exact = np.asarray(forward_ensemble(params, g, cfg, exact))
        np.testing.assert_allclose(out_cons, out_plain, rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(out_exact, out_plain, rtol=1e-4, atol=1e-5)
        g_cons = jax.grad(lambda p: ensemble_loss(p, g, y, cfg, cons))(params)
        g_exact = jax.grad(lambda p: ensemble_loss(p, g, y, cfg, exact))(params)
        for a, b in zip(
            jax.tree_util.tree_leaves(g_cons), jax.tree_util.tree_leaves(g_exact)
        ):
            np.testing.assert_allclose(
                np.asarray(a), np.asarray(b), rtol=5e-3, atol=5e-4
            )


def test_exact_banding_trims_and_covers():
    """The trim keeps exactly the rows active somewhere in the batch (in the
    depth-clustered order), the type runs tile the trimmed layout, and every
    depth-d row of every graph falls inside that level's span (in trimmed
    coordinates), with its parents under the contraction bound."""
    from repro.core import exact_banding
    from repro.training import dataset_from_traces

    ds = dataset_from_traces(WorkloadGenerator(seed=23).corpus(30), "latency_p")
    banding = exact_banding(ds.graphs)
    mask = np.asarray(ds.graphs.op_mask) > 0
    depth = np.asarray(ds.graphs.op_depth)
    types = np.asarray(ds.graphs.op_type)[0]
    keep = np.flatnonzero(mask.any(axis=0))
    # same row set, depth-clustered order (mean active depth non-decreasing)
    assert sorted(banding.rows) == [int(r) for r in keep]
    means = [depth[:, r][mask[:, r]].mean() for r in banding.rows]
    assert all(a <= b for a, b in zip(means, means[1:]))
    assert banding.ranges[0][1] == 0 and banding.ranges[-1][2] == len(keep)
    for (_, _, stop), (_, start2, _) in zip(banding.ranges, banding.ranges[1:]):
        assert stop == start2  # runs tile the trimmed order
    for t, a, b in banding.ranges:
        assert all(int(types[banding.rows[i]]) == t for i in range(a, b))
    spans = {d: (span, parents) for d, span, parents in banding.levels}
    pos = {int(r): i for i, r in enumerate(banding.rows)}
    for i in range(len(ds)):
        for d in range(1, int((depth[i] * mask[i]).max()) + 1):
            rows = [pos[r] for r in np.flatnonzero((depth[i] == d) & mask[i])]
            if not rows:
                continue
            (s, e), parents = spans[d]
            assert s <= min(rows) and max(rows) < e
            # every shallower active row (superset of real parents) is bounded
            shallower = [pos[r] for r in np.flatnonzero((depth[i] < d) & mask[i])]
            assert all(r < parents for r in shallower)


def test_banded_forward_supports_deep_update_banks():
    """Banding must also serve configs the kernels cannot fuse (>2 update
    layers, jnp path): the generic banded step equals the full scan."""
    g, _, banding = _bucketed_batch(seed=16, n=16)
    cfg = CostModelConfig(
        metric="latency_p", n_ensemble=2, gnn=GNNConfig(hidden=16, update_layers=3)
    )
    params = init_cost_model(jax.random.PRNGKey(4), cfg)
    banded = np.asarray(forward_ensemble(params, g, cfg, banding))
    plain = np.asarray(forward_ensemble(params, g, cfg))
    np.testing.assert_allclose(banded, plain, rtol=1e-5, atol=1e-6)


def test_training_forward_use_pallas_raises_on_unfusable_config():
    """use_pallas on the training path must fail loudly for configs the
    kernels cannot fuse, exactly like the placed path."""
    g, _, banding = _bucketed_batch(seed=15, n=8)
    cfg = CostModelConfig(
        metric="latency_p",
        n_ensemble=2,
        gnn=GNNConfig(hidden=16, update_layers=3, use_pallas=True),
    )
    params = init_cost_model(jax.random.PRNGKey(3), cfg)
    with pytest.raises(NotImplementedError, match="use_pallas"):
        forward_ensemble(params, g, cfg, banding)
