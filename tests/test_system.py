"""End-to-end behaviour: train COSTREAM on a small corpus, verify the learned
model (a) predicts better than untrained, (b) drives placement decisions that
beat the heuristic baseline on simulator-measured latency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    CostModelConfig,
    GNNConfig,
    init_cost_model,
    qerror_summary,
)
from repro.serve.estimator import ensemble_predict
from repro.dsps import WorkloadGenerator, simulate
from repro.placement import PlacementOptimizer, heuristic_placement
from repro.training import TrainConfig, dataset_from_traces, split_dataset, train_cost_model


@pytest.fixture(scope="module")
def trained():
    gen = WorkloadGenerator(seed=77)
    traces = gen.corpus(700)
    models = {}
    tests = {}
    for metric in ("latency_p", "success", "backpressure"):
        ds = dataset_from_traces(traces, metric)
        tr, va, te = split_dataset(ds, seed=1)
        cfg = CostModelConfig(metric=metric, n_ensemble=2, gnn=GNNConfig(hidden=32))
        res = train_cost_model(
            tr, va, cfg, TrainConfig(epochs=10, batch_size=128, verbose=False)
        )
        models[metric] = (res.params, cfg)
        tests[metric] = te
    return models, tests


def test_trained_beats_untrained(trained):
    models, tests = trained
    params, cfg = models["latency_p"]
    te = tests["latency_p"]
    g = jax.tree_util.tree_map(jnp.asarray, te.graphs)
    trained_q = qerror_summary(te.labels, ensemble_predict(params, g, cfg))["q50"]
    untrained = init_cost_model(jax.random.PRNGKey(9), cfg)
    untrained_q = qerror_summary(te.labels, ensemble_predict(untrained, g, cfg))["q50"]
    assert trained_q < untrained_q * 0.5, (trained_q, untrained_q)
    assert trained_q < 5.0  # small corpus, loose bound


def test_costream_placement_beats_heuristic(trained):
    """De-flaked (ROADMAP): with a weakly-trained ensemble, individual queries
    can land within simulator noise of the heuristic, and the old strict
    ``got <= base`` win could tip on float-level prediction changes (e.g. a
    different score-tie argmin after reduction-order changes).  A win now
    tolerates an explicit 2% margin — near-ties are not losses — and the
    aggregate (median latency ratio) must still not regress the heuristic."""
    models, _ = trained
    opt = PlacementOptimizer(models)
    gen = WorkloadGenerator(seed=88)
    rng = np.random.default_rng(0)
    ratios = []
    for i in range(12):
        q = gen.query(kind="linear", name=f"pl{i}")
        c = gen.cluster(6)
        base = heuristic_placement(q, c)
        base_lat = simulate(q, c, base).latency_p
        res = opt.optimize(q, c, "latency_p", k=24, rng=rng)
        got_lat = simulate(q, c, res.placement).latency_p
        ratios.append(got_lat / max(base_lat, 1e-9))
    ratios = np.asarray(ratios)
    wins = int((ratios <= 1.02).sum())
    assert wins / len(ratios) >= 0.6, f"won {wins}/{len(ratios)}: {np.round(ratios, 3)}"
    assert float(np.median(ratios)) <= 1.0, f"median ratio {np.median(ratios):.3f}"
