"""Serving subsystem: bundle round-trips + versioning, the CostEstimator
facade (parity with the pre-redesign paths, cache/forward counters), the
deprecation shims, and PlacementService micro-batching."""

import json
import os
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
import repro.serve.estimator as estimator_mod
from repro.core import CostModelConfig, GNNConfig, init_cost_model
from repro.core.graph import (
    batch_graphs,
    build_a_place_batch,
    build_graph,
    build_graph_skeleton,
    query_static,
    skeleton_cache_key,
)
from repro.dsps import WorkloadGenerator
from repro.dsps.placement import Placement
from repro.placement import PlacementOptimizer, sample_assignment_matrix
from repro.serve import (
    BUNDLE_SCHEMA_VERSION,
    BundleVersionError,
    CostEstimator,
    CostModelBundle,
    PlacementService,
    bundle_from_checkpoint,
    merge_bundles,
)
from repro.serve.estimator import placed_predict
from repro.training import TrainConfig, dataset_from_traces, split_dataset, train_cost_model

GEN = WorkloadGenerator(seed=33)


def _models(hidden=16, n_ensemble=2, metrics=("latency_p", "success", "backpressure")):
    models = {}
    for i, m in enumerate(metrics):
        cfg = CostModelConfig(metric=m, n_ensemble=n_ensemble, gnn=GNNConfig(hidden=hidden))
        models[m] = (init_cost_model(jax.random.PRNGKey(i), cfg), cfg)
    return models


def _graphs(n=9, seed=3):
    gen = WorkloadGenerator(seed=seed)
    traces = gen.corpus(n)
    g = batch_graphs([build_graph(t.query, t.cluster, t.placement) for t in traces])
    return traces, jax.tree_util.tree_map(jnp.asarray, g)


# -- bundle ---------------------------------------------------------------------


def test_bundle_roundtrip_bit_identical(tmp_path):
    """save -> load must reproduce params exactly and predictions bit-identically."""
    models = _models()
    bundle = CostModelBundle(models, meta={"note": "roundtrip"})
    d = str(tmp_path / "bundle")
    bundle.save(d)
    loaded = CostModelBundle.load(d)
    assert loaded.metrics == bundle.metrics
    assert loaded.meta == {"note": "roundtrip"}
    for m in bundle.metrics:
        assert loaded.config(m) == bundle.config(m)
        for a, b in zip(
            jax.tree_util.tree_leaves(bundle.params(m)),
            jax.tree_util.tree_leaves(loaded.params(m)),
        ):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    _, g = _graphs()
    before = CostEstimator(models).estimate(g)
    after = CostEstimator.from_bundle(loaded).estimate(g)
    for m in before:
        np.testing.assert_array_equal(before[m], after[m], err_msg=m)


def _tamper_manifest(directory, mutate):
    step_dir = os.path.join(directory, "step_0000000000")
    p = os.path.join(step_dir, "manifest.json")
    with open(p) as f:
        manifest = json.load(f)
    mutate(manifest["extra"])
    with open(p, "w") as f:
        json.dump(manifest, f)


def test_bundle_refuses_incompatible_versions(tmp_path):
    """A bumped schema version or a different slot layout must refuse loudly,
    never deserialize into silently mis-predicting models."""
    bundle = CostModelBundle(_models(metrics=("latency_p",)))
    d = str(tmp_path / "schema")
    bundle.save(d)
    _tamper_manifest(d, lambda extra: extra.update(schema_version=BUNDLE_SCHEMA_VERSION + 1))
    with pytest.raises(BundleVersionError, match="schema_version"):
        CostModelBundle.load(d)

    d2 = str(tmp_path / "layout")
    bundle.save(d2)

    def bump_layout(extra):
        extra["layout"]["slot_ranges"][0][2] += 1  # pretend 4 source slots

    _tamper_manifest(d2, bump_layout)
    with pytest.raises(BundleVersionError, match="slot layout"):
        CostModelBundle.load(d2)


def test_bundle_from_training_checkpoint(tmp_path):
    """The train_cost_model checkpoint ((params, opt_state, ef)) exports to a
    bundle whose params are exactly the persisted best params."""
    ds = dataset_from_traces(WorkloadGenerator(seed=5).corpus(24), "latency_p")
    tr, va, _ = split_dataset(ds, seed=0)
    cfg = CostModelConfig(metric="latency_p", n_ensemble=1, gnn=GNNConfig(hidden=8))
    ckpt = str(tmp_path / "ckpt")
    res = train_cost_model(tr, va, cfg, TrainConfig(epochs=1, batch_size=16, ckpt_dir=ckpt))
    bundle = bundle_from_checkpoint(ckpt, cfg)
    assert bundle.metrics == ("latency_p",)
    assert bundle.meta["step"] == res.steps
    for a, b in zip(
        jax.tree_util.tree_leaves(res.params),
        jax.tree_util.tree_leaves(bundle.params("latency_p")),
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # wrong config must fail with a shape complaint, not deserialize garbage
    bad = CostModelConfig(metric="latency_p", n_ensemble=2, gnn=GNNConfig(hidden=8))
    with pytest.raises(ValueError, match="shape mismatch"):
        bundle_from_checkpoint(ckpt, bad)


def test_merge_bundles():
    """Disjoint and agreeing meta merge flat; conflicting keys (e.g. each
    export's own checkpoint provenance) are namespaced, never overwritten."""
    a = CostModelBundle(
        _models(metrics=("latency_p",)), meta={"a": 1, "corpus": 100, "step": 7}
    )
    b = CostModelBundle(
        _models(metrics=("success",)), meta={"b": 2, "corpus": 100, "step": 9}
    )
    merged = merge_bundles(a, b)
    assert set(merged.metrics) == {"latency_p", "success"}
    assert merged.meta == {
        "a": 1,
        "b": 2,
        "corpus": 100,
        "latency_p/step": 7,
        "success/step": 9,
    }


# -- estimator ------------------------------------------------------------------


def test_estimator_score_matches_pre_redesign_path():
    """CostEstimator.score on a fixed seed == the per-metric placed forward
    (the pre-facade reference), and estimate == the facade's own score on the
    equivalent broadcast batch."""
    models = _models()
    est = CostEstimator(models)
    q = GEN.query(kind="two_way", name="parity")
    c = GEN.cluster(6)
    a = sample_assignment_matrix(q, c, 13, np.random.default_rng(11))
    got = est.score(q, c, a)
    skel = jax.tree_util.tree_map(jnp.asarray, build_graph_skeleton(q, c))
    static = query_static(q)
    a_place = jnp.asarray(build_a_place_batch(q, c, a))
    for m, (params, cfg) in models.items():
        ref = placed_predict(params, skel, a_place, static, cfg)
        np.testing.assert_allclose(got[m], ref[: len(a)], rtol=1e-5, atol=1e-6, err_msg=m)
    # generic estimate over the broadcast batch agrees with the placed scorer
    g = batch_graphs([build_graph(q, c, Placement.of(r)) for r in a])
    scored = est.estimate(g)
    for m in models:
        np.testing.assert_allclose(got[m], scored[m], rtol=1e-4, atol=1e-4, err_msg=m)


def test_estimator_optimize_matches_optimizer():
    """estimator.optimize is the same search as PlacementOptimizer.optimize
    on a fixed seed: identical placement, predictions, and score vector."""
    models = _models()
    est = CostEstimator(models)
    opt = PlacementOptimizer(_models())  # fresh estimator, same weights
    q = GEN.query(kind="linear", name="optparity")
    c = GEN.cluster(6)
    r1 = est.optimize(q, c, "latency_p", k=16, rng=np.random.default_rng(4), refine_rounds=1)
    r2 = opt.optimize(q, c, "latency_p", k=16, rng=np.random.default_rng(4), refine_rounds=1)
    assert r1.placement.assignment == r2.placement.assignment
    assert r1.predicted == r2.predicted
    assert r1.n_candidates == r2.n_candidates and r1.n_feasible == r2.n_feasible
    np.testing.assert_array_equal(r1.scores, r2.scores)


def test_estimator_estimate_accepts_traces():
    traces, g = _graphs(n=7, seed=9)
    est = CostEstimator(_models(metrics=("latency_p",)))
    np.testing.assert_array_equal(
        est.estimate(traces)["latency_p"], est.estimate(g)["latency_p"]
    )


def test_score_one_skeleton_build_one_stacked_forward(monkeypatch):
    """Counter-asserted serving contract: across repeated score calls on one
    (query, cluster) pair the facade builds the skeleton at most ONCE, and
    each scored batch issues exactly ONE fused stacked forward (traced once),
    never a per-metric loop."""
    calls = {"skel": 0, "fused": 0, "per_metric": 0, "traced": 0}
    orig_skel = estimator_mod.build_graph_skeleton
    orig_fused = estimator_mod.placed_predict_fused
    orig_placed = estimator_mod.placed_predict
    orig_apply = estimator_mod.apply_gnn_placed_stacked

    monkeypatch.setattr(
        estimator_mod,
        "build_graph_skeleton",
        lambda *a, **k: (calls.__setitem__("skel", calls["skel"] + 1), orig_skel(*a, **k))[1],
    )
    monkeypatch.setattr(
        estimator_mod,
        "placed_predict_fused",
        lambda *a, **k: (calls.__setitem__("fused", calls["fused"] + 1), orig_fused(*a, **k))[1],
    )
    monkeypatch.setattr(
        estimator_mod,
        "placed_predict",
        lambda *a, **k: (calls.__setitem__("per_metric", calls["per_metric"] + 1), orig_placed(*a, **k))[1],
    )
    monkeypatch.setattr(
        estimator_mod,
        "apply_gnn_placed_stacked",
        lambda *a, **k: (calls.__setitem__("traced", calls["traced"] + 1), orig_apply(*a, **k))[1],
    )

    # unique hidden size: the jit caches are shared across estimators, so a
    # config no other test uses guarantees the trace happens HERE
    est = CostEstimator(_models(hidden=20))
    q = GEN.query(kind="two_way", name="counters")
    c = GEN.cluster(6)
    rng = np.random.default_rng(2)
    a1 = sample_assignment_matrix(q, c, 9, rng)
    a2 = sample_assignment_matrix(q, c, 9, rng)
    s1 = est.score(q, c, a1)
    s2 = est.score(q, c, a2)
    assert calls["skel"] == 1, "second score on the same pair must hit the LRU"
    assert calls["fused"] == 2, "exactly one fused stacked forward per scored batch"
    assert calls["per_metric"] == 0, "fusable configs must never take the per-metric loop"
    assert calls["traced"] == 1, "the stacked forward must be traced once, then cached"
    assert set(s1) == set(s2) == {"latency_p", "success", "backpressure"}


def _mixed_requests(n=8, cands=5, seed=43):
    """n score requests over n DISTINCT (query, cluster) structures."""
    gen = WorkloadGenerator(seed=seed)
    rng = np.random.default_rng(seed)
    out = []
    kinds = ("linear", "two_way", "three_way")
    for i in range(n):
        q = gen.query(kind=kinds[i % len(kinds)], name=f"mix{i}")
        c = gen.cluster(3 + i % 5)
        out.append((q, c, sample_assignment_matrix(q, c, cands, rng)))
    return out


def test_score_many_matches_serial_score():
    """Cross-query coalescing is invisible: score_many over a mixed stream
    answers each request exactly like a serial per-request score (to float
    tolerance — the merged generic engine and the placement-specialized
    engine are the same math in different sweep orders)."""
    est = CostEstimator(_models())
    requests = _mixed_requests()
    serial = [est.score(q, c, a) for q, c, a in requests]
    merged = est.score_many(requests)
    assert len(merged) == len(requests)
    for want, have in zip(serial, merged):
        for m in want:
            np.testing.assert_allclose(have[m], want[m], rtol=1e-4, atol=1e-5, err_msg=m)
    # chunked (max_rows smaller than the merged stream) stays exact too
    chunked = est.score_many(requests, max_rows=8)
    for want, have in zip(serial, chunked):
        for m in want:
            np.testing.assert_allclose(have[m], want[m], rtol=1e-4, atol=1e-5, err_msg=m)


def test_estimate_many_matches_serial_estimate():
    """Merged estimate batches answer exactly like per-batch estimate."""
    est = CostEstimator(_models(metrics=("latency_p", "success")))
    _, g1 = _graphs(n=6, seed=47)
    _, g2 = _graphs(n=3, seed=53)
    serial = [est.estimate(g1), est.estimate(g2)]
    merged = est.estimate_many([g1, g2])
    for want, have in zip(serial, merged):
        for m in want:
            np.testing.assert_allclose(have[m], want[m], rtol=1e-4, atol=1e-5, err_msg=m)


def test_mixed_drain_is_one_forward_for_eight_structures(monkeypatch):
    """Counter-asserted tentpole contract: 8 score requests over 8 DISTINCT
    query structures, drained together, must issue exactly ONE stacked
    forward (not one per structure), traced once."""
    calls = {"stacked": 0}
    orig = estimator_mod._jitted_merged_forward.__wrapped__

    @estimator_mod.lru_cache(maxsize=128)
    def counting(*a, **k):
        calls["stacked"] += 1
        return orig(*a, **k)

    monkeypatch.setattr(estimator_mod, "_jitted_merged_forward", counting)
    # unique hidden size so the trace cannot come from another test's cache
    est = CostEstimator(_models(hidden=28))
    requests = _mixed_requests()
    assert len({skeleton_cache_key(q, c) for q, c, _ in requests}) == 8
    svc = PlacementService(est, auto_start=False)
    futs = [svc.submit_score(q, c, a) for q, c, a in requests]
    svc.start()
    answers = [f.result(timeout=120) for f in futs]
    svc.close()
    assert all(set(ans) == set(est.models) for ans in answers)
    assert svc.stats.n_batches == 1, "pre-queued requests must drain in one wake-up"
    assert svc.stats.n_forwards == 1, "8 distinct structures must share ONE forward"
    assert svc.stats.n_cross_query == 8
    assert calls["stacked"] == 1, "the merged forward must be traced exactly once"


def test_lazy_bundle_loads_metrics_on_first_use(tmp_path):
    """load() defers each metric's params to first access; an estimator over
    a lazy bundle only ever touches the metrics it serves."""
    from repro.serve import LazyModels

    bundle = CostModelBundle(_models(), meta={"note": "lazy"})
    d = str(tmp_path / "lazy")
    bundle.save(d)
    loaded = CostModelBundle.load(d)
    assert isinstance(loaded.models, LazyModels)
    assert loaded.metrics == bundle.metrics  # manifest-only, nothing loaded
    assert not loaded.models._loaded
    est = CostEstimator.from_bundle(loaded)
    _, g = _graphs(n=4, seed=59)
    est.estimate(g, ["latency_p"])
    assert set(loaded.models._loaded) == {"latency_p"}, "untouched metrics must stay on disk"
    # the loaded params equal the eager load bit-for-bit
    eager = CostModelBundle.load(d, lazy=False)
    for a, b in zip(
        jax.tree_util.tree_leaves(loaded.params("latency_p")),
        jax.tree_util.tree_leaves(eager.params("latency_p")),
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_from_bundle_warns_on_corpus_fingerprint_mismatch():
    """A recorded corpus_fingerprint that disagrees with the caller's is a
    provenance mismatch: warn (once per call), never silently serve; agreeing
    or absent fingerprints stay silent."""
    from repro.serve import corpus_fingerprint

    traces = WorkloadGenerator(seed=61).corpus(6)
    fp = corpus_fingerprint(traces)
    assert fp == corpus_fingerprint(list(traces)), "fingerprint must be deterministic"
    assert fp != corpus_fingerprint(traces[:5])
    models = _models(metrics=("latency_p",))
    bundle = CostModelBundle(models, meta={"corpus_fingerprint": fp})
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        CostEstimator.from_bundle(bundle)  # no expectation: silent
        CostEstimator.from_bundle(bundle, corpus_fingerprint=fp)  # agreeing: silent
        # no recorded fingerprint: nothing to check against
        CostEstimator.from_bundle(CostModelBundle(models), corpus_fingerprint=fp)
    with pytest.warns(UserWarning, match="provenance mismatch"):
        CostEstimator.from_bundle(bundle, corpus_fingerprint=corpus_fingerprint(traces[:5]))


def test_from_bundle_strict_provenance_raises():
    """strict_provenance=True turns the provenance-mismatch warning into a
    typed BundleVersionError — deployment pipelines opt in to refusing a
    model trained on the wrong corpus instead of serving it with a warning."""
    from repro.serve import BundleVersionError, corpus_fingerprint

    traces = WorkloadGenerator(seed=61).corpus(6)
    fp = corpus_fingerprint(traces)
    bundle = CostModelBundle(_models(metrics=("latency_p",)), meta={"corpus_fingerprint": fp})
    with pytest.raises(BundleVersionError, match="provenance mismatch"):
        CostEstimator.from_bundle(
            bundle,
            corpus_fingerprint=corpus_fingerprint(traces[:5]),
            strict_provenance=True,
        )
    with warnings.catch_warnings():
        warnings.simplefilter("error")  # agreeing fingerprints: strict stays silent
        CostEstimator.from_bundle(bundle, corpus_fingerprint=fp, strict_provenance=True)


def test_bundle_load_verify_rejects_corrupt_arrays(tmp_path):
    """load(verify=True) must read every metric's npz params up front and
    wrap corruption in BundleIntegrityError at load time — not at first use
    mid-drain (the lazy default defers exactly that discovery)."""
    from repro.serve import BundleIntegrityError
    from repro.serve.chaos import corrupt_bundle

    bundle = CostModelBundle(_models(metrics=("latency_p",)), meta={"note": "verify"})
    d = str(tmp_path / "verify")
    bundle.save(d)
    CostModelBundle.load(d, verify=True)  # pristine: verification passes
    corrupt_bundle(d, seed=3)
    loaded = CostModelBundle.load(d)  # lazy default: corruption undetected
    assert loaded.metrics == ("latency_p",)
    with pytest.raises(BundleIntegrityError, match="failed verification"):
        CostModelBundle.load(d, verify=True)


# -- 0.7 shim removal ------------------------------------------------------------


def test_predict_shims_removed_in_0_7():
    """The deprecated ``core.model.predict_*`` surface is GONE at 0.7 (the
    removal horizon pinned in docs/api.md): no shim symbols, no deprecation
    machinery, and the numeric core neither imports ``warnings`` nor mentions
    ``DeprecationWarning``.  The facade is the one inference surface."""
    import inspect

    import repro
    from repro.core import model as model_mod

    assert repro.__version__.split(".")[:2] == ["0", "7"]
    for name in (
        "predict",
        "predict_proba",
        "predict_metrics",
        "predict_placements",
        "predict_placements_fused",
        "_DEPRECATION_WARNED",
        "_warn_deprecated",
    ):
        assert not hasattr(model_mod, name), f"core.model.{name} must be removed"
        assert not hasattr(repro.core, name), f"repro.core.{name} must be removed"
    src = inspect.getsource(model_mod)
    assert "DeprecationWarning" not in src
    assert "import warnings" not in src
    # the facade still answers everything the shims used to
    models = _models(metrics=("latency_p", "success"))
    est = CostEstimator(models)
    _, g = _graphs(n=6, seed=13)
    out = est.estimate(g)
    assert set(out) == {"latency_p", "success"}
    # proba is the mean of per-member sigmoids (not 1/mean(1+e^-x))
    from repro.kernels import active_lowering
    from repro.serve.estimator import _jitted_forward

    sparams, scfg = models["success"]
    raw = np.asarray(_jitted_forward(scfg, active_lowering())(sparams, g))
    np.testing.assert_allclose(
        est.proba(g, "success"), (1.0 / (1.0 + np.exp(-raw))).mean(axis=0), rtol=1e-6
    )


# -- service --------------------------------------------------------------------


def _service_inputs(n_requests=5, cands=6, seed=17):
    q = GEN.query(kind="two_way", name=f"svc{seed}")
    c = GEN.cluster(6)
    pool = sample_assignment_matrix(q, c, n_requests * cands, np.random.default_rng(seed))
    idx = np.arange(n_requests * cands) % len(pool)
    return q, c, [pool[idx[i * cands : (i + 1) * cands]] for i in range(n_requests)]


def test_service_coalesces_score_requests():
    """Requests enqueued before the worker starts drain as ONE batch; every
    answer equals the direct facade answer (coalescing is invisible)."""
    est = CostEstimator(_models())
    q, c, requests = _service_inputs()
    ref = [est.score(q, c, r) for r in requests]
    svc = PlacementService(est, auto_start=False)
    futs = [svc.submit_score(q, c, r) for r in requests]
    svc.start()
    got = [f.result(timeout=60) for f in futs]
    svc.close()
    for want, have in zip(ref, got):
        for m in want:
            np.testing.assert_allclose(have[m], want[m], rtol=1e-5, atol=1e-6, err_msg=m)
    assert svc.stats.n_requests == len(requests)
    assert svc.stats.n_batches == 1, "pre-queued requests must drain in one wake-up"
    assert svc.stats.n_forwards == 1, "same (query, cluster, metrics): one fused forward"
    assert svc.stats.n_coalesced == len(requests)


def test_service_groups_incompatible_requests():
    """Score and estimate requests coalesce only within their own kind, and
    all answers stay exact.  Score requests for *different* (query, cluster)
    structures now share ONE merged cross-query forward (the broadcast-batch
    path); estimates coalesce per metrics tuple as before."""
    est = CostEstimator(_models())
    q1, c1, reqs1 = _service_inputs(n_requests=2, seed=19)
    q2, c2, reqs2 = _service_inputs(n_requests=2, seed=23)
    traces, g = _graphs(n=5, seed=29)
    ref_est = est.estimate(g, ["latency_p"])
    svc = PlacementService(est, auto_start=False)
    f_scores = [svc.submit_score(q1, c1, r) for r in reqs1]
    f_scores += [svc.submit_score(q2, c2, r) for r in reqs2]
    f_est = svc.submit_estimate(g, ["latency_p"])
    f_est2 = svc.submit_estimate(g, ["latency_p"])
    svc.start()
    got = [f.result(timeout=60) for f in f_scores]
    got_est = f_est.result(timeout=60)
    got_est2 = f_est2.result(timeout=60)
    svc.close()
    refs = [est.score(q1, c1, r) for r in reqs1] + [est.score(q2, c2, r) for r in reqs2]
    for want, have in zip(refs, got):
        for m in want:
            # merged cross-query answers run the generic signature-banded
            # engine, not the placement-specialized sweep: same math,
            # different reduction order -> float-level tolerance
            np.testing.assert_allclose(have[m], want[m], rtol=1e-4, atol=1e-5, err_msg=m)
    # coalesced estimates run at the merged batch shape: float-level
    # reduction-order differences are allowed, semantic ones are not
    np.testing.assert_allclose(got_est["latency_p"], ref_est["latency_p"], rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(got_est2["latency_p"], ref_est["latency_p"], rtol=1e-5, atol=1e-6)
    # 2 groups: score (q1 + q2 merged cross-query), estimate -- one drain
    assert svc.stats.n_forwards == 2
    assert svc.stats.n_coalesced == 6
    assert svc.stats.n_cross_query == 4  # the four score requests merged


def test_service_cross_query_off_restores_per_structure_drain():
    """cross_query=False pins the pre-merge semantics: one forward per
    (query structure, cluster, metrics) group, identical answers."""
    est = CostEstimator(_models())
    q1, c1, reqs1 = _service_inputs(n_requests=2, seed=19)
    q2, c2, reqs2 = _service_inputs(n_requests=2, seed=23)
    svc = PlacementService(est, auto_start=False, cross_query=False)
    futs = [svc.submit_score(q1, c1, r) for r in reqs1]
    futs += [svc.submit_score(q2, c2, r) for r in reqs2]
    svc.start()
    got = [f.result(timeout=60) for f in futs]
    svc.close()
    refs = [est.score(q1, c1, r) for r in reqs1] + [est.score(q2, c2, r) for r in reqs2]
    for want, have in zip(refs, got):
        for m in want:
            # per-structure groups take the same placement-specialized path
            # as the direct facade call: answers are bit-identical
            np.testing.assert_array_equal(have[m], want[m], err_msg=m)
    assert svc.stats.n_forwards == 2  # one per structure
    assert svc.stats.n_cross_query == 0


def test_service_delivers_exceptions():
    est = CostEstimator(_models(metrics=("latency_p",)))
    q, c, requests = _service_inputs(n_requests=1, seed=31)
    with PlacementService(est) as svc:
        bad = svc.submit_score(q, c, np.zeros((0, requests[0].shape[1]), dtype=np.int64))
        with pytest.raises(ValueError, match="no candidates"):
            bad.result(timeout=60)
        # the worker must survive a failed group and keep serving
        ok = svc.score(q, c, requests[0])
    np.testing.assert_allclose(
        ok["latency_p"], est.score(q, c, requests[0])["latency_p"], rtol=1e-5, atol=1e-6
    )
    # after close(): submissions must fail fast, never hang a future
    with pytest.raises(RuntimeError, match="closed"):
        svc.submit_score(q, c, requests[0])
    # close() before start() must fail queued futures, not strand them
    svc2 = PlacementService(est, auto_start=False)
    orphan = svc2.submit_score(q, c, requests[0])
    svc2.close()
    with pytest.raises(RuntimeError, match="closed before start"):
        orphan.result(timeout=60)


def test_bad_request_never_fails_its_batchmates():
    """Metrics-tuple groups span unrelated callers: an empty (invalid) score
    request drained together with valid ones — same or different structures —
    must fail alone while every batchmate gets its exact answer."""
    est = CostEstimator(_models(metrics=("latency_p",)))
    good = _mixed_requests(n=3, cands=4, seed=67)
    q0, c0, a0 = good[0]
    svc = PlacementService(est, auto_start=False)
    futs = [svc.submit_score(q, c, a) for q, c, a in good]
    bad = svc.submit_score(q0, c0, np.zeros((0, a0.shape[1]), dtype=np.int64))
    svc.start()
    with pytest.raises(ValueError, match="no candidates"):
        bad.result(timeout=60)
    got = [f.result(timeout=60) for f in futs]
    svc.close()
    for (q, c, a), have in zip(good, got):
        want = est.score(q, c, a)
        np.testing.assert_allclose(
            have["latency_p"], want["latency_p"], rtol=1e-4, atol=1e-5
        )
    assert svc.stats.n_cross_query == 3  # the valid requests still merged


def test_service_chunks_oversized_groups():
    """A coalesced group larger than max_batch is scored in chunks but still
    answered per request, exactly."""
    est = CostEstimator(_models(metrics=("latency_p",)))
    q, c, requests = _service_inputs(n_requests=6, cands=4, seed=37)
    ref = [est.score(q, c, r) for r in requests]
    svc = PlacementService(est, max_batch=8, auto_start=False)
    futs = [svc.submit_score(q, c, r) for r in requests]
    svc.start()
    got = [f.result(timeout=60) for f in futs]
    svc.close()
    for want, have in zip(ref, got):
        np.testing.assert_allclose(have["latency_p"], want["latency_p"], rtol=1e-5, atol=1e-6)
    assert svc.stats.n_forwards == 3  # 24 rows / max_batch 8

    # the estimate path chunks by max_batch too, splitting WITHIN a request
    _, g = _graphs(n=5, seed=41)
    ref_g = est.estimate(g, ["latency_p"])["latency_p"]
    svc = PlacementService(est, max_batch=4, auto_start=False)
    futs = [svc.submit_estimate(g, ["latency_p"]) for _ in range(2)]
    svc.start()
    answers = [f.result(timeout=60) for f in futs]
    svc.close()
    for have in answers:
        np.testing.assert_allclose(have["latency_p"], ref_g, rtol=1e-4, atol=1e-5)
    assert svc.stats.n_forwards == 3  # 10 graphs / max_batch 4


# -- package surface ------------------------------------------------------------


def test_top_level_package_surface():
    assert repro.__version__
    for name in repro.__all__:
        assert getattr(repro, name, None) is not None, name
    assert repro.CostEstimator is CostEstimator
    assert repro.CostModelBundle is CostModelBundle
