"""Placement enumeration rules (Fig. 5), optimizer (Fig. 4), baselines."""

import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import CostModelConfig, GNNConfig, init_cost_model
from repro.dsps import WorkloadGenerator, simulate
from repro.dsps.placement import is_acyclic_placement, respects_increasing_capability
from repro.placement import (
    PlacementOptimizer,
    enumerate_candidates,
    heuristic_placement,
    online_monitoring_run,
    valid_candidate,
)

GEN = WorkloadGenerator(seed=21)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 5000))
def test_enumeration_respects_rules(seed):
    gen = WorkloadGenerator(seed=seed)
    q = gen.query(name="e")
    c = gen.cluster(6)
    rng = np.random.default_rng(seed)
    for p in enumerate_candidates(q, c, 8, rng):
        assert respects_increasing_capability(q, c, p)
        assert is_acyclic_placement(q, p)
        p.validate(q, c)


def test_heuristic_placement_valid():
    for i in range(10):
        q = GEN.query(name=f"h{i}")
        c = GEN.cluster(6)
        p = heuristic_placement(q, c)
        p.validate(q, c)
        assert valid_candidate(q, c, p)


def _tiny_models():
    models = {}
    for m in ("latency_p", "success", "backpressure"):
        cfg = CostModelConfig(metric=m, n_ensemble=2, gnn=GNNConfig(hidden=16))
        models[m] = (init_cost_model(jax.random.PRNGKey(0), cfg), cfg)
    return models


def test_optimizer_returns_valid_candidate():
    opt = PlacementOptimizer(_tiny_models())
    q = GEN.query(kind="two_way", name="opt")
    c = GEN.cluster(6)
    res = opt.optimize(q, c, "latency_p", k=12, rng=np.random.default_rng(1))
    res.placement.validate(q, c)
    assert valid_candidate(q, c, res.placement)
    assert res.n_candidates > 0
    assert len(res.scores) == res.n_candidates


def test_optimizer_feasibility_filter():
    opt = PlacementOptimizer(_tiny_models())
    q = GEN.query(name="feas")
    c = GEN.cluster(5)
    res = opt.optimize(q, c, "latency_p", k=8, rng=np.random.default_rng(2))
    assert 0 < res.n_feasible <= res.n_candidates


def test_monitoring_baseline_improves_or_stops():
    q = GEN.query(kind="linear", name="mon")
    c = GEN.cluster(6)
    init = heuristic_placement(q, c)
    target = simulate(q, c, init).latency_p * 0.5  # ambitious target
    res = online_monitoring_run(q, c, init, target_latency=target, max_rounds=6)
    assert res.final_latency <= res.initial_latency * 1.5
    assert len(res.steps) >= 1
    assert res.migrations >= 0
