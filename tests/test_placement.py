"""Placement enumeration rules (Fig. 5), optimizer (Fig. 4), baselines."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _propcheck import given, settings, strategies as st

from repro.core import CostModelConfig, GNNConfig, init_cost_model
from repro.core.graph import batch_graphs, build_graph
from repro.core.model import predict
from repro.dsps import WorkloadGenerator, simulate
from repro.dsps.placement import (
    Placement,
    is_acyclic_placement,
    respects_increasing_capability,
)
from repro.dsps.simulator import SimulatorConfig
from repro.placement import (
    PlacementOptimizer,
    batch_validity_mask,
    enumerate_candidates,
    heuristic_placement,
    mutate_assignments,
    online_monitoring_run,
    sample_assignment_matrix,
    sample_assignments,
    valid_candidate,
)

GEN = WorkloadGenerator(seed=21)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 5000))
def test_enumeration_respects_rules(seed):
    gen = WorkloadGenerator(seed=seed)
    q = gen.query(name="e")
    c = gen.cluster(6)
    rng = np.random.default_rng(seed)
    for p in enumerate_candidates(q, c, 8, rng):
        assert respects_increasing_capability(q, c, p)
        assert is_acyclic_placement(q, p)
        p.validate(q, c)


def test_heuristic_placement_valid():
    for i in range(10):
        q = GEN.query(name=f"h{i}")
        c = GEN.cluster(6)
        p = heuristic_placement(q, c)
        p.validate(q, c)
        assert valid_candidate(q, c, p)


def _tiny_models():
    models = {}
    for m in ("latency_p", "success", "backpressure"):
        cfg = CostModelConfig(metric=m, n_ensemble=2, gnn=GNNConfig(hidden=16))
        models[m] = (init_cost_model(jax.random.PRNGKey(0), cfg), cfg)
    return models


def test_optimizer_returns_valid_candidate():
    opt = PlacementOptimizer(_tiny_models())
    q = GEN.query(kind="two_way", name="opt")
    c = GEN.cluster(6)
    res = opt.optimize(q, c, "latency_p", k=12, rng=np.random.default_rng(1))
    res.placement.validate(q, c)
    assert valid_candidate(q, c, res.placement)
    assert res.n_candidates > 0
    assert len(res.scores) == res.n_candidates


def test_optimizer_feasibility_filter():
    opt = PlacementOptimizer(_tiny_models())
    q = GEN.query(name="feas")
    c = GEN.cluster(5)
    res = opt.optimize(q, c, "latency_p", k=8, rng=np.random.default_rng(2))
    assert 0 < res.n_feasible <= res.n_candidates


def test_monitoring_baseline_improves_or_stops():
    q = GEN.query(kind="linear", name="mon")
    c = GEN.cluster(6)
    init = heuristic_placement(q, c)
    target = simulate(q, c, init).latency_p * 0.5  # ambitious target
    res = online_monitoring_run(q, c, init, target_latency=target, max_rounds=6)
    assert res.final_latency <= res.initial_latency * 1.5
    assert len(res.steps) >= 1
    assert res.migrations >= 0


# -- vectorized search path (docs/placement_search.md) -------------------------


@settings(max_examples=12, deadline=None)
@given(st.integers(0, 5000))
def test_batch_validity_mask_matches_scalar_rules(seed):
    """The vectorized rule check is exactly the scalar Fig.-5 predicates."""
    gen = WorkloadGenerator(seed=seed)
    q = gen.query(name="vm")
    c = gen.cluster(3 + seed % 6)
    rng = np.random.default_rng(seed)
    a = sample_assignments(q, c, 128, rng)
    mask = batch_validity_mask(q, c, a)
    ref = np.asarray([valid_candidate(q, c, Placement.of(row)) for row in a])
    np.testing.assert_array_equal(mask, ref)


def test_sampler_produces_only_valid_distinct_candidates():
    for seed in range(6):
        gen = WorkloadGenerator(seed=seed)
        q = gen.query(name="sv")
        c = gen.cluster(6)
        a = sample_assignment_matrix(q, c, 32, np.random.default_rng(seed))
        assert 0 < len(a) <= 32
        assert len(np.unique(a, axis=0)) == len(a)
        for row in a:
            assert valid_candidate(q, c, Placement.of(row))


def test_mutations_stay_valid_and_distinct():
    q = GEN.query(kind="two_way", name="mut")
    c = GEN.cluster(6)
    rng = np.random.default_rng(5)
    parents = sample_assignment_matrix(q, c, 8, rng)
    children = mutate_assignments(q, c, parents, 6, rng)
    assert len(children) > 0
    assert len(np.unique(children, axis=0)) == len(children)
    for row in children:
        assert valid_candidate(q, c, Placement.of(row))


def test_batched_scorer_matches_per_candidate_predict():
    """score_assignments (build once, all metrics) == per-candidate predict."""
    opt = PlacementOptimizer(_tiny_models())
    q = GEN.query(kind="linear", name="par")
    c = GEN.cluster(6)
    a = sample_assignment_matrix(q, c, 11, np.random.default_rng(7))
    fast = opt.score_assignments(q, c, a, ["latency_p", "success", "backpressure"])
    for metric in fast:
        params, cfg = opt.models[metric]
        singles = batch_graphs([build_graph(q, c, Placement.of(row)) for row in a])
        ref = predict(params, jax.tree_util.tree_map(jnp.asarray, singles), cfg)
        np.testing.assert_allclose(fast[metric], ref, rtol=1e-5, atol=1e-6, err_msg=metric)


def test_padding_bucket_invariance():
    """Scores are identical whether the batch is bucket-padded or not, and do
    not depend on which other candidates share the batch."""
    opt = PlacementOptimizer(_tiny_models())
    q = GEN.query(name="pad")
    c = GEN.cluster(6)
    a = sample_assignment_matrix(q, c, 11, np.random.default_rng(9))
    n = len(a)
    together = opt.score_assignments(q, c, a, ["latency_p"])["latency_p"]
    head = opt.score_assignments(q, c, a[: n // 2], ["latency_p"])["latency_p"]
    np.testing.assert_allclose(together[: n // 2], head, rtol=1e-5, atol=1e-6)
    # power-of-two count: pad_batch is the identity, same scores still
    four = opt.score_assignments(q, c, a[:4], ["latency_p"])["latency_p"]
    np.testing.assert_allclose(together[:4], four, rtol=1e-5, atol=1e-6)


class _OracleOptimizer(PlacementOptimizer):
    """Scores candidates with the simulator itself (no learned model), which
    isolates the search machinery — sampling, batching, refinement — from
    cost-model accuracy."""

    def __init__(self, sim):
        super().__init__(models={})
        self.sim = sim

    def score_assignments(self, query, cluster, assignments, metrics):
        lat = np.asarray(
            [
                simulate(query, cluster, Placement.of(row), self.sim).latency_p
                for row in np.asarray(assignments)
            ]
        )
        return {m: lat for m in metrics}


def test_refined_search_beats_heuristic_end_to_end():
    """With an oracle scorer, the refined search must find a placement at
    least as good (simulator-measured) as the deterministic heuristic, and
    refinement must never do worse than the unrefined sample."""
    sim = SimulatorConfig(noise_sigma=0.0)
    opt = _OracleOptimizer(sim)
    gen = WorkloadGenerator(seed=31)
    for i in range(4):
        q = gen.query(name=f"e2e{i}")
        c = gen.cluster(6)
        base_lat = simulate(q, c, heuristic_placement(q, c), sim).latency_p
        plain = opt.optimize(q, c, "latency_p", k=16, rng=np.random.default_rng(i), refine_rounds=0)
        refined = opt.optimize(q, c, "latency_p", k=16, rng=np.random.default_rng(i), refine_rounds=3)
        plain_lat = simulate(q, c, plain.placement, sim).latency_p
        refined_lat = simulate(q, c, refined.placement, sim).latency_p
        assert refined.n_candidates >= plain.n_candidates
        assert refined_lat <= plain_lat + 1e-9
        assert refined_lat <= base_lat + 1e-9
