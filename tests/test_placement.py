"""Placement enumeration rules (Fig. 5), optimizer (Fig. 4), baselines."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _propcheck import given, settings, strategies as st

from repro.core import CostModelConfig, GNNConfig, init_cost_model
from repro.core.graph import (
    batch_graphs,
    build_a_place_batch,
    build_graph,
    build_graph_skeleton,
    query_static,
    skeleton_cache_key,
)
from repro.serve.estimator import (
    CostEstimator,
    ensemble_predict,
    placed_predict,
    placed_predict_fused,
)
from repro.serve.stacking import stack_metric_models
from repro.dsps import WorkloadGenerator, simulate
from repro.dsps.placement import (
    Placement,
    is_acyclic_placement,
    respects_increasing_capability,
)
from repro.dsps.simulator import SimulatorConfig
from repro.placement import (
    PlacementOptimizer,
    batch_validity_mask,
    heuristic_placement,
    mutate_assignments,
    online_monitoring_run,
    sample_assignment_matrix,
    sample_assignments,
    valid_candidate,
)

GEN = WorkloadGenerator(seed=21)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 5000))
def test_enumeration_respects_rules(seed):
    gen = WorkloadGenerator(seed=seed)
    q = gen.query(name="e")
    c = gen.cluster(6)
    rng = np.random.default_rng(seed)
    for row in sample_assignment_matrix(q, c, 8, rng):
        p = Placement.of(row)
        assert respects_increasing_capability(q, c, p)
        assert is_acyclic_placement(q, p)
        p.validate(q, c)


def test_heuristic_placement_valid():
    for i in range(10):
        q = GEN.query(name=f"h{i}")
        c = GEN.cluster(6)
        p = heuristic_placement(q, c)
        p.validate(q, c)
        assert valid_candidate(q, c, p)


def _tiny_models():
    models = {}
    for m in ("latency_p", "success", "backpressure"):
        cfg = CostModelConfig(metric=m, n_ensemble=2, gnn=GNNConfig(hidden=16))
        models[m] = (init_cost_model(jax.random.PRNGKey(0), cfg), cfg)
    return models


def test_optimizer_returns_valid_candidate():
    opt = PlacementOptimizer(_tiny_models())
    q = GEN.query(kind="two_way", name="opt")
    c = GEN.cluster(6)
    res = opt.optimize(q, c, "latency_p", k=12, rng=np.random.default_rng(1))
    res.placement.validate(q, c)
    assert valid_candidate(q, c, res.placement)
    assert res.n_candidates > 0
    assert len(res.scores) == res.n_candidates


def test_optimizer_feasibility_filter():
    opt = PlacementOptimizer(_tiny_models())
    q = GEN.query(name="feas")
    c = GEN.cluster(5)
    res = opt.optimize(q, c, "latency_p", k=8, rng=np.random.default_rng(2))
    assert 0 < res.n_feasible <= res.n_candidates


def test_monitoring_baseline_improves_or_stops():
    q = GEN.query(kind="linear", name="mon")
    c = GEN.cluster(6)
    init = heuristic_placement(q, c)
    target = simulate(q, c, init).latency_p * 0.5  # ambitious target
    res = online_monitoring_run(q, c, init, target_latency=target, max_rounds=6)
    assert res.final_latency <= res.initial_latency * 1.5
    assert len(res.steps) >= 1
    assert res.migrations >= 0


# -- vectorized search path (docs/placement_search.md) -------------------------


@settings(max_examples=12, deadline=None)
@given(st.integers(0, 5000))
def test_batch_validity_mask_matches_scalar_rules(seed):
    """The vectorized rule check is exactly the scalar Fig.-5 predicates."""
    gen = WorkloadGenerator(seed=seed)
    q = gen.query(name="vm")
    c = gen.cluster(3 + seed % 6)
    rng = np.random.default_rng(seed)
    a = sample_assignments(q, c, 128, rng)
    mask = batch_validity_mask(q, c, a)
    ref = np.asarray([valid_candidate(q, c, Placement.of(row)) for row in a])
    np.testing.assert_array_equal(mask, ref)


def test_sampler_produces_only_valid_distinct_candidates():
    for seed in range(6):
        gen = WorkloadGenerator(seed=seed)
        q = gen.query(name="sv")
        c = gen.cluster(6)
        a = sample_assignment_matrix(q, c, 32, np.random.default_rng(seed))
        assert 0 < len(a) <= 32
        assert len(np.unique(a, axis=0)) == len(a)
        for row in a:
            assert valid_candidate(q, c, Placement.of(row))


def test_mutations_stay_valid_and_distinct():
    q = GEN.query(kind="two_way", name="mut")
    c = GEN.cluster(6)
    rng = np.random.default_rng(5)
    parents = sample_assignment_matrix(q, c, 8, rng)
    children = mutate_assignments(q, c, parents, 6, rng)
    assert len(children) > 0
    assert len(np.unique(children, axis=0)) == len(children)
    for row in children:
        assert valid_candidate(q, c, Placement.of(row))


def test_batched_scorer_matches_per_candidate_predict():
    """score_assignments (build once, all metrics) == per-candidate predict."""
    opt = PlacementOptimizer(_tiny_models())
    q = GEN.query(kind="linear", name="par")
    c = GEN.cluster(6)
    a = sample_assignment_matrix(q, c, 11, np.random.default_rng(7))
    fast = opt.score_assignments(q, c, a, ["latency_p", "success", "backpressure"])
    for metric in fast:
        params, cfg = opt.models[metric]
        singles = batch_graphs([build_graph(q, c, Placement.of(row)) for row in a])
        ref = ensemble_predict(params, jax.tree_util.tree_map(jnp.asarray, singles), cfg)
        np.testing.assert_allclose(fast[metric], ref, rtol=1e-5, atol=1e-6, err_msg=metric)


def test_padding_bucket_invariance():
    """Scores are identical whether the batch is bucket-padded or not, and do
    not depend on which other candidates share the batch."""
    opt = PlacementOptimizer(_tiny_models())
    q = GEN.query(name="pad")
    c = GEN.cluster(6)
    a = sample_assignment_matrix(q, c, 11, np.random.default_rng(9))
    n = len(a)
    together = opt.score_assignments(q, c, a, ["latency_p"])["latency_p"]
    head = opt.score_assignments(q, c, a[: n // 2], ["latency_p"])["latency_p"]
    np.testing.assert_allclose(together[: n // 2], head, rtol=1e-5, atol=1e-6)
    # power-of-two count: pad_batch is the identity, same scores still
    four = opt.score_assignments(q, c, a[:4], ["latency_p"])["latency_p"]
    np.testing.assert_allclose(together[:4], four, rtol=1e-5, atol=1e-6)


# -- kernel routing + fused ensembles + skeleton cache -------------------------


def _placed_inputs(seed=7, n=11, kind="two_way"):
    q = GEN.query(kind=kind, name=f"pk{seed}")
    c = GEN.cluster(6)
    a = sample_assignment_matrix(q, c, n, np.random.default_rng(seed))
    skel = jax.tree_util.tree_map(jnp.asarray, build_graph_skeleton(q, c))
    static = query_static(q)
    a_place = jnp.asarray(build_a_place_batch(q, c, a))
    return q, c, a, skel, static, a_place


@pytest.mark.parametrize("lowering", ["ref", "interpret"])
def test_placed_path_pallas_matches_jnp(lowering, monkeypatch):
    """apply_gnn_placed with use_pallas=True must be numerically equivalent to
    the jnp banked-MLP path under BOTH off-TPU lowerings of the kernel ops:
    the compiled jnp-oracle lowering (default) and the forced Pallas
    interpreter, which executes the actual kernel bodies."""
    from repro.core.gnn import apply_gnn_placed, init_gnn

    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1" if lowering == "interpret" else "0")
    _, _, _, skel, static, a_place = _placed_inputs()
    cfg_j = GNNConfig(hidden=16)
    cfg_p = GNNConfig(hidden=16, use_pallas=True)
    params = init_gnn(jax.random.PRNGKey(3), cfg_j)
    out_j = apply_gnn_placed(params, skel, a_place, static, cfg_j)
    out_p = apply_gnn_placed(params, skel, a_place, static, cfg_p)
    np.testing.assert_allclose(np.asarray(out_j), np.asarray(out_p), atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("lowering", ["ref", "interpret"])
def test_stacked_path_pallas_matches_jnp(lowering, monkeypatch):
    """The stacked trimmed forward under use_pallas — including the banded
    per-level row_span mp_update calls — matches its jnp twin under both
    off-TPU lowerings (the interpret case executes the kernel bodies)."""
    from repro.core.gnn import apply_gnn_placed_stacked

    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1" if lowering == "interpret" else "0")
    _, _, _, skel, static, a_place = _placed_inputs(seed=12)
    models = _tiny_models()
    stacked = stack_metric_models(models)
    n_hw = int(np.asarray(skel.hw_mask).sum())
    gnn_j = models["latency_p"][1].gnn
    gnn_p = GNNConfig(hidden=gnn_j.hidden, use_pallas=True)
    out_j = apply_gnn_placed_stacked(stacked.params, skel, a_place, static, gnn_j, n_hw)
    out_p = apply_gnn_placed_stacked(stacked.params, skel, a_place, static, gnn_p, n_hw)
    np.testing.assert_allclose(np.asarray(out_j), np.asarray(out_p), atol=1e-4, rtol=1e-4)


def test_placed_predict_pallas_parity():
    """The full placed-predict path (jit + ensemble vmap + vote) agrees
    between the Pallas-routed and jnp scorers on every metric type."""
    _, _, _, skel, static, a_place = _placed_inputs(seed=8)
    for metric in ("latency_p", "success"):
        cfg_j = CostModelConfig(metric=metric, n_ensemble=2, gnn=GNNConfig(hidden=16))
        cfg_p = CostModelConfig(
            metric=metric, n_ensemble=2, gnn=GNNConfig(hidden=16, use_pallas=True)
        )
        params = init_cost_model(jax.random.PRNGKey(0), cfg_j)
        ref = placed_predict(params, skel, a_place, static, cfg_j)
        got = placed_predict(params, skel, a_place, static, cfg_p)
        if metric == "success":  # classification: votes must match exactly
            np.testing.assert_array_equal(got, ref, err_msg=metric)
        else:
            np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-4, err_msg=metric)


def test_stacked_ensembles_match_per_metric_loop():
    """One fused stacked forward == the per-(metric, member) loop, to float
    tolerance, for both the placed path and the generic estimate path."""
    q, c, a, skel, static, a_place = _placed_inputs(seed=9)
    models = _tiny_models()
    stacked = stack_metric_models(models)
    assert stacked.sizes == (2, 2, 2)
    fused = placed_predict_fused(stacked, skel, a_place, static)
    for metric, (params, cfg) in models.items():
        ref = placed_predict(params, skel, a_place, static, cfg)
        np.testing.assert_allclose(fused[metric], ref, rtol=1e-5, atol=1e-6, err_msg=metric)
    # generic path: estimate (fused internally) vs per-metric ensemble_predict
    g = jax.tree_util.tree_map(
        jnp.asarray, batch_graphs([build_graph(q, c, Placement.of(r)) for r in a])
    )
    scored = CostEstimator(models).estimate(g)
    for metric, (params, cfg) in models.items():
        np.testing.assert_allclose(
            scored[metric], ensemble_predict(params, g, cfg), rtol=1e-5, atol=1e-6, err_msg=metric
        )


def test_stack_metric_models_rejects_mixed_configs():
    models = _tiny_models()
    cfg = CostModelConfig(metric="latency_e", n_ensemble=2, gnn=GNNConfig(hidden=8))
    models["latency_e"] = (init_cost_model(jax.random.PRNGKey(5), cfg), cfg)
    with pytest.raises(ValueError):
        stack_metric_models(models)
    # the optimizer must still score correctly through the per-metric fallback
    opt = PlacementOptimizer(models)
    q = GEN.query(name="mix")
    c = GEN.cluster(6)
    a = sample_assignment_matrix(q, c, 6, np.random.default_rng(3))
    got = opt.score_assignments(q, c, a, ["latency_p", "latency_e"])
    for metric in ("latency_p", "latency_e"):
        params, cfg = opt.models[metric]
        skel = jax.tree_util.tree_map(jnp.asarray, build_graph_skeleton(q, c))
        ref = placed_predict(
            params, skel, jnp.asarray(build_a_place_batch(q, c, a)), query_static(q), cfg
        )[: len(a)]
        np.testing.assert_allclose(got[metric], ref, rtol=1e-5, atol=1e-6, err_msg=metric)


def test_use_pallas_raises_loudly_on_unfusable_config():
    """use_pallas must never silently fall back to jnp: a config the kernels
    cannot fuse (!= 2 layers) raises instead."""
    from repro.core.gnn import apply_gnn_placed, init_gnn

    _, _, _, skel, static, a_place = _placed_inputs(seed=10)
    cfg = GNNConfig(hidden=16, update_layers=3, use_pallas=True)
    params = init_gnn(jax.random.PRNGKey(0), cfg)
    with pytest.raises(NotImplementedError, match="use_pallas"):
        apply_gnn_placed(params, skel, a_place, static, cfg)


def test_skeleton_cached_across_optimize_calls(monkeypatch):
    """The second optimize() on the same (query, cluster) must perform ZERO
    build_graph_skeleton rebuilds (the online-monitoring amortization).

    The skeleton LRU lives on the CostEstimator facade since the serving
    redesign, so the counter patches repro.serve.estimator."""
    import repro.serve.estimator as estimator_mod

    calls = {"n": 0}
    orig = estimator_mod.build_graph_skeleton

    def counted(*args, **kw):
        calls["n"] += 1
        return orig(*args, **kw)

    monkeypatch.setattr(estimator_mod, "build_graph_skeleton", counted)
    opt = PlacementOptimizer(_tiny_models())
    q = GEN.query(kind="linear", name="cache")
    c = GEN.cluster(6)
    opt.optimize(q, c, "latency_p", k=8, rng=np.random.default_rng(0))
    first = calls["n"]
    assert first == 1
    r1 = opt.optimize(q, c, "latency_p", k=8, rng=np.random.default_rng(1))
    assert calls["n"] == first  # cache hit: zero rebuilds
    # a *different* query must miss the cache, not reuse a stale skeleton
    q2 = GEN.query(kind="two_way", name="cache2")
    assert skeleton_cache_key(q2, c) != skeleton_cache_key(q, c)
    opt.optimize(q2, c, "latency_p", k=8, rng=np.random.default_rng(2))
    assert calls["n"] == first + 1
    r1.placement.validate(q, c)


def test_skeleton_cache_key_structural():
    """Equal-structure (query, cluster) pairs share a key even when they are
    distinct objects; differing clusters do not."""
    gen_a = WorkloadGenerator(seed=55)
    gen_b = WorkloadGenerator(seed=55)
    qa, qb = gen_a.query(name="a"), gen_b.query(name="b")
    ca, cb = gen_a.cluster(5), gen_b.cluster(5)
    assert qa is not qb and ca is not cb
    assert skeleton_cache_key(qa, ca) == skeleton_cache_key(qb, cb)
    assert skeleton_cache_key(qa, ca) != skeleton_cache_key(qa, gen_a.cluster(5))


class _OracleOptimizer(PlacementOptimizer):
    """Scores candidates with the simulator itself (no learned model), which
    isolates the search machinery — sampling, batching, refinement — from
    cost-model accuracy."""

    def __init__(self, sim):
        super().__init__(models={})
        self.sim = sim

    def score_assignments(self, query, cluster, assignments, metrics):
        lat = np.asarray(
            [
                simulate(query, cluster, Placement.of(row), self.sim).latency_p
                for row in np.asarray(assignments)
            ]
        )
        return {m: lat for m in metrics}


def test_refined_search_beats_heuristic_end_to_end():
    """With an oracle scorer, the refined search must find a placement at
    least as good (simulator-measured) as the deterministic heuristic, and
    refinement must never do worse than the unrefined sample."""
    sim = SimulatorConfig(noise_sigma=0.0)
    opt = _OracleOptimizer(sim)
    gen = WorkloadGenerator(seed=31)
    for i in range(4):
        q = gen.query(name=f"e2e{i}")
        c = gen.cluster(6)
        base_lat = simulate(q, c, heuristic_placement(q, c), sim).latency_p
        plain = opt.optimize(q, c, "latency_p", k=16, rng=np.random.default_rng(i), refine_rounds=0)
        refined = opt.optimize(q, c, "latency_p", k=16, rng=np.random.default_rng(i), refine_rounds=3)
        plain_lat = simulate(q, c, plain.placement, sim).latency_p
        refined_lat = simulate(q, c, refined.placement, sim).latency_p
        assert refined.n_candidates >= plain.n_candidates
        assert refined_lat <= plain_lat + 1e-9
        assert refined_lat <= base_lat + 1e-9
