"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, shape + finiteness assertions (assignment requirement (f))."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, reduced
from repro.models.params import abstract, count_params, materialize
from repro.models.steps import TrainStepConfig, lm_loss, make_serve_step, make_train_step
from repro.models.transformer import model_cache_defs, model_defs, forward


def _batch(cfg, B=2, S=16):
    batch = {"tokens": jnp.ones((B, S), jnp.int32)}
    if cfg.frontend == "vision":
        batch = {
            "tokens": jnp.ones((B, S - cfg.vis_len), jnp.int32),
            "vis_embeds": jnp.zeros((B, cfg.vis_len, cfg.d_model), jnp.float32),
        }
    if cfg.frontend == "audio":
        batch["frames"] = jnp.zeros((B, S, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_train_step(arch):
    cfg = reduced(get_config(arch))
    params = materialize(jax.random.PRNGKey(0), model_defs(cfg), dtype_override=jnp.float32)
    batch = _batch(cfg)

    logits, _ = forward(
        params, cfg, batch["tokens"],
        vis_embeds=batch.get("vis_embeds"), frames=batch.get("frames"),
    )
    S_total = batch["tokens"].shape[1] + (cfg.vis_len if cfg.frontend == "vision" else 0)
    assert logits.shape == (2, S_total, cfg.vocab)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"

    train_step, opt = make_train_step(cfg, TrainStepConfig(lr=1e-3))
    state = {"params": params, "opt": opt.init(params), "step": jnp.zeros((), jnp.int32)}
    state, metrics = train_step(state, batch)
    assert bool(jnp.isfinite(metrics["loss"])), f"{arch}: non-finite loss"
    assert bool(jnp.isfinite(metrics["grad_norm"])), f"{arch}: non-finite grads"
    assert int(state["step"]) == 1


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_two_steps(arch):
    cfg = reduced(get_config(arch))
    params = materialize(jax.random.PRNGKey(1), model_defs(cfg), dtype_override=jnp.float32)
    B, S = 2, 32
    cache = materialize(jax.random.PRNGKey(2), model_cache_defs(cfg, B, S))
    cache = jax.tree_util.tree_map(
        lambda x: x.astype(jnp.float32) if x.dtype == jnp.bfloat16 else x, cache
    )
    serve = make_serve_step(cfg)
    toks = jnp.ones((B, 1), jnp.int32)
    logits, cache, nxt = serve(params, cache, toks, jnp.asarray(3, jnp.int32))
    logits2, cache, nxt2 = serve(params, cache, nxt, jnp.asarray(4, jnp.int32))
    for l in (logits, logits2):
        assert l.shape == (B, 1, cfg.vocab)
        assert bool(jnp.isfinite(l).all()), f"{arch}: non-finite decode logits"
    assert nxt.dtype == jnp.int32 and nxt.shape == (B, 1)


def test_param_counts_match_names():
    """Full configs land near their nominal sizes."""
    expected = {
        "internlm2-1.8b": (1.5e9, 2.3e9),
        "qwen3-8b": (7.5e9, 9e9),
        "deepseek-67b": (62e9, 70e9),
        "gemma2-2b": (2.2e9, 3.2e9),
        "recurrentgemma-2b": (2.2e9, 3.2e9),
        "arctic-480b": (430e9, 520e9),
        "deepseek-v2-236b": (210e9, 250e9),
        "internvl2-1b": (0.4e9, 0.9e9),  # LM backbone only (ViT is a stub)
        "xlstm-125m": (0.1e9, 0.17e9),
        "whisper-base": (0.05e9, 0.11e9),
    }
    for arch, (lo, hi) in expected.items():
        n = count_params(model_defs(get_config(arch)))
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B outside [{lo/1e9}, {hi/1e9}]"


def test_train_loss_decreases_xlstm():
    """A few steps on one small arch actually learn (sanity of the substrate)."""
    cfg = reduced(get_config("xlstm-125m"))
    params = materialize(jax.random.PRNGKey(0), model_defs(cfg), dtype_override=jnp.float32)
    batch = _batch(cfg, B=4, S=16)
    train_step, opt = make_train_step(cfg, TrainStepConfig(lr=3e-3))
    state = {"params": params, "opt": opt.init(params), "step": jnp.zeros((), jnp.int32)}
    losses = []
    for _ in range(8):
        state, m = train_step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]
